"""Repo-root pytest shim: make `python/` importable so
`pytest python/tests/` works from the repository root (the Makefile runs
pytest from inside `python/`; CI-style invocations run it from here)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent / "python"))
