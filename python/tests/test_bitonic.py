"""Pallas bitonic kernel vs pure-jnp oracle — the core L1 correctness signal."""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitonic, ref

I64_MIN = -(2**63)
I64_MAX = 2**63 - 1


def rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


@pytest.mark.parametrize("b,n", [(1, 2), (1, 64), (4, 16), (8, 128), (3, 256), (16, 512)])
def test_sort_matches_ref_uniform(b, n):
    x = jnp.asarray(
        rng(b * 1000 + n).integers(I64_MIN, I64_MAX, size=(b, n), dtype=np.int64)
    )
    got = bitonic.bitonic_sort_batched(x)
    np.testing.assert_array_equal(got, ref.sort_batched_ref(x))


@pytest.mark.parametrize("n", [4, 32, 128])
def test_sort_all_equal(n):
    x = jnp.full((3, n), 42, dtype=jnp.int64)
    got = bitonic.bitonic_sort_batched(x)
    np.testing.assert_array_equal(got, x)


@pytest.mark.parametrize("n", [8, 64])
def test_sort_reverse_and_presorted(n):
    fwd = jnp.arange(n, dtype=jnp.int64)[None, :]
    rev = fwd[:, ::-1]
    np.testing.assert_array_equal(bitonic.bitonic_sort_batched(rev), fwd)
    np.testing.assert_array_equal(bitonic.bitonic_sort_batched(fwd), fwd)


def test_sort_with_padding_sentinel():
    # rows padded with i64::MAX: padding must sort to the tail untouched.
    x = jnp.asarray(
        [[5, I64_MAX, 1, I64_MAX], [I64_MAX, I64_MAX, I64_MAX, I64_MAX]],
        dtype=jnp.int64,
    )
    got = bitonic.bitonic_sort_batched(x)
    np.testing.assert_array_equal(
        got,
        jnp.asarray(
            [[1, 5, I64_MAX, I64_MAX], [I64_MAX] * 4],
            dtype=jnp.int64,
        ),
    )


def test_sort_negative_keys():
    x = jnp.asarray([[0, -1, I64_MIN, I64_MAX, 7, -7, 3, 3]], dtype=jnp.int64)
    np.testing.assert_array_equal(
        bitonic.bitonic_sort_batched(x), ref.sort_batched_ref(x)
    )


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 6),
    logn=st.integers(0, 8),
    seed=st.integers(0, 2**31 - 1),
    dup=st.sampled_from([None, 1, 4]),
)
def test_sort_hypothesis_shapes_and_duplicates(b, logn, seed, dup):
    n = 2**logn
    g = rng(seed)
    if dup is None:
        x = g.integers(I64_MIN, I64_MAX, size=(b, n), dtype=np.int64)
    else:
        x = g.integers(0, dup + 1, size=(b, n)).astype(np.int64)
    x = jnp.asarray(x)
    got = bitonic.bitonic_sort_batched(x)
    np.testing.assert_array_equal(got, ref.sort_batched_ref(x))


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 4),
    logn=st.integers(1, 7),
    seed=st.integers(0, 2**31 - 1),
    nkeys=st.sampled_from([1, 2, 8]),
)
def test_sort_pairs_hypothesis_lexicographic(b, logn, seed, nkeys):
    """Heavy duplicates: (key, id) order must be strict and match lexsort."""
    n = 2**logn
    g = rng(seed)
    keys = jnp.asarray(g.integers(0, nkeys, size=(b, n)).astype(np.int64))
    ids = jnp.asarray(g.permutation(b * n).reshape(b, n).astype(np.int64))
    gk, gv = bitonic.bitonic_sort_pairs_batched(keys, ids)
    ek, ev = ref.sort_pairs_batched_ref(keys, ids)
    np.testing.assert_array_equal(gk, ek)
    np.testing.assert_array_equal(gv, ev)


def test_sort_pairs_unique_ids_total_order():
    keys = jnp.zeros((2, 16), dtype=jnp.int64)
    ids = jnp.asarray(
        np.stack([np.arange(16)[::-1], np.arange(16)]), dtype=jnp.int64
    )
    _, gv = bitonic.bitonic_sort_pairs_batched(keys, ids)
    np.testing.assert_array_equal(gv, jnp.stack([jnp.arange(16)] * 2))


@pytest.mark.parametrize("tile_b", [1, 2, 4])
def test_sort_tile_b_invariance(tile_b):
    x = jnp.asarray(
        rng(7).integers(I64_MIN, I64_MAX, size=(4, 64), dtype=np.int64)
    )
    got = bitonic.bitonic_sort_batched(x, tile_b=tile_b)
    np.testing.assert_array_equal(got, ref.sort_batched_ref(x))
