"""SSSS classifier kernel vs oracle, incl. the tie-breaking descent."""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import classify, ref

I64_MAX = 2**63 - 1


def rng(seed):
    return np.random.default_rng(seed)


def make_splitters(g, s, lo=-1000, hi=1000):
    vals = np.unique(g.integers(lo, hi, size=4 * s + 16, dtype=np.int64))
    while len(vals) < s:  # pathological collision case: widen draw
        vals = np.unique(
            np.concatenate([vals, g.integers(lo, hi, size=4 * s + 16, dtype=np.int64)])
        )
    idx = g.choice(len(vals), size=s, replace=False)
    return jnp.asarray(np.sort(vals[idx]))


def test_build_tree_is_eytzinger():
    s = jnp.asarray([10, 20, 30, 40, 50, 60, 70], dtype=jnp.int64)
    tree = classify.build_tree(s)
    # BFS of the balanced BST over [10..70]
    np.testing.assert_array_equal(
        np.asarray(tree)[1:], np.asarray([40, 20, 60, 10, 30, 50, 70])
    )


@pytest.mark.parametrize("b,n,s", [(1, 8, 1), (2, 64, 7), (4, 128, 31), (2, 256, 63)])
def test_classify_matches_ref(b, n, s):
    g = rng(b * n + s)
    spl = make_splitters(g, s)
    x = jnp.asarray(g.integers(-1200, 1200, size=(b, n), dtype=np.int64))
    tree = classify.build_tree(spl)
    got = classify.classify_batched(x, tree)
    np.testing.assert_array_equal(got, ref.classify_ref(x, spl))


def test_classify_exact_splitter_keys_go_left():
    # side='left' semantics: an element equal to splitter b lands in bucket b.
    spl = jnp.asarray([10, 20, 30], dtype=jnp.int64)
    tree = classify.build_tree(spl)
    x = jnp.asarray([[5, 10, 15, 20, 25, 30, 35, 10]], dtype=jnp.int64)
    got = classify.classify_batched(x, tree)
    np.testing.assert_array_equal(got, [[0, 0, 1, 1, 2, 2, 3, 0]])


def test_classify_extremes():
    spl = jnp.asarray([0], dtype=jnp.int64)
    tree = classify.build_tree(spl)
    x = jnp.asarray([[-(2**62), 2**62, 0, -1, 1]], dtype=jnp.int64)
    np.testing.assert_array_equal(
        classify.classify_batched(x, tree), [[0, 1, 0, 0, 1]]
    )


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    logn=st.integers(0, 7),
    h=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_classify_hypothesis(b, logn, h, seed):
    n, s = 2**logn, 2**h - 1
    g = rng(seed)
    spl = make_splitters(g, s, -(2**40), 2**40)
    x = jnp.asarray(
        g.integers(-(2**41), 2**41, size=(b, n), dtype=np.int64)
    )
    tree = classify.build_tree(spl)
    got = classify.classify_batched(x, tree)
    np.testing.assert_array_equal(got, ref.classify_ref(x, spl))


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    logn=st.integers(1, 6),
    h=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
    nkeys=st.sampled_from([1, 2, 5]),
)
def test_classify_tb_hypothesis_heavy_duplicates(b, logn, h, seed, nkeys):
    """The RAMS robustness core: equal keys split by origin id."""
    n, s = 2**logn, 2**h - 1
    g = rng(seed)
    keys = jnp.asarray(g.integers(0, nkeys, size=(b, n)).astype(np.int64))
    ids = jnp.asarray(g.permutation(b * n).reshape(b, n).astype(np.int64))
    # splitters: (key, id) pairs sorted lexicographically, unique ids
    skeys = np.sort(g.integers(0, nkeys, size=s)).astype(np.int64)
    sids = np.sort(g.choice(100_000, size=s, replace=False)).astype(np.int64)
    order = np.lexsort((sids, skeys))
    skeys, sids = jnp.asarray(skeys[order]), jnp.asarray(sids[order])
    ktree = classify.build_tree(skeys)
    itree = classify.build_tree(sids)
    got = classify.classify_tb_batched(keys, ids, ktree, itree)
    np.testing.assert_array_equal(got, ref.classify_tb_ref(keys, ids, skeys, sids))


def test_classify_tb_all_equal_keys_balances():
    """All keys identical: buckets determined purely by id — a perfect split.

    This is exactly why RAMS survives the Zero/DeterDupl instances.
    """
    b, n, s = 1, 64, 3
    keys = jnp.zeros((b, n), dtype=jnp.int64)
    ids = jnp.asarray(np.arange(n)[None, :], dtype=jnp.int64)
    skeys = jnp.zeros(s, dtype=jnp.int64)
    sids = jnp.asarray([15, 31, 47], dtype=jnp.int64)
    got = classify.classify_tb_batched(
        keys, ids, classify.build_tree(skeys), classify.build_tree(sids)
    )
    counts = np.bincount(np.asarray(got).ravel(), minlength=4)
    assert counts.tolist() == [16, 16, 16, 16]


def test_classify_tb_matches_plain_on_unique_keys():
    g = rng(3)
    keys = jnp.asarray(
        g.permutation(256)[:128].reshape(2, 64).astype(np.int64)
    )
    ids = jnp.asarray(np.arange(128).reshape(2, 64) + 1000, dtype=jnp.int64)
    spl = jnp.asarray([300, 400, 500], dtype=jnp.int64)  # disjoint from keys
    sids = jnp.asarray([0, 1, 2], dtype=jnp.int64)
    plain = classify.classify_batched(keys, classify.build_tree(spl))
    tb = classify.classify_tb_batched(
        keys, ids, classify.build_tree(spl), classify.build_tree(sids)
    )
    np.testing.assert_array_equal(plain, tb)
