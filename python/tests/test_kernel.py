"""L2 model-level tests + AOT round-trip smoke (HLO text artifacts)."""

from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

I64_MAX = 2**63 - 1


def rng(seed):
    return np.random.default_rng(seed)


def test_local_sort_model():
    x = jnp.asarray(
        rng(0).integers(-(2**62), 2**62, size=(8, 128), dtype=np.int64)
    )
    (got,) = model.local_sort(x)
    np.testing.assert_array_equal(got, ref.sort_batched_ref(x))


def test_local_sort_pairs_model():
    g = rng(1)
    keys = jnp.asarray(g.integers(0, 4, size=(4, 64)).astype(np.int64))
    ids = jnp.asarray(g.permutation(256).reshape(4, 64).astype(np.int64))
    gk, gv = model.local_sort_pairs(keys, ids)
    ek, ev = ref.sort_pairs_batched_ref(keys, ids)
    np.testing.assert_array_equal(gk, ek)
    np.testing.assert_array_equal(gv, ev)


@pytest.mark.parametrize("k", [2, 4, 8])
def test_sort_and_median_window(k):
    g = rng(k)
    x = jnp.asarray(g.integers(0, 10_000, size=(4, 64), dtype=np.int64))
    s, win = model.sort_and_median_window(x, k)
    np.testing.assert_array_equal(s, ref.sort_batched_ref(x))
    n = 64
    expect = np.asarray(s)[:, n // 2 - k // 2 : n // 2 + k // 2]
    np.testing.assert_array_equal(win, expect)


def test_median_window_merge_ref_centres():
    a = jnp.asarray([1, 2, 3, 4], dtype=jnp.int64)
    b = jnp.asarray([2, 3, 5, 9], dtype=jnp.int64)
    got = ref.median_window_merge_ref(a, b)
    # merged = [1,2,2,3,3,4,5,9]; centre 4-window = indices 2..5 = [2,3,3,4]
    np.testing.assert_array_equal(got, jnp.asarray([2, 3, 3, 4]))


def test_jit_lowering_compiles_static():
    """The exported graphs must lower + compile with fully static shapes."""
    spec = jax.ShapeDtypeStruct((4, 64), model.KEY_DTYPE)
    lowered = jax.jit(model.local_sort).lower(spec)
    assert lowered.compile() is not None


def test_aot_quick_roundtrip(tmp_path):
    """Run the AOT driver end-to-end (quick sizes) and sanity-check output."""
    out = tmp_path / "artifacts"
    pkg_root = Path(__file__).resolve().parent.parent  # python/
    proc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--quick"],
        capture_output=True,
        text=True,
        cwd=str(pkg_root),
    )
    assert proc.returncode == 0, proc.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert "model" in manifest
    for name in manifest:
        if name == "model":
            continue
        text = (out / f"{name}.hlo.txt").read_text()
        assert text.startswith("HloModule"), name
    model_text = (out / "model.hlo.txt").read_text()
    assert "HloModule" in model_text.splitlines()[0]
