"""L2: the node-local compute graph of the sorting stack, in JAX.

The paper's algorithms all share the same node-local phases: sort the local
fragment, (for RAMS/SSort) classify elements against a splitter tree, and
(for RQuick) extract the k-window around the local median that feeds the
single-reduction median approximation of §III-B. This module composes the
L1 Pallas kernels into the exported entry points that `aot.py` lowers to
HLO text and the Rust runtime executes via PJRT.

Everything here is build-time only — Python never runs on the sort path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import bitonic, classify

# i64 keys: the Rust side holds u64; u64 <-> i64 order-preserving mapping is
# key ^ (1 << 63), applied on the Rust side. Kernels sort i64 ascending.
KEY_DTYPE = jnp.int64
ID_DTYPE = jnp.int64


def local_sort(x: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Batched local sort: each row is one PE's (padded) fragment."""
    return (bitonic.bitonic_sort_batched(x),)


def local_sort_pairs(
    keys: jnp.ndarray, ids: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched local sort on (key, origin-id) lexicographic order.

    The id channel is the paper's implicit tie-breaker: equal keys acquire a
    strict total order without communicating any extra information.
    """
    ks, vs = bitonic.bitonic_sort_pairs_batched(keys, ids)
    return (ks, vs)


def classify_elements(
    x: jnp.ndarray, tree: jnp.ndarray
) -> tuple[jnp.ndarray]:
    """SSSS bucket index for every element; tree is the eytzinger layout."""
    return (classify.classify_batched(x, tree),)


def classify_elements_tb(
    keys: jnp.ndarray,
    ids: jnp.ndarray,
    ktree: jnp.ndarray,
    itree: jnp.ndarray,
) -> tuple[jnp.ndarray]:
    """Tie-breaking SSSS bucket index on (key, id) lexicographic order."""
    return (classify.classify_tb_batched(keys, ids, ktree, itree),)


def sort_and_median_window(x: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused RQuick local phase: sort rows, then extract the k-window around
    each row's median (§III-B leaf contribution).

    Padding (i64::MAX) sorts to the tail; callers with short rows pass the
    true length via the `valid` trick on the Rust side (window re-centred
    there). Here rows are assumed fully valid — the fused artifact is used
    for the common dense case.
    """
    s = bitonic.bitonic_sort_batched(x)
    n = s.shape[-1]
    lo = n // 2 - k // 2
    return (s, jax.lax.dynamic_slice_in_dim(s, lo, k, axis=-1))


def build_splitter_tree(sorted_splitters: jnp.ndarray) -> jnp.ndarray:
    """Host-side helper re-exported for tests and the AOT driver."""
    return classify.build_tree(sorted_splitters)
