"""AOT driver: lower the L2 graphs to HLO *text* artifacts for the Rust
runtime.

HLO text (NOT ``.serialize()``): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
``xla`` 0.1.6 crate binds) rejects (``proto.id() <= INT_MAX``). The text
parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Each artifact is one statically-shaped entry point; the Rust ArtifactStore
picks the artifact whose padded shape fits the request. ``make artifacts``
runs this once; Python never runs at sort time.

Usage: python -m compile.aot --out-dir ../artifacts [--quick]
"""

from __future__ import annotations

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# (batch, row) sizes for the batched local-sort artifacts. One executable
# per size; the Rust side pads fragments to the next size up.
SORT_SIZES = [(64, 64), (64, 256), (32, 1024), (16, 4096)]
PAIR_SIZES = [(64, 256), (32, 1024)]
# (batch, row, splitters) for the classifier artifacts; S = 2^h - 1.
CLASSIFY_SIZES = [(64, 256, 63), (32, 1024, 127)]
QUICK_SORT_SIZES = [(64, 256)]
QUICK_PAIR_SIZES = [(64, 256)]
QUICK_CLASSIFY_SIZES = [(64, 256, 63)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(fn, args, path: str) -> None:
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file alias")
    ap.add_argument(
        "--quick", action="store_true", help="emit only the smallest sizes"
    )
    ns = ap.parse_args()
    out_dir = ns.out_dir
    if ns.out is not None:
        out_dir = os.path.dirname(ns.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    sort_sizes = QUICK_SORT_SIZES if ns.quick else SORT_SIZES
    pair_sizes = QUICK_PAIR_SIZES if ns.quick else PAIR_SIZES
    classify_sizes = QUICK_CLASSIFY_SIZES if ns.quick else CLASSIFY_SIZES

    manifest: dict[str, dict] = {}

    for b, n in sort_sizes:
        spec = jax.ShapeDtypeStruct((b, n), model.KEY_DTYPE)
        name = f"sort_i64_{b}x{n}"
        emit(model.local_sort, (spec,), os.path.join(out_dir, f"{name}.hlo.txt"))
        manifest[name] = {"kind": "sort", "batch": b, "n": n}

    for b, n in pair_sizes:
        kspec = jax.ShapeDtypeStruct((b, n), model.KEY_DTYPE)
        ispec = jax.ShapeDtypeStruct((b, n), model.ID_DTYPE)
        name = f"sort_pairs_i64_{b}x{n}"
        emit(
            model.local_sort_pairs,
            (kspec, ispec),
            os.path.join(out_dir, f"{name}.hlo.txt"),
        )
        manifest[name] = {"kind": "sort_pairs", "batch": b, "n": n}

    for b, n, s in classify_sizes:
        xspec = jax.ShapeDtypeStruct((b, n), model.KEY_DTYPE)
        tspec = jax.ShapeDtypeStruct((s + 1,), model.KEY_DTYPE)
        name = f"classify_i64_{b}x{n}_s{s}"
        emit(
            model.classify_elements,
            (xspec, tspec),
            os.path.join(out_dir, f"{name}.hlo.txt"),
        )
        manifest[name] = {"kind": "classify", "batch": b, "n": n, "splitters": s}

        ispec = jax.ShapeDtypeStruct((b, n), model.ID_DTYPE)
        itspec = jax.ShapeDtypeStruct((s + 1,), model.ID_DTYPE)
        name = f"classify_tb_i64_{b}x{n}_s{s}"
        emit(
            model.classify_elements_tb,
            (xspec, ispec, tspec, itspec),
            os.path.join(out_dir, f"{name}.hlo.txt"),
        )
        manifest[name] = {
            "kind": "classify_tb",
            "batch": b,
            "n": n,
            "splitters": s,
        }

    # Canonical single artifact (Makefile dependency + quickstart).
    b, n = sort_sizes[0]
    spec = jax.ShapeDtypeStruct((b, n), model.KEY_DTYPE)
    emit(model.local_sort, (spec,), os.path.join(out_dir, "model.hlo.txt"))
    manifest["model"] = {"kind": "sort", "batch": b, "n": n}

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    # plain-text manifest for the (dependency-light) Rust loader:
    #   name kind batch n [splitters]
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("# name kind batch n splitters\n")
        for name in sorted(manifest):
            m = manifest[name]
            f.write(
                f"{name} {m['kind']} {m['batch']} {m['n']} "
                f"{m.get('splitters', 0)}\n"
            )
    print(f"  wrote {os.path.join(out_dir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
