"""L1 Pallas kernel: Super Scalar Sample Sort element classifier.

The partitioning hot-spot of RAMS (and SSort): assign every local element to
one of S+1 buckets delimited by S sorted splitters, using the branchless
perfect-binary-tree descent of Sanders & Winkel's Super Scalar Sample Sort
[26] — log2(S+1) fused select steps over the whole tile, no data-dependent
branches.

Two variants:
  * ``classify_batched``       — plain keys (nonrobust / unique-key path).
  * ``classify_tb_batched``    — tie-breaking descent on (key, id)
    lexicographic order (App. G): equal keys are split by origin id, which
    is exactly how RAMS simulates unique keys with no extra communication.

S must be 2^h - 1 (perfect tree). The splitter tree is laid out in
breadth-first order tree[1..S]; see ``build_tree`` .
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def build_tree(sorted_splitters: jnp.ndarray) -> jnp.ndarray:
    """Breadth-first perfect-tree layout, 1-based: tree[0] unused.

    Equivalent to the eytzinger layout of the sorted splitter array.
    """
    s = sorted_splitters.shape[-1]
    assert (s + 1) & s == 0, "need 2^h - 1 splitters"
    tree = [None] * (s + 1)

    def fill(t: int, lo: int, hi: int):
        if t > s:
            return
        mid = (lo + hi) // 2
        tree[t] = sorted_splitters[mid]
        fill(2 * t, lo, mid - 1)
        fill(2 * t + 1, mid + 1, hi)

    fill(1, 0, s - 1)
    tree[0] = tree[1]
    return jnp.stack(tree)


def _descend(x, tree, s):
    """Branchless descent: after log2(s+1) steps t-(s+1) = #splitters < x."""
    h = (s + 1).bit_length() - 1
    t = jnp.ones(x.shape, dtype=jnp.int32)
    for _ in range(h):
        node = jnp.take(tree, t, axis=0)
        t = 2 * t + (node < x).astype(jnp.int32)
    return t - (s + 1)


def _descend_tb(k, i, ktree, itree, s):
    """Tie-breaking descent on strict lexicographic (key, id) order."""
    h = (s + 1).bit_length() - 1
    t = jnp.ones(k.shape, dtype=jnp.int32)
    for _ in range(h):
        nk = jnp.take(ktree, t, axis=0)
        ni = jnp.take(itree, t, axis=0)
        less = (nk < k) | ((nk == k) & (ni < i))
        t = 2 * t + less.astype(jnp.int32)
    return t - (s + 1)


def _classify_kernel(x_ref, tree_ref, o_ref, *, s: int):
    o_ref[...] = _descend(x_ref[...], tree_ref[...], s)


def _classify_tb_kernel(k_ref, i_ref, kt_ref, it_ref, o_ref, *, s: int):
    o_ref[...] = _descend_tb(
        k_ref[...], i_ref[...], kt_ref[...], it_ref[...], s
    )


def classify_batched(
    x: jnp.ndarray, tree: jnp.ndarray, *, tile_b: int | None = None
) -> jnp.ndarray:
    """Bucket index (0..S) for each element of ``x`` (B, N).

    ``tree`` is the (S+1,) breadth-first splitter tree from ``build_tree``.
    Bucket = number of splitters strictly less than the key, matching
    ``ref.classify_ref`` (searchsorted side='left').
    """
    b, n = x.shape
    s = tree.shape[0] - 1
    tb = tile_b or min(b, max(1, 2**16 // max(n, 1)))
    while b % tb != 0:
        tb -= 1
    return pl.pallas_call(
        functools.partial(_classify_kernel, s=s),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
            pl.BlockSpec((s + 1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tb, n), lambda i: (i, 0)),
        interpret=True,
    )(x, tree)


def classify_tb_batched(
    keys: jnp.ndarray,
    ids: jnp.ndarray,
    ktree: jnp.ndarray,
    itree: jnp.ndarray,
    *,
    tile_b: int | None = None,
) -> jnp.ndarray:
    """Tie-breaking bucket index on (key, id) lexicographic order."""
    b, n = keys.shape
    s = ktree.shape[0] - 1
    tb = tile_b or min(b, max(1, 2**15 // max(n, 1)))
    while b % tb != 0:
        tb -= 1
    return pl.pallas_call(
        functools.partial(_classify_tb_kernel, s=s),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
            pl.BlockSpec((s + 1,), lambda i: (0,)),
            pl.BlockSpec((s + 1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tb, n), lambda i: (i, 0)),
        interpret=True,
    )(keys, ids, ktree, itree)
