"""L1 Pallas kernel: batched bitonic sorting network.

The node-local sort hot-spot of every algorithm in the paper (each PE sorts
its O(n/p) fragment before any communication). Expressed as a data-parallel
compare-exchange network over a (B, N) tile so the whole batch of PE
fragments sorts in one fused kernel.

TPU mapping (see DESIGN.md §Hardware-Adaptation): BlockSpec tiles the batch
dimension; each (TB, N) tile lives in VMEM and the O(log^2 N) network stages
are pure element-wise min/max + lane shuffles on the VPU — no MXU needed, no
HBM traffic between stages. ``interpret=True`` everywhere: the CPU PJRT
client cannot run Mosaic custom-calls, and correctness is what we validate
here (real-TPU perf is estimated analytically in DESIGN.md).

N and B are static (one AOT artifact per padded size). Rows are padded with
+inf-equivalent (i64::MAX) by the Rust caller; padding sorts to the tail and
is dropped after the call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange_rows(x: jnp.ndarray, n: int) -> jnp.ndarray:
    """Run the full bitonic network over the last axis of ``x`` (rows).

    Static Python loops — N is a compile-time constant, so the whole network
    unrolls into O(log^2 N) vectorized min/max stages.
    """
    b = x.shape[0]
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            nb = n // (2 * j)
            y = x.reshape(b, nb, 2, j)
            # direction bit: ascending iff bit `k` of the element index is 0;
            # constant within a j-block because j <= k/2.
            asc = ((jnp.arange(nb) * 2 * j) & k) == 0
            lo = jnp.minimum(y[:, :, 0, :], y[:, :, 1, :])
            hi = jnp.maximum(y[:, :, 0, :], y[:, :, 1, :])
            first = jnp.where(asc[None, :, None], lo, hi)
            second = jnp.where(asc[None, :, None], hi, lo)
            x = jnp.stack([first, second], axis=2).reshape(b, n)
            j //= 2
        k *= 2
    return x


def _compare_exchange_pairs(
    keys: jnp.ndarray, vals: jnp.ndarray, n: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bitonic network on (key, val) lexicographic order (tie-break by val)."""
    b = keys.shape[0]
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            nb = n // (2 * j)
            yk = keys.reshape(b, nb, 2, j)
            yv = vals.reshape(b, nb, 2, j)
            asc = (((jnp.arange(nb) * 2 * j) & k) == 0)[None, :, None]
            ak, bk = yk[:, :, 0, :], yk[:, :, 1, :]
            av, bv = yv[:, :, 0, :], yv[:, :, 1, :]
            # swap needed (for ascending) iff (ak, av) > (bk, bv)
            gt = (ak > bk) | ((ak == bk) & (av > bv))
            swap = jnp.where(asc, gt, ~gt)
            k0 = jnp.where(swap, bk, ak)
            k1 = jnp.where(swap, ak, bk)
            v0 = jnp.where(swap, bv, av)
            v1 = jnp.where(swap, av, bv)
            keys = jnp.stack([k0, k1], axis=2).reshape(b, n)
            vals = jnp.stack([v0, v1], axis=2).reshape(b, n)
            j //= 2
        k *= 2
    return keys, vals


def _sort_kernel(x_ref, o_ref, *, n: int):
    o_ref[...] = _compare_exchange_rows(x_ref[...], n)


def _sort_pairs_kernel(k_ref, v_ref, ok_ref, ov_ref, *, n: int):
    ks, vs = _compare_exchange_pairs(k_ref[...], v_ref[...], n)
    ok_ref[...] = ks
    ov_ref[...] = vs


def bitonic_sort_batched(
    x: jnp.ndarray, *, tile_b: int | None = None
) -> jnp.ndarray:
    """Sort each row of ``x`` (B, N) ascending via the Pallas network.

    N must be a power of two. The batch is tiled with BlockSpec so each
    (tile_b, N) block is one grid step (one VMEM tile on real hardware).
    """
    b, n = x.shape
    assert n & (n - 1) == 0, "row length must be a power of two"
    tb = tile_b or min(b, max(1, 2**18 // max(n, 1)))
    while b % tb != 0:
        tb -= 1
    return pl.pallas_call(
        functools.partial(_sort_kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        grid=(b // tb,),
        in_specs=[pl.BlockSpec((tb, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tb, n), lambda i: (i, 0)),
        interpret=True,
    )(x)


def bitonic_sort_pairs_batched(
    keys: jnp.ndarray, vals: jnp.ndarray, *, tile_b: int | None = None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sort rows of (keys, vals) by (key, val) lexicographic order.

    The val channel carries the paper's tie-breaking origin id, so equal
    keys still acquire a strict total order (robustness against duplicates).
    """
    b, n = keys.shape
    assert keys.shape == vals.shape
    assert n & (n - 1) == 0, "row length must be a power of two"
    tb = tile_b or min(b, max(1, 2**17 // max(n, 1)))
    while b % tb != 0:
        tb -= 1
    return pl.pallas_call(
        functools.partial(_sort_pairs_kernel, n=n),
        out_shape=(
            jax.ShapeDtypeStruct((b, n), keys.dtype),
            jax.ShapeDtypeStruct((b, n), vals.dtype),
        ),
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
        ),
        interpret=True,
    )(keys, vals)
