"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package
must match its oracle exactly (integer keys) under pytest + hypothesis.

Key encoding
------------
Sort keys travel through XLA as ``int32``/``int64``. The Rust side holds
``u64`` keys; order-preserving conversion u64 <-> i64 is ``key ^ (1 << 63)``
(same trick as u32 <-> i32). The kernels themselves are ordering-agnostic:
they sort signed integers ascending.
"""

from __future__ import annotations

import jax.numpy as jnp


def sort_batched_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Sort each row of a (B, N) array ascending. Oracle for bitonic kernel."""
    return jnp.sort(x, axis=-1)


def sort_pairs_batched_ref(keys: jnp.ndarray, vals: jnp.ndarray):
    """Sort rows by (key, val) lexicographically, permuting vals alongside.

    This mirrors the paper's tie-breaking quadruple ordering: compare
    (key, id) lexicographically, where id is a unique origin identifier.
    """
    def row(k, v):
        order = jnp.lexsort((v, k))
        return k[order], v[order]

    ks, vs = [], []
    for b in range(keys.shape[0]):
        k, v = row(keys[b], vals[b])
        ks.append(k)
        vs.append(v)
    return jnp.stack(ks), jnp.stack(vs)


def classify_ref(x: jnp.ndarray, splitters: jnp.ndarray) -> jnp.ndarray:
    """SSSS classifier oracle.

    For each element of ``x`` (shape (B, N)) return the bucket index in
    ``0..S`` given ``S`` sorted splitters (shape (S,)): the number of
    splitters <= would put equal keys right of the splitter; we use
    ``side='left'`` so bucket b holds elements in [splitters[b-1],
    splitters[b]) — equal keys go to the splitter's own bucket.
    """
    flat = jnp.searchsorted(splitters, x.reshape(-1), side="left")
    return flat.reshape(x.shape).astype(jnp.int32)


def classify_tb_ref(
    keys: jnp.ndarray,
    ids: jnp.ndarray,
    skeys: jnp.ndarray,
    sids: jnp.ndarray,
) -> jnp.ndarray:
    """Tie-breaking classifier oracle: compare (key, id) lexicographically.

    Elements are (keys, ids) of shape (B, N); splitters are (skeys, sids) of
    shape (S,), sorted lexicographically. Returns the bucket index = number
    of splitters strictly less than the element in (key, id) order. On
    unique keys this equals ``classify_ref`` with side='left' splitting.
    """
    k = keys[..., None]
    i = ids[..., None]
    less = (skeys[None, None, :] < k) | (
        (skeys[None, None, :] == k) & (sids[None, None, :] < i)
    )
    return less.sum(axis=-1).astype(jnp.int32)


def median_window_merge_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Oracle for one internal node of the binary median-reduction tree.

    ``a`` and ``b`` are sorted windows of length k (k even). Merge the 2k
    elements and return the centre k-window merged[k/2 : 3k/2] — per §III-B
    the node keeps the k elements closest to the merged median.
    """
    k = a.shape[-1]
    merged = jnp.sort(jnp.concatenate([a, b], axis=-1), axis=-1)
    return merged[..., k // 2 : k // 2 + k]
