//! The data-plane invariant, machine-wide: across a randomized
//! algorithms × distributions grid, the element count **charged** to the
//! α-β cost model through the [`rmps::sim::Exchange`] equals the element
//! count **delivered** to remote PEs. Every `Exchange::deliver` also
//! `debug_assert!`s the per-round equality, so running this grid in a
//! debug build exercises the assertion on every communication round of
//! every algorithm.

use rmps::algorithms::{find_sorter, Algorithm, Sorter};
use rmps::config::RunConfig;
use rmps::elements::Elem;
use rmps::input::{generate, Distribution};
use rmps::localsort::RustSort;
use rmps::rng::Rng;
use rmps::sim::Machine;

/// Run one cell directly on a `Machine` (the `Runner` hides its machine,
/// and the invariant counters live on the machine).
fn charged_and_moved(alg: Algorithm, cfg: &RunConfig, dist: Distribution) -> (u64, u64, u64) {
    let mut mach = Machine::new(cfg.p, cfg.cost);
    mach.mem_cap_elems = cfg.mem_cap_elems();
    let mut data = generate(cfg, dist);
    let sorter = alg.sorter();
    sorter.sort(&mut mach, &mut data, cfg, &mut RustSort);
    (mach.exchange_charged(), mach.exchange_moved(), mach.stats.words)
}

#[test]
fn charged_equals_moved_across_randomized_grid() {
    let mut rng = Rng::seeded(0xE0C4A46E, 0);
    let dists = Distribution::ALL;
    for case in 0..60 {
        let alg = Algorithm::ALL[rng.below(Algorithm::ALL.len() as u64) as usize];
        let dist = dists[rng.below(dists.len() as u64) as usize];
        let p = 1usize << (2 + rng.below(3)); // 4..16
        let m = match alg {
            Algorithm::Minisort => 1, // only valid at n = p
            _ => 1usize << rng.below(7), // 1..64
        };
        let cfg = RunConfig::default()
            .with_p(p)
            .with_n_per_pe(m)
            .with_seed(0xBEEF + case as u64);
        let ctx = format!("case {case}: {alg:?}/{dist:?}/p={p}/m={m}");
        let (charged, moved, words) = charged_and_moved(alg, &cfg, dist);
        assert_eq!(charged, moved, "{ctx}: charged element count must equal moved");
        assert!(
            charged <= words,
            "{ctx}: element words ({charged}) cannot exceed total words ({words})"
        );
    }
}

#[test]
fn every_algorithm_moves_data_through_the_plane() {
    // a dense run on p > 1 PEs must move elements — and every moved
    // element must have been charged
    let cfg = RunConfig::default().with_p(16).with_n_per_pe(16);
    for alg in Algorithm::ALL {
        if alg == Algorithm::Minisort {
            continue; // requires n = p; covered below
        }
        let (charged, moved, _) = charged_and_moved(alg, &cfg, Distribution::Staggered);
        assert_eq!(charged, moved, "{alg:?}");
        assert!(charged > 0, "{alg:?} moved no elements through the data plane");
    }
    let cfg = RunConfig::default().with_p(16).with_n_per_pe(1);
    let (charged, moved, _) = charged_and_moved(Algorithm::Minisort, &cfg, Distribution::Uniform);
    assert_eq!(charged, moved, "Minisort");
    assert!(charged > 0, "Minisort moved no elements through the data plane");
}

#[test]
fn invariant_holds_under_memory_cap_crashes() {
    // crashed runs abandon mid-superstep state; whatever was delivered
    // before the crash must still balance what was charged
    let mut cfg = RunConfig::default().with_p(16).with_n_per_pe(256);
    cfg.mem_cap_factor = Some(4.0);
    for dist in [Distribution::Zero, Distribution::DeterDupl] {
        for alg in [Algorithm::HykSort, Algorithm::NtbQuick, Algorithm::NtbAms, Algorithm::SSort] {
            let (charged, moved, _) = charged_and_moved(alg, &cfg, dist);
            assert_eq!(charged, moved, "{alg:?}/{dist:?}");
        }
    }
}

/// Randomized irregular h-relations: the 1-factor round-scheduled
/// delivery must charge and move totals identical to the monolithic
/// `post` path — for even and odd participant counts, with self-posts,
/// empty posts, coalescing repeats, and tagged runs in the mix — and
/// fill byte-identical mailboxes. (Debug builds additionally assert the
/// per-round charged == moved equality inside `deliver_1factor`.)
#[test]
fn one_factor_matches_monolithic_on_random_h_relations() {
    let mut rng = Rng::seeded(0x1FAC_7012, 0);
    for case in 0..40 {
        let p = 2 + rng.below(13) as usize; // 2..14, even and odd
        let n_posts = rng.below(40) as usize;
        // record the post script, then replay it on both machines
        let mut script: Vec<(usize, usize, u64, usize)> = Vec::new();
        for _ in 0..n_posts {
            let from = rng.below(p as u64) as usize;
            let to = rng.below(p as u64) as usize; // may equal `from`
            let tag = rng.below(4);
            let len = rng.below(9) as usize; // empty posts included
            script.push((from, to, tag, len));
        }
        let payload = |from: usize, len: usize, salt: usize| -> Vec<Elem> {
            (0..len).map(|i| Elem::new((salt * 1000 + i) as u64, from, i)).collect()
        };

        let cfg = RunConfig::default();
        let mut mono = Machine::new(p, cfg.cost);
        let mut ex = mono.exchange();
        for (s, &(from, to, tag, len)) in script.iter().enumerate() {
            ex.post_tagged(from, to, tag, payload(from, len, s));
        }
        let mono_in = ex.deliver(&mut mono);

        let mut fac = Machine::new(p, cfg.cost);
        let mut ex = fac.exchange();
        for (s, &(from, to, tag, len)) in script.iter().enumerate() {
            ex.post_tagged(from, to, tag, payload(from, len, s));
        }
        let pes: Vec<usize> = (0..p).collect();
        let fac_in = ex.deliver_1factor(&mut fac, &pes);

        let ctx = format!("case {case}: p={p}, {n_posts} posts");
        assert_eq!(mono.exchange_charged(), fac.exchange_charged(), "{ctx}: charged");
        assert_eq!(mono.exchange_moved(), fac.exchange_moved(), "{ctx}: moved");
        assert_eq!(fac.exchange_charged(), fac.exchange_moved(), "{ctx}: invariant");
        assert_eq!(mono.stats.words, fac.stats.words, "{ctx}: word volume");
        for pe in 0..p {
            assert_eq!(mono_in.runs(pe), fac_in.runs(pe), "{ctx}: mailbox of pe {pe}");
        }
        mono.recycle(mono_in);
        fac.recycle(fac_in);
    }
}

/// The AMS family drives every data exchange through `deliver_1factor`;
/// the machine-wide invariant must hold across a randomized grid exactly
/// as it does for the monolithic path of the other 15 sorters.
#[test]
fn ams_family_upholds_the_invariant_via_the_1_factor_path() {
    let mut rng = Rng::seeded(0x1FAC_7013, 0);
    for k in 1..=3u32 {
        let sorter = find_sorter(&format!("AMS-{k}")).expect("AMS family registered");
        for case in 0..8 {
            let p = 1usize << (2 + rng.below(3)); // 4..16
            let m = 1usize << rng.below(8); // 1..128
            let dist =
                Distribution::ALL[rng.below(Distribution::ALL.len() as u64) as usize];
            let cfg = RunConfig::default()
                .with_p(p)
                .with_n_per_pe(m)
                .with_seed(0xA3 + case as u64);
            let mut mach = Machine::new(cfg.p, cfg.cost);
            mach.mem_cap_elems = cfg.mem_cap_elems();
            let mut data = generate(&cfg, dist);
            sorter.sort(&mut mach, &mut data, &cfg, &mut RustSort);
            let ctx = format!("AMS-{k} case {case}: {dist:?}/p={p}/m={m}");
            assert_eq!(mach.exchange_charged(), mach.exchange_moved(), "{ctx}");
            assert!(mach.exchange_charged() <= mach.stats.words, "{ctx}");
        }
    }
}

#[test]
fn runner_reuse_keeps_counters_per_run() {
    // Machine::reset must zero the counters between batched runs
    let cfg = RunConfig::default().with_p(8).with_n_per_pe(8);
    let mut mach = Machine::new(cfg.p, cfg.cost);
    let sorter = Algorithm::RQuick.sorter();
    let mut data = generate(&cfg, Distribution::Uniform);
    sorter.sort(&mut mach, &mut data, &cfg, &mut RustSort);
    let first = mach.exchange_charged();
    assert!(first > 0);
    mach.reset(cfg.p, cfg.cost);
    assert_eq!(mach.exchange_charged(), 0);
    let mut data = generate(&cfg, Distribution::Uniform);
    sorter.sort(&mut mach, &mut data, &cfg, &mut RustSort);
    assert_eq!(mach.exchange_charged(), first, "deterministic rerun, pooled machine");
    assert_eq!(mach.exchange_charged(), mach.exchange_moved());
}
