//! The data-plane invariant, machine-wide: across a randomized
//! algorithms × distributions grid, the element count **charged** to the
//! α-β cost model through the [`rmps::sim::Exchange`] equals the element
//! count **delivered** to remote PEs. Every `Exchange::deliver` also
//! `debug_assert!`s the per-round equality, so running this grid in a
//! debug build exercises the assertion on every communication round of
//! every algorithm.

use rmps::algorithms::{Algorithm, Sorter};
use rmps::config::RunConfig;
use rmps::input::{generate, Distribution};
use rmps::localsort::RustSort;
use rmps::rng::Rng;
use rmps::sim::Machine;

/// Run one cell directly on a `Machine` (the `Runner` hides its machine,
/// and the invariant counters live on the machine).
fn charged_and_moved(alg: Algorithm, cfg: &RunConfig, dist: Distribution) -> (u64, u64, u64) {
    let mut mach = Machine::new(cfg.p, cfg.cost);
    mach.mem_cap_elems = cfg.mem_cap_elems();
    let mut data = generate(cfg, dist);
    let sorter = alg.sorter();
    sorter.sort(&mut mach, &mut data, cfg, &mut RustSort);
    (mach.exchange_charged(), mach.exchange_moved(), mach.stats.words)
}

#[test]
fn charged_equals_moved_across_randomized_grid() {
    let mut rng = Rng::seeded(0xE0C4A46E, 0);
    let dists = Distribution::ALL;
    for case in 0..60 {
        let alg = Algorithm::ALL[rng.below(Algorithm::ALL.len() as u64) as usize];
        let dist = dists[rng.below(dists.len() as u64) as usize];
        let p = 1usize << (2 + rng.below(3)); // 4..16
        let m = match alg {
            Algorithm::Minisort => 1, // only valid at n = p
            _ => 1usize << rng.below(7), // 1..64
        };
        let cfg = RunConfig::default()
            .with_p(p)
            .with_n_per_pe(m)
            .with_seed(0xBEEF + case as u64);
        let ctx = format!("case {case}: {alg:?}/{dist:?}/p={p}/m={m}");
        let (charged, moved, words) = charged_and_moved(alg, &cfg, dist);
        assert_eq!(charged, moved, "{ctx}: charged element count must equal moved");
        assert!(
            charged <= words,
            "{ctx}: element words ({charged}) cannot exceed total words ({words})"
        );
    }
}

#[test]
fn every_algorithm_moves_data_through_the_plane() {
    // a dense run on p > 1 PEs must move elements — and every moved
    // element must have been charged
    let cfg = RunConfig::default().with_p(16).with_n_per_pe(16);
    for alg in Algorithm::ALL {
        if alg == Algorithm::Minisort {
            continue; // requires n = p; covered below
        }
        let (charged, moved, _) = charged_and_moved(alg, &cfg, Distribution::Staggered);
        assert_eq!(charged, moved, "{alg:?}");
        assert!(charged > 0, "{alg:?} moved no elements through the data plane");
    }
    let cfg = RunConfig::default().with_p(16).with_n_per_pe(1);
    let (charged, moved, _) = charged_and_moved(Algorithm::Minisort, &cfg, Distribution::Uniform);
    assert_eq!(charged, moved, "Minisort");
    assert!(charged > 0, "Minisort moved no elements through the data plane");
}

#[test]
fn invariant_holds_under_memory_cap_crashes() {
    // crashed runs abandon mid-superstep state; whatever was delivered
    // before the crash must still balance what was charged
    let mut cfg = RunConfig::default().with_p(16).with_n_per_pe(256);
    cfg.mem_cap_factor = Some(4.0);
    for dist in [Distribution::Zero, Distribution::DeterDupl] {
        for alg in [Algorithm::HykSort, Algorithm::NtbQuick, Algorithm::NtbAms, Algorithm::SSort] {
            let (charged, moved, _) = charged_and_moved(alg, &cfg, dist);
            assert_eq!(charged, moved, "{alg:?}/{dist:?}");
        }
    }
}

#[test]
fn runner_reuse_keeps_counters_per_run() {
    // Machine::reset must zero the counters between batched runs
    let cfg = RunConfig::default().with_p(8).with_n_per_pe(8);
    let mut mach = Machine::new(cfg.p, cfg.cost);
    let sorter = Algorithm::RQuick.sorter();
    let mut data = generate(&cfg, Distribution::Uniform);
    sorter.sort(&mut mach, &mut data, &cfg, &mut RustSort);
    let first = mach.exchange_charged();
    assert!(first > 0);
    mach.reset(cfg.p, cfg.cost);
    assert_eq!(mach.exchange_charged(), 0);
    let mut data = generate(&cfg, Distribution::Uniform);
    sorter.sort(&mut mach, &mut data, &cfg, &mut RustSort);
    assert_eq!(mach.exchange_charged(), first, "deterministic rerun, pooled machine");
    assert_eq!(mach.exchange_charged(), mach.exchange_moved());
}
