//! Giant-p warm-path allocation scaling: on a 2^16-PE machine with one
//! element on every 243rd PE, a warm (second) run must allocate in
//! proportion to the *active* PEs and messages, never one-per-PE — the
//! host-cost half of the O(active + messages) superstep contract (the
//! simulated-cost half is pinned by the equivalence suites).
//!
//! This binary holds exactly ONE test: the counting global allocator is
//! process-wide, and a sibling `#[test]` running concurrently would
//! pollute the counted window. Keep it that way.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use rmps::algorithms::find_sorter;
use rmps::config::RunConfig;
use rmps::input::{generate, Distribution};
use rmps::localsort::RustSort;
use rmps::sim::Machine;

/// System allocator wrapped with a call counter (alloc/realloc/zeroed;
/// frees are not counted — the metric is allocation churn).
struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOC_COUNT.load(Relaxed)
}

#[test]
fn warm_giant_p_runs_allocate_with_messages_not_p() {
    let p = 1usize << 16;
    let cfg = RunConfig::default().with_p(p).with_sparsity(243).with_seed(0x61AA);
    // ~270 occupied PEs; per-sorter ceilings on the warm allocation count,
    // sized from the sorters' host structure with an order of magnitude of
    // headroom (wallclock-independent, so no flakiness margin needed):
    //  - GatherM/Robust: a binomial gather's group bookkeeping is a few
    //    allocations per round (log p rounds) plus one per occupied run —
    //    hundreds. p/4 = 16 384 is far above that and far below the ≥ p
    //    an accidental per-PE allocation path would cost.
    //  - RFIS: its √p × √p grid does Θ(√p) group collectives of size √p
    //    with a few allocations per member round — ~0.2·p legitimately.
    //    2·p still catches regressions that allocate per PE per hypercube
    //    round (≥ 8·p here).
    for (name, bound) in [("GatherM", p / 4), ("Robust", p / 4), ("RFIS", 2 * p)] {
        let sorter = find_sorter(name).expect("giant-p sorter registered");
        let mut mach = Machine::new(cfg.p, cfg.cost);
        mach.mem_cap_elems = cfg.mem_cap_elems();
        // inline PE rounds: pool workers would allocate on other threads
        // into the same process-wide counter
        mach.set_pe_jobs(1);
        let input = generate(&cfg, Distribution::Uniform);

        // cold run: dimensions the machine, fills the data-plane pools
        let mut data = input.clone();
        sorter.sort(&mut mach, &mut data, &cfg, &mut RustSort);
        assert!(!mach.crashed(), "{name}: cold run crashed: {:?}", mach.crash());
        assert_eq!(mach.exchange_charged(), mach.exchange_moved(), "{name}: cold run");

        // warm run on the reset machine — the input clone happens OUTSIDE
        // the counted window, so the delta is the simulation's own churn
        mach.reset(cfg.p, cfg.cost);
        mach.mem_cap_elems = cfg.mem_cap_elems();
        let mut data = input.clone();
        let before = alloc_count();
        sorter.sort(&mut mach, &mut data, &cfg, &mut RustSort);
        let warm = alloc_count() - before;
        assert!(!mach.crashed(), "{name}: warm run crashed: {:?}", mach.crash());
        assert_eq!(mach.exchange_charged(), mach.exchange_moved(), "{name}: warm run");
        assert!(
            (warm as usize) < bound,
            "{name}: {warm} warm-run allocations at p={p} (bound {bound}) — \
             an O(p) allocation path is back on the warm superstep path"
        );
    }
}
