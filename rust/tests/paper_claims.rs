//! The paper's headline claims, asserted as tests (DESIGN.md §4 shape
//! checks). Each test reproduces one qualitative result of §VII on a
//! scaled-down simulated machine — who wins, who crashes, what grows.

use rmps::algorithms::{run, Algorithm};
use rmps::config::RunConfig;
use rmps::experiments::{fig1, fig4, run_cell, NpPoint};
use rmps::input::{generate, Distribution};

/// §VII-A (1): GatherM sorts very sparse inputs fastest;
/// (3) RFIS is fastest for sparse/tiny inputs.
#[test]
fn claim_sparse_winners() {
    let base = RunConfig::default().with_p(1 << 8);
    // very sparse: one element every 27 PEs
    let g = run_cell(Algorithm::GatherM, Distribution::Uniform, &base, NpPoint::Sparse(27), 1);
    let r = run_cell(Algorithm::Rfis, Distribution::Uniform, &base, NpPoint::Sparse(27), 1);
    let q = run_cell(Algorithm::RQuick, Distribution::Uniform, &base, NpPoint::Sparse(27), 1);
    assert!(g.time <= r.time && g.time < q.time, "GatherM wins very sparse: g={} r={} q={}", g.time, r.time, q.time);
    // AllGatherM is "not competitive for any input size": at every point
    // some other algorithm is at least as fast (at massive p the paper
    // sees it lose outright; at simulated scale ties can occur on the
    // latency-only sparse points)
    for pt in [NpPoint::Sparse(27), NpPoint::Dense(1), NpPoint::Dense(64)] {
        let ag = run_cell(Algorithm::AllGatherM, Distribution::Uniform, &base, pt, 1);
        let best_other = [Algorithm::GatherM, Algorithm::Rfis, Algorithm::RQuick]
            .iter()
            .map(|&a| run_cell(a, Distribution::Uniform, &base, pt, 1).time)
            .fold(f64::INFINITY, f64::min);
        assert!(
            best_other <= ag.time,
            "AllGatherM must never win: {pt:?} ag={} best={}",
            ag.time,
            best_other
        );
    }
    // one element per PE: RFIS beats RQuick and Bitonic (paper: >2×)
    let r1 = run_cell(Algorithm::Rfis, Distribution::Uniform, &base, NpPoint::Dense(1), 1);
    let q1 = run_cell(Algorithm::RQuick, Distribution::Uniform, &base, NpPoint::Dense(1), 1);
    let b1 = run_cell(Algorithm::Bitonic, Distribution::Uniform, &base, NpPoint::Dense(1), 1);
    assert!(r1.time < q1.time && r1.time < b1.time, "RFIS wins n=p: {} vs q {} b {}", r1.time, q1.time, b1.time);
}

/// §VII-A (4): RQuick wins the small-input regime robustly; its running
/// time barely depends on the instance.
#[test]
fn claim_rquick_small_input_robust_winner() {
    let base = RunConfig::default().with_p(1 << 8);
    let pt = NpPoint::Dense(1 << 10);
    let rq = run_cell(Algorithm::RQuick, Distribution::Uniform, &base, pt, 1);
    for alg in [Algorithm::Rams, Algorithm::SSort, Algorithm::Rfis] {
        let o = run_cell(alg, Distribution::Uniform, &base, pt, 1);
        assert!(rq.time < o.time, "RQuick {} vs {:?} {}", rq.time, alg, o.time);
    }
    // instance-insensitivity: hard instances cost within 2× of Uniform
    for d in [Distribution::Staggered, Distribution::Mirrored, Distribution::DeterDupl, Distribution::Zero] {
        let o = run_cell(Algorithm::RQuick, d, &base, pt, 1);
        assert!(!o.crashed && o.ok, "{d:?}");
        assert!(o.time < 2.0 * rq.time, "{d:?}: {} vs uniform {}", o.time, rq.time);
    }
}

/// §VII-A: HykSort is competitive for large Uniform inputs but crashes on
/// duplicate-heavy instances where RAMS keeps working; RAMS is the
/// robust/performance compromise for large inputs.
#[test]
fn claim_hyksort_fast_but_fragile() {
    let mut base = RunConfig::default().with_p(1 << 7);
    base.mem_cap_factor = Some(8.0);
    let pt = NpPoint::Dense(1 << 12);
    let hy = run_cell(Algorithm::HykSort, Distribution::Uniform, &base, pt, 1);
    let ra = run_cell(Algorithm::Rams, Distribution::Uniform, &base, pt, 1);
    assert!(!hy.crashed && !ra.crashed);
    // same ballpark on Uniform (paper: HykSort ≤1.38× faster)
    let ratio = hy.time / ra.time;
    assert!(ratio < 1.6, "HykSort/RAMS on Uniform = {ratio}");
    // but HykSort dies on DeterDupl; RAMS does not
    let hy_dd = run_cell(Algorithm::HykSort, Distribution::DeterDupl, &base, pt, 1);
    let ra_dd = run_cell(Algorithm::Rams, Distribution::DeterDupl, &base, pt, 1);
    assert!(hy_dd.crashed, "HykSort must crash on DeterDupl");
    assert!(!ra_dd.crashed && ra_dd.ok, "RAMS must survive DeterDupl");
}

/// §VII-B Fig. 2a: the price of RQuick's robustness on easy inputs is
/// bounded (paper: ≤ ~1.7× for large Uniform), while NTB-Quick fails or
/// explodes on skewed+duplicated instances.
#[test]
fn claim_price_and_payoff_of_rquick_robustness() {
    let mut cfg = RunConfig::default().with_p(1 << 7).with_n_per_pe(1 << 12);
    cfg.mem_cap_factor = Some(8.0);
    let r_uni = run(Algorithm::RQuick, &cfg, generate(&cfg, Distribution::Uniform));
    let n_uni = run(Algorithm::NtbQuick, &cfg, generate(&cfg, Distribution::Uniform));
    assert!(r_uni.succeeded() && n_uni.succeeded());
    let price = r_uni.time / n_uni.time;
    assert!(price < 2.2, "robustness price on Uniform {price}");
    // payoff: NTB-Quick on Mirrored/DeterDupl crashes or unbalances
    for d in [Distribution::Mirrored, Distribution::DeterDupl] {
        let n = run(Algorithm::NtbQuick, &cfg, generate(&cfg, d));
        assert!(
            n.crashed.is_some() || !n.validation.balanced || n.time > 2.0 * r_uni.time,
            "NTB-Quick should fail on {d:?}"
        );
        let r = run(Algorithm::RQuick, &cfg, generate(&cfg, d));
        assert!(r.succeeded(), "RQuick survives {d:?}");
    }
}

/// §VII-B Fig. 2c: DMA collapses the AllToOne hot spot (paper: up to 5.2×).
#[test]
fn claim_dma_speedup_on_all_to_one() {
    let cfg = RunConfig::default().with_p(1 << 9).with_n_per_pe(1 << 9);
    let dma = run(Algorithm::Rams, &cfg, generate(&cfg, Distribution::AllToOne));
    let ndma = run(Algorithm::NdmaAms, &cfg, generate(&cfg, Distribution::AllToOne));
    assert!(dma.succeeded(), "{:?}", dma.validation);
    let speedup = ndma.time / dma.time;
    assert!(speedup > 1.2, "DMA speedup on AllToOne = {speedup}");
}

/// §VII-B Fig. 2d: RAMS beats plain SSort by a wide margin (paper: up to
/// 1000× at 131 072 cores; at simulated scale the gap is smaller but
/// must be decisive).
#[test]
fn claim_rams_dominates_ssort() {
    let cfg = RunConfig::default().with_p(1 << 9).with_n_per_pe(1 << 9);
    let rams = run(Algorithm::Rams, &cfg, generate(&cfg, Distribution::Uniform));
    let ssort = run(Algorithm::SSort, &cfg, generate(&cfg, Distribution::Uniform));
    assert!(rams.succeeded());
    assert!(ssort.validation.ok());
    assert!(
        rams.time < 0.7 * ssort.time,
        "RAMS {} vs SSort {}",
        rams.time,
        ssort.time
    );
}

/// App. H / Fig. 4: the binary k-window tree approximates the median at
/// least as well as the ternary tree, and both errors decay as n^-γ.
#[test]
fn claim_binary_median_tree_quality() {
    let fig = fig4::run(14, 80, 7, rmps::exec::available_jobs());
    // compare at comparable n: binary 2^12=4096 vs ternary 3^8=6561 —
    // binary must not be wildly worse despite smaller n
    let b = fig.binary.iter().find(|p| p.n == 1 << 12).unwrap();
    let t = fig.ternary.iter().find(|p| p.n == 6561).unwrap();
    assert!(b.max_err < 2.0 * t.max_err, "binary {} vs ternary {}", b.max_err, t.max_err);
    assert!(fig.binary_fit.1 > 0.25, "binary γ = {}", fig.binary_fit.1);
}

/// Table I / Fig. 1: the full sweep runs; at every point *some* robust
/// algorithm succeeds — the paper's "four algorithms cover the entire
/// range of possible input sizes".
#[test]
fn claim_full_coverage_of_input_sizes() {
    let base = RunConfig::default().with_p(1 << 6);
    let fig = fig1::run(&base, 8, 1, rmps::exec::available_jobs());
    for &pt in &fig.points {
        for &d in &fig.distributions {
            let robust_ok = ["GatherM", "RFIS", "RQuick", "RAMS"].iter().any(|&a| {
                let c = fig.cell(d, pt, a);
                !c.crashed && c.ok
            });
            assert!(robust_ok, "no robust algorithm covers {d:?} at {pt:?}");
        }
    }
}
