//! The intra-run parallelism determinism contract: every `pe_jobs` value
//! must produce **bit-identical** [`RunReport`]s — the PE-task ledgers
//! settle in PE order regardless of which worker ran what (see the
//! `PeCtx` docs in `rmps::sim`), so `--pe-jobs 1`, `--pe-jobs 3`, and
//! `--pe-jobs <all cores>` are indistinguishable in everything but host
//! wallclock. The same contract covers the inline-vs-pooled gate:
//! `--par-min-work` values from `1` to `usize::MAX` only move rounds
//! between the caller's thread and the persistent pool, never the
//! report (`reports_identical_for_every_par_min_work_value`).
//!
//! Style of `exchange_equivalence.rs`: field-by-field equality (floats as
//! raw bits) over the 15 enum sorters (the registry-only AMS family gets
//! its own grid below) × a distributions/sizes grid,
//! including out-of-range inputs and memory-capped **crash reports** —
//! the crashing (PE, resident count, context) string must not depend on
//! worker interleaving either.

use rmps::algorithms::{find_sorter, Algorithm, RunReport, Runner};
use rmps::config::RunConfig;
use rmps::input::{generate, Distribution};

/// Field-by-field byte comparison (floats as raw bits). `wall_ms` is host
/// wallclock and exempt by nature.
fn assert_reports_identical(a: &RunReport, b: &RunReport, ctx: &str) {
    assert_eq!(a.algorithm, b.algorithm, "{ctx}: algorithm");
    assert_eq!(a.time.to_bits(), b.time.to_bits(), "{ctx}: time");
    assert_eq!(a.stats.messages, b.stats.messages, "{ctx}: messages");
    assert_eq!(a.stats.words, b.stats.words, "{ctx}: words");
    assert_eq!(
        a.stats.local_work.to_bits(),
        b.stats.local_work.to_bits(),
        "{ctx}: local_work"
    );
    assert_eq!(a.stats.max_mem_elems, b.stats.max_mem_elems, "{ctx}: max_mem_elems");
    assert_eq!(a.stats.max_degree, b.stats.max_degree, "{ctx}: max_degree");
    assert_eq!(a.crashed, b.crashed, "{ctx}: crashed");
    assert_eq!(a.output_shape, b.output_shape, "{ctx}: output_shape");
    assert_eq!(a.is_globally_sorted, b.is_globally_sorted, "{ctx}: is_globally_sorted");
    let (va, vb) = (&a.validation, &b.validation);
    assert_eq!(va.locally_sorted, vb.locally_sorted, "{ctx}: locally_sorted");
    assert_eq!(va.globally_sorted, vb.globally_sorted, "{ctx}: globally_sorted");
    assert_eq!(va.multiset_preserved, vb.multiset_preserved, "{ctx}: multiset");
    assert_eq!(va.balanced, vb.balanced, "{ctx}: balanced");
    assert_eq!(va.imbalance.max_load, vb.imbalance.max_load, "{ctx}: max_load");
    assert_eq!(va.imbalance.min_load, vb.imbalance.min_load, "{ctx}: min_load");
    assert_eq!(
        va.imbalance.epsilon.to_bits(),
        vb.imbalance.epsilon.to_bits(),
        "{ctx}: imbalance ε"
    );
    assert_eq!(a.output, b.output, "{ctx}: output");
}

/// The pe_jobs values under test: serial, a deliberately awkward odd
/// count, and everything the host has.
fn pe_jobs_values() -> Vec<usize> {
    let host = rmps::exec::available_jobs();
    let mut v = vec![1usize, 3];
    if !v.contains(&host) {
        v.push(host);
    }
    v
}

fn run_with_pe_jobs(alg: Algorithm, cfg: &RunConfig, input: Vec<Vec<rmps::elements::Elem>>, pe_jobs: usize) -> RunReport {
    let mut runner = Runner::new(cfg.clone()).pe_jobs(pe_jobs);
    runner.run_algorithm(alg, input)
}

/// All 15 algorithms × a (distribution, size) grid, serial as the
/// reference. `m = 512` (8192 elements at p = 16) clears the
/// `PAR_MIN_WORK` inline gate, so the pooled path really executes;
/// `m ∈ {1, 4, 64}` cover the inline path and the out-of-range crash
/// reports (Minisort on m ≠ 1).
#[test]
fn reports_identical_for_every_pe_jobs_value() {
    let dists = [Distribution::Uniform, Distribution::Zero, Distribution::Staggered];
    for &dist in &dists {
        for m in [1usize, 4, 64, 512] {
            let cfg = RunConfig::default().with_p(16).with_n_per_pe(m);
            for alg in Algorithm::ALL {
                let input = generate(&cfg, dist);
                let reference = run_with_pe_jobs(alg, &cfg, input.clone(), 1);
                for &jobs in &pe_jobs_values()[1..] {
                    let ctx = format!("{alg:?}/{dist:?}/m={m}/pe_jobs={jobs}");
                    let got = run_with_pe_jobs(alg, &cfg, input.clone(), jobs);
                    assert_reports_identical(&reference, &got, &ctx);
                }
            }
        }
    }
}

/// The AMS family (registry-only, no enum tag): classify and merge run
/// as pooled PE tasks and the 1-factor delivery charges one pairwise
/// round per schedule step — all of it must stay bit-identical for every
/// `pe_jobs` value, at sizes on both sides of the inline gate.
#[test]
fn ams_reports_identical_for_every_pe_jobs_value() {
    for k in 1..=3 {
        let sorter = find_sorter(&format!("AMS-{k}")).expect("AMS family registered");
        for dist in [Distribution::Uniform, Distribution::Zero, Distribution::AllToOne] {
            for m in [4usize, 512] {
                let cfg = RunConfig::default().with_p(16).with_n_per_pe(m);
                let input = generate(&cfg, dist);
                let reference = Runner::new(cfg.clone())
                    .pe_jobs(1)
                    .run(sorter.as_ref(), input.clone());
                for &jobs in &pe_jobs_values()[1..] {
                    let ctx = format!("AMS-{k}/{dist:?}/m={m}/pe_jobs={jobs}");
                    let got = Runner::new(cfg.clone())
                        .pe_jobs(jobs)
                        .run(sorter.as_ref(), input.clone());
                    assert_reports_identical(&reference, &got, &ctx);
                }
            }
        }
    }
}

/// The sparse regime (n < p): the selector hands off to GatherM, RFIS
/// routes across a mostly-empty grid, Bitonic refuses the input.
#[test]
fn sparse_reports_identical_for_every_pe_jobs_value() {
    let mut cfg = RunConfig::default().with_p(32).with_sparsity(8);
    cfg.mem_cap_factor = None;
    for alg in Algorithm::ALL {
        let input = generate(&cfg, Distribution::Uniform);
        let reference = run_with_pe_jobs(alg, &cfg, input.clone(), 1);
        for &jobs in &pe_jobs_values()[1..] {
            let ctx = format!("{alg:?}/sparse/pe_jobs={jobs}");
            let got = run_with_pe_jobs(alg, &cfg, input.clone(), jobs);
            assert_reports_identical(&reference, &got, &ctx);
        }
    }
}

/// Memory-capped hard instances: crash strings (PE, resident count,
/// context) must be identical under parallel execution — the first-crash
/// selection replays in PE order, not in worker-finish order. Sizes large
/// enough that the crashing phases run on the pool.
#[test]
fn crash_reports_identical_for_every_pe_jobs_value() {
    let mut cfg = RunConfig::default().with_p(16).with_n_per_pe(512);
    cfg.mem_cap_factor = Some(4.0);
    for dist in [Distribution::Zero, Distribution::DeterDupl] {
        for alg in [
            Algorithm::HykSort,
            Algorithm::NtbQuick,
            Algorithm::NtbAms,
            Algorithm::SSort,
            Algorithm::Rams,
            Algorithm::RQuick,
        ] {
            let input = generate(&cfg, dist);
            let reference = run_with_pe_jobs(alg, &cfg, input.clone(), 1);
            for &jobs in &pe_jobs_values()[1..] {
                let ctx = format!("{alg:?}/{dist:?}/capped/pe_jobs={jobs}");
                let got = run_with_pe_jobs(alg, &cfg, input.clone(), jobs);
                assert_reports_identical(&reference, &got, &ctx);
            }
        }
    }
}

/// The inline-vs-pooled gate is host scheduling too: RunReports must be
/// bit-identical for every `par_min_work` threshold — `1` (every round
/// on the persistent pool, large deliveries parallel-materialized),
/// the default, and `usize::MAX` (everything inline) — across sorters
/// that stress every data-plane flavour, at a size whose rounds straddle
/// the default gate.
#[test]
fn reports_identical_for_every_par_min_work_value() {
    let cfg = RunConfig::default().with_p(16).with_n_per_pe(512);
    for alg in [
        Algorithm::RQuick,
        Algorithm::Rams,
        Algorithm::Bitonic,
        Algorithm::Rfis,
        Algorithm::HykSort,
        Algorithm::Robust,
    ] {
        for dist in [Distribution::Uniform, Distribution::Staggered] {
            let input = generate(&cfg, dist);
            let reference = Runner::new(cfg.clone())
                .pe_jobs(3)
                .par_min_work(usize::MAX)
                .run_algorithm(alg, input.clone());
            for threshold in [1usize, rmps::sim::PAR_MIN_WORK] {
                let ctx = format!("{alg:?}/{dist:?}/par_min_work={threshold}");
                let got = Runner::new(cfg.clone())
                    .pe_jobs(3)
                    .par_min_work(threshold)
                    .run_algorithm(alg, input.clone());
                assert_reports_identical(&reference, &got, &ctx);
            }
        }
    }
    // and the AMS family's 1-factor delivery path
    let sorter = find_sorter("AMS-2").expect("AMS family registered");
    let input = generate(&cfg, Distribution::Uniform);
    let reference = Runner::new(cfg.clone())
        .pe_jobs(3)
        .par_min_work(usize::MAX)
        .run(sorter.as_ref(), input.clone());
    for threshold in [1usize, rmps::sim::PAR_MIN_WORK] {
        let got = Runner::new(cfg.clone())
            .pe_jobs(3)
            .par_min_work(threshold)
            .run(sorter.as_ref(), input.clone());
        assert_reports_identical(&reference, &got, &format!("AMS-2/par_min_work={threshold}"));
    }
}

/// Machine reuse across pe_jobs switches: one Runner, flipping the knob
/// between batched runs, still matches fresh runners bit for bit (the
/// ctx pool and scratch survive `reset` without leaking state).
#[test]
fn pe_jobs_switch_on_a_reused_runner_is_clean() {
    let cfg = RunConfig::default().with_p(16).with_n_per_pe(512);
    let input = generate(&cfg, Distribution::Staggered);
    let mut runner = Runner::new(cfg.clone()).pe_jobs(4);
    let first = runner.run_algorithm(Algorithm::Rams, input.clone());
    let mut runner = runner.pe_jobs(1);
    let second = runner.run_algorithm(Algorithm::Rams, input.clone());
    let mut runner = runner.pe_jobs(4);
    let third = runner.run_algorithm(Algorithm::Rams, input);
    assert_reports_identical(&first, &second, "pe_jobs 4 → 1 on one runner");
    assert_reports_identical(&first, &third, "pe_jobs 1 → 4 on one runner");
}
