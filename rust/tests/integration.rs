//! Integration tests: whole runs over the public API, cross-algorithm
//! agreement, and randomized property sweeps (hand-rolled — proptest is
//! not vendored in this offline environment; failures print the seed).

use rmps::algorithms::{run, Algorithm};
use rmps::config::RunConfig;
use rmps::elements::Elem;
use rmps::input::{generate, Distribution};
use rmps::rng::Rng;

/// All robust algorithms agree with a sequential sort of the same input.
#[test]
fn robust_algorithms_agree_with_sequential_oracle() {
    let cfg = RunConfig::default().with_p(16).with_n_per_pe(64);
    for dist in [Distribution::Uniform, Distribution::RandDupl, Distribution::Staggered] {
        let input = generate(&cfg, dist);
        let mut oracle: Vec<Elem> = input.iter().flatten().copied().collect();
        oracle.sort_unstable();
        let oracle_keys: Vec<u64> = oracle.iter().map(|e| e.key).collect();
        for alg in [Algorithm::Rfis, Algorithm::RQuick, Algorithm::Rams, Algorithm::Bitonic] {
            let report = run(alg, &cfg, input.clone());
            assert!(report.succeeded(), "{alg:?}/{dist:?}: {:?}", report.crashed);
            let got: Vec<u64> =
                report.output.iter().flatten().map(|e| e.key).collect();
            assert_eq!(got, oracle_keys, "{alg:?}/{dist:?} key sequence");
        }
    }
}

/// Property sweep: random (p, n/p, distribution, seed) quadruples — every
/// robust algorithm must produce sorted, multiset-preserving, balanced
/// output. 60 random cases; the failing seed is printed on assert.
#[test]
fn property_sweep_robust_algorithms() {
    let mut meta = Rng::seeded(0xD1CE, 0);
    for case in 0..60 {
        let p = 1usize << (2 + meta.below(5)); // 4..64
        let m = 1usize << meta.below(8); // 1..128
        let dist = Distribution::ALL[meta.below(Distribution::ALL.len() as u64) as usize];
        let seed = meta.next_u64();
        let cfg = RunConfig::default().with_p(p).with_n_per_pe(m).with_seed(seed);
        let input = generate(&cfg, dist);
        for alg in [Algorithm::RQuick, Algorithm::Rams, Algorithm::Rfis, Algorithm::Robust] {
            let report = run(alg, &cfg, input.clone());
            assert!(
                report.succeeded(),
                "case {case}: {alg:?} p={p} m={m} {dist:?} seed={seed:#x}: {:?} {:?}",
                report.crashed,
                report.validation
            );
        }
    }
}

/// Property sweep over sparse inputs (n < p).
#[test]
fn property_sweep_sparse() {
    let mut meta = Rng::seeded(0xBEEF, 1);
    for case in 0..30 {
        let p = 1usize << (3 + meta.below(5)); // 8..128
        let s = 2 + meta.below(9) as usize; // sparsity 2..10
        let dist =
            [Distribution::Uniform, Distribution::Zero, Distribution::Staggered][meta.below(3) as usize];
        let seed = meta.next_u64();
        let cfg = RunConfig::default().with_p(p).with_sparsity(s).with_seed(seed);
        let input = generate(&cfg, dist);
        for alg in [Algorithm::RQuick, Algorithm::Rfis, Algorithm::GatherM, Algorithm::Robust] {
            let report = run(alg, &cfg, input.clone());
            assert!(
                report.crashed.is_none() && report.validation.ok(),
                "case {case}: {alg:?} p={p} s={s} {dist:?} seed={seed:#x}: {:?} {:?}",
                report.crashed,
                report.validation
            );
        }
    }
}

/// Determinism: identical config → identical report (time, stats, output).
#[test]
fn runs_are_deterministic() {
    let cfg = RunConfig::default().with_p(32).with_n_per_pe(64);
    for alg in [Algorithm::RQuick, Algorithm::Rams, Algorithm::Rfis] {
        let a = run(alg, &cfg, generate(&cfg, Distribution::Staggered));
        let b = run(alg, &cfg, generate(&cfg, Distribution::Staggered));
        assert_eq!(a.time, b.time, "{alg:?} time");
        assert_eq!(a.stats.messages, b.stats.messages, "{alg:?} messages");
        assert_eq!(a.output, b.output, "{alg:?} output");
    }
}

/// The ids make every robust sort a *permutation-stable* total order:
/// outputs of different robust algorithms are identical element-for-element
/// on duplicate-heavy inputs (not just key-equal).
#[test]
fn tie_broken_outputs_are_identical_across_algorithms() {
    let cfg = RunConfig::default().with_p(16).with_n_per_pe(32);
    let input = generate(&cfg, Distribution::Zero);
    let a = run(Algorithm::Rfis, &cfg, input.clone());
    let b = run(Algorithm::Rams, &cfg, input.clone());
    assert!(a.succeeded() && b.succeeded());
    let flat_a: Vec<Elem> = a.output.iter().flatten().copied().collect();
    let flat_b: Vec<Elem> = b.output.iter().flatten().copied().collect();
    assert_eq!(flat_a, flat_b, "identical (key,id) total order");
}

/// Failure injection: tiny memory caps crash nonrobust algorithms but
/// never the robust ones.
#[test]
fn memory_pressure_only_kills_nonrobust() {
    let mut cfg = RunConfig::default().with_p(32).with_n_per_pe(256);
    cfg.mem_cap_factor = Some(6.0);
    for dist in [Distribution::Zero, Distribution::DeterDupl] {
        for alg in [Algorithm::RQuick, Algorithm::Rams, Algorithm::Rfis] {
            let r = run(alg, &cfg, generate(&cfg, dist));
            assert!(r.succeeded(), "{alg:?}/{dist:?} must survive: {:?}", r.crashed);
        }
        let ntb = run(Algorithm::NtbQuick, &cfg, generate(&cfg, dist));
        assert!(
            ntb.crashed.is_some() || !ntb.validation.balanced,
            "NTB-Quick should die on {dist:?}"
        );
    }
}

/// Empty machine (n = 0) and single-PE degenerate cases.
#[test]
fn degenerate_shapes() {
    // p = 1: everything is a local sort
    let cfg = RunConfig::default().with_p(1).with_n_per_pe(100);
    for alg in [Algorithm::RQuick, Algorithm::Rfis, Algorithm::GatherM] {
        let r = run(alg, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(r.validation.ok(), "{alg:?} on p=1: {:?}", r.validation);
    }
    // n = 0
    let cfg = RunConfig::default().with_p(8).with_n_per_pe(0);
    for alg in [Algorithm::RQuick, Algorithm::Rams, Algorithm::Rfis] {
        let r = run(alg, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(r.validation.multiset_preserved, "{alg:?} on n=0");
    }
}

/// One element per PE — the MPI_Comm_Split motivation (n = p).
#[test]
fn minisort_regime_n_equals_p() {
    let cfg = RunConfig::default().with_p(64).with_n_per_pe(1);
    for dist in [Distribution::Uniform, Distribution::Zero, Distribution::Mirrored] {
        for alg in [Algorithm::Rfis, Algorithm::RQuick, Algorithm::Robust] {
            let r = run(alg, &cfg, generate(&cfg, dist));
            assert!(r.succeeded(), "{alg:?}/{dist:?}: {:?}", r.validation);
        }
    }
}
