//! The API-redesign compatibility contract: the legacy `run` /
//! `run_with_backend` free functions are thin shims over the `Runner`
//! core and must produce **byte-identical** reports — simulated time,
//! stats, validation, shape, crash, output — for all 15 algorithms.
//! (`wall_ms` is host wallclock and is the one field exempt by nature.)
//!
//! Also pinned here: machine reuse across batched `Runner` runs changes
//! nothing, and the `validate`/`keep_output` opt-outs change payloads but
//! never the simulation.

use rmps::algorithms::{run, Algorithm, Runner, RunReport};
use rmps::config::RunConfig;
use rmps::input::{generate, Distribution};

/// Field-by-field byte comparison (floats as raw bits).
fn assert_reports_identical(a: &RunReport, b: &RunReport, ctx: &str) {
    assert_eq!(a.algorithm, b.algorithm, "{ctx}: algorithm");
    assert_eq!(a.time.to_bits(), b.time.to_bits(), "{ctx}: time");
    assert_eq!(a.stats.messages, b.stats.messages, "{ctx}: messages");
    assert_eq!(a.stats.words, b.stats.words, "{ctx}: words");
    assert_eq!(
        a.stats.local_work.to_bits(),
        b.stats.local_work.to_bits(),
        "{ctx}: local_work"
    );
    assert_eq!(a.stats.max_mem_elems, b.stats.max_mem_elems, "{ctx}: max_mem_elems");
    assert_eq!(a.stats.max_degree, b.stats.max_degree, "{ctx}: max_degree");
    assert_eq!(a.crashed, b.crashed, "{ctx}: crashed");
    assert_eq!(a.output_shape, b.output_shape, "{ctx}: output_shape");
    assert_eq!(a.is_globally_sorted, b.is_globally_sorted, "{ctx}: is_globally_sorted");
    let (va, vb) = (&a.validation, &b.validation);
    assert_eq!(va.locally_sorted, vb.locally_sorted, "{ctx}: locally_sorted");
    assert_eq!(va.globally_sorted, vb.globally_sorted, "{ctx}: globally_sorted");
    assert_eq!(va.multiset_preserved, vb.multiset_preserved, "{ctx}: multiset");
    assert_eq!(va.balanced, vb.balanced, "{ctx}: balanced");
    assert_eq!(va.imbalance.max_load, vb.imbalance.max_load, "{ctx}: max_load");
    assert_eq!(va.imbalance.min_load, vb.imbalance.min_load, "{ctx}: min_load");
    assert_eq!(
        va.imbalance.epsilon.to_bits(),
        vb.imbalance.epsilon.to_bits(),
        "{ctx}: imbalance ε"
    );
    assert_eq!(a.output, b.output, "{ctx}: output");
}

/// All 15 algorithms × a small (distribution, size) grid: the legacy shim
/// and a fresh `Runner` agree bit for bit. Out-of-range combinations
/// (Minisort on m ≠ 1, Bitonic on sparse) are included — their *crash
/// reports* must agree too.
#[test]
fn legacy_shims_match_runner_for_all_algorithms() {
    let dists = [Distribution::Uniform, Distribution::Zero, Distribution::Staggered];
    for &dist in &dists {
        for m in [1usize, 4, 64] {
            let cfg = RunConfig::default().with_p(16).with_n_per_pe(m);
            for alg in Algorithm::ALL {
                let ctx = format!("{alg:?}/{dist:?}/m={m}");
                let input = generate(&cfg, dist);
                let legacy = run(alg, &cfg, input.clone());
                let mut runner = Runner::new(cfg.clone());
                let new = runner.run_algorithm(alg, input);
                assert_reports_identical(&legacy, &new, &ctx);
            }
        }
    }
}

/// The sparse regime (n < p), where the selector hands off to GatherM and
/// the gather baselines shine.
#[test]
fn legacy_shims_match_runner_on_sparse_inputs() {
    let mut cfg = RunConfig::default().with_p(32).with_sparsity(8);
    cfg.mem_cap_factor = None; // gather-style runs concentrate Θ(n)
    for alg in Algorithm::ALL {
        let ctx = format!("{alg:?}/sparse");
        let input = generate(&cfg, Distribution::Uniform);
        let legacy = run(alg, &cfg, input.clone());
        let mut runner = Runner::new(cfg.clone());
        let new = runner.run_algorithm(alg, input);
        assert_reports_identical(&legacy, &new, &ctx);
    }
}

/// One `Runner` running a batch (different seeds, reused machine) agrees
/// bit for bit with fresh legacy runs of each item.
#[test]
fn batched_runner_matches_fresh_legacy_runs() {
    let base = RunConfig::default().with_p(16).with_n_per_pe(32);
    for alg in [Algorithm::RQuick, Algorithm::Rams, Algorithm::Robust, Algorithm::Rfis] {
        let batch: Vec<_> = (0..4u64)
            .map(|s| {
                let cfg = base.clone().with_seed(0xABC0DE + s);
                let input = generate(&cfg, Distribution::RandDupl);
                (cfg, input)
            })
            .collect();
        let sorter = alg.sorter();
        let mut runner = Runner::new(base.clone());
        let batched = runner.run_many(sorter.as_ref(), batch.clone());
        assert_eq!(batched.len(), batch.len());
        for ((cfg, input), got) in batch.into_iter().zip(&batched) {
            let fresh = run(alg, &cfg, input);
            assert_reports_identical(&fresh, got, &format!("{alg:?} batched"));
        }
    }
}

/// `validate(false)` / `keep_output(false)` strip payloads without
/// touching the simulation.
#[test]
fn opt_outs_preserve_simulation_results() {
    let cfg = RunConfig::default().with_p(16).with_n_per_pe(64);
    for alg in [Algorithm::RQuick, Algorithm::Mways, Algorithm::Robust] {
        let input = generate(&cfg, Distribution::Staggered);
        let full = run(alg, &cfg, input.clone());
        let mut lean_runner = Runner::new(cfg.clone()).validate(false).keep_output(false);
        let lean = lean_runner.run_algorithm(alg, input);
        assert_eq!(full.time.to_bits(), lean.time.to_bits(), "{alg:?}: time");
        assert_eq!(full.stats.messages, lean.stats.messages, "{alg:?}: messages");
        assert_eq!(full.stats.words, lean.stats.words, "{alg:?}: words");
        assert_eq!(full.crashed, lean.crashed, "{alg:?}: crashed");
        assert!(lean.output.is_empty(), "{alg:?}: output dropped");
        assert!(
            !lean.validation.ok() && !lean.is_globally_sorted,
            "{alg:?}: unvalidated reports must not claim success"
        );
        assert!(full.validation.ok(), "{alg:?}: the validated twin passes");
    }
}
