//! The hot-kernel rewrite contract (scatter partition, loser-tree merge,
//! radix local sort): every output must be **bit-identical** to what the
//! pre-rewrite kernels produced.
//!
//! The `reference` module below holds *verbatim* copies of the replaced
//! implementations — the label-vec + per-bucket-push `partition_with`,
//! the ping-pong cascade `multiway_merge_into` (including its historic
//! per-pass `reserve`), and the pdqsort run sort — frozen at the commit
//! that rewrote them. Each test drives the old and new kernel over the
//! same randomized grids (duplicate-heavy, all-equal, empty-run, and
//! 1-element cases included) and asserts equality of every byte.
//!
//! The final test pins the whole stack: `RadixSort` vs `RustSort` as the
//! `Runner` backend must yield field-identical `RunReport`s across all
//! FIG1 sorters (`wall_ms` exempt — host wallclock by nature).

use rmps::algorithms::{Algorithm, Runner, RunReport};
use rmps::config::RunConfig;
use rmps::elements::{
    cascade_merge_into, loser_tree_merge_into, multiway_merge_into, Elem, MergeScratch,
    LOSER_TREE_MIN_RUNS,
};
use rmps::input::{generate, Distribution};
use rmps::localsort::{radix_sort_run, RadixSort, RustSort, RADIX_MIN_RUN};
use rmps::partition::{
    partition, partition_scatter, pick_splitters, PartitionScratch, SplitterTree,
};
use rmps::rng::Rng;

/// Pre-rewrite kernels, copied verbatim (modulo `pub` and paths) from the
/// last commit before the scatter/loser-tree/radix rewrite. Do not
/// "improve" these: their whole value is being the frozen original.
mod reference {
    use rmps::elements::Elem;
    use rmps::partition::SplitterTree;

    /// Verbatim old `partition::partition_with`: label vec + counted
    /// per-bucket `Vec::push`, scalar classifier descents.
    fn partition_with(
        data: &[Elem],
        tree: &SplitterTree,
        tie_break: bool,
        mut bucket_buf: impl FnMut(usize) -> Vec<Elem>,
    ) -> Vec<Vec<Elem>> {
        let nb = tree.buckets();
        // two passes: count then place — cache-friendlier than push-per-bucket
        let mut counts = vec![0usize; nb];
        let mut labels = Vec::with_capacity(data.len());
        if tie_break {
            for e in data {
                let b = tree.classify_tb(e);
                labels.push(b as u32);
                counts[b] += 1;
            }
        } else {
            for e in data {
                let b = tree.classify_key(e.key);
                labels.push(b as u32);
                counts[b] += 1;
            }
        }
        let mut out: Vec<Vec<Elem>> = counts.iter().map(|&c| bucket_buf(c)).collect();
        for (e, &b) in data.iter().zip(&labels) {
            out[b as usize].push(*e);
        }
        out
    }

    pub fn partition(data: &[Elem], tree: &SplitterTree, tie_break: bool) -> Vec<Vec<Elem>> {
        partition_with(data, tree, tie_break, Vec::with_capacity)
    }

    /// Verbatim old `elements::merge_append`.
    fn merge_append(a: &[Elem], b: &[Elem], out: &mut Vec<Elem>) {
        out.reserve(a.len() + b.len());
        let (mut i, mut j) = (0, 0);
        while i < a.len() && j < b.len() {
            // `<=` keeps the merge stable in (key, id) order.
            if a[i] <= b[j] {
                out.push(a[i]);
                i += 1;
            } else {
                out.push(b[j]);
                j += 1;
            }
        }
        out.extend_from_slice(&a[i..]);
        out.extend_from_slice(&b[j..]);
    }

    /// Verbatim old `elements::MergeScratch` (pre loser-tree fields).
    #[derive(Clone, Debug, Default)]
    pub struct MergeScratch {
        tmp: Vec<Elem>,
        bounds: Vec<usize>,
        bounds_next: Vec<usize>,
    }

    /// Verbatim old `elements::multiway_merge_into`: the ⌈log k⌉-pass
    /// ping-pong cascade, per-pass `tmp.reserve(total)` and all.
    pub fn multiway_merge_into(runs: &[&[Elem]], out: &mut Vec<Elem>, scratch: &mut MergeScratch) {
        out.clear();
        let total: usize = runs.iter().map(|r| r.len()).sum();
        out.reserve(total);
        let MergeScratch { tmp, bounds, bounds_next } = scratch;
        bounds.clear();
        bounds.push(0);
        // pass 0 reads straight from the input runs (no up-front copy): merge
        // adjacent non-empty pairs into `out`, recording segment boundaries
        {
            let mut it = runs.iter().filter(|r| !r.is_empty());
            while let Some(a) = it.next() {
                match it.next() {
                    Some(b) => merge_append(a, b, out),
                    None => out.extend_from_slice(a),
                }
                bounds.push(out.len());
            }
        }
        // cascade: merge adjacent segments, ping-ponging between the buffers
        while bounds.len() > 2 {
            tmp.clear();
            tmp.reserve(total);
            bounds_next.clear();
            bounds_next.push(0);
            let segs = bounds.len() - 1;
            let mut s = 0;
            while s < segs {
                if s + 1 < segs {
                    // split_at so the two segment borrows and the write
                    // target are provably disjoint
                    let (a, rest) =
                        out[bounds[s]..bounds[s + 2]].split_at(bounds[s + 1] - bounds[s]);
                    merge_append(a, rest, tmp);
                    s += 2;
                } else {
                    tmp.extend_from_slice(&out[bounds[s]..bounds[s + 1]]);
                    s += 1;
                }
                bounds_next.push(tmp.len());
            }
            std::mem::swap(out, tmp);
            std::mem::swap(bounds, bounds_next);
        }
    }

    /// Verbatim old `RustSort::par_run_sort` body — the pdqsort path.
    pub fn pdqsort(run: &mut Vec<Elem>) {
        run.sort_unstable();
    }
}

// ---------------------------------------------------------------- inputs

/// One randomized run/bucket input. `key_space` controls duplicate
/// pressure (1 = all-equal keys); ids repeat every 7 elements so some
/// *fully equal* elements exist — the hardest case for stability.
fn random_elems(rng: &mut Rng, n: usize, key_space: u64) -> Vec<Elem> {
    (0..n)
        .map(|i| Elem::with_id(rng.below(key_space.max(1)), (i % 7) as u64))
        .collect()
}

/// The grid every kernel test sweeps: (len, key_space) covering empty,
/// 1-element, duplicate-heavy, all-equal, and wide-key cases.
const CASES: [(usize, u64); 9] = [
    (0, 1),
    (1, 1),
    (1, 1 << 32),
    (17, 5),
    (64, 1),
    (257, 3),
    (1024, 1 << 32),
    (1500, 2),
    (3000, 1 << 16),
];

// -------------------------------------------------------------- partition

/// New scatter partition (and the pooled `partition_scatter` core it is
/// built on) vs the verbatim old label-vec kernel: identical buckets,
/// identical order inside each bucket, for both classifiers, with the
/// scratch kept warm across every case and splitter count.
#[test]
fn scatter_partition_matches_old_label_vec_kernel() {
    let mut rng = Rng::seeded(0xD1CE, 7);
    let mut scratch = PartitionScratch::default();
    for s in [0usize, 1, 3, 7, 31, 127] {
        for (case, &(n, key_space)) in CASES.iter().enumerate() {
            let data = random_elems(&mut rng, n, key_space);
            let mut sample = data.clone();
            sample.sort();
            let splitters = pick_splitters(&sample, s);
            let tree = SplitterTree::new(&splitters);
            for tie_break in [false, true] {
                let ctx = format!("s={s} case={case} tb={tie_break}");
                let old = reference::partition(&data, &tree, tie_break);
                let new = partition(&data, &tree, tie_break);
                assert_eq!(old, new, "{ctx}: bucket vecs");
                let (flat, bounds) = partition_scatter(&data, &tree, tie_break, &mut scratch);
                assert_eq!(bounds.len(), tree.buckets() + 1, "{ctx}: bounds len");
                assert_eq!(*bounds.last().unwrap(), data.len(), "{ctx}: bounds total");
                for (b, w) in bounds.windows(2).enumerate() {
                    assert_eq!(&flat[w[0]..w[1]], &old[b][..], "{ctx}: segment {b}");
                }
            }
        }
    }
}

// ------------------------------------------------------------------ merge

/// Loser-tree merge vs the verbatim old cascade: bit-identical output for
/// every run count 0..=40 — straddling `LOSER_TREE_MIN_RUNS`, so both the
/// dispatcher's two-finger/cascade branch and the tree branch are hit —
/// with empty runs, 1-element runs, duplicate-heavy and all-equal keys,
/// and warm scratches throughout.
#[test]
fn loser_tree_merge_matches_old_cascade_bit_for_bit() {
    let mut rng = Rng::seeded(0xFEED, 11);
    let mut old_scratch = reference::MergeScratch::default();
    let mut scratch = MergeScratch::default();
    let mut tree_scratch = MergeScratch::default();
    let (mut old_out, mut new_out, mut tree_out) = (Vec::new(), Vec::new(), Vec::new());
    assert!((0..=40).count() > LOSER_TREE_MIN_RUNS);
    for k in 0usize..=40 {
        for &(span, key_space) in &[(9usize, 4u64), (33, 1), (70, 1 << 32)] {
            let runs: Vec<Vec<Elem>> = (0..k)
                .map(|i| {
                    // every 4th run empty, every 7th a single element
                    let n = if i % 4 == 3 {
                        0
                    } else if i % 7 == 6 {
                        1
                    } else {
                        rng.below(span as u64) as usize
                    };
                    let mut r = random_elems(&mut rng, n, key_space);
                    r.sort();
                    r
                })
                .collect();
            let refs: Vec<&[Elem]> = runs.iter().map(|r| r.as_slice()).collect();
            let ctx = format!("k={k} span={span} keys={key_space}");
            reference::multiway_merge_into(&refs, &mut old_out, &mut old_scratch);
            multiway_merge_into(&refs, &mut new_out, &mut scratch);
            assert_eq!(old_out, new_out, "{ctx}: dispatcher");
            loser_tree_merge_into(&refs, &mut tree_out, &mut tree_scratch);
            assert_eq!(old_out, tree_out, "{ctx}: loser tree");
            cascade_merge_into(&refs, &mut tree_out, &mut tree_scratch);
            assert_eq!(old_out, tree_out, "{ctx}: cascade");
        }
    }
}

// ------------------------------------------------------------- local sort

/// Radix local sort vs the verbatim old pdqsort path over the same grid
/// (plus boundary keys), both cold and with the thread-local radix
/// scratch warm.
#[test]
fn radix_local_sort_matches_old_pdqsort_path() {
    let mut rng = Rng::seeded(0xBEEF, 3);
    let mut cases: Vec<Vec<Elem>> = CASES
        .iter()
        .map(|&(n, key_space)| random_elems(&mut rng, n, key_space))
        .collect();
    // straddle the small-run fallback threshold and the key extremes
    cases.push(random_elems(&mut rng, RADIX_MIN_RUN - 1, 1 << 24));
    cases.push(random_elems(&mut rng, RADIX_MIN_RUN, 1 << 24));
    cases.push(vec![
        Elem::with_id(u64::MAX, u64::MAX),
        Elem::with_id(0, 0),
        Elem::with_id(u64::MAX, 0),
        Elem::with_id(0, u64::MAX),
    ]);
    for _pass in 0..2 {
        // pass 1 reruns every case with RADIX_TMP warm
        for (i, case) in cases.iter().enumerate() {
            let mut old = case.clone();
            let mut new = case.clone();
            reference::pdqsort(&mut old);
            radix_sort_run(&mut new);
            assert_eq!(old, new, "case {i} (n={})", case.len());
        }
    }
}

// ------------------------------------------------------- full-stack pin

/// Field-by-field byte comparison (floats as raw bits); `wall_ms` is host
/// wallclock and is the one field exempt by nature.
fn assert_reports_identical(a: &RunReport, b: &RunReport, ctx: &str) {
    assert_eq!(a.algorithm, b.algorithm, "{ctx}: algorithm");
    assert_eq!(a.time.to_bits(), b.time.to_bits(), "{ctx}: time");
    assert_eq!(a.stats.messages, b.stats.messages, "{ctx}: messages");
    assert_eq!(a.stats.words, b.stats.words, "{ctx}: words");
    assert_eq!(
        a.stats.local_work.to_bits(),
        b.stats.local_work.to_bits(),
        "{ctx}: local_work"
    );
    assert_eq!(a.stats.max_mem_elems, b.stats.max_mem_elems, "{ctx}: max_mem_elems");
    assert_eq!(a.stats.max_degree, b.stats.max_degree, "{ctx}: max_degree");
    assert_eq!(a.crashed, b.crashed, "{ctx}: crashed");
    assert_eq!(a.output_shape, b.output_shape, "{ctx}: output_shape");
    assert_eq!(a.is_globally_sorted, b.is_globally_sorted, "{ctx}: is_globally_sorted");
    let (va, vb) = (&a.validation, &b.validation);
    assert_eq!(va.locally_sorted, vb.locally_sorted, "{ctx}: locally_sorted");
    assert_eq!(va.globally_sorted, vb.globally_sorted, "{ctx}: globally_sorted");
    assert_eq!(va.multiset_preserved, vb.multiset_preserved, "{ctx}: multiset");
    assert_eq!(va.balanced, vb.balanced, "{ctx}: balanced");
    assert_eq!(va.imbalance.max_load, vb.imbalance.max_load, "{ctx}: max_load");
    assert_eq!(va.imbalance.min_load, vb.imbalance.min_load, "{ctx}: min_load");
    assert_eq!(
        va.imbalance.epsilon.to_bits(),
        vb.imbalance.epsilon.to_bits(),
        "{ctx}: imbalance ε"
    );
    assert_eq!(a.output, b.output, "{ctx}: output");
}

/// The backend choice must be invisible in every report field: `RadixSort`
/// vs `RustSort` across all FIG1 sorters on a (distribution, size) grid —
/// duplicate annihilation (Zero) and the skew instance (Staggered)
/// included, since those stress the tie-breaking (key, id) order the
/// radix kernel must reproduce exactly.
#[test]
fn radix_backend_reports_identical_to_pdqsort_across_fig1() {
    for &dist in &[Distribution::Uniform, Distribution::Zero, Distribution::Staggered] {
        for m in [1usize, 64] {
            let cfg = RunConfig::default().with_p(16).with_n_per_pe(m);
            for alg in Algorithm::FIG1 {
                let ctx = format!("{alg:?}/{dist:?}/m={m}");
                let input = generate(&cfg, dist);
                let mut pdq = Runner::new(cfg.clone()).backend(Box::new(RustSort));
                let mut radix = Runner::new(cfg.clone()).backend(Box::new(RadixSort));
                let a = pdq.run_algorithm(alg, input.clone());
                let b = radix.run_algorithm(alg, input);
                assert_reports_identical(&a, &b, &ctx);
            }
        }
    }
}
