//! Settlement equivalence: the batched superstep path (`begin_superstep`
//! → buffered `xchg`/`send`/`route_round` → `settle`) and the reusable
//! scratch buffers inside `route_round` must be **bit-identical** — clocks
//! and stats — to the historical per-call implementation on randomized
//! message rounds. The oracle below is a line-for-line copy of the
//! pre-refactor `route_round` (five fresh `vec![…; p]` per call).

use rmps::model::CostModel;
use rmps::prelude::Machine;
use rmps::rng::Rng;

/// Stats the oracle tracks (local_work is untouched by routing).
#[derive(Default)]
struct RefStats {
    messages: u64,
    words: u64,
    max_degree: usize,
}

/// The pre-refactor `Machine::route_round`, verbatim, over plain arrays.
fn reference_route_round(
    p: usize,
    clock: &mut [f64],
    cost: &CostModel,
    stats: &mut RefStats,
    msgs: &[(usize, usize, usize)],
) {
    if msgs.is_empty() {
        return;
    }
    let mut out = vec![0.0f64; p];
    let mut indeg = vec![0usize; p];
    let mut outdeg = vec![0usize; p];
    for &(from, _, l) in msgs {
        out[from] += cost.msg(l);
        outdeg[from] += 1;
    }
    let mut recv_ready = vec![0.0f64; p];
    for &(from, to, _) in msgs {
        if clock[from] > recv_ready[to] {
            recv_ready[to] = clock[from];
        }
        indeg[to] += 1;
    }
    let mut inc = vec![0.0f64; p];
    for &(_, to, l) in msgs {
        inc[to] += cost.msg(l);
    }
    for pe in 0..p {
        let mut t = clock[pe] + out[pe];
        if indeg[pe] > 0 {
            t = t.max(recv_ready[pe]) + inc[pe];
        }
        clock[pe] = t;
        let deg = indeg[pe].max(outdeg[pe]);
        if deg > stats.max_degree {
            stats.max_degree = deg;
        }
    }
    stats.messages += msgs.len() as u64;
    stats.words += msgs.iter().map(|&(_, _, l)| l as u64).sum::<u64>();
}

fn cost() -> CostModel {
    CostModel { alpha: 4000.0, beta: 13.0, cmp: 2.0, duplex: true }
}

/// One random irregular round: up to `3p` messages, arbitrary fan-in/out.
fn random_round(rng: &mut Rng, p: usize) -> Vec<(usize, usize, usize)> {
    let k = 1 + rng.below(3 * p as u64) as usize;
    (0..k)
        .map(|_| {
            let from = rng.below(p as u64) as usize;
            let mut to = rng.below(p as u64) as usize;
            if to == from {
                to = (to + 1) % p;
            }
            (from, to, rng.below(64) as usize)
        })
        .collect()
}

/// Direct `route_round` (scratch-buffer path) vs the allocation-per-call
/// oracle, over sequences of randomized rounds interleaved with local work.
#[test]
fn route_round_matches_reference_bit_for_bit() {
    let mut meta = Rng::seeded(0x5E77, 0);
    for case in 0..40 {
        let p = 1usize << (2 + meta.below(5)); // 4..64
        let mut mach = Machine::new(p, cost());
        let mut clock = vec![0.0f64; p];
        let mut stats = RefStats::default();
        for round in 0..4 {
            // random head start for a few PEs (identical on both sides)
            for _ in 0..meta.below(p as u64) {
                let pe = meta.below(p as u64) as usize;
                let w = meta.below(10_000) as f64;
                mach.work(pe, w);
                clock[pe] += w;
            }
            let msgs = random_round(&mut meta, p);
            mach.route_round(&msgs);
            reference_route_round(p, &mut clock, &cost(), &mut stats, &msgs);
            for pe in 0..p {
                assert_eq!(
                    mach.clock(pe).to_bits(),
                    clock[pe].to_bits(),
                    "case {case} round {round} pe {pe}: {} vs {}",
                    mach.clock(pe),
                    clock[pe]
                );
            }
            assert_eq!(mach.stats.messages, stats.messages, "case {case} round {round}");
            assert_eq!(mach.stats.words, stats.words, "case {case} round {round}");
            assert_eq!(mach.stats.max_degree, stats.max_degree, "case {case} round {round}");
        }
    }
}

/// Transcript mode: the same round delivered through `begin_superstep` +
/// several partial `route_round` calls + one `settle` must equal both the
/// eager path and the oracle, bit for bit.
#[test]
fn transcript_settle_matches_eager_and_reference() {
    let mut meta = Rng::seeded(0xBA7C, 1);
    for case in 0..40 {
        let p = 1usize << (2 + meta.below(5));
        let mut eager = Machine::new(p, cost());
        let mut batched = Machine::new(p, cost());
        let mut clock = vec![0.0f64; p];
        let mut stats = RefStats::default();
        for _ in 0..3 {
            let msgs = random_round(&mut meta, p);
            eager.route_round(&msgs);
            reference_route_round(p, &mut clock, &cost(), &mut stats, &msgs);
            // deliver the identical round in random-sized chunks
            batched.begin_superstep();
            let mut rest: &[(usize, usize, usize)] = &msgs;
            while !rest.is_empty() {
                let cut = 1 + meta.below(rest.len() as u64) as usize;
                batched.route_round(&rest[..cut]);
                rest = &rest[cut..];
            }
            batched.settle();
        }
        for pe in 0..p {
            assert_eq!(eager.clock(pe).to_bits(), batched.clock(pe).to_bits(), "case {case}");
            assert_eq!(batched.clock(pe).to_bits(), clock[pe].to_bits(), "case {case}");
        }
        assert_eq!(eager.stats.messages, batched.stats.messages);
        assert_eq!(batched.stats.messages, stats.messages);
        assert_eq!(batched.stats.words, stats.words);
        assert_eq!(batched.stats.max_degree, stats.max_degree);
    }
}

/// Pairwise rounds (one hypercube dimension: disjoint pairs, random mix of
/// `xchg` and `send`): buffered settlement == eager calls, bit for bit.
#[test]
fn transcript_pairwise_round_matches_eager() {
    let mut meta = Rng::seeded(0xD15C, 2);
    for case in 0..40 {
        let p = 1usize << (2 + meta.below(5));
        let mut eager = Machine::new(p, cost());
        let mut batched = Machine::new(p, cost());
        for _ in 0..4 {
            // random head starts, identical on both machines
            for pe in 0..p {
                let w = meta.below(5_000) as f64;
                eager.work(pe, w);
                batched.work(pe, w);
            }
            // random disjoint pairing: shuffle PEs, take adjacent pairs
            let mut pes: Vec<usize> = (0..p).collect();
            meta.shuffle(&mut pes);
            let ops: Vec<(usize, usize, usize, usize, bool)> = pes
                .chunks_exact(2)
                .map(|c| {
                    (
                        c[0],
                        c[1],
                        meta.below(64) as usize,
                        meta.below(64) as usize,
                        meta.coin(),
                    )
                })
                .collect();
            batched.begin_superstep();
            for &(a, b, l1, l2, is_xchg) in &ops {
                if is_xchg {
                    eager.xchg(a, b, l1, l2);
                    batched.xchg(a, b, l1, l2);
                } else {
                    eager.send(a, b, l1);
                    batched.send(a, b, l1);
                }
            }
            batched.settle();
        }
        for pe in 0..p {
            assert_eq!(
                eager.clock(pe).to_bits(),
                batched.clock(pe).to_bits(),
                "case {case} pe {pe}"
            );
        }
        assert_eq!(eager.stats.messages, batched.stats.messages, "case {case}");
        assert_eq!(eager.stats.words, batched.stats.words, "case {case}");
    }
}
