//! PJRT runtime integration: load the AOT HLO-text artifacts, execute them,
//! and check the XLA local-sort backend agrees bit-for-bit with pdqsort.
//!
//! Requires `make artifacts` (the tests locate the artifact dir relative to
//! CARGO_MANIFEST_DIR and skip loudly if it is missing).
//!
//! Compiled only with `--features xla`: the default test run needs neither
//! PJRT nor the artifacts.

#![cfg(feature = "xla")]

use rmps::algorithms::{run, run_with_backend, Algorithm};
use rmps::config::RunConfig;
use rmps::elements::{key_to_i64, Elem};
use rmps::input::{generate, Distribution};
use rmps::localsort::{RustSort, SortBackend};
use rmps::rng::Rng;
use rmps::runtime::{Runtime, XlaSort};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

macro_rules! need_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
                return;
            }
        }
    };
}

#[test]
fn sort_pairs_artifact_matches_host_sort() {
    let dir = need_artifacts!();
    let mut rt = Runtime::new(dir).expect("runtime");
    let (name, b, n) = ("sort_pairs_i64_64x256", 64usize, 256usize);
    let mut rng = Rng::seeded(11, 0);
    let keys: Vec<i64> = (0..b * n).map(|_| key_to_i64(rng.below(1 << 20))).collect();
    let ids: Vec<i64> = (0..b * n).map(|i| i as i64).collect();
    let (ok, oi) = rt.run_sort_pairs(name, b, n, &keys, &ids).expect("execute");
    for row in 0..b {
        let mut expect: Vec<(i64, i64)> = (0..n)
            .map(|c| (keys[row * n + c], ids[row * n + c]))
            .collect();
        expect.sort_unstable();
        let got: Vec<(i64, i64)> =
            (0..n).map(|c| (ok[row * n + c], oi[row * n + c])).collect();
        assert_eq!(got, expect, "row {row}");
    }
}

#[test]
fn classify_artifact_matches_host_classifier() {
    let dir = need_artifacts!();
    let mut rt = Runtime::new(dir).expect("runtime");
    let (name, b, n, s) = ("classify_i64_64x256_s63", 64usize, 256usize, 63usize);
    let mut rng = Rng::seeded(13, 0);
    // sorted splitters → eytzinger tree (tree[0] mirrors tree[1])
    let mut spl: Vec<i64> = (0..s).map(|_| key_to_i64(rng.below(1 << 20))).collect();
    spl.sort_unstable();
    spl.dedup();
    while spl.len() < s {
        spl.push(*spl.last().unwrap() + 1);
        spl.sort_unstable();
    }
    let elems: Vec<Elem> = spl
        .iter()
        .map(|&v| Elem::with_id(((v as u64) ^ (1 << 63)) as u64, 0))
        .collect();
    let tree = rmps::partition::SplitterTree::new(&elems);
    // rebuild the i64 eytzinger layout the way build_tree does in python
    let mut layout = vec![0i64; s + 1];
    fn fill(spl: &[i64], t: usize, lo: i64, hi: i64, out: &mut [i64]) {
        if t >= out.len() || hi < lo {
            return;
        }
        let mid = ((lo + hi) / 2) as usize;
        out[t] = spl[mid];
        fill(spl, 2 * t, lo, mid as i64 - 1, out);
        fill(spl, 2 * t + 1, mid as i64 + 1, hi, out);
    }
    fill(&spl, 1, 0, s as i64 - 1, &mut layout);
    layout[0] = layout[1];
    let keys: Vec<i64> = (0..b * n).map(|_| key_to_i64(rng.below(1 << 20))).collect();
    let got = rt.run_classify(name, b, n, &keys, &layout).expect("execute");
    for (i, &k) in keys.iter().enumerate() {
        let key_u = (k as u64) ^ (1 << 63);
        let expect = tree.classify_key(key_u) as i32;
        assert_eq!(got[i], expect, "element {i}");
    }
}

#[test]
fn xla_backend_agrees_with_rust_backend_end_to_end() {
    let dir = need_artifacts!();
    std::env::set_var("RMPS_ARTIFACTS", &dir);
    let cfg = RunConfig::default().with_p(64).with_n_per_pe(100);
    for dist in [Distribution::Uniform, Distribution::Zero] {
        let input = generate(&cfg, dist);
        let rust_report = run(Algorithm::RQuick, &cfg, input.clone());
        let mut xla = XlaSort::from_env().expect("xla backend");
        let xla_report = run_with_backend(Algorithm::RQuick, &cfg, input, &mut xla);
        assert!(rust_report.succeeded() && xla_report.succeeded());
        assert_eq!(
            rust_report.output, xla_report.output,
            "{dist:?}: backends must agree bit-for-bit"
        );
        assert_eq!(rust_report.time, xla_report.time, "virtual time is backend-independent");
        assert!(xla.exec_calls > 0, "XLA backend must actually run");
    }
}

#[test]
fn xla_backend_handles_oversized_runs_via_fallback() {
    let dir = need_artifacts!();
    let rt = Runtime::new(dir).expect("runtime");
    let mut xla = XlaSort::new(rt).expect("backend");
    // one run longer than the largest sort_pairs artifact row (1024)
    let mut rng = Rng::seeded(5, 5);
    let mut big: Vec<Elem> = (0..5000).map(|i| Elem::new(rng.next_u64(), 0, i)).collect();
    let mut small: Vec<Elem> = (0..50).map(|i| Elem::new(rng.next_u64(), 1, i)).collect();
    let mut runs: Vec<&mut Vec<Elem>> = vec![&mut big, &mut small];
    xla.sort_runs(&mut runs);
    assert!(rmps::elements::is_sorted(&big));
    assert!(rmps::elements::is_sorted(&small));
}
