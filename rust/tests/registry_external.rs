//! Acceptance test for the open sorter API: a brand-new sorter defined in
//! one file (here, this test crate — outside `rmps` entirely) becomes
//! visible to CLI-style name lookup, registry enumeration, and experiment
//! sweeps purely by implementing `Sorter` and calling `register` — no
//! edit to any dispatch table in `rmps::algorithms`.

use std::sync::Arc;

use rmps::algorithms::{
    builtin_sorters, find_sorter, register, registry, OutputShape, Runner, Sorter,
};
use rmps::config::RunConfig;
use rmps::elements::Elem;
use rmps::experiments::{fig1, NpPoint};
use rmps::input::{generate, Distribution};
use rmps::localsort::SortBackend;
use rmps::sim::Machine;

/// A deliberately naive external sorter: gather everything to PE 0, sort
/// centrally through the local-sort backend, scatter contiguous chunks
/// back. Correct (full `(key, id)` order, balanced) and honestly costed —
/// just slow, like a baseline somebody might plug in from outside.
struct CentralSorter;

impl Sorter for CentralSorter {
    fn name(&self) -> &'static str {
        "Central"
    }

    fn output_shape(&self) -> OutputShape {
        OutputShape::Balanced
    }

    fn is_robust(&self) -> bool {
        true
    }

    fn sort(
        &self,
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) -> OutputShape {
        let p = cfg.p;
        // gather: every non-empty PE ships its fragment to PE 0
        let gather: Vec<(usize, usize, usize)> = data
            .iter()
            .enumerate()
            .filter(|(pe, local)| *pe != 0 && !local.is_empty())
            .map(|(pe, local)| (pe, 0, local.len()))
            .collect();
        mach.route_round(&gather);

        let mut all: Vec<Elem> = data.iter().flatten().copied().collect();
        let n = all.len();
        mach.note_mem(0, n, "central gather");
        mach.work_sort(0, n);
        backend.sort_runs(&mut [&mut all]);

        // scatter contiguous chunks, ⌈n/p⌉ on the first n mod p PEs
        let (chunk, extra) = (n / p, n % p);
        let mut scatter = Vec::new();
        let mut start = 0;
        for (pe, local) in data.iter_mut().enumerate() {
            let len = chunk + usize::from(pe < extra);
            *local = all[start..start + len].to_vec();
            start += len;
            if pe != 0 && len > 0 {
                scatter.push((0, pe, len));
            }
        }
        mach.route_round(&scatter);
        OutputShape::Balanced
    }
}

#[test]
fn external_sorter_is_first_class() {
    register(Arc::new(CentralSorter)).expect("fresh name registers");

    // CLI parsing path (`rmps run --algo central` resolves through this)
    let found = find_sorter("central").expect("registered sorter parses");
    assert_eq!(found.name(), "Central");
    assert!(found.is_robust());

    // registry enumeration: built-ins plus the new one
    assert_eq!(registry().len(), builtin_sorters().len() + 1);
    assert!(registry().iter().any(|s| s.name() == "Central"));

    // duplicate names are rejected (case/separator-insensitively)
    assert!(register(Arc::new(CentralSorter)).is_err());

    // it runs through the Runner and meets the §II contract
    let cfg = RunConfig::default().with_p(16).with_n_per_pe(32);
    let mut runner = Runner::new(cfg.clone());
    for dist in [Distribution::Uniform, Distribution::Zero, Distribution::Staggered] {
        let report = runner.run(found.as_ref(), generate(&cfg, dist));
        assert!(report.succeeded(), "{dist:?}: {:?}", report.validation);
        assert_eq!(report.algorithm, "Central");
    }

    // experiment enumeration: a Fig. 1-style sweep over the *registry*
    // (all built-ins plus the external sorter) produces a cell for it
    let base = RunConfig { p: 1 << 3, ..Default::default() };
    let fig = fig1::run_with(&base, registry(), 2, 1, 2);
    let cell = fig.cell(Distribution::Uniform, NpPoint::Dense(4), "Central");
    assert!(!cell.crashed && cell.ok, "external cell: {cell:?}");
    // and the winner bookkeeping sees it like any built-in
    let _ = fig.winner(Distribution::Uniform, NpPoint::Dense(4));
}
