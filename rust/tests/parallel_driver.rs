//! The parallel experiment driver's determinism guarantee: any `--jobs`
//! count produces byte-identical figures, because every cell is a pure
//! function of its grid spec and results are assembled in grid order.

use rmps::config::RunConfig;
use rmps::experiments::{fig1, fig2, table1, tuning, NpPoint};

/// `--jobs 1` and `--jobs 8` produce identical Fig. 1 cells (times compared
/// as raw f64 bits — "byte-identical", not approximately equal).
#[test]
fn fig1_cells_identical_across_job_counts() {
    let base = RunConfig { p: 1 << 5, ..Default::default() };
    let serial = fig1::run(&base, 3, 1, 1);
    let parallel = fig1::run(&base, 3, 1, 8);
    assert_eq!(serial.cells.len(), parallel.cells.len());
    assert!(!serial.cells.is_empty());
    for (a, b) in serial.cells.iter().zip(&parallel.cells) {
        assert_eq!(a.algorithm, b.algorithm);
        assert_eq!(a.distribution, b.distribution);
        assert_eq!(a.point, b.point);
        assert_eq!(
            a.time.to_bits(),
            b.time.to_bits(),
            "{:?}/{:?}/{:?}: {} vs {}",
            a.algorithm,
            a.distribution,
            a.point,
            a.time,
            b.time
        );
        assert_eq!(a.crashed, b.crashed);
        assert_eq!(a.ok, b.ok);
        let (ra, rb) = (a.report.as_ref(), b.report.as_ref());
        assert_eq!(ra.is_some(), rb.is_some());
        if let (Some(ra), Some(rb)) = (ra, rb) {
            assert_eq!(ra.stats.messages, rb.stats.messages);
            assert_eq!(ra.stats.words, rb.stats.words);
        }
    }
}

/// The same holds for the ratio panels and the α/β footprint table.
#[test]
fn fig2_and_table1_identical_across_job_counts() {
    let base = RunConfig { p: 1 << 5, ..Default::default() };
    let points = [NpPoint::Dense(4), NpPoint::Dense(64)];
    let serial = fig2::fig2a(&base, &points, 1, 1);
    let parallel = fig2::fig2a(&base, &points, 1, 8);
    assert_eq!(serial.len(), parallel.len());
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(s.distribution, p.distribution);
        for (&(ra, ca, na), &(rb, cb, nb)) in s.ratios.iter().zip(&p.ratios) {
            assert_eq!(ra.to_bits(), rb.to_bits(), "{:?}", s.distribution);
            assert_eq!((ca, na), (cb, nb));
        }
    }

    let t_serial = table1::run_table(1 << 6, 1 << 4, 7, 1);
    let t_parallel = table1::run_table(1 << 6, 1 << 4, 7, 8);
    assert_eq!(t_serial.len(), t_parallel.len());
    for (a, b) in t_serial.iter().zip(&t_parallel) {
        assert_eq!(a.algorithm, b.algorithm);
        assert_eq!(a.small.messages_per_pe.to_bits(), b.small.messages_per_pe.to_bits());
        assert_eq!(a.large.words_per_pe.to_bits(), b.large.words_per_pe.to_bits());
        assert_eq!(a.msg_growth.to_bits(), b.msg_growth.to_bits());
    }
}

/// Tuning grids keep their (size, parameter) order under parallel fan-out.
#[test]
fn tuning_grid_identical_across_job_counts() {
    let serial = tuning::run(1 << 5, &[16, 64], 1);
    let parallel = tuning::run(1 << 5, &[16, 64], 6);
    assert_eq!(serial.rams_levels.len(), parallel.rams_levels.len());
    for (a, b) in serial.rams_levels.iter().zip(&parallel.rams_levels) {
        assert_eq!((a.0, a.1), (b.0, b.1));
        assert_eq!(a.2.to_bits(), b.2.to_bits());
    }
    for (a, b) in serial.hyksort_k.iter().zip(&parallel.hyksort_k) {
        assert_eq!((a.0, a.1), (b.0, b.1));
        assert_eq!(a.2.to_bits(), b.2.to_bits());
    }
    for (a, b) in serial.rquick_window.iter().zip(&parallel.rquick_window) {
        assert_eq!((a.0, a.1), (b.0, b.1));
        assert_eq!(a.2.to_bits(), b.2.to_bits());
    }
}
