//! The Exchange data-plane compatibility contract: porting every
//! algorithm's cost charging **and** element movement onto the pooled
//! [`rmps::sim::Exchange`] mailbox must not change a single bit of any
//! [`RunReport`].
//!
//! The oracle below (`mod legacy`) is a **verbatim copy of the
//! pre-refactor implementations** — hand-rolled `Vec<Vec<Elem>>` outgoing
//! and incoming tables, separate `Machine::xchg`/`send`/`route_round`
//! charges — of all 15 algorithms' data-exchange phases, together with
//! the pre-refactor payload collectives (`all_gather_merge`,
//! `gather_merge`, `alltoallv`) and both shuffles they build on. Each
//! grid cell runs the legacy oracle and the current `Runner` path and
//! asserts field-by-field equality (floats as raw bits), in the style of
//! `runner_equivalence.rs`: times, message/word/work stats, memory
//! high-water marks, crash strings, validation, and the full sorted
//! output.

use rmps::algorithms::{Algorithm, RunReport, Runner};
use rmps::config::RunConfig;
use rmps::input::{generate, Distribution};

/// Field-by-field byte comparison (floats as raw bits). `wall_ms` is host
/// wallclock and exempt by nature.
fn assert_reports_identical(a: &RunReport, b: &RunReport, ctx: &str) {
    assert_eq!(a.algorithm, b.algorithm, "{ctx}: algorithm");
    assert_eq!(a.time.to_bits(), b.time.to_bits(), "{ctx}: time");
    assert_eq!(a.stats.messages, b.stats.messages, "{ctx}: messages");
    assert_eq!(a.stats.words, b.stats.words, "{ctx}: words");
    assert_eq!(
        a.stats.local_work.to_bits(),
        b.stats.local_work.to_bits(),
        "{ctx}: local_work"
    );
    assert_eq!(a.stats.max_mem_elems, b.stats.max_mem_elems, "{ctx}: max_mem_elems");
    assert_eq!(a.stats.max_degree, b.stats.max_degree, "{ctx}: max_degree");
    assert_eq!(a.crashed, b.crashed, "{ctx}: crashed");
    assert_eq!(a.output_shape, b.output_shape, "{ctx}: output_shape");
    assert_eq!(a.is_globally_sorted, b.is_globally_sorted, "{ctx}: is_globally_sorted");
    let (va, vb) = (&a.validation, &b.validation);
    assert_eq!(va.locally_sorted, vb.locally_sorted, "{ctx}: locally_sorted");
    assert_eq!(va.globally_sorted, vb.globally_sorted, "{ctx}: globally_sorted");
    assert_eq!(va.multiset_preserved, vb.multiset_preserved, "{ctx}: multiset");
    assert_eq!(va.balanced, vb.balanced, "{ctx}: balanced");
    assert_eq!(va.imbalance.max_load, vb.imbalance.max_load, "{ctx}: max_load");
    assert_eq!(va.imbalance.min_load, vb.imbalance.min_load, "{ctx}: min_load");
    assert_eq!(
        va.imbalance.epsilon.to_bits(),
        vb.imbalance.epsilon.to_bits(),
        "{ctx}: imbalance ε"
    );
    assert_eq!(a.output, b.output, "{ctx}: output");
}

/// Verbatim pre-refactor implementations (charging and movement
/// separate), driving the same public `Machine` cost API the old code
/// used. Do not "fix" or modernize anything in here — it is the oracle.
mod legacy {
    use rmps::algorithms::hyksort::HykConfig;
    use rmps::algorithms::quick::{Pivot, QuickConfig};
    use rmps::algorithms::rams::{AmsConfig, Dma};
    use rmps::algorithms::selector::CrossoverTable;
    use rmps::algorithms::{Algorithm, OutputShape, RunReport};
    use rmps::config::RunConfig;
    use rmps::elements::{merge, merge_into, multiway_merge, Elem, Key};
    use rmps::input::KEY_RANGE;
    use rmps::localsort::{sort_all, RustSort, SortBackend};
    use rmps::median::median_binary;
    use rmps::partition::{partition, pick_splitters, SplitterTree};
    use rmps::rng::Rng;
    use rmps::sim::{
        allreduce_u64, allreduce_vec_u64, bcast_cost, prefix_sum_vec, rank_pairs, Cube,
        GatheredRuns, Machine,
    };
    use rmps::verify::{validate, validate_replicated};

    // ---- pre-refactor payload collectives -----------------------------

    pub fn all_gather_merge(
        mach: &mut Machine,
        pes: &[usize],
        local: &[Vec<Elem>],
    ) -> Vec<GatheredRuns> {
        assert!(pes.len().is_power_of_two());
        let dim = pes.len().trailing_zeros();
        let size = pes.len();
        let mut runs: Vec<GatheredRuns> = pes
            .iter()
            .map(|&pe| GatheredRuns { own: local[pe].clone(), ..Default::default() })
            .collect();
        let mut full: Vec<Vec<Elem>> = pes.iter().map(|&pe| local[pe].clone()).collect();
        for j in 0..dim {
            let bit = 1usize << j;
            let old: Vec<Vec<Elem>> = std::mem::take(&mut full);
            mach.begin_superstep();
            for (r, pr) in rank_pairs(size, j) {
                mach.xchg(pes[r], pes[pr], old[r].len(), old[pr].len());
            }
            mach.settle();
            full = (0..size)
                .map(|r| {
                    let pr = r ^ bit;
                    let incoming = &old[pr];
                    if pr < r {
                        runs[r].left = merge(&runs[r].left, incoming);
                    } else {
                        runs[r].right = merge(&runs[r].right, incoming);
                    }
                    let merged = merge(&old[r], incoming);
                    mach.work_linear(pes[r], merged.len());
                    mach.note_mem(pes[r], merged.len(), "all-gather-merge");
                    merged
                })
                .collect();
        }
        runs
    }

    pub fn gather_merge(mach: &mut Machine, pes: &[usize], local: &[Vec<Elem>]) -> Vec<Elem> {
        assert!(pes.len().is_power_of_two());
        let dim = pes.len().trailing_zeros();
        let size = pes.len();
        let mut cur: Vec<Option<Vec<Elem>>> =
            pes.iter().map(|&pe| Some(local[pe].clone())).collect();
        for j in 0..dim {
            let bit = 1usize << j;
            let mut moves: Vec<(usize, usize, Vec<Elem>)> = Vec::new();
            for r in 0..size {
                if r & bit != 0 && r & (bit - 1) == 0 {
                    let dst = r & !bit;
                    let data = cur[r].take().expect("sender already gave data away");
                    moves.push((r, dst, data));
                }
            }
            mach.begin_superstep();
            for (r, dst, data) in &moves {
                mach.send(pes[*r], pes[*dst], data.len());
            }
            mach.settle();
            for (_, dst, data) in moves {
                let acc = cur[dst].as_mut().expect("receiver must hold data");
                let merged = merge(acc, &data);
                mach.work_linear(pes[dst], merged.len());
                mach.note_mem(pes[dst], merged.len(), "gather-merge");
                *acc = merged;
            }
        }
        cur[0].take().expect("root holds the result")
    }

    pub fn alltoallv(
        mach: &mut Machine,
        pes: &[usize],
        send: Vec<Vec<Vec<Elem>>>,
    ) -> Vec<Vec<Vec<Elem>>> {
        let size = pes.len();
        let mut msgs = Vec::new();
        for (r, targets) in send.iter().enumerate() {
            for (t, data) in targets.iter().enumerate() {
                if t != r && !data.is_empty() {
                    msgs.push((pes[r], pes[t], data.len()));
                }
            }
        }
        mach.route_round(&msgs);
        let mut recv: Vec<Vec<Vec<Elem>>> = (0..size).map(|_| vec![Vec::new(); size]).collect();
        for (r, targets) in send.into_iter().enumerate() {
            for (t, data) in targets.into_iter().enumerate() {
                recv[t][r] = data;
            }
        }
        for t in 0..size {
            let total: usize = recv[t].iter().map(|v| v.len()).sum();
            mach.note_mem(pes[t], total, "alltoallv");
        }
        recv
    }

    // ---- pre-refactor shuffles ----------------------------------------

    pub fn hypercube_shuffle(
        mach: &mut Machine,
        cube: Cube,
        data: &mut [Vec<Elem>],
        rng: &mut Rng,
    ) {
        let size = cube.size();
        let base = cube.base();
        for j in (0..cube.dim).rev() {
            let bit = 1usize << j;
            let mut outgoing: Vec<Vec<Elem>> = vec![Vec::new(); size];
            for r in 0..size {
                let pe = base + r;
                let local = std::mem::take(&mut data[pe]);
                mach.work_linear(pe, local.len());
                let mut v = local;
                let half = v.len() / 2;
                let extra = v.len() % 2 == 1 && rng.coin();
                let cut = half + usize::from(extra);
                for i in 0..cut {
                    let j = i + rng.below((v.len() - i) as u64) as usize;
                    v.swap(i, j);
                }
                let send = v.split_off(cut);
                data[pe] = v;
                outgoing[r] = send;
            }
            mach.begin_superstep();
            for (r, pr) in rank_pairs(size, j) {
                mach.xchg(base + r, base + pr, outgoing[r].len(), outgoing[pr].len());
            }
            mach.settle();
            for r in 0..size {
                let pr = r ^ bit;
                let incoming = std::mem::take(&mut outgoing[pr]);
                data[base + r].extend(incoming);
                mach.note_mem(base + r, data[base + r].len(), "hypercube shuffle");
            }
        }
    }

    pub fn direct_shuffle(
        mach: &mut Machine,
        cube: Cube,
        data: &mut [Vec<Elem>],
        rng: &mut Rng,
    ) {
        let size = cube.size();
        let base = cube.base();
        let mut buckets: Vec<Vec<Vec<Elem>>> =
            (0..size).map(|_| vec![Vec::new(); size]).collect();
        for r in 0..size {
            let pe = base + r;
            for e in std::mem::take(&mut data[pe]) {
                let t = rng.below(size as u64) as usize;
                buckets[r][t].push(e);
            }
            mach.work_linear(pe, buckets[r].iter().map(Vec::len).sum());
        }
        let recv = alltoallv(mach, &cube.pe_vec(), buckets);
        for r in 0..size {
            let pe = base + r;
            let mut v: Vec<Elem> = recv[r].iter().flatten().copied().collect();
            data[pe].append(&mut v);
            mach.note_mem(pe, data[pe].len(), "direct shuffle");
        }
    }

    // ---- pre-refactor hypercube quicksort -----------------------------

    fn split_run(a: &[Elem], s: Key, tie_break: bool) -> (usize, usize) {
        let lo = a.partition_point(|e| e.key < s);
        let hi = a.partition_point(|e| e.key <= s);
        if !tie_break {
            return (lo, lo);
        }
        let m = hi - lo;
        let desired = a.len() / 2;
        let x = desired.saturating_sub(lo).min(m);
        (lo, lo + x)
    }

    fn select_pivot(
        mach: &mut Machine,
        pes: &[usize],
        data: &[Vec<Elem>],
        qc: &QuickConfig,
        rng: &mut Rng,
    ) -> Option<Key> {
        match qc.pivot {
            Pivot::Window => median_binary(mach, pes, data, qc.window_k, rng),
            Pivot::Pe0LocalMedian => {
                let local = &data[pes[0]];
                let s = local.get(local.len() / 2).map(|e| e.key);
                bcast_cost(mach, pes, 0, 1);
                s.or_else(|| {
                    pes.iter()
                        .find_map(|&pe| data[pe].get(data[pe].len() / 2).map(|e| e.key))
                })
            }
            Pivot::MedianOfMedians => {
                let q = pes.len();
                let dim = q.trailing_zeros();
                let mut have: Vec<usize> = vec![1; q];
                for j in 0..dim {
                    let bit = 1usize << j;
                    for r in 0..q {
                        if r & bit != 0 && r & (bit - 1) == 0 {
                            let dst = r & !bit;
                            mach.send(pes[r], pes[dst], have[r]);
                            have[dst] += have[r];
                        }
                    }
                }
                let mut meds: Vec<Key> = pes
                    .iter()
                    .filter_map(|&pe| data[pe].get(data[pe].len() / 2).map(|e| e.key))
                    .collect();
                if meds.is_empty() {
                    return None;
                }
                meds.sort_unstable();
                mach.work_sort(pes[0], q);
                bcast_cost(mach, pes, 0, 1);
                Some(meds[meds.len() / 2])
            }
        }
    }

    pub fn quick_sort(
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
        qc: &QuickConfig,
    ) {
        let p = cfg.p;
        assert!(p.is_power_of_two());
        let mut rng = Rng::seeded(cfg.seed ^ 0x5157_4943, 1);
        if qc.shuffle {
            hypercube_shuffle(mach, Cube::whole(p), data, &mut rng);
        }
        sort_all(mach, data, backend);
        let mut cubes = vec![Cube::whole(p)];
        let mut merge_buf: Vec<Elem> = Vec::new();
        while cubes[0].dim > 0 {
            let mut next = Vec::with_capacity(cubes.len() * 2);
            for cube in &cubes {
                let pes = cube.pe_vec();
                if let Some(s) = select_pivot(mach, &pes, data, qc, &mut rng) {
                    exchange_level(mach, cube, data, s, qc.tie_break, &mut merge_buf);
                }
                let (lo, hi) = cube.split();
                next.push(lo);
                next.push(hi);
                if mach.crashed() {
                    return;
                }
            }
            cubes = next;
        }
    }

    fn exchange_level(
        mach: &mut Machine,
        cube: &Cube,
        data: &mut [Vec<Elem>],
        s: Key,
        tie_break: bool,
        merge_buf: &mut Vec<Elem>,
    ) {
        let j = cube.dim - 1;
        let bit = 1usize << j;
        let size = cube.size();
        let base = cube.base();
        let mut cuts: Vec<usize> = Vec::with_capacity(size);
        for r in 0..size {
            let a = &data[base + r];
            let (_, cut) = split_run(a, s, tie_break);
            mach.work(base + r, 2.0 * (a.len().max(2) as f64).log2());
            cuts.push(cut);
        }
        for r in 0..size {
            let pr = r ^ bit;
            if r < pr {
                let send_r = data[base + r].len() - cuts[r];
                let send_pr = cuts[pr];
                mach.xchg(base + r, base + pr, send_r, send_pr);
            }
        }
        let mut outgoing: Vec<Vec<Elem>> = Vec::with_capacity(size);
        for r in 0..size {
            let pe = base + r;
            let keep_low = r & bit == 0;
            let run = &mut data[pe];
            if keep_low {
                outgoing.push(run.split_off(cuts[r]));
            } else {
                let mut rest = run.split_off(cuts[r]);
                std::mem::swap(run, &mut rest);
                outgoing.push(rest);
            }
        }
        for r in 0..size {
            let pr = r ^ bit;
            let pe = base + r;
            let incoming = std::mem::take(&mut outgoing[pr]);
            merge_into(&data[pe], &incoming, merge_buf);
            std::mem::swap(&mut data[pe], merge_buf);
            mach.work_linear(pe, data[pe].len());
            mach.note_mem(pe, data[pe].len(), "quicksort exchange");
        }
    }

    // ---- pre-refactor bitonic -----------------------------------------

    fn compare_split(mine: &[Elem], theirs: &[Elem], keep_low: bool) -> Vec<Elem> {
        let keep = mine.len();
        let mut out = Vec::with_capacity(keep);
        if keep_low {
            let (mut i, mut j) = (0, 0);
            while out.len() < keep {
                if j >= theirs.len() || (i < mine.len() && mine[i] <= theirs[j]) {
                    out.push(mine[i]);
                    i += 1;
                } else {
                    out.push(theirs[j]);
                    j += 1;
                }
            }
        } else {
            let (mut i, mut j) = (mine.len() as i64 - 1, theirs.len() as i64 - 1);
            while out.len() < keep {
                if j < 0 || (i >= 0 && mine[i as usize] >= theirs[j as usize]) {
                    out.push(mine[i as usize]);
                    i -= 1;
                } else {
                    out.push(theirs[j as usize]);
                    j -= 1;
                }
            }
            out.reverse();
        }
        out
    }

    pub fn bitonic_sort(
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) {
        let p = cfg.p;
        assert!(p.is_power_of_two());
        let d = p.trailing_zeros();
        let m = data[0].len();
        if data.iter().any(|v| v.len() != m) || (m == 0 && cfg.n_total() > 0) {
            mach.fail(0, "bitonic requires dense balanced input");
            return;
        }
        sort_all(mach, data, backend);
        for i in 0..d {
            for j in (0..=i).rev() {
                let bit = 1usize << j;
                for pe in 0..p {
                    let partner = pe ^ bit;
                    if pe < partner {
                        mach.xchg(pe, partner, data[pe].len(), data[partner].len());
                    }
                }
                let snapshot: Vec<Vec<Elem>> = data.clone();
                for pe in 0..p {
                    let partner = pe ^ bit;
                    let ascending = pe & (1 << (i + 1)) == 0;
                    let keep_low = (pe & bit == 0) == ascending;
                    data[pe] = compare_split(&snapshot[pe], &snapshot[partner], keep_low);
                    mach.work_linear(pe, 2 * m);
                    mach.note_mem(pe, 2 * m, "bitonic compare-split");
                }
            }
        }
    }

    // ---- pre-refactor HykSort -----------------------------------------

    pub fn hyksort_sort(
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
        hc: &HykConfig,
    ) {
        let p = cfg.p;
        assert!(p.is_power_of_two());
        let mut rng = Rng::seeded(cfg.seed ^ 0x4859_4B53, 3);
        sort_all(mach, data, backend);
        let mut groups = vec![Cube::whole(p)];
        while groups[0].dim > 0 {
            let mut next = Vec::new();
            for group in &groups {
                hyk_level(mach, group, data, cfg, hc, &mut rng, &mut next);
                if mach.crashed() {
                    return;
                }
            }
            groups = next;
        }
    }

    fn hyk_level(
        mach: &mut Machine,
        group: &Cube,
        data: &mut [Vec<Elem>],
        cfg: &RunConfig,
        hc: &HykConfig,
        rng: &mut Rng,
        next: &mut Vec<Cube>,
    ) {
        let q = group.size();
        let pes = group.pe_vec();
        let logk = (hc.k.max(2).trailing_zeros()).min(group.dim);
        let k = 1usize << logk;
        let subgroups = group.split_k(logk);
        next.extend(subgroups.iter().copied());

        let split_cost = cfg.cost.alpha * (q.max(2) as f64).log2() + cfg.cost.beta * q as f64;
        for &pe in &pes {
            mach.work(pe, split_cost);
        }

        let mut samples: Vec<Vec<Elem>> = vec![Vec::new(); data.len()];
        let budget = mach.mem_cap_elems.unwrap_or(usize::MAX).min(hc.sample_per_pe * q) / 2;
        let per_pe_cap = (budget / q).max(1);
        for &pe in &pes {
            let local = &data[pe];
            let take = hc.sample_per_pe.min(per_pe_cap).min(local.len());
            for _ in 0..take {
                samples[pe].push(local[rng.below(local.len() as u64) as usize]);
            }
            samples[pe].sort_unstable_by_key(|e| e.key);
            mach.work_sort(pe, take);
        }
        let gathered = all_gather_merge(mach, &pes, &samples);
        let sorted_samples = gathered[0].merged();
        let splitters: Vec<Key> = (1..k)
            .map(|i| {
                if sorted_samples.is_empty() {
                    Key::MAX
                } else {
                    sorted_samples[(i * sorted_samples.len() / k).min(sorted_samples.len() - 1)]
                        .key
                }
            })
            .collect();

        let q_sub = q / k;
        let mut outgoing: Vec<Vec<Vec<Elem>>> = vec![Vec::new(); data.len()];
        let mut msgs: Vec<(usize, usize, usize)> = Vec::new();
        for r in 0..q {
            let pe = pes[r];
            let local = std::mem::take(&mut data[pe]);
            mach.work_classify(pe, local.len(), k);
            let mut buckets: Vec<Vec<Elem>> = vec![Vec::new(); k];
            for e in local {
                let b = splitters.partition_point(|&s| s < e.key);
                buckets[b].push(e);
            }
            for (b, bucket) in buckets.iter().enumerate() {
                if bucket.is_empty() {
                    continue;
                }
                let target = subgroups[b].pe(r % q_sub);
                if target != pe {
                    msgs.push((pe, target, bucket.len()));
                }
            }
            outgoing[pe] = buckets;
        }
        mach.route_round(&msgs);

        let mut incoming: Vec<Vec<Vec<Elem>>> = vec![Vec::new(); data.len()];
        for r in 0..q {
            let pe = pes[r];
            for (b, bucket) in std::mem::take(&mut outgoing[pe]).into_iter().enumerate() {
                if bucket.is_empty() {
                    continue;
                }
                let target = subgroups[b].pe(r % q_sub);
                incoming[target].push(bucket);
            }
        }
        for &pe in &pes {
            let runs = std::mem::take(&mut incoming[pe]);
            let refs: Vec<&[Elem]> = runs.iter().map(|v| v.as_slice()).collect();
            let merged = multiway_merge(&refs);
            mach.work(
                pe,
                cfg.cost.cmp * merged.len() as f64 * (runs.len().max(2) as f64).log2(),
            );
            mach.note_mem(pe, merged.len(), "HykSort k-way exchange");
            data[pe] = merged;
        }
    }

    // ---- pre-refactor RAMS --------------------------------------------

    pub fn rams_sort(
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
        ac: &AmsConfig,
    ) {
        let p = cfg.p;
        assert!(p.is_power_of_two());
        let mut rng = Rng::seeded(cfg.seed ^ 0x414D_5331, 4);
        sort_all(mach, data, backend);
        let mut groups = vec![(Cube::whole(p), ac.levels.max(1))];
        while let Some((group, levels_left)) = groups.pop() {
            if group.dim == 0 || levels_left == 0 {
                continue;
            }
            let subs = rams_level(mach, &group, data, cfg, ac, levels_left, &mut rng);
            if mach.crashed() {
                return;
            }
            for s in subs {
                groups.push((s, levels_left - 1));
            }
        }
    }

    fn rams_level(
        mach: &mut Machine,
        group: &Cube,
        data: &mut [Vec<Elem>],
        cfg: &RunConfig,
        ac: &AmsConfig,
        levels_left: usize,
        rng: &mut Rng,
    ) -> Vec<Cube> {
        let q = group.size();
        let pes = group.pe_vec();
        let logk = group.dim.div_ceil(levels_left as u32).max(1);
        let k = 1usize << logk;
        let subgroups = group.split_k(logk);
        let q_sub = q / k;

        let b = (2.0 / ((1.0 + ac.epsilon).powf(1.0 / ac.levels as f64) - 1.0)).ceil() as usize;
        let nb = ((b * k).next_power_of_two() - 1).max(k - 1).min(1023);

        let mut samples: Vec<Vec<Elem>> = vec![Vec::new(); data.len()];
        let budget = mach.mem_cap_elems.unwrap_or(usize::MAX).min(4 * nb.max(k));
        let s_loc_target = (budget as f64 / q as f64).ceil() as usize;
        for &pe in &pes {
            let local = &data[pe];
            let take = s_loc_target.max(1).min(local.len());
            for _ in 0..take {
                samples[pe].push(local[rng.below(local.len() as u64) as usize]);
            }
            samples[pe].sort_unstable();
            mach.work_sort(pe, take);
        }
        let gathered = all_gather_merge(mach, &pes, &samples);
        let sorted_samples = gathered[0].merged();
        let splitters = pick_splitters(&sorted_samples, nb);
        let tree = SplitterTree::new(&splitters);

        let mut buckets: Vec<Vec<Vec<Elem>>> = vec![Vec::new(); data.len()];
        let mut counts: Vec<Vec<usize>> = Vec::with_capacity(q);
        for &pe in &pes {
            let local = std::mem::take(&mut data[pe]);
            mach.work_classify(pe, local.len(), nb + 1);
            let parts = partition(&local, &tree, ac.tie_break);
            counts.push(parts.iter().map(Vec::len).collect());
            buckets[pe] = parts;
        }

        let prefixes = prefix_sum_vec(mach, &pes, &counts);
        let totals: Vec<usize> = prefixes[0].1.clone();
        let grand_total: usize = totals.iter().sum();
        let ideal = grand_total as f64 / k as f64;
        let mut assignment = vec![0usize; nb + 1];
        {
            let mut cum = 0usize;
            let mut g = 0usize;
            for (bkt, &t) in totals.iter().enumerate() {
                let remaining_buckets = nb + 1 - bkt;
                let remaining_groups = k - g;
                if g + 1 < k
                    && cum as f64 >= (g + 1) as f64 * ideal
                    && remaining_buckets > remaining_groups - 1
                {
                    g += 1;
                }
                assignment[bkt] = g;
                cum += t;
            }
            mach.work(pes[0], cfg.cost.cmp * (nb + 1) as f64);
        }
        let mut sub_total = vec![0usize; k];
        for (bkt, &g) in assignment.iter().enumerate() {
            sub_total[g] += totals[bkt];
        }
        let mut bucket_base = vec![0usize; nb + 1];
        {
            let mut acc = vec![0usize; k];
            for (bkt, &g) in assignment.iter().enumerate() {
                bucket_base[bkt] = acc[g];
                acc[g] += totals[bkt];
            }
        }

        let caps: Vec<usize> = sub_total.iter().map(|&t| t.div_ceil(q_sub).max(1)).collect();
        struct Msg {
            from_pe: usize,
            to_pe: usize,
            bucket: usize,
            start: usize,
            end: usize,
        }
        let mut msgs: Vec<Msg> = Vec::new();
        for (r, &pe) in pes.iter().enumerate() {
            let pre = &prefixes[r].0;
            for bkt in 0..=nb {
                let len = buckets[pe][bkt].len();
                if len == 0 {
                    continue;
                }
                let g = assignment[bkt];
                let goff = bucket_base[bkt] + pre[bkt];
                let cap = caps[g];
                let mut local_start = 0usize;
                while local_start < len {
                    let gpos = goff + local_start;
                    let t_idx = (gpos / cap).min(q_sub - 1);
                    let t_end_gpos = ((t_idx + 1) * cap).min(goff + len);
                    let local_end = t_end_gpos - goff;
                    msgs.push(Msg {
                        from_pe: pe,
                        to_pe: subgroups[g].pe(t_idx),
                        bucket: bkt,
                        start: local_start,
                        end: local_end,
                    });
                    local_start = local_end;
                }
            }
        }

        let mut wire: std::collections::HashMap<(usize, usize), usize> =
            std::collections::HashMap::new();
        for m in &msgs {
            if m.from_pe != m.to_pe {
                *wire.entry((m.from_pe, m.to_pe)).or_insert(0) += m.end - m.start;
            }
        }
        let mut wire: Vec<(usize, usize, usize)> =
            wire.into_iter().map(|((f, t), l)| (f, t, l)).collect();
        wire.sort_unstable();

        let mut fan_in = std::collections::HashMap::new();
        for &(_, to, _) in &wire {
            *fan_in.entry(to).or_insert(0usize) += 1;
        }
        let max_fan_in = fan_in.values().copied().max().unwrap_or(0);
        let use_dma = match ac.dma {
            Dma::Always => true,
            Dma::Never => false,
            Dma::Auto => {
                allreduce_u64(mach, &pes, &vec![0u64; data.len()], |a, b| a.max(b));
                max_fan_in > 4 * k
            }
        };

        if use_dma {
            let addr_cost = cfg.cost.alpha * ((q.max(2) as f64).log2() + k as f64);
            for &pe in &pes {
                mach.work(pe, addr_cost);
            }
            mach.barrier(&pes);
            let mut per_sub: std::collections::HashMap<(usize, usize), usize> =
                std::collections::HashMap::new();
            for m in &msgs {
                let g = assignment[m.bucket];
                *per_sub.entry((m.from_pe, g)).or_insert(0) += m.end - m.start;
            }
            // deterministic iteration (the historical HashMap iteration
            // order was unspecified; note_mem aggregates by max so any
            // order yields the same non-crash state — iterate sorted like
            // the current implementation does)
            let mut per_sub: Vec<((usize, usize), usize)> = per_sub.into_iter().collect();
            per_sub.sort_unstable();
            let mut round1: Vec<(usize, usize, usize)> = Vec::new();
            for &((from, g), len) in &per_sub {
                let entry = subgroups[g].pe(group.rank(from) % q_sub);
                if entry != from {
                    round1.push((from, entry, len));
                }
                mach.note_mem(entry, len, "DMA subgroup entry");
            }
            round1.sort_unstable();
            mach.route_round(&round1);
            let mut round2: std::collections::HashMap<(usize, usize), usize> =
                std::collections::HashMap::new();
            for m in &msgs {
                let g = assignment[m.bucket];
                let entry = subgroups[g].pe(group.rank(m.from_pe) % q_sub);
                if entry != m.to_pe {
                    *round2.entry((entry, m.to_pe)).or_insert(0) += m.end - m.start;
                }
            }
            let mut round2: Vec<(usize, usize, usize)> =
                round2.into_iter().map(|((f, t), l)| (f, t, l)).collect();
            round2.sort_unstable();
            mach.route_round(&round2);
        } else {
            mach.route_round(&wire);
        }

        let mut incoming: Vec<Vec<Vec<Elem>>> = vec![Vec::new(); data.len()];
        for m in &msgs {
            let slice = buckets[m.from_pe][m.bucket][m.start..m.end].to_vec();
            incoming[m.to_pe].push(slice);
        }
        for &pe in &pes {
            let runs = std::mem::take(&mut incoming[pe]);
            let refs: Vec<&[Elem]> = runs.iter().map(|v| v.as_slice()).collect();
            let merged = multiway_merge(&refs);
            mach.work(
                pe,
                cfg.cost.cmp * merged.len() as f64 * (runs.len().max(2) as f64).log2(),
            );
            mach.note_mem(pe, merged.len(), "AMS data exchange");
            data[pe] = merged;
        }

        subgroups
    }

    // ---- pre-refactor SSort -------------------------------------------

    fn gather_words_cost(mach: &mut Machine, pes: &[usize], counts: &mut [usize]) {
        let dim = pes.len().trailing_zeros();
        for j in 0..dim {
            let bit = 1usize << j;
            for r in 0..pes.len() {
                if r & bit != 0 && r & (bit - 1) == 0 {
                    let dst = r & !bit;
                    mach.send(pes[r], pes[dst], counts[r]);
                    counts[dst] += counts[r];
                }
            }
        }
    }

    pub fn ssort_sort(
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
        charge_splitters: bool,
    ) {
        let p = cfg.p;
        assert!(p.is_power_of_two());
        let logp = p.trailing_zeros().max(1) as usize;
        let mut rng = Rng::seeded(cfg.seed ^ 0x5350_4C54, 2);
        let pes = Cube::whole(p).pe_vec();
        sort_all(mach, data, backend);

        let per_pe_sample = 16 * logp;
        let mut sample: Vec<Elem> = Vec::new();
        let mut sample_counts = vec![0usize; p];
        for (pe, local) in data.iter().enumerate() {
            let take = per_pe_sample.min(local.len());
            for _ in 0..take {
                sample.push(local[rng.below(local.len() as u64) as usize]);
            }
            sample_counts[pe] = take;
        }
        sample.sort_unstable_by_key(|e| e.key);
        let splitters: Vec<Key> = (1..p)
            .map(|i| {
                if sample.is_empty() {
                    Key::MAX
                } else {
                    sample[(i * sample.len() / p).min(sample.len() - 1)].key
                }
            })
            .collect();
        if charge_splitters {
            gather_words_cost(mach, &pes, &mut sample_counts);
            mach.work_sort(0, sample.len());
            bcast_cost(mach, &pes, 0, p - 1);
        }

        let mut send: Vec<Vec<Vec<Elem>>> = Vec::with_capacity(p);
        for pe in 0..p {
            let local = std::mem::take(&mut data[pe]);
            mach.work_classify(pe, local.len(), p);
            let mut buckets: Vec<Vec<Elem>> = vec![Vec::new(); p];
            for e in local {
                let b = splitters.partition_point(|&s| s < e.key);
                buckets[b].push(e);
            }
            send.push(buckets);
        }
        let recv = alltoallv(mach, &pes, send);

        for (r, runs) in recv.into_iter().enumerate() {
            let pe = pes[r];
            let refs: Vec<&[Elem]> = runs.iter().map(|v| v.as_slice()).collect();
            let merged = multiway_merge(&refs);
            mach.work(pe, cfg.cost.cmp * merged.len() as f64 * (p.max(2) as f64).log2());
            mach.note_mem(pe, merged.len(), "sample sort receive");
            data[pe] = merged;
        }
    }

    // ---- pre-refactor multiway mergesort ------------------------------

    #[inline]
    fn point(e: &Elem) -> u128 {
        ((e.key as u128) << 64) | e.id as u128
    }

    pub fn mways_sort(
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) {
        let p = cfg.p;
        assert!(p.is_power_of_two());
        let pes = Cube::whole(p).pe_vec();
        let n: usize = data.iter().map(Vec::len).sum();
        if n == 0 {
            return;
        }
        sort_all(mach, data, backend);

        let nb = p - 1;
        let target: Vec<usize> = (0..nb).map(|b| ((b + 1) * n) / p).collect();
        let mut lo = vec![0u128; nb];
        let mut hi = vec![(KEY_RANGE as u128) << 64; nb];
        let rounds = 96;
        let mut counts: Vec<Vec<u64>> = vec![vec![0; nb]; p];
        for _ in 0..rounds {
            if lo.iter().zip(&hi).all(|(l, h)| l + 1 >= *h) {
                break;
            }
            let mid: Vec<u128> = lo.iter().zip(&hi).map(|(l, h)| (l + h) / 2).collect();
            for (pe, local) in data.iter().enumerate() {
                for (b, &m) in mid.iter().enumerate() {
                    counts[pe][b] = local.partition_point(|e| point(e) < m) as u64;
                }
                mach.work(pe, cfg.cost.cmp * nb as f64 * (local.len().max(2) as f64).log2());
            }
            allreduce_vec_u64(mach, &pes, &mut counts, |a, b| a + b);
            let total = &counts[0];
            for b in 0..nb {
                if (total[b] as usize) < target[b] {
                    lo[b] = mid[b];
                } else {
                    hi[b] = mid[b];
                }
            }
            for c in counts.iter_mut() {
                for v in c.iter_mut() {
                    *v = 0;
                }
            }
        }
        let splitters: Vec<u128> = hi;

        let mut send: Vec<Vec<Vec<Elem>>> = Vec::with_capacity(p);
        for pe in 0..p {
            let local = std::mem::take(&mut data[pe]);
            mach.work_classify(pe, local.len(), p);
            let mut buckets: Vec<Vec<Elem>> = vec![Vec::new(); p];
            for e in local {
                let b = splitters.partition_point(|&s| s <= point(&e));
                buckets[b].push(e);
            }
            send.push(buckets);
        }
        let recv = alltoallv(mach, &pes, send);
        for (r, runs) in recv.into_iter().enumerate() {
            let pe = pes[r];
            let refs: Vec<&[Elem]> = runs.iter().map(|v| v.as_slice()).collect();
            let merged = multiway_merge(&refs);
            mach.work(pe, cfg.cost.cmp * merged.len() as f64 * (p.max(2) as f64).log2());
            mach.note_mem(pe, merged.len(), "multiway mergesort receive");
            data[pe] = merged;
        }
    }

    // ---- pre-refactor RFIS --------------------------------------------

    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    enum RowClass {
        Left,
        Own(usize),
        Right,
    }

    fn grid(p: usize) -> (usize, usize) {
        let d = p.trailing_zeros();
        let cols = 1usize << (d / 2);
        (p / cols, cols)
    }

    #[inline]
    fn ub(run: &[Elem], key: u64) -> u64 {
        run.partition_point(|e| e.key <= key) as u64
    }

    #[inline]
    fn lb(run: &[Elem], key: u64) -> u64 {
        run.partition_point(|e| e.key < key) as u64
    }

    pub fn rfis_sort(
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) {
        let p = cfg.p;
        assert!(p.is_power_of_two());
        let n: usize = data.iter().map(Vec::len).sum();
        if n == 0 {
            return;
        }
        let (rows, cols) = grid(p);
        sort_all(mach, data, backend);

        let mut row_runs = vec![None; p];
        for r in 0..rows {
            let pes: Vec<usize> = (0..cols).map(|c| r * cols + c).collect();
            let runs = all_gather_merge(mach, &pes, data);
            for (c, g) in runs.into_iter().enumerate() {
                row_runs[r * cols + c] = Some(g);
            }
        }
        let mut col_runs = vec![None; p];
        for c in 0..cols {
            let pes: Vec<usize> = (0..rows).map(|r| r * cols + c).collect();
            let runs = all_gather_merge(mach, &pes, data);
            for (r, g) in runs.into_iter().enumerate() {
                col_runs[r * cols + c] = Some(g);
            }
        }

        let mut ranks: Vec<Vec<u64>> = vec![Vec::new(); p];
        let mut row_merged: Vec<Vec<Elem>> = vec![Vec::new(); p];
        for pe in 0..p {
            let row = row_runs[pe].take().expect("row gather ran");
            let col = col_runs[pe].take().expect("col gather ran");
            let mut annotated: Vec<(Elem, RowClass)> = Vec::with_capacity(row.total());
            {
                let (mut i, mut j, mut k) = (0usize, 0usize, 0usize);
                let (l, o, r) = (&row.left, &row.own, &row.right);
                while i < l.len() || j < o.len() || k < r.len() {
                    let lv = l.get(i);
                    let ov = o.get(j);
                    let rv = r.get(k);
                    let pick_l = lv.is_some()
                        && ov.map_or(true, |x| lv.unwrap() <= x)
                        && rv.map_or(true, |x| lv.unwrap() <= x);
                    if pick_l {
                        annotated.push((l[i], RowClass::Left));
                        i += 1;
                    } else if ov.is_some() && rv.map_or(true, |x| ov.unwrap() <= x) {
                        annotated.push((o[j], RowClass::Own(j)));
                        j += 1;
                    } else {
                        annotated.push((r[k], RowClass::Right));
                        k += 1;
                    }
                }
            }
            let (up, own_col, down) = (&col.left, &col.own, &col.right);
            let mut rk = Vec::with_capacity(annotated.len());
            for (e, class) in &annotated {
                let r = match class {
                    RowClass::Left => ub(up, e.key) + lb(own_col, e.key) + lb(down, e.key),
                    RowClass::Right => ub(up, e.key) + ub(own_col, e.key) + lb(down, e.key),
                    RowClass::Own(i) => ub(up, e.key) + *i as u64 + lb(down, e.key),
                };
                rk.push(r);
            }
            let total = annotated.len() + col.total();
            mach.work(
                pe,
                cfg.cost.cmp * annotated.len() as f64 * ((col.total().max(2)) as f64).log2(),
            );
            mach.note_mem(pe, total, "RFIS gather footprint");
            ranks[pe] = rk;
            row_merged[pe] = annotated.into_iter().map(|(e, _)| e).collect();
        }

        for r in 0..rows {
            let pes: Vec<usize> = (0..cols).map(|c| r * cols + c).collect();
            if !ranks[pes[0]].is_empty() {
                allreduce_vec_u64(mach, &pes, &mut ranks, |a, b| a + b);
            }
        }

        let dest_pe = |rank: u64| -> usize { ((rank as u128 * p as u128) / n as u128) as usize };
        let mut in_flight: Vec<Vec<(Elem, usize)>> = vec![Vec::new(); p];
        for pe in 0..p {
            let c = pe % cols;
            let merged = std::mem::take(&mut row_merged[pe]);
            let rk = std::mem::take(&mut ranks[pe]);
            mach.work_linear(pe, merged.len());
            for (e, r) in merged.into_iter().zip(rk) {
                let dest = dest_pe(r);
                if dest % cols == c {
                    in_flight[pe].push((e, dest / cols));
                }
            }
            data[pe].clear();
        }
        let row_dims = rows.trailing_zeros();
        for j in (0..row_dims).rev() {
            let bit = 1usize << j;
            for c in 0..cols {
                let mut outgoing: Vec<Vec<(Elem, usize)>> = vec![Vec::new(); rows];
                for r in 0..rows {
                    let pe = r * cols + c;
                    let (stay, go): (Vec<_>, Vec<_>) = std::mem::take(&mut in_flight[pe])
                        .into_iter()
                        .partition(|(_, d)| d & bit == r & bit);
                    in_flight[pe] = stay;
                    outgoing[r] = go;
                }
                for r in 0..rows {
                    let pr = r ^ bit;
                    if r < pr {
                        mach.xchg(
                            r * cols + c,
                            pr * cols + c,
                            outgoing[r].len(),
                            outgoing[pr].len(),
                        );
                    }
                }
                for r in 0..rows {
                    let pr = r ^ bit;
                    let incoming = std::mem::take(&mut outgoing[pr]);
                    let pe = r * cols + c;
                    in_flight[pe].extend(incoming);
                    mach.note_mem(pe, in_flight[pe].len(), "RFIS delivery");
                }
            }
        }
        for pe in 0..p {
            let mut v: Vec<Elem> =
                std::mem::take(&mut in_flight[pe]).into_iter().map(|(e, _)| e).collect();
            mach.work_sort(pe, v.len());
            v.sort_unstable();
            data[pe] = v;
        }
    }

    // ---- pre-refactor Minisort / GatherM / AllGatherM / selector -------

    pub fn minisort_sort(
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) {
        if data.iter().any(|v| v.len() != 1) {
            mach.fail(0, "Minisort requires exactly one element per PE (n = p)");
            return;
        }
        let qc = QuickConfig { shuffle: true, tie_break: true, pivot: Pivot::Window, window_k: 2 };
        quick_sort(mach, data, cfg, backend, &qc);
    }

    pub fn gatherm_sort(
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) {
        sort_all(mach, data, backend);
        let pes = Cube::whole(cfg.p).pe_vec();
        let merged = gather_merge(mach, &pes, data);
        for v in data.iter_mut() {
            v.clear();
        }
        data[0] = merged;
    }

    pub fn allgatherm_sort(
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) {
        sort_all(mach, data, backend);
        let pes = Cube::whole(cfg.p).pe_vec();
        let runs = all_gather_merge(mach, &pes, data);
        for (pe, r) in runs.into_iter().enumerate() {
            data[pe] = r.merged();
        }
    }

    fn selector_sort(
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) -> OutputShape {
        let table = CrossoverTable::JUQUEEN;
        let n: usize = data.iter().map(Vec::len).sum();
        let npp = n as f64 / cfg.p as f64;
        match table.choose(npp) {
            "GatherM" => {
                gatherm_sort(mach, data, cfg, backend);
                OutputShape::RootOnly
            }
            "RFIS" => {
                rfis_sort(mach, data, cfg, backend);
                OutputShape::Balanced
            }
            "RQuick" => {
                quick_sort(mach, data, cfg, backend, &QuickConfig::robust());
                OutputShape::Balanced
            }
            _ => {
                rams_sort(mach, data, cfg, backend, &AmsConfig::robust(cfg));
                OutputShape::Balanced
            }
        }
    }

    // ---- the legacy run harness (the pre-refactor `execute`) -----------

    pub fn run(alg: Algorithm, cfg: &RunConfig, input: Vec<Vec<Elem>>) -> RunReport {
        let mut mach = Machine::new(cfg.p, cfg.cost);
        mach.mem_cap_elems = cfg.mem_cap_elems();
        let reference = input.clone();
        let mut data = input;
        let backend: &mut dyn SortBackend = &mut RustSort;
        let shape = match alg {
            Algorithm::GatherM => {
                gatherm_sort(&mut mach, &mut data, cfg, backend);
                OutputShape::RootOnly
            }
            Algorithm::AllGatherM => {
                allgatherm_sort(&mut mach, &mut data, cfg, backend);
                OutputShape::Replicated
            }
            Algorithm::Rfis => {
                rfis_sort(&mut mach, &mut data, cfg, backend);
                OutputShape::Balanced
            }
            Algorithm::RQuick => {
                quick_sort(&mut mach, &mut data, cfg, backend, &QuickConfig::robust());
                OutputShape::Balanced
            }
            Algorithm::NtbQuick => {
                quick_sort(&mut mach, &mut data, cfg, backend, &QuickConfig::nonrobust());
                OutputShape::Balanced
            }
            Algorithm::Bitonic => {
                bitonic_sort(&mut mach, &mut data, cfg, backend);
                OutputShape::Balanced
            }
            Algorithm::Rams => {
                rams_sort(&mut mach, &mut data, cfg, backend, &AmsConfig::robust(cfg));
                OutputShape::Balanced
            }
            Algorithm::NtbAms => {
                let mut ac = AmsConfig::robust(cfg);
                ac.tie_break = false;
                rams_sort(&mut mach, &mut data, cfg, backend, &ac);
                OutputShape::Balanced
            }
            Algorithm::NdmaAms => {
                let mut ac = AmsConfig::robust(cfg);
                ac.dma = Dma::Never;
                rams_sort(&mut mach, &mut data, cfg, backend, &ac);
                OutputShape::Balanced
            }
            Algorithm::HykSort => {
                hyksort_sort(&mut mach, &mut data, cfg, backend, &HykConfig::default());
                OutputShape::Balanced
            }
            Algorithm::SSort => {
                ssort_sort(&mut mach, &mut data, cfg, backend, true);
                OutputShape::Balanced
            }
            Algorithm::NsSSort => {
                ssort_sort(&mut mach, &mut data, cfg, backend, false);
                OutputShape::Balanced
            }
            Algorithm::Minisort => {
                minisort_sort(&mut mach, &mut data, cfg, backend);
                OutputShape::Balanced
            }
            Algorithm::Mways => {
                mways_sort(&mut mach, &mut data, cfg, backend);
                OutputShape::Balanced
            }
            Algorithm::Robust => selector_sort(&mut mach, &mut data, cfg, backend),
        };
        let crashed = mach.crash().map(|c| c.to_string());
        let validation = match shape {
            OutputShape::Balanced => validate(&reference, &data, cfg.epsilon),
            OutputShape::RootOnly => {
                let mut proj = vec![Vec::new(); cfg.p];
                proj[0] = data[0].clone();
                let mut v = validate(&reference, &proj, f64::INFINITY);
                v.balanced = false;
                v
            }
            OutputShape::Replicated => validate_replicated(&reference, &data),
        };
        RunReport {
            algorithm: alg.name(),
            time: mach.time(),
            stats: mach.stats,
            is_globally_sorted: validation.globally_sorted && crashed.is_none(),
            validation,
            output_shape: shape,
            crashed,
            wall_ms: 0.0,
            output: data,
        }
    }
}

/// All 15 algorithms × a (distribution, size) grid: the verbatim
/// pre-refactor oracle and the Exchange-based `Runner` agree bit for bit.
/// Out-of-range combinations (Minisort on m ≠ 1, Bitonic on sparse) are
/// included — their *crash reports* must agree too.
#[test]
fn exchange_path_matches_legacy_for_all_algorithms() {
    let dists = [Distribution::Uniform, Distribution::Zero, Distribution::Staggered];
    for &dist in &dists {
        for m in [1usize, 4, 64] {
            let cfg = RunConfig::default().with_p(16).with_n_per_pe(m);
            for alg in Algorithm::ALL {
                let ctx = format!("{alg:?}/{dist:?}/m={m}");
                let input = generate(&cfg, dist);
                let want = legacy::run(alg, &cfg, input.clone());
                let mut runner = Runner::new(cfg.clone());
                let got = runner.run_algorithm(alg, input);
                assert_reports_identical(&want, &got, &ctx);
            }
        }
    }
}

/// The sparse regime (n < p): the selector hands off to GatherM, RFIS
/// routes across a mostly-empty grid, Bitonic refuses the input.
#[test]
fn exchange_path_matches_legacy_on_sparse_inputs() {
    let mut cfg = RunConfig::default().with_p(32).with_sparsity(8);
    cfg.mem_cap_factor = None;
    for alg in Algorithm::ALL {
        let ctx = format!("{alg:?}/sparse");
        let input = generate(&cfg, Distribution::Uniform);
        let want = legacy::run(alg, &cfg, input.clone());
        let mut runner = Runner::new(cfg.clone());
        let got = runner.run_algorithm(alg, input);
        assert_reports_identical(&want, &got, &ctx);
    }
}

/// Memory-capped hard instances: the crash reports (PE, resident count,
/// context string) of nonrobust algorithms must survive the port
/// byte-for-byte.
#[test]
fn exchange_path_matches_legacy_crash_reports() {
    let mut cfg = RunConfig::default().with_p(16).with_n_per_pe(256);
    cfg.mem_cap_factor = Some(4.0);
    for dist in [Distribution::Zero, Distribution::DeterDupl] {
        for alg in [
            Algorithm::HykSort,
            Algorithm::NtbQuick,
            Algorithm::NtbAms,
            Algorithm::SSort,
            Algorithm::Rams,
            Algorithm::RQuick,
        ] {
            let ctx = format!("{alg:?}/{dist:?}/capped");
            let input = generate(&cfg, dist);
            let want = legacy::run(alg, &cfg, input.clone());
            let mut runner = Runner::new(cfg.clone());
            let got = runner.run_algorithm(alg, input);
            assert_reports_identical(&want, &got, &ctx);
        }
    }
}

/// The two shuffle primitives directly against their verbatim legacy
/// twins: same RNG stream, same clocks/stats bits, same element placement.
/// (`direct_shuffle` is not reachable through any `Algorithm`, so the
/// RunReport grids above never cover it.)
#[test]
fn shuffles_match_legacy_bit_for_bit() {
    use rmps::rng::Rng;
    use rmps::sim::{Cube, Machine};
    for seed in [1u64, 7, 42] {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(24).with_seed(seed);
        for direct in [false, true] {
            let input = generate(&cfg, Distribution::Mirrored);
            let mut want_data = input.clone();
            let mut got_data = input;
            let mut want_mach = Machine::new(cfg.p, cfg.cost);
            let mut got_mach = Machine::new(cfg.p, cfg.cost);
            let mut want_rng = Rng::seeded(seed, 99);
            let mut got_rng = Rng::seeded(seed, 99);
            if direct {
                legacy::direct_shuffle(&mut want_mach, Cube::whole(cfg.p), &mut want_data, &mut want_rng);
                rmps::shuffle::direct_shuffle(&mut got_mach, Cube::whole(cfg.p), &mut got_data, &mut got_rng);
            } else {
                legacy::hypercube_shuffle(&mut want_mach, Cube::whole(cfg.p), &mut want_data, &mut want_rng);
                rmps::shuffle::hypercube_shuffle(&mut got_mach, Cube::whole(cfg.p), &mut got_data, &mut got_rng);
            }
            let ctx = format!("seed {seed} direct={direct}");
            assert_eq!(want_data, got_data, "{ctx}: element placement");
            for pe in 0..cfg.p {
                assert_eq!(
                    want_mach.clock(pe).to_bits(),
                    got_mach.clock(pe).to_bits(),
                    "{ctx}: clock pe {pe}"
                );
            }
            assert_eq!(want_mach.stats.messages, got_mach.stats.messages, "{ctx}");
            assert_eq!(want_mach.stats.words, got_mach.stats.words, "{ctx}");
            assert_eq!(want_mach.stats.max_degree, got_mach.stats.max_degree, "{ctx}");
            assert_eq!(want_mach.stats.max_mem_elems, got_mach.stats.max_mem_elems, "{ctx}");
            assert_eq!(
                want_mach.stats.local_work.to_bits(),
                got_mach.stats.local_work.to_bits(),
                "{ctx}: local_work"
            );
        }
    }
}

/// Giant-p representation independence: a runner that has just simulated
/// a 2^16-PE sparse run — epoch/floor clocks exercised at scale, mailbox
/// tables and touched-slot indexes grown to giant dimensions — must
/// produce bit-identical reports on subsequent small cells compared to a
/// fresh runner. The pooled giant-p state may only ever change host cost,
/// never a report bit.
#[test]
fn giant_p_warmed_runner_matches_fresh_runner_bit_for_bit() {
    let giant = RunConfig::default().with_p(1 << 16).with_sparsity(243);
    let mut warmed = Runner::new(giant.clone());
    let warm =
        warmed.run_algorithm(Algorithm::Rfis, generate(&giant, Distribution::Uniform));
    assert!(warm.crashed.is_none(), "giant-p warmup crashed: {:?}", warm.crashed);
    assert!(warm.validation.ok(), "giant-p warmup invalid");
    for dist in [Distribution::Uniform, Distribution::Zero, Distribution::Staggered] {
        for m in [1usize, 64] {
            let cfg = RunConfig::default().with_p(16).with_n_per_pe(m);
            for alg in
                [Algorithm::GatherM, Algorithm::Rfis, Algorithm::Rams, Algorithm::Robust]
            {
                let ctx = format!("{alg:?}/{dist:?}/m={m} after giant-p warmup");
                let input = generate(&cfg, dist);
                warmed.set_config(cfg.clone());
                let got = warmed.run_algorithm(alg, input.clone());
                let want = Runner::new(cfg.clone()).run_algorithm(alg, input);
                assert_reports_identical(&want, &got, &ctx);
            }
        }
    }
}

/// The Fig. 2c regime that actually triggers deterministic message
/// assignment (fan-in ≫ k on AllToOne): the two-hop payload movement of
/// the Exchange port must reproduce the legacy overlay charging exactly.
#[test]
fn exchange_path_matches_legacy_in_dma_regime() {
    let cfg = RunConfig::default().with_p(512).with_n_per_pe(512);
    for alg in [Algorithm::Rams, Algorithm::NdmaAms] {
        let ctx = format!("{alg:?}/AllToOne/dma");
        let input = generate(&cfg, Distribution::AllToOne);
        let want = legacy::run(alg, &cfg, input.clone());
        let mut runner = Runner::new(cfg.clone());
        let got = runner.run_algorithm(alg, input);
        assert_reports_identical(&want, &got, &ctx);
    }
}
