//! The semantic test layer over the whole registry: every sorter in
//! `builtin_sorters()` — the 15 of the paper's evaluation **and** the
//! successor paper's AMS-1/2/3 — must, on a randomized grid of seeds ×
//! distributions × sizes (skewed, duplicate-heavy, and sparse included):
//!
//! * leave the output **globally sorted**,
//! * keep it a **permutation of the input** (order-independent multiset
//!   checksum on top of the element-exact `verify::validate`),
//! * respect its **declared `output_shape`** (composite sorters may
//!   legally degrade `Balanced` to a gather shape — the `Robust`
//!   selector does on sparse inputs — but fixed-shape sorters may not
//!   drift), and
//! * end the run with **`exchange_charged == exchange_moved`** on the
//!   machine-wide data-plane counters.
//!
//! Unlike the bit-identical oracle suites, these properties hold for any
//! future sorter too — a new `register`ed algorithm inherits this
//! coverage by being enumerable, with no per-algorithm pinning required.

use rmps::algorithms::{builtin_sorters, find_sorter, OutputShape, Runner, Sorter};
use rmps::config::RunConfig;
use rmps::elements::Elem;
use rmps::input::{generate, Distribution};
use rmps::localsort::RustSort;
use rmps::rng::Rng;
use rmps::sim::Machine;
use rmps::verify::{validate, validate_replicated};

/// splitmix64 finalizer — the checksum must not cancel structured inputs
/// (e.g. Mirrored pairs), so every element is mixed before folding.
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Order-independent multiset checksum: (count, wrapping sum, xor fold)
/// over mixed `(key, id)` pairs. Equal iff the multisets are equal with
/// overwhelming probability — and cheap enough to run on every cell.
fn multiset_checksum<'a>(elems: impl Iterator<Item = &'a Elem>) -> (usize, u64, u64) {
    let mut count = 0usize;
    let mut sum = 0u64;
    let mut xor = 0u64;
    for e in elems {
        let h = mix(e.key ^ mix(e.id));
        count += 1;
        sum = sum.wrapping_add(h);
        xor ^= h;
    }
    (count, sum, xor)
}

/// Run one cell directly on a [`Machine`] (the `Runner` hides its
/// machine, and the data-plane invariant counters live on the machine)
/// and assert every property the harness pins.
fn check_sorter(sorter: &dyn Sorter, cfg: &RunConfig, dist: Distribution, ctx: &str) {
    let input = generate(cfg, dist);
    let mut mach = Machine::new(cfg.p, cfg.cost);
    mach.mem_cap_elems = cfg.mem_cap_elems();
    let mut data = input.clone();
    let shape = sorter.sort(&mut mach, &mut data, cfg, &mut RustSort);

    // the data-plane invariant holds at run end even for crashed runs —
    // whatever was delivered before the crash was charged, and vice versa
    assert_eq!(
        mach.exchange_charged(),
        mach.exchange_moved(),
        "{ctx}: charged element count must equal moved"
    );

    if mach.crashed() {
        assert!(
            !sorter.is_robust(),
            "{ctx}: a robust sorter crashed in range: {:?}",
            mach.crash()
        );
        return; // mid-run state: output checks don't apply
    }

    // declared shape is honored: fixed-shape sorters return exactly what
    // they promise; composite sorters (declared Balanced) may pick a
    // gather-style delegate, which the shape-dispatched validation covers
    let declared = sorter.output_shape();
    assert!(
        shape == declared || declared == OutputShape::Balanced,
        "{ctx}: declared {declared:?} but produced {shape:?}"
    );

    // sorted + permutation, dispatched on the actual shape like the Runner
    let (v, output_view): (_, Vec<Vec<Elem>>) = match shape {
        OutputShape::Balanced => (validate(&input, &data, cfg.epsilon), data.clone()),
        OutputShape::RootOnly => {
            let mut proj = vec![Vec::new(); cfg.p];
            proj[0] = data[0].clone();
            (validate(&input, &proj, f64::INFINITY), proj)
        }
        OutputShape::Replicated => {
            let v = validate_replicated(&input, &data);
            (v, vec![data.first().cloned().unwrap_or_default()])
        }
    };
    assert!(v.locally_sorted, "{ctx}: output not locally sorted");
    assert!(v.globally_sorted, "{ctx}: output not globally sorted");
    assert!(v.multiset_preserved, "{ctx}: output is not a permutation of the input");

    // independent permutation witness: order-insensitive checksum
    assert_eq!(
        multiset_checksum(input.iter().flatten()),
        multiset_checksum(output_view.iter().flatten()),
        "{ctx}: multiset checksum diverged"
    );
}

/// The dense grid: every builtin × eleven distributions × three sizes,
/// with a per-cell randomized seed. Sizes straddle the inline/pooled
/// per-PE execution gate and include the duplicate-heavy and skewed
/// instances (Zero, DeterDupl, AllToOne) that kill nonrobust sorters.
#[test]
fn every_builtin_upholds_the_contract_on_the_dense_grid() {
    let mut rng = Rng::seeded(0x50_52_4F_50, 0); // "PROP"
    for sorter in builtin_sorters() {
        for dist in Distribution::ALL {
            for m in [1usize, 4, 64] {
                let p = 1usize << (2 + rng.below(3)); // 4..16
                let cfg = RunConfig::default()
                    .with_p(p)
                    .with_n_per_pe(m)
                    .with_seed(0x5EED ^ rng.below(1 << 30));
                if !sorter.valid_range(cfg.n_over_p(), p) {
                    continue; // out-of-range refusals are covered elsewhere
                }
                let ctx = format!("{}/{dist:?}/p={p}/m={m}", sorter.name());
                check_sorter(sorter.as_ref(), &cfg, dist, &ctx);
            }
        }
    }
}

/// The sparse regime (n < p): gather delegates, mostly-empty exchanges.
#[test]
fn every_builtin_upholds_the_contract_on_sparse_inputs() {
    let mut rng = Rng::seeded(0x50_52_4F_50, 1);
    for sorter in builtin_sorters() {
        for sparsity in [2usize, 8] {
            let p = 32;
            let cfg = RunConfig::default()
                .with_p(p)
                .with_sparsity(sparsity)
                .with_seed(0x5EED ^ rng.below(1 << 30));
            if !sorter.valid_range(cfg.n_over_p(), p) {
                continue;
            }
            let ctx = format!("{}/sparse(1/{sparsity})", sorter.name());
            check_sorter(sorter.as_ref(), &cfg, Distribution::Uniform, &ctx);
        }
    }
}

/// The giant-p regime: 2^16 PEs at the paper's sparsest point (3^-5 —
/// one element on every 243rd PE). Affordable even in debug builds
/// because supersteps cost O(active PEs + messages) host work, not O(p)
/// (the touched-slot contract on `sim::Machine`); the properties pinned
/// are exactly the dense grid's.
#[test]
fn giant_p_sparse_cells_uphold_the_contract() {
    let p = 1usize << 16;
    for name in ["GatherM", "RFIS", "Robust"] {
        let sorter = find_sorter(name).expect("giant-p sorter registered");
        let cfg = RunConfig::default().with_p(p).with_sparsity(243).with_seed(0x61A9);
        assert!(
            sorter.valid_range(cfg.n_over_p(), p),
            "{name} must cover the sparse end"
        );
        let ctx = format!("{name}/giant-p/p=2^16/sparse(1/243)");
        check_sorter(sorter.as_ref(), &cfg, Distribution::Uniform, &ctx);
    }
}

/// Acceptance pin for the tentpole: the AMS family sorts **all eleven
/// distributions** through the full `Runner` validation path, for every
/// registered level count.
#[test]
fn ams_family_passes_validation_on_all_eleven_distributions() {
    for k in 1..=3 {
        let sorter = find_sorter(&format!("AMS-{k}")).expect("AMS family registered");
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(256);
        let mut runner = Runner::new(cfg.clone());
        for dist in Distribution::ALL {
            let report = runner.run(sorter.as_ref(), generate(&cfg, dist));
            assert!(
                report.succeeded(),
                "AMS-{k}/{dist:?}: {:?} {:?}",
                report.crashed,
                report.validation
            );
        }
    }
}
