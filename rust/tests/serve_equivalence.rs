//! The serve determinism contract: a drained job stream produces
//! **field-by-field bit-identical** [`RunReport`]s to running every job
//! standalone through [`Runner::run`] with the same `(config, seed,
//! sorter)` — at every job-concurrency level (1, an awkward 3, and the
//! host width). Admission control, queueing, and worker interleaving
//! decide only *when* a job runs, never *what it computes*; crash
//! strings included (the robustness memory cap and the Minisort
//! out-of-range refusal must report identically from inside the
//! service).
//!
//! Plus the admission-control soak: while a host-width drain is in
//! flight, the process-wide worker-token budget must never go negative —
//! the job level is the third consumer of one shared pool, not a new
//! pool.

use rmps::algorithms::{Runner, RunReport};
use rmps::config::RunConfig;
use rmps::input::generate;
use rmps::serve::{resolve_sorter, JobSpec, Service, ServeOptions};

/// Field-by-field byte comparison (floats as raw bits). `wall_ms` is host
/// wallclock and exempt by nature.
fn assert_reports_identical(a: &RunReport, b: &RunReport, ctx: &str) {
    assert_eq!(a.algorithm, b.algorithm, "{ctx}: algorithm");
    assert_eq!(a.time.to_bits(), b.time.to_bits(), "{ctx}: time");
    assert_eq!(a.stats.messages, b.stats.messages, "{ctx}: messages");
    assert_eq!(a.stats.words, b.stats.words, "{ctx}: words");
    assert_eq!(
        a.stats.local_work.to_bits(),
        b.stats.local_work.to_bits(),
        "{ctx}: local_work"
    );
    assert_eq!(a.stats.max_mem_elems, b.stats.max_mem_elems, "{ctx}: max_mem_elems");
    assert_eq!(a.stats.max_degree, b.stats.max_degree, "{ctx}: max_degree");
    assert_eq!(a.crashed, b.crashed, "{ctx}: crashed");
    assert_eq!(a.output_shape, b.output_shape, "{ctx}: output_shape");
    assert_eq!(a.is_globally_sorted, b.is_globally_sorted, "{ctx}: is_globally_sorted");
    let (va, vb) = (&a.validation, &b.validation);
    assert_eq!(va.locally_sorted, vb.locally_sorted, "{ctx}: locally_sorted");
    assert_eq!(va.globally_sorted, vb.globally_sorted, "{ctx}: globally_sorted");
    assert_eq!(va.multiset_preserved, vb.multiset_preserved, "{ctx}: multiset");
    assert_eq!(va.balanced, vb.balanced, "{ctx}: balanced");
    assert_eq!(va.imbalance.max_load, vb.imbalance.max_load, "{ctx}: max_load");
    assert_eq!(va.imbalance.min_load, vb.imbalance.min_load, "{ctx}: min_load");
    assert_eq!(
        va.imbalance.epsilon.to_bits(),
        vb.imbalance.epsilon.to_bits(),
        "{ctx}: imbalance ε"
    );
    assert_eq!(a.output, b.output, "{ctx}: output");
}

/// The job-concurrency levels under test: inline, a deliberately awkward
/// odd count, and everything the host has.
fn job_levels() -> Vec<usize> {
    let host = rmps::exec::available_jobs();
    let mut v = vec![1usize, 3];
    if !v.contains(&host) {
        v.push(host);
    }
    v
}

/// A mixed stream exercising every routing and size regime: dense sizes
/// {1, 4, 64, 512}, a sparse job, forced sorters including two
/// memory-capped crashers (HykSort/SSort on hard instances, the
/// `pe_jobs_equivalence.rs` crash recipe) and the Minisort out-of-range
/// refusal, untargeted jobs (tuned Robust routing), and a per-job `p`
/// override.
const STREAM: &str = r#"
{"n_per_pe": 1, "seed": 11, "algo": "RQuick"}
{"n_per_pe": 4, "seed": 12, "algo": "RFIS", "dist": "Staggered"}
{"n_per_pe": 64, "seed": 13, "algo": "RAMS", "dist": "Zero"}
{"n_per_pe": 512, "seed": 14, "algo": "HykSort", "dist": "Zero", "mem_cap": 4.0}
{"n_per_pe": 512, "seed": 15, "algo": "SSort", "dist": "DeterDupl", "mem_cap": 4.0}
{"sparsity": 8, "seed": 16, "algo": "GatherM", "mem_cap": null}
{"n_per_pe": 4, "seed": 17, "algo": "Minisort"}
{"n_per_pe": 64, "seed": 18}
{"sparsity": 4, "seed": 19}
{"n_per_pe": 512, "seed": 20, "dist": "Mirrored"}
{"n_per_pe": 64, "seed": 21, "algo": "Bitonic", "p": 32}
{"n_per_pe": 32, "seed": 22, "algo": "AMS-2"}
"#;

fn stream_specs() -> Vec<JobSpec> {
    STREAM
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| JobSpec::parse(l).expect("stream is valid"))
        .collect()
}

fn base_config() -> RunConfig {
    RunConfig::default().with_p(16).with_n_per_pe(16)
}

/// What `rmps serve` must reproduce: each spec run standalone through a
/// fresh `Runner` (service defaults: validation and output retention on).
fn standalone_references(base: &RunConfig, specs: &[JobSpec]) -> Vec<RunReport> {
    specs
        .iter()
        .map(|spec| {
            let cfg = spec.config(base);
            let sorter = resolve_sorter(spec, &cfg, true).expect("stream sorters exist");
            Runner::new(cfg.clone()).run(sorter.as_ref(), generate(&cfg, spec.dist))
        })
        .collect()
}

#[test]
fn serve_is_bit_identical_to_standalone_runs_at_every_job_level() {
    let base = base_config();
    let specs = stream_specs();
    let references = standalone_references(&base, &specs);
    // the stream must genuinely exercise the crash paths
    let crashers = references.iter().filter(|r| r.crashed.is_some()).count();
    assert!(crashers >= 1, "stream contains no crashing jobs — recipe went stale");

    for jobs in job_levels() {
        let opts = ServeOptions { jobs, base: base.clone(), ..ServeOptions::default() };
        let out = Service::new(opts).drain(specs.clone());
        assert!(out.errors.is_empty(), "jobs={jobs}: {:?}", out.errors);
        assert_eq!(out.reports.len(), references.len(), "jobs={jobs}");
        for (i, (reference, got)) in references.iter().zip(&out.reports).enumerate() {
            assert_reports_identical(reference, got, &format!("job {i}/jobs={jobs}"));
        }
        // records line up with reports, in admission order
        for (i, rec) in out.records.iter().enumerate() {
            assert_eq!(rec.id, i, "jobs={jobs}");
            assert_eq!(rec.algorithm, out.reports[i].algorithm, "jobs={jobs}");
            assert_eq!(
                rec.crashed,
                out.reports[i].crashed.is_some(),
                "jobs={jobs}: record/report crash flag"
            );
            assert_eq!(
                rec.sim_time.to_bits(),
                out.reports[i].time.to_bits(),
                "jobs={jobs}: record sim_time"
            );
        }
        assert_eq!(out.stats.crashed, crashers, "jobs={jobs}");
    }
}

#[test]
fn serve_stats_digest_is_coherent() {
    let base = base_config();
    let out = Service::new(ServeOptions {
        jobs: rmps::exec::available_jobs(),
        base,
        ..ServeOptions::default()
    })
    .drain(stream_specs());

    let s = &out.stats;
    assert_eq!(s.jobs, out.reports.len());
    assert!(s.wall_s > 0.0 && s.throughput_jobs_per_s > 0.0);
    for (label, p) in [("queue", &s.queue), ("service", &s.service), ("e2e", &s.total)] {
        assert!(p.p50 <= p.p95 && p.p95 <= p.p99 && p.p99 <= p.max, "{label}: {p:?}");
        assert!(p.p50 >= 0.0, "{label}: negative latency");
    }
    // e2e of any single job dominates both of its components
    for rec in &out.records {
        assert!(rec.total_us + 1.0 >= rec.queue_us && rec.total_us + 1.0 >= rec.service_us);
    }
    let per_sorter_total: usize = s.per_sorter.iter().map(|(_, n)| n).sum();
    assert_eq!(per_sorter_total, s.jobs, "per-sorter counts partition the stream");
    assert!(s.per_sorter.iter().any(|(name, _)| *name == "Robust"), "untargeted jobs routed");
    assert_eq!(
        s.machine_reuse_hits + s.machine_fresh_builds,
        s.jobs,
        "every job is either a reuse hit or a fresh build"
    );
    // JSON digest carries the SLO keys BENCH_serve.json promises
    let json = s.to_json();
    for key in ["throughput_jobs_per_s", "queue_us", "service_us", "e2e_us", "p99"] {
        assert!(json.contains(key), "missing {key} in {json}");
    }
}

/// Admission-control soak: hammer host-width drains while a monitor
/// thread polls the process-wide worker-token budget. The job grant, the
/// PE-task level, and the pool must share one budget — a negative
/// remainder means oversubscription and fails the test.
#[test]
fn soak_worker_token_budget_is_never_exceeded() {
    use std::sync::atomic::{AtomicBool, Ordering};

    let stop = AtomicBool::new(false);
    let violated = AtomicBool::new(false);
    std::thread::scope(|scope| {
        scope.spawn(|| {
            while !stop.load(Ordering::Relaxed) {
                if rmps::exec::budget_remaining() < 0 {
                    violated.store(true, Ordering::Relaxed);
                }
                std::thread::yield_now();
            }
        });

        let base = base_config();
        let specs = stream_specs();
        for round in 0..3u64 {
            let mut specs = specs.clone();
            for spec in &mut specs {
                // shift seeds so rounds are distinct work, same shape
                spec.seed = spec.seed.map(|s| s + 1000 * round);
            }
            let out = Service::new(ServeOptions {
                jobs: rmps::exec::available_jobs(),
                base: base.clone(),
                keep_output: false,
                ..ServeOptions::default()
            })
            .drain(specs);
            assert_eq!(out.reports.len(), stream_specs().len(), "round {round}");
        }
        stop.store(true, Ordering::Relaxed);
    });
    assert!(!violated.load(Ordering::Relaxed), "worker-token budget went negative");
}
