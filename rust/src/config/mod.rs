//! Run configuration: machine size, input size/sparsity, cost model,
//! balance requirement, robustness knobs. Serializable so experiment
//! sweeps and the CLI share one source of truth.

use crate::model::CostModel;

/// Configuration of a single sorting run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Number of PEs (power of two for the hypercube algorithms).
    pub p: usize,
    /// Elements per PE for dense inputs (`sparsity == 1`).
    pub n_per_pe: usize,
    /// Sparsity factor: if `> 1`, only every `sparsity`-th PE holds one
    /// element and `n_per_pe` is ignored (the paper's `n/p = 3^-k` points).
    pub sparsity: usize,
    /// Master seed; every PE derives its own deterministic stream.
    pub seed: u64,
    /// α-β cost model.
    pub cost: CostModel,
    /// Output balance requirement: at most `(1+epsilon)·n/p` per PE.
    pub epsilon: f64,
    /// Per-PE memory budget as a multiple of `max(n/p, 1)`; exceeding it
    /// is a crash (nonrobust algorithms on hard instances). `None` = ∞.
    pub mem_cap_factor: Option<f64>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            p: 1 << 8,
            n_per_pe: 1 << 10,
            sparsity: 1,
            seed: 0xC0FFEE,
            cost: CostModel::default(),
            epsilon: 0.2,
            mem_cap_factor: Some(64.0),
        }
    }
}

impl RunConfig {
    /// Total input size n.
    pub fn n_total(&self) -> usize {
        if self.sparsity > 1 {
            self.p.div_ceil(self.sparsity)
        } else {
            self.p * self.n_per_pe
        }
    }

    /// n/p as a float (can be < 1 for sparse inputs).
    pub fn n_over_p(&self) -> f64 {
        self.n_total() as f64 / self.p as f64
    }

    /// The memory cap in elements, if enabled.
    pub fn mem_cap_elems(&self) -> Option<usize> {
        self.mem_cap_factor.map(|f| {
            let per_pe = (self.n_total() as f64 / self.p as f64).max(1.0);
            // at least a few thousand elements so tiny runs never trip it
            ((f * per_pe) as usize).max(4096)
        })
    }

    pub fn with_p(mut self, p: usize) -> Self {
        self.p = p;
        self
    }

    pub fn with_n_per_pe(mut self, n: usize) -> Self {
        self.n_per_pe = n;
        self.sparsity = 1;
        self
    }

    pub fn with_sparsity(mut self, s: usize) -> Self {
        self.sparsity = s;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_dense_and_sparse() {
        let c = RunConfig::default().with_p(64).with_n_per_pe(10);
        assert_eq!(c.n_total(), 640);
        assert!((c.n_over_p() - 10.0).abs() < 1e-12);
        let s = RunConfig::default().with_p(64).with_sparsity(9);
        assert_eq!(s.n_total(), 8);
        assert!(s.n_over_p() < 1.0);
    }

    #[test]
    fn mem_cap_floor() {
        let c = RunConfig::default().with_p(4).with_n_per_pe(2);
        assert!(c.mem_cap_elems().unwrap() >= 4096);
    }

    #[test]
    fn builder_roundtrip() {
        let c = RunConfig::default().with_p(16).with_n_per_pe(8).with_seed(7);
        assert_eq!((c.p, c.n_per_pe, c.seed), (16, 8, 7));
    }
}
