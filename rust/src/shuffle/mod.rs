//! Randomized shuffling on hypercubes (§III-A, Appendix C).
//!
//! The folklore skew-removal: Helman et al. send every element to a random
//! PE directly (α·p + β·n/p). The paper's small-input variant routes along
//! the hypercube instead — each PE splits its local data into two random
//! halves per dimension and ships one half to the partner — for
//! O((α + β·n/p)·log p) total.

use crate::elements::Elem;
use crate::rng::Rng;
use crate::sim::{Cube, Machine};

/// Hypercube random redistribution over `cube`. `data` is indexed by
/// global PE; only cube members are touched. After the call, every element
/// resides on a uniformly random member (up to the balanced-split
/// constraint, which the paper prefers for slightly better balance).
pub fn hypercube_shuffle(
    mach: &mut Machine,
    cube: Cube,
    data: &mut [Vec<Elem>],
    rng: &mut Rng,
) {
    let size = cube.size();
    let base = cube.base();
    for j in (0..cube.dim).rev() {
        let bit = 1usize << j;
        // each member splits locally into keep/send halves; the send half
        // goes straight into the exchange as one pooled payload — no
        // per-dimension outgoing table. The split loop stays sequential:
        // all members draw from one shared RNG stream, so task-parallel
        // execution would reorder the draws and change the (seeded,
        // reproducible) permutation.
        let mut ex = mach.exchange();
        for r in 0..size {
            let pe = base + r;
            let local = std::mem::take(&mut data[pe]);
            mach.work_linear(pe, local.len());
            // balanced random split (App. C's "split local data in two
            // random halves"): a *partial* Fisher–Yates that randomises
            // only the kept prefix — half the RNG draws and moves of a
            // full shuffle, same uniform-random-subset distribution (§Perf)
            let mut v = local;
            let half = v.len() / 2;
            let extra = v.len() % 2 == 1 && rng.coin();
            let cut = half + usize::from(extra);
            for i in 0..cut {
                let j = i + rng.below((v.len() - i) as u64) as usize;
                v.swap(i, j);
            }
            let mut send = mach.take_buf();
            send.extend_from_slice(&v[cut..]);
            v.truncate(cut);
            data[pe] = v;
            ex.xchg_leg(pe, base + (r ^ bit), send);
        }
        let inboxes = ex.deliver(mach);
        // receive-side materialization: one PE task per member
        let total: usize = (0..size).map(|r| inboxes.total(base + r)).sum();
        mach.par_pes(
            base,
            crate::sim::ParSpec::work(2 * total),
            &mut data[base..base + size],
            |ctx, run| {
                run.extend_from_slice(inboxes.single(ctx.pe()));
                ctx.note_mem(run.len(), "hypercube shuffle");
            },
        );
        mach.recycle(inboxes);
    }
}

/// Direct shuffle (Helman et al. [5]): each element is sent straight to a
/// uniformly random PE — one irregular round costing up to α·p startups
/// per PE. Used by SSort-style baselines.
pub fn direct_shuffle(
    mach: &mut Machine,
    cube: Cube,
    data: &mut [Vec<Elem>],
    rng: &mut Rng,
) {
    let size = cube.size();
    let base = cube.base();
    let mut buckets: Vec<Vec<Vec<Elem>>> = (0..size)
        .map(|_| (0..size).map(|_| mach.take_buf()).collect())
        .collect();
    for r in 0..size {
        let pe = base + r;
        for e in std::mem::take(&mut data[pe]) {
            let t = rng.below(size as u64) as usize;
            buckets[r][t].push(e);
        }
        mach.work_linear(pe, buckets[r].iter().map(Vec::len).sum());
    }
    let recv = crate::sim::alltoallv(mach, &cube.pe_vec(), buckets);
    for (r, runs) in recv.into_iter().enumerate() {
        let pe = base + r;
        for run in runs {
            data[pe].extend_from_slice(&run);
            mach.recycle_buf(run);
        }
        mach.note_mem(pe, data[pe].len(), "direct shuffle");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CostModel;

    fn machine(p: usize) -> Machine {
        Machine::new(p, CostModel { alpha: 100.0, beta: 1.0, cmp: 1.0, duplex: true })
    }

    fn skewed_input(p: usize, n: usize) -> Vec<Vec<Elem>> {
        // everything on PE 0 — maximal skew
        let mut data = vec![Vec::new(); p];
        data[0] = (0..n).map(|i| Elem::new(i as u64, 0, i)).collect();
        data
    }

    #[test]
    fn hypercube_shuffle_preserves_multiset() {
        let p = 16;
        let mut mach = machine(p);
        let mut rng = Rng::seeded(1, 0);
        let mut data = skewed_input(p, 512);
        let mut before: Vec<Elem> = data.iter().flatten().copied().collect();
        hypercube_shuffle(&mut mach, Cube::whole(p), &mut data, &mut rng);
        let mut after: Vec<Elem> = data.iter().flatten().copied().collect();
        before.sort();
        after.sort();
        assert_eq!(before, after);
    }

    #[test]
    fn hypercube_shuffle_balances_skew() {
        let p = 16;
        let n = 1024;
        let mut mach = machine(p);
        let mut rng = Rng::seeded(2, 0);
        let mut data = skewed_input(p, n);
        hypercube_shuffle(&mut mach, Cube::whole(p), &mut data, &mut rng);
        let avg = n / p;
        for (pe, v) in data.iter().enumerate() {
            assert!(
                v.len() <= 2 * avg && v.len() >= avg / 2,
                "PE {pe} holds {} (avg {avg})",
                v.len()
            );
        }
    }

    #[test]
    fn hypercube_shuffle_latency_is_logarithmic() {
        let p = 64;
        let mut mach = machine(p);
        let mut rng = Rng::seeded(3, 0);
        let mut data: Vec<Vec<Elem>> = (0..p)
            .map(|pe| (0..8).map(|i| Elem::new(i as u64, pe, i)).collect())
            .collect();
        hypercube_shuffle(&mut mach, Cube::whole(p), &mut data, &mut rng);
        // 6 dims → ~6 α-rounds, far below the α·p of a direct exchange
        assert!(mach.time() < 10.0 * 100.0 + 600.0, "time {}", mach.time());
    }

    #[test]
    fn direct_shuffle_preserves_multiset_and_costs_p_startups() {
        let p = 8;
        let mut mach = machine(p);
        let mut rng = Rng::seeded(4, 0);
        let mut data: Vec<Vec<Elem>> = (0..p)
            .map(|pe| (0..64).map(|i| Elem::new((pe * 64 + i) as u64, pe, i)).collect())
            .collect();
        let before: usize = data.iter().map(Vec::len).sum();
        direct_shuffle(&mut mach, Cube::whole(p), &mut data, &mut rng);
        let after: usize = data.iter().map(Vec::len).sum();
        assert_eq!(before, after);
        assert!(mach.stats.messages as usize >= p * (p - 1) / 2);
    }

    #[test]
    fn shuffle_on_subcube_leaves_rest_alone() {
        let p = 8;
        let mut mach = machine(p);
        let mut rng = Rng::seeded(5, 0);
        let mut data: Vec<Vec<Elem>> = (0..p)
            .map(|pe| vec![Elem::new(pe as u64, pe, 0)])
            .collect();
        let cube = Cube { prefix: 0, dim: 2 }; // PEs 0..4
        hypercube_shuffle(&mut mach, cube, &mut data, &mut rng);
        for pe in 4..8 {
            assert_eq!(data[pe].len(), 1);
            assert_eq!(data[pe][0].key, pe as u64);
            assert_eq!(mach.clock(pe), 0.0);
        }
        let low: usize = data[..4].iter().map(Vec::len).sum();
        assert_eq!(low, 4);
    }
}
