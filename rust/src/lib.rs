//! # rmps — Robust Massively Parallel Sorting
//!
//! A full reproduction of *Robust Massively Parallel Sorting*
//! (Axtmann & Sanders, 2016): the four robust algorithms that together
//! cover the entire input-size spectrum — **GatherM** (very sparse),
//! **RFIS** (sparse/tiny), **RQuick** (small), **RAMS** (large) — plus every
//! baseline the paper compares against (AllGatherM, Bitonic, SSort,
//! HykSort, and the nonrobust NTB-/NDMA- ablation variants).
//!
//! The machine substrate is a deterministic single-ported α-β
//! message-passing simulator ([`sim`]): algorithms move *real elements*
//! between virtual PEs while per-PE virtual clocks advance by `α + β·len`
//! per message plus calibrated local work — exactly the cost model the
//! paper's analysis (Table I / Appendix A, see [`model`]) is stated in, so
//! crossover points and robustness blowups reproduce even though absolute
//! seconds belong to JUQUEEN.
//!
//! The default build is pure Rust: node-local sorting uses pdqsort
//! ([`localsort::RustSort`]) or the digit-skipping LSD radix kernel
//! ([`localsort::RadixSort`], `--sort-backend radix-lsd` /
//! `RMPS_SORT_BACKEND`) and nothing outside the standard library is
//! required. With the off-by-default `xla` cargo feature, the node-local
//! hot phases (batched bitonic local sort and the Super Scalar Sample Sort
//! classifier) can instead execute AOT-compiled JAX/Pallas kernels through
//! PJRT via the [`runtime`] module; Python never runs on the sort path.
//!
//! Runs go through the builder-style [`algorithms::Runner`], which owns
//! the simulated machine and reuses it across batched runs; algorithms are
//! first-class [`algorithms::Sorter`] values enumerated by
//! [`algorithms::registry`] (external implementations join via
//! [`algorithms::register`]):
//!
//! ```no_run
//! use rmps::prelude::*;
//!
//! let cfg = RunConfig { p: 1 << 8, n_per_pe: 1 << 10, ..Default::default() };
//! let mut runner = Runner::new(cfg.clone());
//! let input = rmps::input::generate(&cfg, Distribution::Uniform);
//! let report = runner.run_algorithm(Algorithm::RQuick, input);
//! assert!(report.is_globally_sorted);
//!
//! // batched: same runner, new seed per repetition, machine scratch reused
//! let batch = (0..5u64).map(|s| {
//!     let cfg = cfg.clone().with_seed(s);
//!     let input = rmps::input::generate(&cfg, Distribution::Staggered);
//!     (cfg, input)
//! });
//! let sorter = Algorithm::Robust.sorter();
//! let reports = runner.run_many(sorter.as_ref(), batch);
//! assert!(reports.iter().all(|r| r.succeeded()));
//! ```
//!
//! The pre-redesign free functions `algorithms::run` /
//! `algorithms::run_with_backend` remain as thin shims over the same core
//! and produce byte-identical reports (see `rust/tests/runner_equivalence.rs`).

// Tolerate lint names that older clippy releases do not know yet.
#![allow(unknown_lints)]
// The simulator walks many parallel per-PE arrays by rank in lock-step
// (clocks, payloads, outboxes, histograms), so index loops *are* the
// clearest expression of the algorithms, and the message/bucket plumbing
// carries deliberately explicit nested types. Allowed once here instead of
// peppering every module.
#![allow(
    clippy::needless_range_loop,
    clippy::type_complexity,
    clippy::ptr_arg,
    clippy::unnecessary_unwrap,
    clippy::unnecessary_map_or,
    clippy::collapsible_if,
    clippy::map_entry,
    clippy::too_many_arguments
)]

pub mod algorithms;
pub mod config;
pub mod elements;
pub mod exec;
pub mod experiments;
pub mod input;
pub mod localsort;
pub mod median;
pub mod metrics;
pub mod model;
pub mod partition;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod shuffle;
pub mod sim;
pub mod verify;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::algorithms::selector::CrossoverTable;
    pub use crate::algorithms::{
        find_sorter, register, registry, Algorithm, OutputShape, Runner, RunReport, Sorter,
    };
    pub use crate::config::RunConfig;
    pub use crate::elements::Elem;
    pub use crate::input::Distribution;
    pub use crate::model::CostModel;
    pub use crate::sim::{Exchange, Inboxes, Machine, ParSpec, PeCtx};
}
