//! Input instance generators — the eleven distributions of the paper's
//! evaluation (§VII, Appendix J): the eight Helman et al. instances
//! (Uniform, Gaussian, BucketSorted, DeterDupl, RandDupl, Zero, g-Group,
//! Staggered) plus Mirrored, AllToOne, and Reverse, each designed to
//! break a specific nonrobust mechanism.
//!
//! Keys are drawn from `[0, 2^32)` like the paper's 32-bit key ranges;
//! every element carries a unique origin id (never read by nonrobust
//! variants).

use crate::config::RunConfig;
use crate::elements::Elem;
use crate::rng::Rng;
use crate::sim::bit_reverse;

/// Key domain (the paper generates 32-bit keys inside 64-bit elements).
pub const KEY_RANGE: u64 = 1 << 32;

/// The benchmark input instances.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Distribution {
    /// Independent uniform random keys.
    Uniform,
    /// Independent Gaussian keys (centre 2^31).
    Gaussian,
    /// Locally random, globally sorted: PE i draws from bucket i.
    BucketSorted,
    /// Deterministic duplicates: halving blocks of identical keys —
    /// only O(log n) distinct keys (kills algorithms without tie-breaking).
    DeterDupl,
    /// 32 local buckets of random size, each filled with one value 0..31.
    RandDupl,
    /// All keys equal.
    Zero,
    /// √p groups, bit-reversed group-to-bucket mapping.
    GGroup,
    /// Helman's staggered instance (hard for hypercube routing).
    Staggered,
    /// Bit-reversed PE→bucket mapping: after log(p)/2 naive quicksort
    /// recursions, √p PEs hold n/√p elements each (§VII).
    Mirrored,
    /// All last elements route to PE 0 at the first sample-sort level:
    /// min(p, n/p) messages hit one PE without DMA (Fig. 2c).
    AllToOne,
    /// Globally reverse-sorted.
    Reverse,
}

impl Distribution {
    pub const ALL: [Distribution; 11] = [
        Distribution::Uniform,
        Distribution::Gaussian,
        Distribution::BucketSorted,
        Distribution::DeterDupl,
        Distribution::RandDupl,
        Distribution::Zero,
        Distribution::GGroup,
        Distribution::Staggered,
        Distribution::Mirrored,
        Distribution::AllToOne,
        Distribution::Reverse,
    ];

    /// The four instances Figure 1 plots.
    pub const FIG1: [Distribution; 4] = [
        Distribution::Uniform,
        Distribution::Staggered,
        Distribution::BucketSorted,
        Distribution::DeterDupl,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Distribution::Uniform => "Uniform",
            Distribution::Gaussian => "Gaussian",
            Distribution::BucketSorted => "BucketSorted",
            Distribution::DeterDupl => "DeterDupl",
            Distribution::RandDupl => "RandDupl",
            Distribution::Zero => "Zero",
            Distribution::GGroup => "g-Group",
            Distribution::Staggered => "Staggered",
            Distribution::Mirrored => "Mirrored",
            Distribution::AllToOne => "AllToOne",
            Distribution::Reverse => "Reverse",
        }
    }

    /// Resolve a name, insensitive to ASCII case and to `-` separators
    /// (`"g-group"`, `"ggroup"`, and `"G-Group"` all parse). Allocation
    /// free: candidates are compared byte-wise with dashes skipped.
    pub fn parse(s: &str) -> Option<Distribution> {
        fn eq_loose(a: &str, b: &str) -> bool {
            let mut ai = a.bytes().filter(|&c| c != b'-');
            let mut bi = b.bytes().filter(|&c| c != b'-');
            loop {
                match (ai.next(), bi.next()) {
                    (None, None) => return true,
                    (Some(x), Some(y)) if x.eq_ignore_ascii_case(&y) => {}
                    _ => return false,
                }
            }
        }
        Self::ALL.iter().copied().find(|d| eq_loose(d.name(), s))
    }
}

/// A generated instance in **occupied-run form**: only PEs that actually
/// hold elements carry an entry, so a sparse instance on a giant machine
/// (p = 2^18, one element per 243rd PE) costs O(occupied) to generate and
/// hold — not p vector headers.
///
/// [`generate`] is a thin wrapper ([`CompactInput::into_dense`]) around
/// this type, so dense and compact generation are bit-identical by
/// construction; giant-p call sites generate compactly, keep the compact
/// form across repetitions, and expand only when a sorter needs the dense
/// per-PE table.
#[derive(Clone, Debug)]
pub struct CompactInput {
    p: usize,
    /// `(pe, elements)` for every occupied PE, `pe` strictly increasing.
    runs: Vec<(usize, Vec<Elem>)>,
}

impl CompactInput {
    /// Machine size this instance was generated for.
    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    /// Occupied PEs (entries in [`CompactInput::runs`]).
    #[inline]
    pub fn occupied(&self) -> usize {
        self.runs.len()
    }

    /// Total elements across all occupied PEs.
    pub fn n_total(&self) -> usize {
        self.runs.iter().map(|(_, v)| v.len()).sum()
    }

    /// The occupied runs, ordered by PE.
    #[inline]
    pub fn runs(&self) -> &[(usize, Vec<Elem>)] {
        &self.runs
    }

    /// Expand to the dense one-vector-per-PE table the sorters consume,
    /// cloning the runs (the compact form stays usable — repetition loops
    /// expand per rep). Bit-identical to [`generate`].
    pub fn expand(&self) -> Vec<Vec<Elem>> {
        let mut data = vec![Vec::new(); self.p];
        for (pe, run) in &self.runs {
            data[*pe] = run.clone();
        }
        data
    }

    /// Expand into an existing dense table, reusing its headers and run
    /// capacities: every slot is cleared, occupied slots are refilled.
    /// `data` must already have length ≥ p (e.g. the table of the previous
    /// repetition); grows it if shorter.
    pub fn expand_into(&self, data: &mut Vec<Vec<Elem>>) {
        if data.len() < self.p {
            data.resize_with(self.p, Vec::new);
        }
        for run in data.iter_mut() {
            run.clear();
        }
        for (pe, run) in &self.runs {
            data[*pe].extend_from_slice(run);
        }
    }

    /// Consume into the dense table without cloning the element runs.
    pub fn into_dense(self) -> Vec<Vec<Elem>> {
        let mut data = vec![Vec::new(); self.p];
        for (pe, run) in self.runs {
            data[pe] = run;
        }
        data
    }
}

/// Generate the full input: one vector of elements per PE.
pub fn generate(cfg: &RunConfig, dist: Distribution) -> Vec<Vec<Elem>> {
    generate_compact(cfg, dist).into_dense()
}

/// Generate in occupied-run form ([`CompactInput`]): O(occupied PEs) work
/// and memory, the giant-p entry point. Dense [`generate`] delegates here.
pub fn generate_compact(cfg: &RunConfig, dist: Distribution) -> CompactInput {
    let p = cfg.p;
    let runs = if cfg.sparsity > 1 {
        (0..p)
            .step_by(cfg.sparsity)
            .map(|pe| (pe, generate_pe(cfg, dist, pe, 1)))
            .collect()
    } else {
        (0..p).map(|pe| (pe, generate_pe(cfg, dist, pe, cfg.n_per_pe))).collect()
    };
    CompactInput { p, runs }
}

/// Keys for one PE (m elements), per the instance definitions.
fn generate_pe(cfg: &RunConfig, dist: Distribution, pe: usize, m: usize) -> Vec<Elem> {
    let p = cfg.p as u64;
    let logp = (cfg.p.max(2)).trailing_zeros().max(1);
    let mut rng = Rng::seeded(cfg.seed, pe as u64);
    let bucket_w = (KEY_RANGE / p).max(1);
    let keys: Vec<u64> = match dist {
        Distribution::Uniform => (0..m).map(|_| rng.below(KEY_RANGE)).collect(),
        Distribution::Gaussian => (0..m)
            .map(|_| {
                let x = rng.normal() * (KEY_RANGE as f64 / 8.0) + KEY_RANGE as f64 / 2.0;
                x.clamp(0.0, (KEY_RANGE - 1) as f64) as u64
            })
            .collect(),
        Distribution::BucketSorted => {
            let lo = pe as u64 * bucket_w;
            (0..m).map(|_| rng.range(lo, lo + bucket_w)).collect()
        }
        Distribution::DeterDupl => {
            // halving blocks of identical keys: values log2(n), log2(n/2)…
            let n = (cfg.p * m).max(2);
            let top = 63 - (n as u64).leading_zeros() as u64; // ≈ log2 n
            let mut keys = Vec::with_capacity(m);
            let mut block = m / 2;
            let mut v = top;
            while keys.len() < m && block > 0 {
                for _ in 0..block {
                    if keys.len() < m {
                        keys.push(v);
                    }
                }
                block /= 2;
                v = v.saturating_sub(1);
            }
            while keys.len() < m {
                keys.push(0);
            }
            keys
        }
        Distribution::RandDupl => {
            // 32 local buckets of random size, each filled with a value 0..31
            let mut keys = Vec::with_capacity(m);
            while keys.len() < m {
                let remaining = m - keys.len();
                let size = (rng.below(m.max(1) as u64 / 8 + 1) as usize + 1).min(remaining);
                let v = rng.below(32);
                let new_len = keys.len() + size;
                keys.resize(new_len, v);
            }
            keys
        }
        Distribution::Zero => vec![0; m],
        Distribution::GGroup => {
            // g = √p groups; group j draws from bucket bit_reverse(j)
            let g = (1usize << (logp / 2)).max(1);
            let group = pe / (cfg.p / g).max(1);
            let gbits = g.trailing_zeros();
            let bucket = bit_reverse(group, gbits) as u64;
            let w = (KEY_RANGE / g as u64).max(1);
            let lo = bucket * w;
            (0..m).map(|_| rng.range(lo, lo + w)).collect()
        }
        Distribution::Staggered => {
            // PE i < p/2 → bucket 2i+1; else bucket 2(i − p/2)
            let half = cfg.p / 2;
            let bucket = if pe < half.max(1) {
                (2 * pe + 1) as u64 % p
            } else {
                (2 * (pe - half)) as u64
            };
            let lo = bucket * bucket_w;
            (0..m).map(|_| rng.range(lo, lo + bucket_w)).collect()
        }
        Distribution::Mirrored => {
            let bucket = bit_reverse(pe, logp) as u64 % p;
            let lo = bucket * bucket_w;
            (0..m).map(|_| rng.range(lo, lo + bucket_w)).collect()
        }
        Distribution::AllToOne => {
            // first m−1 elements: decreasing bucket by PE (reverse-sorted
            // globally); last element: tiny key p − i → all route to PE 0.
            let span = KEY_RANGE - p;
            let w = (span / p).max(1);
            let lo = p + (p - 1 - pe as u64) * w;
            let hi = lo + w;
            let mut keys: Vec<u64> =
                (0..m.saturating_sub(1)).map(|_| rng.range(lo, hi.min(KEY_RANGE))).collect();
            keys.push(p - pe as u64);
            keys
        }
        Distribution::Reverse => {
            // globally reverse sorted, unique-ish keys
            let lo = (p - 1 - pe as u64) * bucket_w;
            let step = (bucket_w / m.max(1) as u64).max(1);
            (0..m).map(|j| lo + (m - 1 - j) as u64 * step).collect()
        }
    };
    keys.into_iter()
        .enumerate()
        .map(|(idx, key)| Elem::new(key, pe, idx))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(p: usize, m: usize) -> RunConfig {
        RunConfig::default().with_p(p).with_n_per_pe(m)
    }

    #[test]
    fn all_distributions_generate_right_sizes_and_unique_ids() {
        let c = cfg(16, 32);
        for d in Distribution::ALL {
            let data = generate(&c, d);
            assert_eq!(data.len(), 16);
            assert!(data.iter().all(|v| v.len() == 32), "{d:?}");
            let mut ids: Vec<u64> = data.iter().flatten().map(|e| e.id).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), 512, "{d:?} ids must be unique");
            assert!(data.iter().flatten().all(|e| e.key < KEY_RANGE), "{d:?}");
        }
    }

    #[test]
    fn sparse_only_every_kth_pe() {
        let c = RunConfig::default().with_p(27).with_sparsity(9);
        let data = generate(&c, Distribution::Uniform);
        for (pe, v) in data.iter().enumerate() {
            assert_eq!(v.len(), usize::from(pe % 9 == 0));
        }
        assert_eq!(data.iter().map(Vec::len).sum::<usize>(), 3);
    }

    #[test]
    fn bucket_sorted_is_globally_sorted_across_pes() {
        let c = cfg(8, 64);
        let data = generate(&c, Distribution::BucketSorted);
        for pe in 0..7 {
            let max = data[pe].iter().map(|e| e.key).max().unwrap();
            let min = data[pe + 1].iter().map(|e| e.key).min().unwrap();
            assert!(max <= min + (KEY_RANGE / 8), "adjacent buckets overlap grossly");
            assert!(
                data[pe].iter().map(|e| e.key).min().unwrap()
                    < data[pe + 1].iter().map(|e| e.key).max().unwrap()
            );
        }
    }

    #[test]
    fn deter_dupl_has_few_distinct_keys() {
        let c = cfg(32, 256);
        let data = generate(&c, Distribution::DeterDupl);
        let mut keys: Vec<u64> = data.iter().flatten().map(|e| e.key).collect();
        keys.sort_unstable();
        keys.dedup();
        assert!(keys.len() <= 2 * 13 + 2, "distinct keys: {}", keys.len());
    }

    #[test]
    fn zero_is_all_equal() {
        let data = generate(&cfg(4, 16), Distribution::Zero);
        assert!(data.iter().flatten().all(|e| e.key == 0));
    }

    #[test]
    fn all_to_one_last_elements_are_tiny() {
        let c = cfg(16, 8);
        let data = generate(&c, Distribution::AllToOne);
        for (pe, v) in data.iter().enumerate() {
            let last = v.last().unwrap().key;
            assert_eq!(last, 16 - pe as u64);
            // non-last elements are all ≥ p (route high)
            assert!(v[..v.len() - 1].iter().all(|e| e.key >= 16));
        }
    }

    #[test]
    fn reverse_is_globally_descending_across_pes() {
        let c = cfg(8, 4);
        let data = generate(&c, Distribution::Reverse);
        let flat: Vec<u64> = data.iter().flatten().map(|e| e.key).collect();
        let mut sorted = flat.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(flat, sorted, "must already be reverse-sorted");
    }

    #[test]
    fn mirrored_buckets_are_bit_reversed() {
        let c = cfg(8, 16);
        let data = generate(&c, Distribution::Mirrored);
        let w = KEY_RANGE / 8;
        for (pe, v) in data.iter().enumerate() {
            let bucket = bit_reverse(pe, 3) as u64;
            assert!(v.iter().all(|e| e.key / w == bucket), "pe {pe}");
        }
    }

    #[test]
    fn compact_matches_dense_and_counts_occupied() {
        let c = RunConfig::default().with_p(27).with_sparsity(9);
        let compact = generate_compact(&c, Distribution::Uniform);
        assert_eq!(compact.p(), 27);
        assert_eq!(compact.occupied(), 3);
        assert_eq!(compact.n_total(), 3);
        let dense = generate(&c, Distribution::Uniform);
        assert_eq!(compact.expand(), dense);
        // expand_into reuses a dirty table of any prior shape
        let mut reused = vec![vec![Elem::new(9, 0, 0)]; 27];
        compact.expand_into(&mut reused);
        assert_eq!(reused, dense);
        let mut short: Vec<Vec<Elem>> = Vec::new();
        compact.expand_into(&mut short);
        assert_eq!(short, dense);
        assert_eq!(compact.into_dense(), dense);
        // dense configs round-trip too (every PE occupied)
        let c = cfg(8, 4);
        let compact = generate_compact(&c, Distribution::Staggered);
        assert_eq!(compact.occupied(), 8);
        assert_eq!(compact.into_dense(), generate(&c, Distribution::Staggered));
    }

    #[test]
    fn generation_is_deterministic() {
        let c = cfg(8, 32);
        let a = generate(&c, Distribution::Uniform);
        let b = generate(&c, Distribution::Uniform);
        assert_eq!(a, b);
        let c2 = c.clone().with_seed(999);
        assert_ne!(a, generate(&c2, Distribution::Uniform));
    }

    #[test]
    fn parse_names() {
        assert_eq!(Distribution::parse("uniform"), Some(Distribution::Uniform));
        assert_eq!(Distribution::parse("g-group"), Some(Distribution::GGroup));
        assert_eq!(Distribution::parse("ggroup"), Some(Distribution::GGroup));
        assert_eq!(Distribution::parse("nope"), None);
    }

    /// Every name round-trips through `parse`, insensitive to case and to
    /// `-` separators; near-misses (prefixes, extensions) are rejected.
    #[test]
    fn parse_round_trips_every_distribution() {
        assert_eq!(Distribution::ALL.len(), 11);
        for d in Distribution::ALL {
            let name = d.name();
            assert_eq!(Distribution::parse(name), Some(d), "{name}");
            assert_eq!(Distribution::parse(&name.to_lowercase()), Some(d), "{name} lower");
            assert_eq!(Distribution::parse(&name.to_uppercase()), Some(d), "{name} upper");
            assert_eq!(Distribution::parse(&name.replace('-', "")), Some(d), "{name} no dash");
            assert_eq!(Distribution::parse(&name[..name.len() - 1]), None, "{name} prefix");
            assert_eq!(Distribution::parse(&format!("{name}x")), None, "{name} extended");
        }
    }
}
