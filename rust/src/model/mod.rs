//! The paper's model of computation (Appendix A): symmetric single-ported
//! message passing. Sending a message of `l` machine words costs
//! `α + l·β`; local work is measured in machine instructions (unit 1),
//! with `α ≫ β ≫ 1`.
//!
//! # Calibration of the default constants
//!
//! Defaults are calibrated to JUQUEEN (the paper's testbed): PowerPC A2 at
//! 1.6 GHz, 2.5 µs worst-case MPI latency (≈ 4000 cycles → [`CostModel::alpha`])
//! and an effective per-core bandwidth of ≈ 1 GB/s (≈ 13 cycles per 8-byte
//! word → [`CostModel::beta`]); one element-comparison in a merge/partition
//! loop is charged ≈ 2 instructions ([`CostModel::cmp`]). Absolute values
//! only scale the time axis; the *ratios* α/β and β/1 determine every
//! crossover in the paper's figures.
//!
//! # Which Table I row each algorithm's charged cost reproduces
//!
//! Table I of the paper states, per algorithm, the startup latencies
//! (number of α terms on the critical path) and the communication volume
//! (β-weighted words per PE). The simulator charges costs through
//! [`CostModel::msg`]/[`CostModel::xchg`] for every real message an
//! algorithm sends, so each row emerges from the implementation rather
//! than being hard-coded:
//!
//! | Table I row                      | latency (α·)        | volume (β·)       | charged by |
//! |----------------------------------|---------------------|-------------------|------------|
//! | Gather/merge (GatherM)           | `log p`             | `n`  (at root)    | [`crate::algorithms::gather_merge`] via the binomial tree in [`crate::sim`] |
//! | All-gather-merge (AllGatherM)    | `log p`             | `n` per PE        | [`crate::algorithms::all_gather_merge`] |
//! | Minisort                         | `log² p`            | `log² p`          | [`crate::algorithms::minisort`] (RQuick at m = 1) |
//! | FIS/RFIS (§V)                    | `O(log p)`          | `n/√p`            | [`crate::algorithms::rfis`] row/column gathers + rank all-reduce |
//! | Hypercube quicksort (RQuick, §VI)| `log² p`            | `(n/p)·log p`     | [`crate::algorithms::quick`]; `+ median of medians` adds the `β·p` pivot term ([`crate::algorithms::quick::Pivot::MedianOfMedians`]) |
//! | Bitonic                          | `log² p`            | `(n/p)·log² p`    | [`crate::algorithms::bitonic`] compare-split rounds |
//! | HykSort                          | `≥ k·log_k p` (comm-split Ω(β·q) per level) | `(n/p)·log_k p` | [`crate::algorithms::hyksort`] |
//! | Single-level sample sort (SSort) | `≥ p`               | `n/p`             | [`crate::algorithms::ssort`] direct all-to-all |
//! | Multiway mergesort (Mways)       | `≥ p`               | `≥ n/p`           | [`crate::algorithms::mergesort`] exact-splitter binary search (β·p·log K) |
//! | AMS-sort / RAMS (App. G)         | `l·(p^(1/l) + log p)` | `(n/p)·l`       | [`crate::algorithms::rams`] per-level sample, histogram, DMA exchange |
//!
//! Local-work terms use [`CostModel::sort_work`] (`cmp·m·log m` for the
//! node-local sort), [`CostModel::linear_work`] (`cmp·m` merges/splits),
//! and [`CostModel::classify_work`] (`cmp·m·log k` splitter-tree descents).

/// α-β cost model parameters.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Message startup overhead (machine instructions).
    pub alpha: f64,
    /// Per-word transfer time (machine instructions). One element = one word.
    pub beta: f64,
    /// Local work per element-comparison (merge step, partition step).
    pub cmp: f64,
    /// Full-duplex exchanges: a pairwise sendrecv of `l1`/`l2` words costs
    /// `α + β·max(l1,l2)` when `true` (telephone model), `α + β·(l1+l2)`
    /// when `false`. BlueGene/Q links are bidirectional → default `true`.
    pub duplex: bool,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            alpha: 4000.0,
            beta: 13.0,
            cmp: 2.0,
            duplex: true,
        }
    }
}

impl CostModel {
    /// Cost of one message of `l` words.
    #[inline]
    pub fn msg(&self, l: usize) -> f64 {
        self.alpha + self.beta * l as f64
    }

    /// Cost of a pairwise exchange sending `l_out` and receiving `l_in`.
    #[inline]
    pub fn xchg(&self, l_out: usize, l_in: usize) -> f64 {
        if self.duplex {
            self.alpha + self.beta * l_out.max(l_in) as f64
        } else {
            self.alpha + self.beta * (l_out + l_in) as f64
        }
    }

    /// Local sorting cost for `m` elements: `cmp · m·log2(m)`.
    #[inline]
    pub fn sort_work(&self, m: usize) -> f64 {
        if m <= 1 {
            return self.cmp;
        }
        self.cmp * m as f64 * (m as f64).log2()
    }

    /// Local merge/partition cost for `m` elements: `cmp · m`.
    #[inline]
    pub fn linear_work(&self, m: usize) -> f64 {
        self.cmp * m as f64
    }

    /// Cost of a `log k`-deep branchless classifier pass over `m` elements.
    #[inline]
    pub fn classify_work(&self, m: usize, k: usize) -> f64 {
        self.cmp * m as f64 * (k.max(2) as f64).log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_respect_alpha_gg_beta_gg_one() {
        let c = CostModel::default();
        assert!(c.alpha > 10.0 * c.beta);
        assert!(c.beta > 1.0);
    }

    #[test]
    fn msg_cost_is_affine() {
        let c = CostModel::default();
        assert_eq!(c.msg(0), c.alpha);
        assert_eq!(c.msg(10) - c.msg(0), 10.0 * c.beta);
    }

    #[test]
    fn duplex_exchange_takes_max() {
        let c = CostModel { duplex: true, ..Default::default() };
        assert_eq!(c.xchg(10, 4), c.alpha + 10.0 * c.beta);
        let h = CostModel { duplex: false, ..Default::default() };
        assert_eq!(h.xchg(10, 4), h.alpha + 14.0 * h.beta);
    }

    #[test]
    fn sort_work_monotone() {
        let c = CostModel::default();
        assert!(c.sort_work(0) <= c.sort_work(2));
        assert!(c.sort_work(100) < c.sort_work(1000));
    }
}
