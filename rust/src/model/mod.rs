//! The paper's model of computation (Appendix A): symmetric single-ported
//! message passing. Sending a message of `l` machine words costs
//! `α + l·β`; local work is measured in machine instructions (unit 1),
//! with `α ≫ β ≫ 1`.
//!
//! Default constants are calibrated to JUQUEEN (the paper's testbed):
//! PowerPC A2 at 1.6 GHz, 2.5 µs worst-case MPI latency (≈ 4000 cycles)
//! and an effective per-core bandwidth of ≈ 1 GB/s (≈ 13 cycles per 8-byte
//! word). Absolute values only scale the time axis; the *ratios* α/β and
//! β/1 determine every crossover in the paper's figures.

/// α-β cost model parameters.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Message startup overhead (machine instructions).
    pub alpha: f64,
    /// Per-word transfer time (machine instructions). One element = one word.
    pub beta: f64,
    /// Local work per element-comparison (merge step, partition step).
    pub cmp: f64,
    /// Full-duplex exchanges: a pairwise sendrecv of `l1`/`l2` words costs
    /// `α + β·max(l1,l2)` when `true` (telephone model), `α + β·(l1+l2)`
    /// when `false`. BlueGene/Q links are bidirectional → default `true`.
    pub duplex: bool,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            alpha: 4000.0,
            beta: 13.0,
            cmp: 2.0,
            duplex: true,
        }
    }
}

impl CostModel {
    /// Cost of one message of `l` words.
    #[inline]
    pub fn msg(&self, l: usize) -> f64 {
        self.alpha + self.beta * l as f64
    }

    /// Cost of a pairwise exchange sending `l_out` and receiving `l_in`.
    #[inline]
    pub fn xchg(&self, l_out: usize, l_in: usize) -> f64 {
        if self.duplex {
            self.alpha + self.beta * l_out.max(l_in) as f64
        } else {
            self.alpha + self.beta * (l_out + l_in) as f64
        }
    }

    /// Local sorting cost for `m` elements: `cmp · m·log2(m)`.
    #[inline]
    pub fn sort_work(&self, m: usize) -> f64 {
        if m <= 1 {
            return self.cmp;
        }
        self.cmp * m as f64 * (m as f64).log2()
    }

    /// Local merge/partition cost for `m` elements: `cmp · m`.
    #[inline]
    pub fn linear_work(&self, m: usize) -> f64 {
        self.cmp * m as f64
    }

    /// Cost of a `log k`-deep branchless classifier pass over `m` elements.
    #[inline]
    pub fn classify_work(&self, m: usize, k: usize) -> f64 {
        self.cmp * m as f64 * (k.max(2) as f64).log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_respect_alpha_gg_beta_gg_one() {
        let c = CostModel::default();
        assert!(c.alpha > 10.0 * c.beta);
        assert!(c.beta > 1.0);
    }

    #[test]
    fn msg_cost_is_affine() {
        let c = CostModel::default();
        assert_eq!(c.msg(0), c.alpha);
        assert_eq!(c.msg(10) - c.msg(0), 10.0 * c.beta);
    }

    #[test]
    fn duplex_exchange_takes_max() {
        let c = CostModel { duplex: true, ..Default::default() };
        assert_eq!(c.xchg(10, 4), c.alpha + 10.0 * c.beta);
        let h = CostModel { duplex: false, ..Default::default() };
        assert_eq!(h.xchg(10, 4), h.alpha + 14.0 * h.beta);
    }

    #[test]
    fn sort_work_monotone() {
        let c = CostModel::default();
        assert!(c.sort_work(0) <= c.sort_work(2));
        assert!(c.sort_work(100) < c.sort_work(1000));
    }
}
