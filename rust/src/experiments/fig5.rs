//! Figure 5 / Appendix K: running-time *ratios* of every algorithm to the
//! fastest algorithm per (instance, n/p) — the paper's normalized view of
//! Fig. 1.

use crate::config::RunConfig;
use crate::experiments::fig1::{self, Fig1};
use crate::input::Distribution;

pub struct Fig5 {
    pub fig1: Fig1,
}

pub fn run(base: &RunConfig, max_log: u32, reps: usize, jobs: usize) -> Fig5 {
    Fig5 { fig1: fig1::run(base, max_log, reps, jobs) }
}

impl Fig5 {
    /// ratio of the named algorithm to the per-point winner (∞ for crashes).
    pub fn ratio(&self, dist: Distribution, pt: crate::experiments::NpPoint, alg: &str) -> f64 {
        let best = self.fig1.winner(dist, pt);
        let b = self.fig1.cell(dist, pt, best).time;
        let c = self.fig1.cell(dist, pt, alg);
        if c.crashed {
            f64::INFINITY
        } else {
            c.time / b
        }
    }

    pub fn print(&self) {
        for &dist in &self.fig1.distributions {
            println!("\n== Fig.5 [{}] — ratio to fastest ==", dist.name());
            print!("{:>8}", "n/p");
            for a in &self.fig1.algorithms {
                print!("{:>12}", a.name());
            }
            println!();
            for &pt in &self.fig1.points {
                print!("{:>8}", pt.label());
                for a in &self.fig1.algorithms {
                    let r = self.ratio(dist, pt, a.name());
                    if r.is_finite() {
                        print!("{r:>12.2}");
                    } else {
                        print!("{:>12}", "CRASH");
                    }
                }
                println!();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::NpPoint;

    #[test]
    fn winner_has_ratio_one() {
        let base = RunConfig { p: 1 << 5, ..Default::default() };
        let fig = run(&base, 3, 1, 2);
        for &d in &[Distribution::Uniform] {
            for &pt in &[NpPoint::Dense(1), NpPoint::Dense(8)] {
                let w = fig.fig1.winner(d, pt);
                assert!((fig.ratio(d, pt, w) - 1.0).abs() < 1e-12);
            }
        }
    }
}
