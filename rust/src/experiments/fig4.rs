//! Figure 4 / Appendix H: median-approximation quality of the binary
//! k-window tree (§III-B) vs Dean et al.'s ternary tree — max rank error
//! and rank-error variance over repeated runs, with the c·n^−γ fit.

use crate::median::{sequential_binary_estimate, sequential_ternary_estimate};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct MedianErrorPoint {
    pub n: usize,
    pub max_err: f64,
    pub var: f64,
}

/// Rank error |r/(n−1) − 1/2| statistics over `reps` random permutations.
fn error_stats(
    n: usize,
    reps: usize,
    seed: u64,
    estimate: impl Fn(&[u64], &mut Rng) -> Option<u64>,
) -> MedianErrorPoint {
    let mut rng = Rng::seeded(seed, n as u64);
    let mut vals: Vec<u64> = (0..n as u64).collect();
    let mut errs = Vec::with_capacity(reps);
    for _ in 0..reps {
        rng.shuffle(&mut vals);
        let est = estimate(&vals, &mut rng).expect("non-empty");
        let err = (est as f64 / (n - 1) as f64 - 0.5).abs();
        errs.push(err);
    }
    let max_err = errs.iter().copied().fold(0.0, f64::max);
    let mean = errs.iter().sum::<f64>() / errs.len() as f64;
    let var = errs.iter().map(|e| (e - mean) * (e - mean)).sum::<f64>() / errs.len() as f64;
    MedianErrorPoint { n, max_err, var }
}

pub struct Fig4 {
    pub binary: Vec<MedianErrorPoint>,
    pub ternary: Vec<MedianErrorPoint>,
    /// fitted (c, γ) for max_err ≈ c·n^−γ
    pub binary_fit: (f64, f64),
    pub ternary_fit: (f64, f64),
}

/// Least-squares fit of log(err) = log c − γ·log n.
pub fn fit_power_law(points: &[MedianErrorPoint]) -> (f64, f64) {
    let xs: Vec<f64> = points.iter().map(|p| (p.n as f64).ln()).collect();
    let ys: Vec<f64> = points.iter().map(|p| p.max_err.max(1e-12).ln()).collect();
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(&ys).map(|(x, y)| x * y).sum();
    let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    let intercept = (sy - slope * sx) / n;
    (intercept.exp(), -slope)
}

/// Binary tree over powers of two, ternary over powers of three (the
/// paper's Fig. 4 setup: inputs up to 2^20, 2000 reps — scale down via
/// `max_pow` / `reps` for CI). Every (tree, n) grid point runs as one job
/// on the worker pool; each is seeded independently, so any `jobs` count
/// yields identical statistics.
pub fn run(max_pow2: u32, reps: usize, seed: u64, jobs: usize) -> Fig4 {
    #[derive(Clone, Copy)]
    enum Tree {
        Bin(u32),
        Ter(u32),
    }
    let max_pow3 = ((max_pow2 as f64) * 2f64.ln() / 3f64.ln()).floor() as u32;
    let mut specs: Vec<Tree> = (4..=max_pow2).map(Tree::Bin).collect();
    let n_bin = specs.len();
    specs.extend((3..=max_pow3).map(Tree::Ter));
    let mut pts = crate::exec::parallel_map(jobs, specs.len(), |i| match specs[i] {
        Tree::Bin(l) => {
            error_stats(1 << l, reps, seed, |v, r| sequential_binary_estimate(v, 2, r))
        }
        Tree::Ter(l) => {
            error_stats(3usize.pow(l), reps, seed, |v, r| sequential_ternary_estimate(v, r))
        }
    });
    let ternary: Vec<MedianErrorPoint> = pts.split_off(n_bin);
    let binary = pts;
    let binary_fit = fit_power_law(&binary);
    let ternary_fit = fit_power_law(&ternary);
    Fig4 { binary, ternary, binary_fit, ternary_fit }
}

impl Fig4 {
    pub fn print(&self) {
        println!("\n== Fig.4 — median approximation quality ==");
        println!("{:>10} {:>12} {:>12}", "n", "max_err", "variance");
        println!("-- binary k-window tree (§III-B) --");
        for p in &self.binary {
            println!("{:>10} {:>12.5} {:>12.3e}", p.n, p.max_err, p.var);
        }
        println!("-- ternary tree (Dean et al.) --");
        for p in &self.ternary {
            println!("{:>10} {:>12.5} {:>12.3e}", p.n, p.max_err, p.var);
        }
        println!(
            "fit: binary max_err ≈ {:.2}·n^-{:.3}   (paper: 1.44·n^-0.39)",
            self.binary_fit.0, self.binary_fit.1
        );
        println!(
            "fit: ternary max_err ≈ {:.2}·n^-{:.3}  (paper: 2·n^-0.37)",
            self.ternary_fit.0, self.ternary_fit.1
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_beats_ternary_and_errors_decay() {
        let fig = run(12, 60, 42, crate::exec::available_jobs());
        // errors decay with n
        let firstb = fig.binary.first().unwrap().max_err;
        let lastb = fig.binary.last().unwrap().max_err;
        assert!(lastb < firstb, "binary error must decay: {firstb} → {lastb}");
        // fitted exponents land near the paper's (γ ≈ 0.37..0.39)
        assert!(
            fig.binary_fit.1 > 0.2 && fig.binary_fit.1 < 0.6,
            "binary γ {}",
            fig.binary_fit.1
        );
        assert!(
            fig.ternary_fit.1 > 0.2 && fig.ternary_fit.1 < 0.6,
            "ternary γ {}",
            fig.ternary_fit.1
        );
    }

    #[test]
    fn power_law_fit_recovers_known_curve() {
        let pts: Vec<MedianErrorPoint> = (4..12)
            .map(|l| {
                let n = 1usize << l;
                MedianErrorPoint { n, max_err: 1.5 * (n as f64).powf(-0.4), var: 0.0 }
            })
            .collect();
        let (c, g) = fit_power_law(&pts);
        assert!((c - 1.5).abs() < 0.05, "c = {c}");
        assert!((g - 0.4).abs() < 0.01, "γ = {g}");
    }
}
