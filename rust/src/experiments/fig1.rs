//! Figure 1: running times of each algorithm over the n/p sweep, per input
//! instance (Uniform, Staggered, BucketSorted, DeterDupl) — the paper's
//! central comparison on 262 144 cores, here on a configurable simulated
//! machine.

use std::sync::Arc;

use crate::algorithms::{Algorithm, Sorter};
use crate::config::RunConfig;
use crate::experiments::{np_sweep, run_cells, CellResult, NpPoint};
use crate::input::Distribution;

/// The sweep result. `cells` is laid out as a dense
/// distribution-major/point/algorithm grid, so [`Fig1::cell`] is an index
/// computation, not a scan.
pub struct Fig1 {
    pub points: Vec<NpPoint>,
    pub algorithms: Vec<Arc<dyn Sorter>>,
    pub distributions: Vec<Distribution>,
    pub cells: Vec<CellResult>,
}

/// Regenerate Figure 1 over the paper's eight algorithms on `jobs` worker
/// threads (`1` = fully serial; the result is byte-identical for every job
/// count).
pub fn run(base: &RunConfig, max_log: u32, reps: usize, jobs: usize) -> Fig1 {
    run_with(
        base,
        Algorithm::FIG1.iter().map(|a| a.sorter()).collect(),
        max_log,
        reps,
        jobs,
    )
}

/// Figure 1 extended with the successor paper's multi-level AMS family:
/// the eight FIG1 algorithms plus `AMS-1`/`AMS-2`/`AMS-3`, so the sweep
/// reports where the 1-factor AMS beats RAMS/HykSort on the simulated
/// cost model. Kept separate from [`run`] — the paper's figure is the
/// eight-algorithm set, and its winner structure is pinned by tests.
pub fn run_ams(base: &RunConfig, max_log: u32, reps: usize, jobs: usize) -> Fig1 {
    let mut algorithms: Vec<Arc<dyn Sorter>> =
        Algorithm::FIG1.iter().map(|a| a.sorter()).collect();
    algorithms.extend(
        crate::algorithms::builtin_sorters()
            .into_iter()
            .filter(|s| s.name().starts_with("AMS-")),
    );
    run_with(base, algorithms, max_log, reps, jobs)
}

/// The same sweep over an arbitrary sorter set — e.g. (a subset of) the
/// [`crate::algorithms::registry`], which includes externally registered
/// sorters.
///
/// Cells are keyed by sorter name, so names must be unique within the set
/// (asserted — two config variants of one algorithm would otherwise
/// silently address each other's cells).
pub fn run_with(
    base: &RunConfig,
    algorithms: Vec<Arc<dyn Sorter>>,
    max_log: u32,
    reps: usize,
    jobs: usize,
) -> Fig1 {
    let mut names: Vec<String> = algorithms
        .iter()
        .map(|s| crate::algorithms::normalize(s.name()))
        .collect();
    names.sort_unstable();
    names.dedup();
    assert_eq!(
        names.len(),
        algorithms.len(),
        "fig1 sweep requires unique sorter names (cells are name-keyed)"
    );
    let points = np_sweep(max_log);
    let distributions: Vec<Distribution> = Distribution::FIG1.to_vec();
    let mut specs = Vec::with_capacity(distributions.len() * points.len() * algorithms.len());
    for &dist in &distributions {
        for &point in &points {
            for alg in &algorithms {
                specs.push((alg.clone(), dist, point));
            }
        }
    }
    let cells = run_cells(jobs, base, &specs, reps);
    Fig1 { points, algorithms, distributions, cells }
}

/// The paper's headline machine sizes for the giant-p sweep: the JUQUEEN
/// runs top out at 2^18 = 262 144 cores (§I), which the simulator reaches
/// because supersteps cost O(active PEs + messages) host work, not O(p)
/// (see the touched-slot contract on [`crate::sim::Machine`]).
pub const GIANT_P_LADDER: [usize; 3] = [1 << 14, 1 << 16, 1 << 18];

/// The sorters the giant-p sweep compares: the gather-style winners of
/// the sparse regime (GatherM, RFIS — Fig. 1's left edge) plus the robust
/// selector that must match them there.
pub fn giant_p_sorters() -> Vec<Arc<dyn Sorter>> {
    [Algorithm::GatherM, Algorithm::Rfis, Algorithm::Robust]
        .iter()
        .map(|a| a.sorter())
        .collect()
}

/// The giant-p n/p axis: the sparse ladder 3^-5..3^-1 plus the
/// one-element-per-PE point. No dense tail — at 2^18 PEs even n/p = 1 is
/// already 262 144 elements, and the sparse end is where giant machines
/// differ from small ones.
pub fn giant_p_points() -> Vec<NpPoint> {
    let mut pts: Vec<NpPoint> =
        (1..=5u32).rev().map(|k| NpPoint::Sparse(3usize.pow(k))).collect();
    pts.push(NpPoint::Dense(1));
    pts
}

/// The giant-p sweep result: `cells` is a dense p-major/point/algorithm
/// grid over the Uniform instance (one distribution keeps the 2^18 column
/// affordable; sparse occupancy, not value skew, is what giant-p probes).
pub struct GiantP {
    pub ladder: Vec<usize>,
    pub points: Vec<NpPoint>,
    pub algorithms: Vec<Arc<dyn Sorter>>,
    pub cells: Vec<CellResult>,
}

/// Run the giant-p sweep: every machine size in `ladder` × every point in
/// `points` × [`giant_p_sorters`]-style `algorithms`, Uniform inputs,
/// `reps` seeds per cell on `jobs` workers (byte-identical for every job
/// count, like [`run_with`]).
pub fn run_giant_p(
    base: &RunConfig,
    ladder: &[usize],
    points: &[NpPoint],
    algorithms: Vec<Arc<dyn Sorter>>,
    reps: usize,
    jobs: usize,
) -> GiantP {
    let mut names: Vec<String> = algorithms
        .iter()
        .map(|s| crate::algorithms::normalize(s.name()))
        .collect();
    names.sort_unstable();
    names.dedup();
    assert_eq!(
        names.len(),
        algorithms.len(),
        "giant-p sweep requires unique sorter names (cells are name-keyed)"
    );
    let mut cells = Vec::with_capacity(ladder.len() * points.len() * algorithms.len());
    for &p in ladder {
        let mut specs = Vec::with_capacity(points.len() * algorithms.len());
        for &point in points {
            for alg in &algorithms {
                specs.push((alg.clone(), Distribution::Uniform, point));
            }
        }
        cells.extend(run_cells(jobs, &base.clone().with_p(p), &specs, reps));
    }
    GiantP {
        ladder: ladder.to_vec(),
        points: points.to_vec(),
        algorithms,
        cells,
    }
}

impl GiantP {
    fn index_of(&self, p: usize, point: NpPoint, algorithm: &str) -> usize {
        let pi = self.ladder.iter().position(|&x| x == p).expect("p in ladder");
        let pt = self.points.iter().position(|&x| x == point).expect("point in sweep");
        let a = self
            .algorithms
            .iter()
            .position(|s| s.name() == algorithm)
            .expect("algorithm in sweep");
        (pi * self.points.len() + pt) * self.algorithms.len() + a
    }

    pub fn cell(&self, p: usize, point: NpPoint, algorithm: &str) -> &CellResult {
        let c = &self.cells[self.index_of(p, point, algorithm)];
        debug_assert!(
            c.point == point && c.algorithm == algorithm,
            "cell grid out of order"
        );
        c
    }

    /// All cells of one machine size, in point/algorithm order.
    pub fn cells_at(&self, p: usize) -> &[CellResult] {
        let pi = self.ladder.iter().position(|&x| x == p).expect("p in ladder");
        let stride = self.points.len() * self.algorithms.len();
        &self.cells[pi * stride..(pi + 1) * stride]
    }

    /// Σ host wallclock / Σ settled supersteps over every cell of one
    /// machine size — the series the giant-p bench records; sublinear
    /// growth in `p` is the O(active + messages) acceptance criterion.
    pub fn host_us_per_round(&self, p: usize) -> f64 {
        let cells = self.cells_at(p);
        let wall_ms: f64 = cells.iter().map(|c| c.host_wall_ms).sum();
        let rounds: u64 = cells.iter().map(|c| c.host_rounds).sum();
        wall_ms * 1e3 / rounds as f64
    }

    /// Print the sweep as one table per machine size.
    pub fn print(&self) {
        for &p in &self.ladder {
            println!("\n== Fig.1 giant-p [Uniform, p=2^{}] — simulated time per n/p ==",
                (p as f64).log2().round() as u32);
            print!("{:>8}", "n/p");
            for a in &self.algorithms {
                print!("{:>12}", a.name());
            }
            println!();
            for &pt in &self.points {
                print!("{:>8}", pt.label());
                for a in &self.algorithms {
                    print!("{:>12}", self.cell(p, pt, a.name()).display_time());
                }
                println!();
            }
            let rounds: u64 = self.cells_at(p).iter().map(|c| c.host_rounds).sum();
            println!(
                "   host: {rounds} supersteps settled, {:.2} µs/superstep",
                self.host_us_per_round(p)
            );
        }
    }
}

impl Fig1 {
    /// Dense grid index of `(dist, point, algorithm-name)`; panics (like
    /// the old linear scan) if the coordinate is not part of the sweep.
    fn index_of(&self, dist: Distribution, point: NpPoint, algorithm: &str) -> usize {
        let d = self
            .distributions
            .iter()
            .position(|&x| x == dist)
            .expect("distribution in sweep");
        let pt = self.points.iter().position(|&x| x == point).expect("point in sweep");
        let a = self
            .algorithms
            .iter()
            .position(|s| s.name() == algorithm)
            .expect("algorithm in sweep");
        (d * self.points.len() + pt) * self.algorithms.len() + a
    }

    pub fn cell(&self, dist: Distribution, point: NpPoint, algorithm: &str) -> &CellResult {
        let c = &self.cells[self.index_of(dist, point, algorithm)];
        debug_assert!(
            c.distribution == dist && c.point == point && c.algorithm == algorithm,
            "cell grid out of order"
        );
        c
    }

    /// Fastest algorithm at a point (ignoring crashes), by registry name.
    pub fn winner(&self, dist: Distribution, point: NpPoint) -> &'static str {
        self.algorithms
            .iter()
            .map(|s| s.name())
            .filter(|&a| !self.cell(dist, point, a).crashed)
            .min_by(|&a, &b| {
                self.cell(dist, point, a)
                    .time
                    .total_cmp(&self.cell(dist, point, b).time)
            })
            .expect("at least one algorithm survives")
    }

    /// Print the figure as a table (one block per distribution).
    pub fn print(&self) {
        for &dist in &self.distributions {
            println!("\n== Fig.1 [{}] — simulated time per n/p ==", dist.name());
            print!("{:>8}", "n/p");
            for a in &self.algorithms {
                print!("{:>12}", a.name());
            }
            println!("  winner");
            for &pt in &self.points {
                print!("{:>8}", pt.label());
                for a in &self.algorithms {
                    print!("{:>12}", self.cell(dist, pt, a.name()).display_time());
                }
                println!("  {}", self.winner(dist, pt));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's headline shape on a small machine: GatherM/RFIS win the
    /// sparse end, hypercube algorithms the small-dense middle (Fig. 1
    /// discussion §VII-A).
    #[test]
    fn fig1_shape_holds_on_small_machine() {
        let base = RunConfig { p: 1 << 6, ..Default::default() };
        let fig = run(&base, 4, 1, crate::exec::available_jobs());
        // every cell either crashed (allowed for nonrobust algos on hard
        // instances) or produced a correct result
        for c in &fig.cells {
            assert!(c.crashed || c.ok, "{} {:?} {:?}", c.algorithm, c.distribution, c.point);
        }
        // sparse end: gather-style algorithms win
        let sparse_winner = fig.winner(Distribution::Uniform, NpPoint::Sparse(243));
        assert!(
            ["GatherM", "RFIS"].contains(&sparse_winner),
            "sparse winner {sparse_winner:?}"
        );
        // the one-element-per-PE point goes to RFIS (paper: >2× faster)
        let tiny_winner = fig.winner(Distribution::Uniform, NpPoint::Dense(1));
        assert!(
            ["RFIS", "GatherM"].contains(&tiny_winner),
            "tiny winner {tiny_winner:?}"
        );
    }

    /// The AMS-extended sweep carries a cell per AMS level count, every
    /// cell is correct-or-crashed, and the grid is byte-identical for
    /// every worker count (the determinism contract of [`run_with`]).
    #[test]
    fn ams_extended_sweep_is_correct_and_job_invariant() {
        let base = RunConfig { p: 1 << 4, ..Default::default() };
        let serial = run_ams(&base, 2, 1, 1);
        assert_eq!(serial.algorithms.len(), Algorithm::FIG1.len() + 3);
        for c in &serial.cells {
            assert!(c.crashed || c.ok, "{} {:?} {:?}", c.algorithm, c.distribution, c.point);
        }
        for k in 1..=3 {
            let name = format!("AMS-{k}");
            let c = serial.cell(Distribution::Uniform, NpPoint::Dense(4), &name);
            assert!(!c.crashed && c.ok, "{name}: {c:?}");
        }
        let parallel = run_ams(&base, 2, 1, 3);
        for (a, b) in serial.cells.iter().zip(&parallel.cells) {
            assert_eq!(a.algorithm, b.algorithm);
            assert_eq!(a.time.to_bits(), b.time.to_bits(), "{} {:?} {:?}", a.algorithm, a.distribution, a.point);
            assert_eq!((a.crashed, a.ok), (b.crashed, b.ok), "{}", a.algorithm);
        }
    }

    /// The giant-p grid on a small ladder: every cell correct-or-crashed
    /// with supersteps counted, the O(1) lookup addresses the right cell,
    /// and the grid is byte-identical across worker counts.
    #[test]
    fn giant_p_sweep_holds_on_small_ladder() {
        let base = RunConfig::default();
        let ladder = [1 << 4, 1 << 6];
        let points = giant_p_points();
        let fig = run_giant_p(&base, &ladder, &points, giant_p_sorters(), 1, 3);
        assert_eq!(fig.cells.len(), ladder.len() * points.len() * 3);
        for c in &fig.cells {
            assert!(c.crashed || c.ok, "{} {:?}", c.algorithm, c.point);
            assert!(c.host_rounds > 0, "{} {:?} settled no superstep", c.algorithm, c.point);
            assert!(c.host_wall_ms >= 0.0);
        }
        let c = fig.cell(1 << 6, NpPoint::Dense(1), "RFIS");
        assert!(c.algorithm == "RFIS" && c.point == NpPoint::Dense(1));
        let serial = run_giant_p(&base, &ladder, &points, giant_p_sorters(), 1, 1);
        for (a, b) in serial.cells.iter().zip(&fig.cells) {
            assert_eq!(a.algorithm, b.algorithm);
            assert_eq!(a.time.to_bits(), b.time.to_bits(), "{} {:?}", a.algorithm, a.point);
            assert_eq!((a.crashed, a.ok), (b.crashed, b.ok), "{}", a.algorithm);
        }
    }

    /// The O(1) grid lookup agrees with a full scan on every coordinate.
    #[test]
    fn indexed_cell_lookup_matches_scan() {
        let base = RunConfig { p: 1 << 4, ..Default::default() };
        let fig = run(&base, 2, 1, 2);
        for &dist in &fig.distributions {
            for &pt in &fig.points {
                for alg in &fig.algorithms {
                    let indexed = fig.cell(dist, pt, alg.name());
                    let scanned = fig
                        .cells
                        .iter()
                        .find(|c| {
                            c.distribution == dist && c.point == pt && c.algorithm == alg.name()
                        })
                        .expect("cell exists");
                    assert!(std::ptr::eq(indexed, scanned));
                }
            }
        }
    }
}
