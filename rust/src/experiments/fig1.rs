//! Figure 1: running times of each algorithm over the n/p sweep, per input
//! instance (Uniform, Staggered, BucketSorted, DeterDupl) — the paper's
//! central comparison on 262 144 cores, here on a configurable simulated
//! machine.

use crate::algorithms::Algorithm;
use crate::config::RunConfig;
use crate::experiments::{np_sweep, run_cell, CellResult, NpPoint};
use crate::input::Distribution;

/// The sweep result: `rows[dist][point][alg]`.
pub struct Fig1 {
    pub points: Vec<NpPoint>,
    pub algorithms: Vec<Algorithm>,
    pub distributions: Vec<Distribution>,
    pub cells: Vec<CellResult>,
}

pub fn run(base: &RunConfig, max_log: u32, reps: usize) -> Fig1 {
    let points = np_sweep(max_log);
    let algorithms: Vec<Algorithm> = Algorithm::FIG1.to_vec();
    let distributions: Vec<Distribution> = Distribution::FIG1.to_vec();
    let mut cells = Vec::new();
    for &dist in &distributions {
        for &point in &points {
            for &alg in &algorithms {
                cells.push(run_cell(alg, dist, base, point, reps));
            }
        }
    }
    Fig1 { points, algorithms, distributions, cells }
}

impl Fig1 {
    pub fn cell(&self, dist: Distribution, point: NpPoint, alg: Algorithm) -> &CellResult {
        self.cells
            .iter()
            .find(|c| c.distribution == dist && c.point == point && c.algorithm == alg)
            .expect("cell exists")
    }

    /// Fastest algorithm at a point (ignoring crashes).
    pub fn winner(&self, dist: Distribution, point: NpPoint) -> Algorithm {
        self.algorithms
            .iter()
            .copied()
            .filter(|&a| !self.cell(dist, point, a).crashed)
            .min_by(|&a, &b| {
                self.cell(dist, point, a)
                    .time
                    .total_cmp(&self.cell(dist, point, b).time)
            })
            .expect("at least one algorithm survives")
    }

    /// Print the figure as a table (one block per distribution).
    pub fn print(&self) {
        for &dist in &self.distributions {
            println!("\n== Fig.1 [{}] — simulated time per n/p ==", dist.name());
            print!("{:>8}", "n/p");
            for a in &self.algorithms {
                print!("{:>12}", a.name());
            }
            println!("  winner");
            for &pt in &self.points {
                print!("{:>8}", pt.label());
                for &a in &self.algorithms {
                    print!("{:>12}", self.cell(dist, pt, a).display_time());
                }
                println!("  {}", self.winner(dist, pt).name());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's headline shape on a small machine: GatherM/RFIS win the
    /// sparse end, hypercube algorithms the small-dense middle (Fig. 1
    /// discussion §VII-A).
    #[test]
    fn fig1_shape_holds_on_small_machine() {
        let base = RunConfig { p: 1 << 6, ..Default::default() };
        let fig = run(&base, 4, 1);
        // every cell either crashed (allowed for nonrobust algos on hard
        // instances) or produced a correct result
        for c in &fig.cells {
            assert!(c.crashed || c.ok, "{:?} {:?} {:?}", c.algorithm, c.distribution, c.point);
        }
        // sparse end: gather-style algorithms win
        let sparse_winner = fig.winner(Distribution::Uniform, NpPoint::Sparse(243));
        assert!(
            matches!(sparse_winner, Algorithm::GatherM | Algorithm::Rfis),
            "sparse winner {sparse_winner:?}"
        );
        // the one-element-per-PE point goes to RFIS (paper: >2× faster)
        let tiny_winner = fig.winner(Distribution::Uniform, NpPoint::Dense(1));
        assert!(
            matches!(tiny_winner, Algorithm::Rfis | Algorithm::GatherM),
            "tiny winner {tiny_winner:?}"
        );
    }
}
