//! Figure 2: robust-vs-nonrobust running-time ratios.
//!
//! 2a) RQuick / NTB-Quick — the price (Uniform) and payoff (Staggered,
//!     Mirrored, BucketSorted, DeterDupl) of shuffle + tie-breaking.
//! 2b) same comparison on a smaller machine (tie-breaking focus).
//! 2c) RAMS / NDMA-AMS — deterministic message assignment on AllToOne.
//! 2d) RAMS / NS-SSort — multi-level vs the single-delivery lower bound.

use crate::algorithms::Algorithm;
use crate::config::RunConfig;
use crate::experiments::{run_cells, NpPoint};
use crate::input::Distribution;

/// One ratio series: time(robust)/time(nonrobust) per n/p point.
/// `f64::INFINITY` in the denominator run (nonrobust crash) maps to 0.0 —
/// the paper plots these as "orders of magnitude" wins.
pub struct RatioSeries {
    pub distribution: Distribution,
    pub points: Vec<NpPoint>,
    /// (ratio, robust_crashed, nonrobust_crashed)
    pub ratios: Vec<(f64, bool, bool)>,
}

/// Fan the whole (distribution × point × {robust, nonrobust}) grid of a
/// Fig. 2 panel out over the worker pool, then assemble the ratio series
/// in deterministic grid order.
fn ratio_figure(
    robust: Algorithm,
    nonrobust: Algorithm,
    dists: &[Distribution],
    base: &RunConfig,
    points: &[NpPoint],
    reps: usize,
    jobs: usize,
) -> Vec<RatioSeries> {
    let (robust, nonrobust) = (robust.sorter(), nonrobust.sorter());
    let mut specs = Vec::with_capacity(dists.len() * points.len() * 2);
    for &d in dists {
        for &pt in points {
            specs.push((robust.clone(), d, pt));
            specs.push((nonrobust.clone(), d, pt));
        }
    }
    let mut cells = run_cells(jobs, base, &specs, reps).into_iter();
    dists
        .iter()
        .map(|&d| {
            let ratios = points
                .iter()
                .map(|&pt| {
                    let r = cells.next().expect("robust cell");
                    let n = cells.next().expect("nonrobust cell");
                    debug_assert!(
                        r.algorithm == robust.name() && r.distribution == d && r.point == pt,
                        "ratio grid out of order"
                    );
                    debug_assert!(
                        n.algorithm == nonrobust.name() && n.distribution == d && n.point == pt,
                        "ratio grid out of order"
                    );
                    let ratio = if n.crashed {
                        0.0 // nonrobust failed: robust wins "infinitely"
                    } else if r.crashed {
                        f64::INFINITY
                    } else {
                        r.time / n.time
                    };
                    (ratio, r.crashed, n.crashed)
                })
                .collect();
            RatioSeries { distribution: d, points: points.to_vec(), ratios }
        })
        .collect()
}

pub fn ratio_series(
    robust: Algorithm,
    nonrobust: Algorithm,
    dist: Distribution,
    base: &RunConfig,
    points: &[NpPoint],
    reps: usize,
    jobs: usize,
) -> RatioSeries {
    ratio_figure(robust, nonrobust, &[dist], base, points, reps, jobs)
        .pop()
        .expect("one series")
}

/// The instances of Fig. 2a/2b.
pub const QUICK_INSTANCES: [Distribution; 5] = [
    Distribution::Uniform,
    Distribution::Staggered,
    Distribution::Mirrored,
    Distribution::BucketSorted,
    Distribution::DeterDupl,
];

/// The instances of Fig. 2c.
pub const AMS_INSTANCES: [Distribution; 5] = [
    Distribution::Uniform,
    Distribution::AllToOne,
    Distribution::Staggered,
    Distribution::BucketSorted,
    Distribution::DeterDupl,
];

pub fn fig2a(base: &RunConfig, points: &[NpPoint], reps: usize, jobs: usize) -> Vec<RatioSeries> {
    ratio_figure(Algorithm::RQuick, Algorithm::NtbQuick, &QUICK_INSTANCES, base, points, reps, jobs)
}

pub fn fig2c(base: &RunConfig, points: &[NpPoint], reps: usize, jobs: usize) -> Vec<RatioSeries> {
    ratio_figure(Algorithm::Rams, Algorithm::NdmaAms, &AMS_INSTANCES, base, points, reps, jobs)
}

pub fn fig2d(base: &RunConfig, points: &[NpPoint], reps: usize, jobs: usize) -> Vec<RatioSeries> {
    vec![ratio_series(
        Algorithm::Rams,
        Algorithm::NsSSort,
        Distribution::Uniform,
        base,
        points,
        reps,
        jobs,
    )]
}

pub fn print_series(title: &str, series: &[RatioSeries]) {
    println!("\n== {title} — ratio robust/nonrobust (0 = nonrobust crashed) ==");
    if series.is_empty() {
        return;
    }
    print!("{:>14}", "instance");
    for pt in &series[0].points {
        print!("{:>10}", pt.label());
    }
    println!();
    for s in series {
        print!("{:>14}", s.distribution.name());
        for &(ratio, rc, nc) in &s.ratios {
            let cell = if nc {
                "NTB✗".to_string()
            } else if rc {
                "R✗".to_string()
            } else {
                format!("{ratio:.2}")
            };
            print!("{cell:>10}");
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2a_uniform_price_is_bounded_and_hard_instances_pay_off() {
        let base = RunConfig { p: 1 << 6, ..Default::default() };
        let points = [NpPoint::Dense(64), NpPoint::Dense(256)];
        let series = fig2a(&base, &points, 1, crate::exec::available_jobs());
        let uni = &series[0];
        for &(ratio, rc, _) in &uni.ratios {
            assert!(!rc);
            // price of robustness on Uniform: bounded (paper: ≤ ~1.7)
            assert!(ratio < 2.5, "uniform ratio {ratio}");
        }
        // DeterDupl: NTB-Quick crashes or is much slower → ratio ≤ 1ish/0
        let dd = series.iter().find(|s| s.distribution == Distribution::DeterDupl).unwrap();
        for &(ratio, rc, _) in &dd.ratios {
            assert!(!rc, "RQuick must survive DeterDupl");
            assert!(ratio < 1.0 + 1e-9 || ratio == 0.0, "DeterDupl ratio {ratio}");
        }
    }

    #[test]
    fn fig2d_rams_beats_full_ssort() {
        // "RAMS for Uniform instances is up to 1000 times faster than
        // SSort" — the splitter phase (gather 16·log p samples per PE to
        // PE 0, sort, broadcast) alone dwarfs RAMS at scale
        let base = RunConfig { p: 1 << 8, ..Default::default() };
        let points = [NpPoint::Dense(256)];
        let series = ratio_series(
            Algorithm::Rams,
            Algorithm::SSort,
            Distribution::Uniform,
            &base,
            &points,
            1,
            2,
        );
        let (ratio, rc, nc) = series.ratios[0];
        assert!(!rc && !nc);
        assert!(ratio < 1.0, "RAMS/SSort ratio {ratio} (must win)");
    }

    #[test]
    fn fig2d_ns_ssort_is_a_lower_bound_at_moderate_np() {
        // NS-SSort (free splitters) is a *lower bound* for single-delivery
        // algorithms; at moderate p and n/p RAMS lands within a small
        // factor of it (the paper's 1.5–7.4× band is at 131 072 cores)
        let base = RunConfig { p: 1 << 6, ..Default::default() };
        let points = [NpPoint::Dense(512)];
        let series = fig2d(&base, &points, 1, 2);
        let (ratio, rc, nc) = series[0].ratios[0];
        assert!(!rc && !nc);
        assert!(ratio.is_finite() && ratio < 8.0, "RAMS/NS-SSort ratio {ratio}");
    }
}
