//! Appendix J2: parameter tuning — RAMS level counts and HykSort k, plus
//! the selector crossover thresholds, derived for the *configured* α/β by
//! probing instead of hard-coding the paper's JUQUEEN numbers
//! ([`crossover_table`]). Long-lived callers (the [`crate::serve`]
//! front-end) go through the process-wide memoized
//! [`crossover_table_cached`], so repeat machine configs skip the probe
//! sweep entirely.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::algorithms::gather_merge::GatherMSorter;
use crate::algorithms::hyksort::{HykConfig, HykSorter};
use crate::algorithms::quick::{QuickConfig, RQuickSorter};
use crate::algorithms::rams::RamsSorter;
use crate::algorithms::rfis::RfisSorter;
use crate::algorithms::selector::CrossoverTable;
use crate::algorithms::{Runner, Sorter};
use crate::config::RunConfig;
use crate::input::{generate, Distribution};

/// Simulated time of one probe run (∞ on crash). Validation and output
/// retention are off — tuning reads only the clock — and the memory cap is
/// lifted because gather-style probes legitimately concentrate Θ(n).
fn probe_time(cfg: &RunConfig, sorter: &dyn Sorter) -> f64 {
    let mut cfg = cfg.clone();
    cfg.mem_cap_factor = None;
    let mut runner = Runner::new(cfg.clone()).validate(false).keep_output(false);
    let report = runner.run(sorter, generate(&cfg, Distribution::Uniform));
    if report.crashed.is_some() {
        f64::INFINITY
    } else {
        report.time
    }
}

/// Simulated time of RAMS at a fixed level count.
pub fn rams_time(cfg: &RunConfig, levels: usize) -> f64 {
    probe_time(cfg, &RamsSorter::robust().with_levels(levels))
}

/// Simulated time of HykSort at a given k.
pub fn hyksort_time(cfg: &RunConfig, k: usize) -> f64 {
    probe_time(cfg, &HykSorter::with_config(HykConfig { k, ..Default::default() }))
}

/// Simulated time of RQuick at a given median window k.
pub fn rquick_time(cfg: &RunConfig, window_k: usize) -> f64 {
    let qc = QuickConfig { window_k, ..QuickConfig::robust() };
    probe_time(cfg, &RQuickSorter::with_config(qc))
}

pub struct Tuning {
    pub p: usize,
    /// (n_per_pe, level, time) grid
    pub rams_levels: Vec<(usize, usize, f64)>,
    /// (n_per_pe, k, time) grid
    pub hyksort_k: Vec<(usize, usize, f64)>,
    /// (n_per_pe, window, time) grid
    pub rquick_window: Vec<(usize, usize, f64)>,
}

pub fn run(p: usize, sizes: &[usize], jobs: usize) -> Tuning {
    #[derive(Clone, Copy)]
    enum Probe {
        Rams(usize, usize),
        Hyk(usize, usize),
        Quick(usize, usize),
    }
    let base = RunConfig::default().with_p(p);
    let mut specs = Vec::with_capacity(sizes.len() * 10);
    for &m in sizes {
        for levels in 1..=3 {
            specs.push(Probe::Rams(m, levels));
        }
        for k in [8usize, 16, 32, 64] {
            specs.push(Probe::Hyk(m, k));
        }
        for w in [4usize, 16, 64] {
            specs.push(Probe::Quick(m, w));
        }
    }
    let times = crate::exec::parallel_map(jobs, specs.len(), |i| match specs[i] {
        Probe::Rams(m, levels) => rams_time(&base.clone().with_n_per_pe(m), levels),
        Probe::Hyk(m, k) => hyksort_time(&base.clone().with_n_per_pe(m), k),
        Probe::Quick(m, w) => rquick_time(&base.clone().with_n_per_pe(m), w),
    });
    let mut rams_levels = Vec::new();
    let mut hyksort_k = Vec::new();
    let mut rquick_window = Vec::new();
    for (spec, t) in specs.iter().zip(times) {
        match *spec {
            Probe::Rams(m, levels) => rams_levels.push((m, levels, t)),
            Probe::Hyk(m, k) => hyksort_k.push((m, k, t)),
            Probe::Quick(m, w) => rquick_window.push((m, w, t)),
        }
    }
    Tuning { p, rams_levels, hyksort_k, rquick_window }
}

impl Tuning {
    pub fn print(&self) {
        println!("\n== App. J2 tuning on p = {} ==", self.p);
        println!("-- RAMS levels (n/p, l, time) --");
        for (m, l, t) in &self.rams_levels {
            println!("{m:>8} l={l}  {t:.3e}");
        }
        println!("-- HykSort k --");
        for (m, k, t) in &self.hyksort_k {
            println!("{m:>8} k={k:<3} {t:.3e}");
        }
        println!("-- RQuick median window --");
        for (m, w, t) in &self.rquick_window {
            println!("{m:>8} w={w:<3} {t:.3e}");
        }
    }

    /// Best RAMS level per size (paper: more levels help small inputs).
    pub fn best_rams_level(&self, m: usize) -> usize {
        self.rams_levels
            .iter()
            .filter(|(mm, _, _)| *mm == m)
            .min_by(|a, b| a.2.total_cmp(&b.2))
            .map(|(_, l, _)| *l)
            .unwrap_or(1)
    }
}

/// Derive a selector [`CrossoverTable`] for the configured machine ratio
/// (α/β in `base.cost`) by probing each pair of adjacent robust algorithms
/// on Uniform inputs — the ROADMAP "crossover auto-tuning" item. The
/// default ladders probe sparsities 1/16..1/2, small sizes 1..16, and
/// large sizes 2^8..2^14; hand the result to
/// [`crate::algorithms::selector::RobustSorter::with_table`].
pub fn crossover_table(base: &RunConfig) -> CrossoverTable {
    crossover_table_with(base, &[16, 8, 4, 2], &[1, 2, 4, 8, 16], &[256, 1024, 4096, 16384])
}

/// [`crossover_table`] with explicit probe ladders:
///
/// * `sparse_s` — sparsity factors (n/p = 1/s) for the GatherM↔RFIS
///   boundary; `gather_max` becomes the largest probed n/p where GatherM
///   still wins, or half the smallest probed n/p if it never does.
/// * `small_m` — dense n/p for the RFIS↔RQuick boundary; `rfis_max`
///   becomes the smallest probed n/p where RQuick takes over (RFIS keeps
///   everything strictly below it), or twice the largest probe if RFIS
///   wins the whole ladder.
/// * `large_m` — dense n/p for the RQuick↔RAMS boundary; `rquick_max`
///   becomes the largest probed n/p where RQuick still wins, or half the
///   smallest probe if RAMS wins everywhere.
///
/// Ladders must be sorted ascending in n/p (i.e. `sparse_s` descending).
/// The simulator is deterministic, so the table is reproducible for a
/// given config.
pub fn crossover_table_with(
    base: &RunConfig,
    sparse_s: &[usize],
    small_m: &[usize],
    large_m: &[usize],
) -> CrossoverTable {
    let gather = GatherMSorter;
    let rfis = RfisSorter;
    let rquick = RQuickSorter::robust();
    let rams = RamsSorter::robust();
    let mut table = CrossoverTable::JUQUEEN;

    // GatherM ↔ RFIS over the sparse ladder
    let mut gather_max = None;
    for &s in sparse_s {
        let cfg = base.clone().with_sparsity(s);
        if probe_time(&cfg, &gather) <= probe_time(&cfg, &rfis) {
            let npp = 1.0 / s as f64;
            gather_max = Some(gather_max.map_or(npp, |prev: f64| prev.max(npp)));
        }
    }
    table.gather_max = gather_max
        .unwrap_or_else(|| sparse_s.iter().map(|&s| 1.0 / s as f64).fold(f64::MAX, f64::min) / 2.0);

    // RFIS ↔ RQuick over the small dense ladder
    let mut rfis_max = None;
    for &m in small_m {
        let cfg = base.clone().with_n_per_pe(m);
        if probe_time(&cfg, &rquick) <= probe_time(&cfg, &rfis) {
            rfis_max = Some(m as f64);
            break;
        }
    }
    // RFIS won the whole ladder: extend its regime one octave past the
    // probes instead of silently keeping the JUQUEEN number
    table.rfis_max =
        rfis_max.unwrap_or_else(|| 2.0 * small_m.last().copied().unwrap_or(2) as f64);

    // RQuick ↔ RAMS over the large dense ladder
    let mut rquick_max = None;
    for &m in large_m {
        let cfg = base.clone().with_n_per_pe(m);
        if probe_time(&cfg, &rquick) <= probe_time(&cfg, &rams) {
            rquick_max = Some(rquick_max.map_or(m as f64, |prev: f64| prev.max(m as f64)));
        }
    }
    table.rquick_max =
        rquick_max.unwrap_or_else(|| large_m.first().copied().unwrap_or(512) as f64 / 2.0);

    table
}

/// Every config field a crossover probe's outcome depends on: machine
/// width, cost-model constants, balance requirement, and the master seed
/// (probe inputs are generated from it). `n_per_pe`, `sparsity`, and
/// `mem_cap_factor` are deliberately excluded — the probe ladders
/// override the size fields and lift the memory cap, so they cannot
/// influence the derived table.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct ProbeKey {
    p: usize,
    seed: u64,
    alpha: u64,
    beta: u64,
    cmp: u64,
    duplex: bool,
    epsilon: u64,
}

impl ProbeKey {
    fn of(cfg: &RunConfig) -> Self {
        Self {
            p: cfg.p,
            seed: cfg.seed,
            alpha: cfg.cost.alpha.to_bits(),
            beta: cfg.cost.beta.to_bits(),
            cmp: cfg.cost.cmp.to_bits(),
            duplex: cfg.cost.duplex,
            epsilon: cfg.epsilon.to_bits(),
        }
    }
}

fn crossover_cache() -> &'static Mutex<HashMap<ProbeKey, CrossoverTable>> {
    static CACHE: OnceLock<Mutex<HashMap<ProbeKey, CrossoverTable>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

static CACHE_HITS: AtomicU64 = AtomicU64::new(0);
static CACHE_PROBES: AtomicU64 = AtomicU64::new(0);

/// [`crossover_table`] memoized per machine config, process-wide: the
/// first request for a `(p, α, β, cmp, duplex, ε, seed)` combination pays
/// the full probe sweep, every later request returns the cached table.
/// The probe is deterministic (see [`crossover_table_with`]), so caching
/// is invisible in results — only in latency, which is exactly what the
/// serve front-end needs when a stream of jobs repeats a handful of
/// machine configs.
///
/// The probe runs *outside* the cache lock, so concurrent first requests
/// for distinct configs probe in parallel; concurrent first requests for
/// the *same* config may both probe, but insert identical tables.
pub fn crossover_table_cached(base: &RunConfig) -> CrossoverTable {
    let key = ProbeKey::of(base);
    if let Some(table) = crossover_cache().lock().unwrap().get(&key) {
        CACHE_HITS.fetch_add(1, Ordering::Relaxed);
        return *table;
    }
    let table = crossover_table(base);
    CACHE_PROBES.fetch_add(1, Ordering::Relaxed);
    crossover_cache().lock().unwrap().insert(key, table);
    table
}

/// Cumulative `(cache hits, probe sweeps run)` of
/// [`crossover_table_cached`] — the serve stats report the delta over a
/// drain so "repeat configs skip re-probing" is measurable, not assumed.
pub fn crossover_cache_counters() -> (u64, u64) {
    (CACHE_HITS.load(Ordering::Relaxed), CACHE_PROBES.load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_levels_help_small_inputs_on_bigger_machines() {
        // the App. J2 finding: more levels speed up RAMS for small inputs
        // (k ≈ p startups per PE collapse to l·p^(1/l)); with n/p = 256 on
        // p = 256 the 1-level variant pays ~min(p, n/p) startups per PE
        let t = run(1 << 8, &[256], crate::exec::available_jobs());
        let small_best = t.best_rams_level(256);
        assert!(small_best >= 2, "small-input best level {small_best}");
    }

    #[test]
    fn tuning_grid_is_complete() {
        let t = run(1 << 6, &[64], 2);
        assert_eq!(t.rams_levels.len(), 3);
        assert_eq!(t.hyksort_k.len(), 4);
        assert_eq!(t.rquick_window.len(), 3);
        assert!(t.rams_levels.iter().all(|(_, _, t)| t.is_finite()));
    }

    /// Derived crossovers are ordered, in the probed ranges, and keep the
    /// qualitative Fig. 1 shape on the default cost model: a sparse
    /// GatherM regime below 1, an RFIS window, an RQuick plateau.
    #[test]
    fn crossover_table_orders_the_four_regimes() {
        let base = RunConfig::default().with_p(1 << 5);
        let t = crossover_table_with(&base, &[16, 8, 4, 2], &[1, 2, 4, 8], &[64, 256, 1024]);
        assert!(t.gather_max < 1.0, "gather regime is sparse: {t:?}");
        assert!(t.gather_max < t.rfis_max, "{t:?}");
        assert!(t.rfis_max <= t.rquick_max, "{t:?}");
        assert_eq!(t.choose(t.gather_max / 2.0), "GatherM");
        assert_eq!(t.choose(t.rquick_max * 2.0 + 1.0), "RAMS");
    }

    /// The probe is deterministic: same config, same table.
    #[test]
    fn crossover_table_is_deterministic() {
        let base = RunConfig::default().with_p(1 << 4);
        let a = crossover_table_with(&base, &[4, 2], &[1, 4], &[64, 256]);
        let b = crossover_table_with(&base, &[4, 2], &[1, 4], &[64, 256]);
        assert_eq!(a, b);
    }

    /// The cache: a repeat config returns the identical table without a
    /// second probe sweep, and size fields do not fragment the key (the
    /// ladders override them). This is the only test in this binary that
    /// touches the cache counters, so the probe-delta assertion cannot
    /// race another thread probing concurrently.
    #[test]
    fn crossover_table_cached_skips_reprobing_repeat_configs() {
        // a key no other call site uses, so the first call really probes
        let base = RunConfig::default().with_p(1 << 3).with_seed(0xCAC4E);
        let first = crossover_table_cached(&base);
        let (_, probes_after_first) = crossover_cache_counters();
        let second = crossover_table_cached(&base);
        assert_eq!(first, second);
        let (hits, probes) = crossover_cache_counters();
        assert_eq!(probes, probes_after_first, "repeat config must not re-probe");
        assert!(hits >= 1);
        // n_per_pe / sparsity / mem-cap changes address the same cache slot
        let resized = base.clone().with_n_per_pe(4096);
        assert_eq!(crossover_table_cached(&resized), first);
        let (_, probes_resized) = crossover_cache_counters();
        assert_eq!(probes_resized, probes_after_first, "size fields are not part of the key");
        // a different machine config is a genuine miss
        let other_seed = base.clone().with_seed(0xCAC4F);
        let _ = crossover_table_cached(&other_seed);
        let (_, probes_other) = crossover_cache_counters();
        assert_eq!(probes_other, probes_after_first + 1);
        // and the uncached path agrees with what was cached
        assert_eq!(crossover_table(&base), first);
    }
}
