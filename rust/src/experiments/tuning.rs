//! Appendix J2: parameter tuning — RAMS level counts and HykSort k, plus
//! the selector crossover thresholds.

use crate::algorithms::{hyksort, quick, rams};
use crate::config::RunConfig;
use crate::input::{generate, Distribution};
use crate::localsort::RustSort;
use crate::sim::Machine;

/// Simulated time of RAMS at a fixed level count.
pub fn rams_time(cfg: &RunConfig, levels: usize) -> f64 {
    let mut mach = Machine::new(cfg.p, cfg.cost);
    mach.mem_cap_elems = cfg.mem_cap_elems();
    let mut data = generate(cfg, Distribution::Uniform);
    let ac = rams::AmsConfig::robust(cfg).with_levels(levels);
    rams::sort(&mut mach, &mut data, cfg, &mut RustSort, &ac);
    mach.time()
}

/// Simulated time of HykSort at a given k.
pub fn hyksort_time(cfg: &RunConfig, k: usize) -> f64 {
    let mut mach = Machine::new(cfg.p, cfg.cost);
    mach.mem_cap_elems = cfg.mem_cap_elems();
    let mut data = generate(cfg, Distribution::Uniform);
    let hc = hyksort::HykConfig { k, ..Default::default() };
    hyksort::sort(&mut mach, &mut data, cfg, &mut RustSort, &hc);
    mach.time()
}

/// Simulated time of RQuick at a given median window k.
pub fn rquick_time(cfg: &RunConfig, window_k: usize) -> f64 {
    let mut mach = Machine::new(cfg.p, cfg.cost);
    mach.mem_cap_elems = cfg.mem_cap_elems();
    let mut data = generate(cfg, Distribution::Uniform);
    let qc = quick::QuickConfig { window_k, ..quick::QuickConfig::robust() };
    quick::sort(&mut mach, &mut data, cfg, &mut RustSort, &qc);
    mach.time()
}

pub struct Tuning {
    pub p: usize,
    /// (n_per_pe, level, time) grid
    pub rams_levels: Vec<(usize, usize, f64)>,
    /// (n_per_pe, k, time) grid
    pub hyksort_k: Vec<(usize, usize, f64)>,
    /// (n_per_pe, window, time) grid
    pub rquick_window: Vec<(usize, usize, f64)>,
}

pub fn run(p: usize, sizes: &[usize], jobs: usize) -> Tuning {
    #[derive(Clone, Copy)]
    enum Probe {
        Rams(usize, usize),
        Hyk(usize, usize),
        Quick(usize, usize),
    }
    let base = RunConfig::default().with_p(p);
    let mut specs = Vec::with_capacity(sizes.len() * 10);
    for &m in sizes {
        for levels in 1..=3 {
            specs.push(Probe::Rams(m, levels));
        }
        for k in [8usize, 16, 32, 64] {
            specs.push(Probe::Hyk(m, k));
        }
        for w in [4usize, 16, 64] {
            specs.push(Probe::Quick(m, w));
        }
    }
    let times = crate::exec::parallel_map(jobs, specs.len(), |i| match specs[i] {
        Probe::Rams(m, levels) => rams_time(&base.clone().with_n_per_pe(m), levels),
        Probe::Hyk(m, k) => hyksort_time(&base.clone().with_n_per_pe(m), k),
        Probe::Quick(m, w) => rquick_time(&base.clone().with_n_per_pe(m), w),
    });
    let mut rams_levels = Vec::new();
    let mut hyksort_k = Vec::new();
    let mut rquick_window = Vec::new();
    for (spec, t) in specs.iter().zip(times) {
        match *spec {
            Probe::Rams(m, levels) => rams_levels.push((m, levels, t)),
            Probe::Hyk(m, k) => hyksort_k.push((m, k, t)),
            Probe::Quick(m, w) => rquick_window.push((m, w, t)),
        }
    }
    Tuning { p, rams_levels, hyksort_k, rquick_window }
}

impl Tuning {
    pub fn print(&self) {
        println!("\n== App. J2 tuning on p = {} ==", self.p);
        println!("-- RAMS levels (n/p, l, time) --");
        for (m, l, t) in &self.rams_levels {
            println!("{m:>8} l={l}  {t:.3e}");
        }
        println!("-- HykSort k --");
        for (m, k, t) in &self.hyksort_k {
            println!("{m:>8} k={k:<3} {t:.3e}");
        }
        println!("-- RQuick median window --");
        for (m, w, t) in &self.rquick_window {
            println!("{m:>8} w={w:<3} {t:.3e}");
        }
    }

    /// Best RAMS level per size (paper: more levels help small inputs).
    pub fn best_rams_level(&self, m: usize) -> usize {
        self.rams_levels
            .iter()
            .filter(|(mm, _, _)| *mm == m)
            .min_by(|a, b| a.2.total_cmp(&b.2))
            .map(|(_, l, _)| *l)
            .unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_levels_help_small_inputs_on_bigger_machines() {
        // the App. J2 finding: more levels speed up RAMS for small inputs
        // (k ≈ p startups per PE collapse to l·p^(1/l)); with n/p = 256 on
        // p = 256 the 1-level variant pays ~min(p, n/p) startups per PE
        let t = run(1 << 8, &[256], crate::exec::available_jobs());
        let small_best = t.best_rams_level(256);
        assert!(small_best >= 2, "small-input best level {small_best}");
    }

    #[test]
    fn tuning_grid_is_complete() {
        let t = run(1 << 6, &[64], 2);
        assert_eq!(t.rams_levels.len(), 3);
        assert_eq!(t.hyksort_k.len(), 4);
        assert_eq!(t.rquick_window.len(), 3);
        assert!(t.rams_levels.iter().all(|(_, _, t)| t.is_finite()));
    }
}
