//! Table I: asymptotic latency (α-count) and communication volume
//! (β-volume) of the algorithms. We validate the table empirically: the
//! simulator counts startups and words exactly, so measuring two machine
//! sizes and checking growth against the predicted exponent reproduces
//! each row.

use crate::algorithms::{Algorithm, Runner};
use crate::config::RunConfig;
use crate::input::{generate, Distribution};

/// Measured α/β footprint of one run.
#[derive(Clone, Copy, Debug)]
pub struct Footprint {
    pub p: usize,
    pub n_per_pe: usize,
    /// max startups on the critical path ≈ messages / p (aggregate proxy)
    pub messages_per_pe: f64,
    pub words_per_pe: f64,
    pub time: f64,
}

pub fn measure(alg: Algorithm, p: usize, n_per_pe: usize, seed: u64) -> Option<Footprint> {
    let mut cfg = RunConfig::default().with_p(p).with_n_per_pe(n_per_pe).with_seed(seed);
    // footprint measurement must not trip the memory cap: gather-style
    // algorithms legitimately concentrate Θ(n) on one PE
    cfg.mem_cap_factor = None;
    // footprints read only time/stats — skip the reference clone and the
    // output payload
    let mut runner = Runner::new(cfg.clone()).validate(false).keep_output(false);
    let report = runner.run_algorithm(alg, generate(&cfg, Distribution::Uniform));
    if report.crashed.is_some() {
        return None;
    }
    Some(Footprint {
        p,
        n_per_pe,
        messages_per_pe: report.stats.messages as f64 / p as f64,
        words_per_pe: report.stats.words as f64 / p as f64,
        time: report.time,
    })
}

/// One row of the empirical Table I.
#[derive(Clone, Debug)]
pub struct Row {
    /// Registry name of the sorter ([`crate::algorithms::Sorter::name`]).
    pub algorithm: &'static str,
    pub small: Footprint,
    pub large: Footprint,
    /// growth of per-PE messages when p quadruples (≈ latency exponent)
    pub msg_growth: f64,
    /// growth of per-PE words when p quadruples
    pub word_growth: f64,
}

/// Compare footprints at p and 4p (same n/p). Every (algorithm, machine
/// size) measurement is one job on the worker pool; rows keep the fixed
/// algorithm order regardless of completion order.
pub fn run_table(n_per_pe: usize, p_small: usize, seed: u64, jobs: usize) -> Vec<Row> {
    let p_large = p_small * 4;
    // the same eight-algorithm comparison set as Figure 1 — one list,
    // derived from the registry tags
    let algos = Algorithm::FIG1;
    let foots = crate::exec::parallel_map(jobs, algos.len() * 2, |i| {
        let alg = algos[i / 2];
        let p = if i % 2 == 0 { p_small } else { p_large };
        measure(alg, p, n_per_pe, seed)
    });
    let mut rows = Vec::new();
    for (k, &alg) in algos.iter().enumerate() {
        let (Some(s), Some(l)) = (foots[2 * k], foots[2 * k + 1]) else {
            continue;
        };
        rows.push(Row {
            algorithm: alg.name(),
            small: s,
            large: l,
            msg_growth: l.messages_per_pe / s.messages_per_pe,
            word_growth: l.words_per_pe / s.words_per_pe,
        });
    }
    rows
}

pub fn print_rows(rows: &[Row]) {
    println!("\n== Table I (empirical): per-PE α/β footprint growth when p ×4 ==");
    println!(
        "{:>12} {:>12} {:>12} {:>12} {:>12}",
        "algorithm", "msgs/PE(p)", "msgs/PE(4p)", "msg ×", "words ×"
    );
    for r in rows {
        println!(
            "{:>12} {:>12.1} {:>12.1} {:>12.2} {:>12.2}",
            r.algorithm,
            r.small.messages_per_pe,
            r.large.messages_per_pe,
            r.msg_growth,
            r.word_growth
        );
    }
    println!("expected: log-latency rows grow ~(log4p/logp); SSort words ~×1, msgs ×4");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_growth_ranks_algorithms() {
        // n/p must exceed 4·p_small so SSort's per-PE message count is not
        // capped by the element count (Ω(p) needs p distinct targets)
        let rows = run_table(1 << 9, 1 << 5, 7, crate::exec::available_jobs());
        let get = |a: &str| rows.iter().find(|r| r.algorithm == a);
        // SSort's per-PE message count grows ~linearly with p (Ω(p) row);
        // RQuick's grows only logarithmically (log²p row)
        let ss = get("SSort").expect("ssort measured");
        let rq = get("RQuick").expect("rquick measured");
        assert!(
            ss.msg_growth > 2.0,
            "SSort msgs must grow ~linearly: {}",
            ss.msg_growth
        );
        assert!(
            rq.msg_growth < ss.msg_growth,
            "RQuick {} vs SSort {}",
            rq.msg_growth,
            ss.msg_growth
        );
        // Bitonic moves Θ(n/p·log²p) words per PE — more than RQuick's
        // Θ(n/p·log p) at the same size
        let bi = get("Bitonic").expect("bitonic measured");
        assert!(bi.large.words_per_pe > rq.large.words_per_pe);
        // AllGatherM words per PE ~ n (grows ×4 with p at fixed n/p)
        let ag = get("AllGatherM").expect("allgatherm measured");
        assert!(ag.word_growth > 3.0, "AllGatherM {}", ag.word_growth);
        // RFIS words per PE ~ n/√p (grows ×2)
        let rf = get("RFIS").expect("rfis measured");
        assert!(
            rf.word_growth > 1.5 && rf.word_growth < 3.0,
            "RFIS {}",
            rf.word_growth
        );
    }
}
