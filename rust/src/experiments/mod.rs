//! Experiment harness: one module per paper table/figure (see DESIGN.md §4).
//!
//! Cells are keyed by *sorter* ([`crate::algorithms::Sorter`]), so sweeps
//! enumerate the registry — including externally
//! [`crate::algorithms::register`]ed sorters — instead of a closed enum;
//! [`run_cell`] remains as an [`Algorithm`]-tagged convenience shim.

pub mod fig1;
pub mod fig2;
pub mod fig4;
pub mod fig5;
pub mod table1;
pub mod tuning;

use std::sync::Arc;

use crate::algorithms::{Algorithm, OutputShape, Runner, RunReport, Sorter};
use crate::config::RunConfig;
use crate::exec;
use crate::input::{generate, Distribution};

/// One cell spec of a sweep grid: which sorter, on which instance, at
/// which point of the n/p axis.
pub type SorterSpec = (Arc<dyn Sorter>, Distribution, NpPoint);

/// Run a batch of cells across the persistent worker pool
/// ([`crate::exec::parallel_map`]), returning results **in spec order**.
///
/// Every cell is a pure function of its spec (all randomness derives from
/// per-config seeds), so any `jobs ≥ 1` produces byte-identical figures;
/// the pool only changes wallclock — and peak transient memory, which
/// scales with `jobs` because up to that many cells simulate concurrently
/// (stored cells are lean: the cell runner drops the output payload).
pub fn run_cells(
    jobs: usize,
    base: &RunConfig,
    specs: &[SorterSpec],
    reps: usize,
) -> Vec<CellResult> {
    exec::parallel_map(jobs, specs.len(), |i| {
        let (sorter, dist, point) = &specs[i];
        run_sorter_cell(sorter.as_ref(), *dist, base, *point, reps)
    })
}

/// The n/p sweep grid of the paper's Fig. 1: sparse points 3^-5..3^-1 and
/// dense powers of two up to `max_log`.
pub fn np_sweep(max_log: u32) -> Vec<NpPoint> {
    let mut pts = Vec::new();
    for k in (1..=5u32).rev() {
        pts.push(NpPoint::Sparse(3usize.pow(k)));
    }
    for l in 0..=max_log {
        pts.push(NpPoint::Dense(1usize << l));
    }
    pts
}

/// One point on the n/p axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NpPoint {
    /// `Sparse(s)`: one element on every s-th PE (n/p = 1/s).
    Sparse(usize),
    /// `Dense(m)`: m elements per PE.
    Dense(usize),
}

impl NpPoint {
    pub fn apply(&self, cfg: &RunConfig) -> RunConfig {
        match *self {
            NpPoint::Sparse(s) => cfg.clone().with_sparsity(s),
            NpPoint::Dense(m) => cfg.clone().with_n_per_pe(m),
        }
    }

    pub fn n_over_p(&self) -> f64 {
        match *self {
            NpPoint::Sparse(s) => 1.0 / s as f64,
            NpPoint::Dense(m) => m as f64,
        }
    }

    pub fn label(&self) -> String {
        match *self {
            NpPoint::Sparse(s) => format!("3^-{}", (s as f64).log(3.0).round() as u32),
            NpPoint::Dense(m) => format!("2^{}", (m as f64).log2().round() as u32),
        }
    }
}

/// [`run_sorter_cell`] addressed by the legacy enum tag.
pub fn run_cell(
    alg: Algorithm,
    dist: Distribution,
    base: &RunConfig,
    point: NpPoint,
    reps: usize,
) -> CellResult {
    run_sorter_cell(alg.sorter().as_ref(), dist, base, point, reps)
}

/// Run one (sorter, distribution, n/p) cell, averaging `reps` seeds (the
/// paper averages 5 runs after a warmup). One [`Runner`] executes the
/// whole cell, so repetitions reuse the machine's scratch, and the Θ(n)
/// output payload — which no figure reads — is never retained.
pub fn run_sorter_cell(
    sorter: &dyn Sorter,
    dist: Distribution,
    base: &RunConfig,
    point: NpPoint,
    reps: usize,
) -> CellResult {
    let algorithm = sorter.name();
    // gather-style sorters (non-balanced output shapes) concentrate Θ(n)
    // on one PE by design — the sweep shows their (steep) curve instead of
    // tripping the robustness memory cap meant for *accidental*
    // concentration
    let gather_style = sorter.output_shape() != OutputShape::Balanced;
    // replicating sorters hold n·p resident elements. Past a host-memory
    // threshold that is an OOM on the real machine too — report it as such
    // instead of thrashing.
    let cell_cfg = point.apply(base);
    if sorter.output_shape() == OutputShape::Replicated
        && cell_cfg.n_total().saturating_mul(cell_cfg.p) > (1 << 27)
    {
        return CellResult {
            algorithm,
            distribution: dist,
            point,
            time: f64::INFINITY,
            crashed: true,
            ok: false,
            report: None,
            machine_reuse_hits: 0,
            machine_fresh_builds: 0,
            host_rounds: 0,
            host_wall_ms: 0.0,
        };
    }

    // repetitions share one runner ([`Runner::run_many`] semantics, but
    // unrolled so a crashing cell stops at the first failed rep instead of
    // simulating the rest)
    let mut runner = Runner::new(cell_cfg).keep_output(false);
    let reps = reps.max(1);
    let mut times = Vec::with_capacity(reps);
    let mut last: Option<RunReport> = None;
    let mut host_rounds = 0u64;
    let mut host_wall_ms = 0.0f64;
    for rep in 0..reps {
        let mut cfg = point.apply(base).with_seed(base.seed.wrapping_add(rep as u64 * 7919));
        if gather_style {
            cfg.mem_cap_factor = None;
        }
        let input = generate(&cfg, dist);
        runner.set_config(cfg);
        let (report, meta) = runner.run_with_meta(sorter, input);
        host_rounds += meta.host_rounds;
        host_wall_ms += meta.wall_ms;
        if report.crashed.is_some() {
            let (hits, fresh) = runner.reuse_counters();
            return CellResult {
                algorithm,
                distribution: dist,
                point,
                time: f64::INFINITY,
                crashed: true,
                ok: false,
                report: Some(report),
                machine_reuse_hits: hits,
                machine_fresh_builds: fresh,
                host_rounds,
                host_wall_ms,
            };
        }
        times.push(report.time);
        last = Some(report);
    }
    let report = last.unwrap();
    let (hits, fresh) = runner.reuse_counters();
    CellResult {
        algorithm,
        distribution: dist,
        point,
        time: times.iter().sum::<f64>() / times.len() as f64,
        crashed: false,
        ok: report.validation.ok(),
        report: Some(report),
        machine_reuse_hits: hits,
        machine_fresh_builds: fresh,
        host_rounds,
        host_wall_ms,
    }
}

/// One cell of a figure.
#[derive(Debug)]
pub struct CellResult {
    /// Registry name of the sorter ([`Sorter::name`]).
    pub algorithm: &'static str,
    pub distribution: Distribution,
    pub point: NpPoint,
    pub time: f64,
    pub crashed: bool,
    pub ok: bool,
    pub report: Option<RunReport>,
    /// Machine-reuse breakdown of the cell's repetitions (the runner is
    /// shared, so reps after the first are reuse hits): from
    /// [`Runner::reuse_counters`], for free via [`Runner::run_with_meta`].
    pub machine_reuse_hits: u64,
    pub machine_fresh_builds: u64,
    /// Host-side superstep settlements summed over the cell's repetitions
    /// (Σ [`crate::algorithms::runner::RunMeta::host_rounds`]).
    pub host_rounds: u64,
    /// Host wallclock of the simulation windows summed over the cell's
    /// repetitions, ms. With `host_rounds` this yields the giant-p sweep's
    /// host-µs-per-superstep metric ([`CellResult::host_us_per_round`]).
    pub host_wall_ms: f64,
}

impl CellResult {
    /// Host µs per settled superstep, averaged over the cell's
    /// repetitions — the giant-p scaling metric (non-finite if the cell
    /// never settled a superstep, e.g. the replicated-OOM guard fired).
    pub fn host_us_per_round(&self) -> f64 {
        self.host_wall_ms * 1e3 / self.host_rounds as f64
    }

    pub fn display_time(&self) -> String {
        if self.crashed {
            "CRASH".to_string()
        } else {
            format!("{:.3e}", self.time)
        }
    }
}
