//! Experiment harness: one module per paper table/figure (see DESIGN.md §4).

pub mod fig1;
pub mod fig2;
pub mod fig4;
pub mod fig5;
pub mod table1;
pub mod tuning;

use crate::algorithms::{run, Algorithm, RunReport};
use crate::config::RunConfig;
use crate::exec;
use crate::input::{generate, Distribution};

/// Run a batch of cells across the scoped-thread worker pool
/// ([`crate::exec::parallel_map`]), returning results **in spec order**.
///
/// Every cell is a pure function of its spec (all randomness derives from
/// per-config seeds), so any `jobs ≥ 1` produces byte-identical figures;
/// the pool only changes wallclock — and peak transient memory, which
/// scales with `jobs` because up to that many cells simulate concurrently
/// (stored cells are lean: [`run_cell`] drops the output payload).
pub fn run_cells(
    jobs: usize,
    base: &RunConfig,
    specs: &[(Algorithm, Distribution, NpPoint)],
    reps: usize,
) -> Vec<CellResult> {
    exec::parallel_map(jobs, specs.len(), |i| {
        let (alg, dist, point) = specs[i];
        run_cell(alg, dist, base, point, reps)
    })
}

/// The n/p sweep grid of the paper's Fig. 1: sparse points 3^-5..3^-1 and
/// dense powers of two up to `max_log`.
pub fn np_sweep(max_log: u32) -> Vec<NpPoint> {
    let mut pts = Vec::new();
    for k in (1..=5u32).rev() {
        pts.push(NpPoint::Sparse(3usize.pow(k)));
    }
    for l in 0..=max_log {
        pts.push(NpPoint::Dense(1usize << l));
    }
    pts
}

/// One point on the n/p axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NpPoint {
    /// `Sparse(s)`: one element on every s-th PE (n/p = 1/s).
    Sparse(usize),
    /// `Dense(m)`: m elements per PE.
    Dense(usize),
}

impl NpPoint {
    pub fn apply(&self, cfg: &RunConfig) -> RunConfig {
        match *self {
            NpPoint::Sparse(s) => cfg.clone().with_sparsity(s),
            NpPoint::Dense(m) => cfg.clone().with_n_per_pe(m),
        }
    }

    pub fn n_over_p(&self) -> f64 {
        match *self {
            NpPoint::Sparse(s) => 1.0 / s as f64,
            NpPoint::Dense(m) => m as f64,
        }
    }

    pub fn label(&self) -> String {
        match *self {
            NpPoint::Sparse(s) => format!("3^-{}", (s as f64).log(3.0).round() as u32),
            NpPoint::Dense(m) => format!("2^{}", (m as f64).log2().round() as u32),
        }
    }
}

/// Run one (algorithm, distribution, n/p) cell, averaging `reps` seeds
/// (the paper averages 5 runs after a warmup).
pub fn run_cell(
    alg: Algorithm,
    dist: Distribution,
    base: &RunConfig,
    point: NpPoint,
    reps: usize,
) -> CellResult {
    let mut times = Vec::with_capacity(reps);
    let mut last: Option<RunReport> = None;
    for rep in 0..reps.max(1) {
        let mut cfg = point.apply(base).with_seed(base.seed.wrapping_add(rep as u64 * 7919));
        // gather-style algorithms concentrate Θ(n) on one PE by design —
        // the sweep shows their (steep) curve instead of tripping the
        // robustness memory cap meant for *accidental* concentration
        if matches!(alg, Algorithm::GatherM | Algorithm::AllGatherM) {
            cfg.mem_cap_factor = None;
        }
        // AllGatherM replicates the whole input on every PE: n·p resident
        // elements. Past a host-memory threshold that is an OOM on the
        // real machine too — report it as such instead of thrashing.
        if alg == Algorithm::AllGatherM && cfg.n_total().saturating_mul(cfg.p) > (1 << 27) {
            return CellResult {
                algorithm: alg,
                distribution: dist,
                point,
                time: f64::INFINITY,
                crashed: true,
                ok: false,
                report: None,
            };
        }
        let mut report = run(alg, &cfg, generate(&cfg, dist));
        // figures keep every cell alive for the whole sweep, and the
        // parallel driver keeps up to `jobs` cells in flight on top: drop
        // the per-PE output payload (Θ(n), or Θ(n·p) for AllGatherM's
        // replicated output), which no figure consumer reads — the cell
        // only needs time/stats/validation
        report.output = Vec::new();
        if report.crashed.is_some() {
            return CellResult {
                algorithm: alg,
                distribution: dist,
                point,
                time: f64::INFINITY,
                crashed: true,
                ok: false,
                report: Some(report),
            };
        }
        times.push(report.time);
        last = Some(report);
    }
    let report = last.unwrap();
    CellResult {
        algorithm: alg,
        distribution: dist,
        point,
        time: times.iter().sum::<f64>() / times.len() as f64,
        crashed: false,
        ok: report.validation.ok(),
        report: Some(report),
    }
}

/// One cell of a figure.
#[derive(Debug)]
pub struct CellResult {
    pub algorithm: Algorithm,
    pub distribution: Distribution,
    pub point: NpPoint,
    pub time: f64,
    pub crashed: bool,
    pub ok: bool,
    pub report: Option<RunReport>,
}

impl CellResult {
    pub fn display_time(&self) -> String {
        if self.crashed {
            "CRASH".to_string()
        } else {
            format!("{:.3e}", self.time)
        }
    }
}
