//! Elements, keys, and the tie-breaking identity.
//!
//! The paper's elements are 64-bit values; robustness against duplicates is
//! obtained *implicitly* — RQuick splits duplicate runs locally (§VI), RFIS
//! tracks provenance buckets (App. F), RAMS tie-breaks with sample
//! positions (App. G). To let the *robust* code paths simulate unique keys,
//! every element carries an origin id `(pe, idx)` packed into a `u64`.
//! **Nonrobust variants never look at it** — they compare keys only, which
//! is exactly what makes them collapse on duplicate-heavy instances.
//!
//! This module also owns the k-way merge host kernel
//! ([`multiway_merge_into`]): a two-finger ping-pong cascade for small
//! run counts and a single-pass stable tournament loser tree
//! ([`loser_tree_merge_into`]) above [`LOSER_TREE_MIN_RUNS`] — same
//! output bit for bit, O(total) instead of O(total · log k) memory
//! traffic on the path every hypercube round and bucket receipt runs.

/// Sort key. The paper generates 64-bit elements with 32-bit key ranges;
/// we keep the full `u64` domain (generators mostly use `[0, 2^32)`).
pub type Key = u64;

/// One input element: key plus origin identity for explicit tie-breaking.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug, Hash)]
pub struct Elem {
    /// Primary sort key.
    pub key: Key,
    /// Unique origin id: `(pe << IDX_BITS) | idx` with a 40-bit local
    /// index — see [`Elem::new`].
    pub id: u64,
}

/// Number of low bits of `id` reserved for the local index.
const IDX_BITS: u32 = 40;

impl Elem {
    /// Construct with the packed `(pe, idx)` origin id.
    #[inline]
    pub fn new(key: Key, pe: usize, idx: usize) -> Self {
        debug_assert!((idx as u64) < (1 << IDX_BITS));
        Self {
            key,
            id: ((pe as u64) << IDX_BITS) | idx as u64,
        }
    }

    /// Construct with an explicit id (used by generators with global ids).
    #[inline]
    pub fn with_id(key: Key, id: u64) -> Self {
        Self { key, id }
    }

    /// Origin PE encoded in the id.
    #[inline]
    pub fn origin_pe(&self) -> usize {
        (self.id >> IDX_BITS) as usize
    }

    /// Compare by key only — the *nonrobust* ordering.
    #[inline]
    pub fn key_cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key)
    }
}

/// Order-preserving u64 → i64 mapping (for the XLA kernels, which sort
/// signed 64-bit integers).
#[inline]
pub fn key_to_i64(k: Key) -> i64 {
    (k ^ (1u64 << 63)) as i64
}

/// Inverse of [`key_to_i64`].
#[inline]
pub fn key_from_i64(v: i64) -> Key {
    (v as u64) ^ (1u64 << 63)
}

/// Merge two sorted runs into a fresh sorted run (full `(key, id)` order).
pub fn merge(a: &[Elem], b: &[Elem]) -> Vec<Elem> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    merge_into(a, b, &mut out);
    out
}

/// Merge two sorted runs into `out` (cleared first). Branch-light two-finger
/// merge — the hot path of every hypercube exchange step.
pub fn merge_into(a: &[Elem], b: &[Elem], out: &mut Vec<Elem>) {
    out.clear();
    merge_append(a, b, out);
}

/// [`merge_into`] without the clear: appends the merged sequence to `out`.
/// The cascade passes of [`multiway_merge_into`] write consecutive merged
/// segments into one buffer through this.
fn merge_append(a: &[Elem], b: &[Elem], out: &mut Vec<Elem>) {
    out.reserve(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        // `<=` keeps the merge stable in (key, id) order.
        if a[i] <= b[j] {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
}

/// Reusable scratch for [`multiway_merge_into`]: the cascade's ping-pong
/// partner buffer and segment-boundary tables, plus the loser tree's
/// per-leaf state (run indices, cached heads, cursors, liveness, and the
/// tournament nodes). Every `Vec` keeps its capacity across calls, so a
/// warm scratch makes the k-way merge allocation-free on either path.
#[derive(Clone, Debug, Default)]
pub struct MergeScratch {
    tmp: Vec<Elem>,
    bounds: Vec<usize>,
    bounds_next: Vec<usize>,
    live: Vec<u32>,
    heads: Vec<Elem>,
    cursor: Vec<usize>,
    alive: Vec<bool>,
    tree: Vec<u32>,
}

/// Non-empty-run count at and above which [`multiway_merge_into`] uses
/// the single-pass tournament loser tree instead of the ⌈log k⌉-pass
/// two-finger cascade. Below it the cascade's at-most-two extra passes
/// cost less than the tree's per-element replay; above it the loser tree
/// cuts memory traffic from O(n · log k) to O(n).
pub const LOSER_TREE_MIN_RUNS: usize = 8;

/// k-way merge of sorted runs into `out` (cleared first) with **O(total)**
/// buffer space and zero allocations once the scratch is warm. Dispatches
/// on the non-empty run count: below [`LOSER_TREE_MIN_RUNS`] the
/// ping-pong two-finger cascade ([`cascade_merge_into`]), at or above it
/// the single-pass stable tournament loser tree
/// ([`loser_tree_merge_into`]) — every element is written to `out`
/// exactly once instead of once per cascade level.
///
/// Both paths produce the same output bit for bit: the merged sequence in
/// full `(key, id)` order with ties between *fully equal* elements
/// resolved by lower run index (the order the historical adjacent-pair
/// cascade produced, pinned in `rust/tests/kernel_equivalence.rs`).
pub fn multiway_merge_into(runs: &[&[Elem]], out: &mut Vec<Elem>, scratch: &mut MergeScratch) {
    let nonempty = runs.iter().filter(|r| !r.is_empty()).count();
    if nonempty >= LOSER_TREE_MIN_RUNS {
        loser_tree_merge_into(runs, out, scratch);
    } else {
        cascade_merge_into(runs, out, scratch);
    }
}

/// The ⌈log k⌉-pass two-finger cascade: merge adjacent pairs of the
/// non-empty runs, ping-ponging merged segments between `out` and the
/// scratch buffer. The small-k path of [`multiway_merge_into`] (public so
/// the hotpath bench and the equivalence suites can pit it against the
/// loser tree at any k); the merge tree keeps the historical
/// adjacent-pair shape, with an unpaired last segment carried verbatim to
/// the next pass.
pub fn cascade_merge_into(runs: &[&[Elem]], out: &mut Vec<Elem>, scratch: &mut MergeScratch) {
    out.clear();
    let total: usize = runs.iter().map(|r| r.len()).sum();
    out.reserve(total);
    let MergeScratch { tmp, bounds, bounds_next, .. } = scratch;
    bounds.clear();
    bounds.push(0);
    // pass 0 reads straight from the input runs (no up-front copy): merge
    // adjacent non-empty pairs into `out`, recording segment boundaries
    {
        let mut it = runs.iter().filter(|r| !r.is_empty());
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => merge_append(a, b, out),
                None => out.extend_from_slice(a),
            }
            bounds.push(out.len());
        }
    }
    // cascade: merge adjacent segments, ping-ponging between the buffers
    tmp.clear();
    tmp.reserve(total); // once — every pass fills at most `total` elements
    while bounds.len() > 2 {
        tmp.clear();
        bounds_next.clear();
        bounds_next.push(0);
        let segs = bounds.len() - 1;
        let mut s = 0;
        while s < segs {
            if s + 1 < segs {
                // split_at so the two segment borrows and the write
                // target are provably disjoint
                let (a, rest) = out[bounds[s]..bounds[s + 2]].split_at(bounds[s + 1] - bounds[s]);
                merge_append(a, rest, tmp);
                s += 2;
            } else {
                tmp.extend_from_slice(&out[bounds[s]..bounds[s + 1]]);
                s += 1;
            }
            bounds_next.push(tmp.len());
        }
        std::mem::swap(out, tmp);
        std::mem::swap(bounds, bounds_next);
    }
}

/// Does leaf `a` strictly win a tournament match against leaf `b`?
/// Exhausted leaves always lose; between live leaves the order is
/// lexicographic on `(head element, leaf index)`, so fully equal elements
/// resolve to the lower leaf — leaves are numbered in run order, which is
/// exactly the equal-element order of the adjacent-pair cascade.
#[inline]
fn leaf_beats(a: u32, b: u32, heads: &[Elem], alive: &[bool]) -> bool {
    match (alive[a as usize], alive[b as usize]) {
        (true, true) => {
            let (ha, hb) = (heads[a as usize], heads[b as usize]);
            ha < hb || (ha == hb && a < b)
        }
        (true, false) => true,
        (false, _) => false,
    }
}

/// Build the loser tree below `node`: every internal node stores the
/// *loser* of the match between its two subtree winners; the subtree
/// winner is returned. Leaves are `m..2m` (leaf `i` at node `m + i`).
fn init_loser_tree(node: usize, m: usize, tree: &mut [u32], heads: &[Elem], alive: &[bool]) -> u32 {
    if node >= m {
        return (node - m) as u32;
    }
    let a = init_loser_tree(2 * node, m, tree, heads, alive);
    let b = init_loser_tree(2 * node + 1, m, tree, heads, alive);
    let (winner, loser) = if leaf_beats(a, b, heads, alive) { (a, b) } else { (b, a) };
    tree[node] = loser;
    winner
}

/// Single-pass stable k-way merge on a tournament **loser tree** (the
/// classic multiway-merge structure, cf. IPS⁴o and the SSSS lineage):
/// every internal node caches the loser of its subtree match, so
/// replacing the emitted element replays exactly one leaf-to-root path —
/// ⌈log k⌉ compares per element against *cached* heads, and each element
/// is written to `out` exactly once (O(total) memory traffic, vs the
/// cascade's O(total · log k)).
///
/// Ties between fully equal elements resolve by lower run index
/// ([`leaf_beats`]) — the cascade's equal-element order, so the two paths
/// of [`multiway_merge_into`] are interchangeable bit for bit. The
/// large-k path; public for the bench and equivalence suites.
pub fn loser_tree_merge_into(runs: &[&[Elem]], out: &mut Vec<Elem>, scratch: &mut MergeScratch) {
    out.clear();
    let total: usize = runs.iter().map(|r| r.len()).sum();
    out.reserve(total);
    let MergeScratch { live, heads, cursor, alive, tree, .. } = scratch;
    live.clear();
    for (i, r) in runs.iter().enumerate() {
        if !r.is_empty() {
            live.push(i as u32);
        }
    }
    let k = live.len();
    if k == 0 {
        return;
    }
    if k == 1 {
        out.extend_from_slice(runs[live[0] as usize]);
        return;
    }
    // leaves 0..k hold the runs; padding leaves k..m are born exhausted
    let m = k.next_power_of_two();
    heads.clear();
    cursor.clear();
    alive.clear();
    for &ri in live.iter() {
        heads.push(runs[ri as usize][0]);
        cursor.push(0);
        alive.push(true);
    }
    alive.resize(m, false);
    tree.clear();
    tree.resize(m, 0);
    let mut winner = init_loser_tree(1, m, tree, heads, alive);
    for _ in 0..total {
        let leaf = winner as usize;
        out.push(heads[leaf]);
        // advance the emitted leaf, then replay its path to the root:
        // at each ancestor the carried winner meets the stored loser
        let run = runs[live[leaf] as usize];
        cursor[leaf] += 1;
        if cursor[leaf] < run.len() {
            heads[leaf] = run[cursor[leaf]];
        } else {
            alive[leaf] = false;
        }
        let mut node = (m + leaf) >> 1;
        while node >= 1 {
            let other = tree[node];
            if leaf_beats(other, winner, heads, alive) {
                tree[node] = winner;
                winner = other;
            }
            node >>= 1;
        }
    }
}

/// k-way merge of sorted runs (used by gather-merge trees and RAMS data
/// receipt), allocating its result and scratch — convenience wrapper over
/// [`multiway_merge_into`], which hot paths call with pooled buffers.
pub fn multiway_merge(runs: &[&[Elem]]) -> Vec<Elem> {
    let mut out = Vec::new();
    let mut scratch = MergeScratch::default();
    multiway_merge_into(runs, &mut out, &mut scratch);
    out
}

/// `true` iff `v` is sorted in full `(key, id)` order.
pub fn is_sorted(v: &[Elem]) -> bool {
    v.windows(2).all(|w| w[0] <= w[1])
}

/// `true` iff `v` is sorted by key (ties in any order).
pub fn is_key_sorted(v: &[Elem]) -> bool {
    v.windows(2).all(|w| w[0].key <= w[1].key)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ord_is_key_then_id() {
        let a = Elem::with_id(5, 1);
        let b = Elem::with_id(5, 2);
        let c = Elem::with_id(6, 0);
        assert!(a < b && b < c);
    }

    #[test]
    fn origin_pe_roundtrip() {
        let e = Elem::new(0, 12345, 678);
        assert_eq!(e.origin_pe(), 12345);
        assert_eq!(e.id & ((1 << 40) - 1), 678);
    }

    #[test]
    fn key_i64_mapping_is_order_preserving() {
        let keys = [0u64, 1, u64::MAX / 2, u64::MAX / 2 + 1, u64::MAX];
        for w in keys.windows(2) {
            assert!(key_to_i64(w[0]) < key_to_i64(w[1]));
            assert_eq!(key_from_i64(key_to_i64(w[0])), w[0]);
        }
    }

    #[test]
    fn merge_preserves_order_and_content() {
        let a: Vec<Elem> = [1u64, 3, 5, 5].iter().enumerate().map(|(i, &k)| Elem::new(k, 0, i)).collect();
        let b: Vec<Elem> = [2u64, 5, 6].iter().enumerate().map(|(i, &k)| Elem::new(k, 1, i)).collect();
        let m = merge(&a, &b);
        assert_eq!(m.len(), 7);
        assert!(is_sorted(&m));
    }

    #[test]
    fn merge_empty_sides() {
        let a: Vec<Elem> = vec![Elem::new(1, 0, 0)];
        assert_eq!(merge(&a, &[]), a);
        assert_eq!(merge(&[], &a), a);
        assert!(merge(&[], &[]).is_empty());
    }

    #[test]
    fn multiway_merge_matches_sort() {
        let runs: Vec<Vec<Elem>> = vec![
            vec![Elem::new(1, 0, 0), Elem::new(9, 0, 1)],
            vec![Elem::new(2, 1, 0), Elem::new(2, 1, 1), Elem::new(8, 1, 2)],
            vec![],
            vec![Elem::new(0, 2, 0)],
        ];
        let refs: Vec<&[Elem]> = runs.iter().map(|r| r.as_slice()).collect();
        let merged = multiway_merge(&refs);
        let mut flat: Vec<Elem> = runs.iter().flatten().copied().collect();
        flat.sort();
        assert_eq!(merged, flat);
    }

    /// The ping-pong cascade over a reused scratch matches the allocating
    /// wrapper (and a plain sort) for every run count — even/odd segment
    /// counts exercise the carried-segment path, and back-to-back calls
    /// exercise scratch reuse.
    #[test]
    fn multiway_merge_into_matches_for_all_run_counts() {
        let mut scratch = MergeScratch::default();
        let mut out = Vec::new();
        for k in 0..12usize {
            let runs: Vec<Vec<Elem>> = (0..k)
                .map(|r| {
                    let len = (r * 7 + 3) % 9; // includes empty runs
                    let mut v: Vec<Elem> = (0..len)
                        .map(|i| Elem::new(((i * 31 + r * 17) % 23) as u64, r, i))
                        .collect();
                    v.sort_unstable();
                    v
                })
                .collect();
            let refs: Vec<&[Elem]> = runs.iter().map(|r| r.as_slice()).collect();
            multiway_merge_into(&refs, &mut out, &mut scratch);
            assert_eq!(out, multiway_merge(&refs), "k = {k}");
            let mut flat: Vec<Elem> = runs.iter().flatten().copied().collect();
            flat.sort();
            assert_eq!(out, flat, "k = {k}");
        }
    }

    /// Fully-equal elements (same key *and* id — duplicated samples) keep
    /// the historical first-run-first order through the rewrite.
    #[test]
    fn multiway_merge_into_is_stable_on_equal_elements() {
        let a = vec![Elem::with_id(5, 1); 3];
        let b = vec![Elem::with_id(5, 1); 2];
        let c = vec![Elem::with_id(5, 1); 4];
        let refs: Vec<&[Elem]> = vec![&a, &b, &c];
        let mut out = Vec::new();
        multiway_merge_into(&refs, &mut out, &mut MergeScratch::default());
        assert_eq!(out.len(), 9);
        assert_eq!(out, multiway_merge(&refs));
    }

    /// The loser tree and the cascade agree bit for bit at every run
    /// count straddling the dispatch threshold — duplicate-heavy keys,
    /// interleaved empty runs, 1-element runs, and runs of fully equal
    /// elements (same key *and* id) all included, on warm scratches
    /// reused across calls.
    #[test]
    fn loser_tree_matches_cascade_bit_for_bit() {
        let mut tree_scratch = MergeScratch::default();
        let mut casc_scratch = MergeScratch::default();
        let (mut via_tree, mut via_casc) = (Vec::new(), Vec::new());
        for k in 0..40usize {
            let runs: Vec<Vec<Elem>> = (0..k)
                .map(|r| {
                    let len = (r * 13 + 5) % 11; // includes empty and 1-elem runs
                    let mut v: Vec<Elem> = (0..len)
                        .map(|i| {
                            // heavy duplication across runs: 5 distinct keys,
                            // 3 distinct ids — plenty of full (key, id) ties
                            Elem::with_id(((i * 7 + r) % 5) as u64, ((i + r) % 3) as u64)
                        })
                        .collect();
                    v.sort_unstable();
                    v
                })
                .collect();
            let refs: Vec<&[Elem]> = runs.iter().map(|r| r.as_slice()).collect();
            loser_tree_merge_into(&refs, &mut via_tree, &mut tree_scratch);
            cascade_merge_into(&refs, &mut via_casc, &mut casc_scratch);
            assert_eq!(via_tree, via_casc, "k = {k}");
            let mut flat: Vec<Elem> = runs.iter().flatten().copied().collect();
            flat.sort();
            assert_eq!(via_tree, flat, "k = {k} vs sort");
            // the dispatcher picks one of the two — also bit-identical
            let mut out = Vec::new();
            multiway_merge_into(&refs, &mut out, &mut MergeScratch::default());
            assert_eq!(out, via_tree, "k = {k} dispatch");
        }
    }

    /// Degenerate loser-tree inputs: no runs, one non-empty run among
    /// empties, and a non-power-of-two leaf count (padding leaves).
    #[test]
    fn loser_tree_degenerate_shapes() {
        let mut scratch = MergeScratch::default();
        let mut out = vec![Elem::with_id(9, 9)]; // must be cleared
        loser_tree_merge_into(&[], &mut out, &mut scratch);
        assert!(out.is_empty());
        let a: Vec<Elem> = (0..4).map(|i| Elem::with_id(i, 0)).collect();
        let refs: Vec<&[Elem]> = vec![&[], &a, &[]];
        loser_tree_merge_into(&refs, &mut out, &mut scratch);
        assert_eq!(out, a);
        // three live leaves → m = 4, one padding leaf in every match
        let b = vec![Elem::with_id(1, 1)];
        let c = vec![Elem::with_id(0, 7), Elem::with_id(2, 0)];
        let refs: Vec<&[Elem]> = vec![&a, &b, &c];
        loser_tree_merge_into(&refs, &mut out, &mut scratch);
        let mut flat: Vec<Elem> = refs.iter().copied().flatten().copied().collect();
        flat.sort();
        assert_eq!(out, flat);
    }
}
