//! Elements, keys, and the tie-breaking identity.
//!
//! The paper's elements are 64-bit values; robustness against duplicates is
//! obtained *implicitly* — RQuick splits duplicate runs locally (§VI), RFIS
//! tracks provenance buckets (App. F), RAMS tie-breaks with sample
//! positions (App. G). To let the *robust* code paths simulate unique keys,
//! every element carries an origin id `(pe, idx)` packed into a `u64`.
//! **Nonrobust variants never look at it** — they compare keys only, which
//! is exactly what makes them collapse on duplicate-heavy instances.

/// Sort key. The paper generates 64-bit elements with 32-bit key ranges;
/// we keep the full `u64` domain (generators mostly use `[0, 2^32)`).
pub type Key = u64;

/// One input element: key plus origin identity for explicit tie-breaking.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug, Hash)]
pub struct Elem {
    /// Primary sort key.
    pub key: Key,
    /// Unique origin id: `pe << 24-bit-index | idx` — see [`Elem::new`].
    pub id: u64,
}

/// Number of low bits of `id` reserved for the local index.
const IDX_BITS: u32 = 40;

impl Elem {
    /// Construct with the packed `(pe, idx)` origin id.
    #[inline]
    pub fn new(key: Key, pe: usize, idx: usize) -> Self {
        debug_assert!((idx as u64) < (1 << IDX_BITS));
        Self {
            key,
            id: ((pe as u64) << IDX_BITS) | idx as u64,
        }
    }

    /// Construct with an explicit id (used by generators with global ids).
    #[inline]
    pub fn with_id(key: Key, id: u64) -> Self {
        Self { key, id }
    }

    /// Origin PE encoded in the id.
    #[inline]
    pub fn origin_pe(&self) -> usize {
        (self.id >> IDX_BITS) as usize
    }

    /// Compare by key only — the *nonrobust* ordering.
    #[inline]
    pub fn key_cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key)
    }
}

/// Order-preserving u64 → i64 mapping (for the XLA kernels, which sort
/// signed 64-bit integers).
#[inline]
pub fn key_to_i64(k: Key) -> i64 {
    (k ^ (1u64 << 63)) as i64
}

/// Inverse of [`key_to_i64`].
#[inline]
pub fn key_from_i64(v: i64) -> Key {
    (v as u64) ^ (1u64 << 63)
}

/// Merge two sorted runs into a fresh sorted run (full `(key, id)` order).
pub fn merge(a: &[Elem], b: &[Elem]) -> Vec<Elem> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    merge_into(a, b, &mut out);
    out
}

/// Merge two sorted runs into `out` (cleared first). Branch-light two-finger
/// merge — the hot path of every hypercube exchange step.
pub fn merge_into(a: &[Elem], b: &[Elem], out: &mut Vec<Elem>) {
    out.clear();
    merge_append(a, b, out);
}

/// [`merge_into`] without the clear: appends the merged sequence to `out`.
/// The cascade passes of [`multiway_merge_into`] write consecutive merged
/// segments into one buffer through this.
fn merge_append(a: &[Elem], b: &[Elem], out: &mut Vec<Elem>) {
    out.reserve(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        // `<=` keeps the merge stable in (key, id) order.
        if a[i] <= b[j] {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
}

/// Reusable scratch for [`multiway_merge_into`]: the ping-pong partner
/// buffer plus the two segment-boundary tables. Every `Vec` keeps its
/// capacity across calls, so a warm scratch makes the k-way merge
/// allocation-free.
#[derive(Clone, Debug, Default)]
pub struct MergeScratch {
    tmp: Vec<Elem>,
    bounds: Vec<usize>,
    bounds_next: Vec<usize>,
}

/// k-way merge of sorted runs into `out` (cleared first), ping-ponging
/// between `out` and the scratch buffer: ⌈log k⌉ passes of the
/// branch-light two-finger merge with **O(total)** buffer space and zero
/// allocations once the scratch is warm — this replaced a cascade that
/// copied every run into fresh `Vec`s at every level.
///
/// The merge tree has exactly the shape of the historical implementation
/// (adjacent pairs of the non-empty runs, an unpaired last segment carried
/// verbatim to the next pass), so the output — bit for bit, including the
/// order of fully-equal elements — is unchanged.
pub fn multiway_merge_into(runs: &[&[Elem]], out: &mut Vec<Elem>, scratch: &mut MergeScratch) {
    out.clear();
    let total: usize = runs.iter().map(|r| r.len()).sum();
    out.reserve(total);
    let MergeScratch { tmp, bounds, bounds_next } = scratch;
    bounds.clear();
    bounds.push(0);
    // pass 0 reads straight from the input runs (no up-front copy): merge
    // adjacent non-empty pairs into `out`, recording segment boundaries
    {
        let mut it = runs.iter().filter(|r| !r.is_empty());
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => merge_append(a, b, out),
                None => out.extend_from_slice(a),
            }
            bounds.push(out.len());
        }
    }
    // cascade: merge adjacent segments, ping-ponging between the buffers
    while bounds.len() > 2 {
        tmp.clear();
        tmp.reserve(total);
        bounds_next.clear();
        bounds_next.push(0);
        let segs = bounds.len() - 1;
        let mut s = 0;
        while s < segs {
            if s + 1 < segs {
                // split_at so the two segment borrows and the write
                // target are provably disjoint
                let (a, rest) = out[bounds[s]..bounds[s + 2]].split_at(bounds[s + 1] - bounds[s]);
                merge_append(a, rest, tmp);
                s += 2;
            } else {
                tmp.extend_from_slice(&out[bounds[s]..bounds[s + 1]]);
                s += 1;
            }
            bounds_next.push(tmp.len());
        }
        std::mem::swap(out, tmp);
        std::mem::swap(bounds, bounds_next);
    }
}

/// k-way merge of sorted runs (used by gather-merge trees and RAMS data
/// receipt), allocating its result and scratch — convenience wrapper over
/// [`multiway_merge_into`], which hot paths call with pooled buffers.
pub fn multiway_merge(runs: &[&[Elem]]) -> Vec<Elem> {
    let mut out = Vec::new();
    let mut scratch = MergeScratch::default();
    multiway_merge_into(runs, &mut out, &mut scratch);
    out
}

/// `true` iff `v` is sorted in full `(key, id)` order.
pub fn is_sorted(v: &[Elem]) -> bool {
    v.windows(2).all(|w| w[0] <= w[1])
}

/// `true` iff `v` is sorted by key (ties in any order).
pub fn is_key_sorted(v: &[Elem]) -> bool {
    v.windows(2).all(|w| w[0].key <= w[1].key)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ord_is_key_then_id() {
        let a = Elem::with_id(5, 1);
        let b = Elem::with_id(5, 2);
        let c = Elem::with_id(6, 0);
        assert!(a < b && b < c);
    }

    #[test]
    fn origin_pe_roundtrip() {
        let e = Elem::new(0, 12345, 678);
        assert_eq!(e.origin_pe(), 12345);
        assert_eq!(e.id & ((1 << 40) - 1), 678);
    }

    #[test]
    fn key_i64_mapping_is_order_preserving() {
        let keys = [0u64, 1, u64::MAX / 2, u64::MAX / 2 + 1, u64::MAX];
        for w in keys.windows(2) {
            assert!(key_to_i64(w[0]) < key_to_i64(w[1]));
            assert_eq!(key_from_i64(key_to_i64(w[0])), w[0]);
        }
    }

    #[test]
    fn merge_preserves_order_and_content() {
        let a: Vec<Elem> = [1u64, 3, 5, 5].iter().enumerate().map(|(i, &k)| Elem::new(k, 0, i)).collect();
        let b: Vec<Elem> = [2u64, 5, 6].iter().enumerate().map(|(i, &k)| Elem::new(k, 1, i)).collect();
        let m = merge(&a, &b);
        assert_eq!(m.len(), 7);
        assert!(is_sorted(&m));
    }

    #[test]
    fn merge_empty_sides() {
        let a: Vec<Elem> = vec![Elem::new(1, 0, 0)];
        assert_eq!(merge(&a, &[]), a);
        assert_eq!(merge(&[], &a), a);
        assert!(merge(&[], &[]).is_empty());
    }

    #[test]
    fn multiway_merge_matches_sort() {
        let runs: Vec<Vec<Elem>> = vec![
            vec![Elem::new(1, 0, 0), Elem::new(9, 0, 1)],
            vec![Elem::new(2, 1, 0), Elem::new(2, 1, 1), Elem::new(8, 1, 2)],
            vec![],
            vec![Elem::new(0, 2, 0)],
        ];
        let refs: Vec<&[Elem]> = runs.iter().map(|r| r.as_slice()).collect();
        let merged = multiway_merge(&refs);
        let mut flat: Vec<Elem> = runs.iter().flatten().copied().collect();
        flat.sort();
        assert_eq!(merged, flat);
    }

    /// The ping-pong cascade over a reused scratch matches the allocating
    /// wrapper (and a plain sort) for every run count — even/odd segment
    /// counts exercise the carried-segment path, and back-to-back calls
    /// exercise scratch reuse.
    #[test]
    fn multiway_merge_into_matches_for_all_run_counts() {
        let mut scratch = MergeScratch::default();
        let mut out = Vec::new();
        for k in 0..12usize {
            let runs: Vec<Vec<Elem>> = (0..k)
                .map(|r| {
                    let len = (r * 7 + 3) % 9; // includes empty runs
                    let mut v: Vec<Elem> = (0..len)
                        .map(|i| Elem::new(((i * 31 + r * 17) % 23) as u64, r, i))
                        .collect();
                    v.sort_unstable();
                    v
                })
                .collect();
            let refs: Vec<&[Elem]> = runs.iter().map(|r| r.as_slice()).collect();
            multiway_merge_into(&refs, &mut out, &mut scratch);
            assert_eq!(out, multiway_merge(&refs), "k = {k}");
            let mut flat: Vec<Elem> = runs.iter().flatten().copied().collect();
            flat.sort();
            assert_eq!(out, flat, "k = {k}");
        }
    }

    /// Fully-equal elements (same key *and* id — duplicated samples) keep
    /// the historical first-run-first order through the rewrite.
    #[test]
    fn multiway_merge_into_is_stable_on_equal_elements() {
        let a = vec![Elem::with_id(5, 1); 3];
        let b = vec![Elem::with_id(5, 1); 2];
        let c = vec![Elem::with_id(5, 1); 4];
        let refs: Vec<&[Elem]> = vec![&a, &b, &c];
        let mut out = Vec::new();
        multiway_merge_into(&refs, &mut out, &mut MergeScratch::default());
        assert_eq!(out.len(), 9);
        assert_eq!(out, multiway_merge(&refs));
    }
}
