//! PJRT runtime: load the AOT-compiled HLO-text artifacts and run them on
//! the request path (Python is never involved at runtime).
//!
//! Everything that touches PJRT is gated behind the off-by-default `xla`
//! cargo feature — the default build of the crate is pure Rust and sorts
//! locally with [`crate::localsort::RustSort`]. Enabling `--features xla`
//! additionally requires the `xla` PJRT bindings crate as a dependency
//! (deliberately not declared in `Cargo.toml`; see README § "XLA backend
//! (optional)"). The artifact manifest format is parsed by always-compiled
//! pure-Rust code so it stays testable without PJRT.
//!
//! Pipeline per artifact: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//! HLO *text* is the interchange format: jax ≥ 0.5 emits 64-bit instruction
//! ids that xla_extension 0.5.1 rejects in proto form; the text parser
//! reassigns ids (see `python/compile/aot.py`).

use std::collections::HashMap;

/// Error from the artifact loader / PJRT executor. A plain message type so
/// the runtime needs no external error crate.
#[derive(Clone, Debug)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Result shorthand used throughout the runtime.
pub type Result<T> = std::result::Result<T, RuntimeError>;

macro_rules! rterr {
    ($($t:tt)*) => { RuntimeError(format!($($t)*)) };
}

/// One entry of `artifacts/manifest.txt` (`name kind batch n [splitters]`).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub kind: String,
    pub batch: usize,
    pub n: usize,
    pub splitters: usize,
}

/// Parse the whitespace-separated manifest (written by `compile/aot.py`).
/// Blank lines and `#` comments are skipped; a line is
/// `name kind batch n [splitters]`.
pub fn parse_manifest(text: &str) -> Result<HashMap<String, ArtifactMeta>> {
    let mut out = HashMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() < 4 {
            return Err(rterr!("manifest line {} malformed: {line:?}", lineno + 1));
        }
        let field = |s: &str, what: &str| -> Result<usize> {
            s.parse()
                .map_err(|_| rterr!("manifest line {}: bad {what} {s:?}", lineno + 1))
        };
        out.insert(
            f[0].to_string(),
            ArtifactMeta {
                kind: f[1].to_string(),
                batch: field(f[2], "batch")?,
                n: field(f[3], "n")?,
                splitters: match f.get(4) {
                    Some(s) => field(s, "splitters")?,
                    None => 0,
                },
            },
        );
    }
    Ok(out)
}

#[cfg(feature = "xla")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use super::{parse_manifest, ArtifactMeta, Result, RuntimeError};
    use crate::elements::{key_from_i64, key_to_i64, Elem};
    use crate::localsort::SortBackend;

    /// Lazily-compiled store of PJRT executables keyed by artifact name.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        pub manifest: HashMap<String, ArtifactMeta>,
        execs: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Runtime {
        /// Open the artifact directory (built by `make artifacts`).
        pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
            let dir = dir.as_ref().to_path_buf();
            let manifest_path = dir.join("manifest.txt");
            let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
                rterr!("reading {manifest_path:?} — run `make artifacts`: {e}")
            })?;
            let manifest = parse_manifest(&text)?;
            let client =
                xla::PjRtClient::cpu().map_err(|e| rterr!("PJRT cpu client: {e:?}"))?;
            Ok(Self { client, dir, manifest, execs: HashMap::new() })
        }

        /// Default artifact location: `$RMPS_ARTIFACTS` or `./artifacts`.
        pub fn from_env() -> Result<Self> {
            let dir = std::env::var("RMPS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
            Self::new(dir)
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (once) and fetch an executable by artifact name.
        pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.execs.contains_key(name) {
                let path = self.dir.join(format!("{name}.hlo.txt"));
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| rterr!("non-utf8 path"))?,
                )
                .map_err(|e| rterr!("parsing {path:?}: {e:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| rterr!("compiling {name}: {e:?}"))?;
                self.execs.insert(name.to_string(), exe);
            }
            Ok(&self.execs[name])
        }

        /// Execute the `sort_pairs` artifact `name` on a full (B, N) batch of
        /// i64 keys/ids. Returns sorted (keys, ids) row-major.
        pub fn run_sort_pairs(
            &mut self,
            name: &str,
            b: usize,
            n: usize,
            keys: &[i64],
            ids: &[i64],
        ) -> Result<(Vec<i64>, Vec<i64>)> {
            debug_assert_eq!(keys.len(), b * n);
            let kl = xla::Literal::vec1(keys)
                .reshape(&[b as i64, n as i64])
                .map_err(|e| rterr!("{e:?}"))?;
            let il = xla::Literal::vec1(ids)
                .reshape(&[b as i64, n as i64])
                .map_err(|e| rterr!("{e:?}"))?;
            let exe = self.executable(name)?;
            let result = exe
                .execute::<xla::Literal>(&[kl, il])
                .map_err(|e| rterr!("executing {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| rterr!("{e:?}"))?;
            let (ok, oi) = result.to_tuple2().map_err(|e| rterr!("{e:?}"))?;
            Ok((
                ok.to_vec::<i64>().map_err(|e| rterr!("{e:?}"))?,
                oi.to_vec::<i64>().map_err(|e| rterr!("{e:?}"))?,
            ))
        }

        /// Execute a plain `sort` artifact on a (B, N) batch of i64 keys.
        pub fn run_sort(
            &mut self,
            name: &str,
            b: usize,
            n: usize,
            keys: &[i64],
        ) -> Result<Vec<i64>> {
            debug_assert_eq!(keys.len(), b * n);
            let kl = xla::Literal::vec1(keys)
                .reshape(&[b as i64, n as i64])
                .map_err(|e| rterr!("{e:?}"))?;
            let exe = self.executable(name)?;
            let result = exe
                .execute::<xla::Literal>(&[kl])
                .map_err(|e| rterr!("executing {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| rterr!("{e:?}"))?;
            let out = result.to_tuple1().map_err(|e| rterr!("{e:?}"))?;
            out.to_vec::<i64>().map_err(|e| rterr!("{e:?}"))
        }

        /// Execute a `classify` artifact: bucket index per element.
        pub fn run_classify(
            &mut self,
            name: &str,
            b: usize,
            n: usize,
            keys: &[i64],
            tree: &[i64],
        ) -> Result<Vec<i32>> {
            let kl = xla::Literal::vec1(keys)
                .reshape(&[b as i64, n as i64])
                .map_err(|e| rterr!("{e:?}"))?;
            let tl = xla::Literal::vec1(tree);
            let exe = self.executable(name)?;
            let result = exe
                .execute::<xla::Literal>(&[kl, tl])
                .map_err(|e| rterr!("executing {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| rterr!("{e:?}"))?;
            let out = result.to_tuple1().map_err(|e| rterr!("{e:?}"))?;
            out.to_vec::<i32>().map_err(|e| rterr!("{e:?}"))
        }
    }

    /// Padding sentinel: sorts after every real (key, id) pair.
    const PAD_KEY: i64 = i64::MAX;
    const PAD_ID: i64 = i64::MAX;

    /// The PJRT-backed batched local-sort backend: groups fragments by padded
    /// row size, fills (B, N) batches, and launches the Pallas bitonic-network
    /// executable once per batch. Fragments longer than the largest artifact
    /// row fall back to pdqsort.
    pub struct XlaSort {
        rt: Runtime,
        /// `sort_pairs` artifacts as (row_n, batch, name), ascending by n.
        sizes: Vec<(usize, usize, String)>,
        /// number of PJRT launches (batching effectiveness, for §Perf).
        pub exec_calls: usize,
    }

    impl XlaSort {
        pub fn new(rt: Runtime) -> Result<Self> {
            let mut sizes: Vec<(usize, usize, String)> = rt
                .manifest
                .iter()
                .filter(|(_, m)| m.kind == "sort_pairs")
                .map(|(name, m)| (m.n, m.batch, name.clone()))
                .collect();
            if sizes.is_empty() {
                return Err(rterr!(
                    "no sort_pairs artifacts in manifest — run `make artifacts`"
                ));
            }
            sizes.sort();
            Ok(Self { rt, sizes, exec_calls: 0 })
        }

        pub fn from_env() -> Result<Self> {
            Self::new(Runtime::from_env()?)
        }

        /// Smallest artifact row size that fits `len`, if any.
        fn pick(&self, len: usize) -> Option<(usize, usize, String)> {
            self.sizes.iter().find(|(n, _, _)| *n >= len).cloned()
        }

        fn sort_group(&mut self, group: &mut [&mut Vec<Elem>], n: usize, b: usize, name: &str) {
            for chunk in group.chunks_mut(b) {
                let mut keys = vec![PAD_KEY; b * n];
                let mut ids = vec![PAD_ID; b * n];
                for (r, run) in chunk.iter().enumerate() {
                    for (c, e) in run.iter().enumerate() {
                        keys[r * n + c] = key_to_i64(e.key);
                        ids[r * n + c] = e.id as i64;
                    }
                }
                let (ok, oi) = self
                    .rt
                    .run_sort_pairs(name, b, n, &keys, &ids)
                    .expect("PJRT sort_pairs execution failed");
                self.exec_calls += 1;
                for (r, run) in chunk.iter_mut().enumerate() {
                    let len = run.len();
                    run.clear();
                    for c in 0..len {
                        let k = key_from_i64(ok[r * n + c]);
                        let id = oi[r * n + c] as u64;
                        run.push(Elem::with_id(k, id));
                    }
                }
            }
        }
    }

    impl SortBackend for XlaSort {
        fn sort_runs(&mut self, runs: &mut [&mut Vec<Elem>]) {
            // group run indices by target artifact
            let mut groups: HashMap<String, (usize, usize, Vec<usize>)> = HashMap::new();
            let mut fallback: Vec<usize> = Vec::new();
            for (i, run) in runs.iter().enumerate() {
                if run.len() <= 1 {
                    continue;
                }
                match self.pick(run.len()) {
                    Some((n, b, name)) => {
                        groups.entry(name).or_insert_with(|| (n, b, Vec::new())).2.push(i);
                    }
                    None => fallback.push(i),
                }
            }
            let mut names: Vec<String> = groups.keys().cloned().collect();
            names.sort();
            for name in names {
                let (n, b, idxs) = groups.remove(&name).unwrap();
                // move the runs out, sort the batch, move them back — avoids
                // aliasing &mut into `runs` at multiple indices
                let mut taken: Vec<(usize, Vec<Elem>)> =
                    idxs.iter().map(|&i| (i, std::mem::take(runs[i]))).collect();
                {
                    let mut refs: Vec<&mut Vec<Elem>> =
                        taken.iter_mut().map(|(_, v)| v).collect();
                    self.sort_group(&mut refs, n, b, &name);
                }
                for (i, v) in taken {
                    *runs[i] = v;
                }
            }
            for i in fallback {
                runs[i].sort_unstable();
            }
        }

        fn name(&self) -> &'static str {
            "xla-pallas-bitonic"
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::{Runtime, XlaSort};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_entries_comments_and_blanks() {
        let text = "\
# artifact manifest (name kind batch n [splitters])
sort_pairs_i64_64x256 sort_pairs 64 256

classify_i64_64x256_s63 classify 64 256 63
";
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.len(), 2);
        let s = &m["sort_pairs_i64_64x256"];
        assert_eq!(
            (s.kind.as_str(), s.batch, s.n, s.splitters),
            ("sort_pairs", 64, 256, 0)
        );
        assert_eq!(m["classify_i64_64x256_s63"].splitters, 63);
    }

    #[test]
    fn manifest_rejects_malformed_lines() {
        let short = parse_manifest("name kind 64");
        assert!(short.is_err());
        assert!(short.unwrap_err().0.contains("line 1"));
        let bad_num = parse_manifest("ok sort_pairs 64 256\nbad sort_pairs 64 nan");
        assert!(bad_num.is_err());
        assert!(bad_num.unwrap_err().0.contains("line 2"));
        // a *present* but unparseable splitters field is an error too
        assert!(parse_manifest("c classify 64 256 s63").is_err());
    }

    #[test]
    fn manifest_empty_is_ok() {
        assert!(parse_manifest("").unwrap().is_empty());
        assert!(parse_manifest("# only a comment\n").unwrap().is_empty());
    }
}
