//! Dependency-free parallel execution shared by the experiment driver and
//! the intra-run PE tasks.
//!
//! The figure sweeps are embarrassingly parallel across (algorithm,
//! distribution, n/p) cells, and every superstep of a single run is
//! embarrassingly parallel across PEs, but the build environment is
//! offline, so no rayon: this is a scoped-thread self-scheduling pool.
//! Workers pull the next job index from a shared atomic counter (the
//! classic work-stealing degenerate case where the "deque" is a single
//! global index — optimal here because every job is coarse), so long jobs
//! never leave the other workers idle behind a static partition.
//!
//! **One pool, two levels.** Cell-level fan-out (`--jobs`, the experiment
//! drivers) and PE-level fan-out (`--pe-jobs`, [`crate::sim::Machine::par_pes`])
//! share a single process-wide worker budget sized to the host's available
//! parallelism. Every [`parallel_map`] call acquires worker tokens from
//! that budget before spawning and returns them when its scope ends; a
//! call that finds the budget exhausted (e.g. a PE-task round nested
//! inside a cell worker that already holds all tokens) degrades to running
//! inline on the caller's thread. This is the work-depth guard: when
//! fig-grids and PE tasks nest, the total number of live workers stays
//! bounded by the host core count instead of multiplying.
//!
//! The budget also caps a *top-level* `--jobs` request above the core
//! count — a deliberate behavior change from the PR 2 driver, which
//! spawned exactly N workers: every job here is CPU-bound simulation, so
//! oversubscribing cores only adds scheduler churn. Results are identical
//! either way; only the worker count changes.
//!
//! Determinism: results are returned **in index order** regardless of which
//! worker computed what or in which interleaving, so `jobs = 1` and
//! `jobs = N` produce byte-identical experiment tables as long as each job
//! is itself a pure function of its index (every `run_cell` is: all
//! randomness derives from per-config seeds).

use std::sync::atomic::{AtomicIsize, AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Number of worker threads to use by default: the host's available
/// parallelism (the `--jobs` CLI default), or 1 if it cannot be queried.
pub fn available_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

// ---- the shared worker budget (work-depth guard) -----------------------

/// Tokens remaining in the process-wide worker budget. Initialized to the
/// host's available parallelism; every spawned worker holds one token for
/// its lifetime.
fn budget() -> &'static AtomicIsize {
    static BUDGET: OnceLock<AtomicIsize> = OnceLock::new();
    BUDGET.get_or_init(|| AtomicIsize::new(available_jobs() as isize))
}

/// RAII worker-token grant: `n` tokens taken from the shared budget,
/// returned on drop (panic-safe — a propagating worker panic still
/// releases them when the scope unwinds).
struct Tokens {
    n: usize,
}

impl Tokens {
    /// Take up to `want` tokens (possibly zero when the budget is
    /// exhausted by outer parallel levels).
    fn acquire(want: usize) -> Tokens {
        let want = want as isize;
        let prev = budget().fetch_sub(want, Ordering::AcqRel);
        let got = prev.clamp(0, want);
        let refund = want - got;
        if refund > 0 {
            budget().fetch_add(refund, Ordering::AcqRel);
        }
        Tokens { n: got as usize }
    }
}

impl Drop for Tokens {
    fn drop(&mut self) {
        if self.n > 0 {
            budget().fetch_add(self.n as isize, Ordering::AcqRel);
        }
    }
}

// ---- pe-jobs configuration ---------------------------------------------

/// Process-wide `--pe-jobs` override; 0 = unset.
static PE_JOBS_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide default for intra-run PE-task parallelism (the
/// CLI `--pe-jobs` flag). Takes precedence over the `RMPS_PE_JOBS`
/// environment variable; `0` clears the override and restores the
/// env/all-cores default. Affects host scheduling only — simulation
/// results are bit-identical for every value.
pub fn set_pe_jobs(jobs: usize) {
    PE_JOBS_OVERRIDE.store(jobs, Ordering::Relaxed);
}

/// The default intra-run PE-task parallelism a new
/// [`crate::sim::Machine`] starts with: the [`set_pe_jobs`] override if
/// one was given, else `RMPS_PE_JOBS`, else the host's available
/// parallelism.
pub fn default_pe_jobs() -> usize {
    let over = PE_JOBS_OVERRIDE.load(Ordering::Relaxed);
    if over > 0 {
        return over;
    }
    std::env::var("RMPS_PE_JOBS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(available_jobs)
}

// ---- the pool ----------------------------------------------------------

/// Map `f` over `0..n` on up to `jobs` scoped worker threads, returning the
/// results in index order.
///
/// `jobs` is clamped to `[1, n]` and then to the tokens left in the shared
/// worker budget (see the module docs); `jobs <= 1` (or `n <= 1`, or an
/// exhausted budget) runs inline on the caller's thread with no pool
/// overhead, so the serial path is exactly the pre-pool code path. A panic
/// in any job is propagated to the caller with its original payload once
/// the remaining workers have drained.
pub fn parallel_map<R: Send>(jobs: usize, n: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
    let jobs = jobs.clamp(1, n.max(1));
    if jobs <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let tokens = Tokens::acquire(jobs);
    let workers = tokens.n;
    if workers <= 1 {
        // budget exhausted (or down to one token — a single worker plus an
        // idle caller is strictly worse than inline)
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let next = &next;
    let f = &f;
    let mut slots: Vec<Option<R>> = Vec::new();
    slots.resize_with(n, || None);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(move || {
                    let mut done: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        done.push((i, f(i)));
                    }
                    done
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(done) => {
                    for (i, r) in done {
                        slots[i] = Some(r);
                    }
                }
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    drop(tokens);
    slots.into_iter().map(|r| r.expect("pool covered every index")).collect()
}

/// Shared view of a `&mut [T]` for **index-disjoint** parallel writes: the
/// self-scheduling counter in [`parallel_map`] hands out each index exactly
/// once, so the `&mut T` references produced through this pointer are
/// never aliased.
///
/// Crate-internal building block for the `Machine` PE-task scheduler and
/// the exchange's parallel inbox materialization — every use site states
/// its disjointness argument at the `unsafe` block.
pub(crate) struct SliceCells<T> {
    ptr: *mut T,
    len: usize,
}

unsafe impl<T: Send> Sync for SliceCells<T> {}
unsafe impl<T: Send> Send for SliceCells<T> {}

impl<T> SliceCells<T> {
    pub(crate) fn new(slice: &mut [T]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len() }
    }

    #[allow(dead_code)]
    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// # Safety
    /// The caller must guarantee no two live `&mut T` to the same index
    /// (in [`parallel_map`] bodies: each index is claimed exactly once by
    /// the shared atomic counter).
    // the &self → &mut T shape is this type's entire point: disjointness
    // is the documented contract of this unsafe fn, not derivable by the
    // borrow checker
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub(crate) unsafe fn get_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_in_index_order() {
        for jobs in [1, 2, 3, 8, 64] {
            let out = parallel_map(jobs, 100, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>(), "jobs={jobs}");
        }
    }

    #[test]
    fn matches_serial_for_uneven_work() {
        // skewed job sizes exercise the self-scheduling (a static split
        // would also pass, but with idle workers)
        let work = |i: usize| -> u64 {
            let reps = if i % 7 == 0 { 10_000 } else { 10 };
            (0..reps).fold(i as u64, |a, b| a.wrapping_mul(31).wrapping_add(b))
        };
        let serial: Vec<u64> = (0..64).map(work).collect();
        assert_eq!(parallel_map(4, 64, work), serial);
    }

    #[test]
    fn degenerate_sizes() {
        assert_eq!(parallel_map(4, 0, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(4, 1, |i| i + 1), vec![1]);
        assert_eq!(parallel_map(0, 3, |i| i), vec![0, 1, 2]); // jobs clamped to >= 1
        assert_eq!(parallel_map(100, 3, |i| i), vec![0, 1, 2]); // jobs clamped to <= n
    }

    #[test]
    fn worker_panic_propagates() {
        let caught = std::panic::catch_unwind(|| {
            parallel_map(4, 16, |i| {
                if i == 5 {
                    panic!("job 5 failed");
                }
                i
            })
        });
        assert!(caught.is_err());
    }

    #[test]
    fn worker_panic_returns_tokens() {
        // after a panicking round the budget must be whole again, or every
        // later call would silently run inline
        for _ in 0..3 {
            let _ = std::panic::catch_unwind(|| {
                parallel_map(4, 16, |i| {
                    if i == 0 {
                        panic!("boom");
                    }
                    i
                })
            });
        }
        assert_eq!(parallel_map(4, 32, |i| i), (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn nested_levels_share_the_budget() {
        // outer cells × inner PE-style maps: correctness must hold whether
        // the inner level got worker tokens or degraded to inline
        let out = parallel_map(4, 8, |cell| {
            let inner = parallel_map(4, 16, move |pe| (cell * 100 + pe) as u64);
            inner.iter().sum::<u64>()
        });
        let expect: Vec<u64> =
            (0..8).map(|c| (0..16).map(|p| (c * 100 + p) as u64).sum()).collect();
        assert_eq!(out, expect);
    }

    /// The disjoint-index write primitive behind the PE-task scheduler
    /// and the parallel inbox materialization: every index mutated
    /// exactly once, in any worker interleaving.
    #[test]
    fn slice_cells_disjoint_parallel_writes() {
        for jobs in [1, 3, 8] {
            let mut items: Vec<u64> = (0..50).collect();
            let cells = SliceCells::new(&mut items);
            let doubled: Vec<(u64, u64)> = parallel_map(jobs, cells.len(), |i| {
                // SAFETY: parallel_map claims each index exactly once.
                let v = unsafe { cells.get_mut(i) };
                *v *= 2;
                (i as u64, *v)
            });
            assert_eq!(items, (0..50).map(|i| i * 2).collect::<Vec<u64>>(), "jobs={jobs}");
            for (i, (idx, val)) in doubled.iter().enumerate() {
                assert_eq!(*idx, i as u64);
                assert_eq!(*val, items[i]);
            }
        }
    }

    #[test]
    fn available_jobs_is_positive() {
        assert!(available_jobs() >= 1);
    }

    #[test]
    fn pe_jobs_override_round_trips_and_clears() {
        // the override is process-global; every value keeps results
        // identical, so flipping it here cannot disturb other tests —
        // but it MUST be cleared afterwards, or this test would silently
        // defeat an RMPS_PE_JOBS value set for the whole suite run
        set_pe_jobs(3);
        assert_eq!(default_pe_jobs(), 3);
        set_pe_jobs(0); // clear: back to env / all-cores
        let restored = default_pe_jobs();
        assert!(restored >= 1);
    }
}
