//! Dependency-free parallel execution for the experiment driver.
//!
//! The figure sweeps are embarrassingly parallel across (algorithm,
//! distribution, n/p) cells, but the build environment is offline, so no
//! rayon: this is a scoped-thread self-scheduling pool. Workers pull the
//! next job index from a shared atomic counter (the classic work-stealing
//! degenerate case where the "deque" is a single global index — optimal
//! here because every job is coarse), so long cells never leave the other
//! workers idle behind a static partition.
//!
//! Determinism: results are returned **in index order** regardless of which
//! worker computed what or in which interleaving, so `jobs = 1` and
//! `jobs = N` produce byte-identical experiment tables as long as each job
//! is itself a pure function of its index (every `run_cell` is: all
//! randomness derives from per-config seeds).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default: the host's available
/// parallelism (the `--jobs` CLI default), or 1 if it cannot be queried.
pub fn available_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `0..n` on up to `jobs` scoped worker threads, returning the
/// results in index order.
///
/// `jobs` is clamped to `[1, n]`; `jobs <= 1` (or `n <= 1`) runs inline on
/// the caller's thread with no pool overhead, so the serial path is exactly
/// the pre-pool code path. A panic in any job is propagated to the caller
/// with its original payload once the remaining workers have drained.
pub fn parallel_map<R: Send>(jobs: usize, n: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
    let jobs = jobs.clamp(1, n.max(1));
    if jobs <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let next = &next;
    let f = &f;
    let mut slots: Vec<Option<R>> = Vec::new();
    slots.resize_with(n, || None);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..jobs)
            .map(|_| {
                s.spawn(move || {
                    let mut done: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        done.push((i, f(i)));
                    }
                    done
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(done) => {
                    for (i, r) in done {
                        slots[i] = Some(r);
                    }
                }
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    slots.into_iter().map(|r| r.expect("pool covered every index")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_in_index_order() {
        for jobs in [1, 2, 3, 8, 64] {
            let out = parallel_map(jobs, 100, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>(), "jobs={jobs}");
        }
    }

    #[test]
    fn matches_serial_for_uneven_work() {
        // skewed job sizes exercise the self-scheduling (a static split
        // would also pass, but with idle workers)
        let work = |i: usize| -> u64 {
            let reps = if i % 7 == 0 { 10_000 } else { 10 };
            (0..reps).fold(i as u64, |a, b| a.wrapping_mul(31).wrapping_add(b))
        };
        let serial: Vec<u64> = (0..64).map(work).collect();
        assert_eq!(parallel_map(4, 64, work), serial);
    }

    #[test]
    fn degenerate_sizes() {
        assert_eq!(parallel_map(4, 0, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(4, 1, |i| i + 1), vec![1]);
        assert_eq!(parallel_map(0, 3, |i| i), vec![0, 1, 2]); // jobs clamped to >= 1
        assert_eq!(parallel_map(100, 3, |i| i), vec![0, 1, 2]); // jobs clamped to <= n
    }

    #[test]
    fn worker_panic_propagates() {
        let caught = std::panic::catch_unwind(|| {
            parallel_map(4, 16, |i| {
                if i == 5 {
                    panic!("job 5 failed");
                }
                i
            })
        });
        assert!(caught.is_err());
    }

    #[test]
    fn available_jobs_is_positive() {
        assert!(available_jobs() >= 1);
    }
}
