//! Dependency-free parallel execution shared by the experiment driver and
//! the intra-run PE tasks.
//!
//! The figure sweeps are embarrassingly parallel across (algorithm,
//! distribution, n/p) cells, and every superstep of a single run is
//! embarrassingly parallel across PEs, but the build environment is
//! offline, so no rayon: this is a **persistent** self-scheduling worker
//! pool. Long-lived `std::thread` workers are started lazily (at most
//! [`available_jobs`] of them, ever) and **parked** on a Condvar between
//! rounds, so a [`parallel_map`] round costs a wake/park handshake instead
//! of a thread spawn/join — the difference that matters for many-small-round
//! algorithms (bitonic's O(log²p) compare-split rounds, the AMS family's
//! per-level exchanges), which used to pay spawn latency once per superstep.
//!
//! **Wake/park protocol.** A round is published under the pool mutex as a
//! job-index counter plus an erased pointer to the caller's closure;
//! parked workers are notified, join the round (up to the helper count the
//! caller's worker tokens allow), and claim work until the counter is
//! exhausted. The **caller participates too** — it claims chunks like any
//! worker instead of blocking in a join — and returns only after every
//! helper has left the round, which is what makes lending stack-borrowed
//! closures to `'static` worker threads sound (see `Pool::run`).
//!
//! **Chunked self-scheduling.** Workers claim index *batches* from the
//! shared counter when the round is large (`chunk_for`): giant-p PE
//! rounds (262 144 tasks and beyond) would otherwise serialize on the
//! atomic counter, while coarse rounds (figure cells, modest-p supersteps)
//! keep single-index claiming for best load balance — a long job never
//! strands work behind a static partition either way.
//!
//! **One pool, two levels.** Cell-level fan-out (`--jobs`, the experiment
//! drivers) and PE-level fan-out (`--pe-jobs`, [`crate::sim::Machine::par_pes`])
//! share a single process-wide worker budget sized to the host's available
//! parallelism. Every [`parallel_map`] call acquires worker tokens from
//! that budget (a lock-free compare-exchange loop — the budget is never
//! observed negative, even mid-acquire) before engaging the pool and
//! returns them when the round ends; a call that finds the budget
//! exhausted (e.g. a PE-task round nested inside a cell worker that
//! already holds all tokens) degrades to running inline on the caller's
//! thread. This is the work-depth guard: when fig-grids and PE tasks
//! nest, the total number of live computing threads stays bounded by the
//! host core count instead of multiplying.
//!
//! The budget also caps a *top-level* `--jobs` request above the core
//! count — every job here is CPU-bound simulation, so oversubscribing
//! cores only adds scheduler churn. Results are identical either way;
//! only the worker count changes.
//!
//! Determinism: results are written **by index** into pre-sized slots
//! (through `SliceCells` — no per-worker staging, no post-join copy)
//! and returned in index order regardless of which worker computed what
//! or in which interleaving, so `jobs = 1` and `jobs = N` produce
//! byte-identical experiment tables as long as each job is itself a pure
//! function of its index (every `run_cell` is: all randomness derives
//! from per-config seeds). A panic in any job is re-raised on the caller
//! with its original payload after the round's workers have left it; the
//! panicking participant stops claiming, the rest drain the counter.

use std::any::Any;
use std::sync::atomic::{AtomicIsize, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Number of worker threads to use by default: the host's available
/// parallelism (the `--jobs` CLI default), or 1 if it cannot be queried.
pub fn available_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

// ---- the shared worker budget (work-depth guard) -----------------------

/// Tokens remaining in the process-wide worker budget. Initialized to the
/// host's available parallelism; every computing participant of a round
/// (helpers and the caller alike) holds one token while the round runs.
fn budget() -> &'static AtomicIsize {
    static BUDGET: OnceLock<AtomicIsize> = OnceLock::new();
    BUDGET.get_or_init(|| AtomicIsize::new(available_jobs() as isize))
}

/// RAII worker-token grant: `n` tokens taken from the shared budget,
/// returned on drop (panic-safe — a propagating round panic still
/// releases them when the caller's frame unwinds).
struct Tokens {
    n: usize,
}

impl Tokens {
    /// Take up to `want` tokens (possibly zero when the budget is
    /// exhausted by outer parallel levels).
    ///
    /// Lock-free claim via compare-exchange: a grant only ever subtracts
    /// what the witnessed balance covers, so the budget is **never
    /// negative, at any instant** — unlike a fetch-sub-then-refund
    /// scheme, where two racing acquires can both witness a positive
    /// balance, overshoot, and leave the budget transiently negative
    /// until the refunds settle. The invariant is stress-asserted in
    /// `token_budget_never_negative_under_contention`.
    fn acquire(want: usize) -> Tokens {
        let want = want as isize;
        let b = budget();
        let mut cur = b.load(Ordering::Relaxed);
        loop {
            let got = cur.clamp(0, want);
            if got == 0 {
                return Tokens { n: 0 };
            }
            match b.compare_exchange_weak(cur, cur - got, Ordering::AcqRel, Ordering::Relaxed) {
                Ok(_) => return Tokens { n: got as usize },
                Err(now) => cur = now,
            }
        }
    }
}

impl Drop for Tokens {
    fn drop(&mut self) {
        if self.n > 0 {
            budget().fetch_add(self.n as isize, Ordering::AcqRel);
        }
    }
}

// ---- job-level admission (the serve front-end) -------------------------

/// RAII grant of **job-level** worker tokens — the third parallelism
/// level (cell × PE × job) on the same process-wide budget, used by the
/// [`crate::serve`] front-end for admission control. Each admitted
/// concurrent job holds one token for as long as it is being served, so
/// jobs, figure-cell fan-out, and PE-task rounds can never oversubscribe
/// the host together; inner levels that find the budget drained degrade
/// to inline exactly as they do today.
///
/// Dropping the grant returns every token (panic-safe via [`Tokens`]).
pub struct JobGrant {
    tokens: Tokens,
}

impl JobGrant {
    /// Number of tokens actually granted (possibly fewer than requested,
    /// possibly zero when outer levels hold the whole budget — the caller
    /// then serves inline on its own thread, which needs no token: that
    /// thread is already accounted to whatever round it is nested in, or
    /// is the process's root thread).
    pub fn granted(&self) -> usize {
        self.tokens.n
    }
}

/// Take up to `want` job-level worker tokens from the shared budget.
/// `want` is clamped to [`available_jobs`] first — a service asking for
/// more concurrent jobs than the host has cores would only add scheduler
/// churn, exactly like an oversized `--jobs` (and the clamp keeps the
/// `usize → isize` conversion inside [`Tokens::acquire`] safe for any
/// caller-supplied value).
pub fn acquire_job_workers(want: usize) -> JobGrant {
    JobGrant { tokens: Tokens::acquire(want.min(available_jobs())) }
}

/// Snapshot of the tokens currently unclaimed in the process-wide worker
/// budget. Diagnostics/tests only: the serve soak test samples this
/// during a concurrent drain and asserts it is **never negative** — the
/// budget-never-oversubscribed invariant across all three levels.
pub fn budget_remaining() -> isize {
    budget().load(Ordering::Relaxed)
}

// ---- pe-jobs configuration ---------------------------------------------

/// Process-wide `--pe-jobs` override; 0 = unset.
static PE_JOBS_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide default for intra-run PE-task parallelism (the
/// CLI `--pe-jobs` flag). Takes precedence over the `RMPS_PE_JOBS`
/// environment variable; `0` clears the override and restores the
/// env/all-cores default. Affects host scheduling only — simulation
/// results are bit-identical for every value.
pub fn set_pe_jobs(jobs: usize) {
    PE_JOBS_OVERRIDE.store(jobs, Ordering::Relaxed);
}

/// The default intra-run PE-task parallelism a new
/// [`crate::sim::Machine`] starts with: the [`set_pe_jobs`] override if
/// one was given, else `RMPS_PE_JOBS`, else the host's available
/// parallelism.
pub fn default_pe_jobs() -> usize {
    let over = PE_JOBS_OVERRIDE.load(Ordering::Relaxed);
    if over > 0 {
        return over;
    }
    std::env::var("RMPS_PE_JOBS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(available_jobs)
}

// ---- the persistent pool -----------------------------------------------

/// Erased pointer to a round's chunk runner (`Fn(lo, hi)` over job
/// indices), callable from worker threads via a monomorphized trampoline.
///
/// # Safety
/// The pointee lives on the submitting caller's stack. [`Pool::run`] does
/// not return until the round is unreachable by every worker (removed
/// from the pending list **and** zero active helpers, both witnessed
/// under the pool mutex), which bounds every dereference by the pointee's
/// lifetime.
struct TaskRef {
    data: *const (),
    call: unsafe fn(*const (), usize, usize),
}

unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

impl TaskRef {
    fn new<F: Fn(usize, usize) + Sync>(f: &F) -> Self {
        unsafe fn trampoline<F: Fn(usize, usize)>(data: *const (), lo: usize, hi: usize) {
            (*data.cast::<F>())(lo, hi)
        }
        Self { data: (f as *const F).cast(), call: trampoline::<F> }
    }
}

/// One published round: a shared claim counter over `n` job indices plus
/// the erased chunk runner. Workers that joined the round claim
/// `chunk`-sized index batches until the counter is exhausted.
struct Round {
    task: TaskRef,
    n: usize,
    chunk: usize,
    /// Next unclaimed job index (may overshoot `n` by up to one chunk per
    /// participant — claims at or past `n` are empty).
    next: AtomicUsize,
    /// Helpers currently inside the round (joined, not yet left). Only
    /// mutated under the pool mutex; the done-Condvar handshake relies on
    /// that.
    active: AtomicUsize,
    /// First panic payload raised by any participant, re-thrown on the
    /// caller once the round has quiesced.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl Round {
    /// Claim and run chunks until the counter is exhausted. Never
    /// unwinds: a panicking job stops *this* participant's claiming and
    /// parks its payload for the caller; other participants keep
    /// draining the counter (the pre-pool behavior, where a panicking
    /// scoped worker died and the rest finished the remaining jobs).
    fn run_chunks(&self) {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
            let lo = self.next.fetch_add(self.chunk, Ordering::Relaxed);
            if lo >= self.n {
                break;
            }
            let hi = (lo + self.chunk).min(self.n);
            // SAFETY: see TaskRef — the pointee outlives the round.
            unsafe { (self.task.call)(self.task.data, lo, hi) };
        }));
        if let Err(payload) = result {
            let mut slot = self.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
    }
}

/// A round waiting for helpers, still listed in [`PoolQueue::pending`].
struct PendingRound {
    round: Arc<Round>,
    /// Helper slots not yet claimed by a worker; the entry is delisted
    /// when this reaches zero (or the round's counter is exhausted).
    helpers_wanted: usize,
}

/// Mutex-guarded pool state: the rounds seeking helpers plus worker
/// bookkeeping.
#[derive(Default)]
struct PoolQueue {
    pending: Vec<PendingRound>,
    /// Worker threads ever spawned (they never exit; see module docs).
    spawned: usize,
    /// Workers currently parked on [`Pool::work`].
    idle: usize,
}

/// The process-wide persistent pool singleton.
struct Pool {
    q: Mutex<PoolQueue>,
    /// Parked workers wait here; notified when a round is published.
    work: Condvar,
    /// Round submitters wait here for their last helper to leave.
    done: Condvar,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        q: Mutex::new(PoolQueue::default()),
        work: Condvar::new(),
        done: Condvar::new(),
    })
}

/// Number of persistent worker threads started so far. Monotone, bounded
/// by [`available_jobs`] for the life of the process — the no-thread-leak
/// half of the pool lifecycle contract (asserted across 1 000 rounds in
/// this module's tests). Diagnostics/tests only.
pub fn pool_workers() -> usize {
    pool().q.lock().unwrap().spawned
}

/// Index-batch size for one round: single-index claiming for coarse
/// rounds (figure cells, modest-p supersteps — a batch of two heavy cells
/// would undo the self-scheduling balance), batches for giant rounds so a
/// 2^18-task PE round performs a few hundred counter claims instead of a
/// quarter million.
fn chunk_for(n: usize, workers: usize) -> usize {
    /// Target claims per worker per round once chunking engages — enough
    /// slack for self-scheduling to absorb skew, few enough to keep the
    /// counter cold.
    const CHUNKS_PER_WORKER: usize = 16;
    /// Hard batch cap, so even million-task rounds rebalance.
    const MAX_CHUNK: usize = 4096;
    let per_worker = n / workers.max(1);
    if per_worker < 2 * CHUNKS_PER_WORKER {
        1
    } else {
        (per_worker / CHUNKS_PER_WORKER).min(MAX_CHUNK)
    }
}

/// Take one round off the pending list, if any round still wants helpers.
/// Called under the pool mutex. Drained rounds encountered on the way are
/// delisted (their submitter no longer benefits from helpers).
fn pick_round(q: &mut PoolQueue) -> Option<Arc<Round>> {
    let mut i = 0;
    while i < q.pending.len() {
        if q.pending[i].round.next.load(Ordering::Relaxed) >= q.pending[i].round.n {
            q.pending.remove(i);
            continue;
        }
        let entry = &mut q.pending[i];
        entry.helpers_wanted -= 1;
        // the join (active += 1) happens under the mutex, so a submitter
        // that delists its round and reads active == 0 cannot race a
        // late joiner
        entry.round.active.fetch_add(1, Ordering::Relaxed);
        let round = Arc::clone(&entry.round);
        if entry.helpers_wanted == 0 {
            q.pending.remove(i);
        }
        return Some(round);
    }
    None
}

/// Body of one persistent worker: pick a round or park, forever. Workers
/// never exit — a parked worker costs one stack and zero CPU, and the
/// next round reuses it instead of paying a spawn.
fn worker_loop() {
    let pool = pool();
    let mut q = pool.q.lock().unwrap();
    loop {
        if let Some(round) = pick_round(&mut q) {
            drop(q);
            round.run_chunks();
            q = pool.q.lock().unwrap();
            round.active.fetch_sub(1, Ordering::Relaxed);
            // wake every submitter; each re-checks its own round
            pool.done.notify_all();
        } else {
            q.idle += 1;
            q = pool.work.wait(q).unwrap();
            q.idle -= 1;
        }
    }
}

impl Pool {
    /// Publish one round over `0..n` and run it to completion with up to
    /// `helpers` pool workers assisting the calling thread. Missing
    /// workers are spawned lazily (never beyond [`available_jobs`]
    /// process-wide; a failed spawn just means fewer helpers). Returns
    /// after the round has quiesced, re-raising the first job panic with
    /// its original payload.
    fn run(&'static self, task: TaskRef, n: usize, helpers: usize, chunk: usize) {
        debug_assert!(n > 0 && chunk > 0);
        let round = Arc::new(Round {
            task,
            n,
            chunk,
            next: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            panic: Mutex::new(None),
        });
        {
            let mut q = self.q.lock().unwrap();
            let deficit = helpers.saturating_sub(q.idle);
            let spawnable = available_jobs().saturating_sub(q.spawned).min(deficit);
            for _ in 0..spawnable {
                let spawned = std::thread::Builder::new()
                    .name("rmps-pool".into())
                    .spawn(worker_loop)
                    .is_ok();
                if !spawned {
                    break;
                }
                q.spawned += 1;
            }
            q.pending.push(PendingRound { round: Arc::clone(&round), helpers_wanted: helpers });
            self.work.notify_all();
        }
        // the caller is a full participant, not a blocked joiner
        round.run_chunks();
        {
            // delist (helpers that never joined are no longer wanted),
            // then wait for the ones that did to leave — after this
            // block no worker can reach the round, which is what lets
            // `task` borrow from the caller's stack
            let mut q = self.q.lock().unwrap();
            if let Some(pos) = q.pending.iter().position(|p| Arc::ptr_eq(&p.round, &round)) {
                q.pending.remove(pos);
            }
            let _q = self
                .done
                .wait_while(q, |_| round.active.load(Ordering::Relaxed) > 0)
                .unwrap();
        }
        if let Some(payload) = round.panic.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
    }
}

/// Map `f` over `0..n` with up to `jobs` participants (the calling thread
/// plus parked pool workers), returning the results in index order.
///
/// `jobs` is clamped to `[1, n]` and then to the tokens left in the shared
/// worker budget (see the module docs); `jobs <= 1` (or `n <= 1`, or an
/// exhausted budget) runs inline on the caller's thread with no pool
/// overhead, so the serial path is exactly the pre-pool code path. A panic
/// in any job is propagated to the caller with its original payload once
/// the round's workers have left it.
pub fn parallel_map<R: Send>(jobs: usize, n: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
    let jobs = jobs.clamp(1, n.max(1));
    if jobs <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let tokens = Tokens::acquire(jobs);
    let workers = tokens.n;
    if workers <= 1 {
        // budget exhausted (or down to one token — a lone participant is
        // exactly the inline path, minus the round overhead)
        return (0..n).map(f).collect();
    }
    let mut slots: Vec<Option<R>> = Vec::new();
    slots.resize_with(n, || None);
    {
        // results are written straight into their destination slots —
        // no per-worker staging vectors, no copy-after-join
        let cells = SliceCells::new(&mut slots);
        let f = &f;
        let run_chunk = move |lo: usize, hi: usize| {
            debug_assert!(hi <= cells.len());
            for i in lo..hi {
                // SAFETY: the round counter hands out each index exactly
                // once, so this is the only &mut borrow of slots[i].
                let slot = unsafe { cells.get_mut(i) };
                *slot = Some(f(i));
            }
        };
        pool().run(TaskRef::new(&run_chunk), n, workers - 1, chunk_for(n, workers));
    }
    drop(tokens);
    slots.into_iter().map(|r| r.expect("pool covered every index")).collect()
}

/// Shared view of a `&mut [T]` for **index-disjoint** parallel writes: the
/// self-scheduling counter in [`parallel_map`] hands out each index exactly
/// once, so the `&mut T` references produced through this pointer are
/// never aliased.
///
/// Crate-internal building block for [`parallel_map`]'s own result slots,
/// the `Machine` PE-task scheduler, and the exchange's parallel inbox
/// materialization — every use site states its disjointness argument at
/// the `unsafe` block.
pub(crate) struct SliceCells<T> {
    ptr: *mut T,
    len: usize,
}

unsafe impl<T: Send> Sync for SliceCells<T> {}
unsafe impl<T: Send> Send for SliceCells<T> {}

impl<T> SliceCells<T> {
    pub(crate) fn new(slice: &mut [T]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len() }
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// # Safety
    /// The caller must guarantee no two live `&mut T` to the same index
    /// (in [`parallel_map`] bodies: each index is claimed exactly once by
    /// the shared counter).
    // the &self → &mut T shape is this type's entire point: disjointness
    // is the documented contract of this unsafe fn, not derivable by the
    // borrow checker
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub(crate) unsafe fn get_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }
}

// SliceCells<T> is a *mut-based view; Copy lets round closures capture it
// by value without re-borrow gymnastics. Manual impls because derive
// would bound T: Clone / T: Copy, which the raw-pointer view doesn't need.
#[allow(clippy::expl_impl_clone_on_copy)]
impl<T> Clone for SliceCells<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> Copy for SliceCells<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_in_index_order() {
        for jobs in [1, 2, 3, 8, 64] {
            let out = parallel_map(jobs, 100, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>(), "jobs={jobs}");
        }
    }

    #[test]
    fn matches_serial_for_uneven_work() {
        // skewed job sizes exercise the self-scheduling (a static split
        // would also pass, but with idle workers)
        let work = |i: usize| -> u64 {
            let reps = if i % 7 == 0 { 10_000 } else { 10 };
            (0..reps).fold(i as u64, |a, b| a.wrapping_mul(31).wrapping_add(b))
        };
        let serial: Vec<u64> = (0..64).map(work).collect();
        assert_eq!(parallel_map(4, 64, work), serial);
    }

    #[test]
    fn degenerate_sizes() {
        assert_eq!(parallel_map(4, 0, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(4, 1, |i| i + 1), vec![1]);
        assert_eq!(parallel_map(0, 3, |i| i), vec![0, 1, 2]); // jobs clamped to >= 1
        assert_eq!(parallel_map(100, 3, |i| i), vec![0, 1, 2]); // jobs clamped to <= n
    }

    #[test]
    fn chunking_covers_every_index_at_every_size() {
        // exercise chunk sizes on both sides of the single-index cutoff,
        // including n not divisible by the chunk
        for n in [2usize, 31, 64, 65, 1000, 4097] {
            for jobs in [2usize, 3, 8] {
                let out = parallel_map(jobs, n, |i| i as u64 + 1);
                assert_eq!(out, (0..n).map(|i| i as u64 + 1).collect::<Vec<_>>(), "n={n} jobs={jobs}");
            }
        }
    }

    #[test]
    fn chunk_for_is_single_index_when_coarse_and_batched_when_giant() {
        assert_eq!(chunk_for(30, 8), 1, "figure-cell rounds claim singly");
        assert_eq!(chunk_for(64, 4), 1);
        assert!(chunk_for(1 << 18, 8) > 1, "giant rounds claim batches");
        assert!(chunk_for(1 << 18, 8) <= 4096, "batches stay bounded");
        assert!(chunk_for(usize::MAX / 2, 1) <= 4096);
        assert!(chunk_for(5, 0) >= 1, "workers clamped, chunk stays positive");
    }

    #[test]
    fn worker_panic_propagates() {
        let caught = std::panic::catch_unwind(|| {
            parallel_map(4, 16, |i| {
                if i == 5 {
                    panic!("job 5 failed");
                }
                i
            })
        });
        let payload = caught.expect_err("panic must propagate");
        // original payload, not a wrapper
        let msg = payload.downcast_ref::<&str>().copied();
        assert_eq!(msg, Some("job 5 failed"));
    }

    #[test]
    fn worker_panic_returns_tokens() {
        // after a panicking round the budget must be whole again, or every
        // later call would silently run inline
        for _ in 0..3 {
            let _ = std::panic::catch_unwind(|| {
                parallel_map(4, 16, |i| {
                    if i == 0 {
                        panic!("boom");
                    }
                    i
                })
            });
        }
        assert_eq!(parallel_map(4, 32, |i| i), (0..32).collect::<Vec<_>>());
    }

    /// Pool lifecycle: 1 000 pooled rounds reuse the same parked workers.
    /// The spawn count is monotone and can never exceed the host core
    /// count — under the old spawn-per-round pool this loop would have
    /// created and destroyed thousands of threads.
    #[test]
    fn pool_reuses_workers_across_rounds() {
        // warm: force helpers into existence
        for _ in 0..8 {
            parallel_map(available_jobs(), 256, |i| i);
        }
        let before = pool_workers();
        assert!(before <= available_jobs(), "spawn cap: {before}");
        for round in 0..1000 {
            let out = parallel_map(4, 64, |i| i + round);
            assert_eq!(out.len(), 64);
        }
        let after = pool_workers();
        assert!(after <= available_jobs(), "spawn cap after 1000 rounds: {after}");
        assert!(after >= before, "spawn count is monotone");
        if before == available_jobs() {
            assert_eq!(after, before, "saturated pool must not grow");
        }
    }

    /// Panicking rounds must not leak workers or wedge the pool: the same
    /// parked team serves the next round.
    #[test]
    fn pool_survives_panicking_rounds_with_stable_workers() {
        parallel_map(4, 64, |i| i); // ensure the pool exists
        let before = pool_workers();
        for _ in 0..50 {
            let _ = std::panic::catch_unwind(|| {
                parallel_map(4, 32, |i| {
                    if i == 7 {
                        panic!("boom");
                    }
                    i
                })
            });
        }
        assert!(pool_workers() <= available_jobs());
        assert!(pool_workers() >= before);
        assert_eq!(parallel_map(4, 128, |i| i * 2), (0..128).map(|i| i * 2).collect::<Vec<_>>());
    }

    /// The compare-exchange budget never goes negative — not even
    /// transiently mid-acquire, which the old fetch-sub-then-refund
    /// scheme could not guarantee. Hammer it from several threads while
    /// sampling the balance.
    #[test]
    fn token_budget_never_negative_under_contention() {
        std::thread::scope(|s| {
            for t in 0..8 {
                s.spawn(move || {
                    for i in 0..20_000usize {
                        let want = 1 + (i + t) % 4;
                        let tokens = Tokens::acquire(want);
                        assert!(tokens.n <= want, "grant exceeds request");
                        assert!(
                            budget().load(Ordering::Relaxed) >= 0,
                            "budget observed negative while holding a grant"
                        );
                        drop(tokens);
                        assert!(
                            budget().load(Ordering::Relaxed) >= 0,
                            "budget observed negative after refund"
                        );
                    }
                });
            }
        });
    }

    #[test]
    fn nested_levels_share_the_budget() {
        // outer cells × inner PE-style maps: correctness must hold whether
        // the inner level got worker tokens or degraded to inline
        let out = parallel_map(4, 8, |cell| {
            let inner = parallel_map(4, 16, move |pe| (cell * 100 + pe) as u64);
            inner.iter().sum::<u64>()
        });
        let expect: Vec<u64> =
            (0..8).map(|c| (0..16).map(|p| (c * 100 + p) as u64).sum()).collect();
        assert_eq!(out, expect);
    }

    /// Concurrent top-level rounds (two threads submitting to the one
    /// pool at once) must not cross-deliver results or deadlock — the
    /// shape of a figure sweep running beside a deep single run.
    #[test]
    fn concurrent_rounds_on_the_shared_pool_stay_isolated() {
        std::thread::scope(|s| {
            for t in 0..4usize {
                s.spawn(move || {
                    for round in 0..50usize {
                        let out = parallel_map(3, 40, |i| t * 10_000 + round * 100 + i);
                        let expect: Vec<usize> =
                            (0..40).map(|i| t * 10_000 + round * 100 + i).collect();
                        assert_eq!(out, expect, "thread {t} round {round}");
                    }
                });
            }
        });
    }

    /// The disjoint-index write primitive behind the PE-task scheduler,
    /// the parallel inbox materialization, and parallel_map's own result
    /// slots: every index mutated exactly once, in any interleaving.
    #[test]
    fn slice_cells_disjoint_parallel_writes() {
        for jobs in [1, 3, 8] {
            let mut items: Vec<u64> = (0..50).collect();
            let cells = SliceCells::new(&mut items);
            let doubled: Vec<(u64, u64)> = parallel_map(jobs, cells.len(), |i| {
                // SAFETY: parallel_map claims each index exactly once.
                let v = unsafe { cells.get_mut(i) };
                *v *= 2;
                (i as u64, *v)
            });
            assert_eq!(items, (0..50).map(|i| i * 2).collect::<Vec<u64>>(), "jobs={jobs}");
            for (i, (idx, val)) in doubled.iter().enumerate() {
                assert_eq!(*idx, i as u64);
                assert_eq!(*val, items[i]);
            }
        }
    }

    #[test]
    fn available_jobs_is_positive() {
        assert!(available_jobs() >= 1);
    }

    /// Job-level grants draw from the same budget as the cell/PE levels:
    /// a grant never exceeds the request, never drives the budget
    /// negative, and dropping it restores what it took. (Exact balance
    /// values cannot be asserted here — other tests in this binary hold
    /// and release tokens concurrently — so the assertions are the
    /// race-safe invariants.)
    #[test]
    fn job_grant_respects_the_shared_budget() {
        let grant = acquire_job_workers(2);
        assert!(grant.granted() <= 2);
        assert!(budget_remaining() >= 0, "budget negative while grant held");
        drop(grant);
        assert!(budget_remaining() >= 0, "budget negative after grant release");
        // an absurd request is clamped to the host width, not cast raw
        let grant = acquire_job_workers(usize::MAX);
        assert!(grant.granted() <= available_jobs());
        assert!(budget_remaining() >= 0);
    }

    /// With a job-level grant pinning tokens, nested parallel_map rounds
    /// must still complete correctly (degrading to inline when the grant
    /// holds the whole budget) — the three-level no-oversubscription
    /// story.
    #[test]
    fn nested_rounds_degrade_inline_under_a_job_grant() {
        let grant = acquire_job_workers(available_jobs());
        let out = parallel_map(4, 32, |i| i * 3);
        assert_eq!(out, (0..32).map(|i| i * 3).collect::<Vec<_>>());
        drop(grant);
    }

    #[test]
    fn pe_jobs_override_round_trips_and_clears() {
        // the override is process-global; every value keeps results
        // identical, so flipping it here cannot disturb other tests —
        // but it MUST be cleared afterwards, or this test would silently
        // defeat an RMPS_PE_JOBS value set for the whole suite run
        set_pe_jobs(3);
        assert_eq!(default_pe_jobs(), 3);
        set_pe_jobs(0); // clear: back to env / all-cores
        let restored = default_pe_jobs();
        assert!(restored >= 1);
    }
}
