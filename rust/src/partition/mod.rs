//! Super Scalar Sample Sort partitioning (Sanders & Winkel [26]) with the
//! tie-breaking extension of App. G — the local phase of RAMS and SSort.
//!
//! The classifier is a branchless descent of a perfect splitter tree
//! (eytzinger layout): `log k` fused compare/select steps per element.
//! Following the SSSS playbook, the descent keeps **four independent
//! elements in flight** per loop ([`SplitterTree::classify_key4`] /
//! [`SplitterTree::classify_tb4`]): the four cursor chains have no data
//! dependence on each other, so their tree loads overlap instead of
//! serializing on one chain — instruction-level parallelism the scalar
//! descent leaves on the table. The tie-breaking variant descends on
//! strict lexicographic `(key, id)` order, which *simulates unique keys*
//! — the reason RAMS survives DeterDupl/Zero.
//!
//! Placement is the SSSS count → exclusive-prefix-sum → scatter scheme
//! ([`partition_scatter`]): one classify pass records per-element labels
//! and per-bucket counts, the prefix sums turn the counts into bucket
//! boundaries, and one scatter pass writes every element to its final
//! slot of a **single contiguous buffer** — stable within buckets, no
//! per-bucket `Vec` growth on the hot path. [`partition`] /
//! [`partition_ctx`] slice per-bucket `Vec`s out of that buffer, so
//! Exchange `post` callers keep their bucket-vector API.
//!
//! Mirrors `python/compile/kernels/classify.py` (the PJRT-accelerated
//! version); both are validated against each other in `rust/tests/`
//! (and bit-for-bit against the verbatim pre-rewrite kernel in
//! `rust/tests/kernel_equivalence.rs`).

use crate::elements::{Elem, Key};

/// A perfect splitter tree over `S = 2^h − 1` splitters.
#[derive(Clone, Debug)]
pub struct SplitterTree {
    /// eytzinger layout, 1-based; index 0 unused (mirrors the kernel).
    keys: Vec<Key>,
    /// packed (key, id) as u128 — one branchless compare per tie-breaking
    /// descent level instead of key/id cascades (§Perf).
    packed: Vec<u128>,
    /// number of splitters S.
    s: usize,
    /// tree height h = log2(S+1).
    h: u32,
}

#[inline]
fn pack(e: &Elem) -> u128 {
    ((e.key as u128) << 64) | e.id as u128
}

impl SplitterTree {
    /// Build from splitters sorted in `(key, id)` order. `S+1` must be a
    /// power of two (callers pad by repeating the last splitter).
    pub fn new(sorted: &[Elem]) -> Self {
        let s = sorted.len();
        assert!((s + 1).is_power_of_two(), "need 2^h - 1 splitters, got {s}");
        debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
        let mut keys = vec![0; s + 1];
        let mut ids = vec![0; s + 1];
        // recursive BFS fill == eytzinger layout
        fn fill(
            sorted: &[Elem],
            keys: &mut [Key],
            ids: &mut [u64],
            t: usize,
            lo: usize,
            hi: i64,
        ) {
            if t >= keys.len() || hi < lo as i64 {
                return;
            }
            let mid = (lo as i64 + hi) as usize / 2;
            keys[t] = sorted[mid].key;
            ids[t] = sorted[mid].id;
            fill(sorted, keys, ids, 2 * t, lo, mid as i64 - 1);
            fill(sorted, keys, ids, 2 * t + 1, mid + 1, hi);
        }
        if s > 0 {
            fill(sorted, &mut keys, &mut ids, 1, 0, s as i64 - 1);
            keys[0] = keys[1];
            ids[0] = ids[1];
        }
        let packed = keys
            .iter()
            .zip(&ids)
            .map(|(&k, &i)| ((k as u128) << 64) | i as u128)
            .collect();
        Self { keys, packed, s, h: (s + 1).trailing_zeros() }
    }

    /// Number of buckets (S + 1).
    #[inline]
    pub fn buckets(&self) -> usize {
        self.s + 1
    }

    /// Nonrobust bucket index: number of splitters with key strictly less
    /// than `key` (equal keys all land in the splitter's own bucket — the
    /// behaviour that melts down on duplicate-heavy instances).
    #[inline]
    pub fn classify_key(&self, key: Key) -> usize {
        let mut t = 1usize;
        for _ in 0..self.h {
            t = 2 * t + usize::from(self.keys[t] < key);
        }
        t - (self.s + 1)
    }

    /// Four [`SplitterTree::classify_key`] descents at once: one shared
    /// `h`-step loop advances four independent cursors, so the four tree
    /// loads of each level issue in parallel (ILP) instead of waiting on
    /// one serial compare→load chain. Same result as four scalar calls.
    #[inline]
    pub fn classify_key4(&self, k: [Key; 4]) -> [usize; 4] {
        let mut t = [1usize; 4];
        for _ in 0..self.h {
            t = [
                2 * t[0] + usize::from(self.keys[t[0]] < k[0]),
                2 * t[1] + usize::from(self.keys[t[1]] < k[1]),
                2 * t[2] + usize::from(self.keys[t[2]] < k[2]),
                2 * t[3] + usize::from(self.keys[t[3]] < k[3]),
            ];
        }
        let nb = self.s + 1;
        [t[0] - nb, t[1] - nb, t[2] - nb, t[3] - nb]
    }

    /// Tie-breaking bucket index on strict lexicographic `(key, id)` order
    /// (App. G): equal keys spread across buckets by origin id. The
    /// (key, id) pair is compared as one packed u128 — branchless.
    #[inline]
    pub fn classify_tb(&self, e: &Elem) -> usize {
        let pe = pack(e);
        let mut t = 1usize;
        for _ in 0..self.h {
            t = 2 * t + usize::from(self.packed[t] < pe);
        }
        t - (self.s + 1)
    }

    /// Four [`SplitterTree::classify_tb`] descents at once — the packed
    /// u128 compare with four independent cursors per level (see
    /// [`SplitterTree::classify_key4`]). Same result as four scalar calls.
    #[inline]
    pub fn classify_tb4(&self, e: [&Elem; 4]) -> [usize; 4] {
        let k = [pack(e[0]), pack(e[1]), pack(e[2]), pack(e[3])];
        let mut t = [1usize; 4];
        for _ in 0..self.h {
            t = [
                2 * t[0] + usize::from(self.packed[t[0]] < k[0]),
                2 * t[1] + usize::from(self.packed[t[1]] < k[1]),
                2 * t[2] + usize::from(self.packed[t[2]] < k[2]),
                2 * t[3] + usize::from(self.packed[t[3]] < k[3]),
            ];
        }
        let nb = self.s + 1;
        [t[0] - nb, t[1] - nb, t[2] - nb, t[3] - nb]
    }
}

/// Reusable scratch for [`partition_scatter`]: the per-element label vec,
/// the bucket-boundary table, the scatter write cursors, and the
/// contiguous output buffer. Every `Vec` keeps its capacity across calls,
/// so a warm scratch makes the whole partition kernel allocation-free.
#[derive(Clone, Debug, Default)]
pub struct PartitionScratch {
    labels: Vec<u32>,
    bounds: Vec<usize>,
    cursors: Vec<usize>,
    scatter: Vec<Elem>,
}

/// Partition `data` into bucket-contiguous stable order inside one
/// buffer: classify every element (four descents in flight), turn the
/// bucket counts into exclusive prefix sums, and scatter each element to
/// its final slot. Returns the scattered elements and the `nb + 1`
/// bucket boundaries (`buf[bounds[b]..bounds[b + 1]]` is bucket `b`,
/// input order preserved inside each bucket).
///
/// This is the zero-copy core of [`partition`] / [`partition_ctx`]; call
/// it directly when bucket slices are enough (no per-bucket `Vec`s).
pub fn partition_scatter<'a>(
    data: &[Elem],
    tree: &SplitterTree,
    tie_break: bool,
    scratch: &'a mut PartitionScratch,
) -> (&'a [Elem], &'a [usize]) {
    let nb = tree.buckets();
    let n = data.len();
    let PartitionScratch { labels, bounds, cursors, scatter } = scratch;

    // pass 1: classify — labels recorded for the scatter, counts tallied
    // into bounds[1..] (shifted one slot so the in-place scan below turns
    // them directly into exclusive prefix sums)
    labels.clear();
    labels.reserve(n);
    bounds.clear();
    bounds.resize(nb + 1, 0);
    {
        let counts = &mut bounds[1..];
        let mut quads = data.chunks_exact(4);
        if tie_break {
            for q in &mut quads {
                for b in tree.classify_tb4([&q[0], &q[1], &q[2], &q[3]]) {
                    labels.push(b as u32);
                    counts[b] += 1;
                }
            }
            for e in quads.remainder() {
                let b = tree.classify_tb(e);
                labels.push(b as u32);
                counts[b] += 1;
            }
        } else {
            for q in &mut quads {
                for b in tree.classify_key4([q[0].key, q[1].key, q[2].key, q[3].key]) {
                    labels.push(b as u32);
                    counts[b] += 1;
                }
            }
            for e in quads.remainder() {
                let b = tree.classify_key(e.key);
                labels.push(b as u32);
                counts[b] += 1;
            }
        }
    }

    // exclusive prefix sums in place: bounds[b] = first slot of bucket b
    for b in 1..=nb {
        bounds[b] += bounds[b - 1];
    }

    // pass 2: scatter into one contiguous buffer, one write cursor per
    // bucket — stable, every slot in 0..n written exactly once (so the
    // grow-only resize below never exposes stale contents)
    if scatter.len() < n {
        scatter.resize(n, Elem::with_id(0, 0));
    }
    cursors.clear();
    cursors.extend_from_slice(&bounds[..nb]);
    for (e, &b) in data.iter().zip(labels.iter()) {
        let c = &mut cursors[b as usize];
        scatter[*c] = *e;
        *c += 1;
    }
    (&scatter[..n], &bounds[..])
}

/// Partition `data` into `tree.buckets()` buckets. `tie_break` selects the
/// robust (App. G) or nonrobust classifier. Preserves input order inside
/// each bucket (stable).
pub fn partition(data: &[Elem], tree: &SplitterTree, tie_break: bool) -> Vec<Vec<Elem>> {
    let mut scratch = PartitionScratch::default();
    let (buf, bounds) = partition_scatter(data, tree, tie_break, &mut scratch);
    bounds
        .windows(2)
        .map(|w| {
            let seg = &buf[w[0]..w[1]];
            let mut v = Vec::with_capacity(seg.len());
            v.extend_from_slice(seg);
            v
        })
        .collect()
}

/// [`partition`] with the scatter scratch held by a pool-scheduled PE
/// task ([`crate::sim::PeCtx::partition_scratch`]) and the bucket vectors
/// drawn from its buffer stash ([`crate::sim::PeCtx::take_buf`],
/// pre-seeded from the machine's data-plane pool via
/// [`crate::sim::ParSpec::bufs`]) — the hot-path variant for algorithms
/// that classify every element per superstep and ship the buckets through
/// an [`crate::sim::Exchange`] round (RAMS, AMS): the per-PE partition
/// phases run concurrently, each bucket is one contiguous copy out of the
/// scattered buffer, and the buffers cycle back to the pool when the
/// delivered mail is recycled, so steady-state levels allocate nothing
/// for buckets. Bucket contents and order are identical to [`partition`].
pub fn partition_ctx(
    ctx: &mut crate::sim::PeCtx,
    data: &[Elem],
    tree: &SplitterTree,
    tie_break: bool,
) -> Vec<Vec<Elem>> {
    let nb = tree.buckets();
    let mut out: Vec<Vec<Elem>> = (0..nb).map(|_| ctx.take_buf()).collect();
    let (buf, bounds) = partition_scatter(data, tree, tie_break, ctx.partition_scratch());
    for (b, v) in out.iter_mut().enumerate() {
        let seg = &buf[bounds[b]..bounds[b + 1]];
        v.reserve(seg.len());
        v.extend_from_slice(seg);
    }
    out
}

/// Pick `s` evenly spaced splitters from a globally sorted sample
/// (`sample[⌈(i+1)·len/(s+1)⌉−1`-ish positions), padding to `2^h − 1` by
/// repeating the maximum — the shape [`SplitterTree::new`] requires.
pub fn pick_splitters(sample: &[Elem], s: usize) -> Vec<Elem> {
    debug_assert!((s + 1).is_power_of_two());
    if sample.is_empty() {
        // degenerate: all-identical sentinel splitters (single real bucket)
        return vec![Elem::with_id(Key::MAX, u64::MAX); s];
    }
    let mut out = Vec::with_capacity(s);
    for i in 1..=s {
        let idx = (i * sample.len()) / (s + 1);
        out.push(sample[idx.min(sample.len() - 1)]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn elems(keys: &[u64]) -> Vec<Elem> {
        keys.iter().enumerate().map(|(i, &k)| Elem::new(k, 0, i)).collect()
    }

    fn sorted_elems(keys: &[u64]) -> Vec<Elem> {
        let mut v = elems(keys);
        v.sort();
        v
    }

    #[test]
    fn classify_matches_linear_scan() {
        let spl = sorted_elems(&[10, 20, 30, 40, 50, 60, 70]);
        let tree = SplitterTree::new(&spl);
        for key in [0u64, 10, 11, 20, 35, 70, 71, 100] {
            let expect = spl.iter().filter(|s| s.key < key).count();
            assert_eq!(tree.classify_key(key), expect, "key {key}");
        }
    }

    #[test]
    fn classify_tb_matches_linear_scan_with_duplicates() {
        let mut spl: Vec<Elem> = vec![
            Elem::with_id(5, 10),
            Elem::with_id(5, 20),
            Elem::with_id(5, 30),
        ];
        spl.sort();
        let tree = SplitterTree::new(&spl);
        for id in [0u64, 10, 15, 20, 25, 30, 99] {
            let e = Elem::with_id(5, id);
            let expect = spl.iter().filter(|s| **s < e).count();
            assert_eq!(tree.classify_tb(&e), expect, "id {id}");
        }
        // keys off the splitter value ignore ids
        assert_eq!(tree.classify_tb(&Elem::with_id(4, 999)), 0);
        assert_eq!(tree.classify_tb(&Elem::with_id(6, 0)), 3);
    }

    /// The 4-lane descents agree with four scalar descents for every tree
    /// height — random keys, duplicate-heavy keys, and exact splitter
    /// hits (the `<` vs `<=` boundary cases).
    #[test]
    fn lane4_matches_scalar_descent() {
        let mut rng = Rng::seeded(7, 7);
        for s in [0usize, 1, 3, 7, 15, 63, 127] {
            let sample: Vec<Elem> = (0..256)
                .map(|i| Elem::with_id(rng.next_u64() % 97, i))
                .collect();
            let mut sample = sample;
            sample.sort();
            let spl = pick_splitters(&sample, s);
            let tree = SplitterTree::new(&spl);
            let data: Vec<Elem> = (0..64)
                .map(|i| {
                    // mix random probes with exact splitter values
                    if i % 3 == 0 && !spl.is_empty() {
                        spl[i % spl.len()]
                    } else {
                        Elem::with_id(rng.next_u64() % 97, rng.next_u64() % 50)
                    }
                })
                .collect();
            for q in data.chunks_exact(4) {
                let keys4 = tree.classify_key4([q[0].key, q[1].key, q[2].key, q[3].key]);
                let tb4 = tree.classify_tb4([&q[0], &q[1], &q[2], &q[3]]);
                for l in 0..4 {
                    assert_eq!(keys4[l], tree.classify_key(q[l].key), "s={s} lane {l}");
                    assert_eq!(tb4[l], tree.classify_tb(&q[l]), "s={s} lane {l}");
                }
            }
        }
    }

    /// The scatter core: boundaries are monotone, cover the input, and
    /// each bucket segment preserves input order (stability) — on a warm
    /// scratch reused across differently-sized calls.
    #[test]
    fn partition_scatter_bounds_and_stability() {
        let mut rng = Rng::seeded(3, 9);
        let mut scratch = PartitionScratch::default();
        for n in [0usize, 1, 2, 3, 4, 5, 63, 64, 200, 17] {
            let data: Vec<Elem> =
                (0..n).map(|i| Elem::new(rng.next_u64() % 31, 0, i)).collect();
            let mut sample = data.clone();
            sample.sort();
            let spl = pick_splitters(&sample, 7);
            let tree = SplitterTree::new(&spl);
            let (buf, bounds) = partition_scatter(&data, &tree, true, &mut scratch);
            assert_eq!(bounds.len(), tree.buckets() + 1);
            assert_eq!(bounds[0], 0);
            assert_eq!(*bounds.last().unwrap(), n, "n={n}");
            assert!(bounds.windows(2).all(|w| w[0] <= w[1]));
            for b in 0..tree.buckets() {
                let seg = &buf[bounds[b]..bounds[b + 1]];
                // same subsequence as a filter of the input (stability)
                let expect: Vec<Elem> = data
                    .iter()
                    .filter(|e| tree.classify_tb(e) == b)
                    .copied()
                    .collect();
                assert_eq!(seg, expect.as_slice(), "n={n} bucket {b}");
            }
        }
    }

    #[test]
    fn partition_is_ordered_and_complete() {
        let spl = sorted_elems(&[100, 200, 300]);
        let tree = SplitterTree::new(&spl);
        let data = elems(&[50, 150, 250, 350, 100, 200, 300, 0]);
        let parts = partition(&data, &tree, false);
        assert_eq!(parts.len(), 4);
        let total: usize = parts.iter().map(Vec::len).sum();
        assert_eq!(total, data.len());
        // bucket membership: all keys in bucket b are in (spl[b-1], spl[b]]
        for (b, part) in parts.iter().enumerate() {
            for e in part {
                if b > 0 {
                    assert!(e.key >= spl[b - 1].key);
                }
                if b < 3 {
                    assert!(e.key <= spl[b].key);
                }
            }
        }
    }

    #[test]
    fn tb_partition_balances_all_equal_keys() {
        // the Zero instance in miniature: 64 equal keys, ids 0..64,
        // splitters at ids 15/31/47 → four buckets of 16
        let mut spl: Vec<Elem> =
            [15u64, 31, 47].iter().map(|&i| Elem::with_id(0, i)).collect();
        spl.sort();
        let tree = SplitterTree::new(&spl);
        let data: Vec<Elem> = (0..64).map(|i| Elem::with_id(0, i)).collect();
        let parts = partition(&data, &tree, true);
        assert_eq!(parts.iter().map(Vec::len).collect::<Vec<_>>(), vec![16, 16, 16, 16]);
        // nonrobust classifier dumps everything into one bucket
        let parts = partition(&data, &tree, false);
        assert_eq!(parts[0].len(), 64);
    }

    #[test]
    fn pick_splitters_even_spread() {
        let sample = sorted_elems(&(0..100u64).collect::<Vec<_>>());
        let spl = pick_splitters(&sample, 3);
        let keys: Vec<u64> = spl.iter().map(|e| e.key).collect();
        assert_eq!(keys, vec![25, 50, 75]);
    }

    #[test]
    fn pick_splitters_empty_sample() {
        let spl = pick_splitters(&[], 7);
        assert_eq!(spl.len(), 7);
        let tree = SplitterTree::new(&spl);
        assert_eq!(tree.classify_key(12345), 0);
    }

    #[test]
    fn single_splitter_tree() {
        let spl = sorted_elems(&[42]);
        let tree = SplitterTree::new(&spl);
        assert_eq!(tree.buckets(), 2);
        assert_eq!(tree.classify_key(41), 0);
        assert_eq!(tree.classify_key(42), 0);
        assert_eq!(tree.classify_key(43), 1);
    }
}
