//! Super Scalar Sample Sort partitioning (Sanders & Winkel [26]) with the
//! tie-breaking extension of App. G — the local phase of RAMS and SSort.
//!
//! The classifier is a branchless descent of a perfect splitter tree
//! (eytzinger layout): `log k` fused compare/select steps per element. The
//! tie-breaking variant descends on strict lexicographic `(key, id)` order,
//! which *simulates unique keys* — the reason RAMS survives DeterDupl/Zero.
//!
//! Mirrors `python/compile/kernels/classify.py` (the PJRT-accelerated
//! version); both are validated against each other in `rust/tests/`.

use crate::elements::{Elem, Key};

/// A perfect splitter tree over `S = 2^h − 1` splitters.
#[derive(Clone, Debug)]
pub struct SplitterTree {
    /// eytzinger layout, 1-based; index 0 unused (mirrors the kernel).
    keys: Vec<Key>,
    /// packed (key, id) as u128 — one branchless compare per tie-breaking
    /// descent level instead of key/id cascades (§Perf).
    packed: Vec<u128>,
    /// number of splitters S.
    s: usize,
    /// tree height h = log2(S+1).
    h: u32,
}

#[inline]
fn pack(e: &Elem) -> u128 {
    ((e.key as u128) << 64) | e.id as u128
}

impl SplitterTree {
    /// Build from splitters sorted in `(key, id)` order. `S+1` must be a
    /// power of two (callers pad by repeating the last splitter).
    pub fn new(sorted: &[Elem]) -> Self {
        let s = sorted.len();
        assert!((s + 1).is_power_of_two(), "need 2^h - 1 splitters, got {s}");
        debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
        let mut keys = vec![0; s + 1];
        let mut ids = vec![0; s + 1];
        // recursive BFS fill == eytzinger layout
        fn fill(
            sorted: &[Elem],
            keys: &mut [Key],
            ids: &mut [u64],
            t: usize,
            lo: usize,
            hi: i64,
        ) {
            if t >= keys.len() || hi < lo as i64 {
                return;
            }
            let mid = (lo as i64 + hi) as usize / 2;
            keys[t] = sorted[mid].key;
            ids[t] = sorted[mid].id;
            fill(sorted, keys, ids, 2 * t, lo, mid as i64 - 1);
            fill(sorted, keys, ids, 2 * t + 1, mid + 1, hi);
        }
        if s > 0 {
            fill(sorted, &mut keys, &mut ids, 1, 0, s as i64 - 1);
            keys[0] = keys[1];
            ids[0] = ids[1];
        }
        let packed = keys
            .iter()
            .zip(&ids)
            .map(|(&k, &i)| ((k as u128) << 64) | i as u128)
            .collect();
        Self { keys, packed, s, h: (s + 1).trailing_zeros() }
    }

    /// Number of buckets (S + 1).
    #[inline]
    pub fn buckets(&self) -> usize {
        self.s + 1
    }

    /// Nonrobust bucket index: number of splitters with key strictly less
    /// than `key` (equal keys all land in the splitter's own bucket — the
    /// behaviour that melts down on duplicate-heavy instances).
    #[inline]
    pub fn classify_key(&self, key: Key) -> usize {
        let mut t = 1usize;
        for _ in 0..self.h {
            t = 2 * t + usize::from(self.keys[t] < key);
        }
        t - (self.s + 1)
    }

    /// Tie-breaking bucket index on strict lexicographic `(key, id)` order
    /// (App. G): equal keys spread across buckets by origin id. The
    /// (key, id) pair is compared as one packed u128 — branchless.
    #[inline]
    pub fn classify_tb(&self, e: &Elem) -> usize {
        let pe = pack(e);
        let mut t = 1usize;
        for _ in 0..self.h {
            t = 2 * t + usize::from(self.packed[t] < pe);
        }
        t - (self.s + 1)
    }
}

/// Partition `data` into `tree.buckets()` buckets. `tie_break` selects the
/// robust (App. G) or nonrobust classifier. Preserves input order inside
/// each bucket (stable).
pub fn partition(data: &[Elem], tree: &SplitterTree, tie_break: bool) -> Vec<Vec<Elem>> {
    partition_with(data, tree, tie_break, Vec::with_capacity)
}

/// [`partition`] with bucket vectors drawn from a pool-scheduled PE
/// task's buffer stash ([`crate::sim::PeCtx::take_buf`], pre-seeded from
/// the machine's data-plane pool via [`crate::sim::ParSpec::bufs`]) — the
/// hot-path variant for algorithms that classify every element per
/// superstep and ship the buckets through an [`crate::sim::Exchange`]
/// round (RAMS): the per-PE partition phases run concurrently and the
/// buffers cycle back to the pool when the delivered mail is recycled, so
/// steady-state levels allocate nothing for buckets. Bucket contents and
/// order are identical to [`partition`].
pub fn partition_ctx(
    ctx: &mut crate::sim::PeCtx,
    data: &[Elem],
    tree: &SplitterTree,
    tie_break: bool,
) -> Vec<Vec<Elem>> {
    partition_with(data, tree, tie_break, |c| {
        let mut buf = ctx.take_buf();
        buf.reserve(c);
        buf
    })
}

fn partition_with(
    data: &[Elem],
    tree: &SplitterTree,
    tie_break: bool,
    mut bucket_buf: impl FnMut(usize) -> Vec<Elem>,
) -> Vec<Vec<Elem>> {
    let nb = tree.buckets();
    // two passes: count then place — cache-friendlier than push-per-bucket
    let mut counts = vec![0usize; nb];
    let mut labels = Vec::with_capacity(data.len());
    if tie_break {
        for e in data {
            let b = tree.classify_tb(e);
            labels.push(b as u32);
            counts[b] += 1;
        }
    } else {
        for e in data {
            let b = tree.classify_key(e.key);
            labels.push(b as u32);
            counts[b] += 1;
        }
    }
    let mut out: Vec<Vec<Elem>> = counts.iter().map(|&c| bucket_buf(c)).collect();
    for (e, &b) in data.iter().zip(&labels) {
        out[b as usize].push(*e);
    }
    out
}

/// Pick `s` evenly spaced splitters from a globally sorted sample
/// (`sample[⌈(i+1)·len/(s+1)⌉−1`-ish positions), padding to `2^h − 1` by
/// repeating the maximum — the shape [`SplitterTree::new`] requires.
pub fn pick_splitters(sample: &[Elem], s: usize) -> Vec<Elem> {
    debug_assert!((s + 1).is_power_of_two());
    if sample.is_empty() {
        // degenerate: all-identical sentinel splitters (single real bucket)
        return vec![Elem::with_id(Key::MAX, u64::MAX); s];
    }
    let mut out = Vec::with_capacity(s);
    for i in 1..=s {
        let idx = (i * sample.len()) / (s + 1);
        out.push(sample[idx.min(sample.len() - 1)]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn elems(keys: &[u64]) -> Vec<Elem> {
        keys.iter().enumerate().map(|(i, &k)| Elem::new(k, 0, i)).collect()
    }

    fn sorted_elems(keys: &[u64]) -> Vec<Elem> {
        let mut v = elems(keys);
        v.sort();
        v
    }

    #[test]
    fn classify_matches_linear_scan() {
        let spl = sorted_elems(&[10, 20, 30, 40, 50, 60, 70]);
        let tree = SplitterTree::new(&spl);
        for key in [0u64, 10, 11, 20, 35, 70, 71, 100] {
            let expect = spl.iter().filter(|s| s.key < key).count();
            assert_eq!(tree.classify_key(key), expect, "key {key}");
        }
    }

    #[test]
    fn classify_tb_matches_linear_scan_with_duplicates() {
        let mut spl: Vec<Elem> = vec![
            Elem::with_id(5, 10),
            Elem::with_id(5, 20),
            Elem::with_id(5, 30),
        ];
        spl.sort();
        let tree = SplitterTree::new(&spl);
        for id in [0u64, 10, 15, 20, 25, 30, 99] {
            let e = Elem::with_id(5, id);
            let expect = spl.iter().filter(|s| **s < e).count();
            assert_eq!(tree.classify_tb(&e), expect, "id {id}");
        }
        // keys off the splitter value ignore ids
        assert_eq!(tree.classify_tb(&Elem::with_id(4, 999)), 0);
        assert_eq!(tree.classify_tb(&Elem::with_id(6, 0)), 3);
    }

    #[test]
    fn partition_is_ordered_and_complete() {
        let spl = sorted_elems(&[100, 200, 300]);
        let tree = SplitterTree::new(&spl);
        let data = elems(&[50, 150, 250, 350, 100, 200, 300, 0]);
        let parts = partition(&data, &tree, false);
        assert_eq!(parts.len(), 4);
        let total: usize = parts.iter().map(Vec::len).sum();
        assert_eq!(total, data.len());
        // bucket membership: all keys in bucket b are in (spl[b-1], spl[b]]
        for (b, part) in parts.iter().enumerate() {
            for e in part {
                if b > 0 {
                    assert!(e.key >= spl[b - 1].key);
                }
                if b < 3 {
                    assert!(e.key <= spl[b].key);
                }
            }
        }
    }

    #[test]
    fn tb_partition_balances_all_equal_keys() {
        // the Zero instance in miniature: 64 equal keys, ids 0..64,
        // splitters at ids 15/31/47 → four buckets of 16
        let mut spl: Vec<Elem> =
            [15u64, 31, 47].iter().map(|&i| Elem::with_id(0, i)).collect();
        spl.sort();
        let tree = SplitterTree::new(&spl);
        let data: Vec<Elem> = (0..64).map(|i| Elem::with_id(0, i)).collect();
        let parts = partition(&data, &tree, true);
        assert_eq!(parts.iter().map(Vec::len).collect::<Vec<_>>(), vec![16, 16, 16, 16]);
        // nonrobust classifier dumps everything into one bucket
        let parts = partition(&data, &tree, false);
        assert_eq!(parts[0].len(), 64);
    }

    #[test]
    fn pick_splitters_even_spread() {
        let sample = sorted_elems(&(0..100u64).collect::<Vec<_>>());
        let spl = pick_splitters(&sample, 3);
        let keys: Vec<u64> = spl.iter().map(|e| e.key).collect();
        assert_eq!(keys, vec![25, 50, 75]);
    }

    #[test]
    fn pick_splitters_empty_sample() {
        let spl = pick_splitters(&[], 7);
        assert_eq!(spl.len(), 7);
        let tree = SplitterTree::new(&spl);
        assert_eq!(tree.classify_key(12345), 0);
    }

    #[test]
    fn single_splitter_tree() {
        let spl = sorted_elems(&[42]);
        let tree = SplitterTree::new(&spl);
        assert_eq!(tree.buckets(), 2);
        assert_eq!(tree.classify_key(41), 0);
        assert_eq!(tree.classify_key(42), 0);
        assert_eq!(tree.classify_key(43), 1);
    }
}
