//! Sort-as-a-service front-end: a long-lived drain loop that accepts
//! queued sort jobs (JSONL [`JobSpec`]s), admission-controls them
//! through the process-wide worker-token budget of [`crate::exec`], and
//! dispatches each through a reused [`Runner`].
//!
//! ## Admission control
//!
//! Job concurrency is the **third** level drawing from the single
//! process-wide worker-token budget, above the cell level
//! (`experiments::run_cells` / `--jobs`) and the PE-task level
//! (`Machine` rounds / `--pe-jobs`). A [`Service`] acquires a
//! [`crate::exec::JobGrant`] of up to `opts.jobs` tokens for the
//! lifetime of a drain; `granted()` workers serve the queue (the caller
//! is always one of them, so a grant of 0 or 1 degrades to inline
//! serving, never deadlock). Inner PE-task rounds draw from whatever
//! budget remains, so the three levels together can never oversubscribe
//! the host — asserted by the soak test in `tests/serve_equivalence.rs`.
//!
//! ## Routing
//!
//! A job that names an `"algo"` runs exactly that registry sorter. An
//! untargeted job routes through the Robust selector — by default with
//! a **tuned** crossover table from
//! [`crate::experiments::tuning::crossover_table_cached`], probed once
//! per distinct machine config and cached process-wide, so only the
//! first job on a new config pays the probe. `route_tuned: false`
//! falls back to the paper's static JUQUEEN table.
//!
//! ## Determinism
//!
//! Scheduling decides only *when* a job runs, never *what it computes*:
//! each job's [`RunReport`] depends on `(config, distribution, seed,
//! sorter)` alone, so a drained stream is field-by-field bit-identical
//! to running every job standalone, at any `jobs` level (the
//! equivalence test asserts this for 1, 3, and the host width). Queue
//! and service latencies are host wall-clock and live only in the
//! [`JobRecord`]s / [`Stats`] digest.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::algorithms::runner::RunMeta;
use crate::algorithms::selector::RobustSorter;
use crate::algorithms::{find_sorter, RunReport, Runner, Sorter};
use crate::config::RunConfig;
use crate::exec;
use crate::experiments::tuning::{crossover_cache_counters, crossover_table_cached};

mod job;
mod stats;

pub use job::JobSpec;
pub use stats::{JobRecord, Stats};

/// Configuration of a [`Service`].
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Desired job-level concurrency; the actual grant is capped by the
    /// worker-token budget left by outer levels.
    pub jobs: usize,
    /// Base run configuration; each job overrides selected fields.
    pub base: RunConfig,
    /// Validate each job's output (the Θ(n) reference clone).
    pub validate: bool,
    /// Keep each job's sorted payload in its report.
    pub keep_output: bool,
    /// Route untargeted jobs with a tuned (probed + cached) crossover
    /// table instead of the paper's JUQUEEN constants.
    pub route_tuned: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            jobs: exec::available_jobs(),
            base: RunConfig::default(),
            validate: true,
            keep_output: true,
            route_tuned: true,
        }
    }
}

/// Everything a drained job stream produced: per-job reports and timing
/// records (both in admission order, parallel to each other), rejected
/// specs, and the aggregate digest.
#[derive(Debug, Default)]
pub struct ServeOutcome {
    pub reports: Vec<RunReport>,
    pub records: Vec<JobRecord>,
    /// Rejected submissions as `(input index, error)`. For
    /// [`Service::drain_lines`] the index is the 1-based line number;
    /// for [`Service::drain`] it is the 0-based spec index.
    pub errors: Vec<(usize, String)>,
    pub stats: Stats,
}

/// Resolve the sorter a spec will run: a named registry sorter, or the
/// Robust selector (tuned per machine config, or the paper table).
pub fn resolve_sorter(
    spec: &JobSpec,
    cfg: &RunConfig,
    route_tuned: bool,
) -> Result<std::sync::Arc<dyn Sorter>, String> {
    match &spec.algo {
        Some(name) => {
            find_sorter(name).ok_or_else(|| format!("unknown algorithm {name:?}"))
        }
        None if route_tuned => {
            Ok(std::sync::Arc::new(RobustSorter::with_table(crossover_table_cached(cfg))))
        }
        None => Ok(std::sync::Arc::new(RobustSorter::new())),
    }
}

/// Submission-side validation: everything that should bounce a spec at
/// enqueue time instead of inside a worker.
fn validate_spec(spec: &JobSpec, base: &RunConfig) -> Result<(), String> {
    if let Some(name) = &spec.algo {
        if find_sorter(name).is_none() {
            return Err(format!("unknown algorithm {name:?}"));
        }
    }
    let p = spec.p.unwrap_or(base.p);
    if p == 0 || !p.is_power_of_two() {
        return Err(format!("p must be a nonzero power of two, got {p}"));
    }
    Ok(())
}

struct Queued {
    id: usize,
    spec: JobSpec,
    submitted: Instant,
}

#[derive(Default)]
struct QueueState {
    jobs: VecDeque<Queued>,
    closed: bool,
}

/// The shared job queue: a mutexed deque plus a condvar so idle workers
/// park instead of spinning while the producer is still reading specs.
struct JobQueue {
    state: Mutex<QueueState>,
    ready: Condvar,
}

impl JobQueue {
    fn new() -> Self {
        Self { state: Mutex::new(QueueState::default()), ready: Condvar::new() }
    }

    fn push(&self, queued: Queued) {
        self.state.lock().unwrap().jobs.push_back(queued);
        self.ready.notify_one();
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.ready.notify_all();
    }

    /// Next job, blocking while the queue is open and empty; `None` once
    /// it is closed and drained.
    fn pop(&self) -> Option<Queued> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(j) = st.jobs.pop_front() {
                return Some(j);
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).unwrap();
        }
    }
}

/// The sort-as-a-service drain loop. One instance serves one stream of
/// jobs; construct another for the next stream.
pub struct Service {
    opts: ServeOptions,
}

impl Service {
    pub fn new(opts: ServeOptions) -> Self {
        Self { opts }
    }

    /// Drain an in-memory batch of specs. Invalid specs are rejected
    /// into `errors` (indexed by position) without stopping the rest.
    pub fn drain(&self, specs: Vec<JobSpec>) -> ServeOutcome {
        self.run(|queue, errors| {
            let mut admitted = 0usize;
            for (i, spec) in specs.into_iter().enumerate() {
                match validate_spec(&spec, &self.opts.base) {
                    Ok(()) => {
                        queue.push(Queued { id: admitted, spec, submitted: Instant::now() });
                        admitted += 1;
                    }
                    Err(e) => errors.push((i, e)),
                }
            }
        })
    }

    /// Drain a stream of JSONL lines (a spec file or stdin): jobs are
    /// admitted as their lines arrive, so workers overlap with input
    /// parsing. Blank lines are skipped; malformed or invalid lines are
    /// rejected into `errors` by 1-based line number.
    pub fn drain_lines(&self, lines: impl Iterator<Item = String>) -> ServeOutcome {
        self.run(|queue, errors| {
            let mut admitted = 0usize;
            for (lineno, line) in lines.enumerate() {
                if line.trim().is_empty() {
                    continue;
                }
                match JobSpec::parse(&line)
                    .and_then(|spec| validate_spec(&spec, &self.opts.base).map(|()| spec))
                {
                    Ok(spec) => {
                        queue.push(Queued { id: admitted, spec, submitted: Instant::now() });
                        admitted += 1;
                    }
                    Err(e) => errors.push((lineno + 1, e)),
                }
            }
        })
    }

    /// Shared drain core: acquire the job-level worker grant, spawn the
    /// helper workers, run `producer` on the caller thread, then have the
    /// caller join the serving until the queue is dry.
    fn run(&self, producer: impl FnOnce(&JobQueue, &mut Vec<(usize, String)>)) -> ServeOutcome {
        let t0 = Instant::now();
        let cache_before = crossover_cache_counters();
        let grant = exec::acquire_job_workers(self.opts.jobs.max(1));
        // the caller serves too, so only granted-1 helpers are spawned
        // (a grant of 0 or 1 means pure inline serving)
        let helpers = grant.granted().saturating_sub(1);

        let queue = JobQueue::new();
        let sink: Mutex<Vec<(JobRecord, RunReport)>> = Mutex::new(Vec::new());
        let mut errors = Vec::new();
        std::thread::scope(|s| {
            for _ in 0..helpers {
                s.spawn(|| self.worker(&queue, &sink));
            }
            producer(&queue, &mut errors);
            queue.close();
            self.worker(&queue, &sink);
        });
        drop(grant);

        let mut done = sink.into_inner().unwrap();
        done.sort_by_key(|(rec, _)| rec.id);
        let (records, reports): (Vec<_>, Vec<_>) = done.into_iter().unzip();

        let cache_after = crossover_cache_counters();
        let wall_s = t0.elapsed().as_secs_f64();
        let stats = Stats::from_records(
            &records,
            wall_s,
            (cache_after.0 - cache_before.0, cache_after.1 - cache_before.1),
        );
        ServeOutcome { reports, records, errors, stats }
    }

    /// One worker's serving loop. Each worker owns one lazily-built
    /// [`Runner`] reused across every job it serves, so same-`p` job
    /// sequences keep the simulated machine's allocations warm.
    fn worker(&self, queue: &JobQueue, sink: &Mutex<Vec<(JobRecord, RunReport)>>) {
        let mut runner: Option<Runner> = None;
        while let Some(job) = queue.pop() {
            let admitted = Instant::now();
            let cfg = job.spec.config(&self.opts.base);
            // cannot fail: names were checked at submission and the
            // registry is append-only; an untargeted job's tuned table
            // probe happens here, inside its service window, caching
            // per machine config for every later job
            let sorter = resolve_sorter(&job.spec, &cfg, self.opts.route_tuned)
                .expect("spec validated at submission");
            let input = crate::input::generate(&cfg, job.spec.dist);
            if runner.is_none() {
                runner = Some(
                    Runner::new(cfg.clone())
                        .validate(self.opts.validate)
                        .keep_output(self.opts.keep_output),
                );
            }
            let r = runner.as_mut().unwrap();
            r.set_config(cfg.clone());
            let (report, meta): (RunReport, RunMeta) = r.run_with_meta(sorter.as_ref(), input);
            let done = Instant::now();
            let record = JobRecord {
                id: job.id,
                algorithm: report.algorithm,
                p: cfg.p,
                n_total: cfg.n_total(),
                sim_time: report.time,
                crashed: report.crashed.is_some(),
                queue_us: (admitted - job.submitted).as_secs_f64() * 1e6,
                service_us: (done - admitted).as_secs_f64() * 1e6,
                total_us: (done - job.submitted).as_secs_f64() * 1e6,
                machine_reused: meta.machine_reused,
            };
            sink.lock().unwrap().push((record, report));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::input::Distribution;

    fn tiny_opts(jobs: usize) -> ServeOptions {
        ServeOptions {
            jobs,
            base: RunConfig::default().with_p(8).with_n_per_pe(16),
            ..ServeOptions::default()
        }
    }

    #[test]
    fn drain_preserves_submission_order_and_counts() {
        let specs: Vec<JobSpec> = (0..6)
            .map(|i| JobSpec {
                seed: Some(100 + i as u64),
                algo: Some("RQuick".into()),
                ..JobSpec::default()
            })
            .collect();
        let out = Service::new(tiny_opts(3)).drain(specs);
        assert!(out.errors.is_empty());
        assert_eq!(out.reports.len(), 6);
        assert_eq!(out.records.len(), 6);
        for (i, rec) in out.records.iter().enumerate() {
            assert_eq!(rec.id, i, "records sorted by admission id");
            assert_eq!(rec.algorithm, "RQuick");
            assert!(rec.total_us >= rec.service_us);
        }
        assert_eq!(out.stats.jobs, 6);
        assert_eq!(out.stats.per_sorter, vec![("RQuick", 6)]);
        assert_eq!(out.stats.machine_reuse_hits + out.stats.machine_fresh_builds, 6);
    }

    #[test]
    fn invalid_specs_bounce_without_stopping_the_stream() {
        let lines = [
            r#"{"seed": 1, "algo": "RQuick"}"#,
            r#"{"algo": "NoSuchSorter"}"#,
            "this is not json",
            "",
            r#"{"p": 12}"#,
            r#"{"seed": 2, "algo": "Rfis"}"#,
        ];
        let out =
            Service::new(tiny_opts(2)).drain_lines(lines.iter().map(|s| s.to_string()));
        assert_eq!(out.reports.len(), 2, "two valid jobs served");
        assert_eq!(out.errors.len(), 3);
        let by_line: Vec<usize> = out.errors.iter().map(|(l, _)| *l).collect();
        assert_eq!(by_line, vec![2, 3, 5], "1-based line numbers; blank line skipped");
        assert!(out.errors[0].1.contains("unknown algorithm"));
        assert!(out.errors[2].1.contains("power of two"));
    }

    /// Untargeted specs route through the Robust selector. Paper-table
    /// routing only — the tuned path would bump the process-wide
    /// crossover-cache counters this binary's tuning test asserts on;
    /// tuned routing is covered by `tests/serve_equivalence.rs`.
    #[test]
    fn untargeted_jobs_route_through_the_selector() {
        let spec =
            JobSpec { dist: Distribution::Staggered, seed: Some(42), ..JobSpec::default() };
        let mut opts = tiny_opts(1);
        opts.route_tuned = false;
        let out = Service::new(opts).drain(vec![spec]);
        assert_eq!(out.reports.len(), 1);
        assert_eq!(out.reports[0].algorithm, "Robust");
        assert!(out.reports[0].crashed.is_none());
    }

    #[test]
    fn grant_of_zero_or_one_serves_inline() {
        // request 1 job-worker: the caller thread serves everything
        let spec = JobSpec { algo: Some("GatherM".into()), ..JobSpec::default() };
        let out = Service::new(tiny_opts(1)).drain(vec![spec.clone(), spec]);
        assert_eq!(out.reports.len(), 2);
        assert_eq!(out.stats.machine_fresh_builds, 1, "one worker, one runner");
        assert_eq!(out.stats.machine_reuse_hits, 1);
    }
}
