//! Service-side accounting: one [`JobRecord`] per drained job (host
//! wall-clock queue/service/end-to-end latency plus what ran and where),
//! folded into a [`Stats`] digest — throughput, p50/p95/p99 latency
//! percentiles, per-sorter counts, machine-reuse and crossover-cache hit
//! rates. This is the half of a serve run that legitimately depends on
//! the host; the sorted outputs themselves stay bit-identical to
//! standalone `Runner::run` (see `tests/serve_equivalence.rs`).

use std::collections::BTreeMap;

use crate::metrics::Percentiles;

/// Timing and routing record for one completed job. Latencies are host
/// wall-clock microseconds; `sim_time` is the simulated α-β cost.
#[derive(Clone, Debug)]
pub struct JobRecord {
    /// Admission index (0-based, submission order).
    pub id: usize,
    /// Registry name of the sorter that ran (for untargeted jobs: the
    /// Robust selector).
    pub algorithm: &'static str,
    /// Effective machine width for this job.
    pub p: usize,
    /// Effective total input size.
    pub n_total: usize,
    /// Simulated time of the run (crashed runs report their cost up to
    /// the crash point, matching `RunReport::time`).
    pub sim_time: f64,
    /// Whether the run crashed (the report carries the message).
    pub crashed: bool,
    /// Submission → admission by a worker (µs).
    pub queue_us: f64,
    /// Admission → completion: input generation + sort + validation (µs).
    pub service_us: f64,
    /// Submission → completion (µs); `queue_us + service_us` up to clock
    /// granularity.
    pub total_us: f64,
    /// Whether the worker's `Runner` reused its simulated machine
    /// (same `p` as the worker's previous job) instead of rebuilding.
    pub machine_reused: bool,
}

/// Aggregate digest of one drained job stream.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub jobs: usize,
    pub crashed: usize,
    /// Wall time of the whole drain (submission of the first job through
    /// completion of the last), seconds.
    pub wall_s: f64,
    pub throughput_jobs_per_s: f64,
    pub queue: Percentiles,
    pub service: Percentiles,
    pub total: Percentiles,
    /// Completed jobs per sorter name, sorted by name.
    pub per_sorter: Vec<(&'static str, usize)>,
    pub machine_reuse_hits: usize,
    pub machine_fresh_builds: usize,
    /// Crossover-cache traffic during the drain: `(hits, probes)` delta
    /// of [`crate::experiments::tuning::crossover_cache_counters`].
    pub crossover_cache_hits: u64,
    pub crossover_probes: u64,
}

impl Stats {
    pub fn from_records(records: &[JobRecord], wall_s: f64, cache_delta: (u64, u64)) -> Self {
        let collect = |f: fn(&JobRecord) -> f64| -> Vec<f64> { records.iter().map(f).collect() };
        let mut per_sorter: BTreeMap<&'static str, usize> = BTreeMap::new();
        for r in records {
            *per_sorter.entry(r.algorithm).or_insert(0) += 1;
        }
        let hits = records.iter().filter(|r| r.machine_reused).count();
        Self {
            jobs: records.len(),
            crashed: records.iter().filter(|r| r.crashed).count(),
            wall_s,
            throughput_jobs_per_s: if wall_s > 0.0 { records.len() as f64 / wall_s } else { 0.0 },
            queue: Percentiles::of(&collect(|r| r.queue_us)),
            service: Percentiles::of(&collect(|r| r.service_us)),
            total: Percentiles::of(&collect(|r| r.total_us)),
            per_sorter: per_sorter.into_iter().collect(),
            machine_reuse_hits: hits,
            machine_fresh_builds: records.len() - hits,
            crossover_cache_hits: cache_delta.0,
            crossover_probes: cache_delta.1,
        }
    }

    /// Human-readable drain summary for the CLI.
    pub fn print(&self) {
        println!(
            "drained {} job(s) in {:.3} s  ({:.1} jobs/s, {} crashed)",
            self.jobs, self.wall_s, self.throughput_jobs_per_s, self.crashed
        );
        let row = |label: &str, p: &Percentiles| {
            println!(
                "  {label:<9} p50 {:>10.0} µs   p95 {:>10.0} µs   p99 {:>10.0} µs   max {:>10.0} µs",
                p.p50, p.p95, p.p99, p.max
            );
        };
        row("queue", &self.queue);
        row("service", &self.service);
        row("e2e", &self.total);
        let sorters: Vec<String> =
            self.per_sorter.iter().map(|(name, n)| format!("{name}×{n}")).collect();
        println!("  sorters   {}", sorters.join("  "));
        println!(
            "  machines  {} reused / {} fresh;  crossover cache {} hit(s) / {} probe(s)",
            self.machine_reuse_hits,
            self.machine_fresh_builds,
            self.crossover_cache_hits,
            self.crossover_probes
        );
    }

    /// The digest as a standalone JSON document (`BENCH_serve.json` /
    /// `rmps serve --json-out`).
    pub fn to_json(&self) -> String {
        let sorters: Vec<String> = self
            .per_sorter
            .iter()
            .map(|(name, n)| format!("\"{}\": {n}", name.replace('\\', "\\\\").replace('"', "\\\"")))
            .collect();
        format!(
            "{{\n  \"jobs\": {},\n  \"crashed\": {},\n  \"wall_s\": {:.6},\n  \
             \"throughput_jobs_per_s\": {:.3},\n  \"queue_us\": {},\n  \"service_us\": {},\n  \
             \"e2e_us\": {},\n  \"per_sorter\": {{{}}},\n  \
             \"machine_reuse\": {{\"hits\": {}, \"fresh\": {}}},\n  \
             \"crossover_cache\": {{\"hits\": {}, \"probes\": {}}}\n}}\n",
            self.jobs,
            self.crashed,
            self.wall_s,
            self.throughput_jobs_per_s,
            self.queue.to_json(),
            self.service.to_json(),
            self.total.to_json(),
            sorters.join(", "),
            self.machine_reuse_hits,
            self.machine_fresh_builds,
            self.crossover_cache_hits,
            self.crossover_probes
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: usize, algorithm: &'static str, queue_us: f64, service_us: f64, reused: bool) -> JobRecord {
        JobRecord {
            id,
            algorithm,
            p: 16,
            n_total: 256,
            sim_time: 1.0,
            crashed: false,
            queue_us,
            service_us,
            total_us: queue_us + service_us,
            machine_reused: reused,
        }
    }

    #[test]
    fn digest_counts_and_percentiles() {
        let mut records: Vec<JobRecord> =
            (0..10).map(|i| rec(i, "RQuick", (i + 1) as f64 * 10.0, 100.0, i > 0)).collect();
        records[3].algorithm = "GatherMerge";
        records[7].crashed = true;
        let s = Stats::from_records(&records, 0.5, (4, 6));
        assert_eq!(s.jobs, 10);
        assert_eq!(s.crashed, 1);
        assert!((s.throughput_jobs_per_s - 20.0).abs() < 1e-9);
        // nearest-rank over 10,20,...,100
        assert_eq!(s.queue.p50, 50.0);
        assert_eq!(s.queue.p99, 100.0);
        assert_eq!(s.service.p50, 100.0);
        assert_eq!(s.per_sorter, vec![("GatherMerge", 1), ("RQuick", 9)]);
        assert_eq!((s.machine_reuse_hits, s.machine_fresh_builds), (9, 1));
        assert_eq!((s.crossover_cache_hits, s.crossover_probes), (4, 6));
    }

    #[test]
    fn empty_stream_digest_is_well_formed() {
        let s = Stats::from_records(&[], 0.0, (0, 0));
        assert_eq!(s.jobs, 0);
        assert_eq!(s.throughput_jobs_per_s, 0.0);
        assert_eq!(s.queue, Percentiles::default());
        assert!(s.to_json().contains("\"jobs\": 0"));
    }

    #[test]
    fn json_digest_shape() {
        let s = Stats::from_records(&[rec(0, "RQuick", 5.0, 10.0, false)], 0.25, (1, 2));
        let j = s.to_json();
        for key in [
            "\"jobs\": 1",
            "\"throughput_jobs_per_s\": 4.000",
            "\"queue_us\"",
            "\"service_us\"",
            "\"e2e_us\"",
            "\"per_sorter\": {\"RQuick\": 1}",
            "\"machine_reuse\": {\"hits\": 0, \"fresh\": 1}",
            "\"crossover_cache\": {\"hits\": 1, \"probes\": 2}",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }
}
