//! Job specifications for the sort-as-a-service front-end: one JSON
//! object per line (JSONL), read from a file or stdin by `rmps serve`.
//!
//! The build environment is offline (no serde), so the parser is a
//! hand-rolled reader for exactly the shape a job spec needs: one flat
//! object of string / number / bool / null fields. Unknown fields are
//! rejected — a typo'd `"ditst"` silently inheriting the default
//! distribution would corrupt a latency study.
//!
//! ```text
//! {"n_per_pe": 4096, "dist": "Staggered", "seed": 7, "algo": "RQuick"}
//! {"sparsity": 8, "seed": 8}
//! {"n_per_pe": 512, "dist": "Zero", "algo": "HykSort", "mem_cap": 2.0, "p": 64}
//! ```
//!
//! Every field is optional; omitted fields inherit the service's base
//! [`RunConfig`] (the CLI's machine flags). A job without `"algo"` is
//! *untargeted*: the service routes it through the Robust selector (by
//! default with a tuned crossover table cached per machine config — see
//! [`crate::serve`]).

use crate::config::RunConfig;
use crate::input::Distribution;

/// One queued sort job, as parsed from a JSONL line. `None` fields
/// inherit the service's base config.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// Dense elements per PE. Ignored when `sparsity > 1`.
    pub n_per_pe: Option<usize>,
    /// Sparsity factor (`> 1` = one element per `s` PEs), like the CLI's
    /// `--sparsity`; takes precedence over `n_per_pe`.
    pub sparsity: Option<usize>,
    /// Input distribution (default: the base config's generator default,
    /// Uniform).
    pub dist: Distribution,
    /// Master RNG seed for this job's input.
    pub seed: Option<u64>,
    /// Registry name of a forced sorter; `None` (or JSON `null`) routes
    /// through the Robust selector.
    pub algo: Option<String>,
    /// Simulated machine width (power of two).
    pub p: Option<usize>,
    /// Cost-model overrides.
    pub alpha: Option<f64>,
    pub beta: Option<f64>,
    /// Memory-cap override: outer `None` = inherit, `Some(None)` (JSON
    /// `null`) = lift the cap, `Some(Some(f))` = cap at `f · n/p`.
    pub mem_cap: Option<Option<f64>>,
}

impl Default for JobSpec {
    fn default() -> Self {
        Self {
            n_per_pe: None,
            sparsity: None,
            dist: Distribution::Uniform,
            seed: None,
            algo: None,
            p: None,
            alpha: None,
            beta: None,
            mem_cap: None,
        }
    }
}

impl JobSpec {
    /// The effective run configuration: the service's base config with
    /// this spec's overrides applied. Size semantics follow the CLI:
    /// `sparsity > 1` makes the job sparse (ignoring `n_per_pe`),
    /// otherwise the job is dense at `n_per_pe` (or the base's).
    pub fn config(&self, base: &RunConfig) -> RunConfig {
        let mut cfg = base.clone();
        if let Some(p) = self.p {
            cfg.p = p;
        }
        if let Some(seed) = self.seed {
            cfg.seed = seed;
        }
        if let Some(alpha) = self.alpha {
            cfg.cost.alpha = alpha;
        }
        if let Some(beta) = self.beta {
            cfg.cost.beta = beta;
        }
        if let Some(cap) = self.mem_cap {
            cfg.mem_cap_factor = cap;
        }
        match self.sparsity {
            Some(s) if s > 1 => cfg.with_sparsity(s),
            _ => {
                let m = self.n_per_pe.unwrap_or(cfg.n_per_pe);
                cfg.with_n_per_pe(m)
            }
        }
    }

    /// Parse one JSONL line. Errors name the offending field; unknown
    /// fields are errors too.
    pub fn parse(line: &str) -> Result<JobSpec, String> {
        let mut spec = JobSpec::default();
        for (key, val) in parse_flat_object(line)? {
            match key.as_str() {
                "n_per_pe" => spec.n_per_pe = Some(as_usize(&key, &val)?),
                "sparsity" => spec.sparsity = Some(as_usize(&key, &val)?),
                "p" => spec.p = Some(as_usize(&key, &val)?),
                "seed" => spec.seed = Some(as_u64(&key, &val)?),
                "alpha" => spec.alpha = Some(as_f64(&key, &val)?),
                "beta" => spec.beta = Some(as_f64(&key, &val)?),
                "dist" => {
                    let name = as_str(&key, &val)?;
                    spec.dist = Distribution::parse(&name)
                        .ok_or_else(|| format!("unknown distribution {name:?}"))?;
                }
                "algo" => {
                    spec.algo = match val {
                        JsonVal::Null => None,
                        other => Some(as_str(&key, &other)?),
                    }
                }
                "mem_cap" => {
                    spec.mem_cap = Some(match val {
                        JsonVal::Null => None,
                        other => Some(as_f64(&key, &other)?),
                    })
                }
                other => return Err(format!("unknown field {other:?}")),
            }
        }
        Ok(spec)
    }
}

/// A parsed JSON scalar — all a flat job spec can hold.
#[derive(Clone, Debug, PartialEq)]
enum JsonVal {
    Str(String),
    Num(f64),
    Bool(bool),
    Null,
}

fn as_f64(key: &str, v: &JsonVal) -> Result<f64, String> {
    match v {
        JsonVal::Num(n) => Ok(*n),
        other => Err(format!("field {key:?} must be a number, got {other:?}")),
    }
}

/// Integer fields ride in JSON numbers; require a non-negative integral
/// value inside f64's exact range (2^53 — seeds and sizes both fit).
fn as_u64(key: &str, v: &JsonVal) -> Result<u64, String> {
    let n = as_f64(key, v)?;
    if n.fract() != 0.0 || !(0.0..=9007199254740992.0).contains(&n) {
        return Err(format!("field {key:?} must be a non-negative integer, got {n}"));
    }
    Ok(n as u64)
}

fn as_usize(key: &str, v: &JsonVal) -> Result<usize, String> {
    Ok(as_u64(key, v)? as usize)
}

fn as_str(key: &str, v: &JsonVal) -> Result<String, String> {
    match v {
        JsonVal::Str(s) => Ok(s.clone()),
        other => Err(format!("field {key:?} must be a string, got {other:?}")),
    }
}

/// Parse `{"key": value, ...}` with scalar values only. Positions in
/// error messages are byte offsets into the line.
fn parse_flat_object(line: &str) -> Result<Vec<(String, JsonVal)>, String> {
    let bytes = line.as_bytes();
    let mut pos = 0usize;
    let mut fields = Vec::new();

    fn skip_ws(bytes: &[u8], pos: &mut usize) {
        while *pos < bytes.len() && bytes[*pos].is_ascii_whitespace() {
            *pos += 1;
        }
    }

    fn expect(bytes: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
        skip_ws(bytes, pos);
        if *pos < bytes.len() && bytes[*pos] == c {
            *pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, *pos))
        }
    }

    fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String, String> {
        expect(bytes, pos, b'"')?;
        let mut out = String::new();
        while *pos < bytes.len() {
            match bytes[*pos] {
                b'"' => {
                    *pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    *pos += 1;
                    let esc = *bytes.get(*pos).ok_or("unterminated escape")?;
                    out.push(match esc {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        other => {
                            return Err(format!(
                                "unsupported escape \\{} at byte {}",
                                other as char, *pos
                            ))
                        }
                    });
                    *pos += 1;
                }
                _ => {
                    // multi-byte UTF-8 sequences pass through verbatim
                    let start = *pos;
                    *pos += 1;
                    while *pos < bytes.len() && bytes[*pos] & 0xC0 == 0x80 {
                        *pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&bytes[start..*pos]).map_err(|e| e.to_string())?,
                    );
                }
            }
        }
        Err("unterminated string".into())
    }

    fn parse_value(bytes: &[u8], pos: &mut usize) -> Result<JsonVal, String> {
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b'"') => Ok(JsonVal::Str(parse_string(bytes, pos)?)),
            Some(b't') if bytes[*pos..].starts_with(b"true") => {
                *pos += 4;
                Ok(JsonVal::Bool(true))
            }
            Some(b'f') if bytes[*pos..].starts_with(b"false") => {
                *pos += 5;
                Ok(JsonVal::Bool(false))
            }
            Some(b'n') if bytes[*pos..].starts_with(b"null") => {
                *pos += 4;
                Ok(JsonVal::Null)
            }
            Some(_) => {
                let start = *pos;
                while *pos < bytes.len()
                    && matches!(bytes[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
                {
                    *pos += 1;
                }
                let tok = std::str::from_utf8(&bytes[start..*pos]).map_err(|e| e.to_string())?;
                tok.parse::<f64>()
                    .map(JsonVal::Num)
                    .map_err(|_| format!("invalid JSON value {tok:?} at byte {start}"))
            }
            None => Err(format!("expected a value at byte {}", *pos)),
        }
    }

    expect(bytes, &mut pos, b'{')?;
    skip_ws(bytes, &mut pos);
    if pos < bytes.len() && bytes[pos] == b'}' {
        pos += 1;
    } else {
        loop {
            skip_ws(bytes, &mut pos);
            let key = parse_string(bytes, &mut pos)?;
            expect(bytes, &mut pos, b':')?;
            let val = parse_value(bytes, &mut pos)?;
            fields.push((key, val));
            skip_ws(bytes, &mut pos);
            match bytes.get(pos) {
                Some(b',') => pos += 1,
                Some(b'}') => {
                    pos += 1;
                    break;
                }
                _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
            }
        }
    }
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing content after object at byte {pos}"));
    }
    Ok(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_spec_round_trips() {
        let spec = JobSpec::parse(
            r#"{"n_per_pe": 4096, "dist": "Staggered", "seed": 7, "algo": "RQuick",
                "p": 64, "alpha": 2000, "beta": 8.5, "mem_cap": 4.0}"#
                .replace('\n', " ")
                .as_str(),
        )
        .unwrap();
        assert_eq!(spec.n_per_pe, Some(4096));
        assert_eq!(spec.dist, Distribution::Staggered);
        assert_eq!(spec.seed, Some(7));
        assert_eq!(spec.algo.as_deref(), Some("RQuick"));
        assert_eq!(spec.p, Some(64));
        assert_eq!(spec.alpha, Some(2000.0));
        assert_eq!(spec.beta, Some(8.5));
        assert_eq!(spec.mem_cap, Some(Some(4.0)));
    }

    #[test]
    fn minimal_and_null_fields() {
        let spec = JobSpec::parse("{}").unwrap();
        assert_eq!(spec, JobSpec::default());
        let spec = JobSpec::parse(r#"{"algo": null, "mem_cap": null, "sparsity": 8}"#).unwrap();
        assert_eq!(spec.algo, None);
        assert_eq!(spec.mem_cap, Some(None), "null lifts the cap");
        assert_eq!(spec.sparsity, Some(8));
    }

    #[test]
    fn malformed_lines_are_rejected_with_field_names() {
        for (line, needle) in [
            (r#"{"n_per_pe": "many"}"#, "n_per_pe"),
            (r#"{"dist": "Uniformm"}"#, "unknown distribution"),
            (r#"{"ditst": "Uniform"}"#, "unknown field"),
            (r#"{"seed": -1}"#, "non-negative"),
            (r#"{"seed": 1.5}"#, "non-negative integer"),
            (r#"{"n_per_pe": 3"#, "expected"),
            (r#"{"a": 1} extra"#, "trailing"),
            ("not json", "expected"),
        ] {
            let err = JobSpec::parse(line).unwrap_err();
            assert!(err.contains(needle), "{line:?} → {err:?}");
        }
    }

    #[test]
    fn config_merges_over_base() {
        let base = RunConfig::default().with_p(256).with_n_per_pe(1024);
        // dense override
        let spec = JobSpec::parse(r#"{"n_per_pe": 32, "seed": 9, "p": 64}"#).unwrap();
        let cfg = spec.config(&base);
        assert_eq!((cfg.p, cfg.n_per_pe, cfg.sparsity, cfg.seed), (64, 32, 1, 9));
        // sparse wins over dense, like the CLI
        let spec = JobSpec::parse(r#"{"sparsity": 8, "n_per_pe": 32}"#).unwrap();
        let cfg = spec.config(&base);
        assert_eq!(cfg.sparsity, 8);
        assert!(cfg.n_over_p() < 1.0);
        // mem_cap: null lifts, number scales, absent inherits
        assert_eq!(JobSpec::parse(r#"{"mem_cap": null}"#).unwrap().config(&base).mem_cap_factor, None);
        assert_eq!(
            JobSpec::parse(r#"{"mem_cap": 4.0}"#).unwrap().config(&base).mem_cap_factor,
            Some(4.0)
        );
        assert_eq!(JobSpec::parse("{}").unwrap().config(&base).mem_cap_factor, base.mem_cap_factor);
        // cost overrides
        let cfg = JobSpec::parse(r#"{"alpha": 100, "beta": 2}"#).unwrap().config(&base);
        assert_eq!((cfg.cost.alpha, cfg.cost.beta), (100.0, 2.0));
    }

    #[test]
    fn escapes_and_unicode_in_strings() {
        let spec = JobSpec::parse(r#"{"algo": "My\"Sorter\\v2"}"#).unwrap();
        assert_eq!(spec.algo.as_deref(), Some("My\"Sorter\\v2"));
        let err = JobSpec::parse(r#"{"algo": "\u0041"}"#).unwrap_err();
        assert!(err.contains("unsupported escape"), "{err}");
    }
}
