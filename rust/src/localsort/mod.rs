//! Node-local sort backends.
//!
//! Every algorithm starts by sorting each PE's fragment. Three backends:
//! pure-Rust pdqsort ([`RustSort`]), an LSD radix sort on the packed
//! `(key, id)` bytes with constant-digit skipping ([`RadixSort`] — on the
//! 32-bit key ranges and small origin ids the generators produce, most of
//! the 16 byte passes vanish, the IPS⁴o observation for fixed-width
//! integer keys), and — behind the off-by-default `xla` cargo feature —
//! the PJRT-executed Pallas bitonic network (`XlaSort` in
//! [`crate::runtime`]), which batches all fragments of a round into one
//! executable launch — the AOT artifact on the hot path.
//!
//! The built-in host backends are selectable by name: programmatically
//! via [`crate::algorithms::Runner::backend`] / [`backend_by_name`],
//! process-wide via [`set_default_backend`] (the CLI `--sort-backend`
//! flag), or by the `RMPS_SORT_BACKEND` environment variable. Every
//! backend produces the identical ascending `(key, id)` sequence — the
//! order is a strict total order, so the choice can never change a
//! `RunReport` (pinned in `rust/tests/kernel_equivalence.rs`).
//!
//! The *virtual* cost charged to PE clocks is the same in every case
//! (`cmp·m·log m`); the backend choice affects only host wallclock, which
//! is what the §Perf benchmarks measure.

use crate::elements::Elem;
use crate::sim::ParSpec;

/// A batched local-sort backend. Sorts each run ascending in full
/// `(key, id)` order.
pub trait SortBackend {
    fn sort_runs(&mut self, runs: &mut [&mut Vec<Elem>]);
    fn name(&self) -> &'static str;

    /// A stateless per-run sort function, if the backend supports
    /// dispatching one run at a time — [`sort_all`] then fans the runs
    /// out over the PE-task pool. `None` (the default) keeps the batched
    /// [`SortBackend::sort_runs`] path, which backends that fuse all
    /// fragments into one launch (the PJRT `XlaSort`) require.
    fn par_run_sort(&self) -> Option<fn(&mut Vec<Elem>)> {
        None
    }
}

/// Pure-Rust backend: `slice::sort_unstable` (pdqsort) per run.
#[derive(Default, Clone, Copy, Debug)]
pub struct RustSort;

impl SortBackend for RustSort {
    fn sort_runs(&mut self, runs: &mut [&mut Vec<Elem>]) {
        for run in runs {
            run.sort_unstable();
        }
    }

    fn name(&self) -> &'static str {
        "rust-pdqsort"
    }

    fn par_run_sort(&self) -> Option<fn(&mut Vec<Elem>)> {
        Some(|run| run.sort_unstable())
    }
}

/// Pure-Rust LSD radix backend: byte-wise counting sort over the packed
/// `(key, id)` 128-bit value, least-significant digit first, skipping
/// every digit position whose byte is constant across the run (detected
/// with one cheap OR/AND prescan). Runs below [`RADIX_MIN_RUN`] fall back
/// to pdqsort — identical output either way, since ascending `(key, id)`
/// is a strict total order.
#[derive(Default, Clone, Copy, Debug)]
pub struct RadixSort;

/// Run length below which [`RadixSort`] delegates to pdqsort: the fixed
/// histogram/scatter machinery only amortizes once a run clearly exceeds
/// the 256-entry digit tables.
pub const RADIX_MIN_RUN: usize = 128;

impl SortBackend for RadixSort {
    fn sort_runs(&mut self, runs: &mut [&mut Vec<Elem>]) {
        for run in runs {
            radix_sort_run(run);
        }
    }

    fn name(&self) -> &'static str {
        "radix-lsd"
    }

    fn par_run_sort(&self) -> Option<fn(&mut Vec<Elem>)> {
        Some(radix_sort_run)
    }
}

std::thread_local! {
    /// Ping-pong partner buffer for [`radix_sort_run`]. Thread-local so
    /// the stateless `par_run_sort` fn stays allocation-free on warm
    /// pool workers (the workers are persistent — see `crate::exec`).
    static RADIX_TMP: std::cell::RefCell<Vec<Elem>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Sort one run ascending in `(key, id)` order with the digit-skipping
/// LSD radix kernel (the [`RadixSort`] per-run entry point).
pub fn radix_sort_run(run: &mut Vec<Elem>) {
    RADIX_TMP.with(|tmp| radix_sort(run, &mut tmp.borrow_mut()));
}

/// One scatter pass of the LSD radix sort: distribute `src` into `dst`
/// by byte `d` of the packed `(key, id)` value, using `cur` as the
/// per-byte write cursors (already prefix-summed). Stable.
#[inline]
fn radix_scatter(src: &[Elem], dst: &mut [Elem], d: u32, cur: &mut [usize; 256]) {
    let shift = 8 * d;
    if d < 8 {
        for e in src {
            let b = ((e.id >> shift) & 0xFF) as usize;
            dst[cur[b]] = *e;
            cur[b] += 1;
        }
    } else {
        let shift = shift - 64;
        for e in src {
            let b = ((e.key >> shift) & 0xFF) as usize;
            dst[cur[b]] = *e;
            cur[b] += 1;
        }
    }
}

/// The radix kernel body: OR/AND prescan finds the varying byte
/// positions, one histogram pass fills the 256-entry tables of **all**
/// varying digits at once (they stay cache-resident), then one stable
/// scatter per varying digit ping-pongs between `v` and `tmp`.
fn radix_sort(v: &mut [Elem], tmp: &mut Vec<Elem>) {
    let n = v.len();
    if n < RADIX_MIN_RUN {
        v.sort_unstable();
        return;
    }
    // a byte position is constant across the run iff OR and AND agree on
    // it — on 32-bit key ranges with small ids this kills most digits
    let (mut all_or, mut all_and) = (0u128, !0u128);
    for e in v.iter() {
        let x = ((e.key as u128) << 64) | e.id as u128;
        all_or |= x;
        all_and &= x;
    }
    let varying = all_or ^ all_and;
    let mut digits = [0u32; 16];
    let mut nd = 0usize;
    for d in 0..16u32 {
        if (varying >> (8 * d)) & 0xFF != 0 {
            digits[nd] = d;
            nd += 1;
        }
    }
    if nd == 0 {
        return; // every element identical — already sorted
    }
    let digits = &digits[..nd];
    // histograms of every varying digit in one pass over the elements
    let mut hist = vec![[0usize; 256]; nd];
    for e in v.iter() {
        let x = ((e.key as u128) << 64) | e.id as u128;
        for (h, &d) in hist.iter_mut().zip(digits) {
            h[((x >> (8 * d)) & 0xFF) as usize] += 1;
        }
    }
    // grow-only resize: every slot of tmp[..n] is written before it is
    // read, so stale contents from a previous (longer) run never surface
    if tmp.len() < n {
        tmp.resize(n, Elem::with_id(0, 0));
    }
    let tmp = &mut tmp[..n];
    let mut in_v = true;
    for (h, &d) in hist.iter_mut().zip(digits) {
        // counts → exclusive prefix sums → write cursors
        let mut sum = 0usize;
        for c in h.iter_mut() {
            let count = *c;
            *c = sum;
            sum += count;
        }
        if in_v {
            radix_scatter(v, tmp, d, h);
        } else {
            radix_scatter(tmp, v, d, h);
        }
        in_v = !in_v;
    }
    if !in_v {
        v.copy_from_slice(tmp);
    }
}

/// Backend selection tag: 1 = [`RustSort`], 2 = [`RadixSort`]; 0 = no
/// process-wide override installed.
static DEFAULT_BACKEND: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// The `name()`s of the built-in host backends (the values
/// [`backend_by_name`] accepts, aliases aside) — for CLI help and error
/// messages.
pub const BACKEND_NAMES: [&str; 2] = ["rust-pdqsort", "radix-lsd"];

/// Loose name equality in the [`crate::input::Distribution::parse`]
/// style: ASCII case-insensitive, `-`/`_` ignored, allocation-free.
fn name_eq(a: &str, b: &str) -> bool {
    let mut ai = a.bytes().filter(|c| *c != b'-' && *c != b'_').map(|c| c.to_ascii_lowercase());
    let mut bi = b.bytes().filter(|c| *c != b'-' && *c != b'_').map(|c| c.to_ascii_lowercase());
    loop {
        match (ai.next(), bi.next()) {
            (None, None) => return true,
            (Some(x), Some(y)) if x == y => {}
            _ => return false,
        }
    }
}

fn backend_tag(name: &str) -> Option<usize> {
    if name_eq(name, "rust-pdqsort") || name_eq(name, "pdqsort") {
        Some(1)
    } else if name_eq(name, "radix-lsd") || name_eq(name, "radix") {
        Some(2)
    } else {
        None
    }
}

fn backend_from_tag(tag: usize) -> Box<dyn SortBackend> {
    match tag {
        2 => Box::new(RadixSort),
        _ => Box::new(RustSort),
    }
}

/// A boxed built-in backend by `name()` (or the short aliases `pdqsort` /
/// `radix`); `None` for unknown names. Matching is case-insensitive and
/// ignores dashes/underscores, like `Distribution::parse`.
pub fn backend_by_name(name: &str) -> Option<Box<dyn SortBackend>> {
    backend_tag(name).map(backend_from_tag)
}

/// Install a process-wide default sort backend (what the CLI
/// `--sort-backend` flag calls); returns `false` and changes nothing if
/// the name is unknown. Takes precedence over `RMPS_SORT_BACKEND`.
/// Affects [`default_backend`] callers constructed afterwards (every
/// [`crate::algorithms::Runner::new`]). Host wallclock only — outputs
/// and reports are bit-identical for every backend.
pub fn set_default_backend(name: &str) -> bool {
    match backend_tag(name) {
        Some(tag) => {
            DEFAULT_BACKEND.store(tag, std::sync::atomic::Ordering::Relaxed);
            true
        }
        None => false,
    }
}

/// The process default backend: the [`set_default_backend`] override if
/// one was installed, else `RMPS_SORT_BACKEND` (parsed once on first
/// use; unknown names are ignored), else [`RustSort`] — the backend
/// every `Runner` starts with.
pub fn default_backend() -> Box<dyn SortBackend> {
    let over = DEFAULT_BACKEND.load(std::sync::atomic::Ordering::Relaxed);
    if over > 0 {
        return backend_from_tag(over);
    }
    static ENV: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    let tag = *ENV.get_or_init(|| {
        std::env::var("RMPS_SORT_BACKEND").ok().and_then(|s| backend_tag(&s)).unwrap_or(1)
    });
    backend_from_tag(tag)
}

/// Sort all of a machine's per-PE fragments with `backend`, charging each
/// PE the model's sort cost.
///
/// Per-run backends ([`SortBackend::par_run_sort`]) execute as one
/// pool-scheduled PE task per fragment, with the `work_sort` charge
/// recorded by the same task that sorts — cost and work originate from
/// the same call, mirroring the Exchange charged == moved discipline —
/// and settled in PE order, bit-identical to the historical
/// charge-loop-then-sort sequence. Batch-only backends keep the two-phase
/// shape (the charge loop already was in PE order).
pub fn sort_all(
    mach: &mut crate::sim::Machine,
    data: &mut [Vec<Elem>],
    backend: &mut dyn SortBackend,
) {
    if let Some(sort_one) = backend.par_run_sort() {
        let total: usize = data.iter().map(Vec::len).sum();
        mach.par_pes(0, ParSpec::work(total), data, |ctx, run| {
            ctx.work_sort(run.len());
            sort_one(run);
        });
    } else {
        for (pe, run) in data.iter().enumerate() {
            mach.work_sort(pe, run.len());
        }
        let mut refs: Vec<&mut Vec<Elem>> = data.iter_mut().collect();
        backend.sort_runs(&mut refs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CostModel;
    use crate::rng::Rng;
    use crate::sim::Machine;

    #[test]
    fn rust_sort_orders_by_key_then_id() {
        let mut runs = vec![vec![
            Elem::with_id(5, 2),
            Elem::with_id(1, 9),
            Elem::with_id(5, 1),
            Elem::with_id(0, 0),
        ]];
        let mut refs: Vec<&mut Vec<Elem>> = runs.iter_mut().collect();
        RustSort.sort_runs(&mut refs);
        assert!(crate::elements::is_sorted(&runs[0]));
        assert_eq!(runs[0][1], Elem::with_id(1, 9));
        assert_eq!(runs[0][2], Elem::with_id(5, 1));
    }

    #[test]
    fn sort_all_charges_cost() {
        let mut mach = Machine::new(2, CostModel::default());
        let mut rng = Rng::seeded(0, 0);
        let mut data: Vec<Vec<Elem>> = (0..2)
            .map(|pe| (0..128).map(|i| Elem::new(rng.next_u64(), pe, i)).collect())
            .collect();
        sort_all(&mut mach, &mut data, &mut RustSort);
        assert!(data.iter().all(|r| crate::elements::is_sorted(r)));
        assert!(mach.clock(0) > 0.0 && mach.clock(1) > 0.0);
    }

    /// A batch-only backend (no `par_run_sort`, like `XlaSort`) and the
    /// pool-scheduled per-run path must charge identical costs and produce
    /// identical runs — the inline gate pinned low so the per-run path
    /// really runs on the persistent pool whatever `RMPS_PAR_MIN_WORK`
    /// says.
    #[test]
    fn par_and_batch_paths_agree_bitwise() {
        struct BatchOnly;
        impl SortBackend for BatchOnly {
            fn sort_runs(&mut self, runs: &mut [&mut Vec<Elem>]) {
                for run in runs {
                    run.sort_unstable();
                }
            }
            fn name(&self) -> &'static str {
                "batch-only"
            }
        }
        let p = 8;
        let gen = |seed| -> Vec<Vec<Elem>> {
            let mut rng = Rng::seeded(seed, 1);
            (0..p).map(|pe| (0..1024).map(|i| Elem::new(rng.next_u64(), pe, i)).collect()).collect()
        };
        let mut batch_mach = Machine::new(p, CostModel::default());
        let mut batch_data = gen(9);
        sort_all(&mut batch_mach, &mut batch_data, &mut BatchOnly);
        let mut par_mach = Machine::new(p, CostModel::default());
        par_mach.set_pe_jobs(4);
        par_mach.set_par_min_work(1);
        let mut par_data = gen(9);
        sort_all(&mut par_mach, &mut par_data, &mut RustSort);
        assert_eq!(batch_data, par_data);
        for pe in 0..p {
            assert_eq!(batch_mach.clock(pe).to_bits(), par_mach.clock(pe).to_bits(), "pe {pe}");
        }
        assert_eq!(
            batch_mach.stats.local_work.to_bits(),
            par_mach.stats.local_work.to_bits()
        );
    }

    /// Radix and pdqsort agree element for element on adversarial runs:
    /// random 64-bit keys, duplicate-heavy, all-equal (key *and* id),
    /// boundary values, tiny runs below the pdqsort fallback threshold,
    /// and runs straddling [`RADIX_MIN_RUN`].
    #[test]
    fn radix_matches_pdqsort_bitwise() {
        let mut rng = Rng::seeded(11, 4);
        let cases: Vec<Vec<Elem>> = vec![
            Vec::new(),
            vec![Elem::with_id(3, 9)],
            (0..RADIX_MIN_RUN - 1).map(|i| Elem::new(rng.next_u64(), 0, i)).collect(),
            (0..RADIX_MIN_RUN).map(|i| Elem::new(rng.next_u64(), 1, i)).collect(),
            (0..4096).map(|i| Elem::new(rng.next_u64(), 2, i)).collect(),
            // 32-bit key range, small ids — the generator shape that
            // makes most digit passes constant
            (0..4096).map(|i| Elem::new(rng.next_u64() >> 32, 3, i)).collect(),
            // duplicate-heavy and all-equal
            (0..2048).map(|i| Elem::new(rng.next_u64() % 7, 4, i)).collect(),
            vec![Elem::with_id(5, 5); 1024],
            // boundary values in both halves of the packed word
            (0..1024)
                .map(|i| {
                    let k = [0u64, 1, u64::MAX, u64::MAX - 1][i % 4];
                    Elem::with_id(k, [u64::MAX, 0, 1 << 40, 7][(i / 4) % 4])
                })
                .collect(),
        ];
        for (ci, case) in cases.into_iter().enumerate() {
            let mut via_radix = case.clone();
            let mut via_pdq = case;
            radix_sort_run(&mut via_radix);
            via_pdq.sort_unstable();
            assert_eq!(via_radix, via_pdq, "case {ci}");
            // warm thread-local tmp: a second (smaller) run must not see
            // stale slots
            let mut small: Vec<Elem> =
                (0..RADIX_MIN_RUN + 3).map(|i| Elem::new(rng.next_u64(), 9, i)).collect();
            let mut expect = small.clone();
            radix_sort_run(&mut small);
            expect.sort_unstable();
            assert_eq!(small, expect, "case {ci} warm-tmp rerun");
        }
    }

    /// The backend registry: both built-ins resolve by name (loosely
    /// matched), unknown names don't, and the process default follows
    /// [`set_default_backend`] — with `""` impossible, tag resets are
    /// covered by restoring pdqsort at the end.
    #[test]
    fn backend_name_lookup_and_default() {
        for (name, expect) in
            [("rust-pdqsort", "rust-pdqsort"), ("PDQSort", "rust-pdqsort"), ("radix-lsd", "radix-lsd"), ("RADIX", "radix-lsd"), ("radix_lsd", "radix-lsd")]
        {
            let mut b = backend_by_name(name).unwrap_or_else(|| panic!("{name} must resolve"));
            assert_eq!(b.name(), expect, "{name}");
            // every resolved backend actually sorts
            let mut runs = vec![vec![Elem::with_id(2, 0), Elem::with_id(1, 0)]];
            let mut refs: Vec<&mut Vec<Elem>> = runs.iter_mut().collect();
            b.sort_runs(&mut refs);
            assert!(crate::elements::is_sorted(&runs[0]));
        }
        assert!(backend_by_name("timsort").is_none());
        assert!(!set_default_backend("timsort"), "unknown names rejected");
        assert!(set_default_backend("radix-lsd"));
        assert_eq!(default_backend().name(), "radix-lsd");
        assert!(set_default_backend("rust-pdqsort"));
        assert_eq!(default_backend().name(), "rust-pdqsort");
        for name in BACKEND_NAMES {
            assert!(backend_by_name(name).is_some(), "{name} listed but not resolvable");
        }
    }
}
