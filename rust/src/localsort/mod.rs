//! Node-local sort backends.
//!
//! Every algorithm starts by sorting each PE's fragment. Two backends:
//! pure-Rust pdqsort ([`RustSort`]) and — behind the off-by-default `xla`
//! cargo feature — the PJRT-executed Pallas bitonic network (`XlaSort` in
//! [`crate::runtime`]), which batches all fragments of a round into one
//! executable launch — the AOT artifact on the hot path.
//!
//! The *virtual* cost charged to PE clocks is the same either way
//! (`cmp·m·log m`); the backend choice affects only host wallclock, which
//! is what the §Perf benchmarks measure.

use crate::elements::Elem;

/// A batched local-sort backend. Sorts each run ascending in full
/// `(key, id)` order.
pub trait SortBackend {
    fn sort_runs(&mut self, runs: &mut [&mut Vec<Elem>]);
    fn name(&self) -> &'static str;
}

/// Pure-Rust backend: `slice::sort_unstable` (pdqsort) per run.
#[derive(Default, Clone, Copy, Debug)]
pub struct RustSort;

impl SortBackend for RustSort {
    fn sort_runs(&mut self, runs: &mut [&mut Vec<Elem>]) {
        for run in runs {
            run.sort_unstable();
        }
    }

    fn name(&self) -> &'static str {
        "rust-pdqsort"
    }
}

/// Sort all of a machine's per-PE fragments with `backend`, charging each
/// PE the model's sort cost.
pub fn sort_all(
    mach: &mut crate::sim::Machine,
    data: &mut [Vec<Elem>],
    backend: &mut dyn SortBackend,
) {
    for (pe, run) in data.iter().enumerate() {
        mach.work_sort(pe, run.len());
    }
    let mut refs: Vec<&mut Vec<Elem>> = data.iter_mut().collect();
    backend.sort_runs(&mut refs);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CostModel;
    use crate::rng::Rng;
    use crate::sim::Machine;

    #[test]
    fn rust_sort_orders_by_key_then_id() {
        let mut runs = vec![vec![
            Elem::with_id(5, 2),
            Elem::with_id(1, 9),
            Elem::with_id(5, 1),
            Elem::with_id(0, 0),
        ]];
        let mut refs: Vec<&mut Vec<Elem>> = runs.iter_mut().collect();
        RustSort.sort_runs(&mut refs);
        assert!(crate::elements::is_sorted(&runs[0]));
        assert_eq!(runs[0][1], Elem::with_id(1, 9));
        assert_eq!(runs[0][2], Elem::with_id(5, 1));
    }

    #[test]
    fn sort_all_charges_cost() {
        let mut mach = Machine::new(2, CostModel::default());
        let mut rng = Rng::seeded(0, 0);
        let mut data: Vec<Vec<Elem>> = (0..2)
            .map(|pe| (0..128).map(|i| Elem::new(rng.next_u64(), pe, i)).collect())
            .collect();
        sort_all(&mut mach, &mut data, &mut RustSort);
        assert!(data.iter().all(|r| crate::elements::is_sorted(r)));
        assert!(mach.clock(0) > 0.0 && mach.clock(1) > 0.0);
    }
}
