//! Node-local sort backends.
//!
//! Every algorithm starts by sorting each PE's fragment. Two backends:
//! pure-Rust pdqsort ([`RustSort`]) and — behind the off-by-default `xla`
//! cargo feature — the PJRT-executed Pallas bitonic network (`XlaSort` in
//! [`crate::runtime`]), which batches all fragments of a round into one
//! executable launch — the AOT artifact on the hot path.
//!
//! The *virtual* cost charged to PE clocks is the same either way
//! (`cmp·m·log m`); the backend choice affects only host wallclock, which
//! is what the §Perf benchmarks measure.

use crate::elements::Elem;
use crate::sim::ParSpec;

/// A batched local-sort backend. Sorts each run ascending in full
/// `(key, id)` order.
pub trait SortBackend {
    fn sort_runs(&mut self, runs: &mut [&mut Vec<Elem>]);
    fn name(&self) -> &'static str;

    /// A stateless per-run sort function, if the backend supports
    /// dispatching one run at a time — [`sort_all`] then fans the runs
    /// out over the PE-task pool. `None` (the default) keeps the batched
    /// [`SortBackend::sort_runs`] path, which backends that fuse all
    /// fragments into one launch (the PJRT `XlaSort`) require.
    fn par_run_sort(&self) -> Option<fn(&mut Vec<Elem>)> {
        None
    }
}

/// Pure-Rust backend: `slice::sort_unstable` (pdqsort) per run.
#[derive(Default, Clone, Copy, Debug)]
pub struct RustSort;

impl SortBackend for RustSort {
    fn sort_runs(&mut self, runs: &mut [&mut Vec<Elem>]) {
        for run in runs {
            run.sort_unstable();
        }
    }

    fn name(&self) -> &'static str {
        "rust-pdqsort"
    }

    fn par_run_sort(&self) -> Option<fn(&mut Vec<Elem>)> {
        Some(|run| run.sort_unstable())
    }
}

/// Sort all of a machine's per-PE fragments with `backend`, charging each
/// PE the model's sort cost.
///
/// Per-run backends ([`SortBackend::par_run_sort`]) execute as one
/// pool-scheduled PE task per fragment, with the `work_sort` charge
/// recorded by the same task that sorts — cost and work originate from
/// the same call, mirroring the Exchange charged == moved discipline —
/// and settled in PE order, bit-identical to the historical
/// charge-loop-then-sort sequence. Batch-only backends keep the two-phase
/// shape (the charge loop already was in PE order).
pub fn sort_all(
    mach: &mut crate::sim::Machine,
    data: &mut [Vec<Elem>],
    backend: &mut dyn SortBackend,
) {
    if let Some(sort_one) = backend.par_run_sort() {
        let total: usize = data.iter().map(Vec::len).sum();
        mach.par_pes(0, ParSpec::work(total), data, |ctx, run| {
            ctx.work_sort(run.len());
            sort_one(run);
        });
    } else {
        for (pe, run) in data.iter().enumerate() {
            mach.work_sort(pe, run.len());
        }
        let mut refs: Vec<&mut Vec<Elem>> = data.iter_mut().collect();
        backend.sort_runs(&mut refs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CostModel;
    use crate::rng::Rng;
    use crate::sim::Machine;

    #[test]
    fn rust_sort_orders_by_key_then_id() {
        let mut runs = vec![vec![
            Elem::with_id(5, 2),
            Elem::with_id(1, 9),
            Elem::with_id(5, 1),
            Elem::with_id(0, 0),
        ]];
        let mut refs: Vec<&mut Vec<Elem>> = runs.iter_mut().collect();
        RustSort.sort_runs(&mut refs);
        assert!(crate::elements::is_sorted(&runs[0]));
        assert_eq!(runs[0][1], Elem::with_id(1, 9));
        assert_eq!(runs[0][2], Elem::with_id(5, 1));
    }

    #[test]
    fn sort_all_charges_cost() {
        let mut mach = Machine::new(2, CostModel::default());
        let mut rng = Rng::seeded(0, 0);
        let mut data: Vec<Vec<Elem>> = (0..2)
            .map(|pe| (0..128).map(|i| Elem::new(rng.next_u64(), pe, i)).collect())
            .collect();
        sort_all(&mut mach, &mut data, &mut RustSort);
        assert!(data.iter().all(|r| crate::elements::is_sorted(r)));
        assert!(mach.clock(0) > 0.0 && mach.clock(1) > 0.0);
    }

    /// A batch-only backend (no `par_run_sort`, like `XlaSort`) and the
    /// pool-scheduled per-run path must charge identical costs and produce
    /// identical runs — the inline gate pinned low so the per-run path
    /// really runs on the persistent pool whatever `RMPS_PAR_MIN_WORK`
    /// says.
    #[test]
    fn par_and_batch_paths_agree_bitwise() {
        struct BatchOnly;
        impl SortBackend for BatchOnly {
            fn sort_runs(&mut self, runs: &mut [&mut Vec<Elem>]) {
                for run in runs {
                    run.sort_unstable();
                }
            }
            fn name(&self) -> &'static str {
                "batch-only"
            }
        }
        let p = 8;
        let gen = |seed| -> Vec<Vec<Elem>> {
            let mut rng = Rng::seeded(seed, 1);
            (0..p).map(|pe| (0..1024).map(|i| Elem::new(rng.next_u64(), pe, i)).collect()).collect()
        };
        let mut batch_mach = Machine::new(p, CostModel::default());
        let mut batch_data = gen(9);
        sort_all(&mut batch_mach, &mut batch_data, &mut BatchOnly);
        let mut par_mach = Machine::new(p, CostModel::default());
        par_mach.set_pe_jobs(4);
        par_mach.set_par_min_work(1);
        let mut par_data = gen(9);
        sort_all(&mut par_mach, &mut par_data, &mut RustSort);
        assert_eq!(batch_data, par_data);
        for pe in 0..p {
            assert_eq!(batch_mach.clock(pe).to_bits(), par_mach.clock(pe).to_bits(), "pe {pe}");
        }
        assert_eq!(
            batch_mach.stats.local_work.to_bits(),
            par_mach.stats.local_work.to_bits()
        );
    }
}
