//! Deterministic, dependency-free PRNGs.
//!
//! Every virtual PE owns its own stream (seeded from the run seed and the
//! PE index via SplitMix64), so simulations are reproducible regardless of
//! execution order — a requirement for the paper's repeated-measurement
//! methodology and for `proptest` shrinking.

/// SplitMix64 — used for seeding and as a cheap standalone generator.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse generator for input synthesis and shuffling.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed a stream; `stream` is typically the PE index.
    pub fn seeded(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.wrapping_mul(0xA076_1D64_78BD_642F));
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (bound > 0).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Fair coin flip.
    #[inline]
    pub fn coin(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Approximate standard normal (sum of 12 uniforms, Irwin–Hall).
    pub fn normal(&mut self) -> f64 {
        let mut s = 0.0;
        for _ in 0..12 {
            s += self.unit_f64();
        }
        s - 6.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::seeded(42, 7);
        let mut b = Rng::seeded(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_streams_differ() {
        let mut a = Rng::seeded(42, 0);
        let mut b = Rng::seeded(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::seeded(1, 2);
        for bound in [1u64, 2, 3, 10, 1 << 32] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn range_covers_endpoints_eventually() {
        let mut r = Rng::seeded(3, 4);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range(5, 8);
            assert!((5..8).contains(&v));
            seen_lo |= v == 5;
            seen_hi |= v == 7;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(9, 9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn unit_f64_in_unit_interval() {
        let mut r = Rng::seeded(11, 0);
        for _ in 0..1000 {
            let x = r.unit_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_has_reasonable_moments() {
        let mut r = Rng::seeded(13, 0);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
