//! Ternary median tree of Dean et al. [16] — the App. H / Fig. 4 baseline:
//! leaves are single elements, each internal node forwards the median of
//! its three children. Rank error ≈ 2·n^−0.37 (the paper's binary k-window
//! tree beats it at ≈ 1.44·n^−0.39).

use crate::elements::Key;
use crate::rng::Rng;

/// Median of three keys.
#[inline]
fn med3(a: Key, b: Key, c: Key) -> Key {
    a.max(b).min(a.min(b).max(c))
}

/// Sequential ternary-tree estimate over `n = 3^h` elements. The input is
/// randomly permuted by the caller (the estimator is only truthful for
/// random permutations, §III-B); `rng` is used for nothing here but kept
/// for signature symmetry with the binary estimator.
pub fn sequential_ternary_estimate(vals: &[Key], _rng: &mut Rng) -> Option<Key> {
    let n = vals.len();
    if n == 0 {
        return None;
    }
    assert!(is_power_of_three(n), "ternary tree needs n = 3^h");
    let mut level: Vec<Key> = vals.to_vec();
    while level.len() > 1 {
        level = level.chunks(3).map(|c| med3(c[0], c[1], c[2])).collect();
    }
    Some(level[0])
}

/// `true` iff `n` is a power of three.
pub fn is_power_of_three(mut n: usize) -> bool {
    if n == 0 {
        return false;
    }
    while n % 3 == 0 {
        n /= 3;
    }
    n == 1
}

/// Largest power of three ≤ `n`.
pub fn pow3_below(n: usize) -> usize {
    let mut p = 1;
    while p * 3 <= n {
        p *= 3;
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn med3_cases() {
        assert_eq!(med3(1, 2, 3), 2);
        assert_eq!(med3(3, 1, 2), 2);
        assert_eq!(med3(2, 3, 1), 2);
        assert_eq!(med3(5, 5, 1), 5);
        assert_eq!(med3(7, 7, 7), 7);
    }

    #[test]
    fn power_of_three_detection() {
        assert!(is_power_of_three(1));
        assert!(is_power_of_three(3));
        assert!(is_power_of_three(81));
        assert!(!is_power_of_three(0));
        assert!(!is_power_of_three(2));
        assert!(!is_power_of_three(12));
        assert_eq!(pow3_below(100), 81);
        assert_eq!(pow3_below(3), 3);
    }

    #[test]
    fn estimate_is_near_median_for_random_permutation() {
        let mut rng = Rng::seeded(7, 0);
        let n = 3usize.pow(8); // 6561
        let mut vals: Vec<u64> = (0..n as u64).collect();
        let mut errs = Vec::new();
        for _ in 0..30 {
            rng.shuffle(&mut vals);
            let est = sequential_ternary_estimate(&vals, &mut rng).unwrap();
            errs.push((est as f64 / n as f64 - 0.5).abs());
        }
        let mean = errs.iter().sum::<f64>() / errs.len() as f64;
        // Dean et al.: error ~ 2·n^-0.37 ≈ 0.077 for n = 6561
        assert!(mean < 0.12, "mean rank error {mean}");
    }

    #[test]
    fn estimate_singleton() {
        let mut rng = Rng::seeded(0, 0);
        assert_eq!(sequential_ternary_estimate(&[42], &mut rng), Some(42));
        assert_eq!(sequential_ternary_estimate(&[], &mut rng), None);
    }
}
