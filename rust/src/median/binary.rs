//! Binary-tree median approximation (§III-B): the k-window reduction.
//!
//! Window slots may be "undefined": entries running off the left of a local
//! array are treated as −∞, off the right as +∞ (the paper's convention),
//! encoded in a `u128` with a +1 offset so both sentinels order correctly.

use crate::elements::{Elem, Key};
use crate::rng::Rng;
use crate::sim::{bcast_cost, Machine};

/// −∞ sentinel (undefined slots left of the data).
const NEG: u128 = 0;
/// +∞ sentinel (undefined slots right of the data).
const POS: u128 = u64::MAX as u128 + 2;

#[inline]
fn enc(k: Key) -> u128 {
    k as u128 + 1
}

#[inline]
fn dec(v: u128) -> Option<Key> {
    if v == NEG || v == POS {
        None
    } else {
        Some((v - 1) as u64)
    }
}

/// A sorted k-window of (possibly undefined) key slots.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Window(pub Vec<u128>);

impl Window {
    /// The leaf contribution of a PE holding sorted keys `a` (§III-B):
    /// the k slots around the local median, with sentinel padding and a
    /// coin flip between ⌊m/2⌋ / ⌈m/2⌉ centring for odd m.
    pub fn leaf(a: &[Key], k: usize, rng: &mut Rng) -> Self {
        debug_assert!(k >= 2 && k % 2 == 0);
        debug_assert!(a.windows(2).all(|w| w[0] <= w[1]));
        let m = a.len();
        // centre position (1-indexed half point); coin flip for odd m
        let c = if m % 2 == 0 {
            m / 2
        } else if rng.coin() {
            m / 2
        } else {
            m / 2 + 1
        };
        // 1-indexed slots c − k/2 + 1 ..= c + k/2
        let mut w = Vec::with_capacity(k);
        for s in 0..k {
            let pos1 = c as i64 - (k / 2) as i64 + 1 + s as i64; // 1-indexed
            if pos1 < 1 {
                w.push(NEG);
            } else if pos1 as usize > m {
                w.push(POS);
            } else {
                w.push(enc(a[pos1 as usize - 1]));
            }
        }
        Window(w)
    }

    /// Internal node: merge two k-windows, keep the centre k slots.
    pub fn merge(&self, other: &Window) -> Window {
        let mut out = Window(Vec::new());
        let mut scratch = Vec::new();
        self.merge_into(other, &mut out, &mut scratch);
        out
    }

    /// Allocation-free core of [`Window::merge`]: the 2k-way merge runs in
    /// `scratch`, the centre k slots land in `out` (cleared first) — both
    /// reuse their capacity, so a caller looping over many merges (the
    /// pivot-selection butterfly) allocates nothing after warmup. Values
    /// are bit-identical to [`Window::merge`].
    pub fn merge_into(&self, other: &Window, out: &mut Window, scratch: &mut Vec<u128>) {
        let k = self.0.len();
        debug_assert_eq!(k, other.0.len());
        scratch.clear();
        scratch.reserve(2 * k);
        let (a, b) = (&self.0, &other.0);
        let (mut i, mut j) = (0, 0);
        while i < k && j < k {
            if a[i] <= b[j] {
                scratch.push(a[i]);
                i += 1;
            } else {
                scratch.push(b[j]);
                j += 1;
            }
        }
        scratch.extend_from_slice(&a[i..]);
        scratch.extend_from_slice(&b[j..]);
        out.0.clear();
        out.0.extend_from_slice(&scratch[k / 2..k / 2 + k]);
    }

    /// Root: coin flip between the two central slots (a[k/2], a[k/2+1]
    /// 1-indexed). Falls back to the nearest defined slot; `None` if the
    /// whole window is undefined (no elements anywhere).
    pub fn root_pick(&self, rng: &mut Rng) -> Option<Key> {
        let k = self.0.len();
        let first = k / 2 - 1; // 0-indexed a[k/2]
        let pick = if rng.coin() { first } else { first + 1 };
        if let Some(v) = dec(self.0[pick]) {
            return Some(v);
        }
        // nearest defined slot
        for d in 1..k {
            for idx in [pick.wrapping_sub(d), pick + d] {
                if idx < k {
                    if let Some(v) = dec(self.0[idx]) {
                        return Some(v);
                    }
                }
            }
        }
        None
    }

    pub fn is_all_undefined(&self) -> bool {
        self.0.iter().all(|&v| v == NEG || v == POS)
    }
}

/// Distributed median approximation over a PE group (§III-B), implemented
/// as an *allreduce butterfly* of k-windows — "in most MPI implementations
/// this algorithm can be implemented by defining an appropriate reduction
/// operator": log q pairwise exchange rounds, every member ends with the
/// same merged window, no separate broadcast. O((α + β·k)·log q).
///
/// `local[pe]` must be sorted by key (global PE indexing). Returns `None`
/// iff the group holds no elements at all (the RQuick "ISEMPTY(s)" exit).
pub fn median_binary(
    mach: &mut Machine,
    pes: &[usize],
    local: &[Vec<Elem>],
    k: usize,
    rng: &mut Rng,
) -> Option<Key> {
    assert!(pes.len().is_power_of_two());
    let dim = pes.len().trailing_zeros();
    let size = pes.len();
    // one reusable key buffer for all leaf extractions (this function runs
    // once per recursion level of the calling sorter — per-call churn here
    // multiplies across the whole pivot-selection phase)
    let mut keys: Vec<Key> = Vec::new();
    let mut win: Vec<Window> = pes
        .iter()
        .map(|&pe| {
            keys.clear();
            keys.extend(local[pe].iter().map(|e| e.key));
            mach.work_linear(pe, k); // window extraction
            Window::leaf(&keys, k, rng)
        })
        .collect();
    // double-buffered butterfly: `snapshot` holds the previous round's
    // windows and is refilled in place (fixed width k, capacity reused),
    // and merges land in `win` through `merge_into` — after the first
    // round the loop allocates nothing, where it used to clone the whole
    // window table per round
    let mut snapshot: Vec<Window> = (0..size).map(|_| Window(Vec::new())).collect();
    let mut scratch: Vec<u128> = Vec::with_capacity(2 * k);
    for j in 0..dim {
        let bit = 1usize << j;
        for (s, w) in snapshot.iter_mut().zip(win.iter()) {
            s.0.clear();
            s.0.extend_from_slice(&w.0);
        }
        for r in 0..size {
            let pr = r ^ bit;
            if r < pr {
                mach.xchg(pes[r], pes[pr], k, k);
            }
            snapshot[r].merge_into(&snapshot[pr], &mut win[r], &mut scratch);
            mach.work_linear(pes[r], 2 * k);
        }
    }
    // all members hold the identical window; one shared coin flip
    debug_assert!(win.iter().all(|w| w == &win[0]));
    win[0].root_pick(rng)
}

/// Binomial-tree variant (reduce-to-root + broadcast): kept for the cost
/// comparison in benches — ~2× the α-depth of the butterfly.
pub fn median_binary_tree_bcast(
    mach: &mut Machine,
    pes: &[usize],
    local: &[Vec<Elem>],
    k: usize,
    rng: &mut Rng,
) -> Option<Key> {
    assert!(pes.len().is_power_of_two());
    let dim = pes.len().trailing_zeros();
    let size = pes.len();
    let mut win: Vec<Option<Window>> = pes
        .iter()
        .map(|&pe| {
            let keys: Vec<Key> = local[pe].iter().map(|e| e.key).collect();
            mach.work_linear(pe, k);
            Some(Window::leaf(&keys, k, rng))
        })
        .collect();
    for j in 0..dim {
        let bit = 1usize << j;
        for r in 0..size {
            if r & bit != 0 && r & (bit - 1) == 0 {
                let dst = r & !bit;
                let w = win[r].take().expect("window already sent");
                mach.send(pes[r], pes[dst], k);
                let acc = win[dst].as_mut().expect("reducer holds window");
                *acc = acc.merge(&w);
                mach.work_linear(pes[dst], 2 * k);
            }
        }
    }
    let root = win[0].take().expect("root window");
    let result = root.root_pick(rng);
    bcast_cost(mach, pes, 0, 1);
    result
}

/// Sequential binary-tree estimate over `n = 2^d` single-element leaves —
/// the Fig. 4 / App. H benchmark harness (no Machine involved).
pub fn sequential_binary_estimate(vals: &[Key], k: usize, rng: &mut Rng) -> Option<Key> {
    assert!(vals.len().is_power_of_two());
    let mut level: Vec<Window> = vals
        .iter()
        .map(|&v| Window::leaf(&[v], k, rng))
        .collect();
    while level.len() > 1 {
        level = level
            .chunks(2)
            .map(|pair| pair[0].merge(&pair[1]))
            .collect();
    }
    level[0].root_pick(rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CostModel;
    use crate::sim::Cube;

    fn rng() -> Rng {
        Rng::seeded(42, 0)
    }

    #[test]
    fn leaf_window_even() {
        // a = [1..6], m=6, k=2 → slots a[3], a[4] (1-indexed) = 3, 4
        let w = Window::leaf(&[1, 2, 3, 4, 5, 6], 2, &mut rng());
        assert_eq!(w.0, vec![enc(3), enc(4)]);
    }

    #[test]
    fn leaf_window_pads_with_sentinels() {
        let w = Window::leaf(&[7], 4, &mut rng());
        // m=1 odd: centre 0 or 1; either way one real slot, NEG left, POS right
        let real: Vec<_> = w.0.iter().filter_map(|&v| dec(v)).collect();
        assert_eq!(real, vec![7]);
        assert!(w.0[0] == NEG);
        assert!(*w.0.last().unwrap() == POS);
    }

    #[test]
    fn leaf_window_empty_is_all_undefined() {
        let w = Window::leaf(&[], 4, &mut rng());
        assert!(w.is_all_undefined());
        assert_eq!(w.root_pick(&mut rng()), None);
    }

    #[test]
    fn merge_keeps_centre() {
        let a = Window(vec![enc(1), enc(2), enc(3), enc(4)]);
        let b = Window(vec![enc(2), enc(3), enc(5), enc(9)]);
        // merged: 1 2 2 3 3 4 5 9 → centre 4: 2 3 3 4
        assert_eq!(a.merge(&b).0, vec![enc(2), enc(3), enc(3), enc(4)]);
    }

    #[test]
    fn merge_sentinels_order_correctly() {
        let a = Window(vec![NEG, enc(10)]);
        let b = Window(vec![enc(5), POS]);
        // merged: NEG 5 10 POS → centre 2: 5, 10
        assert_eq!(a.merge(&b).0, vec![enc(5), enc(10)]);
    }

    #[test]
    fn distributed_median_is_reasonable() {
        let p = 64;
        let m = 64;
        let mut mach = Machine::new(p, CostModel::default());
        let mut r = rng();
        // PE-local sorted runs of a global 0..(p·m) permutation-ish uniform
        let mut all: Vec<u64> = (0..(p * m) as u64).collect();
        r.shuffle(&mut all);
        let local: Vec<Vec<Elem>> = (0..p)
            .map(|pe| {
                let mut v: Vec<Elem> = all[pe * m..(pe + 1) * m]
                    .iter()
                    .map(|&k| Elem::new(k, pe, 0))
                    .collect();
                v.sort();
                v
            })
            .collect();
        let est = median_binary(&mut mach, &Cube::whole(p).pe_vec(), &local, 8, &mut r)
            .expect("non-empty");
        let n = (p * m) as f64;
        let rel = (est as f64 / n - 0.5).abs();
        assert!(rel < 0.15, "estimate rank error {rel}");
        // latency: O(α log p) — must stay well under α·p
        assert!(mach.time() < CostModel::default().alpha * p as f64 / 2.0);
    }

    #[test]
    fn distributed_median_empty_cube_returns_none() {
        let p = 4;
        let mut mach = Machine::new(p, CostModel::default());
        let local: Vec<Vec<Elem>> = vec![Vec::new(); p];
        assert_eq!(
            median_binary(&mut mach, &Cube::whole(p).pe_vec(), &local, 4, &mut rng()),
            None
        );
    }

    #[test]
    fn sequential_estimate_close_to_true_median() {
        let mut r = rng();
        let n = 1 << 12;
        let mut vals: Vec<u64> = (0..n as u64).collect();
        r.shuffle(&mut vals);
        let mut errs = Vec::new();
        for _ in 0..20 {
            let est = sequential_binary_estimate(&vals, 2, &mut r).unwrap();
            errs.push((est as f64 / n as f64 - 0.5).abs());
        }
        let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
        // App. H: error ~ 1.44·n^-0.39 ≈ 0.055 for n = 4096
        assert!(mean_err < 0.1, "mean rank error {mean_err}");
    }
}
