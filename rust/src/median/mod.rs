//! Approximate median selection with a single reduction (§III-B, App. H).
//!
//! The paper's splitter selector: every PE contributes the k-window around
//! its local median; a binomial-tree reduction merges windows keeping the
//! centre k; the root coin-flips between the two central candidates. Total
//! cost O(α·log p) — the ingredient that keeps RQuick's latency at
//! O(log²p) where median-of-medians pays Ω(β·p).
//!
//! [`ternary`] implements Dean et al.'s median-of-three tree for the
//! Fig. 4 / App. H comparison.

pub mod binary;
pub mod ternary;

pub use binary::{median_binary, sequential_binary_estimate, Window};
pub use ternary::sequential_ternary_estimate;
