//! Output validation: the paper's correctness contract (§II) — globally
//! sorted output with consecutive ranks per PE, multiset-preserving, and
//! balanced to (1+ε)·n/p.

use crate::elements::{is_key_sorted, Elem};
use crate::metrics::Imbalance;

/// Result of validating one run's output against its input.
#[derive(Clone, Debug, Default)]
pub struct Validation {
    pub locally_sorted: bool,
    pub globally_sorted: bool,
    pub multiset_preserved: bool,
    pub imbalance: Imbalance,
    /// balance check against (1+ε)·n/p (not applied to gather variants)
    pub balanced: bool,
}

impl Validation {
    pub fn ok(&self) -> bool {
        self.locally_sorted && self.globally_sorted && self.multiset_preserved
    }

    pub fn ok_balanced(&self) -> bool {
        self.ok() && self.balanced
    }
}

/// Validate `output` against `input` with balance bound `epsilon`.
pub fn validate(input: &[Vec<Elem>], output: &[Vec<Elem>], epsilon: f64) -> Validation {
    let locally_sorted = output.iter().all(|v| is_key_sorted(v));

    // boundaries between consecutive non-empty PEs must be ordered
    let mut globally_sorted = locally_sorted;
    let mut last_max: Option<u64> = None;
    for v in output {
        if let (Some(first), Some(&prev)) = (v.first(), last_max.as_ref()) {
            if first.key < prev {
                globally_sorted = false;
            }
        }
        if let Some(last) = v.last() {
            last_max = Some(last.key);
        }
    }

    // multiset check via sorted (key, id) lists
    let mut a: Vec<Elem> = input.iter().flatten().copied().collect();
    let mut b: Vec<Elem> = output.iter().flatten().copied().collect();
    a.sort_unstable();
    b.sort_unstable();
    let multiset_preserved = a == b;

    let n: usize = a.len();
    let p = output.len().max(1);
    let imbalance = Imbalance::from_loads(output.iter().map(Vec::len));
    // dense contract: (1+ε)·n/p per PE. For tiny n/p the paper itself
    // observes larger ε (imbalance "always < 0.1 except n/p ≤ 16"), and a
    // randomized placement of k ≪ p elements is Poisson-loaded — allow a
    // small additive slack that vanishes relative to dense loads.
    let npp = n as f64 / p as f64;
    // ε = ∞ (gather-style shapes) saturates the cap — saturating math
    let cap = ((1.0 + epsilon) * npp).ceil().min(usize::MAX as f64) as usize;
    let slack = if npp < 16.0 { 3 } else { 0 };
    let balanced = imbalance.max_load <= cap.max(1).saturating_add(slack);

    Validation { locally_sorted, globally_sorted, multiset_preserved, imbalance, balanced }
}

/// Validate a *replicated* output
/// ([`crate::algorithms::OutputShape::Replicated`]): every PE must hold
/// the complete input in sorted `(key, id)` order. Each PE's copy is
/// checked against the sorted reference — not merely against PE 0's copy,
/// so a uniformly wrong replica cannot pass.
///
/// `balanced` is always false: full replication holds Θ(n) per PE by
/// construction and never meets the (1+ε)·n/p contract.
pub fn validate_replicated(input: &[Vec<Elem>], output: &[Vec<Elem>]) -> Validation {
    let mut expected: Vec<Elem> = input.iter().flatten().copied().collect();
    expected.sort_unstable();
    let locally_sorted = output.iter().all(|v| is_key_sorted(v));
    let complete = !output.is_empty() && output.iter().all(|v| *v == expected);
    Validation {
        locally_sorted,
        globally_sorted: locally_sorted && complete,
        multiset_preserved: complete,
        imbalance: Imbalance::from_loads(output.iter().map(Vec::len)),
        balanced: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(k: u64, id: u64) -> Elem {
        Elem::with_id(k, id)
    }

    #[test]
    fn accepts_correct_output() {
        let input = vec![vec![e(3, 0), e(1, 1)], vec![e(2, 2), e(0, 3)]];
        let output = vec![vec![e(0, 3), e(1, 1)], vec![e(2, 2), e(3, 0)]];
        let v = validate(&input, &output, 0.2);
        assert!(v.ok_balanced(), "{v:?}");
        assert_eq!(v.imbalance.epsilon, 0.0);
    }

    #[test]
    fn rejects_unsorted_boundary() {
        let input = vec![vec![e(1, 0)], vec![e(2, 1)]];
        let output = vec![vec![e(2, 1)], vec![e(1, 0)]];
        let v = validate(&input, &output, 0.2);
        assert!(!v.globally_sorted);
    }

    #[test]
    fn rejects_lost_elements() {
        let input = vec![vec![e(1, 0), e(2, 1)]];
        let output = vec![vec![e(1, 0)]];
        assert!(!validate(&input, &output, 0.2).multiset_preserved);
    }

    #[test]
    fn rejects_duplicated_elements() {
        let input = vec![vec![e(1, 0)]];
        let output = vec![vec![e(1, 0), e(1, 0)]];
        assert!(!validate(&input, &output, 0.2).multiset_preserved);
    }

    #[test]
    fn flags_imbalance() {
        // 64 elements all on one of 2 PEs: n/p = 32, cap = ⌈1.2·32⌉ = 39
        let run: Vec<Elem> = (0..64).map(|i| e(i, i)).collect();
        let input = vec![run.clone(), vec![]];
        let output = vec![run, vec![]];
        let v = validate(&input, &output, 0.2);
        assert!(v.ok());
        assert!(!v.balanced, "64 elements on one of 2 PEs breaks ε=0.2");
    }

    #[test]
    fn duplicate_keys_across_boundary_are_fine() {
        let input = vec![vec![e(5, 0), e(5, 1)], vec![e(5, 2), e(5, 3)]];
        let output = vec![vec![e(5, 2), e(5, 0)], vec![e(5, 3), e(5, 1)]];
        let v = validate(&input, &output, 0.2);
        assert!(v.globally_sorted);
        assert!(v.multiset_preserved);
    }

    #[test]
    fn empty_pes_in_middle_are_fine() {
        let input = vec![vec![e(1, 0)], vec![], vec![e(2, 1)]];
        let output = vec![vec![e(1, 0)], vec![], vec![e(2, 1)]];
        assert!(validate(&input, &output, 0.2).ok());
    }

    #[test]
    fn replicated_accepts_full_copies_everywhere() {
        let input = vec![vec![e(3, 0), e(1, 1)], vec![e(2, 2)]];
        let full = vec![e(1, 1), e(2, 2), e(3, 0)];
        let v = validate_replicated(&input, &[full.clone(), full]);
        assert!(v.ok(), "{v:?}");
        assert!(!v.balanced, "replication never satisfies the balance contract");
    }

    /// The hole the old PE-0-projection check left open: if every PE holds
    /// the *same* wrong copy, "all PEs equal PE 0" is vacuously true. The
    /// per-PE reference comparison must reject it.
    #[test]
    fn replicated_rejects_uniformly_wrong_copies() {
        let input = vec![vec![e(3, 0), e(1, 1)], vec![e(2, 2)]];
        let wrong = vec![e(1, 1), e(2, 2)]; // lost element 3, uniformly
        let v = validate_replicated(&input, &[wrong.clone(), wrong]);
        assert!(!v.ok());
        assert!(!v.multiset_preserved);
    }

    #[test]
    fn replicated_rejects_one_divergent_pe() {
        let input = vec![vec![e(3, 0), e(1, 1)], vec![e(2, 2)]];
        let full = vec![e(1, 1), e(2, 2), e(3, 0)];
        let divergent = vec![e(1, 1), e(3, 0), e(2, 2)];
        let v = validate_replicated(&input, &[full, divergent]);
        assert!(!v.ok());
    }
}
