//! NBX-style dynamic sparse data exchange (Hoefler et al. [27]), as used by
//! RAMS' deterministic message assignment (App. G): receivers do not know
//! how many messages to expect, so a non-blocking barrier detects
//! termination. Cost: the irregular round itself plus an O(α·log q)
//! barrier term.

use crate::sim::Machine;

/// Exchange opaque word-counted messages among a PE group; returns, per
/// receiving member (group rank), the list of `(sender_rank, payload_index)`
/// — the caller keeps payloads and uses the indices to deliver.
///
/// `msgs` are `(from_rank, to_rank, words)` within the group.
pub fn nbx_exchange(
    mach: &mut Machine,
    pes: &[usize],
    msgs: &[(usize, usize, usize)],
) -> Vec<Vec<(usize, usize)>> {
    let global: Vec<(usize, usize, usize)> = msgs
        .iter()
        .map(|&(f, t, l)| (pes[f], pes[t], l))
        .collect();
    mach.route_round(&global);
    // the non-blocking barrier: log q rounds of empty messages
    mach.barrier(pes);
    let mut recv: Vec<Vec<(usize, usize)>> = vec![Vec::new(); pes.len()];
    for (idx, &(f, t, _)) in msgs.iter().enumerate() {
        recv[t].push((f, idx));
    }
    recv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CostModel;
    use crate::sim::Cube;

    #[test]
    fn nbx_delivers_and_prices_barrier() {
        let mut m = Machine::new(
            8,
            CostModel { alpha: 100.0, beta: 1.0, cmp: 1.0, duplex: true },
        );
        let pes = Cube::whole(8).pe_vec();
        let msgs = vec![(0, 3, 5), (1, 3, 2), (7, 0, 1)];
        let recv = nbx_exchange(&mut m, &pes, &msgs);
        assert_eq!(recv[3].len(), 2);
        assert_eq!(recv[0], vec![(7, 2)]);
        assert!(recv[1].is_empty());
        // barrier synchronised all clocks
        let t = m.clock(0);
        assert!((0..8).all(|pe| m.clock(pe) == t));
        assert!(t >= 100.0); // at least one α
    }

    #[test]
    fn nbx_empty_is_barrier_only() {
        let mut m = Machine::new(
            4,
            CostModel { alpha: 100.0, beta: 1.0, cmp: 1.0, duplex: true },
        );
        let recv = nbx_exchange(&mut m, &Cube::whole(4).pe_vec(), &[]);
        assert!(recv.iter().all(|r| r.is_empty()));
        assert!(m.time() > 0.0);
    }

    #[test]
    fn nbx_on_subgroup_leaves_rest_untouched() {
        let mut m = Machine::new(
            8,
            CostModel { alpha: 100.0, beta: 1.0, cmp: 1.0, duplex: true },
        );
        let recv = nbx_exchange(&mut m, &[4, 5, 6, 7], &[(0, 1, 3)]);
        assert_eq!(recv[1], vec![(0, 0)]);
        assert_eq!(m.clock(0), 0.0);
        assert!(m.clock(4) > 0.0);
    }
}
