//! The machine substrate: a deterministic single-ported α-β message-passing
//! simulator (the paper's Appendix A model made executable).
//!
//! Algorithms move *real elements* between virtual PEs; the simulator
//! advances one virtual clock per PE. The reported running time of a run is
//! the maximum clock (makespan), exactly the quantity the paper's analysis
//! bounds.

mod collectives;
mod hypercube;
mod machine;
mod sparse;

pub use collectives::*;
pub use hypercube::*;
pub use machine::*;
pub use sparse::*;
