//! The machine substrate: a deterministic single-ported α-β message-passing
//! simulator (the paper's Appendix A model made executable).
//!
//! Algorithms move *real elements* between virtual PEs; the simulator
//! advances one virtual clock per PE. The reported running time of a run is
//! the maximum clock (makespan), exactly the quantity the paper's analysis
//! bounds.
//!
//! Element payloads travel through the pooled [`Exchange`] data plane
//! ([`Machine::exchange`]), which charges the cost model and moves the
//! elements from the same call and asserts that the two volumes agree;
//! the raw [`Machine`] charge API (`xchg`/`send`/`route_round`,
//! `begin_superstep`/`settle`) remains for scalar/metadata traffic that
//! moves no elements (pivot windows, histograms, splitter broadcasts).

mod collectives;
mod exchange;
mod hypercube;
mod machine;
mod sparse;

pub use collectives::*;
pub use exchange::{
    one_factor_partner, one_factor_round_of, one_factor_rounds, Exchange, Inboxes, Run,
};
pub use hypercube::*;
pub use machine::*;
pub use sparse::*;
