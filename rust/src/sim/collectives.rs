//! Collective operations on PE groups, with the textbook hypercube /
//! binomial-tree costs the paper assumes (§II, Appendix B).
//!
//! Groups are explicit ordered PE lists (`pes: &[usize]`) so the same
//! collectives serve contiguous subcubes (quicksort, RAMS) *and* strided
//! groups (RFIS' grid rows and columns). Hypercube collectives require a
//! power-of-two group size, like the paper's algorithms.
//!
//! Data-moving collectives really move the elements; scalar collectives
//! really combine the values — the simulator never "fakes" a result, it
//! only *prices* it.
//!
//! The **data-moving** collectives ([`all_gather_merge`], [`gather_merge`],
//! [`alltoallv`]) move their payloads through the pooled
//! [`crate::sim::Exchange`] data plane: each dimension round posts the
//! element payloads and the delivery itself charges the cost model, so the
//! charged and moved volumes agree by construction. The **scalar**
//! collectives (all-reduce, prefix sums, [`bcast_cost`]) move metadata
//! words, not elements, and stay on the cost-only batched superstep path
//! ([`Machine::begin_superstep`]/[`Machine::settle`]); both batchings are
//! bit-identical to eager per-call charging because the pairs of one
//! dimension are disjoint — see the exactness contract on
//! [`Machine::begin_superstep`].

use crate::elements::{merge, merge_into, Elem};
use crate::sim::{rank_pairs, Machine, ParSpec};

fn assert_pow2(pes: &[usize]) -> u32 {
    assert!(pes.len().is_power_of_two(), "hypercube collective needs 2^d members");
    pes.len().trailing_zeros()
}

/// Provenance-tracking result of [`all_gather_merge`]: the three sorted
/// runs each PE ends with — elements that arrived from lower-ranked group
/// members (`left`), its own elements (`own`), and elements from
/// higher-ranked members (`right`). RFIS' tie-breaking (App. F) needs
/// exactly this split; plain AllGatherM output is `left ⊕ own ⊕ right`.
#[derive(Clone, Debug, Default)]
pub struct GatheredRuns {
    pub left: Vec<Elem>,
    pub own: Vec<Elem>,
    pub right: Vec<Elem>,
}

impl GatheredRuns {
    /// All elements in sorted order (the classical all-gather-merge output).
    pub fn merged(&self) -> Vec<Elem> {
        merge(&merge(&self.left, &self.own), &self.right)
    }

    pub fn total(&self) -> usize {
        self.left.len() + self.own.len() + self.right.len()
    }
}

/// Hypercube all-gather-merge over the group (O(β·q·|a| + α·log q)).
///
/// `local[pe]` is each member's sorted input run (indexed by *global* PE
/// number). Returns per-member [`GatheredRuns`] in group rank order.
pub fn all_gather_merge(
    mach: &mut Machine,
    pes: &[usize],
    local: &[Vec<Elem>],
) -> Vec<GatheredRuns> {
    let dim = assert_pow2(pes);
    let size = pes.len();
    /// Per-member round state, bundled so the post-delivery merge phase
    /// runs as one pool-scheduled PE task per member.
    struct AgmState {
        runs: GatheredRuns,
        /// full merged content, exchanged wholesale each round
        full: Vec<Elem>,
    }
    let mut st: Vec<AgmState> = pes
        .iter()
        .map(|&pe| AgmState {
            runs: GatheredRuns { own: local[pe].clone(), ..Default::default() },
            full: local[pe].clone(),
        })
        .collect();

    for j in 0..dim {
        let bit = 1usize << j;
        // every member's state moves through the exchange: after delivery
        // the partner's inbox holds this member's old run, so both old
        // runs are read back without cloning the payload (§Perf)
        let mut ex = mach.exchange();
        for (r, pr) in rank_pairs(size, j) {
            let a = std::mem::take(&mut st[r].full);
            let b = std::mem::take(&mut st[pr].full);
            ex.xchg(pes[r], pes[pr], a, b);
        }
        let inboxes = ex.deliver(mach);
        let total: usize = pes.iter().map(|&pe| inboxes.total(pe)).sum();
        mach.par_pes_on(pes, ParSpec::work(2 * total).bufs(2), &mut st, |ctx, s| {
            let r = ctx.rank();
            let pr = r ^ bit;
            let incoming = inboxes.single(pes[r]);
            let own = inboxes.single(pes[pr]);
            if pr < r {
                let mut left = ctx.take_buf();
                merge_into(&s.runs.left, incoming, &mut left);
                ctx.recycle_buf(std::mem::replace(&mut s.runs.left, left));
            } else {
                let mut right = ctx.take_buf();
                merge_into(&s.runs.right, incoming, &mut right);
                ctx.recycle_buf(std::mem::replace(&mut s.runs.right, right));
            }
            let mut merged = ctx.take_buf();
            merge_into(own, incoming, &mut merged);
            ctx.work_linear(merged.len());
            ctx.note_mem(merged.len(), "all-gather-merge");
            s.full = merged;
        });
        mach.recycle(inboxes);
    }
    st.into_iter().map(|s| s.runs).collect()
}

/// Binomial-tree gather-merge to the group's rank-0 member (GatherM).
/// Returns the merged data (resident on `pes[0]`).
pub fn gather_merge(mach: &mut Machine, pes: &[usize], local: &[Vec<Elem>]) -> Vec<Elem> {
    let dim = assert_pow2(pes);
    let size = pes.len();
    let mut cur: Vec<Option<Vec<Elem>>> =
        pes.iter().map(|&pe| Some(local[pe].clone())).collect();
    let mut dsts: Vec<usize> = Vec::new();
    for j in 0..dim {
        let bit = 1usize << j;
        // senders this round: lowest set bit of r is `bit`; their runs
        // travel through the exchange, receivers merge after delivery
        let mut ex = mach.exchange();
        dsts.clear();
        for r in 0..size {
            if r & bit != 0 && r & (bit - 1) == 0 {
                let dst = r & !bit;
                let data = cur[r].take().expect("sender already gave data away");
                ex.send(pes[r], pes[dst], data);
                dsts.push(dst);
            }
        }
        let inboxes = ex.deliver(mach);
        // pull each receiver's accumulator into a dense task list (cheap
        // pointer moves — `cur` is rank-indexed and the receivers are
        // strided), merge as one PE task per receiver, put back
        let mut accs: Vec<Vec<Elem>> = dsts
            .iter()
            .map(|&dst| cur[dst].take().expect("receiver must hold data"))
            .collect();
        let task_pes: Vec<usize> = dsts.iter().map(|&dst| pes[dst]).collect();
        let total: usize = accs.iter().map(Vec::len).sum::<usize>()
            + task_pes.iter().map(|&pe| inboxes.total(pe)).sum::<usize>();
        mach.par_pes_on(&task_pes, ParSpec::work(total).bufs(1), &mut accs, |ctx, acc| {
            let mut merged = ctx.take_buf();
            merge_into(acc, inboxes.single(ctx.pe()), &mut merged);
            ctx.work_linear(merged.len());
            ctx.note_mem(merged.len(), "gather-merge");
            ctx.recycle_buf(std::mem::replace(acc, merged));
        });
        for (&dst, acc) in dsts.iter().zip(accs) {
            cur[dst] = Some(acc);
        }
        mach.recycle(inboxes);
    }
    cur[0].take().expect("root holds the result")
}

/// Binomial broadcast of `l` words from group rank `root_r`.
/// Only prices the communication; the caller distributes the value.
pub fn bcast_cost(mach: &mut Machine, pes: &[usize], root_r: usize, l: usize) {
    let size = pes.len();
    if size <= 1 {
        return;
    }
    let dim = assert_pow2(pes);
    // relabel so the root is rank 0
    let rel = |r: usize| r ^ root_r;
    let mut have: Vec<bool> = (0..size).map(|r| rel(r) == 0).collect();
    for j in (0..dim).rev() {
        let bit = 1usize << j;
        // one binomial round: holders pass to their dimension-j partners —
        // sender/receiver sets are disjoint, so the round batches exactly
        mach.begin_superstep();
        for r in 0..size {
            if have[r] && rel(r) & (bit - 1) == 0 && rel(r) & bit == 0 {
                let partner = rel(rel(r) | bit); // undo relabel
                if !have[partner] {
                    mach.send(pes[r], pes[partner], l);
                    have[partner] = true;
                }
            }
        }
        mach.settle();
    }
    debug_assert!(have.iter().all(|&h| h));
}

/// Hypercube all-reduce of one `u64` per member with operator `op`.
/// Returns the reduced value (same on every member). Cost: (α+β)·log q.
pub fn allreduce_u64(
    mach: &mut Machine,
    pes: &[usize],
    vals: &[u64],
    op: impl Fn(u64, u64) -> u64,
) -> u64 {
    let dim = assert_pow2(pes);
    let size = pes.len();
    let mut cur: Vec<u64> = pes.iter().map(|&pe| vals[pe]).collect();
    for j in 0..dim {
        let bit = 1usize << j;
        let snapshot = cur.clone();
        mach.begin_superstep();
        for (r, pr) in rank_pairs(size, j) {
            mach.xchg(pes[r], pes[pr], 1, 1);
        }
        mach.settle();
        for r in 0..size {
            cur[r] = op(snapshot[r], snapshot[r ^ bit]);
        }
    }
    let v = cur[0];
    debug_assert!(cur.iter().all(|&x| x == v));
    v
}

/// Element-wise all-reduce of equal-length `u64` vectors (RFIS' scattered
/// rank reduction uses this along grid rows). `vals` is indexed by global
/// PE. Cost: (α + β·len)·log q.
pub fn allreduce_vec_u64(
    mach: &mut Machine,
    pes: &[usize],
    vals: &mut [Vec<u64>],
    op: impl Fn(u64, u64) -> u64 + Sync,
) {
    let dim = assert_pow2(pes);
    let size = pes.len();
    let len = vals[pes[0]].len();
    debug_assert!(pes.iter().all(|&pe| vals[pe].len() == len));
    for j in 0..dim {
        let bit = 1usize << j;
        let snapshot: Vec<Vec<u64>> = pes.iter().map(|&pe| vals[pe].clone()).collect();
        mach.begin_superstep();
        for (r, pr) in rank_pairs(size, j) {
            mach.xchg(pes[r], pes[pr], len, len);
        }
        mach.settle();
        // element-wise combine: one PE task per member — RFIS' rank
        // reduction runs this over n/√p-length vectors. `vals` is
        // global-PE-indexed and the group may be strided, so the vectors
        // are taken out around the round (pointer moves).
        let mut items: Vec<Vec<u64>> =
            pes.iter().map(|&pe| std::mem::take(&mut vals[pe])).collect();
        let op = &op;
        mach.par_pes_on(pes, ParSpec::work(size * len), &mut items, |ctx, dst| {
            let pr = ctx.rank() ^ bit;
            for (d, s) in dst.iter_mut().zip(snapshot[pr].iter()) {
                *d = op(*d, *s);
            }
            ctx.work_linear(len);
        });
        for (&pe, item) in pes.iter().zip(items) {
            vals[pe] = item;
        }
    }
}

/// Hypercube exclusive prefix sum + total over one `usize` per member.
/// Returns `(exclusive_prefix, total)` per member in group rank order.
pub fn prefix_sum(mach: &mut Machine, pes: &[usize], vals: &[usize]) -> Vec<(usize, usize)> {
    let dim = assert_pow2(pes);
    let size = pes.len();
    let mut pre: Vec<usize> = vec![0; size];
    let mut tot: Vec<usize> = pes.iter().map(|&pe| vals[pe]).collect();
    for j in 0..dim {
        let bit = 1usize << j;
        let pre_snap = pre.clone();
        let tot_snap = tot.clone();
        mach.begin_superstep();
        for (r, pr) in rank_pairs(size, j) {
            mach.xchg(pes[r], pes[pr], 1, 1);
        }
        mach.settle();
        for r in 0..size {
            let pr = r ^ bit;
            if pr < r {
                pre[r] = pre_snap[r] + tot_snap[pr];
            }
            tot[r] = tot_snap[r] + tot_snap[pr];
        }
    }
    pre.into_iter().zip(tot).collect()
}

/// Vector variant of [`prefix_sum`]: per-member vector of `usize` counts
/// (e.g. one slot per bucket); returns `(exclusive_prefix_vec, total_vec)`
/// per member in rank order. Cost: (α + β·len)·log q.
pub fn prefix_sum_vec(
    mach: &mut Machine,
    pes: &[usize],
    vals: &[Vec<usize>],
) -> Vec<(Vec<usize>, Vec<usize>)> {
    let dim = assert_pow2(pes);
    let size = pes.len();
    let len = vals[0].len();
    debug_assert!(vals.iter().all(|v| v.len() == len));
    let mut pre: Vec<Vec<usize>> = vec![vec![0; len]; size];
    let mut tot: Vec<Vec<usize>> = vals.to_vec();
    for j in 0..dim {
        let bit = 1usize << j;
        let pre_snap = pre.clone();
        let tot_snap = tot.clone();
        mach.begin_superstep();
        for (r, pr) in rank_pairs(size, j) {
            mach.xchg(pes[r], pes[pr], len, len);
        }
        mach.settle();
        for r in 0..size {
            let pr = r ^ bit;
            for i in 0..len {
                if pr < r {
                    pre[r][i] = pre_snap[r][i] + tot_snap[pr][i];
                }
                tot[r][i] = tot_snap[r][i] + tot_snap[pr][i];
            }
            mach.work_linear(pes[r], len);
        }
    }
    pre.into_iter().zip(tot).collect()
}

/// Direct (non-hypercube) all-to-all personalized exchange: member `r`
/// sends `send[r][t]` to member `t` in one irregular round — the Ω(q)
/// startup pattern of single-level algorithms (SSort).
/// Returns `recv[t][r] = send[r][t]`.
pub fn alltoallv(
    mach: &mut Machine,
    pes: &[usize],
    send: Vec<Vec<Vec<Elem>>>,
) -> Vec<Vec<Vec<Elem>>> {
    let size = pes.len();
    debug_assert_eq!(send.len(), size);
    let mut ex = mach.exchange();
    for (r, targets) in send.into_iter().enumerate() {
        debug_assert_eq!(targets.len(), size);
        for (t, data) in targets.into_iter().enumerate() {
            // sender-rank tags rebuild the transposed table below; empty
            // payloads are skipped (never a wire message), self-posts are
            // free local moves — the historical route-round semantics
            ex.post_tagged(pes[r], pes[t], r as u64, data);
        }
    }
    let mut inboxes = ex.deliver(mach);
    let mut recv: Vec<Vec<Vec<Elem>>> = (0..size).map(|_| vec![Vec::new(); size]).collect();
    for t in 0..size {
        for (tag, payload) in inboxes.take(pes[t]) {
            recv[t][tag as usize] = payload;
        }
        let total: usize = recv[t].iter().map(|v| v.len()).sum();
        mach.note_mem(pes[t], total, "alltoallv");
    }
    mach.recycle(inboxes);
    recv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CostModel;
    use crate::sim::Cube;

    fn machine(p: usize) -> Machine {
        Machine::new(p, CostModel { alpha: 100.0, beta: 1.0, cmp: 1.0, duplex: true })
    }

    fn elems(pe: usize, keys: &[u64]) -> Vec<Elem> {
        let mut v: Vec<Elem> =
            keys.iter().enumerate().map(|(i, &k)| Elem::new(k, pe, i)).collect();
        v.sort();
        v
    }

    #[test]
    fn all_gather_merge_collects_everything_sorted() {
        let mut m = machine(4);
        let local = vec![
            elems(0, &[10, 40]),
            elems(1, &[20]),
            elems(2, &[5, 30, 35]),
            elems(3, &[25]),
        ];
        let runs = all_gather_merge(&mut m, &Cube::whole(4).pe_vec(), &local);
        for (pe, r) in runs.iter().enumerate() {
            let merged = r.merged();
            assert_eq!(merged.len(), 7, "pe {pe}");
            assert!(crate::elements::is_sorted(&merged));
            assert_eq!(r.own.len(), local[pe].len());
        }
        // provenance: rank 0 has everything in `right`, rank 3 in `left`
        assert_eq!(runs[0].left.len(), 0);
        assert_eq!(runs[0].right.len(), 5);
        assert_eq!(runs[3].right.len(), 0);
        assert_eq!(runs[3].left.len(), 6);
        assert_eq!(runs[1].left.len(), 2);
        assert_eq!(runs[1].right.len(), 4);
    }

    #[test]
    fn all_gather_merge_on_strided_group() {
        // a "column" of a 2×2 grid: PEs {1, 3}
        let mut m = machine(4);
        let local = vec![elems(0, &[9]), elems(1, &[5]), elems(2, &[9]), elems(3, &[1])];
        let runs = all_gather_merge(&mut m, &[1, 3], &local);
        assert_eq!(runs[0].merged().len(), 2);
        assert_eq!(runs[0].right[0].key, 1); // PE 3's element, higher-ranked
        assert_eq!(runs[1].left[0].key, 5);
        assert_eq!(m.clock(0), 0.0);
        assert_eq!(m.clock(2), 0.0);
    }

    #[test]
    fn all_gather_merge_cost_is_log_latency() {
        let mut m = machine(8);
        let local: Vec<Vec<Elem>> = (0..8).map(|pe| elems(pe, &[pe as u64])).collect();
        all_gather_merge(&mut m, &Cube::whole(8).pe_vec(), &local);
        assert!(m.time() < 4.0 * 100.0 + 100.0);
        assert!(m.time() >= 3.0 * 100.0);
    }

    #[test]
    fn gather_merge_root_gets_sorted_whole() {
        let mut m = machine(8);
        let local: Vec<Vec<Elem>> =
            (0..8).map(|pe| elems(pe, &[(8 - pe) as u64 * 10, pe as u64])).collect();
        let out = gather_merge(&mut m, &Cube::whole(8).pe_vec(), &local);
        assert_eq!(out.len(), 16);
        assert!(crate::elements::is_sorted(&out));
    }

    #[test]
    fn gather_merge_on_subcube() {
        let mut m = machine(8);
        let local: Vec<Vec<Elem>> = (0..8).map(|pe| elems(pe, &[pe as u64])).collect();
        let cube = Cube { prefix: 1, dim: 2 }; // PEs 4..8
        let out = gather_merge(&mut m, &cube.pe_vec(), &local);
        let keys: Vec<u64> = out.iter().map(|e| e.key).collect();
        assert_eq!(keys, vec![4, 5, 6, 7]);
        assert_eq!(m.clock(0), 0.0);
    }

    #[test]
    fn allreduce_u64_sums() {
        let mut m = machine(8);
        let vals: Vec<u64> = (0..8).collect();
        let s = allreduce_u64(&mut m, &Cube::whole(8).pe_vec(), &vals, |a, b| a + b);
        assert_eq!(s, 28);
        assert_eq!(m.stats.messages, 2 * 4 * 3);
    }

    #[test]
    fn allreduce_vec_sums_elementwise() {
        let mut m = machine(4);
        let mut vals: Vec<Vec<u64>> = (0..4).map(|pe| vec![pe as u64, 1]).collect();
        allreduce_vec_u64(&mut m, &Cube::whole(4).pe_vec(), &mut vals, |a, b| a + b);
        for v in &vals {
            assert_eq!(v, &vec![6, 4]);
        }
    }

    #[test]
    fn prefix_sum_exclusive() {
        let mut m = machine(8);
        let vals: Vec<usize> = (0..8).map(|pe| pe + 1).collect();
        let out = prefix_sum(&mut m, &Cube::whole(8).pe_vec(), &vals);
        let mut acc = 0;
        for (r, &(pre, tot)) in out.iter().enumerate() {
            assert_eq!(pre, acc, "rank {r}");
            assert_eq!(tot, 36);
            acc += vals[r];
        }
    }

    #[test]
    fn prefix_sum_vec_per_slot() {
        let mut m = machine(4);
        let vals: Vec<Vec<usize>> = (0..4).map(|r| vec![r, 10 * r]).collect();
        let out = prefix_sum_vec(&mut m, &Cube::whole(4).pe_vec(), &vals);
        let mut acc = [0usize, 0];
        for (r, (pre, tot)) in out.iter().enumerate() {
            assert_eq!(pre[0], acc[0]);
            assert_eq!(pre[1], acc[1]);
            assert_eq!(tot, &vec![6, 60]);
            acc[0] += vals[r][0];
            acc[1] += vals[r][1];
        }
    }

    #[test]
    fn alltoallv_delivers_transposed() {
        let mut m = machine(4);
        let send: Vec<Vec<Vec<Elem>>> = (0..4)
            .map(|r| (0..4).map(|t| elems(r, &[(r * 10 + t) as u64])).collect())
            .collect();
        let recv = alltoallv(&mut m, &Cube::whole(4).pe_vec(), send);
        for t in 0..4 {
            for r in 0..4 {
                assert_eq!(recv[t][r][0].key, (r * 10 + t) as u64);
            }
        }
        assert_eq!(m.stats.messages, 12);
    }

    #[test]
    fn bcast_cost_log_rounds() {
        let mut m = machine(16);
        bcast_cost(&mut m, &Cube::whole(16).pe_vec(), 0, 1);
        assert_eq!(m.stats.messages, 15);
        assert!(m.time() <= 4.0 * 101.0 + 1e-9);
        // non-zero root
        let mut m = machine(8);
        bcast_cost(&mut m, &Cube::whole(8).pe_vec(), 5, 2);
        assert_eq!(m.stats.messages, 7);
    }
}
