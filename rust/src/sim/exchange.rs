//! The pooled **Exchange** data plane: typed element movement that charges
//! the cost model from the same call.
//!
//! Before this layer existed, every communication step lived twice — once
//! as a cost charge on [`Machine`] (`xchg`/`send`/`route_round`) and once
//! as hand-rolled `Vec<Vec<Elem>>` payload juggling inside each algorithm.
//! The paper's robustness results hinge on the *charged* volumes matching
//! the *moved* volumes (that is what DMA and tie-breaking bound — §III,
//! Fig. 2), yet nothing enforced that correspondence, and the duplicated
//! bookkeeping was the dominant allocation churn on the simulator's hot
//! path.
//!
//! An [`Exchange`] is a one-round mailbox: algorithms obtain one from
//! [`Machine::exchange`], post element payloads with [`Exchange::xchg`] /
//! [`Exchange::xchg_leg`] / [`Exchange::send`] / [`Exchange::post`], and
//! close the round with [`Exchange::deliver`], which
//!
//! 1. charges the machine — pairwise ops in call order (exactly the eager
//!    `Machine::xchg`/`Machine::send` sequence every converted call site
//!    used to issue), then all routed posts as **one** irregular
//!    h-relation, coalesced per `(from, to)` pair and charged in sorted
//!    `(from, to)` order (exactly the sorted message list the call sites
//!    used to hand to `Machine::route_round`);
//! 2. moves every posted payload into per-PE inboxes ([`Inboxes`]),
//!    preserving post order per receiver;
//! 3. `debug_assert!`s that the element count charged to the cost model
//!    equals the element count delivered remotely — the charged == moved
//!    invariant. Both counts also accumulate on the machine
//!    ([`Machine::exchange_charged`] / [`Machine::exchange_moved`]) so
//!    tests can check the invariant machine-wide across a whole run.
//!    The invariant guards *plane-internal* consistency (every payload
//!    that moves through a mailbox is charged exactly once, and nothing
//!    charged fails to arrive); an algorithm that bypasses the plane
//!    entirely never touches the counters, which is why the test suite
//!    additionally asserts that every built-in sorter records *nonzero*
//!    plane traffic (`rust/tests/exchange_invariant.rs`) and pins the
//!    exact charge sequences against pre-refactor oracles
//!    (`rust/tests/exchange_equivalence.rs`).
//!
//! All staging (op lists, the posted-run arena, pair slots, the route
//! coalescing map) and all mailbox buffers are owned by the [`Machine`]
//! and reused across rounds — extending the `Machine::reset` scratch-reuse
//! story: after warmup a dimension round allocates nothing. Algorithms
//! building outgoing payloads draw reusable element buffers from the same
//! pool with [`Machine::take_buf`] and return delivered mail with
//! [`Machine::recycle`].
//!
//! # Charging semantics (identical to the raw machine API)
//!
//! * **Pairwise** ([`Exchange::xchg`], [`Exchange::xchg_leg`],
//!   [`Exchange::xchg_touch`]): the telephone model — both partners finish
//!   at `max(c_i, c_j) + α + β·len`. A pair is charged once per round even
//!   if both directions are empty (lock-step hypercube rounds pay the
//!   startup regardless). At most one pairwise op per PE per round (the
//!   disjointness contract of one hypercube dimension).
//! * **One-way** ([`Exchange::send`]): sender busy `α + β·l`, receiver
//!   resumes at the arrival — always charged, even for an empty payload
//!   (binomial-tree rounds send headers regardless).
//! * **Routed** ([`Exchange::post`]): buffered into the round's combined
//!   h-relation. Posts to *self* are local moves (delivered, never
//!   charged); empty payloads are skipped entirely (no message, no
//!   delivery) — matching the historical `route_round` call sites, which
//!   never enqueued empty messages.
//!
//! Routed rounds have a second delivery flavour,
//! [`Exchange::deliver_1factor`]: instead of charging the h-relation as
//! one monolithic superstep, the irregular exchange is scheduled into
//! [`one_factor_rounds`] lock-step pairwise rounds (the 1-factor
//! algorithm of the successor paper, *Practical Massively Parallel
//! Sorting*), each round a perfect matching charged as disjoint
//! [`Machine::xchg`] calls. Charged and moved element totals are
//! identical to [`Exchange::deliver`]; debug builds additionally assert
//! charged == moved **per round**.
//!
//! Scalar/metadata traffic (pivot windows, splitter broadcasts, histogram
//! reductions) moves no elements and stays on the raw
//! `Machine::xchg`/`send`/`route_round` API — the invariant deliberately
//! covers element payloads only.

use std::collections::HashMap;

use crate::elements::Elem;
use crate::sim::Machine;

/// One delivered payload run: `(tag, elements)`. Tags are opaque to the
/// data plane; algorithms use them to address multi-hop traffic (RAMS'
/// deterministic message assignment forwards on the tag) or to carry
/// per-run metadata (RFIS tags runs with the destination row). Plain
/// consumers post with tag 0 and ignore it.
pub type Run = (u64, Vec<Elem>);

/// A buffered pairwise (`xchg`/`send`) operation of an open exchange.
#[derive(Clone, Debug)]
struct PairOp {
    /// First-leg direction `i → j` (the charge is issued as
    /// `Machine::xchg(i, j, len_ij, len_ji)`, matching the historical
    /// low-rank-first call sites).
    i: usize,
    j: usize,
    len_ij: usize,
    len_ji: usize,
    is_send: bool,
}

/// One payload run in flight, in post order.
#[derive(Clone, Debug)]
struct PostedRun {
    /// Originating PE — the 1-factor delivery needs it to place the run
    /// into its scheduled round; the monolithic path ignores it.
    from: usize,
    dest: usize,
    tag: u64,
    /// Whether this run's words were charged to the cost model (false for
    /// local `post`s from a PE to itself).
    charged: bool,
    payload: Vec<Elem>,
}

/// Machine-owned staging + pools for the data plane (all reused across
/// rounds; see the module docs).
#[derive(Clone, Debug, Default)]
pub(crate) struct PlanePool {
    /// Spare cleared element buffers ([`Machine::take_buf`]).
    bufs: Vec<Vec<Elem>>,
    /// Spare per-PE inbox tables (slots empty).
    tables: Vec<Vec<Vec<Run>>>,
    /// Staging for the next [`Machine::exchange`] round.
    ops: Vec<PairOp>,
    posted: Vec<PostedRun>,
    /// Per-PE pairwise-op slot: op index + 1, 0 = none. Zeroed outside an
    /// open exchange (deliver clears exactly the slots it dirtied).
    pair_slot: Vec<u32>,
    /// Route coalescing: `(from, to)` → index into `route`.
    route_idx: HashMap<(usize, usize), u32>,
    /// Coalesced routed messages `(from, to, words)` in first-post order.
    route: Vec<(usize, usize, usize)>,
    /// Scratch for the sorted charged message list handed to
    /// `route_round`.
    route_sorted: Vec<(usize, usize, usize)>,
    /// Scratch list for empty payloads awaiting return to `bufs`.
    skipped: Vec<Vec<Elem>>,
    /// Per-dest run counts for the parallel inbox materialization of
    /// large rounds (see [`Exchange::deliver`]). All-zero outside a
    /// delivery: each round zeroes exactly the destinations it counted.
    deliver_counts: Vec<u32>,
    /// Per-run inbox slot (post order within its destination), same path.
    deliver_slots: Vec<u32>,
    /// Pool of touched-destination lists — one travels with every
    /// [`Inboxes`] so [`Machine::recycle`] drains only dirtied slots.
    touched_lists: Vec<Vec<u32>>,
    /// 1-factor scratch: per-PE participant rank, all-`u32::MAX` outside
    /// a delivery (each delivery restores exactly the `pes` it ranked).
    fac_rank: Vec<u32>,
    /// 1-factor scratch: coalesced message lengths bucketed by
    /// `(scheduled round, low rank)` — the O(messages) side table that
    /// replaces per-pair hash probes in [`Exchange::deliver_1factor`].
    fac_entries: Vec<(u32, u32, usize, usize)>,
}

impl PlanePool {
    pub(crate) fn take_buf(&mut self) -> Vec<Elem> {
        self.bufs.pop().unwrap_or_default()
    }

    pub(crate) fn recycle_buf(&mut self, mut buf: Vec<Elem>) {
        buf.clear();
        self.bufs.push(buf);
    }

    /// Defensive clear between runs. Staging handed back by
    /// [`Exchange::deliver`] is always drained (an Exchange abandoned
    /// *without* delivering drops its staging with itself), so these loops
    /// normally find nothing — they exist so no future partial-return
    /// path can leak one run's state into the next.
    pub(crate) fn reset(&mut self) {
        while let Some(run) = self.posted.pop() {
            self.recycle_buf(run.payload);
        }
        while let Some(buf) = self.skipped.pop() {
            self.recycle_buf(buf);
        }
        // pair slots are only dirtied together with an `ops` entry, so
        // the staged ops name every dirty slot — O(staged), never O(p)
        for idx in 0..self.ops.len() {
            let (a, b) = (self.ops[idx].i, self.ops[idx].j);
            if let Some(s) = self.pair_slot.get_mut(a) {
                *s = 0;
            }
            if let Some(s) = self.pair_slot.get_mut(b) {
                *s = 0;
            }
        }
        self.ops.clear();
        self.route_idx.clear();
        self.route.clear();
        self.route_sorted.clear();
        self.deliver_counts.clear();
        self.deliver_slots.clear();
        self.fac_entries.clear();
    }
}

/// Posted-run multiplier over [`Machine::par_min_work`] from which
/// [`Exchange::deliver`] distributes the per-PE inbox materialization
/// over the worker pool (see [`Machine::par_deliver_min_runs`]); below
/// it the sequential drain wins — each move is only a ~32-byte pointer
/// relocation, so the break-even sits higher than for element-touching
/// PE tasks. `2 ×` the default 8192-element threshold keeps the
/// long-standing `1 << 14`-runs cutoff now that [`crate::sim::PAR_MIN_WORK`]
/// is re-pinned to CI's measured crossover (it was `4 ×` over the old
/// 4096 default — same product, one knob still tunes both gates).
const PAR_DELIVER_RUNS_FACTOR: usize = 2;

/// Rounds in the 1-factorization of the complete graph on `q`
/// participants: `q − 1` for even `q` (every round a perfect matching),
/// `q` for odd `q` (one participant idles per round), `0` when there is
/// at most one participant.
pub fn one_factor_rounds(q: usize) -> usize {
    match q {
        0 | 1 => 0,
        q if q % 2 == 0 => q - 1,
        q => q,
    }
}

/// The 1-factor partner of local rank `i` (of `q` participants) in round
/// `r`, or `None` when `i` idles that round (odd `q` only).
///
/// The classic circle construction: for odd `q`, ranks `i` and `j` meet
/// in round `(i + j) mod q` and the rank with `2i ≡ r (mod q)` idles; for
/// even `q`, ranks `0..q−1` play the odd schedule over `q − 1` and the
/// rank that would idle meets rank `q − 1` instead. Every unordered pair
/// meets in exactly one of the [`one_factor_rounds`]`(q)` rounds
/// (asserted over a q-grid in this module's tests).
pub fn one_factor_partner(q: usize, r: usize, i: usize) -> Option<usize> {
    debug_assert!(i < q && r < one_factor_rounds(q));
    if q % 2 == 0 {
        let m = q - 1;
        if i == m {
            // the rank self-paired in round r: the unique x with
            // 2x ≡ r (mod m), m odd
            Some(if r % 2 == 0 { r / 2 } else { (r + m) / 2 })
        } else {
            let j = (r + m - i) % m;
            if j == i {
                Some(m)
            } else {
                Some(j)
            }
        }
    } else {
        let j = (r + q - i) % q;
        if j == i {
            None
        } else {
            Some(j)
        }
    }
}

/// The round in which ranks `i` and `j` meet under
/// [`one_factor_partner`]`(q, ..)`.
pub fn one_factor_round_of(q: usize, i: usize, j: usize) -> usize {
    debug_assert!(i < q && j < q && i != j);
    if q % 2 == 0 {
        let m = q - 1;
        if i == m {
            (2 * j) % m
        } else if j == m {
            (2 * i) % m
        } else {
            (i + j) % m
        }
    } else {
        (i + j) % q
    }
}

/// An open payload round on one [`Machine`] — see the module docs.
///
/// Obtained from [`Machine::exchange`]; does **not** borrow the machine,
/// so local-work charges (`Machine::work_*`, `Machine::note_mem`) freely
/// interleave with posting, exactly like the historical call sites.
/// Consumed by [`Exchange::deliver`].
#[derive(Debug)]
pub struct Exchange {
    p: usize,
    /// Identity of the machine that opened this round — `deliver` on a
    /// different machine would charge the wrong clocks and migrate pooled
    /// staging between machines, so it is asserted against.
    mach_id: u64,
    ops: Vec<PairOp>,
    posted: Vec<PostedRun>,
    pair_slot: Vec<u32>,
    route_idx: HashMap<(usize, usize), u32>,
    route: Vec<(usize, usize, usize)>,
    route_sorted: Vec<(usize, usize, usize)>,
    /// Payloads skipped as empty routed posts — returned to the pool at
    /// delivery so callers can post pool buffers unconditionally.
    skipped: Vec<Vec<Elem>>,
}

impl Exchange {
    fn op_slot(&mut self, a: usize, b: usize, is_send: bool) -> usize {
        debug_assert!(a != b, "exchange op endpoints must differ ({a})");
        debug_assert!(a < self.p && b < self.p);
        // lazy growth to the highest PE that ever joins a pairwise op —
        // amortized one-time per machine, never an O(p) clear per round
        let hi = a.max(b);
        if self.pair_slot.len() <= hi {
            self.pair_slot.resize(hi + 1, 0);
        }
        let slot = self.pair_slot[a];
        if slot != 0 {
            let idx = slot as usize - 1;
            let op = &self.ops[idx];
            debug_assert!(
                !op.is_send && !is_send && (op.i == a && op.j == b || op.i == b && op.j == a),
                "a PE may appear in at most one pairwise op per round \
                 (PE {a} reused)"
            );
            return idx;
        }
        debug_assert!(
            self.pair_slot[b] == 0,
            "a PE may appear in at most one pairwise op per round (PE {b} reused)"
        );
        let idx = self.ops.len();
        self.ops.push(PairOp { i: a, j: b, len_ij: 0, len_ji: 0, is_send });
        self.pair_slot[a] = idx as u32 + 1;
        self.pair_slot[b] = idx as u32 + 1;
        idx
    }

    /// Ensure the pairwise op `(i, j)` exists with zero-length legs — the
    /// lock-step rounds that pay α even when neither side has data
    /// (RFIS' in-column delivery touches every pair every round).
    pub fn xchg_touch(&mut self, i: usize, j: usize) {
        self.op_slot(i, j, false);
    }

    /// One leg of a pairwise exchange: `payload` travels `from → to`.
    /// The partner leg (posted separately, possibly empty) completes the
    /// op; the pair is charged once as `Machine::xchg` at delivery, in
    /// first-leg call order.
    pub fn xchg_leg(&mut self, from: usize, to: usize, payload: Vec<Elem>) {
        self.xchg_leg_tagged(from, to, 0, payload);
    }

    /// [`Exchange::xchg_leg`] with an explicit run tag. Repeated legs in
    /// the same direction accumulate (charged as their total length,
    /// delivered as separate runs in post order).
    pub fn xchg_leg_tagged(&mut self, from: usize, to: usize, tag: u64, payload: Vec<Elem>) {
        let idx = self.op_slot(from, to, false);
        let op = &mut self.ops[idx];
        if op.i == from {
            op.len_ij += payload.len();
        } else {
            op.len_ji += payload.len();
        }
        if payload.is_empty() {
            self.skipped.push(payload);
        } else {
            self.posted.push(PostedRun { from, dest: to, tag, charged: true, payload });
        }
    }

    /// Full pairwise exchange: `a` travels `i → j`, `b` travels `j → i`,
    /// charged once as `Machine::xchg(i, j, |a|, |b|)` at delivery.
    pub fn xchg(&mut self, i: usize, j: usize, a: Vec<Elem>, b: Vec<Elem>) {
        self.xchg_leg(i, j, a);
        self.xchg_leg(j, i, b);
    }

    /// One-way message (binomial-tree rounds): charged as
    /// `Machine::send(from, to, |payload|)` at delivery, in call order —
    /// even when the payload is empty.
    pub fn send(&mut self, from: usize, to: usize, payload: Vec<Elem>) {
        let idx = self.op_slot(from, to, true);
        debug_assert!(self.ops[idx].i == from, "send ops are one-directional");
        self.ops[idx].len_ij += payload.len();
        if payload.is_empty() {
            self.skipped.push(payload);
        } else {
            self.posted.push(PostedRun { from, dest: to, tag: 0, charged: true, payload });
        }
    }

    /// Routed message for the round's irregular h-relation — tag 0.
    /// See [`Exchange::post_tagged`].
    pub fn post(&mut self, from: usize, to: usize, payload: Vec<Elem>) {
        self.post_tagged(from, to, 0, payload);
    }

    /// Routed message with an explicit run tag. Posts to the same
    /// `(from, to)` pair coalesce into one wire message (one α, β·total),
    /// delivered as separate runs in post order. `from == to` is a free
    /// local move; empty payloads are skipped entirely.
    pub fn post_tagged(&mut self, from: usize, to: usize, tag: u64, payload: Vec<Elem>) {
        debug_assert!(from < self.p && to < self.p);
        if payload.is_empty() {
            self.skipped.push(payload);
            return;
        }
        if from == to {
            self.posted.push(PostedRun { from, dest: to, tag, charged: false, payload });
            return;
        }
        match self.route_idx.entry((from, to)) {
            std::collections::hash_map::Entry::Occupied(e) => {
                self.route[*e.get() as usize].2 += payload.len();
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(self.route.len() as u32);
                self.route.push((from, to, payload.len()));
            }
        }
        self.posted.push(PostedRun { from, dest: to, tag, charged: true, payload });
    }

    /// Close the round: charge the machine (pairwise ops in call order,
    /// then the routed h-relation in sorted `(from, to)` order), move all
    /// payloads into per-PE inboxes, and assert charged == moved.
    pub fn deliver(mut self, mach: &mut Machine) -> Inboxes {
        self.check_deliverable(mach);
        // ---- charge ---------------------------------------------------
        let mut charged_words: u64 = 0;
        for op in &self.ops {
            if op.is_send {
                debug_assert_eq!(op.len_ji, 0);
                mach.send(op.i, op.j, op.len_ij);
            } else {
                mach.xchg(op.i, op.j, op.len_ij, op.len_ji);
            }
            charged_words += (op.len_ij + op.len_ji) as u64;
        }
        self.route_sorted.clear();
        self.route_sorted.extend_from_slice(&self.route);
        self.route_sorted.sort_unstable();
        #[cfg(debug_assertions)]
        for &(from, to, _) in &self.route_sorted {
            debug_assert!(
                !self.pair_slot.get(from).is_some_and(|&s| s != 0)
                    && !self.pair_slot.get(to).is_some_and(|&s| s != 0),
                "routed posts must not share PEs with pairwise ops in one \
                 round (message {from}→{to})"
            );
        }
        mach.route_round(&self.route_sorted);
        charged_words += self.route_sorted.iter().map(|&(_, _, l)| l as u64).sum::<u64>();

        self.finish(mach, charged_words)
    }

    /// Close the round with the **1-factor schedule** of the successor
    /// paper (*Practical Massively Parallel Sorting*, Axtmann et al.):
    /// instead of one monolithic [`Machine::route_round`], the irregular
    /// h-relation is delivered in [`one_factor_rounds`]`(q)` lock-step
    /// pairwise rounds over the `q` participants in `pes` — `q − 1`
    /// rounds for even `q`, `q` for odd. Round `r` pairs local rank `i`
    /// with [`one_factor_partner`]`(q, r, i)` and charges each pair as
    /// one [`Machine::xchg`] (telephone model; the α is paid even when
    /// neither direction has data — the schedule is oblivious), so a
    /// receiver's fan-in is spread over rounds instead of serializing on
    /// one PE. Startup is Θ(α·q) per participant regardless of sparsity;
    /// the word volume charged is identical to [`Exchange::deliver`], as
    /// are payload movement and per-receiver run order. Debug builds
    /// assert charged == moved **per scheduled round** on top of the
    /// usual round total.
    ///
    /// Only routed posts ([`Exchange::post`] / [`Exchange::post_tagged`])
    /// may be staged — pairwise ops carry their own schedule (asserted).
    /// Every remote post's endpoints must be listed in `pes`; posts to
    /// self stay free local moves.
    pub fn deliver_1factor(mut self, mach: &mut Machine, pes: &[usize]) -> Inboxes {
        self.check_deliverable(mach);
        assert!(
            self.ops.is_empty(),
            "a 1-factor delivery covers routed posts only (pairwise ops staged)"
        );
        let q = pes.len();
        // Pooled participant-rank table, sized to the highest participant
        // ever seen (not to p) and restored to all-`u32::MAX` by walking
        // `pes` afterwards — ranking is O(q) per delivery with zero
        // steady-state allocation.
        let mut rank = std::mem::take(&mut mach.plane.fac_rank);
        let hi = pes.iter().copied().max().map_or(0, |m| m + 1);
        if rank.len() < hi {
            rank.resize(hi, u32::MAX);
        }
        for (r, &pe) in pes.iter().enumerate() {
            assert!(pe < self.p, "participant {pe} outside the machine");
            debug_assert!(rank[pe] == u32::MAX, "participant {pe} listed twice");
            rank[pe] = r as u32;
        }
        // Bucket the coalesced message lengths by (scheduled round, low
        // rank): the charge loop below walks them with a cursor instead of
        // probing the route hash per pair per round, so all length
        // bookkeeping is O(messages · log messages). The round × rank
        // enumeration itself must stay exhaustive — the 1-factor schedule
        // is *oblivious*, every pair pays its α every round even when both
        // directions are empty, and that simulated cost is exactly what
        // the equivalence suites pin. Host cost per delivery is therefore
        // O(q² + messages) with q = |pes| the *active* participants, never
        // O(p).
        let mut entries = std::mem::take(&mut mach.plane.fac_entries);
        debug_assert!(entries.is_empty());
        for &(from, to, l) in &self.route {
            let (ri, rj) = (
                rank.get(from).copied().unwrap_or(u32::MAX),
                rank.get(to).copied().unwrap_or(u32::MAX),
            );
            assert!(
                ri != u32::MAX && rj != u32::MAX,
                "1-factor participants must cover every posted endpoint \
                 (message {from}→{to})"
            );
            let r = one_factor_round_of(q, ri as usize, rj as usize) as u32;
            // a pair is charged at loop index i = min(ri, rj); store the
            // direction relative to that low rank
            if ri < rj {
                entries.push((r, ri, l, 0));
            } else {
                entries.push((r, rj, 0, l));
            }
        }
        entries.sort_unstable();
        // ---- charge: one pairwise xchg per pair per round --------------
        let rounds = one_factor_rounds(q);
        let mut charged_words: u64 = 0;
        #[cfg(debug_assertions)]
        let mut charged_per_round = vec![0u64; rounds];
        let mut cur = 0usize;
        for r in 0..rounds {
            for i in 0..q {
                let Some(j) = one_factor_partner(q, r, i) else { continue };
                if j < i {
                    continue; // each pair charged once, low rank first
                }
                // within a round each low rank appears in at most one pair
                // and pairs are visited in increasing low-rank order, so
                // the sorted entries advance strictly with the loop
                let (mut l_ab, mut l_ba) = (0usize, 0usize);
                while let Some(&(er, ei, ab, ba)) = entries.get(cur) {
                    if er as usize != r || ei as usize != i {
                        break;
                    }
                    l_ab += ab;
                    l_ba += ba;
                    cur += 1;
                }
                mach.xchg(pes[i], pes[j], l_ab, l_ba);
                charged_words += (l_ab + l_ba) as u64;
                #[cfg(debug_assertions)]
                {
                    charged_per_round[r] += (l_ab + l_ba) as u64;
                }
            }
        }
        debug_assert_eq!(cur, entries.len(), "1-factor entries not fully consumed");
        #[cfg(debug_assertions)]
        {
            // per-round invariant: each round's charged words equal the
            // words of the payloads whose (from, to) pair that round serves
            let mut moved_per_round = vec![0u64; rounds];
            for run in &self.posted {
                if run.charged {
                    let (i, j) = (rank[run.from] as usize, rank[run.dest] as usize);
                    moved_per_round[one_factor_round_of(q, i, j)] += run.payload.len() as u64;
                }
            }
            debug_assert_eq!(
                charged_per_round, moved_per_round,
                "1-factor schedule violated charged == moved within a round"
            );
        }
        // restore the pooled scratch invariants: rank all-MAX, entries empty
        for &pe in pes {
            rank[pe] = u32::MAX;
        }
        entries.clear();
        mach.plane.fac_rank = rank;
        mach.plane.fac_entries = entries;
        self.finish(mach, charged_words)
    }

    fn check_deliverable(&self, mach: &Machine) {
        assert_eq!(
            self.mach_id,
            mach.instance_id(),
            "exchange delivered on a different machine than opened it"
        );
        // charges must apply eagerly, not be buffered into (and reordered
        // by) an unrelated scalar superstep's transcript
        assert!(
            !mach.in_superstep(),
            "cannot deliver an exchange while a raw cost superstep is open"
        );
    }

    /// Shared second half of every delivery flavour: move the posted runs
    /// into per-PE inboxes, record and assert the charged == moved
    /// invariant, and hand all staging back to the machine's pool.
    fn finish(mut self, mach: &mut Machine, charged_words: u64) -> Inboxes {
        // ---- move -----------------------------------------------------
        // Host cost of this drain is O(posts): the mailbox table grows
        // lazily to the highest destination actually addressed, slots are
        // only touched where runs land, and a `touched` list of exactly
        // those destinations travels with the [`Inboxes`] so
        // [`Machine::recycle`] never walks the dense table.
        let mut table = mach.plane.tables.pop().unwrap_or_default();
        #[cfg(debug_assertions)]
        if table.len() <= 1 << 12 {
            debug_assert!(table.iter().all(|slot| slot.is_empty()));
        }
        let mut touched = mach.plane.touched_lists.pop().unwrap_or_default();
        debug_assert!(touched.is_empty());
        let mut moved: u64 = 0;
        if self.posted.len() >= mach.par_deliver_min_runs() && mach.pe_jobs() > 1 {
            // Large round: materialize the inboxes on the worker pool. A
            // counting pass assigns every run its (dest, slot) — slot =
            // post order within the destination, so per-receiver run
            // order is identical to the sequential drain — then the
            // pre-sized slots are filled in parallel. The final table is
            // bit-identical either way; only host wallclock changes.
            let posted_len = self.posted.len();
            let mut counts = std::mem::take(&mut mach.plane.deliver_counts);
            let mut slots = std::mem::take(&mut mach.plane.deliver_slots);
            slots.clear();
            slots.reserve(posted_len);
            let mut hi = 0usize;
            for run in &self.posted {
                if run.charged {
                    moved += run.payload.len() as u64;
                }
                if counts.len() <= run.dest {
                    counts.resize(run.dest + 1, 0);
                }
                if counts[run.dest] == 0 {
                    touched.push(run.dest as u32);
                }
                hi = hi.max(run.dest);
                slots.push(counts[run.dest]);
                counts[run.dest] += 1;
            }
            if table.len() <= hi {
                table.resize_with(hi + 1, Vec::new);
            }
            for &dest in &touched {
                // placeholder runs are overwritten below; `Vec::new` does
                // not allocate, so pre-sizing is one resize per touched dest
                table[dest as usize]
                    .resize_with(counts[dest as usize] as usize, || (0u64, Vec::new()));
            }
            {
                // bases cover only the addressed prefix — every run.dest
                // is ≤ hi, and pooled tables can be longer than this round
                let bases: Vec<crate::exec::SliceCells<Run>> = table[..hi + 1]
                    .iter_mut()
                    .map(|dest_box| crate::exec::SliceCells::new(dest_box.as_mut_slice()))
                    .collect();
                let posted_cells = crate::exec::SliceCells::new(&mut self.posted);
                let bases = &bases;
                let slots = &slots;
                crate::exec::parallel_map(mach.pe_jobs(), posted_len, move |i| {
                    // SAFETY: parallel_map claims each posted index exactly
                    // once, and every (dest, slot) pair is unique (slots
                    // are per-dest counters), so the two &mut borrows are
                    // disjoint across workers.
                    let run = unsafe { posted_cells.get_mut(i) };
                    let target = unsafe { bases[run.dest].get_mut(slots[i] as usize) };
                    *target = (run.tag, std::mem::take(&mut run.payload));
                });
            }
            self.posted.clear();
            // restore the all-zero invariant by walking only the slots
            // this round counted — O(touched), never O(p)
            for &dest in &touched {
                counts[dest as usize] = 0;
            }
            mach.plane.deliver_counts = counts;
            mach.plane.deliver_slots = slots;
        } else {
            for run in self.posted.drain(..) {
                if run.charged {
                    moved += run.payload.len() as u64;
                }
                if table.len() <= run.dest {
                    table.resize_with(run.dest + 1, Vec::new);
                }
                if table[run.dest].is_empty() {
                    touched.push(run.dest as u32);
                }
                table[run.dest].push((run.tag, run.payload));
            }
        }
        debug_assert_eq!(
            charged_words, moved,
            "exchange invariant violated: {charged_words} element-words \
             charged but {moved} elements delivered remotely"
        );
        mach.note_exchange(charged_words, moved);

        // ---- return staging + skipped buffers to the machine ----------
        for op in &self.ops {
            self.pair_slot[op.i] = 0;
            self.pair_slot[op.j] = 0;
        }
        self.ops.clear();
        self.route_idx.clear();
        self.route.clear();
        self.route_sorted.clear();
        for buf in self.skipped.drain(..) {
            mach.plane.recycle_buf(buf);
        }
        mach.plane.ops = std::mem::take(&mut self.ops);
        mach.plane.posted = std::mem::take(&mut self.posted);
        mach.plane.pair_slot = std::mem::take(&mut self.pair_slot);
        mach.plane.route_idx = std::mem::take(&mut self.route_idx);
        mach.plane.route = std::mem::take(&mut self.route);
        mach.plane.route_sorted = std::mem::take(&mut self.route_sorted);
        mach.plane.skipped = std::mem::take(&mut self.skipped);

        // One host settlement round closed, however it was charged.
        mach.bump_host_rounds();

        Inboxes { boxes: table, touched }
    }
}

/// Per-PE mailboxes returned by [`Exchange::deliver`], indexed by global
/// PE number. Hand back to [`Machine::recycle`] when drained so the run
/// lists and payload buffers return to the pool.
///
/// The table may be shorter than the machine's `p` — accessors treat
/// missing slots as empty. A `touched` index of exactly the destinations
/// that received runs travels with the mailboxes so recycling drains
/// O(touched) slots, never O(p).
#[derive(Debug, Default)]
pub struct Inboxes {
    boxes: Vec<Vec<Run>>,
    /// Destinations with at least one delivered run (dedup'd, first-post
    /// order). [`Machine::recycle`] drains exactly these slots.
    touched: Vec<u32>,
}

impl Inboxes {
    /// All runs delivered to `pe`, in post order.
    #[inline]
    pub fn runs(&self, pe: usize) -> &[Run] {
        self.boxes.get(pe).map_or(&[], Vec::as_slice)
    }

    /// The single run delivered to `pe` (empty slice if none) — for the
    /// pairwise rounds where each PE receives at most one payload.
    #[inline]
    pub fn single(&self, pe: usize) -> &[Elem] {
        let runs = self.runs(pe);
        debug_assert!(runs.len() <= 1, "PE {pe} received {} runs", runs.len());
        runs.first().map_or(&[], |(_, v)| v.as_slice())
    }

    /// Total elements delivered to `pe` (for memory accounting).
    #[inline]
    pub fn total(&self, pe: usize) -> usize {
        self.runs(pe).iter().map(|(_, v)| v.len()).sum()
    }

    /// Move `pe`'s runs out (the mailbox slot is left empty) — for
    /// consumers that forward payloads onward (RAMS' second DMA hop).
    pub fn take(&mut self, pe: usize) -> Vec<Run> {
        match self.boxes.get_mut(pe) {
            Some(slot) => std::mem::take(slot),
            None => Vec::new(),
        }
    }
}

impl Machine {
    /// Open a payload round on this machine — see [`Exchange`]. The
    /// returned object does not borrow the machine; interleave
    /// `work_*`/`note_mem` charges freely while posting, then call
    /// [`Exchange::deliver`].
    pub fn exchange(&mut self) -> Exchange {
        assert!(
            !self.in_superstep(),
            "cannot open an exchange inside a raw cost superstep"
        );
        // `pair_slot` grows lazily inside `op_slot` to the highest PE that
        // ever joins a pairwise op — opening an exchange on a giant-p
        // machine allocates nothing. The all-clean invariant is only
        // re-checked exhaustively at small sizes; at giant p the touched
        // cleanup paths (deliver / PlanePool::reset) are the contract.
        let pair_slot = std::mem::take(&mut self.plane.pair_slot);
        #[cfg(debug_assertions)]
        if pair_slot.len() <= 1 << 12 {
            debug_assert!(pair_slot.iter().all(|&s| s == 0));
        }
        Exchange {
            p: self.p(),
            mach_id: self.instance_id(),
            ops: std::mem::take(&mut self.plane.ops),
            posted: std::mem::take(&mut self.plane.posted),
            pair_slot,
            route_idx: std::mem::take(&mut self.plane.route_idx),
            route: std::mem::take(&mut self.plane.route),
            route_sorted: std::mem::take(&mut self.plane.route_sorted),
            skipped: std::mem::take(&mut self.plane.skipped),
        }
    }

    /// Posted-run count from which [`Exchange::deliver`] materializes the
    /// per-PE inboxes on the worker pool: `PAR_DELIVER_RUNS_FACTOR` ×
    /// the machine's [`Machine::par_min_work`] threshold, so the one
    /// `--par-min-work` / `RMPS_PAR_MIN_WORK` knob tunes both pooling
    /// gates together (`RMPS_PAR_MIN_WORK=1` force-pools delivery too; the
    /// default threshold reproduces the long-standing `1 << 14` cutoff).
    /// Saturating, so `--par-min-work` near `usize::MAX` cleanly means
    /// "never pooled". Like the PE-task gate, this affects host
    /// scheduling only — inbox tables are bit-identical either way.
    #[inline]
    pub fn par_deliver_min_runs(&self) -> usize {
        self.par_min_work().saturating_mul(PAR_DELIVER_RUNS_FACTOR)
    }

    /// A cleared element buffer from the data-plane pool (or a fresh one).
    /// Algorithms build outgoing payloads in these; the buffers cycle back
    /// through [`Machine::recycle`] after delivery.
    #[inline]
    pub fn take_buf(&mut self) -> Vec<Elem> {
        self.plane.take_buf()
    }

    /// Return a payload buffer to the pool (cleared).
    #[inline]
    pub fn recycle_buf(&mut self, buf: Vec<Elem>) {
        self.plane.recycle_buf(buf);
    }

    /// Return drained mailboxes to the pool: every remaining payload
    /// buffer is cleared and pooled, the table itself is reused by the
    /// next [`Exchange::deliver`]. Walks only the touched-slot index the
    /// delivery recorded — O(runs delivered), never O(p).
    pub fn recycle(&mut self, inboxes: Inboxes) {
        let Inboxes { mut boxes, mut touched } = inboxes;
        for &dest in &touched {
            if let Some(slot) = boxes.get_mut(dest as usize) {
                for (_, payload) in slot.drain(..) {
                    self.plane.recycle_buf(payload);
                }
            }
        }
        #[cfg(debug_assertions)]
        if boxes.len() <= 1 << 12 {
            debug_assert!(
                boxes.iter().all(|slot| slot.is_empty()),
                "recycled mailboxes held runs outside the touched index"
            );
        }
        touched.clear();
        self.plane.touched_lists.push(touched);
        self.plane.tables.push(boxes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CostModel;

    fn m(p: usize) -> Machine {
        Machine::new(p, CostModel { alpha: 100.0, beta: 1.0, cmp: 1.0, duplex: true })
    }

    fn elems(pe: usize, n: usize) -> Vec<Elem> {
        (0..n).map(|i| Elem::new((pe * 100 + i) as u64, pe, i)).collect()
    }

    #[test]
    fn xchg_charges_like_raw_machine_and_moves_payloads() {
        let mut raw = m(4);
        raw.work(0, 50.0);
        raw.xchg(0, 1, 3, 2);
        raw.xchg(2, 3, 0, 0);

        let mut mach = m(4);
        mach.work(0, 50.0);
        let mut ex = mach.exchange();
        ex.xchg(0, 1, elems(0, 3), elems(1, 2));
        ex.xchg(2, 3, Vec::new(), Vec::new());
        let inboxes = ex.deliver(&mut mach);

        for pe in 0..4 {
            assert_eq!(mach.clock(pe).to_bits(), raw.clock(pe).to_bits(), "pe {pe}");
        }
        assert_eq!(mach.stats.messages, raw.stats.messages);
        assert_eq!(mach.stats.words, raw.stats.words);
        assert_eq!(inboxes.single(0), elems(1, 2).as_slice());
        assert_eq!(inboxes.single(1), elems(0, 3).as_slice());
        assert!(inboxes.single(2).is_empty() && inboxes.single(3).is_empty());
        assert_eq!(mach.exchange_charged(), 5);
        assert_eq!(mach.exchange_moved(), 5);
        mach.recycle(inboxes);
    }

    #[test]
    fn legs_accumulate_and_charge_once_per_pair() {
        let mut raw = m(2);
        raw.xchg(0, 1, 5, 1);

        let mut mach = m(2);
        let mut ex = mach.exchange();
        ex.xchg_leg_tagged(0, 1, 7, elems(0, 2));
        ex.xchg_leg_tagged(0, 1, 9, elems(0, 3));
        ex.xchg_leg(1, 0, elems(1, 1));
        let inboxes = ex.deliver(&mut mach);

        assert_eq!(mach.clock(0).to_bits(), raw.clock(0).to_bits());
        assert_eq!(mach.stats.messages, 2);
        let runs = inboxes.runs(1);
        assert_eq!(runs.len(), 2);
        assert_eq!((runs[0].0, runs[0].1.len()), (7, 2));
        assert_eq!((runs[1].0, runs[1].1.len()), (9, 3));
        mach.recycle(inboxes);
    }

    #[test]
    fn send_charges_even_empty() {
        let mut raw = m(4);
        raw.send(0, 1, 4);
        raw.send(3, 2, 0);

        let mut mach = m(4);
        let mut ex = mach.exchange();
        ex.send(0, 1, elems(0, 4));
        ex.send(3, 2, Vec::new());
        let inboxes = ex.deliver(&mut mach);
        for pe in 0..4 {
            assert_eq!(mach.clock(pe).to_bits(), raw.clock(pe).to_bits(), "pe {pe}");
        }
        assert_eq!(inboxes.total(1), 4);
        assert_eq!(inboxes.runs(2).len(), 0);
        mach.recycle(inboxes);
    }

    #[test]
    fn posts_coalesce_and_route_in_sorted_order() {
        // raw: one route round, coalesced per (from, to), sorted
        let mut raw = m(4);
        raw.route_round(&[(0, 2, 5), (1, 2, 2), (3, 0, 1)]);

        let mut mach = m(4);
        let mut ex = mach.exchange();
        ex.post(3, 0, elems(3, 1)); // out-of-order post
        ex.post(0, 2, elems(0, 3));
        ex.post(1, 2, elems(1, 2));
        ex.post(0, 2, elems(0, 2)); // coalesces with the earlier 0→2
        ex.post(2, 2, elems(2, 9)); // local: delivered, never charged
        ex.post(1, 3, Vec::new()); // empty: skipped entirely
        let inboxes = ex.deliver(&mut mach);

        for pe in 0..4 {
            assert_eq!(mach.clock(pe).to_bits(), raw.clock(pe).to_bits(), "pe {pe}");
        }
        assert_eq!(mach.stats.messages, raw.stats.messages);
        assert_eq!(mach.stats.words, raw.stats.words);
        assert_eq!(mach.stats.max_degree, raw.stats.max_degree);
        // delivery: runs stay separate (two remote posts coalesce on the
        // wire but arrive as distinct runs) in post order per receiver
        assert_eq!(inboxes.runs(2).len(), 4);
        assert_eq!(inboxes.total(2), 5 + 2 + 9);
        assert_eq!(inboxes.total(0), 1);
        // local move delivered but not charged
        assert_eq!(mach.exchange_charged(), 8);
        assert_eq!(mach.exchange_moved(), 8);
        mach.recycle(inboxes);
    }

    #[test]
    fn pooling_reuses_buffers_across_rounds() {
        let mut mach = m(2);
        for round in 0..3 {
            let mut buf = mach.take_buf();
            assert!(buf.is_empty(), "round {round}: pooled buffers arrive clean");
            buf.extend(elems(0, 8));
            let cap_before = buf.capacity();
            let mut ex = mach.exchange();
            ex.xchg(0, 1, buf, Vec::new());
            let inboxes = ex.deliver(&mut mach);
            assert_eq!(inboxes.total(1), 8);
            mach.recycle(inboxes);
            if round > 0 {
                assert!(cap_before >= 8, "recycled buffer kept its capacity");
            }
        }
    }

    #[test]
    fn machine_reset_clears_exchange_counters() {
        let mut mach = m(2);
        let mut ex = mach.exchange();
        ex.xchg(0, 1, elems(0, 3), Vec::new());
        let inboxes = ex.deliver(&mut mach);
        mach.recycle(inboxes);
        assert_eq!(mach.exchange_charged(), 3);
        mach.reset(2, CostModel { alpha: 100.0, beta: 1.0, cmp: 1.0, duplex: true });
        assert_eq!(mach.exchange_charged(), 0);
        assert_eq!(mach.exchange_moved(), 0);
    }

    /// Above the size gate, deliver materializes the inboxes on the
    /// worker pool; the table (runs, per-receiver order, tags) and the
    /// charges must match the sequential drain bit for bit. The gate is
    /// pinned low via `set_par_min_work` so the pooled path really runs
    /// (and the round stays small) regardless of the environment.
    #[test]
    fn parallel_materialization_matches_sequential() {
        let post_all = |mach: &mut Machine| -> Inboxes {
            let p = mach.p();
            let runs = mach.par_deliver_min_runs();
            let mut ex = mach.exchange();
            for i in 0..runs {
                let from = i % p;
                // every 5th post is local (from == to), the rest remote
                let to = if i % 5 == 0 { from } else { (i * 7 + 3) % p };
                let mut run = mach.take_buf();
                run.push(Elem::new(i as u64, from, i));
                ex.post_tagged(from, to, i as u64, run);
            }
            ex.deliver(mach)
        };
        let mut seq = m(8);
        seq.set_pe_jobs(1);
        seq.set_par_min_work(256);
        let seq_in = post_all(&mut seq);
        let mut par = m(8);
        par.set_pe_jobs(4);
        par.set_par_min_work(256);
        assert_eq!(par.par_deliver_min_runs(), 256 * PAR_DELIVER_RUNS_FACTOR);
        let par_in = post_all(&mut par);
        for pe in 0..8 {
            assert_eq!(seq.clock(pe).to_bits(), par.clock(pe).to_bits(), "pe {pe}");
            let (a, b) = (seq_in.runs(pe), par_in.runs(pe));
            assert_eq!(a.len(), b.len(), "pe {pe} run count");
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.0, y.0, "pe {pe} tag");
                assert_eq!(x.1, y.1, "pe {pe} payload");
            }
        }
        assert_eq!(seq.exchange_charged(), par.exchange_charged());
        assert_eq!(seq.exchange_moved(), par.exchange_moved());
        seq.recycle(seq_in);
        par.recycle(par_in);
    }

    #[test]
    #[should_panic(expected = "different machine")]
    fn delivering_on_a_different_machine_panics() {
        let mut m1 = m(2);
        let mut m2 = m(2);
        let ex = m1.exchange();
        let _ = ex.deliver(&mut m2);
    }

    #[test]
    #[should_panic(expected = "at most one pairwise op")]
    #[cfg(debug_assertions)]
    fn reusing_a_pe_across_pairwise_ops_panics() {
        let mut mach = m(4);
        let mut ex = mach.exchange();
        ex.xchg_touch(0, 1);
        ex.xchg_touch(1, 2);
        let _ = ex.deliver(&mut mach);
    }

    /// The circle construction really is a 1-factorization: every round a
    /// (near-)perfect matching, every unordered pair met exactly once,
    /// `one_factor_round_of` consistent with `one_factor_partner`.
    #[test]
    fn one_factor_schedule_is_a_1_factorization() {
        assert_eq!(one_factor_rounds(0), 0);
        assert_eq!(one_factor_rounds(1), 0);
        for q in [2usize, 3, 4, 5, 6, 7, 8, 9, 16, 17] {
            let rounds = one_factor_rounds(q);
            assert_eq!(rounds, if q % 2 == 0 { q - 1 } else { q }, "q={q}");
            let mut met = vec![vec![false; q]; q];
            for r in 0..rounds {
                let mut busy = 0usize;
                for i in 0..q {
                    match one_factor_partner(q, r, i) {
                        Some(j) => {
                            assert_ne!(i, j, "q={q} r={r}");
                            assert_eq!(one_factor_partner(q, r, j), Some(i), "q={q} r={r} i={i}");
                            assert_eq!(one_factor_round_of(q, i, j), r, "q={q} i={i} j={j}");
                            if i < j {
                                assert!(!met[i][j], "pair ({i},{j}) met twice, q={q}");
                                met[i][j] = true;
                            }
                            busy += 1;
                        }
                        None => assert_eq!(q % 2, 1, "even q has no idle rank"),
                    }
                }
                assert_eq!(q - busy, q % 2, "q={q} r={r}: idle count");
            }
            for i in 0..q {
                for j in i + 1..q {
                    assert!(met[i][j], "pair ({i},{j}) never met, q={q}");
                }
            }
        }
    }

    /// The 1-factor delivery charges and moves the same word totals as
    /// the monolithic path and fills identical mailboxes; the startup
    /// profile differs (q−1 lock-step pairwise rounds, α paid per pair
    /// per round).
    #[test]
    fn one_factor_delivery_matches_monolithic_mailboxes() {
        let p = 6;
        let post_all = |ex: &mut Exchange| {
            ex.post(3, 0, elems(3, 1));
            ex.post(0, 2, elems(0, 3));
            ex.post(1, 2, elems(1, 2));
            ex.post(0, 2, elems(0, 2)); // coalesces with the earlier 0→2
            ex.post(2, 2, elems(2, 9)); // local: delivered, never charged
            ex.post(1, 3, Vec::new()); // empty: skipped entirely
            ex.post_tagged(4, 5, 7, elems(4, 4));
        };
        let mut mono = m(p);
        let mut ex = mono.exchange();
        post_all(&mut ex);
        let mono_in = ex.deliver(&mut mono);

        let mut fac = m(p);
        let mut ex = fac.exchange();
        post_all(&mut ex);
        let pes: Vec<usize> = (0..p).collect();
        let fac_in = ex.deliver_1factor(&mut fac, &pes);

        assert_eq!(mono.exchange_charged(), fac.exchange_charged());
        assert_eq!(mono.exchange_moved(), fac.exchange_moved());
        assert_eq!(fac.exchange_charged(), fac.exchange_moved());
        // lock-step schedule: every pair pays its xchg every round
        let rounds = one_factor_rounds(p) as u64;
        assert_eq!(fac.stats.messages, rounds * (p as u64 / 2) * 2);
        assert_eq!(fac.stats.words, mono.stats.words);
        for pe in 0..p {
            let (a, b) = (mono_in.runs(pe), fac_in.runs(pe));
            assert_eq!(a.len(), b.len(), "pe {pe} run count");
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.0, y.0, "pe {pe} tag");
                assert_eq!(x.1, y.1, "pe {pe} payload");
            }
        }
        mono.recycle(mono_in);
        fac.recycle(fac_in);
    }

    /// Odd participant counts get q rounds with one idle rank per round;
    /// participants may be a strict subset of the machine.
    #[test]
    fn one_factor_delivery_on_an_odd_subset() {
        let mut mach = m(8);
        let mut ex = mach.exchange();
        ex.post(0, 4, elems(0, 5));
        ex.post(4, 2, elems(4, 3));
        ex.post(2, 0, elems(2, 1));
        let pes = [0usize, 2, 4];
        let inboxes = ex.deliver_1factor(&mut mach, &pes);
        assert_eq!(mach.exchange_charged(), 9);
        assert_eq!(mach.exchange_moved(), 9);
        // 3 rounds, one pair each (the third rank idles)
        assert_eq!(mach.stats.messages, 3 * 2);
        assert_eq!(inboxes.total(4), 5);
        assert_eq!(inboxes.total(2), 3);
        assert_eq!(inboxes.total(0), 1);
        mach.recycle(inboxes);
    }

    #[test]
    #[should_panic(expected = "routed posts only")]
    fn one_factor_delivery_rejects_pairwise_ops() {
        let mut mach = m(4);
        let mut ex = mach.exchange();
        ex.xchg(0, 1, elems(0, 2), Vec::new());
        let _ = ex.deliver_1factor(&mut mach, &[0, 1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "cover every posted endpoint")]
    fn one_factor_delivery_rejects_uncovered_endpoints() {
        let mut mach = m(4);
        let mut ex = mach.exchange();
        ex.post(0, 3, elems(0, 2));
        let _ = ex.deliver_1factor(&mut mach, &[0, 1, 2]);
    }
}
