//! Per-PE virtual clocks + the α-β accounting rules.

use crate::metrics::Stats;
use crate::model::CostModel;

/// Reported when a nonrobust algorithm blows past a PE's memory budget —
/// the simulator analogue of "HykSort crashes on DeterDupl/BucketSorted".
#[derive(Clone, Debug, PartialEq)]
pub struct Crash {
    pub pe: usize,
    pub resident_elems: usize,
    pub cap: usize,
    pub context: String,
}

impl std::fmt::Display for Crash {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PE {} out of memory: {} resident elements (cap {}) during {}",
            self.pe, self.resident_elems, self.cap, self.context
        )
    }
}

/// The simulated machine: `p` PEs, one virtual clock each.
#[derive(Clone, Debug)]
pub struct Machine {
    p: usize,
    clock: Vec<f64>,
    pub cost: CostModel,
    pub stats: Stats,
    /// Per-PE memory budget in elements; `None` disables crash detection.
    pub mem_cap_elems: Option<usize>,
    crash: Option<Crash>,
}

impl Machine {
    /// A machine of `p` PEs (any `p ≥ 1`; hypercube algorithms require a
    /// power of two and assert it themselves, like the paper's codes).
    pub fn new(p: usize, cost: CostModel) -> Self {
        assert!(p >= 1);
        Self {
            p,
            clock: vec![0.0; p],
            cost,
            stats: Stats::default(),
            mem_cap_elems: None,
            crash: None,
        }
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    /// log2(p) for power-of-two machines.
    #[inline]
    pub fn dims(&self) -> u32 {
        debug_assert!(self.p.is_power_of_two());
        self.p.trailing_zeros()
    }

    /// Makespan: the running time the paper reports.
    pub fn time(&self) -> f64 {
        self.clock.iter().copied().fold(0.0, f64::max)
    }

    /// Clock of a single PE (tests / diagnostics).
    #[inline]
    pub fn clock(&self, pe: usize) -> f64 {
        self.clock[pe]
    }

    /// First crash observed, if any.
    pub fn crash(&self) -> Option<&Crash> {
        self.crash.as_ref()
    }

    pub fn crashed(&self) -> bool {
        self.crash.is_some()
    }

    // ---- local work ---------------------------------------------------

    /// Charge raw local work (instruction units) to one PE.
    #[inline]
    pub fn work(&mut self, pe: usize, ops: f64) {
        self.clock[pe] += ops;
        self.stats.local_work += ops;
    }

    /// Charge a comparison-sort of `m` local elements.
    #[inline]
    pub fn work_sort(&mut self, pe: usize, m: usize) {
        self.work(pe, self.cost.sort_work(m));
    }

    /// Charge a linear pass (merge / split / copy) over `m` elements.
    #[inline]
    pub fn work_linear(&mut self, pe: usize, m: usize) {
        self.work(pe, self.cost.linear_work(m));
    }

    /// Charge a branchless classifier pass over `m` elements, `k` buckets.
    #[inline]
    pub fn work_classify(&mut self, pe: usize, m: usize, k: usize) {
        self.work(pe, self.cost.classify_work(m, k));
    }

    // ---- memory tracking ----------------------------------------------

    /// Record that `pe` currently holds `elems` elements; crash if over cap.
    pub fn note_mem(&mut self, pe: usize, elems: usize, context: &str) {
        self.stats.max_mem_elems = self.stats.max_mem_elems.max(elems);
        if let Some(cap) = self.mem_cap_elems {
            if elems > cap && self.crash.is_none() {
                self.crash = Some(Crash {
                    pe,
                    resident_elems: elems,
                    cap,
                    context: context.to_string(),
                });
            }
        }
    }

    /// Explicitly record an unconditional failure (e.g. an algorithm
    /// refusing an input shape, like Bitonic on sparse inputs).
    pub fn fail(&mut self, pe: usize, context: &str) {
        if self.crash.is_none() {
            self.crash = Some(Crash {
                pe,
                resident_elems: 0,
                cap: 0,
                context: context.to_string(),
            });
        }
    }

    // ---- communication ------------------------------------------------

    /// Pairwise sendrecv: PE `i` sends `l_ij` words to `j`, receives `l_ji`.
    /// Both finish at `max(c_i, c_j) + α + β·len` (telephone model).
    pub fn xchg(&mut self, i: usize, j: usize, l_ij: usize, l_ji: usize) {
        debug_assert!(i != j);
        let start = self.clock[i].max(self.clock[j]);
        let t = start + self.cost.xchg(l_ij, l_ji);
        self.clock[i] = t;
        self.clock[j] = t;
        self.stats.messages += 2;
        self.stats.words += (l_ij + l_ji) as u64;
    }

    /// One-way message: sender busy `α + β·l`; receiver resumes no earlier
    /// than the arrival and pays the receive overhead.
    pub fn send(&mut self, from: usize, to: usize, l: usize) {
        debug_assert!(from != to);
        let c = self.cost.msg(l);
        self.clock[from] += c;
        let arrival = self.clock[from];
        self.clock[to] = self.clock[to].max(arrival);
        self.stats.messages += 1;
        self.stats.words += l as u64;
    }

    /// An irregular superstep: every `(from, to, words)` message is sent in
    /// this round. Single-ported accounting: a PE's send time is the sum of
    /// its outgoing message costs, its receive time the sum of incoming
    /// costs; a PE finishes at
    /// `max(own_start + out, latest sender finish) + in`.
    ///
    /// This is the standard superstep approximation for h-relation routing:
    /// exact for 1-relations, within a factor ≤ 2 of an optimal schedule
    /// otherwise — fidelity enough for every crossover in the paper, while
    /// keeping the simulator deterministic.
    pub fn route_round(&mut self, msgs: &[(usize, usize, usize)]) {
        if msgs.is_empty() {
            return;
        }
        let mut out = vec![0.0f64; self.p];
        let mut indeg = vec![0usize; self.p];
        let mut outdeg = vec![0usize; self.p];
        for &(from, _, l) in msgs {
            out[from] += self.cost.msg(l);
            outdeg[from] += 1;
        }
        // a receiver cannot start draining before its senders have started
        // this round (receive time itself overlaps the transmissions —
        // the standard superstep approximation)
        let mut recv_ready = vec![0.0f64; self.p];
        for &(from, to, _) in msgs {
            if self.clock[from] > recv_ready[to] {
                recv_ready[to] = self.clock[from];
            }
            indeg[to] += 1;
        }
        let mut inc = vec![0.0f64; self.p];
        for &(_, to, l) in msgs {
            inc[to] += self.cost.msg(l);
        }
        for pe in 0..self.p {
            let mut t = self.clock[pe] + out[pe];
            if indeg[pe] > 0 {
                t = t.max(recv_ready[pe]) + inc[pe];
            }
            self.clock[pe] = t;
            let deg = indeg[pe].max(outdeg[pe]);
            if deg > self.stats.max_degree {
                self.stats.max_degree = deg;
            }
        }
        self.stats.messages += msgs.len() as u64;
        self.stats.words += msgs.iter().map(|&(_, _, l)| l as u64).sum::<u64>();
    }

    /// Barrier over a PE group: clocks advance to the group max (plus a
    /// log-depth tree of zero-length messages).
    pub fn barrier(&mut self, pes: &[usize]) {
        if pes.len() <= 1 {
            return;
        }
        let max = pes.iter().map(|&i| self.clock[i]).fold(0.0, f64::max);
        let depth = (pes.len() as f64).log2().ceil();
        let t = max + 2.0 * depth * self.cost.alpha;
        for &i in pes {
            self.clock[i] = t;
        }
        self.stats.messages += 2 * (pes.len() as u64 - 1);
    }

    /// Advance every clock in `pes` to their common max (free sync used to
    /// model the implicit synchrony of lock-step collectives that already
    /// paid their message costs).
    pub fn sync_free(&mut self, pes: &[usize]) {
        let max = pes.iter().map(|&i| self.clock[i]).fold(0.0, f64::max);
        for &i in pes {
            self.clock[i] = max;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(p: usize) -> Machine {
        Machine::new(
            p,
            CostModel { alpha: 100.0, beta: 1.0, cmp: 1.0, duplex: true },
        )
    }

    #[test]
    fn xchg_advances_both_to_common_time() {
        let mut mach = m(4);
        mach.work(0, 50.0);
        mach.xchg(0, 1, 10, 4);
        assert_eq!(mach.clock(0), 50.0 + 100.0 + 10.0);
        assert_eq!(mach.clock(1), mach.clock(0));
        assert_eq!(mach.stats.messages, 2);
        assert_eq!(mach.stats.words, 14);
    }

    #[test]
    fn send_receiver_waits_for_arrival() {
        let mut mach = m(2);
        mach.send(0, 1, 10);
        assert_eq!(mach.clock(0), 110.0);
        assert_eq!(mach.clock(1), 110.0);
        // a receiver already past the arrival time is not delayed
        let mut mach = m(2);
        mach.work(1, 500.0);
        mach.send(0, 1, 10);
        assert_eq!(mach.clock(1), 500.0);
    }

    #[test]
    fn route_round_serializes_fan_in() {
        // p-1 PEs all send 1 word to PE 0: PE 0 pays sum of receive costs —
        // the Ω(p) bottleneck RAMS' DMA removes (Fig. 2c).
        let mut mach = m(8);
        let msgs: Vec<_> = (1..8).map(|i| (i, 0usize, 1usize)).collect();
        mach.route_round(&msgs);
        assert!(mach.clock(0) >= 7.0 * 101.0, "clock {}", mach.clock(0));
        assert_eq!(mach.stats.max_degree, 7);
        // senders pay only their own message
        assert_eq!(mach.clock(1), 101.0);
    }

    #[test]
    fn route_round_parallel_pairs_are_cheap() {
        let mut mach = m(8);
        let msgs: Vec<_> = (0..4).map(|i| (2 * i, 2 * i + 1, 5usize)).collect();
        mach.route_round(&msgs);
        assert_eq!(mach.time(), 105.0);
    }

    #[test]
    fn mem_cap_triggers_crash() {
        let mut mach = m(2);
        mach.mem_cap_elems = Some(100);
        mach.note_mem(1, 50, "fill");
        assert!(!mach.crashed());
        mach.note_mem(1, 101, "overflow");
        assert!(mach.crashed());
        let c = mach.crash().unwrap();
        assert_eq!(c.pe, 1);
        assert_eq!(c.resident_elems, 101);
    }

    #[test]
    fn barrier_aligns_clocks() {
        let mut mach = m(4);
        mach.work(2, 1000.0);
        mach.barrier(&[0, 1, 2, 3]);
        let t = mach.clock(0);
        assert!(t >= 1000.0);
        assert!((0..4).all(|i| mach.clock(i) == t));
    }

    #[test]
    fn work_sort_charges_nlogn() {
        let mut mach = m(1);
        mach.work_sort(0, 1024);
        assert_eq!(mach.clock(0), 1024.0 * 10.0);
    }
}
