//! Per-PE virtual clocks + the α-β accounting rules, plus the
//! pool-scheduled **PE task** layer ([`Machine::par_pes`] /
//! [`Machine::par_superstep`]) that lets the p independent local phases of
//! a superstep run on worker threads while staying bit-identical to
//! sequential execution.

use crate::elements::{Elem, MergeScratch};
use crate::exec;
use crate::partition::PartitionScratch;
use crate::metrics::Stats;
use crate::model::CostModel;
use crate::sim::exchange::PlanePool;

/// Reported when a nonrobust algorithm blows past a PE's memory budget —
/// the simulator analogue of "HykSort crashes on DeterDupl/BucketSorted".
#[derive(Clone, Debug, PartialEq)]
pub struct Crash {
    pub pe: usize,
    pub resident_elems: usize,
    pub cap: usize,
    pub context: String,
}

impl std::fmt::Display for Crash {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PE {} out of memory: {} resident elements (cap {}) during {}",
            self.pe, self.resident_elems, self.cap, self.context
        )
    }
}

/// Reusable settlement scratch (dense per-PE accumulators + the list of
/// PEs actually touched this round). Held by the [`Machine`] so irregular
/// rounds cost O(messages) instead of O(p) allocations per call — the
/// per-message overhead that used to dominate host wallclock at p ≥ 2^12.
///
/// Invariant outside of [`Machine::route_round`]/[`Machine::settle`]: every
/// slot is zero/false and `touched` is empty (each settlement cleans only
/// the slots it dirtied).
#[derive(Clone, Debug, Default)]
struct RouteScratch {
    out: Vec<f64>,
    inc: Vec<f64>,
    recv_ready: Vec<f64>,
    indeg: Vec<usize>,
    outdeg: Vec<usize>,
    seen: Vec<bool>,
    touched: Vec<usize>,
}

impl RouteScratch {
    /// Grow every tally to cover PE indices `< n`. Callers pass the
    /// highest PE actually named by the round plus one — not `p` — so a
    /// giant, mostly-idle machine only ever allocates tallies for the
    /// prefix of PEs that communicate.
    fn ensure_capacity(&mut self, n: usize) {
        if self.out.len() < n {
            self.out.resize(n, 0.0);
            self.inc.resize(n, 0.0);
            self.recv_ready.resize(n, 0.0);
            self.indeg.resize(n, 0);
            self.outdeg.resize(n, 0);
            self.seen.resize(n, false);
        }
    }
}

/// Growable per-PE virtual clocks with an **epoch/floor** representation,
/// so machine-wide operations cost O(1) instead of O(p):
///
/// * `floor` is a lower bound on every PE's clock. A whole-machine
///   barrier raises it once instead of writing p slots.
/// * `slot[pe]` is live only while `slot_epoch[pe] == epoch`;
///   [`Clocks::reset`] bumps `epoch`, invalidating every slot at once.
/// * slots grow on first write, so `Machine::new(1 << 20, …)` allocates
///   nothing until PEs are actually charged.
/// * `max` is the running makespan. Clocks are **monotone** (every write
///   is ≥ the value read — all charges are nonnegative, barriers and
///   syncs only advance), so the incremental max is bit-identical to a
///   fold over all p dense clocks.
///
/// The effective clock of a PE is `max(live slot value, floor)`: exact,
/// because every write path reads the effective value first and the
/// floor only ever increases — a stored value below the floor is simply
/// a stale pre-barrier snapshot.
#[derive(Clone, Debug, Default)]
struct Clocks {
    floor: f64,
    slot: Vec<f64>,
    slot_epoch: Vec<u64>,
    epoch: u64,
    max: f64,
}

impl Clocks {
    #[inline]
    fn get(&self, pe: usize) -> f64 {
        match self.slot.get(pe) {
            Some(&v) if self.slot_epoch[pe] == self.epoch => v.max(self.floor),
            _ => self.floor,
        }
    }

    #[inline]
    fn set(&mut self, pe: usize, v: f64) {
        debug_assert!(v >= self.floor, "clocks are monotone (floor {})", self.floor);
        if self.slot.len() <= pe {
            let n = (pe + 1).max(self.slot.len() * 2);
            self.slot.resize(n, 0.0);
            self.slot_epoch.resize(n, 0);
        }
        self.slot[pe] = v;
        self.slot_epoch[pe] = self.epoch;
        if v > self.max {
            self.max = v;
        }
    }

    /// Raise the whole-machine lower bound to `t ≥ max` — the O(1)
    /// settlement of a barrier over **all** PEs (every effective clock
    /// becomes exactly `t`, stale slots included, via the `max(…, floor)`
    /// read path).
    #[inline]
    fn raise_floor(&mut self, t: f64) {
        debug_assert!(t >= self.max);
        self.floor = t;
        self.max = t;
    }

    /// O(1) return to the all-zero state of a fresh machine: bump the
    /// epoch (invalidating every stored slot) and drop floor and max.
    fn reset(&mut self) {
        self.epoch += 1;
        self.floor = 0.0;
        self.max = 0.0;
    }
}

/// One buffered point-to-point operation of an open superstep.
#[derive(Clone, Copy, Debug)]
enum PendingOp {
    Xchg { i: usize, j: usize, l_ij: usize, l_ji: usize },
    Send { from: usize, to: usize, l: usize },
}

/// Transcript of an open superstep: pairwise operations in call order plus
/// all routed messages, settled together by [`Machine::settle`].
#[derive(Clone, Debug, Default)]
struct Transcript {
    ops: Vec<PendingOp>,
    route: Vec<(usize, usize, usize)>,
}

/// Process-unique id source for [`Machine::instance_id`].
static MACHINE_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

/// Default minimum total-work hint (elements touched across all tasks of
/// one [`Machine::par_pes`] round) before pool workers are engaged;
/// smaller rounds run inline, where even a wake/park handshake costs more
/// than it buys. The compiled-in default; the effective threshold is
/// [`par_min_work`], runtime-tunable via [`set_par_min_work`] /
/// `RMPS_PAR_MIN_WORK` / `--par-min-work` /
/// [`crate::algorithms::Runner::par_min_work`]. The hotpath bench sweeps
/// round sizes across the inline/pooled crossover
/// (`pool_crossover` / `measured_crossover_work` in BENCH_hotpath.json)
/// so this default can track the measured break-even on the CI runner.
/// The gate depends only on the hint — never on timing — so it cannot
/// affect results, only host scheduling.
///
/// Re-pinned from 4096 to 8192: the `measured_crossover_work` series CI
/// accumulated in BENCH_hotpath.json since the persistent pool landed
/// puts the pooled/inline break-even one doubling above the original
/// guess on the CI runners (the sweep brackets it between 4096 and
/// 16384, settling at 8192). The CI drift step now reads the compiled
/// default out of the bench JSON, so a future drift is flagged against
/// whatever value ships here.
pub const PAR_MIN_WORK: usize = 8192;

/// Process-wide [`set_par_min_work`] override; 0 = unset.
static PAR_MIN_WORK_OVERRIDE: std::sync::atomic::AtomicUsize =
    std::sync::atomic::AtomicUsize::new(0);

/// Set the process-wide default for the inline-vs-pooled work threshold
/// (the CLI `--par-min-work` flag). Takes precedence over the
/// `RMPS_PAR_MIN_WORK` environment variable; `0` clears the override and
/// restores the env/compiled default. Affects machines constructed (or
/// configured via [`Machine::set_par_min_work`] with `0`) afterwards.
/// Host scheduling only — simulation results are bit-identical for every
/// value.
pub fn set_par_min_work(threshold: usize) {
    PAR_MIN_WORK_OVERRIDE.store(threshold, std::sync::atomic::Ordering::Relaxed);
}

/// The effective default inline-vs-pooled work threshold a new
/// [`Machine`] starts with: the [`set_par_min_work`] override if one was
/// given, else `RMPS_PAR_MIN_WORK` (parsed once, first use), else
/// [`PAR_MIN_WORK`]. Always ≥ 1 (a zero threshold would merely mean
/// "always pooled", which `1` already expresses).
pub fn par_min_work() -> usize {
    let over = PAR_MIN_WORK_OVERRIDE.load(std::sync::atomic::Ordering::Relaxed);
    if over > 0 {
        return over;
    }
    static ENV: std::sync::OnceLock<Option<usize>> = std::sync::OnceLock::new();
    ENV.get_or_init(|| {
        std::env::var("RMPS_PAR_MIN_WORK")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
    })
    .unwrap_or(PAR_MIN_WORK)
}

/// Size/buffer hints for one [`Machine::par_pes`] round.
///
/// `work` is the round's total element count (summed over all tasks); it
/// gates the inline-vs-pooled decision against the machine's
/// [`Machine::par_min_work`] threshold. `bufs`
/// pre-seeds every task's [`PeCtx::take_buf`] stash with that many pooled
/// buffers, keeping the warm path allocation-free without letting tasks
/// touch the machine-owned pool concurrently.
#[derive(Clone, Copy, Debug)]
pub struct ParSpec {
    work: usize,
    bufs_each: usize,
}

impl ParSpec {
    /// A spec with the given total-work hint and no pre-seeded buffers.
    pub fn work(total_elems: usize) -> Self {
        Self { work: total_elems, bufs_each: 0 }
    }

    /// Pre-seed each task's buffer stash with `k` pooled buffers.
    pub fn bufs(mut self, k: usize) -> Self {
        self.bufs_each = k;
        self
    }
}

/// One buffered charge of a task-local ledger (see [`PeCtx`]).
#[derive(Clone, Debug)]
enum PeCharge {
    Work(f64),
    Mem { at: usize, elems: usize, context: &'static str },
    Fail { context: &'static str },
    Xchg { with: usize, l_out: usize, l_in: usize },
    Send { to: usize, words: usize },
    Route { to: usize, words: usize },
}

/// Task-local charge ledger handed to every per-PE closure of a
/// [`Machine::par_pes`] / [`Machine::par_superstep`] round.
///
/// A PE task cannot touch the machine (its clocks, stats, and pools are
/// shared across all tasks of the round); instead it records its
/// work/memory/communication charges here, and the machine **settles** all
/// ledgers *in PE order* after the round — replaying each charge through
/// the exact same `Machine` entry points a sequential `for pe in 0..p`
/// loop would have called, in the exact same order. Settlement is
/// therefore bit-identical to sequential execution (float addition order
/// included), for every `pe_jobs` value and every thread interleaving:
/// the ledger contents depend only on the task's own inputs, never on
/// scheduling.
///
/// The ctx also carries a private buffer stash ([`PeCtx::take_buf`] /
/// [`PeCtx::recycle_buf`]) pre-seeded from the machine's data-plane pool
/// (see [`ParSpec::bufs`]) plus reusable [`MergeScratch`] and
/// [`PartitionScratch`] kernel scratches; leftovers
/// return to the machine pool at settlement. Ctx objects and the round's
/// task container are pooled on the machine too, so the *element-buffer*
/// path of a warm round allocates nothing — the remaining per-round
/// allocations are the small result/collection vectors the closures
/// return, same order as the task count, not the data.
#[derive(Clone, Debug, Default)]
pub struct PeCtx {
    pe: usize,
    rank: usize,
    cost: CostModel,
    charges: Vec<PeCharge>,
    bufs: Vec<Vec<Elem>>,
    merge: MergeScratch,
    part: PartitionScratch,
}

impl PeCtx {
    /// Global PE number this task charges to.
    #[inline]
    pub fn pe(&self) -> usize {
        self.pe
    }

    /// Task index within the round (the group *rank* for
    /// [`Machine::par_pes_on`] call sites).
    #[inline]
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// The machine's cost model (copied per round).
    #[inline]
    pub fn cost(&self) -> &CostModel {
        &self.cost
    }

    /// Charge raw local work (instruction units) to this PE.
    #[inline]
    pub fn work(&mut self, ops: f64) {
        self.charges.push(PeCharge::Work(ops));
    }

    /// Charge a comparison-sort of `m` local elements.
    #[inline]
    pub fn work_sort(&mut self, m: usize) {
        let ops = self.cost.sort_work(m);
        self.work(ops);
    }

    /// Charge a linear pass over `m` elements.
    #[inline]
    pub fn work_linear(&mut self, m: usize) {
        let ops = self.cost.linear_work(m);
        self.work(ops);
    }

    /// Charge a branchless classifier pass over `m` elements, `k` buckets.
    #[inline]
    pub fn work_classify(&mut self, m: usize, k: usize) {
        let ops = self.cost.classify_work(m, k);
        self.work(ops);
    }

    /// Record that this PE currently holds `elems` elements
    /// (→ [`Machine::note_mem`] at settlement).
    #[inline]
    pub fn note_mem(&mut self, elems: usize, context: &'static str) {
        self.charges.push(PeCharge::Mem { at: self.pe, elems, context });
    }

    /// [`PeCtx::note_mem`] against another PE — for phases where a task
    /// computes a *remote* PE's residency (RAMS' DMA entry accounting).
    #[inline]
    pub fn note_mem_at(&mut self, pe: usize, elems: usize, context: &'static str) {
        self.charges.push(PeCharge::Mem { at: pe, elems, context });
    }

    /// Record an unconditional failure (→ [`Machine::fail`]).
    #[inline]
    pub fn fail(&mut self, context: &'static str) {
        self.charges.push(PeCharge::Fail { context });
    }

    /// Buffer a pairwise exchange charge `self.pe() ↔ with`
    /// (→ [`Machine::xchg`] at settlement, in PE order).
    #[inline]
    pub fn xchg(&mut self, with: usize, l_out: usize, l_in: usize) {
        self.charges.push(PeCharge::Xchg { with, l_out, l_in });
    }

    /// Buffer a one-way message charge (→ [`Machine::send`]).
    #[inline]
    pub fn send(&mut self, to: usize, words: usize) {
        self.charges.push(PeCharge::Send { to, words });
    }

    /// Buffer one routed message (→ [`Machine::route_round`]; inside a
    /// [`Machine::par_superstep`] all routed charges of the round settle
    /// as **one** combined h-relation).
    #[inline]
    pub fn route(&mut self, to: usize, words: usize) {
        self.charges.push(PeCharge::Route { to, words });
    }

    /// A cleared element buffer from the task's pre-seeded stash (or a
    /// fresh one once the stash is exhausted). The stash — including
    /// everything recycled back — returns to the machine pool at
    /// settlement.
    #[inline]
    pub fn take_buf(&mut self) -> Vec<Elem> {
        self.bufs.pop().unwrap_or_default()
    }

    /// Return a buffer to the task stash (cleared).
    #[inline]
    pub fn recycle_buf(&mut self, mut buf: Vec<Elem>) {
        buf.clear();
        self.bufs.push(buf);
    }

    /// The task's reusable multiway-merge scratch (for
    /// [`crate::elements::multiway_merge_into`]).
    #[inline]
    pub fn merge_scratch(&mut self) -> &mut MergeScratch {
        &mut self.merge
    }

    /// The task's reusable splitter-partition scratch (labels, bucket
    /// boundaries, and the contiguous scatter buffer of
    /// [`crate::partition::partition_scatter`]) — like the merge scratch,
    /// it rides the pooled ctx object, so warm partition phases allocate
    /// nothing.
    #[inline]
    pub fn partition_scratch(&mut self) -> &mut PartitionScratch {
        &mut self.part
    }
}

/// PE addressing of one parallel round.
#[derive(Clone, Copy)]
enum PeMap<'a> {
    /// Task `i` charges PE `base + i` (contiguous subcubes).
    From(usize),
    /// Task `i` charges PE `pes[i]` (strided groups — RFIS rows/columns).
    Of(&'a [usize]),
}

/// The simulated machine: `p` PEs, one virtual clock each.
///
/// # Touched-slot cleanliness contract
///
/// A machine of `p` PEs never does Θ(p) host work for a round that only
/// touches a few PEs. Every dense per-PE structure it owns — the clock
/// slots ([`Clocks`]), the route tallies ([`RouteScratch`]), the data
/// plane's pair slots, inbox tables, and delivery counters
/// ([`crate::sim::Exchange`]) — obeys one invariant: **outside of a
/// settlement, every slot is in its clean state (zero/empty), and each
/// settlement cleans exactly the slots it dirtied**, driven by a
/// touched-slot index carried alongside the dense storage. Growth is
/// lazy (first write), resets are O(1) (epoch bump) or O(touched), and
/// whole-machine barriers settle O(1) via the clock floor. Consequently
/// per-superstep host cost is O(active PEs + messages), and
/// `Machine::new(1 << 20, …)` is cheap until PEs are actually charged.
/// Any new scratch added to the machine must keep this contract — the
/// giant-p property tests assert allocation scaling against it.
#[derive(Clone, Debug)]
pub struct Machine {
    p: usize,
    /// Process-unique identity (clones share it) — lets the data plane
    /// assert an [`crate::sim::Exchange`] is delivered on the machine
    /// that opened it.
    instance_id: u64,
    clocks: Clocks,
    pub cost: CostModel,
    pub stats: Stats,
    /// Per-PE memory budget in elements; `None` disables crash detection.
    pub mem_cap_elems: Option<usize>,
    crash: Option<Crash>,
    scratch: RouteScratch,
    transcript: Option<Transcript>,
    /// Drained transcript kept for buffer reuse across supersteps.
    spare: Transcript,
    /// Staging + buffer pools of the payload data plane
    /// ([`crate::sim::Exchange`]), reused across rounds and runs.
    pub(crate) plane: PlanePool,
    /// Cumulative element-words charged through the data plane.
    elems_charged: u64,
    /// Cumulative elements delivered remotely through the data plane.
    elems_moved: u64,
    /// Worker threads for PE-task rounds ([`Machine::par_pes`]); host
    /// scheduling only — results are identical for every value.
    pe_jobs: usize,
    /// Inline-vs-pooled work threshold for PE-task rounds (see
    /// [`par_min_work`]); host scheduling only, like `pe_jobs`.
    par_min_work: usize,
    /// Pooled task contexts (drained ledgers, warm scratch), reused across
    /// [`Machine::par_pes`] rounds.
    ctx_pool: Vec<PeCtx>,
    /// Spare round container for `par_core`'s task list (kept empty
    /// between rounds) — warm rounds reuse its capacity instead of
    /// allocating a fresh `Vec` per round.
    ctx_round: Vec<PeCtx>,
    /// Host-side profiling: settled communication rounds this run
    /// (batched supersteps, eager route rounds, barriers, exchange
    /// deliveries). Deliberately **not** part of [`Stats`] — the
    /// equivalence suites compare `Stats` bit for bit as simulated cost,
    /// while this counts host settlement activity (the denominator of
    /// the giant-p bench's µs-per-superstep metric).
    host_rounds: u64,
}

impl Machine {
    /// A machine of `p` PEs (any `p ≥ 1`; hypercube algorithms require a
    /// power of two and assert it themselves, like the paper's codes).
    pub fn new(p: usize, cost: CostModel) -> Self {
        assert!(p >= 1);
        Self {
            p,
            instance_id: MACHINE_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            clocks: Clocks::default(),
            cost,
            stats: Stats::default(),
            mem_cap_elems: None,
            crash: None,
            scratch: RouteScratch::default(),
            transcript: None,
            spare: Transcript::default(),
            plane: PlanePool::default(),
            elems_charged: 0,
            elems_moved: 0,
            pe_jobs: exec::default_pe_jobs(),
            par_min_work: par_min_work(),
            ctx_pool: Vec::new(),
            ctx_round: Vec::new(),
            host_rounds: 0,
        }
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    /// Reset to the pristine state of `Machine::new(p, cost)` — zero
    /// clocks, fresh stats, no crash, no memory cap, no open superstep —
    /// while keeping every scratch allocation (route tallies, transcript
    /// buffers) for reuse. [`crate::algorithms::Runner`] calls this
    /// between batched runs; a reset machine is bit-for-bit equivalent to
    /// a freshly constructed one (the simulation is deterministic and the
    /// scratch invariants guarantee clean slates).
    pub fn reset(&mut self, p: usize, cost: CostModel) {
        assert!(p >= 1);
        self.p = p;
        // O(1): an epoch bump invalidates every stored clock slot
        self.clocks.reset();
        self.cost = cost;
        self.stats = Stats::default();
        self.mem_cap_elems = None;
        self.crash = None;
        // a crashed run may have been abandoned mid-superstep; drop any
        // buffered (never charged) operations
        if let Some(mut t) = self.transcript.take() {
            t.ops.clear();
            t.route.clear();
            self.spare = t;
        }
        // the data plane keeps its pools but forgets any staged round
        self.plane.reset();
        self.elems_charged = 0;
        self.elems_moved = 0;
        self.host_rounds = 0;
        // pe_jobs, par_min_work, and the ctx pool survive: all are
        // host-execution state (scheduling + warm scratch), invisible to
        // simulation results
    }

    /// Set the worker-thread count for PE-task rounds
    /// ([`Machine::par_pes`] / [`Machine::par_superstep`]). Host
    /// scheduling only: results are bit-identical for every value
    /// (default: `RMPS_PE_JOBS` / CLI `--pe-jobs`, else all cores — see
    /// [`crate::exec::default_pe_jobs`]). Survives [`Machine::reset`].
    pub fn set_pe_jobs(&mut self, jobs: usize) {
        self.pe_jobs = jobs.max(1);
    }

    /// Current PE-task worker count (see [`Machine::set_pe_jobs`]).
    #[inline]
    pub fn pe_jobs(&self) -> usize {
        self.pe_jobs
    }

    /// Set this machine's inline-vs-pooled work threshold: a
    /// [`Machine::par_pes`] round engages pool workers only when its
    /// [`ParSpec::work`] hint is at least this many elements. `0` restores
    /// the process default ([`par_min_work`]). Host scheduling only:
    /// results are bit-identical for every value. Survives
    /// [`Machine::reset`].
    pub fn set_par_min_work(&mut self, threshold: usize) {
        self.par_min_work = if threshold == 0 { par_min_work() } else { threshold };
    }

    /// Current inline-vs-pooled work threshold (see
    /// [`Machine::set_par_min_work`]).
    #[inline]
    pub fn par_min_work(&self) -> usize {
        self.par_min_work
    }

    /// Cumulative element-words the data plane has charged to the cost
    /// model ([`crate::sim::Exchange`]); equals [`Machine::exchange_moved`]
    /// whenever every payload moved through the plane — the charged ==
    /// moved invariant, `debug_assert`ed per round and testable per run.
    #[inline]
    pub fn exchange_charged(&self) -> u64 {
        self.elems_charged
    }

    /// Cumulative elements delivered to a *remote* PE through the data
    /// plane (local self-posts excluded). See [`Machine::exchange_charged`].
    #[inline]
    pub fn exchange_moved(&self) -> u64 {
        self.elems_moved
    }

    #[inline]
    pub(crate) fn note_exchange(&mut self, charged: u64, moved: u64) {
        self.elems_charged += charged;
        self.elems_moved += moved;
    }

    /// Process-unique machine identity (survives [`Machine::reset`];
    /// clones share their original's id).
    #[inline]
    pub(crate) fn instance_id(&self) -> u64 {
        self.instance_id
    }

    /// log2(p) for power-of-two machines.
    #[inline]
    pub fn dims(&self) -> u32 {
        debug_assert!(self.p.is_power_of_two());
        self.p.trailing_zeros()
    }

    /// Makespan: the running time the paper reports. O(1): the clocks
    /// keep an incremental maximum, bit-identical to a fold over all p
    /// per-PE clocks (clocks are monotone, non-NaN).
    #[inline]
    pub fn time(&self) -> f64 {
        self.clocks.max
    }

    /// Clock of a single PE (tests / diagnostics).
    #[inline]
    pub fn clock(&self, pe: usize) -> f64 {
        debug_assert!(pe < self.p);
        self.clocks.get(pe)
    }

    /// Settled communication rounds so far (host profiling — see the
    /// `host_rounds` field; cleared by [`Machine::reset`]).
    #[inline]
    pub fn host_rounds(&self) -> u64 {
        self.host_rounds
    }

    #[inline]
    pub(crate) fn bump_host_rounds(&mut self) {
        self.host_rounds += 1;
    }

    /// First crash observed, if any.
    pub fn crash(&self) -> Option<&Crash> {
        self.crash.as_ref()
    }

    pub fn crashed(&self) -> bool {
        self.crash.is_some()
    }

    // ---- local work ---------------------------------------------------

    /// Charge raw local work (instruction units) to one PE.
    #[inline]
    pub fn work(&mut self, pe: usize, ops: f64) {
        let t = self.clocks.get(pe) + ops;
        self.clocks.set(pe, t);
        self.stats.local_work += ops;
    }

    /// Charge a comparison-sort of `m` local elements.
    #[inline]
    pub fn work_sort(&mut self, pe: usize, m: usize) {
        self.work(pe, self.cost.sort_work(m));
    }

    /// Charge a linear pass (merge / split / copy) over `m` elements.
    #[inline]
    pub fn work_linear(&mut self, pe: usize, m: usize) {
        self.work(pe, self.cost.linear_work(m));
    }

    /// Charge a branchless classifier pass over `m` elements, `k` buckets.
    #[inline]
    pub fn work_classify(&mut self, pe: usize, m: usize, k: usize) {
        self.work(pe, self.cost.classify_work(m, k));
    }

    // ---- memory tracking ----------------------------------------------

    /// Record that `pe` currently holds `elems` elements; crash if over cap.
    pub fn note_mem(&mut self, pe: usize, elems: usize, context: &str) {
        self.stats.max_mem_elems = self.stats.max_mem_elems.max(elems);
        if let Some(cap) = self.mem_cap_elems {
            if elems > cap && self.crash.is_none() {
                self.crash = Some(Crash {
                    pe,
                    resident_elems: elems,
                    cap,
                    context: context.to_string(),
                });
            }
        }
    }

    /// Explicitly record an unconditional failure (e.g. an algorithm
    /// refusing an input shape, like Bitonic on sparse inputs).
    pub fn fail(&mut self, pe: usize, context: &str) {
        if self.crash.is_none() {
            self.crash = Some(Crash {
                pe,
                resident_elems: 0,
                cap: 0,
                context: context.to_string(),
            });
        }
    }

    // ---- communication ------------------------------------------------

    /// Pairwise sendrecv: PE `i` sends `l_ij` words to `j`, receives `l_ji`.
    /// Both finish at `max(c_i, c_j) + α + β·len` (telephone model).
    /// Inside an open superstep the call is buffered until [`settle`].
    ///
    /// [`settle`]: Machine::settle
    pub fn xchg(&mut self, i: usize, j: usize, l_ij: usize, l_ji: usize) {
        debug_assert!(i != j);
        if let Some(t) = self.transcript.as_mut() {
            t.ops.push(PendingOp::Xchg { i, j, l_ij, l_ji });
            return;
        }
        self.xchg_now(i, j, l_ij, l_ji);
    }

    fn xchg_now(&mut self, i: usize, j: usize, l_ij: usize, l_ji: usize) {
        let start = self.clocks.get(i).max(self.clocks.get(j));
        let t = start + self.cost.xchg(l_ij, l_ji);
        self.clocks.set(i, t);
        self.clocks.set(j, t);
        self.stats.messages += 2;
        self.stats.words += (l_ij + l_ji) as u64;
    }

    /// One-way message: sender busy `α + β·l`; receiver resumes no earlier
    /// than the arrival and pays the receive overhead.
    /// Inside an open superstep the call is buffered until [`settle`].
    ///
    /// [`settle`]: Machine::settle
    pub fn send(&mut self, from: usize, to: usize, l: usize) {
        debug_assert!(from != to);
        if let Some(t) = self.transcript.as_mut() {
            t.ops.push(PendingOp::Send { from, to, l });
            return;
        }
        self.send_now(from, to, l);
    }

    fn send_now(&mut self, from: usize, to: usize, l: usize) {
        let c = self.cost.msg(l);
        let arrival = self.clocks.get(from) + c;
        self.clocks.set(from, arrival);
        let t = self.clocks.get(to).max(arrival);
        self.clocks.set(to, t);
        self.stats.messages += 1;
        self.stats.words += l as u64;
    }

    /// An irregular superstep: every `(from, to, words)` message is sent in
    /// this round. Single-ported accounting: a PE's send time is the sum of
    /// its outgoing message costs, its receive time the sum of incoming
    /// costs; a PE finishes at
    /// `max(own_start + out, latest sender finish) + in`.
    ///
    /// This is the standard superstep approximation for h-relation routing:
    /// exact for 1-relations, within a factor ≤ 2 of an optimal schedule
    /// otherwise — fidelity enough for every crossover in the paper, while
    /// keeping the simulator deterministic.
    ///
    /// Inside an open superstep the messages are appended to the round
    /// buffer; all `route_round` calls of the superstep settle as **one**
    /// combined h-relation (see [`Machine::begin_superstep`]).
    pub fn route_round(&mut self, msgs: &[(usize, usize, usize)]) {
        if let Some(t) = self.transcript.as_mut() {
            t.route.extend_from_slice(msgs);
            return;
        }
        self.host_rounds += 1;
        self.settle_route(msgs);
    }

    // ---- batched superstep settlement ----------------------------------

    /// Open a superstep: subsequent [`xchg`]/[`send`]/[`route_round`] calls
    /// are buffered (costs *not* yet charged) until [`settle`] replays them
    /// in one batched pass. Clock reads ([`time`], [`clock`]) in between see
    /// the pre-superstep state.
    ///
    /// This is the **cost-only** batching layer, used by scalar collectives
    /// (all-reduce, prefix sums, broadcast pricing) whose payloads are
    /// metadata words, not elements. Rounds that move element payloads go
    /// through the [`crate::sim::Exchange`] data plane
    /// ([`Machine::exchange`]) instead, which buffers the payloads together
    /// with the charges, delivers them to per-PE inboxes, and asserts that
    /// charged and moved element counts agree; an exchange round cannot be
    /// opened while a raw superstep is open (and vice versa each exchange
    /// settles itself before returning).
    ///
    /// # Semantics preserved
    ///
    /// Settlement is **bit-identical** to issuing the same calls eagerly
    /// provided the superstep is a genuine communication round, which is
    /// how every converted call site uses it:
    ///
    /// * pairwise ops ([`xchg`]/[`send`]) touch pairwise-disjoint PE pairs
    ///   (e.g. one hypercube dimension), so their relative order cannot
    ///   matter — settle applies them in call order;
    /// * routed messages form a single h-relation; buffering several
    ///   [`route_round`] calls merges them into one round, which is exactly
    ///   the superstep approximation the per-call path already used for a
    ///   round handed over in one slice;
    /// * a superstep mixing pairwise ops *and* routed messages must keep
    ///   the two classes on disjoint PE sets (settle applies all pairwise
    ///   ops before the merged route round, so an overlap would reorder
    ///   charges on the shared PE). Debug builds assert both disjointness
    ///   conditions.
    ///
    /// # PE-task settlement ordering
    ///
    /// The pool-scheduled PE-task layer ([`Machine::par_pes`] /
    /// [`Machine::par_superstep`]) builds on the same exactness argument.
    /// Its ordering rules:
    ///
    /// 1. every task's charges replay at settlement in **(PE, call)
    ///    order** — all of task 0's charges in the order it recorded
    ///    them, then task 1's, … — which is exactly the order a
    ///    sequential `for pe { … }` loop issues;
    /// 2. crash selection inherits the first-crash-wins rule of
    ///    [`Machine::note_mem`] under that replay order, so the crashing
    ///    (PE, call site) is identical to sequential execution no matter
    ///    which worker finished first;
    /// 3. in [`Machine::par_superstep`], communication charges buffer
    ///    into this transcript and settle as one batched round *after*
    ///    all work/memory charges, under the same disjointness contract
    ///    as hand-written supersteps;
    /// 4. a raw superstep and a PE-task round never overlap (both
    ///    assert), so there is exactly one charge stream to order.
    ///
    /// [`xchg`]: Machine::xchg
    /// [`send`]: Machine::send
    /// [`route_round`]: Machine::route_round
    /// [`settle`]: Machine::settle
    /// [`time`]: Machine::time
    /// [`clock`]: Machine::clock
    pub fn begin_superstep(&mut self) {
        assert!(self.transcript.is_none(), "superstep already open");
        // reuse the drained transcript's buffers: dimension rounds stay
        // allocation-free after warmup
        self.transcript = Some(std::mem::take(&mut self.spare));
    }

    /// Whether a superstep transcript is currently open.
    pub fn in_superstep(&self) -> bool {
        self.transcript.is_some()
    }

    /// Close the open superstep: apply all buffered pairwise ops in call
    /// order, then settle all buffered routed messages as one h-relation in
    /// a single pass over per-PE message tallies (radix-accumulated by PE
    /// index — the sorted-by-PE view without the sort), using the machine's
    /// reusable scratch buffers. See [`Machine::begin_superstep`] for the
    /// exactness contract.
    pub fn settle(&mut self) {
        let mut t = self.transcript.take().expect("settle() without begin_superstep()");
        self.host_rounds += 1;
        #[cfg(debug_assertions)]
        {
            // the exactness contract (see begin_superstep): pairwise ops
            // of one superstep must touch disjoint PE pairs, and routed
            // messages must not share a PE with any pairwise op (settle
            // reorders pairwise-before-route). Checked via the reusable
            // scratch — no per-superstep allocation even in test builds,
            // sized by the highest PE the superstep names, not by p.
            let hi = t
                .ops
                .iter()
                .map(|op| match *op {
                    PendingOp::Xchg { i, j, .. } => i.max(j),
                    PendingOp::Send { from, to, .. } => from.max(to),
                })
                .chain(t.route.iter().map(|&(f, to, _)| f.max(to)))
                .max()
                .map_or(0, |m| m + 1);
            self.scratch.ensure_capacity(hi);
            let scratch = &mut self.scratch;
            for op in &t.ops {
                let (a, b) = match *op {
                    PendingOp::Xchg { i, j, .. } => (i, j),
                    PendingOp::Send { from, to, .. } => (from, to),
                };
                for pe in [a, b] {
                    debug_assert!(
                        !scratch.seen[pe],
                        "superstep pairwise ops must be disjoint (PE {pe} reused)"
                    );
                    scratch.seen[pe] = true;
                    scratch.touched.push(pe);
                }
            }
            for &(from, to, _) in &t.route {
                debug_assert!(
                    !scratch.seen[from] && !scratch.seen[to],
                    "superstep routed messages must not share PEs with \
                     pairwise ops (message {from}→{to})"
                );
            }
            for &pe in &scratch.touched {
                scratch.seen[pe] = false;
            }
            scratch.touched.clear();
        }
        for op in &t.ops {
            match *op {
                PendingOp::Xchg { i, j, l_ij, l_ji } => self.xchg_now(i, j, l_ij, l_ji),
                PendingOp::Send { from, to, l } => self.send_now(from, to, l),
            }
        }
        self.settle_route(&t.route);
        t.ops.clear();
        t.route.clear();
        self.spare = t;
    }

    /// Charge one irregular round. One pass over the messages accumulates
    /// per-PE send/receive tallies into the reusable scratch (only slots of
    /// PEs that appear in the round are written and re-zeroed), then one
    /// pass over the touched PEs advances clocks and degree stats — the
    /// arithmetic is identical, addition order included, to the historical
    /// per-call implementation that allocated five `vec![…; p]` per round.
    fn settle_route(&mut self, msgs: &[(usize, usize, usize)]) {
        if msgs.is_empty() {
            return;
        }
        // size the tallies by the highest PE this round names — O(msgs),
        // never O(p)
        let hi = msgs.iter().map(|&(f, t, _)| f.max(t)).max().unwrap();
        self.scratch.ensure_capacity(hi + 1);
        let scratch = &mut self.scratch;
        let clocks = &mut self.clocks;
        let cost = &self.cost;
        let stats = &mut self.stats;

        fn mark(seen: &mut [bool], touched: &mut Vec<usize>, pe: usize) {
            if !seen[pe] {
                seen[pe] = true;
                touched.push(pe);
            }
        }

        for &(from, to, l) in msgs {
            debug_assert!(from != to);
            let c = cost.msg(l);
            mark(&mut scratch.seen, &mut scratch.touched, from);
            mark(&mut scratch.seen, &mut scratch.touched, to);
            scratch.out[from] += c;
            scratch.outdeg[from] += 1;
            scratch.inc[to] += c;
            scratch.indeg[to] += 1;
            // a receiver cannot start draining before its senders have
            // started this round (receive time itself overlaps the
            // transmissions — the standard superstep approximation)
            let c_from = clocks.get(from);
            if c_from > scratch.recv_ready[to] {
                scratch.recv_ready[to] = c_from;
            }
        }
        for &pe in &scratch.touched {
            let mut t = clocks.get(pe) + scratch.out[pe];
            if scratch.indeg[pe] > 0 {
                t = t.max(scratch.recv_ready[pe]) + scratch.inc[pe];
            }
            clocks.set(pe, t);
            let deg = scratch.indeg[pe].max(scratch.outdeg[pe]);
            if deg > stats.max_degree {
                stats.max_degree = deg;
            }
        }
        stats.messages += msgs.len() as u64;
        stats.words += msgs.iter().map(|&(_, _, l)| l as u64).sum::<u64>();
        // restore the all-clean invariant, touching only dirtied slots
        for &pe in &scratch.touched {
            scratch.out[pe] = 0.0;
            scratch.inc[pe] = 0.0;
            scratch.recv_ready[pe] = 0.0;
            scratch.indeg[pe] = 0;
            scratch.outdeg[pe] = 0;
            scratch.seen[pe] = false;
        }
        scratch.touched.clear();
    }

    /// Barrier over a PE group: clocks advance to the group max (plus a
    /// log-depth tree of zero-length messages).
    ///
    /// A barrier over **all** p PEs (distinct indices, so `len == p`
    /// means full coverage) settles O(1): the group max is the machine
    /// makespan, and raising the clock floor advances every PE at once.
    pub fn barrier(&mut self, pes: &[usize]) {
        if pes.len() <= 1 {
            return;
        }
        self.host_rounds += 1;
        let depth = (pes.len() as f64).log2().ceil();
        if pes.len() == self.p {
            let t = self.clocks.max + 2.0 * depth * self.cost.alpha;
            self.clocks.raise_floor(t);
        } else {
            let max = pes.iter().map(|&i| self.clocks.get(i)).fold(0.0, f64::max);
            let t = max + 2.0 * depth * self.cost.alpha;
            for &i in pes {
                self.clocks.set(i, t);
            }
        }
        self.stats.messages += 2 * (pes.len() as u64 - 1);
    }

    /// Advance every clock in `pes` to their common max (free sync used to
    /// model the implicit synchrony of lock-step collectives that already
    /// paid their message costs). Whole-machine groups settle O(1) via
    /// the clock floor, like [`Machine::barrier`].
    pub fn sync_free(&mut self, pes: &[usize]) {
        if pes.len() == self.p {
            let t = self.clocks.max;
            self.clocks.raise_floor(t);
            return;
        }
        let max = pes.iter().map(|&i| self.clocks.get(i)).fold(0.0, f64::max);
        for &i in pes {
            self.clocks.set(i, max);
        }
    }

    // ---- pool-scheduled PE tasks ---------------------------------------

    /// Run one per-PE task for every item of `data` — task `i` gets
    /// `&mut data[i]` and a [`PeCtx`] ledger charging PE `first_pe + i` —
    /// on up to [`Machine::pe_jobs`] workers of the shared
    /// [`crate::exec`] pool, then settle all ledgers **in PE order**.
    ///
    /// # Determinism contract
    ///
    /// The closure must be a pure function of its own item, the ctx, and
    /// shared *immutable* captures. Charges are buffered per task and
    /// replayed in (PE, call) order at settlement — the exact sequence a
    /// sequential `for pe { … }` loop over the same bodies would have
    /// issued — so results (clocks, stats, crash selection, float addition
    /// order) are bit-identical for every `pe_jobs` value and every
    /// thread interleaving.
    ///
    /// # `par_min_work()` contract
    ///
    /// Rounds whose [`ParSpec::work`] hint is below the machine's
    /// [`Machine::par_min_work`] threshold (default [`par_min_work`]:
    /// `--par-min-work` / `RMPS_PAR_MIN_WORK` / [`PAR_MIN_WORK`]) run
    /// inline through the *same* ledger machinery, so the inline and
    /// pooled paths cannot diverge: the threshold — like `pe_jobs` — is
    /// pure host scheduling, compared only against the static `work`
    /// hint, never against timing. RunReports are bit-identical for every
    /// threshold value, from `1` (everything pooled) to `usize::MAX`
    /// (everything inline); `pe_jobs_equivalence.rs` pins this.
    ///
    /// Communication charges recorded through [`PeCtx::xchg`] /
    /// [`PeCtx::send`] / [`PeCtx::route`] settle **eagerly** in the same
    /// replay order (each routed message as its own round); use
    /// [`Machine::par_superstep`] to settle them as one batched
    /// superstep instead. Panics if a raw superstep is already open.
    pub fn par_pes<T: Send, R: Send>(
        &mut self,
        first_pe: usize,
        spec: ParSpec,
        data: &mut [T],
        f: impl Fn(&mut PeCtx, &mut T) -> R + Sync,
    ) -> Vec<R> {
        self.par_core(PeMap::From(first_pe), spec, data, false, f)
    }

    /// [`Machine::par_pes`] with an explicit PE mapping: task `i` charges
    /// PE `pes[i]` (strided groups — RFIS grid rows/columns, collectives
    /// over arbitrary member lists). `pes.len()` must equal `data.len()`.
    pub fn par_pes_on<T: Send, R: Send>(
        &mut self,
        pes: &[usize],
        spec: ParSpec,
        data: &mut [T],
        f: impl Fn(&mut PeCtx, &mut T) -> R + Sync,
    ) -> Vec<R> {
        assert_eq!(pes.len(), data.len(), "one task per group member");
        self.par_core(PeMap::Of(pes), spec, data, false, f)
    }

    /// [`Machine::par_pes`] whose communication charges settle as **one**
    /// batched superstep: after the per-PE work/memory charges replay, all
    /// [`PeCtx::xchg`]/[`PeCtx::send`]/[`PeCtx::route`] charges of the
    /// round are applied inside a single
    /// [`begin_superstep`]/[`settle`] window — pairwise ops in (PE, call)
    /// order, routed messages merged into one h-relation. The superstep
    /// exactness contract applies (disjoint pairwise PE pairs; see
    /// [`Machine::begin_superstep`]).
    ///
    /// [`begin_superstep`]: Machine::begin_superstep
    /// [`settle`]: Machine::settle
    pub fn par_superstep<T: Send, R: Send>(
        &mut self,
        first_pe: usize,
        spec: ParSpec,
        data: &mut [T],
        f: impl Fn(&mut PeCtx, &mut T) -> R + Sync,
    ) -> Vec<R> {
        self.par_core(PeMap::From(first_pe), spec, data, true, f)
    }

    fn par_core<T: Send, R: Send>(
        &mut self,
        map: PeMap<'_>,
        spec: ParSpec,
        data: &mut [T],
        superstep: bool,
        f: impl Fn(&mut PeCtx, &mut T) -> R + Sync,
    ) -> Vec<R> {
        assert!(
            !self.in_superstep(),
            "cannot run PE tasks inside an open raw superstep"
        );
        let n = data.len();
        // reuse the spare round container: warm rounds allocate no task
        // list (the ctx objects themselves come from ctx_pool)
        let mut ctxs: Vec<PeCtx> = std::mem::take(&mut self.ctx_round);
        debug_assert!(ctxs.is_empty());
        ctxs.reserve(n);
        for i in 0..n {
            let pe = match map {
                PeMap::From(base) => base + i,
                PeMap::Of(pes) => pes[i],
            };
            debug_assert!(pe < self.p, "task PE {pe} out of range (p = {})", self.p);
            let mut ctx = self.ctx_pool.pop().unwrap_or_default();
            ctx.pe = pe;
            ctx.rank = i;
            ctx.cost = self.cost;
            debug_assert!(ctx.charges.is_empty() && ctx.bufs.is_empty());
            for _ in 0..spec.bufs_each {
                // pooled buffers while they last; an exhausted pool hands
                // out fresh (unallocated) empties
                let buf = self.plane.take_buf();
                ctx.bufs.push(buf);
            }
            ctxs.push(ctx);
        }
        let jobs = if spec.work >= self.par_min_work { self.pe_jobs } else { 1 };
        let results: Vec<R> = {
            let data_cells = exec::SliceCells::new(data);
            let ctx_cells = exec::SliceCells::new(&mut ctxs);
            let f = &f;
            exec::parallel_map(jobs, n, move |i| {
                // SAFETY: parallel_map claims each index exactly once, so
                // these are the only &mut borrows of data[i] and ctxs[i].
                let (ctx, item) = unsafe { (ctx_cells.get_mut(i), data_cells.get_mut(i)) };
                f(ctx, item)
            })
        };
        if superstep {
            // work/mem charges apply eagerly inside the window; comm
            // charges buffer into the transcript and settle as one round
            self.begin_superstep();
        }
        for ctx in ctxs.iter_mut() {
            self.settle_ctx_charges(ctx);
        }
        if superstep {
            self.settle();
        }
        for mut ctx in ctxs.drain(..) {
            for buf in ctx.bufs.drain(..) {
                self.plane.recycle_buf(buf);
            }
            self.ctx_pool.push(ctx);
        }
        self.ctx_round = ctxs;
        results
    }

    /// Replay one task ledger through the ordinary charge entry points —
    /// the settlement half of the [`PeCtx`] determinism contract.
    fn settle_ctx_charges(&mut self, ctx: &mut PeCtx) {
        let pe = ctx.pe;
        for charge in ctx.charges.drain(..) {
            match charge {
                PeCharge::Work(ops) => self.work(pe, ops),
                PeCharge::Mem { at, elems, context } => self.note_mem(at, elems, context),
                PeCharge::Fail { context } => self.fail(pe, context),
                PeCharge::Xchg { with, l_out, l_in } => self.xchg(pe, with, l_out, l_in),
                PeCharge::Send { to, words } => self.send(pe, to, words),
                PeCharge::Route { to, words } => self.route_round(&[(pe, to, words)]),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(p: usize) -> Machine {
        Machine::new(
            p,
            CostModel { alpha: 100.0, beta: 1.0, cmp: 1.0, duplex: true },
        )
    }

    #[test]
    fn xchg_advances_both_to_common_time() {
        let mut mach = m(4);
        mach.work(0, 50.0);
        mach.xchg(0, 1, 10, 4);
        assert_eq!(mach.clock(0), 50.0 + 100.0 + 10.0);
        assert_eq!(mach.clock(1), mach.clock(0));
        assert_eq!(mach.stats.messages, 2);
        assert_eq!(mach.stats.words, 14);
    }

    #[test]
    fn send_receiver_waits_for_arrival() {
        let mut mach = m(2);
        mach.send(0, 1, 10);
        assert_eq!(mach.clock(0), 110.0);
        assert_eq!(mach.clock(1), 110.0);
        // a receiver already past the arrival time is not delayed
        let mut mach = m(2);
        mach.work(1, 500.0);
        mach.send(0, 1, 10);
        assert_eq!(mach.clock(1), 500.0);
    }

    #[test]
    fn route_round_serializes_fan_in() {
        // p-1 PEs all send 1 word to PE 0: PE 0 pays sum of receive costs —
        // the Ω(p) bottleneck RAMS' DMA removes (Fig. 2c).
        let mut mach = m(8);
        let msgs: Vec<_> = (1..8).map(|i| (i, 0usize, 1usize)).collect();
        mach.route_round(&msgs);
        assert!(mach.clock(0) >= 7.0 * 101.0, "clock {}", mach.clock(0));
        assert_eq!(mach.stats.max_degree, 7);
        // senders pay only their own message
        assert_eq!(mach.clock(1), 101.0);
    }

    #[test]
    fn route_round_parallel_pairs_are_cheap() {
        let mut mach = m(8);
        let msgs: Vec<_> = (0..4).map(|i| (2 * i, 2 * i + 1, 5usize)).collect();
        mach.route_round(&msgs);
        assert_eq!(mach.time(), 105.0);
    }

    #[test]
    fn mem_cap_triggers_crash() {
        let mut mach = m(2);
        mach.mem_cap_elems = Some(100);
        mach.note_mem(1, 50, "fill");
        assert!(!mach.crashed());
        mach.note_mem(1, 101, "overflow");
        assert!(mach.crashed());
        let c = mach.crash().unwrap();
        assert_eq!(c.pe, 1);
        assert_eq!(c.resident_elems, 101);
    }

    #[test]
    fn barrier_aligns_clocks() {
        let mut mach = m(4);
        mach.work(2, 1000.0);
        mach.barrier(&[0, 1, 2, 3]);
        let t = mach.clock(0);
        assert!(t >= 1000.0);
        assert!((0..4).all(|i| mach.clock(i) == t));
    }

    #[test]
    fn work_sort_charges_nlogn() {
        let mut mach = m(1);
        mach.work_sort(0, 1024);
        assert_eq!(mach.clock(0), 1024.0 * 10.0);
    }

    #[test]
    fn superstep_xchg_round_matches_eager() {
        let mut eager = m(8);
        let mut batched = m(8);
        for pe in 0..8 {
            eager.work(pe, (pe * 37) as f64);
            batched.work(pe, (pe * 37) as f64);
        }
        for (i, j, a, b) in [(0, 1, 5, 3), (2, 3, 0, 9), (4, 7, 2, 2)] {
            eager.xchg(i, j, a, b);
        }
        batched.begin_superstep();
        assert!(batched.in_superstep());
        for (i, j, a, b) in [(0, 1, 5, 3), (2, 3, 0, 9), (4, 7, 2, 2)] {
            batched.xchg(i, j, a, b);
        }
        // buffered: clocks unchanged until settle
        assert_eq!(batched.clock(0), 0.0);
        batched.settle();
        assert!(!batched.in_superstep());
        for pe in 0..8 {
            assert_eq!(eager.clock(pe).to_bits(), batched.clock(pe).to_bits(), "pe {pe}");
        }
        assert_eq!(eager.stats.messages, batched.stats.messages);
        assert_eq!(eager.stats.words, batched.stats.words);
    }

    #[test]
    fn superstep_send_round_matches_eager() {
        let mut eager = m(4);
        let mut batched = m(4);
        eager.work(2, 500.0);
        batched.work(2, 500.0);
        eager.send(0, 1, 10);
        eager.send(3, 2, 4);
        batched.begin_superstep();
        batched.send(0, 1, 10);
        batched.send(3, 2, 4);
        batched.settle();
        for pe in 0..4 {
            assert_eq!(eager.clock(pe).to_bits(), batched.clock(pe).to_bits(), "pe {pe}");
        }
    }

    #[test]
    fn superstep_merges_route_rounds() {
        // two route_round calls inside one superstep == one eager call on
        // the concatenation
        let a = [(1usize, 0usize, 3usize), (2, 0, 1)];
        let b = [(3usize, 0usize, 2usize), (4, 5, 7)];
        let merged: Vec<_> = a.iter().chain(b.iter()).copied().collect();
        let mut eager = m(8);
        eager.route_round(&merged);
        let mut batched = m(8);
        batched.begin_superstep();
        batched.route_round(&a);
        batched.route_round(&b);
        batched.settle();
        for pe in 0..8 {
            assert_eq!(eager.clock(pe).to_bits(), batched.clock(pe).to_bits(), "pe {pe}");
        }
        assert_eq!(eager.stats.messages, batched.stats.messages);
        assert_eq!(eager.stats.max_degree, batched.stats.max_degree);
    }

    #[test]
    fn route_scratch_is_clean_across_rounds() {
        // back-to-back rounds must not leak tallies into each other
        let mut mach = m(4);
        mach.route_round(&[(0, 1, 10)]);
        let after_first = mach.clock(1);
        mach.route_round(&[(2, 3, 10)]);
        // round 2 must not re-charge PEs 0/1
        assert_eq!(mach.clock(1), after_first);
        assert_eq!(mach.clock(3), 100.0 + 10.0);
        // and an empty superstep settles as a no-op
        mach.begin_superstep();
        mach.settle();
        assert_eq!(mach.clock(3), 110.0);
    }

    #[test]
    #[should_panic(expected = "superstep already open")]
    fn nested_superstep_panics() {
        let mut mach = m(2);
        mach.begin_superstep();
        mach.begin_superstep();
    }

    /// The work/mem ledger settles bit-identically to the sequential loop
    /// it replaces, for any pe_jobs value (forcing the pooled path with a
    /// large work hint).
    #[test]
    fn par_pes_settlement_matches_sequential_loop() {
        let lens: Vec<usize> = (0..16).map(|pe| 10 + 7 * pe).collect();

        let mut eager = m(16);
        eager.mem_cap_elems = Some(100);
        for (pe, &len) in lens.iter().enumerate() {
            eager.work_sort(pe, len);
            eager.work_linear(pe, len / 2);
            eager.note_mem(pe, len, "par test");
        }

        for pe_jobs in [1usize, 3, 8] {
            let mut par = m(16);
            par.mem_cap_elems = Some(100);
            par.set_pe_jobs(pe_jobs);
            let mut items = lens.clone();
            par.par_pes(0, ParSpec::work(PAR_MIN_WORK), &mut items, |ctx, len| {
                ctx.work_sort(*len);
                ctx.work_linear(*len / 2);
                ctx.note_mem(*len, "par test");
            });
            for pe in 0..16 {
                assert_eq!(
                    eager.clock(pe).to_bits(),
                    par.clock(pe).to_bits(),
                    "pe {pe} jobs {pe_jobs}"
                );
            }
            assert_eq!(
                eager.stats.local_work.to_bits(),
                par.stats.local_work.to_bits(),
                "jobs {pe_jobs}"
            );
            assert_eq!(eager.stats.max_mem_elems, par.stats.max_mem_elems);
            // crash selection: the sequential first-crash-wins order
            assert_eq!(
                eager.crash().map(|c| c.to_string()),
                par.crash().map(|c| c.to_string()),
                "jobs {pe_jobs}"
            );
        }
    }

    /// Several tasks over the cap: the crash must name the *lowest* PE —
    /// sequential order — not whichever worker raced there first.
    #[test]
    fn par_pes_crash_selection_is_pe_ordered() {
        let mut mach = m(8);
        mach.mem_cap_elems = Some(10);
        mach.set_pe_jobs(8);
        let mut items = vec![0usize; 8];
        mach.par_pes(0, ParSpec::work(PAR_MIN_WORK), &mut items, |ctx, _| {
            if ctx.pe() >= 3 {
                ctx.note_mem(100 + ctx.pe(), "overflow");
            }
        });
        let c = mach.crash().expect("over cap");
        assert_eq!(c.pe, 3);
        assert_eq!(c.resident_elems, 103);
    }

    /// par_superstep: communication charges of all tasks settle as one
    /// batched round, identical to the hand-written superstep — one
    /// hypercube dimension (PE t paired with t+4) as the canonical shape.
    #[test]
    fn par_superstep_comm_matches_hand_written_superstep() {
        let mut eager = m(8);
        for pe in 0..8 {
            eager.work(pe, (pe * 13) as f64);
        }
        eager.begin_superstep();
        for t in 0..4usize {
            eager.work(t, 5.0);
            eager.xchg(t, t + 4, 4, 2);
        }
        eager.settle();

        let mut par = m(8);
        par.set_pe_jobs(4);
        for pe in 0..8 {
            par.work(pe, (pe * 13) as f64);
        }
        let mut items = [(); 4];
        par.par_superstep(0, ParSpec::work(PAR_MIN_WORK), &mut items, |ctx, _| {
            ctx.work(5.0);
            let partner = ctx.pe() + 4;
            ctx.xchg(partner, 4, 2);
        });
        for pe in 0..8 {
            assert_eq!(eager.clock(pe).to_bits(), par.clock(pe).to_bits(), "pe {pe}");
        }
        assert_eq!(eager.stats.messages, par.stats.messages);
        assert_eq!(eager.stats.words, par.stats.words);
    }

    /// All tasks' routed ledger charges settle as **one** h-relation
    /// under par_superstep: identical to an eager `route_round` over the
    /// concatenated message list.
    #[test]
    fn par_superstep_merges_routed_ledger_charges() {
        let msgs: Vec<(usize, usize, usize)> = (0..4).map(|t| (t, t + 4, 3 + t)).collect();
        let mut eager = m(8);
        eager.route_round(&msgs);

        let mut par = m(8);
        par.set_pe_jobs(4);
        let mut items = [(); 4];
        par.par_superstep(0, ParSpec::work(PAR_MIN_WORK), &mut items, |ctx, _| {
            let to = ctx.pe() + 4;
            ctx.route(to, 3 + ctx.pe());
        });
        for pe in 0..8 {
            assert_eq!(eager.clock(pe).to_bits(), par.clock(pe).to_bits(), "pe {pe}");
        }
        assert_eq!(eager.stats.messages, par.stats.messages);
        assert_eq!(eager.stats.words, par.stats.words);
        assert_eq!(eager.stats.max_degree, par.stats.max_degree);
    }

    /// The send and fail ledger arms replay in (PE, call) order — the
    /// eager sequence of the sequential loop they stand in for.
    #[test]
    fn par_pes_send_and_fail_settle_in_pe_order() {
        let mut eager = m(4);
        eager.send(0, 1, 5);
        eager.fail(1, "task failure");
        eager.send(2, 3, 7);

        let mut par = m(4);
        par.set_pe_jobs(4);
        let mut items = [(); 4];
        par.par_pes(0, ParSpec::work(PAR_MIN_WORK), &mut items, |ctx, _| {
            match ctx.pe() {
                0 => ctx.send(1, 5),
                1 => ctx.fail("task failure"),
                2 => ctx.send(3, 7),
                _ => {}
            }
        });
        for pe in 0..4 {
            assert_eq!(eager.clock(pe).to_bits(), par.clock(pe).to_bits(), "pe {pe}");
        }
        assert_eq!(
            eager.crash().map(|c| c.to_string()),
            par.crash().map(|c| c.to_string())
        );
        assert_eq!(eager.stats.messages, par.stats.messages);
    }

    /// Task buffer stash: pre-seeded from the machine pool, leftovers (and
    /// everything recycled into the ctx) return to the pool afterwards.
    #[test]
    fn par_pes_buffers_cycle_through_the_machine_pool() {
        let mut mach = m(4);
        // warm the pool with recognisable capacity
        let mut warm = Vec::with_capacity(64);
        warm.push(crate::elements::Elem::with_id(1, 1));
        mach.recycle_buf(warm);
        let mut items = [0usize; 4];
        let produced = mach.par_pes(0, ParSpec::work(0).bufs(1), &mut items, |ctx, _| {
            let mut b = ctx.take_buf();
            b.push(crate::elements::Elem::with_id(2, 2));
            ctx.recycle_buf(b);
            let b2 = ctx.take_buf(); // stash: the recycled buffer again
            ctx.recycle_buf(b2);
            ctx.pe()
        });
        assert_eq!(produced, vec![0, 1, 2, 3]);
        // pool holds the returned stash buffers: at least the warm one
        let back = mach.take_buf();
        assert!(back.is_empty(), "recycled buffers come back cleared");
    }

    /// Small rounds run inline, large rounds use pool workers — both
    /// paths go through the same ledger, so the results agree bitwise.
    /// Thresholds pinned per machine so the test forces each path
    /// regardless of any `RMPS_PAR_MIN_WORK` in the environment.
    #[test]
    fn par_pes_inline_and_pooled_agree() {
        let run = |threshold: usize, pe_jobs: usize| -> (Vec<u64>, f64) {
            let mut mach = m(8);
            mach.set_pe_jobs(pe_jobs);
            mach.set_par_min_work(threshold);
            let mut items: Vec<usize> = (0..8).collect();
            let out = mach.par_pes(0, ParSpec::work(64), &mut items, |ctx, v| {
                ctx.work_linear(*v * 100);
                (*v as u64) * 3
            });
            (out, mach.time())
        };
        let (a, ta) = run(usize::MAX, 8); // forced inline
        let (b, tb) = run(1, 8); // forced pooled
        assert_eq!(a, b);
        assert_eq!(ta.to_bits(), tb.to_bits());
    }

    /// The tunable gate: `set_par_min_work` flips the same round between
    /// inline and pooled with bit-identical settlement, `0` restores the
    /// process default, and the knob — host-execution state — survives
    /// `reset`.
    #[test]
    fn par_min_work_knob_round_trips_and_survives_reset() {
        let mut mach = m(8);
        mach.set_par_min_work(7);
        assert_eq!(mach.par_min_work(), 7);
        mach.reset(8, CostModel::default());
        assert_eq!(mach.par_min_work(), 7, "survives reset like pe_jobs");
        mach.set_par_min_work(0);
        assert_eq!(mach.par_min_work(), par_min_work(), "0 restores the default");
        assert!(Machine::new(8, CostModel::default()).par_min_work() >= 1);

        // the process-global override (CLI `--par-min-work`): machines
        // constructed under it inherit it; 0 clears back to env/compiled
        // default. All in one test — the global is process-wide, and
        // every value is results-invariant, so concurrent tests are
        // undisturbed, but asserting the round trip needs one thread.
        set_par_min_work(12_345);
        assert_eq!(par_min_work(), 12_345);
        assert_eq!(Machine::new(2, CostModel::default()).par_min_work(), 12_345);
        set_par_min_work(0);
        assert!(par_min_work() >= 1);
    }

    /// Nested cell × PE rounds on the persistent pool: outer cells fan
    /// out through `exec::parallel_map` while every cell's own machine
    /// runs force-pooled `par_pes` rounds — each cell must settle
    /// bit-identically to the same cell run fully serial, whatever mix of
    /// pool workers and inline degradation the budget hands out.
    #[test]
    fn nested_cell_pe_rounds_match_serial() {
        let cell = |c: usize, pe_jobs: usize, threshold: usize| -> (Vec<u64>, f64) {
            let mut mach = m(8);
            mach.set_pe_jobs(pe_jobs);
            mach.set_par_min_work(threshold);
            let mut items: Vec<usize> = (0..8).map(|i| i + 10 * c).collect();
            let out = mach.par_pes(0, ParSpec::work(64), &mut items, |ctx, v| {
                ctx.work_sort(*v + 1);
                ctx.work_linear(*v);
                (*v as u64).wrapping_mul(2_654_435_761)
            });
            (out, mach.time())
        };
        let serial: Vec<(Vec<u64>, f64)> = (0..6).map(|c| cell(c, 1, usize::MAX)).collect();
        let nested = crate::exec::parallel_map(4, 6, |c| cell(c, 4, 1));
        for (c, (s, n)) in serial.iter().zip(nested.iter()).enumerate() {
            assert_eq!(s.0, n.0, "cell {c} results");
            assert_eq!(s.1.to_bits(), n.1.to_bits(), "cell {c} makespan");
        }
    }

    #[test]
    #[should_panic(expected = "inside an open raw superstep")]
    fn par_pes_inside_superstep_panics() {
        let mut mach = m(2);
        mach.begin_superstep();
        let mut items = [0usize; 2];
        mach.par_pes(0, ParSpec::work(0), &mut items, |_, _| {});
    }
}
