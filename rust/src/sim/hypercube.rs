//! Hypercube topology helpers: subcubes (the paper's §II concept) and the
//! iterate-over-dimensions design pattern (Algorithm 1).

/// A `dim`-dimensional subcube: the PEs whose numbers share the high bits
/// `dim..d-1`, i.e. `prefix·2^dim .. (prefix+1)·2^dim`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cube {
    pub prefix: usize,
    pub dim: u32,
}

impl Cube {
    /// The whole machine of `p = 2^d` PEs.
    pub fn whole(p: usize) -> Self {
        assert!(p.is_power_of_two(), "hypercube algorithms need p = 2^d");
        Self { prefix: 0, dim: p.trailing_zeros() }
    }

    #[inline]
    pub fn size(&self) -> usize {
        1 << self.dim
    }

    /// First global PE number in this cube.
    #[inline]
    pub fn base(&self) -> usize {
        self.prefix << self.dim
    }

    /// Global PE number of local rank `r`.
    #[inline]
    pub fn pe(&self, r: usize) -> usize {
        debug_assert!(r < self.size());
        self.base() + r
    }

    /// Local rank of global PE `pe` (must be a member).
    #[inline]
    pub fn rank(&self, pe: usize) -> usize {
        debug_assert!(self.contains(pe));
        pe - self.base()
    }

    #[inline]
    pub fn contains(&self, pe: usize) -> bool {
        pe >> self.dim == self.prefix
    }

    /// Iterate over member PEs.
    pub fn pes(&self) -> impl Iterator<Item = usize> {
        let base = self.base();
        base..base + self.size()
    }

    /// Member PEs as a vector (for barrier-style APIs).
    pub fn pe_vec(&self) -> Vec<usize> {
        self.pes().collect()
    }

    /// Split along the highest local dimension `dim-1` into the 0-subcube
    /// (low half) and the 1-subcube (high half) — one step of hypercube
    /// quicksort's recursion.
    pub fn split(&self) -> (Cube, Cube) {
        assert!(self.dim >= 1);
        let d = self.dim - 1;
        (
            Cube { prefix: self.prefix << 1, dim: d },
            Cube { prefix: (self.prefix << 1) | 1, dim: d },
        )
    }

    /// Split into `k = 2^logk` equal subcubes along the top `logk` dims.
    pub fn split_k(&self, logk: u32) -> Vec<Cube> {
        assert!(logk <= self.dim);
        let d = self.dim - logk;
        (0..1usize << logk)
            .map(|i| Cube { prefix: (self.prefix << logk) | i, dim: d })
            .collect()
    }

    /// Hypercube partner of `pe` along local dimension `j` (`j < dim`).
    #[inline]
    pub fn partner(&self, pe: usize, j: u32) -> usize {
        debug_assert!(j < self.dim);
        pe ^ (1 << j)
    }
}

/// The pairwise exchange pattern of one hypercube dimension, in *group
/// rank* space: yields each `(r, r | 2^j)` pair once, low rank first, in
/// increasing order of `r` — for `r` in `0..size` with bit `j` clear.
///
/// This is the one communication round of "iterate over dimensions"
/// (Algorithm 1); the pairs are disjoint by construction, which is exactly
/// the contract [`crate::sim::Machine::begin_superstep`] needs to settle a
/// whole dimension in one batched pass. Collectives map ranks to global
/// PEs through their `pes` slice, so the same pattern serves contiguous
/// subcubes and strided groups alike.
pub fn rank_pairs(size: usize, j: u32) -> impl Iterator<Item = (usize, usize)> {
    debug_assert!(size.is_power_of_two());
    let bit = 1usize << j;
    debug_assert!(bit < size.max(1));
    (0..size).filter(move |r| r & bit == 0).map(move |r| (r, r | bit))
}

/// Reverse the low `bits` bits of `x` — the Mirrored instance's `m_i` and
/// the bit-fixing routing analysis both need it.
#[inline]
pub fn bit_reverse(x: usize, bits: u32) -> usize {
    if bits == 0 {
        return 0;
    }
    (x.reverse_bits()) >> (usize::BITS - bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whole_and_split() {
        let c = Cube::whole(8);
        assert_eq!(c.size(), 8);
        assert_eq!(c.pes().collect::<Vec<_>>(), (0..8).collect::<Vec<_>>());
        let (lo, hi) = c.split();
        assert_eq!(lo.pes().collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(hi.pes().collect::<Vec<_>>(), vec![4, 5, 6, 7]);
        let (hl, hh) = hi.split();
        assert_eq!(hl.pes().collect::<Vec<_>>(), vec![4, 5]);
        assert_eq!(hh.pes().collect::<Vec<_>>(), vec![6, 7]);
    }

    #[test]
    fn split_k_partitions() {
        let c = Cube::whole(16);
        let subs = c.split_k(2);
        assert_eq!(subs.len(), 4);
        let all: Vec<usize> = subs.iter().flat_map(|s| s.pes()).collect();
        assert_eq!(all, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn rank_pe_roundtrip() {
        let c = Cube { prefix: 3, dim: 2 };
        assert_eq!(c.base(), 12);
        for r in 0..4 {
            assert_eq!(c.rank(c.pe(r)), r);
            assert!(c.contains(c.pe(r)));
        }
        assert!(!c.contains(11));
        assert!(!c.contains(16));
    }

    #[test]
    fn partner_flips_bit() {
        let c = Cube::whole(8);
        assert_eq!(c.partner(0, 2), 4);
        assert_eq!(c.partner(5, 0), 4);
    }

    #[test]
    fn rank_pairs_cover_each_rank_once() {
        for j in 0..3u32 {
            let pairs: Vec<_> = rank_pairs(8, j).collect();
            assert_eq!(pairs.len(), 4, "dim {j}");
            let mut seen = vec![false; 8];
            for (lo, hi) in pairs {
                assert_eq!(lo ^ hi, 1 << j);
                assert!(lo < hi);
                assert!(!seen[lo] && !seen[hi]);
                seen[lo] = true;
                seen[hi] = true;
            }
            assert!(seen.iter().all(|&s| s));
        }
    }

    #[test]
    fn bit_reverse_small() {
        assert_eq!(bit_reverse(0b001, 3), 0b100);
        assert_eq!(bit_reverse(0b110, 3), 0b011);
        assert_eq!(bit_reverse(1, 1), 1);
        assert_eq!(bit_reverse(0, 0), 0);
        // involution
        for x in 0..64 {
            assert_eq!(bit_reverse(bit_reverse(x, 6), 6), x);
        }
    }
}
