//! rmps — CLI launcher for the Robust Massively Parallel Sorting
//! reproduction: single runs, full figure regenerations, and tuning
//! sweeps on the simulated α-β machine.
//!
//! The environment is offline, so argument parsing is hand-rolled
//! (`--key value` flags) instead of pulling in clap, and errors are a
//! plain message type instead of anyhow.

use std::sync::Arc;

use rmps::algorithms::selector::RobustSorter;
use rmps::algorithms::{find_sorter, registry, Runner, Sorter};
use rmps::config::RunConfig;
use rmps::experiments::{self, NpPoint};
use rmps::input::{generate, Distribution};
use rmps::localsort::SortBackend;
use rmps::model::CostModel;

/// Minimal CLI error: `Debug` prints the bare message, which is what
/// `fn main() -> Result<()>` shows on a nonzero exit.
struct CliError(String);

impl std::fmt::Debug for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

type Result<T> = std::result::Result<T, CliError>;

macro_rules! bail {
    ($($t:tt)*) => { return Err(CliError(format!($($t)*))) };
}

const USAGE: &str = "\
rmps — Robust Massively Parallel Sorting (Axtmann & Sanders 2016) reproduction

USAGE: rmps <COMMAND> [--key value ...]

COMMANDS
  run      one algorithm on one instance
             --algo A        (default Robust)   GatherM|AllGatherM|RFIS|RQuick|
                             NTB-Quick|Bitonic|RAMS|NTB-AMS|NDMA-AMS|HykSort|
                             SSort|NS-SSort|Minisort|Mways|Robust|
                             AMS-1|AMS-2|AMS-3 — or any sorter registered
                             with rmps::algorithms::register
             --dist D        (default Uniform)  Uniform|Gaussian|BucketSorted|
                             DeterDupl|RandDupl|Zero|g-Group|Staggered|
                             Mirrored|AllToOne|Reverse
             --n-per-pe M    (default 1024)
             --sparsity S    (default 1; >1 = one element per S PEs)
             --tuned-crossovers  (Robust only) derive the selector's n/p
                             crossovers for the configured α/β by probing
                             instead of using the paper's JUQUEEN table
  fig1     running times of all algorithms over the n/p sweep
             --max-log L     (default 10)    --reps R (default 1)
             --ams           add the multi-level AMS-1/2/3 columns
                             (1-factor exchange, successor paper)
             --giant-p       sweep the paper's machine-size ladder instead
                             (p = 2^14, 2^16, 2^18 — the JUQUEEN scale;
                             sparse points + n/p = 1, GatherM/RFIS/Robust
                             on Uniform; --p is ignored, the ladder sets
                             it). Affordable because supersteps cost
                             O(active PEs + messages) host work, not O(p)
  fig2a    RQuick / NTB-Quick ratios        --max-log L
  fig2b    fig2a on a smaller default machine
  fig2c    RAMS / NDMA-AMS ratios           --max-log L
  fig2d    RAMS / NS-SSort ratios           --max-log L
  fig4     median-tree quality              --max-pow2 (18) --reps (500)
  fig5     ratios of each algorithm to the fastest --max-log L
  table1   empirical Table I footprint growth  --n-per-pe --p-small
  tuning   App. J2 parameter sweeps          --p
  serve    sort-as-a-service: drain queued JSONL job specs through the
           registry/Runner machinery with admission control
             --drain FILE    read job specs from FILE (default: stdin),
                             one JSON object per line; blank lines are
                             skipped, bad lines are reported and counted
                             as rejections (nonzero exit)
             --jobs N        concurrent jobs admitted; shares the
                             process-wide worker-token budget with the
                             per-job --pe-jobs level, so the host is
                             never oversubscribed (results identical
                             for every N)
             --no-validate   skip the Θ(n) output validation per job
             --paper-crossovers  route untargeted jobs with the paper's
                             JUQUEEN table instead of a tuned table
                             probed once and cached per machine config
             --json-out P    also write the aggregate digest (throughput,
                             p50/p95/p99 queue/service/e2e latency µs,
                             per-sorter counts, reuse/cache rates) to P
           spec fields: p, n_per_pe, sparsity, dist, seed, algo, alpha,
           beta, mem_cap (null lifts the cap); omitted fields inherit
           the machine flags below

MACHINE FLAGS (all commands)
  --p P            simulated PEs, power of two (default 1024)
  --alpha A        startup cost (default 4000)
  --beta B         per-word cost (default 13)
  --seed S         RNG seed (default 0xC0FFEE)
  --jobs N         worker threads for figure/table sweeps
                   (default: available host parallelism; capped at the
                   host core count by the shared worker budget — the
                   simulator is CPU-bound, so oversubscription never
                   helps; results are byte-identical for every N — see
                   README § Parallel experiment driver)
  --pe-jobs N      worker threads for the per-PE phases *inside* one run
                   (default: RMPS_PE_JOBS, else available parallelism;
                   shares one thread pool with --jobs — no
                   oversubscription when both are active — and results
                   are bit-identical for every N — see README
                   § Two-level parallelism)
  --par-min-work W minimum total-work hint (elements) before a per-PE
                   round engages pool workers; smaller rounds run inline
                   (default: RMPS_PAR_MIN_WORK, else 8192 — the measured
                   crossover tracked by the hotpath bench; 1 = always
                   pooled. Host scheduling only: results are
                   bit-identical for every W)
  --sort-backend B node-local sort kernel: rust-pdqsort|radix-lsd
                   (default: RMPS_SORT_BACKEND, else rust-pdqsort;
                   results are bit-identical for every backend)
  --xla-local-sort use the PJRT/XLA batched local sorter
                   (needs artifacts/ and a build with --features xla)
";

/// Minimal `--key value` / `--flag` parser.
struct Args {
    kv: std::collections::HashMap<String, String>,
    flags: std::collections::HashSet<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut kv = std::collections::HashMap::new();
        let mut flags = std::collections::HashSet::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if !a.starts_with("--") {
                bail!("unexpected argument {a:?}");
            }
            let key = a.trim_start_matches("--").to_string();
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                kv.insert(key, argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key);
                i += 1;
            }
        }
        Ok(Self { kv, flags })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.kv.get(key) {
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("invalid value for --{key}: {v:?}"))),
            None => Ok(default),
        }
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.kv.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn flag(&self, key: &str) -> bool {
        self.flags.contains(key)
    }
}

fn machine_config(a: &Args) -> Result<RunConfig> {
    Ok(RunConfig {
        p: a.get("p", 1usize << 10)?,
        seed: a.get("seed", 0xC0FFEEu64)?,
        cost: CostModel {
            alpha: a.get("alpha", 4000.0)?,
            beta: a.get("beta", 13.0)?,
            ..Default::default()
        },
        ..Default::default()
    })
}

fn backend(a: &Args) -> Result<Box<dyn SortBackend>> {
    if a.flag("xla-local-sort") {
        #[cfg(feature = "xla")]
        {
            let b = rmps::runtime::XlaSort::from_env()
                .map_err(|e| CliError(format!("XLA backend: {e}")))?;
            return Ok(Box::new(b));
        }
        #[cfg(not(feature = "xla"))]
        {
            bail!(
                "this binary was built without the `xla` feature; \
                 rebuild with `cargo build --features xla` (see README)"
            );
        }
    }
    // the process default: --sort-backend / RMPS_SORT_BACKEND, else pdqsort
    Ok(rmps::localsort::default_backend())
}

fn dense_points(max_log: u32) -> Vec<NpPoint> {
    (0..=max_log).step_by(2).map(|l| NpPoint::Dense(1 << l)).collect()
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let a = Args::parse(&argv[1..])?;
    let jobs: usize = a.get("jobs", rmps::exec::available_jobs())?;
    // 0 = "not given": keep the RMPS_PE_JOBS / all-cores default
    let pe_jobs: usize = a.get("pe-jobs", 0usize)?;
    if pe_jobs > 0 {
        rmps::exec::set_pe_jobs(pe_jobs);
    }
    // 0 = "not given": keep the RMPS_PAR_MIN_WORK / compiled default
    let par_min_work: usize = a.get("par-min-work", 0usize)?;
    if par_min_work > 0 {
        rmps::sim::set_par_min_work(par_min_work);
    }
    // "" = "not given": keep the RMPS_SORT_BACKEND / pdqsort default
    let sort_backend = a.get_str("sort-backend", "");
    if !sort_backend.is_empty() && !rmps::localsort::set_default_backend(&sort_backend) {
        bail!(
            "unknown sort backend `{sort_backend}`; built-ins: {}",
            rmps::localsort::BACKEND_NAMES.join(", ")
        );
    }

    match cmd.as_str() {
        "run" => {
            let algo = a.get_str("algo", "Robust");
            let dist = a.get_str("dist", "Uniform");
            let d = Distribution::parse(&dist)
                .ok_or_else(|| CliError(format!("unknown distribution {dist}")))?;
            let mut cfg = machine_config(&a)?;
            let sparsity: usize = a.get("sparsity", 1)?;
            if sparsity > 1 {
                cfg = cfg.with_sparsity(sparsity);
            } else {
                cfg = cfg.with_n_per_pe(a.get("n-per-pe", 1024)?);
            }
            // resolve --algo through the registry, so sorters added with
            // rmps::algorithms::register are first-class CLI citizens
            let sorter: Arc<dyn Sorter> = if a.flag("tuned-crossovers") {
                if !algo.eq_ignore_ascii_case("robust") {
                    bail!("--tuned-crossovers only applies to --algo Robust");
                }
                let table = experiments::tuning::crossover_table(&cfg);
                println!(
                    "tuned crossovers: GatherM ≤ {:.4} | RFIS < {} | RQuick ≤ {} | RAMS",
                    table.gather_max, table.rfis_max, table.rquick_max
                );
                Arc::new(RobustSorter::with_table(table))
            } else {
                find_sorter(&algo).ok_or_else(|| {
                    let known: Vec<&str> = registry().iter().map(|s| s.name()).collect();
                    CliError(format!(
                        "unknown algorithm {algo} (known: {})",
                        known.join(", ")
                    ))
                })?
            };
            let mut runner = Runner::new(cfg.clone()).backend(backend(&a)?);
            let input = generate(&cfg, d);
            let report = runner.run(sorter.as_ref(), input);
            println!(
                "algo={} dist={} p={} n/p={:.4}",
                report.algorithm,
                d.name(),
                cfg.p,
                cfg.n_over_p()
            );
            println!(
                "simulated time  : {:.4e} (α={}, β={})",
                report.time, cfg.cost.alpha, cfg.cost.beta
            );
            println!("messages        : {}", report.stats.messages);
            println!("words moved     : {}", report.stats.words);
            println!("max PE memory   : {}", report.stats.max_mem_elems);
            println!("host wallclock  : {:.1} ms", report.wall_ms);
            match &report.crashed {
                Some(c) => println!("CRASHED         : {c}"),
                None => println!(
                    "sorted={} balanced={} imbalance ε={:.3}",
                    report.validation.ok(),
                    report.validation.balanced,
                    report.validation.imbalance.epsilon
                ),
            }
        }
        "fig1" => {
            let cfg = machine_config(&a)?;
            let (max_log, reps) = (a.get("max-log", 10u32)?, a.get("reps", 1)?);
            if a.flag("giant-p") {
                experiments::fig1::run_giant_p(
                    &cfg,
                    &experiments::fig1::GIANT_P_LADDER,
                    &experiments::fig1::giant_p_points(),
                    experiments::fig1::giant_p_sorters(),
                    reps,
                    jobs,
                )
                .print();
            } else if a.flag("ams") {
                experiments::fig1::run_ams(&cfg, max_log, reps, jobs).print();
            } else {
                experiments::fig1::run(&cfg, max_log, reps, jobs).print();
            }
        }
        "fig2a" | "fig2b" => {
            let mut cfg = machine_config(&a)?;
            if cmd == "fig2b" && !a.kv.contains_key("p") {
                cfg.p = 1 << 8; // the paper's smaller 8 192-core machine
            }
            let series =
                experiments::fig2::fig2a(&cfg, &dense_points(a.get("max-log", 10u32)?), 1, jobs);
            experiments::fig2::print_series("Fig.2a/b RQuick vs NTB-Quick", &series);
        }
        "fig2c" => {
            let cfg = machine_config(&a)?;
            let series =
                experiments::fig2::fig2c(&cfg, &dense_points(a.get("max-log", 10u32)?), 1, jobs);
            experiments::fig2::print_series("Fig.2c RAMS vs NDMA-AMS", &series);
        }
        "fig2d" => {
            let cfg = machine_config(&a)?;
            let series =
                experiments::fig2::fig2d(&cfg, &dense_points(a.get("max-log", 12u32)?), 1, jobs);
            experiments::fig2::print_series("Fig.2d RAMS vs NS-SSort", &series);
        }
        "fig4" => {
            experiments::fig4::run(
                a.get("max-pow2", 18u32)?,
                a.get("reps", 500usize)?,
                a.get("seed", 42u64)?,
                jobs,
            )
            .print();
        }
        "fig5" => {
            let cfg = machine_config(&a)?;
            experiments::fig5::run(&cfg, a.get("max-log", 10u32)?, 1, jobs).print();
        }
        "table1" => {
            let rows = experiments::table1::run_table(
                a.get("n-per-pe", 64usize)?,
                a.get("p-small", 1usize << 6)?,
                a.get("seed", 7u64)?,
                jobs,
            );
            experiments::table1::print_rows(&rows);
        }
        "tuning" => {
            experiments::tuning::run(a.get("p", 1usize << 8)?, &[16, 256, 4096], jobs).print();
        }
        "serve" => {
            let opts = rmps::serve::ServeOptions {
                jobs,
                base: machine_config(&a)?,
                validate: !a.flag("no-validate"),
                // the CLI prints digests, never payloads — don't retain Θ(n)
                keep_output: false,
                route_tuned: !a.flag("paper-crossovers"),
            };
            let service = rmps::serve::Service::new(opts);
            let outcome = match a.kv.get("drain") {
                Some(path) => {
                    let text = std::fs::read_to_string(path)
                        .map_err(|e| CliError(format!("cannot read {path}: {e}")))?;
                    service.drain_lines(text.lines().map(str::to_string))
                }
                None => {
                    use std::io::BufRead;
                    let stdin = std::io::stdin();
                    let lines = stdin.lock().lines().map_while(|l| l.ok());
                    service.drain_lines(lines)
                }
            };
            for (rec, rep) in outcome.records.iter().zip(&outcome.reports) {
                let tail = match &rep.crashed {
                    Some(c) => format!("  CRASHED: {c}"),
                    None => String::new(),
                };
                println!(
                    "job {:>4}  {:<12} p={:<6} n={:<9} sim={:<12.4e} queue {:>9.0} µs  \
                     service {:>9.0} µs  e2e {:>9.0} µs{}",
                    rec.id,
                    rec.algorithm,
                    rec.p,
                    rec.n_total,
                    rec.sim_time,
                    rec.queue_us,
                    rec.service_us,
                    rec.total_us,
                    tail
                );
            }
            outcome.stats.print();
            for (line, err) in &outcome.errors {
                eprintln!("rejected job spec at input line {line}: {err}");
            }
            if let Some(path) = a.kv.get("json-out") {
                std::fs::write(path, outcome.stats.to_json())
                    .map_err(|e| CliError(format!("cannot write {path}: {e}")))?;
                println!("wrote {path}");
            }
            if !outcome.errors.is_empty() {
                bail!("{} job spec(s) rejected", outcome.errors.len());
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
    Ok(())
}
