//! AMS — multi-level adaptive samplesort with a **1-factor** data
//! exchange, from the paper's successor work (*Practical Massively
//! Parallel Sorting*, Axtmann et al.; PAPERS.md).
//!
//! The single-level algorithms of the evaluation stop scaling when the
//! splitter count and the exchange degree reach O(p). AMS generalizes
//! samplesort to `k` recursion levels so that **both stay O(p^(1/k))**:
//! each level splits a PE group of size q into q^(1/levels-left)
//! subgroups, so after `k` levels every PE owns one contiguous key range.
//! Per level:
//!
//! 1. sample with position tie-breakers and rank the sample globally
//!    (the same [`crate::partition`] splitter machinery RAMS uses — the
//!    tie-breaking *simulates unique keys*, App. G of the base paper);
//! 2. partition locally with the Super Scalar Sample Sort classifier,
//!    one pooled PE task per member;
//! 3. group-wide bucket histograms via a vector prefix sum, greedy
//!    contiguous assignment of buckets to subgroups, and exact target
//!    offsets from the prefix sums (message assignment without the
//!    two-hop DMA detour);
//! 4. the irregular h-relation travels through
//!    [`crate::sim::Exchange::deliver_1factor`]: q−1 (q even; q for odd)
//!    lock-step pairwise rounds pairing rank i with
//!    [`crate::sim::one_factor_partner`], so a receiver's fan-in is
//!    spread over rounds instead of serializing on one PE — this is what
//!    replaces DMA on adversarial skew (AllToOne) and keeps the exchange
//!    degree O(p^(1/k)) per round;
//! 5. receivers merge their runs; recurse into the subgroups.

use crate::config::RunConfig;
use crate::elements::{multiway_merge_into, Elem};
use crate::localsort::{sort_all, SortBackend};
use crate::partition::{partition_ctx, pick_splitters, SplitterTree};
use crate::rng::Rng;
use crate::sim::{all_gather_merge, prefix_sum_vec, Cube, Machine, ParSpec};

use super::{OutputShape, Sorter};

/// Multi-level AMS-sort with the 1-factor exchange as a [`Sorter`] value.
///
/// The level count `k` is fixed at construction ([`AmsSorter::with_levels`])
/// and bounds the per-level splitter count and exchange degree to
/// **O(p^(1/k))** — the central claim of *Practical Massively Parallel
/// Sorting*. `k = 1` degenerates to a single-level samplesort with a
/// round-scheduled alltoallv; the registry carries k ∈ {1, 2, 3} as
/// `AMS-1`/`AMS-2`/`AMS-3`.
///
/// Robust in the §VII-B sense: splitter tie-breaking on `(key, id)`
/// survives duplicate-heavy inputs, and the oblivious 1-factor schedule
/// bounds per-round fan-in where direct delivery (NDMA-AMS) serializes
/// Ω(min(p, n/p)) receives on one PE.
#[derive(Clone, Copy, Debug)]
pub struct AmsSorter {
    /// Recursion depth k ≥ 1.
    pub levels: usize,
    name: &'static str,
}

impl AmsSorter {
    /// AMS with exactly `levels` recursion levels (clamped to ≥ 1).
    pub fn with_levels(levels: usize) -> Self {
        let levels = levels.max(1);
        let name = match levels {
            1 => "AMS-1",
            2 => "AMS-2",
            3 => "AMS-3",
            _ => "AMS",
        };
        Self { levels, name }
    }
}

impl Sorter for AmsSorter {
    fn name(&self) -> &'static str {
        self.name
    }

    fn output_shape(&self) -> OutputShape {
        OutputShape::Balanced
    }

    fn is_robust(&self) -> bool {
        true
    }

    fn sort(
        &self,
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) -> OutputShape {
        sort(mach, data, cfg, backend, self.levels);
        OutputShape::Balanced
    }
}

pub fn sort(
    mach: &mut Machine,
    data: &mut Vec<Vec<Elem>>,
    cfg: &RunConfig,
    backend: &mut dyn SortBackend,
    levels: usize,
) {
    let p = cfg.p;
    assert!(p.is_power_of_two());
    let levels = levels.max(1);
    let mut rng = Rng::seeded(cfg.seed ^ 0x414D_5332, 5);

    sort_all(mach, data, backend);

    let mut groups = vec![(Cube::whole(p), levels)];
    while let Some((group, levels_left)) = groups.pop() {
        if group.dim == 0 || levels_left == 0 {
            continue;
        }
        let subs = level(mach, &group, data, cfg, levels, levels_left, &mut rng);
        if mach.crashed() {
            return;
        }
        for s in subs {
            groups.push((s, levels_left - 1));
        }
    }
}

/// One k-way AMS level; returns the subgroups for recursion. The level
/// skeleton is RAMS's (rams.rs) with always-on tie-breaking, no DMA
/// branch, and the 1-factor delivery closing the exchange.
fn level(
    mach: &mut Machine,
    group: &Cube,
    data: &mut [Vec<Elem>],
    cfg: &RunConfig,
    levels: usize,
    levels_left: usize,
    rng: &mut Rng,
) -> Vec<Cube> {
    let q = group.size();
    let pes = group.pe_vec();
    // arity: split the remaining dims evenly over the remaining levels,
    // so the splitter count and exchange degree stay O(q^(1/levels))
    let logk = group.dim.div_ceil(levels_left as u32).max(1);
    let k = 1usize << logk;
    let subgroups = group.split_k(logk);
    let q_sub = q / k;

    // --- oversampling factor b (App. J1): b = 2/((1+ε)^(1/l) − 1) ------
    let b = (2.0 / ((1.0 + cfg.epsilon).powf(1.0 / levels as f64) - 1.0)).ceil() as usize;
    let nb = ((b * k).next_power_of_two() - 1).max(k - 1).min(1023);

    // --- sampling with position tie-breakers ---------------------------
    let mut samples: Vec<Vec<Elem>> = vec![Vec::new(); data.len()];
    let budget = mach.mem_cap_elems.unwrap_or(usize::MAX).min(4 * nb.max(k));
    let s_loc_target = (budget as f64 / q as f64).ceil() as usize;
    for &pe in &pes {
        let local = &data[pe];
        let take = s_loc_target.max(1).min(local.len());
        for _ in 0..take {
            samples[pe].push(local[rng.below(local.len() as u64) as usize]);
        }
        samples[pe].sort_unstable();
        mach.work_sort(pe, take);
    }
    let gathered = all_gather_merge(mach, &pes, &samples);
    let sorted_samples = gathered[0].merged();
    let splitters = pick_splitters(&sorted_samples, nb);
    let tree = SplitterTree::new(&splitters);

    // --- local partition, always tie-breaking on (key, id) -------------
    let base = group.base();
    let mut buckets: Vec<Vec<Vec<Elem>>> = vec![Vec::new(); data.len()];
    let mut counts: Vec<Vec<usize>> = Vec::with_capacity(q);
    let total: usize = pes.iter().map(|&pe| data[pe].len()).sum();
    let parts_list: Vec<Vec<Vec<Elem>>> = mach.par_pes(
        base,
        ParSpec::work(total).bufs(nb + 2),
        &mut data[base..base + q],
        |ctx, slot| {
            let local = std::mem::take(slot);
            ctx.work_classify(local.len(), nb + 1);
            let parts = partition_ctx(ctx, &local, &tree, true);
            ctx.recycle_buf(local);
            parts
        },
    );
    for (r, parts) in parts_list.into_iter().enumerate() {
        counts.push(parts.iter().map(Vec::len).collect());
        buckets[base + r] = parts;
    }

    // --- histograms + greedy contiguous bucket→subgroup assignment -----
    let prefixes = prefix_sum_vec(mach, &pes, &counts);
    let totals: Vec<usize> = prefixes[0].1.clone();
    let grand_total: usize = totals.iter().sum();
    let ideal = grand_total as f64 / k as f64;
    let mut assignment = vec![0usize; nb + 1]; // bucket → subgroup
    {
        let mut cum = 0usize;
        let mut g = 0usize;
        for (bkt, &t) in totals.iter().enumerate() {
            let remaining_buckets = nb + 1 - bkt;
            let remaining_groups = k - g;
            if g + 1 < k
                && cum as f64 >= (g + 1) as f64 * ideal
                && remaining_buckets > remaining_groups - 1
            {
                g += 1;
            }
            assignment[bkt] = g;
            cum += t;
        }
        mach.work(pes[0], cfg.cost.cmp * (nb + 1) as f64);
    }
    let mut sub_total = vec![0usize; k];
    for (bkt, &g) in assignment.iter().enumerate() {
        sub_total[g] += totals[bkt];
    }
    // exclusive offset of bucket bkt within its subgroup's global order
    let mut bucket_base = vec![0usize; nb + 1];
    {
        let mut acc = vec![0usize; k];
        for (bkt, &g) in assignment.iter().enumerate() {
            bucket_base[bkt] = acc[g];
            acc[g] += totals[bkt];
        }
    }

    // --- exact message assignment: (sender, target, slice of bucket) ---
    let caps: Vec<usize> = sub_total.iter().map(|&t| t.div_ceil(q_sub).max(1)).collect();
    struct Msg {
        from_pe: usize,
        to_pe: usize,
        bucket: usize,
        start: usize, // element range within the sender's bucket
        end: usize,
    }
    let mut msgs: Vec<Msg> = Vec::new();
    let mut sender_spans: Vec<(usize, usize)> = Vec::with_capacity(q);
    for &pe in &pes {
        let r = group.rank(pe);
        let span_start = msgs.len();
        let pre = &prefixes[r].0;
        for bkt in 0..=nb {
            let len = buckets[pe][bkt].len();
            if len == 0 {
                continue;
            }
            let g = assignment[bkt];
            let goff = bucket_base[bkt] + pre[bkt]; // global offset in subgroup g
            let cap = caps[g];
            // split [goff, goff+len) on target-PE boundaries
            let mut local_start = 0usize;
            while local_start < len {
                let gpos = goff + local_start;
                let t_idx = (gpos / cap).min(q_sub - 1);
                let t_end_gpos = ((t_idx + 1) * cap).min(goff + len);
                let local_end = t_end_gpos - goff;
                msgs.push(Msg {
                    from_pe: pe,
                    to_pe: subgroups[g].pe(t_idx),
                    bucket: bkt,
                    start: local_start,
                    end: local_end,
                });
                local_start = local_end;
            }
        }
        sender_spans.push((span_start, msgs.len()));
    }

    // --- the 1-factor exchange ------------------------------------------
    // Direct per-(sender, target) messages like NDMA-AMS — but delivered
    // on the oblivious round schedule, so no receiver serializes more
    // than one message per round. Payload staging runs as one PE task per
    // sender; posting stays serial in the sender-major msgs order.
    let sender_runs: Vec<Vec<(usize, Vec<Elem>)>> = mach.par_pes_on(
        &pes,
        ParSpec::work(grand_total).bufs(2 * k),
        &mut sender_spans,
        |ctx, span| {
            let (lo, hi) = *span;
            msgs[lo..hi]
                .iter()
                .map(|m| {
                    let mut run = ctx.take_buf();
                    run.extend_from_slice(&buckets[m.from_pe][m.bucket][m.start..m.end]);
                    (m.to_pe, run)
                })
                .collect()
        },
    );
    let mut ex = mach.exchange();
    for (r, runs) in sender_runs.into_iter().enumerate() {
        for (to, run) in runs {
            ex.post(pes[r], to, run);
        }
    }
    let inboxes = ex.deliver_1factor(mach, &pes);
    for &pe in &pes {
        for bucket in std::mem::take(&mut buckets[pe]) {
            mach.recycle_buf(bucket);
        }
    }
    // receivers merge their runs: one PE task per member, ping-pong
    // multiway merge over pooled buffers
    let total_recv: usize = pes.iter().map(|&pe| inboxes.total(pe)).sum();
    mach.par_pes(
        base,
        ParSpec::work(2 * total_recv).bufs(2),
        &mut data[base..base + q],
        |ctx, slot| {
            let refs: Vec<&[Elem]> =
                inboxes.runs(ctx.pe()).iter().map(|(_, v)| v.as_slice()).collect();
            let mut merged = ctx.take_buf();
            multiway_merge_into(&refs, &mut merged, ctx.merge_scratch());
            ctx.work(cfg.cost.cmp * merged.len() as f64 * (refs.len().max(2) as f64).log2());
            ctx.note_mem(merged.len(), "AMS 1-factor exchange");
            *slot = merged;
        },
    );
    mach.recycle(inboxes);

    subgroups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::run_sorter_with_backend;
    use crate::input::{generate, Distribution};
    use crate::localsort::RustSort;

    fn run_ams(levels: usize, cfg: &RunConfig, dist: Distribution) -> crate::algorithms::RunReport {
        let sorter = AmsSorter::with_levels(levels);
        run_sorter_with_backend(&sorter, cfg, generate(cfg, dist), &mut RustSort)
    }

    #[test]
    fn ams_sorts_uniform_large_at_every_level_count() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(1024);
        for levels in [1usize, 2, 3] {
            let report = run_ams(levels, &cfg, Distribution::Uniform);
            assert!(report.succeeded(), "k={levels}: {:?} {:?}", report.crashed, report.validation);
            // the ε=0.2 contract is asserted for the single-level run;
            // deeper recursions compound per-level sampling error (the
            // base paper itself reports ε < 0.1 only for its tuned level
            // counts), so k ∈ {2, 3} pin a looser factor-2 bound
            if levels == 1 {
                assert!(
                    report.validation.balanced,
                    "k=1: imbalance {:?}",
                    report.validation.imbalance
                );
            } else {
                let npp = 1024.0;
                assert!(
                    (report.validation.imbalance.max_load as f64) <= 2.0 * npp,
                    "k={levels}: imbalance {:?}",
                    report.validation.imbalance
                );
            }
        }
    }

    #[test]
    fn ams_sorts_every_distribution() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(256);
        for levels in [1usize, 2, 3] {
            for d in Distribution::ALL {
                let report = run_ams(levels, &cfg, d);
                assert!(
                    report.succeeded(),
                    "k={levels}/{d:?}: {:?} {:?}",
                    report.crashed,
                    report.validation
                );
            }
        }
    }

    #[test]
    fn ams_survives_all_to_one_skew() {
        // the Fig. 2c regime of the base paper: fan-in min(p, n/p) ≫ k.
        // Tie-breaking spreads the skewed keys over the splitter range and
        // the 1-factor rounds deliver the resulting h-relation with at
        // most one receive per PE per round — the run must stay balanced.
        let cfg = RunConfig::default().with_p(256).with_n_per_pe(256);
        for levels in [1usize, 2] {
            let report = run_ams(levels, &cfg, Distribution::AllToOne);
            assert!(report.succeeded(), "k={levels}: {:?} {:?}", report.crashed, report.validation);
        }
    }

    #[test]
    fn ams_handles_sparse() {
        let cfg = RunConfig::default().with_p(32).with_sparsity(2);
        for levels in [1usize, 2, 3] {
            let report = run_ams(levels, &cfg, Distribution::Uniform);
            assert!(report.validation.ok(), "k={levels}: {:?}", report.validation);
        }
    }

    #[test]
    fn excess_levels_clamp_to_the_dimension() {
        // k = 3 on p = 4 (dim 2): the first two levels consume the cube,
        // the third finds dim-0 groups and recursion stops cleanly
        let cfg = RunConfig::default().with_p(4).with_n_per_pe(64);
        let report = run_ams(3, &cfg, Distribution::Staggered);
        assert!(report.succeeded(), "{:?} {:?}", report.crashed, report.validation);
    }
}
