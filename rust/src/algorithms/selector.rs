//! The paper's headline result as a component: *four algorithms cover the
//! entire range of possible input sizes* (§I, §VIII). The selector routes
//! a sort request to GatherM / RFIS / RQuick / RAMS by n/p.
//!
//! The crossover points live in a [`CrossoverTable`]. The default table is
//! the one the evaluation establishes on JUQUEEN (Fig. 1):
//!
//! * n/p ≤ 1/8      → GatherM  (very sparse: "sorts" while gathering)
//! * n/p < 4        → RFIS     (sparse / tiny)
//! * n/p ≤ 2^14     → RQuick   (small)
//! * otherwise      → RAMS     (large; level count by n/p)
//!
//! Thresholds are machine-ratio-dependent: for a different α/β, derive a
//! table with [`crate::experiments::tuning::crossover_table`] and hand it
//! to [`RobustSorter::with_table`] (the CLI: `rmps run --algo Robust
//! --tuned-crossovers`).

use crate::algorithms::{gather_merge, quick, rams, rfis, OutputShape, Sorter};
use crate::config::RunConfig;
use crate::elements::Elem;
use crate::localsort::SortBackend;
use crate::sim::Machine;

/// The selector's n/p crossover thresholds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CrossoverTable {
    /// n/p ≤ `gather_max` → GatherM.
    pub gather_max: f64,
    /// n/p < `rfis_max` → RFIS.
    pub rfis_max: f64,
    /// n/p ≤ `rquick_max` → RQuick; above → RAMS.
    pub rquick_max: f64,
}

impl CrossoverTable {
    /// The crossovers the paper's evaluation establishes on JUQUEEN
    /// (Fig. 1): 1/8, 4, and 2^14.
    pub const JUQUEEN: CrossoverTable =
        CrossoverTable { gather_max: 0.125, rfis_max: 4.0, rquick_max: 16384.0 };

    /// Which of the four robust algorithms this table picks for `n_over_p`.
    pub fn choose(&self, n_over_p: f64) -> &'static str {
        if n_over_p <= self.gather_max {
            "GatherM"
        } else if n_over_p < self.rfis_max {
            "RFIS"
        } else if n_over_p <= self.rquick_max {
            "RQuick"
        } else {
            "RAMS"
        }
    }
}

impl Default for CrossoverTable {
    fn default() -> Self {
        Self::JUQUEEN
    }
}

/// Which algorithm the selector picks for a given n/p under the paper's
/// JUQUEEN thresholds (shorthand for `CrossoverTable::JUQUEEN.choose`).
pub fn choose(n_over_p: f64) -> &'static str {
    CrossoverTable::JUQUEEN.choose(n_over_p)
}

/// Selector dispatch under the paper's JUQUEEN table.
pub fn sort(
    mach: &mut Machine,
    data: &mut Vec<Vec<Elem>>,
    cfg: &RunConfig,
    backend: &mut dyn SortBackend,
) -> OutputShape {
    sort_with_table(mach, data, cfg, backend, &CrossoverTable::JUQUEEN)
}

/// Selector dispatch under an explicit crossover table.
pub fn sort_with_table(
    mach: &mut Machine,
    data: &mut Vec<Vec<Elem>>,
    cfg: &RunConfig,
    backend: &mut dyn SortBackend,
    table: &CrossoverTable,
) -> OutputShape {
    let n: usize = data.iter().map(Vec::len).sum();
    let npp = n as f64 / cfg.p as f64;
    match table.choose(npp) {
        "GatherM" => {
            gather_merge::sort(mach, data, cfg, backend);
            OutputShape::RootOnly
        }
        "RFIS" => {
            rfis::sort(mach, data, cfg, backend);
            OutputShape::Balanced
        }
        "RQuick" => {
            quick::sort(mach, data, cfg, backend, &quick::QuickConfig::robust());
            OutputShape::Balanced
        }
        _ => {
            rams::sort(mach, data, cfg, backend, &rams::AmsConfig::robust(cfg));
            OutputShape::Balanced
        }
    }
}

/// [`Sorter`]: Robust — the composed headline algorithm, routing by n/p
/// through its [`CrossoverTable`] (paper table by default).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RobustSorter {
    pub table: CrossoverTable,
}

impl RobustSorter {
    /// The selector with the paper's JUQUEEN crossovers.
    pub fn new() -> Self {
        Self { table: CrossoverTable::JUQUEEN }
    }

    /// The selector with machine-specific crossovers (e.g. from
    /// [`crate::experiments::tuning::crossover_table`]).
    pub fn with_table(table: CrossoverTable) -> Self {
        Self { table }
    }
}

impl Sorter for RobustSorter {
    fn name(&self) -> &'static str {
        "Robust"
    }

    /// The §II contract for dense inputs; a sparse run hands off to
    /// GatherM and *returns* [`OutputShape::RootOnly`] from
    /// [`Sorter::sort`].
    fn output_shape(&self) -> OutputShape {
        OutputShape::Balanced
    }

    fn is_robust(&self) -> bool {
        true
    }

    fn sort(
        &self,
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) -> OutputShape {
        sort_with_table(mach, data, cfg, backend, &self.table)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, Algorithm};
    use crate::input::{generate, Distribution};

    #[test]
    fn choose_thresholds() {
        assert_eq!(choose(0.01), "GatherM");
        assert_eq!(choose(0.5), "RFIS");
        assert_eq!(choose(100.0), "RQuick");
        assert_eq!(choose(100_000.0), "RAMS");
    }

    /// The exact crossover points the module docs promise (Fig. 1): each
    /// boundary value lands on the documented side.
    #[test]
    fn choose_crossover_boundaries() {
        // n/p ≤ 1/8 → GatherM; just above → RFIS
        assert_eq!(choose(0.125), "GatherM");
        assert_eq!(choose(0.126), "RFIS");
        // n/p < 4 → RFIS; exactly 4 → RQuick
        assert_eq!(choose(3.999), "RFIS");
        assert_eq!(choose(4.0), "RQuick");
        // n/p ≤ 2^14 → RQuick; above → RAMS
        assert_eq!(choose((1 << 14) as f64), "RQuick");
        assert_eq!(choose((1 << 14) as f64 + 1.0), "RAMS");
    }

    /// A custom table really moves the crossovers.
    #[test]
    fn custom_table_shifts_crossovers() {
        let t = CrossoverTable { gather_max: 1.0, rfis_max: 32.0, rquick_max: 256.0 };
        assert_eq!(t.choose(1.0), "GatherM");
        assert_eq!(t.choose(8.0), "RFIS");
        assert_eq!(t.choose(256.0), "RQuick");
        assert_eq!(t.choose(257.0), "RAMS");
        // and the sorter built on it still sorts correctly
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(64);
        let input = generate(&cfg, Distribution::Staggered);
        let mut runner = crate::algorithms::Runner::new(cfg.clone());
        let r = runner.run(&RobustSorter::with_table(t), input);
        assert!(r.succeeded(), "{:?}", r.validation);
        assert_eq!(r.output_shape, OutputShape::Balanced);
    }

    /// `Algorithm::Robust` really dispatches on n/p: the chosen algorithm's
    /// footprint shows. Sparse picks GatherM (root-only output shape); the
    /// n = p point picks RFIS (balanced); both sort correctly.
    #[test]
    fn robust_dispatch_follows_choose() {
        // n/p = 1/16 ≤ 1/8 → GatherM leaves everything on PE 0
        let cfg = RunConfig::default().with_p(32).with_sparsity(16);
        assert_eq!(choose(cfg.n_over_p()), "GatherM");
        let r = run(Algorithm::Robust, &cfg, generate(&cfg, Distribution::Uniform));
        assert_eq!(r.output_shape, OutputShape::RootOnly);
        assert!(r.validation.ok(), "{:?}", r.validation);
        // n/p = 1 < 4 → RFIS: balanced output shape
        let cfg = RunConfig::default().with_p(32).with_n_per_pe(1);
        assert_eq!(choose(cfg.n_over_p()), "RFIS");
        let r = run(Algorithm::Robust, &cfg, generate(&cfg, Distribution::Uniform));
        assert_eq!(r.output_shape, OutputShape::Balanced);
        assert!(r.succeeded(), "{:?}", r.validation);
    }

    #[test]
    fn selector_sorts_across_the_size_spectrum() {
        // sparse → GatherM
        let cfg = RunConfig::default().with_p(64).with_sparsity(16);
        let r = run(Algorithm::Robust, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(r.validation.ok(), "sparse: {:?}", r.validation);
        assert_eq!(r.output_shape, OutputShape::RootOnly);
        // tiny → RFIS
        let cfg = RunConfig::default().with_p(64).with_n_per_pe(2);
        let r = run(Algorithm::Robust, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(r.succeeded(), "tiny: {:?}", r.validation);
        assert_eq!(r.output_shape, OutputShape::Balanced);
        // small → RQuick
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(128);
        let r = run(Algorithm::Robust, &cfg, generate(&cfg, Distribution::Staggered));
        assert!(r.succeeded(), "small: {:?}", r.validation);
        // large → RAMS
        let cfg = RunConfig::default().with_p(8).with_n_per_pe(1 << 15);
        let r = run(Algorithm::Robust, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(r.succeeded(), "large: {:?}", r.validation);
    }

    #[test]
    fn selector_is_robust_on_hard_instances() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(64);
        for d in [Distribution::Zero, Distribution::DeterDupl, Distribution::Mirrored] {
            let r = run(Algorithm::Robust, &cfg, generate(&cfg, d));
            assert!(r.succeeded(), "{d:?}: {:?}", r.validation);
        }
    }
}
