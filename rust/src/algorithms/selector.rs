//! The paper's headline result as a component: *four algorithms cover the
//! entire range of possible input sizes* (§I, §VIII). The selector routes
//! a sort request to GatherM / RFIS / RQuick / RAMS by n/p, with the
//! thresholds the evaluation establishes (Fig. 1):
//!
//! * n/p ≤ 1/8      → GatherM  (very sparse: "sorts" while gathering)
//! * n/p < 4        → RFIS     (sparse / tiny)
//! * n/p ≤ 2^14     → RQuick   (small)
//! * otherwise      → RAMS     (large; level count by n/p)
//!
//! Thresholds are machine-ratio-dependent; `-- tuning` regenerates them.

use crate::algorithms::{gather_merge, quick, rams, rfis, OutputShape};
use crate::config::RunConfig;
use crate::elements::Elem;
use crate::localsort::SortBackend;
use crate::sim::Machine;

/// Which algorithm the selector picks for a given n/p.
pub fn choose(n_over_p: f64) -> &'static str {
    if n_over_p <= 0.125 {
        "GatherM"
    } else if n_over_p < 4.0 {
        "RFIS"
    } else if n_over_p <= (1 << 14) as f64 {
        "RQuick"
    } else {
        "RAMS"
    }
}

pub fn sort(
    mach: &mut Machine,
    data: &mut Vec<Vec<Elem>>,
    cfg: &RunConfig,
    backend: &mut dyn SortBackend,
) -> OutputShape {
    let n: usize = data.iter().map(Vec::len).sum();
    let npp = n as f64 / cfg.p as f64;
    match choose(npp) {
        "GatherM" => {
            gather_merge::sort(mach, data, cfg, backend);
            OutputShape::RootOnly
        }
        "RFIS" => {
            rfis::sort(mach, data, cfg, backend);
            OutputShape::Balanced
        }
        "RQuick" => {
            quick::sort(mach, data, cfg, backend, &quick::QuickConfig::robust());
            OutputShape::Balanced
        }
        _ => {
            rams::sort(mach, data, cfg, backend, &rams::AmsConfig::robust(cfg));
            OutputShape::Balanced
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, Algorithm};
    use crate::input::{generate, Distribution};

    #[test]
    fn choose_thresholds() {
        assert_eq!(choose(0.01), "GatherM");
        assert_eq!(choose(0.5), "RFIS");
        assert_eq!(choose(100.0), "RQuick");
        assert_eq!(choose(100_000.0), "RAMS");
    }

    /// The exact crossover points the module docs promise (Fig. 1): each
    /// boundary value lands on the documented side.
    #[test]
    fn choose_crossover_boundaries() {
        // n/p ≤ 1/8 → GatherM; just above → RFIS
        assert_eq!(choose(0.125), "GatherM");
        assert_eq!(choose(0.126), "RFIS");
        // n/p < 4 → RFIS; exactly 4 → RQuick
        assert_eq!(choose(3.999), "RFIS");
        assert_eq!(choose(4.0), "RQuick");
        // n/p ≤ 2^14 → RQuick; above → RAMS
        assert_eq!(choose((1 << 14) as f64), "RQuick");
        assert_eq!(choose((1 << 14) as f64 + 1.0), "RAMS");
    }

    /// `Algorithm::Robust` really dispatches on n/p: the chosen algorithm's
    /// footprint shows. Sparse picks GatherM (root-only output shape); the
    /// n = p point picks RFIS (balanced); both sort correctly.
    #[test]
    fn robust_dispatch_follows_choose() {
        // n/p = 1/16 ≤ 1/8 → GatherM leaves everything on PE 0
        let cfg = RunConfig::default().with_p(32).with_sparsity(16);
        assert_eq!(choose(cfg.n_over_p()), "GatherM");
        let r = run(Algorithm::Robust, &cfg, generate(&cfg, Distribution::Uniform));
        assert_eq!(r.output_shape, OutputShape::RootOnly);
        assert!(r.validation.ok(), "{:?}", r.validation);
        // n/p = 1 < 4 → RFIS: balanced output shape
        let cfg = RunConfig::default().with_p(32).with_n_per_pe(1);
        assert_eq!(choose(cfg.n_over_p()), "RFIS");
        let r = run(Algorithm::Robust, &cfg, generate(&cfg, Distribution::Uniform));
        assert_eq!(r.output_shape, OutputShape::Balanced);
        assert!(r.succeeded(), "{:?}", r.validation);
    }

    #[test]
    fn selector_sorts_across_the_size_spectrum() {
        // sparse → GatherM
        let cfg = RunConfig::default().with_p(64).with_sparsity(16);
        let r = run(Algorithm::Robust, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(r.validation.ok(), "sparse: {:?}", r.validation);
        assert_eq!(r.output_shape, OutputShape::RootOnly);
        // tiny → RFIS
        let cfg = RunConfig::default().with_p(64).with_n_per_pe(2);
        let r = run(Algorithm::Robust, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(r.succeeded(), "tiny: {:?}", r.validation);
        assert_eq!(r.output_shape, OutputShape::Balanced);
        // small → RQuick
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(128);
        let r = run(Algorithm::Robust, &cfg, generate(&cfg, Distribution::Staggered));
        assert!(r.succeeded(), "small: {:?}", r.validation);
        // large → RAMS
        let cfg = RunConfig::default().with_p(8).with_n_per_pe(1 << 15);
        let r = run(Algorithm::Robust, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(r.succeeded(), "large: {:?}", r.validation);
    }

    #[test]
    fn selector_is_robust_on_hard_instances() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(64);
        for d in [Distribution::Zero, Distribution::DeterDupl, Distribution::Mirrored] {
            let r = run(Algorithm::Robust, &cfg, generate(&cfg, d));
            assert!(r.succeeded(), "{d:?}: {:?}", r.validation);
        }
    }
}
