//! Builder-style run harness: [`Runner`] owns the simulated [`Machine`],
//! reuses its scratch across batched runs, and makes the two Θ(n) side
//! costs of the legacy free functions opt-out — the reference clone for
//! validation ([`Runner::validate`]) and the `RunReport.output` payload
//! ([`Runner::keep_output`]).
//!
//! The legacy [`super::run`]/[`super::run_with_backend`] free functions
//! are thin shims over the same [`execute`] core, so both paths produce
//! byte-identical reports (asserted for all 15 algorithms in
//! `rust/tests/runner_equivalence.rs`).

use crate::config::RunConfig;
use crate::elements::Elem;
use crate::localsort::{default_backend, SortBackend};
use crate::sim::Machine;
use crate::verify::{validate, validate_replicated, Validation};

use super::{Algorithm, OutputShape, RunReport, Sorter};

/// Reusable run harness for one machine configuration.
///
/// ```no_run
/// use rmps::prelude::*;
///
/// let cfg = RunConfig::default().with_p(1 << 6).with_n_per_pe(1 << 8);
/// let mut runner = Runner::new(cfg.clone())
///     .validate(false)      // skip the Θ(n) reference clone
///     .keep_output(false);  // drop the sorted payload from the report
/// let input = rmps::input::generate(&cfg, Distribution::Uniform);
/// let report = runner.run_algorithm(Algorithm::RQuick, input);
/// assert!(report.crashed.is_none());
/// ```
pub struct Runner {
    cfg: RunConfig,
    backend: Box<dyn SortBackend>,
    validate: bool,
    keep_output: bool,
    mach: Machine,
    /// `p` of the previous run, if any — same `p` means `Machine::reset`
    /// kept every per-PE allocation (a machine-reuse hit); a different `p`
    /// re-dimensions the machine (a fresh build).
    last_p: Option<usize>,
    reuse_hits: u64,
    fresh_builds: u64,
}

/// Host-side metadata of one [`Runner::run_with_meta`] call — the
/// per-run breakdown batched callers (the serve front-end, the fig
/// experiment cells) aggregate instead of discarding.
#[derive(Clone, Copy, Debug)]
pub struct RunMeta {
    /// Host wallclock of the simulation window, ms (same value as the
    /// report's `wall_ms`; duplicated here so meta survives after the
    /// report is consumed).
    pub wall_ms: f64,
    /// Whether this run reused the machine's per-PE state from the
    /// previous run (same `p` — scratch, route buffers, and data-plane
    /// pools all survive `reset`) or had to build it fresh (first run on
    /// this runner, or a `p` switch re-dimensioned the machine).
    pub machine_reused: bool,
    /// Host-side superstep settlements of this run
    /// ([`Machine::host_rounds`]): the denominator for the giant-p bench's
    /// host-µs-per-superstep metric. Diagnostic only — never part of the
    /// bit-compared [`RunReport`].
    pub host_rounds: u64,
}

impl Runner {
    /// A runner for `cfg` with the process-default local-sort backend
    /// ([`crate::localsort::default_backend`]: pdqsort unless
    /// `--sort-backend` / `RMPS_SORT_BACKEND` picked another — reports
    /// are bit-identical either way), validation on, and output retention
    /// on — the legacy `run` defaults.
    pub fn new(cfg: RunConfig) -> Self {
        let mach = Machine::new(cfg.p, cfg.cost);
        Self {
            cfg,
            backend: default_backend(),
            validate: true,
            keep_output: true,
            mach,
            last_p: None,
            reuse_hits: 0,
            fresh_builds: 0,
        }
    }

    /// Override the intra-run PE-task parallelism of the owned machine
    /// (see [`Machine::set_pe_jobs`]). Host scheduling only — reports are
    /// bit-identical for every value; the default comes from
    /// `--pe-jobs` / `RMPS_PE_JOBS` / the host core count
    /// ([`crate::exec::default_pe_jobs`]).
    pub fn pe_jobs(mut self, jobs: usize) -> Self {
        self.mach.set_pe_jobs(jobs);
        self
    }

    /// Override the owned machine's inline-vs-pooled work threshold for
    /// PE-task rounds (see [`Machine::set_par_min_work`]; `0` restores
    /// the process default from `--par-min-work` / `RMPS_PAR_MIN_WORK` /
    /// [`crate::sim::PAR_MIN_WORK`]). Host scheduling only — reports are
    /// bit-identical for every value, from `1` (every round pooled) to
    /// `usize::MAX` (every round inline).
    pub fn par_min_work(mut self, threshold: usize) -> Self {
        self.mach.set_par_min_work(threshold);
        self
    }

    /// Replace the node-local sort backend (e.g. the PJRT `XlaSort` from
    /// [`crate::runtime`], available with the `xla` cargo feature).
    pub fn backend(mut self, backend: Box<dyn SortBackend>) -> Self {
        self.backend = backend;
        self
    }

    /// Toggle output validation. `false` skips the Θ(n) reference clone
    /// entirely (halving peak memory); the report's `validation` is then
    /// `Validation::default()` (all checks false) and `is_globally_sorted`
    /// is false — "not validated", not "invalid".
    pub fn validate(mut self, validate: bool) -> Self {
        self.validate = validate;
        self
    }

    /// Toggle retention of the sorted per-PE output in `RunReport.output`.
    /// `false` drops the Θ(n) payload (Θ(n·p) for replicated shapes) —
    /// what figure sweeps want, since no figure reads it.
    pub fn keep_output(mut self, keep: bool) -> Self {
        self.keep_output = keep;
        self
    }

    /// The configuration the next [`Runner::run`] will use.
    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Swap the run configuration (e.g. a new seed between repetitions).
    /// The owned machine is re-dimensioned on the next run; its scratch
    /// allocations are kept.
    pub fn set_config(&mut self, cfg: RunConfig) {
        self.cfg = cfg;
    }

    /// Run `sorter` on `input` under the current configuration. The owned
    /// [`Machine`] is reset — not reallocated — so batched runs reuse its
    /// route scratch and superstep buffers.
    pub fn run(&mut self, sorter: &dyn Sorter, input: Vec<Vec<Elem>>) -> RunReport {
        self.run_with_meta(sorter, input).0
    }

    /// [`Runner::run`] plus the host-side [`RunMeta`] breakdown: the
    /// run's wallclock and whether the machine was a reuse hit or a fresh
    /// build. The report itself is bit-identical to [`Runner::run`] —
    /// meta is observation, not behavior.
    pub fn run_with_meta(
        &mut self,
        sorter: &dyn Sorter,
        input: Vec<Vec<Elem>>,
    ) -> (RunReport, RunMeta) {
        let machine_reused = self.last_p == Some(self.cfg.p);
        self.last_p = Some(self.cfg.p);
        if machine_reused {
            self.reuse_hits += 1;
        } else {
            self.fresh_builds += 1;
        }
        self.mach.reset(self.cfg.p, self.cfg.cost);
        self.mach.mem_cap_elems = self.cfg.mem_cap_elems();
        let report = execute(
            &mut self.mach,
            &self.cfg,
            sorter,
            self.backend.as_mut(),
            input,
            self.validate,
            self.keep_output,
        );
        let meta = RunMeta {
            wall_ms: report.wall_ms,
            machine_reused,
            host_rounds: self.mach.host_rounds(),
        };
        (report, meta)
    }

    /// Cumulative `(machine-reuse hits, fresh builds)` over this runner's
    /// lifetime — the machine-reuse economy of a batch at a glance
    /// (`hits + fresh == runs`).
    pub fn reuse_counters(&self) -> (u64, u64) {
        (self.reuse_hits, self.fresh_builds)
    }

    /// [`Runner::run`] addressed by the legacy enum tag.
    pub fn run_algorithm(&mut self, alg: Algorithm, input: Vec<Vec<Elem>>) -> RunReport {
        self.run(alg.sorter().as_ref(), input)
    }

    /// Batch entry point: run `sorter` once per `(config, input)` pair,
    /// reusing the machine throughout. The iterator is consumed lazily, so
    /// callers can generate each input on demand instead of materializing
    /// the whole batch. (Callers that must stop mid-batch — e.g. the
    /// experiment cells, which short-circuit on a crash — loop over
    /// [`Runner::run`] themselves; the two are equivalent per item.)
    pub fn run_many(
        &mut self,
        sorter: &dyn Sorter,
        batch: impl IntoIterator<Item = (RunConfig, Vec<Vec<Elem>>)>,
    ) -> Vec<RunReport> {
        self.run_many_with_meta(sorter, batch).into_iter().map(|(r, _)| r).collect()
    }

    /// [`Runner::run_many`] surfacing the per-run [`RunMeta`] instead of
    /// discarding it: each item reports its wallclock and whether it hit
    /// the reused machine (same `p` as the previous item) or forced a
    /// fresh build — what [`crate::serve::Stats`] aggregates into the
    /// service's machine-reuse economy.
    pub fn run_many_with_meta(
        &mut self,
        sorter: &dyn Sorter,
        batch: impl IntoIterator<Item = (RunConfig, Vec<Vec<Elem>>)>,
    ) -> Vec<(RunReport, RunMeta)> {
        batch
            .into_iter()
            .map(|(cfg, input)| {
                self.set_config(cfg);
                self.run_with_meta(sorter, input)
            })
            .collect()
    }
}

/// The shared run core behind [`Runner`] and the legacy shims: time the
/// simulation (and only the simulation — the reference clone for
/// validation happens before the wallclock window opens), then validate
/// according to the output shape the sorter reports.
pub(super) fn execute(
    mach: &mut Machine,
    cfg: &RunConfig,
    sorter: &dyn Sorter,
    backend: &mut dyn SortBackend,
    input: Vec<Vec<Elem>>,
    validate_output: bool,
    keep_output: bool,
) -> RunReport {
    let reference = if validate_output { Some(input.clone()) } else { None };
    let mut data = input;
    let start = std::time::Instant::now();

    let shape = sorter.sort(mach, &mut data, cfg, backend);

    let wall_ms = start.elapsed().as_secs_f64() * 1e3;
    let crashed = mach.crash().map(|c| c.to_string());

    let validation = match &reference {
        None => Validation::default(),
        Some(reference) => match shape {
            OutputShape::Balanced => validate(reference, &data, cfg.epsilon),
            OutputShape::RootOnly => {
                // everything must land on PE 0, sorted; balance is broken
                // by construction
                let mut proj = vec![Vec::new(); cfg.p];
                proj[0] = data[0].clone();
                let mut v = validate(reference, &proj, f64::INFINITY);
                v.balanced = false;
                v
            }
            OutputShape::Replicated => validate_replicated(reference, &data),
        },
    };

    RunReport {
        algorithm: sorter.name(),
        time: mach.time(),
        stats: mach.stats,
        is_globally_sorted: validation.globally_sorted && crashed.is_none(),
        validation,
        output_shape: shape,
        crashed,
        wall_ms,
        output: if keep_output { data } else { Vec::new() },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::input::{generate, Distribution};

    #[test]
    fn opt_outs_change_payloads_not_simulation() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(32);
        let input = generate(&cfg, Distribution::Staggered);

        let full = Runner::new(cfg.clone()).run_algorithm(Algorithm::RQuick, input.clone());
        let mut lean_runner =
            Runner::new(cfg.clone()).validate(false).keep_output(false);
        let lean = lean_runner.run_algorithm(Algorithm::RQuick, input);

        assert_eq!(full.time.to_bits(), lean.time.to_bits());
        assert_eq!(full.stats.messages, lean.stats.messages);
        assert_eq!(full.stats.words, lean.stats.words);
        assert!(full.validation.ok() && full.is_globally_sorted);
        assert!(!lean.validation.ok() && !lean.is_globally_sorted, "unvalidated, not invalid");
        assert!(lean.output.is_empty() && !full.output.is_empty());
    }

    #[test]
    fn machine_is_reused_across_runs() {
        let cfg = RunConfig::default().with_p(8).with_n_per_pe(16);
        let mut runner = Runner::new(cfg.clone());
        let a = runner.run_algorithm(Algorithm::Rfis, generate(&cfg, Distribution::Uniform));
        let b = runner.run_algorithm(Algorithm::Rfis, generate(&cfg, Distribution::Uniform));
        assert_eq!(a.time.to_bits(), b.time.to_bits(), "reset must be complete");
        assert_eq!(a.stats.messages, b.stats.messages);
        assert_eq!(a.output, b.output);
    }

    /// Meta is observation only: the first run on a runner is a fresh
    /// build, same-`p` successors are reuse hits, a `p` switch is fresh
    /// again — and the counters tally exactly runs.
    #[test]
    fn run_meta_tracks_machine_reuse() {
        let cfg = RunConfig::default().with_p(8).with_n_per_pe(16);
        let mut runner = Runner::new(cfg.clone());
        let input = generate(&cfg, Distribution::Uniform);
        let (a, meta) = runner.run_with_meta(Algorithm::RQuick.sorter().as_ref(), input.clone());
        assert!(!meta.machine_reused, "first run builds fresh");
        assert!(meta.wall_ms >= 0.0);
        assert_eq!(meta.wall_ms.to_bits(), a.wall_ms.to_bits());
        assert!(meta.host_rounds > 0, "a sort settles at least one superstep");
        let (_, meta) = runner.run_with_meta(Algorithm::RQuick.sorter().as_ref(), input.clone());
        assert!(meta.machine_reused, "same p reuses the machine");
        let wide = cfg.clone().with_p(16);
        runner.set_config(wide.clone());
        let (_, meta) =
            runner.run_with_meta(Algorithm::RQuick.sorter().as_ref(), generate(&wide, Distribution::Uniform));
        assert!(!meta.machine_reused, "p switch re-dimensions");
        assert_eq!(runner.reuse_counters(), (1, 2));
    }

    /// run_many_with_meta: metas line up with reports and the plain
    /// run_many stays byte-identical to the metadata path.
    #[test]
    fn run_many_with_meta_surfaces_the_breakdown() {
        let base = RunConfig::default().with_p(8).with_n_per_pe(16);
        let batch: Vec<_> = [1u64, 2, 3]
            .iter()
            .map(|&s| {
                let cfg = base.clone().with_seed(s);
                let input = generate(&cfg, Distribution::Uniform);
                (cfg, input)
            })
            .collect();
        let mut runner = Runner::new(base.clone());
        let with_meta =
            runner.run_many_with_meta(Algorithm::RQuick.sorter().as_ref(), batch.clone());
        assert_eq!(with_meta.len(), 3);
        assert!(!with_meta[0].1.machine_reused);
        assert!(with_meta[1].1.machine_reused && with_meta[2].1.machine_reused);
        let mut plain_runner = Runner::new(base.clone());
        let plain = plain_runner.run_many(Algorithm::RQuick.sorter().as_ref(), batch);
        for ((r, m), p) in with_meta.iter().zip(&plain) {
            assert_eq!(r.time.to_bits(), p.time.to_bits());
            assert_eq!(r.output, p.output);
            assert_eq!(m.wall_ms.to_bits(), r.wall_ms.to_bits());
        }
    }

    #[test]
    fn run_many_swaps_configs_per_item() {
        let base = RunConfig::default().with_p(8).with_n_per_pe(16);
        let mut runner = Runner::new(base.clone());
        let batch: Vec<_> = [1u64, 2, 3]
            .iter()
            .map(|&s| {
                let cfg = base.clone().with_seed(s);
                let input = generate(&cfg, Distribution::Uniform);
                (cfg, input)
            })
            .collect();
        let reports = runner.run_many(Algorithm::RQuick.sorter().as_ref(), batch.clone());
        assert_eq!(reports.len(), 3);
        for ((cfg, input), got) in batch.into_iter().zip(&reports) {
            let fresh = super::super::run(Algorithm::RQuick, &cfg, input);
            assert_eq!(fresh.time.to_bits(), got.time.to_bits());
            assert_eq!(fresh.output, got.output);
        }
    }
}
