//! Single-level multiway mergesort with *exact* splitters ([4], [8], [10]
//! — the Table I "Multiway merges." row: latency ≥ p, volume ≥ n/p, and
//! perfect partitioning).
//!
//! Exact global rank-r splitters are found by distributed binary search on
//! the key domain: every boundary keeps a [lo, hi) key interval, and each
//! round a vector all-reduce of p−1 local counts halves all intervals at
//! once. This pays Θ(β·p·log K) on the wire — the reason the paper needs
//! `n = Ω(p² log p)` before this family is competitive — but delivers a
//! *perfectly* balanced output (ε = 0 up to rounding).
//!
//! Ties are broken on the full `(key, id)` order, so the exact selection
//! is robust against duplicates by construction.

use crate::config::RunConfig;
use crate::elements::{multiway_merge_into, Elem};
use crate::input::KEY_RANGE;
use crate::localsort::{sort_all, SortBackend};
use crate::sim::{allreduce_vec_u64, Cube, Machine, ParSpec};

use super::{OutputShape, Sorter};

/// 128-bit (key, id) point for the binary search domain: key·2^64 + id.
#[inline]
fn point(e: &Elem) -> u128 {
    ((e.key as u128) << 64) | e.id as u128
}

pub fn sort(
    mach: &mut Machine,
    data: &mut Vec<Vec<Elem>>,
    cfg: &RunConfig,
    backend: &mut dyn SortBackend,
) {
    let p = cfg.p;
    assert!(p.is_power_of_two());
    let pes = Cube::whole(p).pe_vec();
    let n: usize = data.iter().map(Vec::len).sum();
    if n == 0 {
        return;
    }

    sort_all(mach, data, backend);

    // --- exact splitter selection: p−1 simultaneous binary searches ----
    // boundary b must receive global rank r_b = ⌈(b+1)·n/p⌉ as its
    // exclusive upper rank; search over the (key, id) domain
    let nb = p - 1;
    let target: Vec<usize> = (0..nb).map(|b| ((b + 1) * n) / p).collect();
    let mut lo = vec![0u128; nb];
    let mut hi = vec![(KEY_RANGE as u128) << 64; nb];
    // log2 of the search domain: 32-bit keys ⊕ 64-bit ids
    let rounds = 96;
    let mut counts: Vec<Vec<u64>> = vec![vec![0; nb]; p];
    for _ in 0..rounds {
        if lo.iter().zip(&hi).all(|(l, h)| l + 1 >= *h) {
            break;
        }
        let mid: Vec<u128> = lo.iter().zip(&hi).map(|(l, h)| (l + h) / 2).collect();
        // local counts below each mid (binary searches on sorted runs) —
        // one PE task per member, reading its own run
        {
            let data_ref: &[Vec<Elem>] = data;
            mach.par_pes(0, ParSpec::work(n + p * nb), &mut counts, |ctx, cnt| {
                let local = &data_ref[ctx.pe()];
                for (b, &m) in mid.iter().enumerate() {
                    cnt[b] = local.partition_point(|e| point(e) < m) as u64;
                }
                ctx.work(cfg.cost.cmp * nb as f64 * (local.len().max(2) as f64).log2());
            });
        }
        allreduce_vec_u64(mach, &pes, &mut counts, |a, b| a + b);
        let total = &counts[0];
        for b in 0..nb {
            if (total[b] as usize) < target[b] {
                lo[b] = mid[b];
            } else {
                hi[b] = mid[b];
            }
        }
        // reset counts for the next round
        for c in counts.iter_mut() {
            for v in c.iter_mut() {
                *v = 0;
            }
        }
    }
    let splitters: Vec<u128> = hi;

    // --- perfect partition + direct delivery through the data plane ----
    // bucket building runs as one PE task per member; posting (pure
    // pointer moves, in the historical (pe, bucket) order) stays serial
    let outs: Vec<Vec<Vec<Elem>>> =
        mach.par_pes(0, ParSpec::work(n).bufs(p + 1), &mut *data, |ctx, slot| {
            let local = std::mem::take(slot);
            ctx.work_classify(local.len(), p);
            let mut buckets: Vec<Vec<Elem>> = (0..p).map(|_| ctx.take_buf()).collect();
            for &e in &local {
                let b = splitters.partition_point(|&s| s <= point(&e));
                buckets[b].push(e);
            }
            ctx.recycle_buf(local);
            buckets
        });
    let mut ex = mach.exchange();
    for (pe, buckets) in outs.into_iter().enumerate() {
        for (t, bucket) in buckets.into_iter().enumerate() {
            ex.post(pe, t, bucket);
        }
    }
    let inboxes = ex.deliver(mach);
    for &pe in &pes {
        mach.note_mem(pe, inboxes.total(pe), "alltoallv");
    }
    let total_recv: usize = pes.iter().map(|&pe| inboxes.total(pe)).sum();
    mach.par_pes(0, ParSpec::work(2 * total_recv).bufs(1), &mut *data, |ctx, slot| {
        let refs: Vec<&[Elem]> = inboxes.runs(ctx.pe()).iter().map(|(_, v)| v.as_slice()).collect();
        let mut merged = ctx.take_buf();
        multiway_merge_into(&refs, &mut merged, ctx.merge_scratch());
        ctx.work(cfg.cost.cmp * merged.len() as f64 * (p.max(2) as f64).log2());
        ctx.note_mem(merged.len(), "multiway mergesort receive");
        *slot = merged;
    });
    mach.recycle(inboxes);
}

/// [`Sorter`]: Mways — single-level multiway mergesort with exact
/// `(key, id)` splitters. Duplicate-safe by construction, but pays the
/// Θ(β·p·log K) splitter selection that keeps it uncompetitive below
/// n = Ω(p² log p).
#[derive(Clone, Copy, Debug, Default)]
pub struct MwaysSorter;

impl Sorter for MwaysSorter {
    fn name(&self) -> &'static str {
        "Mways"
    }

    fn output_shape(&self) -> OutputShape {
        OutputShape::Balanced
    }

    fn is_robust(&self) -> bool {
        true
    }

    fn sort(
        &self,
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) -> OutputShape {
        self::sort(mach, data, cfg, backend);
        OutputShape::Balanced
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, Algorithm};
    use crate::input::{generate, Distribution};

    #[test]
    fn mways_sorts_with_perfect_balance() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(256);
        for d in [Distribution::Uniform, Distribution::Staggered] {
            let report = run(Algorithm::Mways, &cfg, generate(&cfg, d));
            assert!(report.succeeded(), "{d:?}: {:?}", report.validation);
            // exact splitters: at most ⌈n/p⌉ per PE
            assert!(
                report.validation.imbalance.max_load <= 256,
                "{d:?}: {:?}",
                report.validation.imbalance
            );
        }
    }

    #[test]
    fn mways_perfectly_balances_duplicates() {
        // exact selection on (key, id): even all-equal keys split perfectly
        let cfg = RunConfig::default().with_p(8).with_n_per_pe(64);
        let report = run(Algorithm::Mways, &cfg, generate(&cfg, Distribution::Zero));
        assert!(report.succeeded(), "{:?}", report.validation);
        assert_eq!(report.validation.imbalance.max_load, 64);
        assert_eq!(report.validation.imbalance.min_load, 64);
    }

    #[test]
    fn mways_pays_beta_p_for_selection() {
        // the Table I ≥p row: words moved for splitter selection grow ~p·log K
        let words_at = |p: usize| {
            let cfg = RunConfig::default().with_p(p).with_n_per_pe(32);
            let r = run(Algorithm::Mways, &cfg, generate(&cfg, Distribution::Uniform));
            assert!(r.succeeded());
            r.stats.words as f64 / (p as f64)
        };
        let small = words_at(16);
        let large = words_at(64);
        // per-PE words grow ~linearly in p (vector allreduce of p−1 counts)
        assert!(large > 2.5 * small, "per-PE words: {small} → {large}");
    }
}
