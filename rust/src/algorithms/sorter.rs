//! The open sorter interface: the [`Sorter`] trait every algorithm
//! implements, plus the name-keyed **registry** the CLI, experiments, and
//! external crates share.
//!
//! Algorithms are first-class values here: a sorter carries its own
//! configuration as struct fields (`RQuickSorter::robust()` vs
//! `RQuickSorter::nonrobust()` are two values of one type) and describes
//! itself through metadata (`name`, `output_shape`, `is_robust`,
//! `valid_range`). The built-in registry yields the 15 sorters of the
//! paper's evaluation plus the successor paper's `AMS-1`/`AMS-2`/`AMS-3`
//! family; [`register`] adds external implementations so they
//! appear in CLI parsing ([`find_sorter`]) and experiment enumeration
//! (e.g. [`crate::experiments::fig1::run_with`]) without touching any
//! dispatch table in this crate.

use std::sync::{Arc, OnceLock, RwLock};

use crate::config::RunConfig;
use crate::elements::Elem;
use crate::localsort::SortBackend;
use crate::sim::Machine;

use super::all_gather_merge::AllGatherMSorter;
use super::ams::AmsSorter;
use super::bitonic::BitonicSorter;
use super::gather_merge::GatherMSorter;
use super::hyksort::HykSorter;
use super::mergesort::MwaysSorter;
use super::minisort::MinisortSorter;
use super::quick::RQuickSorter;
use super::rams::RamsSorter;
use super::rfis::RfisSorter;
use super::selector::RobustSorter;
use super::ssort::SSortSorter;
use super::{Algorithm, OutputShape};

/// A massively parallel sorting algorithm as a first-class value.
///
/// Implementations are immutable (all per-run state lives in the
/// [`Machine`] and the data), so one sorter value can be shared across
/// threads and reused for any number of runs — the experiment driver runs
/// `Arc<dyn Sorter>`s on its worker pool.
///
/// Run a sorter through [`super::Runner`] (or the legacy
/// [`super::run`]/[`super::run_with_backend`] shims); call
/// [`Sorter::sort`] directly only when driving a [`Machine`] by hand.
pub trait Sorter: Send + Sync {
    /// Display/CLI name. Must be unique in the registry after
    /// [`normalize`] (case and `-`/`_` separators are ignored on lookup).
    fn name(&self) -> &'static str;

    /// The output shape the sorter's contract promises for dense inputs.
    /// [`Sorter::sort`] returns the *actual* shape of a run, which may
    /// differ for composite sorters (the robust selector hands sparse
    /// inputs to GatherM and reports [`OutputShape::RootOnly`]).
    fn output_shape(&self) -> OutputShape;

    /// Whether the sorter survives the paper's adversarial instances
    /// (duplicates, skew, AllToOne) inside its valid range — §VII-B's
    /// robust/nonrobust split.
    fn is_robust(&self) -> bool;

    /// Whether the sorter accepts inputs of `n_per_pe` elements per PE on
    /// `p` PEs at all. Outside this range a run reports a crash instead of
    /// sorting (Bitonic on sparse inputs, Minisort when n ≠ p). Advisory
    /// metadata — nothing enforces it before running.
    fn valid_range(&self, _n_per_pe: f64, _p: usize) -> bool {
        true
    }

    /// Sort `data` (indexed by global PE) on the virtual machine, charging
    /// all costs to `mach`, and report the shape the output was left in.
    fn sort(
        &self,
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) -> OutputShape;
}

impl Algorithm {
    /// The sorter value behind this legacy enum tag.
    ///
    /// This bridge (and the enum itself) exists for the paper's fixed
    /// evaluation set; new algorithms implement [`Sorter`] and go through
    /// [`register`] / [`find_sorter`] instead of gaining an enum variant.
    pub fn sorter(self) -> Arc<dyn Sorter> {
        match self {
            Algorithm::GatherM => Arc::new(GatherMSorter),
            Algorithm::AllGatherM => Arc::new(AllGatherMSorter),
            Algorithm::Rfis => Arc::new(RfisSorter),
            Algorithm::RQuick => Arc::new(RQuickSorter::robust()),
            Algorithm::NtbQuick => Arc::new(RQuickSorter::nonrobust()),
            Algorithm::Bitonic => Arc::new(BitonicSorter),
            Algorithm::Rams => Arc::new(RamsSorter::robust()),
            Algorithm::NtbAms => Arc::new(RamsSorter::ntb()),
            Algorithm::NdmaAms => Arc::new(RamsSorter::ndma()),
            Algorithm::HykSort => Arc::new(HykSorter::default()),
            Algorithm::SSort => Arc::new(SSortSorter::charged()),
            Algorithm::NsSSort => Arc::new(SSortSorter::free_splitters()),
            Algorithm::Minisort => Arc::new(MinisortSorter),
            Algorithm::Mways => Arc::new(MwaysSorter),
            Algorithm::Robust => Arc::new(RobustSorter::default()),
        }
    }
}

/// Registry lookup key: ASCII-lowercased with `-`/`_` stripped, so
/// `ntb_quick`, `NTB-Quick`, and `ntbquick` all address the same sorter.
pub fn normalize(name: &str) -> String {
    name.to_ascii_lowercase().replace(['-', '_'], "")
}

/// Externally registered sorters (process-global, append-only).
fn extras() -> &'static RwLock<Vec<Arc<dyn Sorter>>> {
    static EXTRAS: OnceLock<RwLock<Vec<Arc<dyn Sorter>>>> = OnceLock::new();
    EXTRAS.get_or_init(|| RwLock::new(Vec::new()))
}

/// The built-in sorters: the 15 of the paper's evaluation in
/// [`Algorithm::ALL`] order, followed by the successor paper's multi-level
/// AMS family (`AMS-1`/`AMS-2`/`AMS-3` — [`AmsSorter::with_levels`] for
/// k ∈ {1, 2, 3}, which has no legacy enum tag). Built once and cached —
/// repeated registry lookups clone `Arc`s, not sorters.
pub fn builtin_sorters() -> Vec<Arc<dyn Sorter>> {
    static BUILTINS: OnceLock<Vec<Arc<dyn Sorter>>> = OnceLock::new();
    BUILTINS
        .get_or_init(|| {
            let mut all: Vec<Arc<dyn Sorter>> =
                Algorithm::ALL.iter().map(|a| a.sorter()).collect();
            all.extend((1..=3).map(|k| Arc::new(AmsSorter::with_levels(k)) as Arc<dyn Sorter>));
            all
        })
        .clone()
}

/// Every known sorter: the built-ins followed by everything added with
/// [`register`], in registration order.
pub fn registry() -> Vec<Arc<dyn Sorter>> {
    let mut all = builtin_sorters();
    all.extend(extras().read().unwrap().iter().cloned());
    all
}

/// A [`register`] rejection: the sorter's normalized name is already taken.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RegisterError {
    pub name: String,
}

impl std::fmt::Display for RegisterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "a sorter named {:?} is already registered", self.name)
    }
}

impl std::error::Error for RegisterError {}

/// Add an external sorter to the process-global registry, making it
/// visible to [`registry`] enumeration and [`find_sorter`] (which the CLI
/// `--algo` flag resolves through). Fails if the normalized name collides
/// with a built-in or a previously registered sorter.
pub fn register(sorter: Arc<dyn Sorter>) -> Result<(), RegisterError> {
    let key = normalize(sorter.name());
    let mut extras = extras().write().unwrap();
    let taken = builtin_sorters()
        .iter()
        .chain(extras.iter())
        .any(|s| normalize(s.name()) == key);
    if taken {
        return Err(RegisterError { name: sorter.name().to_string() });
    }
    extras.push(sorter);
    Ok(())
}

/// Case- and separator-insensitive name lookup over the whole registry
/// (built-ins plus [`register`]ed sorters).
pub fn find_sorter(name: &str) -> Option<Arc<dyn Sorter>> {
    let key = normalize(name);
    registry().into_iter().find(|s| normalize(s.name()) == key)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every enum tag's sorter reports the same name the enum does, so the
    /// two addressing schemes (enum, registry name) can never diverge.
    #[test]
    fn builtin_sorter_names_match_enum() {
        for a in Algorithm::ALL {
            assert_eq!(a.sorter().name(), a.name(), "{a:?}");
        }
    }

    #[test]
    fn builtins_cover_the_enum_plus_the_ams_family() {
        assert_eq!(builtin_sorters().len(), Algorithm::ALL.len() + 3);
        for k in 1..=3 {
            let s = find_sorter(&format!("ams{k}")).unwrap_or_else(|| panic!("AMS-{k}"));
            assert_eq!(s.name(), format!("AMS-{k}"));
            assert!(s.is_robust());
            assert_eq!(s.output_shape(), OutputShape::Balanced);
        }
        // the family has no legacy enum tag — the registry is its home
        assert!(Algorithm::parse("AMS-2").is_none());
    }

    #[test]
    fn find_sorter_is_separator_insensitive() {
        assert_eq!(find_sorter("ntb_quick").unwrap().name(), "NTB-Quick");
        assert_eq!(find_sorter("RQUICK").unwrap().name(), "RQuick");
        assert!(find_sorter("nonexistent").is_none());
    }

    /// Metadata spot checks: the §VII-B robust/nonrobust split and the
    /// declared output shapes.
    #[test]
    fn builtin_metadata_is_faithful() {
        let meta = |a: Algorithm| {
            let s = a.sorter();
            (s.is_robust(), s.output_shape())
        };
        assert_eq!(meta(Algorithm::GatherM), (true, OutputShape::RootOnly));
        assert_eq!(meta(Algorithm::AllGatherM), (true, OutputShape::Replicated));
        assert_eq!(meta(Algorithm::RQuick), (true, OutputShape::Balanced));
        for nonrobust in [
            Algorithm::NtbQuick,
            Algorithm::NtbAms,
            Algorithm::NdmaAms,
            Algorithm::HykSort,
            Algorithm::SSort,
            Algorithm::NsSSort,
        ] {
            assert!(!meta(nonrobust).0, "{nonrobust:?} must not claim robustness");
        }
        assert_eq!(meta(Algorithm::Robust), (true, OutputShape::Balanced));
        // range metadata: the two shape-restricted baselines
        assert!(!Algorithm::Bitonic.sorter().valid_range(0.5, 64));
        assert!(Algorithm::Bitonic.sorter().valid_range(8.0, 64));
        assert!(Algorithm::Minisort.sorter().valid_range(1.0, 64));
        assert!(!Algorithm::Minisort.sorter().valid_range(2.0, 64));
    }
}
