//! GatherM: sort-while-gathering onto a single PE via a binomial merge
//! tree. The paper's winner for very sparse inputs (n/p ≤ 3⁻³): only the
//! PEs that actually hold data pay startups, and the root receives log p
//! pre-merged runs instead of n messages. Does *not* satisfy the balance
//! contract — the output lives entirely on PE 0 (§VII (1)).
//!
//! All element movement happens inside the [`gather_merge`] collective,
//! whose binomial rounds run on the pooled [`crate::sim::Exchange`] data
//! plane (one `send` per tree edge moves the run and charges the model).

use crate::config::RunConfig;
use crate::elements::Elem;
use crate::localsort::{sort_all, SortBackend};
use crate::sim::{gather_merge, Cube, Machine};

use super::{OutputShape, Sorter};

pub fn sort(
    mach: &mut Machine,
    data: &mut Vec<Vec<Elem>>,
    cfg: &RunConfig,
    backend: &mut dyn SortBackend,
) {
    sort_all(mach, data, backend);
    let pes = Cube::whole(cfg.p).pe_vec();
    let merged = gather_merge(mach, &pes, data);
    for v in data.iter_mut() {
        v.clear();
    }
    data[0] = merged;
}

/// [`Sorter`]: GatherM — sort-while-gathering onto PE 0; the winner for
/// very sparse inputs, with a [`OutputShape::RootOnly`] contract.
#[derive(Clone, Copy, Debug, Default)]
pub struct GatherMSorter;

impl Sorter for GatherMSorter {
    fn name(&self) -> &'static str {
        "GatherM"
    }

    fn output_shape(&self) -> OutputShape {
        OutputShape::RootOnly
    }

    fn is_robust(&self) -> bool {
        true
    }

    fn sort(
        &self,
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) -> OutputShape {
        self::sort(mach, data, cfg, backend);
        OutputShape::RootOnly
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, Algorithm};
    use crate::input::{generate, Distribution};

    #[test]
    fn gathers_everything_sorted_on_root() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(8);
        let input = generate(&cfg, Distribution::Uniform);
        let report = run(Algorithm::GatherM, &cfg, input);
        assert!(report.validation.ok(), "{:?}", report.validation);
        assert!(report.crashed.is_none());
    }

    #[test]
    fn sparse_input_is_cheap() {
        // one element every 9 PEs: only the holders + merge tree pay
        let cfg = RunConfig::default().with_p(64).with_sparsity(9);
        let input = generate(&cfg, Distribution::Uniform);
        let report = run(Algorithm::GatherM, &cfg, input);
        assert!(report.validation.ok());
        // log p rounds of the binomial tree bound the makespan
        let alpha = cfg.cost.alpha;
        assert!(report.time < 10.0 * alpha, "time {}", report.time);
    }

    #[test]
    fn handles_duplicates() {
        let cfg = RunConfig::default().with_p(8).with_n_per_pe(16);
        let input = generate(&cfg, Distribution::Zero);
        let report = run(Algorithm::GatherM, &cfg, input);
        assert!(report.validation.ok());
    }
}
