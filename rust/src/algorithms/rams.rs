//! RAMS — the robust multi-level AMS-sort of §V / App. G.
//!
//! Per level over a PE group of size q with arity k:
//! 1. sample with *position tie-breakers* (samples are full `(key, id)`
//!    elements);
//! 2. rank the sample globally (all-gather-merge; the paper uses FIR,
//!    which has the same O(α·log q) latency — divergence noted in
//!    DESIGN.md) and select `b·k` splitters;
//! 3. partition locally with the Super Scalar Sample Sort classifier,
//!    tie-breaking on `(key, id)` (App. G) — this *simulates unique keys*
//!    and is what survives DeterDupl/Zero where HykSort dies;
//! 4. group-wide bucket histograms via a vector prefix-sum, then greedy
//!    contiguous assignment of the `b·k` buckets to the k subgroups,
//!    minimizing imbalance;
//! 5. **deterministic message assignment (DMA)**: exact target offsets
//!    from the prefix sums so every receiver gets Θ(k) coalesced
//!    messages; addresses delivered with an NBX sparse exchange, and the
//!    element payloads really travel in two hops through the
//!    [`crate::sim::Exchange`] data plane (sender → subgroup entry PE →
//!    final target, forwarding on the run tag). Without DMA (NDMA-AMS),
//!    per-(sender,target) messages go out directly and adversarial inputs
//!    (AllToOne) serialize Ω(min(p, n/p)) receives on one PE — Fig. 2c;
//! 6. receivers merge their runs; recurse into the subgroups.

use crate::config::RunConfig;
use crate::elements::{multiway_merge_into, Elem};
use crate::localsort::{sort_all, SortBackend};
use crate::partition::{partition_ctx, pick_splitters, SplitterTree};
use crate::rng::Rng;
use crate::sim::{all_gather_merge, prefix_sum_vec, Cube, Machine, ParSpec};

use super::{OutputShape, Sorter};

/// Deterministic-message-assignment policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dma {
    /// Measure fan-in from the histograms and enable DMA only when it
    /// would help (the paper's RAMS behaviour: "decides to sort … without
    /// DMA as there would be no impact").
    Auto,
    Always,
    Never,
}

#[derive(Clone, Copy, Debug)]
pub struct AmsConfig {
    pub levels: usize,
    pub tie_break: bool,
    pub dma: Dma,
    /// target output imbalance ε (paper: 0.2, measured < 0.1).
    pub epsilon: f64,
}

impl AmsConfig {
    /// The paper's RAMS with the level count from the App. J2 tuning:
    /// more levels for small inputs, fewer for large — but always enough
    /// levels that the per-level arity stays ≤ 64 (k = 32 was the paper's
    /// sweet spot; a single level with k ≈ p degenerates to sample sort).
    pub fn robust(cfg: &RunConfig) -> Self {
        let npp = cfg.n_over_p();
        let by_size = if npp >= 4096.0 {
            1
        } else if npp >= 64.0 {
            2
        } else {
            3
        };
        let dim = cfg.p.max(2).trailing_zeros() as usize;
        let by_arity = dim.div_ceil(6); // k = 2^⌈dim/l⌉ ≤ 64
        let levels = by_size.max(by_arity).max(1);
        Self { levels, tie_break: true, dma: Dma::Auto, epsilon: cfg.epsilon }
    }

    pub fn with_levels(mut self, l: usize) -> Self {
        self.levels = l.max(1);
        self
    }
}

pub fn sort(
    mach: &mut Machine,
    data: &mut Vec<Vec<Elem>>,
    cfg: &RunConfig,
    backend: &mut dyn SortBackend,
    ac: &AmsConfig,
) {
    let p = cfg.p;
    assert!(p.is_power_of_two());
    let mut rng = Rng::seeded(cfg.seed ^ 0x414D_5331, 4);

    sort_all(mach, data, backend);

    let mut groups = vec![(Cube::whole(p), ac.levels.max(1))];
    while let Some((group, levels_left)) = groups.pop() {
        if group.dim == 0 || levels_left == 0 {
            continue;
        }
        let subs = level(mach, &group, data, cfg, ac, levels_left, &mut rng);
        if mach.crashed() {
            return;
        }
        for s in subs {
            groups.push((s, levels_left - 1));
        }
    }
}

/// One k-way AMS level; returns the subgroups for recursion.
fn level(
    mach: &mut Machine,
    group: &Cube,
    data: &mut [Vec<Elem>],
    cfg: &RunConfig,
    ac: &AmsConfig,
    levels_left: usize,
    rng: &mut Rng,
) -> Vec<Cube> {
    let q = group.size();
    let pes = group.pe_vec();
    // arity: split the remaining dims evenly over the remaining levels
    let logk = group.dim.div_ceil(levels_left as u32).max(1);
    let k = 1usize << logk;
    let subgroups = group.split_k(logk);
    let q_sub = q / k;

    // --- oversampling factor b (App. J1): b = 2/((1+ε)^(1/l) − 1) ------
    let b = (2.0 / ((1.0 + ac.epsilon).powf(1.0 / ac.levels as f64) - 1.0)).ceil() as usize;
    // pad b·k − 1 up to 2^h − 1 splitters for the perfect classifier tree
    let nb = ((b * k).next_power_of_two() - 1).max(k - 1).min(1023);

    // --- sampling with position tie-breakers ---------------------------
    // total sample ≈ 4·nb, but never more than what a PE's memory budget
    // tolerates after the all-gather (the ranked sample is replicated).
    // Sequential: every member draws from one shared RNG stream.
    let mut samples: Vec<Vec<Elem>> = vec![Vec::new(); data.len()];
    let budget = mach.mem_cap_elems.unwrap_or(usize::MAX).min(4 * nb.max(k));
    let s_loc_target = (budget as f64 / q as f64).ceil() as usize;
    for &pe in &pes {
        let local = &data[pe];
        let take = s_loc_target.max(1).min(local.len());
        for _ in 0..take {
            samples[pe].push(local[rng.below(local.len() as u64) as usize]);
        }
        samples[pe].sort_unstable();
        mach.work_sort(pe, take);
    }
    // rank samples globally (stand-in for FIR; same latency class)
    let gathered = all_gather_merge(mach, &pes, &samples);
    let sorted_samples = gathered[0].merged();
    let splitters = pick_splitters(&sorted_samples, nb);
    let tree = SplitterTree::new(&splitters);

    // --- local partition with (or without) tie-breaking ----------------
    // the splitter-tree descent over every element is the level's hottest
    // local phase: one PE task per member, buckets from the task stash
    let base = group.base();
    let mut buckets: Vec<Vec<Vec<Elem>>> = vec![Vec::new(); data.len()];
    let mut counts: Vec<Vec<usize>> = Vec::with_capacity(q);
    let total: usize = pes.iter().map(|&pe| data[pe].len()).sum();
    let parts_list: Vec<Vec<Vec<Elem>>> = mach.par_pes(
        base,
        ParSpec::work(total).bufs(nb + 2),
        &mut data[base..base + q],
        |ctx, slot| {
            let local = std::mem::take(slot);
            ctx.work_classify(local.len(), nb + 1);
            let parts = partition_ctx(ctx, &local, &tree, ac.tie_break);
            ctx.recycle_buf(local);
            parts
        },
    );
    for (r, parts) in parts_list.into_iter().enumerate() {
        counts.push(parts.iter().map(Vec::len).collect());
        buckets[base + r] = parts;
    }

    // --- histograms + greedy contiguous bucket→subgroup assignment -----
    let prefixes = prefix_sum_vec(mach, &pes, &counts);
    let totals: Vec<usize> = prefixes[0].1.clone();
    let grand_total: usize = totals.iter().sum();
    let ideal = grand_total as f64 / k as f64;
    // boundary[g] = first bucket of subgroup g; close a subgroup once its
    // cumulative load reaches (g+1)·ideal
    let mut assignment = vec![0usize; nb + 1]; // bucket → subgroup
    {
        let mut cum = 0usize;
        let mut g = 0usize;
        for (bkt, &t) in totals.iter().enumerate() {
            // leave enough buckets for the remaining subgroups
            let remaining_buckets = nb + 1 - bkt;
            let remaining_groups = k - g;
            if g + 1 < k
                && cum as f64 >= (g + 1) as f64 * ideal
                && remaining_buckets > remaining_groups - 1
            {
                g += 1;
            }
            assignment[bkt] = g;
            cum += t;
        }
        mach.work(pes[0], cfg.cost.cmp * (nb + 1) as f64);
    }
    // per-subgroup totals and per-(pe,bucket) global offsets
    let mut sub_total = vec![0usize; k];
    for (bkt, &g) in assignment.iter().enumerate() {
        sub_total[g] += totals[bkt];
    }
    // exclusive offset of bucket bkt within its subgroup's global order
    let mut bucket_base = vec![0usize; nb + 1];
    {
        let mut acc = vec![0usize; k];
        for (bkt, &g) in assignment.iter().enumerate() {
            bucket_base[bkt] = acc[g];
            acc[g] += totals[bkt];
        }
    }

    // --- build the message set: (sender, target, slice of bucket) ------
    // capacity per target PE (perfect balance within the subgroup)
    let caps: Vec<usize> = sub_total.iter().map(|&t| t.div_ceil(q_sub).max(1)).collect();
    struct Msg {
        from_pe: usize,
        to_pe: usize,
        bucket: usize,
        start: usize, // element range within the sender's bucket
        end: usize,
    }
    let mut msgs: Vec<Msg> = Vec::new();
    // per-sender range within `msgs` (sender-major build order) — the
    // unit of the parallel payload-staging tasks below
    let mut sender_spans: Vec<(usize, usize)> = Vec::with_capacity(q);
    for (r, &pe) in pes.iter().enumerate() {
        let span_start = msgs.len();
        let pre = &prefixes[r].0;
        for bkt in 0..=nb {
            let len = buckets[pe][bkt].len();
            if len == 0 {
                continue;
            }
            let g = assignment[bkt];
            let goff = bucket_base[bkt] + pre[bkt]; // global offset in subgroup g
            let cap = caps[g];
            // split [goff, goff+len) on target-PE boundaries
            let mut local_start = 0usize;
            while local_start < len {
                let gpos = goff + local_start;
                let t_idx = (gpos / cap).min(q_sub - 1);
                let t_end_gpos = ((t_idx + 1) * cap).min(goff + len);
                let local_end = t_end_gpos - goff;
                msgs.push(Msg {
                    from_pe: pe,
                    to_pe: subgroups[g].pe(t_idx),
                    bucket: bkt,
                    start: local_start,
                    end: local_end,
                });
                local_start = local_end;
            }
        }
        sender_spans.push((span_start, msgs.len()));
    }

    // --- DMA decision (fan-in of the direct wire pattern) ---------------
    // one wire message per (sender, target) pair: a sender's buckets
    // headed to the same target PE are contiguous in the subgroup order,
    // so the data plane coalesces them into one message.
    let mut pairs: std::collections::HashSet<(usize, usize)> = std::collections::HashSet::new();
    let mut fan_in: std::collections::HashMap<usize, usize> = std::collections::HashMap::new();
    for m in &msgs {
        if m.from_pe != m.to_pe && pairs.insert((m.from_pe, m.to_pe)) {
            *fan_in.entry(m.to_pe).or_insert(0usize) += 1;
        }
    }
    let max_fan_in = fan_in.values().copied().max().unwrap_or(0);
    let use_dma = match ac.dma {
        Dma::Always => true,
        Dma::Never => false,
        Dma::Auto => {
            // the decision itself costs one small all-reduce
            crate::sim::allreduce_u64(mach, &pes, &vec![0u64; data.len()], |a, b| a.max(b));
            max_fan_in > 4 * k
        }
    };

    // --- the exchange: charging and movement are the same calls ----------
    let inboxes = if use_dma {
        // Deterministic message assignment (App. G): address information is
        // routed *to the target group*, which computes exact addresses and
        // replies — O(α·log q + α·k) per PE (Hoefler et al.'s NBX supplies
        // the termination detection). We charge the paper's stated bound
        // plus the non-blocking barrier rather than simulating the
        // tree-aggregated bookkeeping messages individually.
        let addr_cost = cfg.cost.alpha * ((q.max(2) as f64).log2() + k as f64);
        for &pe in &pes {
            mach.work(pe, addr_cost);
        }
        mach.barrier(&pes);
        // With addresses known, senders aggregate per target subgroup and
        // the data really travels in two hops: one coalesced message to a
        // subgroup entry PE (Θ(k) sends per PE), then one intra-subgroup
        // scatter round to the final targets. Runs are tagged with their
        // final target so the entry PE can forward them — every PE sends
        // and receives Θ(k) messages, at the price of the group-internal
        // second hop. The payload staging (the element copies) runs as one
        // PE task per sender; posting stays serial in the historical
        // sender-major msgs order.
        let sender_runs: Vec<Vec<(usize, u64, Vec<Elem>)>> = mach.par_pes_on(
            &pes,
            ParSpec::work(grand_total).bufs(2 * k),
            &mut sender_spans,
            |ctx, span| {
                let (lo, hi) = *span;
                let from = ctx.pe();
                let mut out: Vec<(usize, u64, Vec<Elem>)> = Vec::with_capacity(hi - lo);
                let mut i = lo;
                while i < hi {
                    // msgs are sender-major with nondecreasing bucket, so
                    // the (sender, subgroup) aggregates are contiguous
                    let g = assignment[msgs[i].bucket];
                    let entry = subgroups[g].pe(group.rank(from) % q_sub);
                    let mut total = 0usize;
                    while i < hi && assignment[msgs[i].bucket] == g {
                        let m = &msgs[i];
                        let mut run = ctx.take_buf();
                        run.extend_from_slice(&buckets[m.from_pe][m.bucket][m.start..m.end]);
                        total += run.len();
                        out.push((entry, m.to_pe as u64, run));
                        i += 1;
                    }
                    ctx.note_mem_at(entry, total, "DMA subgroup entry");
                }
                out
            },
        );
        let mut ex = mach.exchange();
        for (r, runs) in sender_runs.into_iter().enumerate() {
            for (entry, tag, run) in runs {
                ex.post_tagged(pes[r], entry, tag, run);
            }
        }
        let mut hop1 = ex.deliver(mach);
        let mut ex = mach.exchange();
        for &pe in &pes {
            for (tag, run) in hop1.take(pe) {
                ex.post(pe, tag as usize, run);
            }
        }
        let inboxes = ex.deliver(mach);
        mach.recycle(hop1);
        inboxes
    } else {
        // direct per-(sender, target) messages: adversarial inputs
        // (AllToOne) serialize Ω(min(p, n/p)) receives on one PE. Payload
        // staging per sender task, posting serial in msgs order.
        let sender_runs: Vec<Vec<(usize, Vec<Elem>)>> = mach.par_pes_on(
            &pes,
            ParSpec::work(grand_total).bufs(2 * k),
            &mut sender_spans,
            |ctx, span| {
                let (lo, hi) = *span;
                msgs[lo..hi]
                    .iter()
                    .map(|m| {
                        let mut run = ctx.take_buf();
                        run.extend_from_slice(&buckets[m.from_pe][m.bucket][m.start..m.end]);
                        (m.to_pe, run)
                    })
                    .collect()
            },
        );
        let mut ex = mach.exchange();
        for (r, runs) in sender_runs.into_iter().enumerate() {
            for (to, run) in runs {
                ex.post(pes[r], to, run);
            }
        }
        ex.deliver(mach)
    };
    for &pe in &pes {
        for bucket in std::mem::take(&mut buckets[pe]) {
            mach.recycle_buf(bucket);
        }
    }
    // receivers merge their runs: one PE task per member, ping-pong
    // multiway merge over pooled buffers
    let total_recv: usize = pes.iter().map(|&pe| inboxes.total(pe)).sum();
    mach.par_pes(
        base,
        ParSpec::work(2 * total_recv).bufs(2),
        &mut data[base..base + q],
        |ctx, slot| {
            let refs: Vec<&[Elem]> =
                inboxes.runs(ctx.pe()).iter().map(|(_, v)| v.as_slice()).collect();
            let mut merged = ctx.take_buf();
            multiway_merge_into(&refs, &mut merged, ctx.merge_scratch());
            ctx.work(cfg.cost.cmp * merged.len() as f64 * (refs.len().max(2) as f64).log2());
            ctx.note_mem(merged.len(), "AMS data exchange");
            *slot = merged;
        },
    );
    mach.recycle(inboxes);

    subgroups
}

/// [`Sorter`] for the multi-level AMS family: the robust **RAMS** plus the
/// **NTB-AMS** / **NDMA-AMS** ablations of Fig. 2 — three values of one
/// type, distinguished by the robustness knobs they carry. The level count
/// is derived from the run config at sort time ([`AmsConfig::robust`],
/// which needs n/p) unless overridden with [`RamsSorter::with_levels`].
#[derive(Clone, Copy, Debug)]
pub struct RamsSorter {
    /// Level-count override; `None` = the paper's tuned count by n/p.
    pub levels: Option<usize>,
    pub tie_break: bool,
    pub dma: Dma,
    name: &'static str,
}

impl RamsSorter {
    /// The paper's RAMS (App. G).
    pub fn robust() -> Self {
        Self { levels: None, tie_break: true, dma: Dma::Auto, name: "RAMS" }
    }

    /// NTB-AMS: no splitter tie-breaking (Fig. 2b).
    pub fn ntb() -> Self {
        Self { tie_break: false, name: "NTB-AMS", ..Self::robust() }
    }

    /// NDMA-AMS: no deterministic message assignment (Fig. 2c).
    pub fn ndma() -> Self {
        Self { dma: Dma::Never, name: "NDMA-AMS", ..Self::robust() }
    }

    /// Fix the level count (App. J2 tuning sweeps).
    pub fn with_levels(mut self, levels: usize) -> Self {
        self.levels = Some(levels.max(1));
        self
    }

    fn ams_config(&self, cfg: &RunConfig) -> AmsConfig {
        let mut ac = AmsConfig::robust(cfg);
        ac.tie_break = self.tie_break;
        ac.dma = self.dma;
        if let Some(levels) = self.levels {
            ac.levels = levels;
        }
        ac
    }
}

impl Sorter for RamsSorter {
    fn name(&self) -> &'static str {
        self.name
    }

    fn output_shape(&self) -> OutputShape {
        OutputShape::Balanced
    }

    fn is_robust(&self) -> bool {
        self.tie_break && self.dma != Dma::Never
    }

    fn sort(
        &self,
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) -> OutputShape {
        let ac = self.ams_config(cfg);
        self::sort(mach, data, cfg, backend, &ac);
        OutputShape::Balanced
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, Algorithm};
    use crate::input::{generate, Distribution};

    #[test]
    fn rams_sorts_uniform_large() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(1024);
        let report = run(Algorithm::Rams, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(report.succeeded(), "{:?} {:?}", report.crashed, report.validation);
        assert!(report.validation.balanced, "imbalance {:?}", report.validation.imbalance);
    }

    #[test]
    fn rams_sorts_every_distribution() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(256);
        for d in Distribution::ALL {
            let report = run(Algorithm::Rams, &cfg, generate(&cfg, d));
            assert!(report.succeeded(), "{d:?}: {:?} {:?}", report.crashed, report.validation);
        }
    }

    #[test]
    fn rams_survives_zero_where_ntb_ams_dies() {
        let mut cfg = RunConfig::default().with_p(16).with_n_per_pe(512);
        cfg.mem_cap_factor = Some(8.0);
        let robust = run(Algorithm::Rams, &cfg, generate(&cfg, Distribution::Zero));
        assert!(robust.succeeded(), "{:?}", robust.validation);
        let ntb = run(Algorithm::NtbAms, &cfg, generate(&cfg, Distribution::Zero));
        let bad = ntb.crashed.is_some() || !ntb.validation.balanced;
        assert!(bad, "NTB-AMS must collapse on Zero: {:?}", ntb.validation.imbalance);
    }

    #[test]
    fn dma_caps_fan_in_on_all_to_one() {
        // the Fig. 2c regime: fan-in min(p, n/p) ≫ k — the paper sees the
        // DMA payoff "begin for n/p > 8k elements per core"
        let cfg = RunConfig::default().with_p(512).with_n_per_pe(512);
        let with = run(Algorithm::Rams, &cfg, generate(&cfg, Distribution::AllToOne));
        let without = run(Algorithm::NdmaAms, &cfg, generate(&cfg, Distribution::AllToOne));
        assert!(with.succeeded(), "{:?}", with.validation);
        assert!(without.validation.ok());
        assert!(
            with.time <= without.time,
            "DMA should not be slower on AllToOne: {} vs {}",
            with.time,
            without.time
        );
    }

    #[test]
    fn rams_multi_level_matches_single_level() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(256);
        for levels in [1usize, 2, 3] {
            let mut mach = Machine::new(cfg.p, cfg.cost);
            let mut data = generate(&cfg, Distribution::Staggered);
            let reference = data.clone();
            let ac = AmsConfig::robust(&cfg).with_levels(levels);
            sort(&mut mach, &mut data, &cfg, &mut crate::localsort::RustSort, &ac);
            let v = crate::verify::validate(&reference, &data, 1.0);
            assert!(v.ok(), "levels={levels}: {v:?}");
        }
    }

    #[test]
    fn rams_handles_sparse() {
        let cfg = RunConfig::default().with_p(32).with_sparsity(2);
        let report = run(Algorithm::Rams, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(report.validation.ok(), "{:?}", report.validation);
    }
}
