//! Simple single-level p-way sample sort (Blelloch et al. [7], as
//! implemented for the paper's Fig. 2d baseline): sample 16·log p keys per
//! PE, sort the sample on PE 0, broadcast p−1 splitters, partition, and
//! deliver everything directly with one all-to-all — the Ω(α·p) startup
//! pattern that makes single-level algorithms "very slow even for rather
//! large n/p".
//!
//! `charge_splitters = false` gives NS-SSort: the splitter phase runs free,
//! making the curve "a rough lower bound for any algorithm that delivers
//! the data directly".

use crate::config::RunConfig;
use crate::elements::{multiway_merge_into, Elem, Key};
use crate::localsort::{sort_all, SortBackend};
use crate::rng::Rng;
use crate::sim::{bcast_cost, Cube, Machine, ParSpec};

use super::{OutputShape, Sorter};

/// Gather `counts[r]` words from every rank to rank 0 along a binomial
/// tree with doubling message sizes (the β·p gather term).
fn gather_words_cost(mach: &mut Machine, pes: &[usize], counts: &mut [usize]) {
    let dim = pes.len().trailing_zeros();
    for j in 0..dim {
        let bit = 1usize << j;
        for r in 0..pes.len() {
            if r & bit != 0 && r & (bit - 1) == 0 {
                let dst = r & !bit;
                mach.send(pes[r], pes[dst], counts[r]);
                counts[dst] += counts[r];
            }
        }
    }
}

pub fn sort(
    mach: &mut Machine,
    data: &mut Vec<Vec<Elem>>,
    cfg: &RunConfig,
    backend: &mut dyn SortBackend,
    charge_splitters: bool,
) {
    let p = cfg.p;
    assert!(p.is_power_of_two());
    let logp = p.trailing_zeros().max(1) as usize;
    let mut rng = Rng::seeded(cfg.seed ^ 0x5350_4C54, 2);
    let pes = Cube::whole(p).pe_vec();

    sort_all(mach, data, backend);

    // --- splitter phase ---------------------------------------------
    let per_pe_sample = 16 * logp;
    let mut sample: Vec<Elem> = Vec::new();
    let mut sample_counts = vec![0usize; p];
    for (pe, local) in data.iter().enumerate() {
        let take = per_pe_sample.min(local.len());
        for _ in 0..take {
            sample.push(local[rng.below(local.len() as u64) as usize]);
        }
        sample_counts[pe] = take;
    }
    sample.sort_unstable_by_key(|e| e.key);
    let splitters: Vec<Key> = (1..p)
        .map(|i| {
            if sample.is_empty() {
                Key::MAX
            } else {
                sample[(i * sample.len() / p).min(sample.len() - 1)].key
            }
        })
        .collect();
    if charge_splitters {
        gather_words_cost(mach, &pes, &mut sample_counts);
        mach.work_sort(0, sample.len());
        bcast_cost(mach, &pes, 0, p - 1);
    }

    // --- partition + direct delivery through the data plane -----------
    // bucket building as one PE task per member; posting keeps the
    // historical (pe, bucket) order
    let total: usize = data.iter().map(Vec::len).sum();
    let outs: Vec<Vec<Vec<Elem>>> =
        mach.par_pes(0, ParSpec::work(total).bufs(p + 1), &mut *data, |ctx, slot| {
            let local = std::mem::take(slot);
            ctx.work_classify(local.len(), p);
            let mut buckets: Vec<Vec<Elem>> = (0..p).map(|_| ctx.take_buf()).collect();
            for &e in &local {
                // nonrobust: key-only binary search (duplicates pile up)
                let b = splitters.partition_point(|&s| s < e.key);
                buckets[b].push(e);
            }
            ctx.recycle_buf(local);
            buckets
        });
    let mut ex = mach.exchange();
    for (pe, buckets) in outs.into_iter().enumerate() {
        for (t, bucket) in buckets.into_iter().enumerate() {
            ex.post(pe, t, bucket);
        }
    }
    let inboxes = ex.deliver(mach);
    for &pe in &pes {
        mach.note_mem(pe, inboxes.total(pe), "alltoallv");
    }

    // --- local merge of received runs: one PE task per member ---------
    let total_recv: usize = pes.iter().map(|&pe| inboxes.total(pe)).sum();
    mach.par_pes(0, ParSpec::work(2 * total_recv).bufs(1), &mut *data, |ctx, slot| {
        let refs: Vec<&[Elem]> = inboxes.runs(ctx.pe()).iter().map(|(_, v)| v.as_slice()).collect();
        let mut merged = ctx.take_buf();
        multiway_merge_into(&refs, &mut merged, ctx.merge_scratch());
        ctx.work(cfg.cost.cmp * merged.len() as f64 * (p.max(2) as f64).log2());
        ctx.note_mem(merged.len(), "sample sort receive");
        *slot = merged;
    });
    mach.recycle(inboxes);
}

/// [`Sorter`] for single-level p-way sample sort: **SSort** charges the
/// splitter phase, **NS-SSort** runs it free — the Fig. 2d lower bound
/// for single-delivery algorithms. Key-only sampling (no tie-breaking)
/// makes both nonrobust on duplicate-heavy instances.
#[derive(Clone, Copy, Debug)]
pub struct SSortSorter {
    /// Whether the splitter-selection phase is charged to the clocks.
    pub charge_splitters: bool,
}

impl SSortSorter {
    /// SSort: the full algorithm, splitter phase included.
    pub fn charged() -> Self {
        Self { charge_splitters: true }
    }

    /// NS-SSort: splitters for free (Fig. 2d's lower bound).
    pub fn free_splitters() -> Self {
        Self { charge_splitters: false }
    }
}

impl Sorter for SSortSorter {
    fn name(&self) -> &'static str {
        if self.charge_splitters {
            "SSort"
        } else {
            "NS-SSort"
        }
    }

    fn output_shape(&self) -> OutputShape {
        OutputShape::Balanced
    }

    fn is_robust(&self) -> bool {
        false
    }

    fn sort(
        &self,
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) -> OutputShape {
        self::sort(mach, data, cfg, backend, self.charge_splitters);
        OutputShape::Balanced
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, Algorithm};
    use crate::input::{generate, Distribution};

    #[test]
    fn ssort_sorts_uniform() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(256);
        let report = run(Algorithm::SSort, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(report.validation.ok(), "{:?}", report.validation);
        assert!(report.crashed.is_none());
    }

    #[test]
    fn ssort_pays_p_startups() {
        let cfg = RunConfig::default().with_p(64).with_n_per_pe(64);
        let report = run(Algorithm::SSort, &cfg, generate(&cfg, Distribution::Uniform));
        // the all-to-all alone is ~p² messages
        assert!(report.stats.messages as usize > 64 * 32, "messages {}", report.stats.messages);
    }

    #[test]
    fn ns_ssort_is_faster_than_ssort() {
        let cfg = RunConfig::default().with_p(32).with_n_per_pe(64);
        let s = run(Algorithm::SSort, &cfg, generate(&cfg, Distribution::Uniform));
        let ns = run(Algorithm::NsSSort, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(ns.validation.ok());
        assert!(ns.time < s.time, "NS {} vs SSort {}", ns.time, s.time);
    }

    #[test]
    fn ssort_imbalances_on_heavy_duplicates() {
        // Zero: all keys equal → one bucket gets everything
        let mut cfg = RunConfig::default().with_p(16).with_n_per_pe(512);
        cfg.mem_cap_factor = Some(8.0);
        let report = run(Algorithm::SSort, &cfg, generate(&cfg, Distribution::Zero));
        let bad = report.crashed.is_some() || !report.validation.balanced;
        assert!(bad, "SSort should collapse on Zero: {:?}", report.validation.imbalance);
    }
}
