//! RFIS — Robust Fast Work-Inefficient Sorting (§V, App. F).
//!
//! The PEs form an O(√p)×O(√p) grid. All-gather-merge along rows and
//! columns gives each PE all elements of its row and column; each PE then
//! ranks its row elements against its column elements and an all-reduce
//! along the row sums the partial ranks into *global* ranks — O(α·log p)
//! latency, O(β·n/√p) volume, massively work-inefficient and exactly right
//! for sparse/tiny inputs.
//!
//! Robustness against duplicates comes from the provenance tie-break of
//! App. F: elements are logically quadruples (x, row, col, i) compared
//! lexicographically, implemented with zero extra communication by
//! tracking which direction data arrived from ({←,H,→} × {↑,H,↓}) plus
//! local positions — the 3×3 compare table below.
//!
//! Delivery: rank r → PE ⌊r·p/n⌋. Every column holds the complete ranked
//! input, so each column keeps only its own targets and routes them to the
//! right row with hypercube bit-fixing.

use crate::config::RunConfig;
use crate::elements::Elem;
use crate::localsort::{sort_all, SortBackend};
use crate::sim::{all_gather_merge, allreduce_vec_u64, GatheredRuns, Machine, ParSpec};

use super::{OutputShape, Sorter};

/// Provenance of a row-gathered element relative to this PE's column.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum RowClass {
    /// arrived from a lower column (←)
    Left,
    /// this PE's own element (H); payload = index in the local sorted run
    Own(usize),
    /// arrived from a higher column (→)
    Right,
}

/// Grid geometry: `rows × cols = p`, rows = 2^⌈d/2⌉.
pub fn grid(p: usize) -> (usize, usize) {
    let d = p.trailing_zeros();
    let cols = 1usize << (d / 2);
    (p / cols, cols)
}

/// count of keys ≤ `key` in a sorted run (upper bound).
#[inline]
fn ub(run: &[Elem], key: u64) -> u64 {
    run.partition_point(|e| e.key <= key) as u64
}

/// count of keys < `key` in a sorted run (lower bound).
#[inline]
fn lb(run: &[Elem], key: u64) -> u64 {
    run.partition_point(|e| e.key < key) as u64
}

pub fn sort(
    mach: &mut Machine,
    data: &mut Vec<Vec<Elem>>,
    cfg: &RunConfig,
    backend: &mut dyn SortBackend,
) {
    let p = cfg.p;
    assert!(p.is_power_of_two());
    let n: usize = data.iter().map(Vec::len).sum();
    if n == 0 {
        return;
    }
    let (rows, cols) = grid(p);

    sort_all(mach, data, backend);

    // --- row and column all-gather-merges (provenance-tracking) ------
    let mut row_runs = vec![None; p];
    for r in 0..rows {
        let pes: Vec<usize> = (0..cols).map(|c| r * cols + c).collect();
        let runs = all_gather_merge(mach, &pes, data);
        for (c, g) in runs.into_iter().enumerate() {
            row_runs[r * cols + c] = Some(g);
        }
    }
    let mut col_runs = vec![None; p];
    for c in 0..cols {
        let pes: Vec<usize> = (0..rows).map(|r| r * cols + c).collect();
        let runs = all_gather_merge(mach, &pes, data);
        for (r, g) in runs.into_iter().enumerate() {
            col_runs[r * cols + c] = Some(g);
        }
    }

    // --- per-PE ranking of row elements against column elements ------
    // The annotated row sequence (canonical (key,id) order — identical on
    // every PE of the row) with provenance classes. Each PE's ranking
    // reads only its own (row, col) gathers — one pool-scheduled PE task
    // per member, the hottest local phase of RFIS.
    let mut gathers: Vec<(GatheredRuns, GatheredRuns)> = row_runs
        .into_iter()
        .zip(col_runs)
        .map(|(row, col)| (row.expect("row gather ran"), col.expect("col gather ran")))
        .collect();
    let gather_total: usize = gathers.iter().map(|(row, col)| row.total() + col.total()).sum();
    let results: Vec<(Vec<u64>, Vec<Elem>)> =
        mach.par_pes(0, ParSpec::work(gather_total), &mut gathers, |ctx, (row, col)| {
            // merge the three tagged row runs in (key, id) order
            let mut annotated: Vec<(Elem, RowClass)> = Vec::with_capacity(row.total());
            {
                let (mut i, mut j, mut k) = (0usize, 0usize, 0usize);
                let (l, o, r) = (&row.left, &row.own, &row.right);
                while i < l.len() || j < o.len() || k < r.len() {
                    let lv = l.get(i);
                    let ov = o.get(j);
                    let rv = r.get(k);
                    let pick_l = lv.is_some()
                        && ov.map_or(true, |x| lv.unwrap() <= x)
                        && rv.map_or(true, |x| lv.unwrap() <= x);
                    if pick_l {
                        annotated.push((l[i], RowClass::Left));
                        i += 1;
                    } else if ov.is_some() && rv.map_or(true, |x| ov.unwrap() <= x) {
                        annotated.push((o[j], RowClass::Own(j)));
                        j += 1;
                    } else {
                        annotated.push((r[k], RowClass::Right));
                        k += 1;
                    }
                }
            }
            // rank each row element within the column data via the App. F
            // table
            let (up, own_col, down) = (&col.left, &col.own, &col.right);
            let mut rk = Vec::with_capacity(annotated.len());
            for (e, class) in &annotated {
                let r = match class {
                    RowClass::Left => ub(up, e.key) + lb(own_col, e.key) + lb(down, e.key),
                    RowClass::Right => ub(up, e.key) + ub(own_col, e.key) + lb(down, e.key),
                    RowClass::Own(i) => ub(up, e.key) + *i as u64 + lb(down, e.key),
                };
                rk.push(r);
            }
            let total = annotated.len() + col.total();
            ctx.work(
                cfg.cost.cmp * annotated.len() as f64
                    * ((col.total().max(2)) as f64).log2(),
            );
            ctx.note_mem(total, "RFIS gather footprint");
            (rk, annotated.into_iter().map(|(e, _)| e).collect::<Vec<Elem>>())
        });
    // results are already in PE order (one task per PE, task i == PE i):
    // unzip moves them straight into the two tables, instead of building
    // zeroed vec![Vec::new(); p] tables and copying over them
    let (mut ranks, row_merged): (Vec<Vec<u64>>, Vec<Vec<Elem>>) =
        results.into_iter().unzip();

    // --- all-reduce partial ranks along each row ----------------------
    for r in 0..rows {
        let pes: Vec<usize> = (0..cols).map(|c| r * cols + c).collect();
        if !ranks[pes[0]].is_empty() {
            allreduce_vec_u64(mach, &pes, &mut ranks, |a, b| a + b);
        }
    }

    // --- delivery: keep own column's targets, route within the column -
    // element with global rank i goes to PE ⌊i·p/n⌋; the full-row scan is
    // per-PE independent — one PE task per member
    let dest_pe = |rank: u64| -> usize { ((rank as u128 * p as u128) / n as u128) as usize };
    let mut items: Vec<(Vec<Elem>, Vec<u64>)> =
        row_merged.into_iter().zip(ranks).collect();
    let scan_total: usize = items.iter().map(|(m, _)| m.len()).sum();
    let mut in_flight: Vec<Vec<(Elem, usize)>> = // (elem, dest_row)
        mach.par_pes(0, ParSpec::work(scan_total), &mut items, |ctx, (merged, rk)| {
            let c = ctx.pe() % cols;
            ctx.work_linear(merged.len());
            let mut keep: Vec<(Elem, usize)> = Vec::new();
            for (e, r) in merged.drain(..).zip(rk.drain(..)) {
                let dest = dest_pe(r);
                if dest % cols == c {
                    keep.push((e, dest / cols));
                }
            }
            keep
        });
    for run in data.iter_mut() {
        run.clear();
    }
    // hypercube bit-fixing over the rows of each column: misrouted
    // elements travel through the data plane as runs tagged with their
    // destination row (the paper's address bits — zero extra words)
    let row_dims = rows.trailing_zeros();
    for j in (0..row_dims).rev() {
        let bit = 1usize << j;
        for c in 0..cols {
            let mut ex = mach.exchange();
            for r in 0..rows {
                let pe = r * cols + c;
                let partner = (r ^ bit) * cols + c;
                // lock-step round: the pair pays its α even when neither
                // side has misrouted elements (as the eager charges did)
                ex.xchg_touch(pe, partner);
                let (stay, mut go): (Vec<_>, Vec<_>) =
                    std::mem::take(&mut in_flight[pe]).into_iter().partition(|(_, d)| d & bit == r & bit);
                in_flight[pe] = stay;
                // one tagged run per destination row
                go.sort_unstable_by_key(|&(_, d)| d);
                let mut i = 0;
                while i < go.len() {
                    let d = go[i].1;
                    let mut run = mach.take_buf();
                    while i < go.len() && go[i].1 == d {
                        run.push(go[i].0);
                        i += 1;
                    }
                    ex.xchg_leg_tagged(pe, partner, d as u64, run);
                }
            }
            let inboxes = ex.deliver(mach);
            for r in 0..rows {
                let pe = r * cols + c;
                for (tag, run) in inboxes.runs(pe) {
                    in_flight[pe].extend(run.iter().map(|&e| (e, *tag as usize)));
                }
                mach.note_mem(pe, in_flight[pe].len(), "RFIS delivery");
            }
            mach.recycle(inboxes);
        }
    }
    // final local sort of the delivered targets: one PE task per member
    let sort_total: usize = in_flight.iter().map(Vec::len).sum();
    let sorted: Vec<Vec<Elem>> =
        mach.par_pes(0, ParSpec::work(sort_total), &mut in_flight, |ctx, fl| {
            let mut v: Vec<Elem> = std::mem::take(fl).into_iter().map(|(e, _)| e).collect();
            ctx.work_sort(v.len());
            v.sort_unstable();
            v
        });
    for (pe, v) in sorted.into_iter().enumerate() {
        data[pe] = v;
    }
}

/// [`Sorter`]: RFIS — the robust fast work-inefficient sort of §V, the
/// paper's pick for sparse/tiny inputs (n/p below the RQuick crossover).
#[derive(Clone, Copy, Debug, Default)]
pub struct RfisSorter;

impl Sorter for RfisSorter {
    fn name(&self) -> &'static str {
        "RFIS"
    }

    fn output_shape(&self) -> OutputShape {
        OutputShape::Balanced
    }

    fn is_robust(&self) -> bool {
        true
    }

    fn sort(
        &self,
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) -> OutputShape {
        self::sort(mach, data, cfg, backend);
        OutputShape::Balanced
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, Algorithm};
    use crate::input::{generate, Distribution};

    #[test]
    fn grid_shapes() {
        assert_eq!(grid(16), (4, 4));
        assert_eq!(grid(8), (4, 2));
        assert_eq!(grid(2), (2, 1));
        assert_eq!(grid(1), (1, 1));
    }

    #[test]
    fn rfis_sorts_uniform_dense() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(4);
        let report = run(Algorithm::Rfis, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(report.succeeded(), "{:?}", report.validation);
        assert!(report.validation.balanced, "{:?}", report.validation.imbalance);
    }

    #[test]
    fn rfis_sorts_every_distribution() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(4);
        for d in Distribution::ALL {
            let report = run(Algorithm::Rfis, &cfg, generate(&cfg, d));
            assert!(report.succeeded(), "{d:?}: {:?}", report.validation);
        }
    }

    #[test]
    fn rfis_duplicates_get_unique_ranks_and_balanced_output() {
        // the tie-breaking core: all-equal keys must still balance perfectly
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(8);
        let report = run(Algorithm::Rfis, &cfg, generate(&cfg, Distribution::Zero));
        assert!(report.succeeded(), "{:?}", report.validation);
        assert_eq!(report.validation.imbalance.max_load, 8, "perfect balance on Zero");
    }

    #[test]
    fn rfis_sparse_single_elements() {
        let cfg = RunConfig::default().with_p(64).with_sparsity(3);
        let report = run(Algorithm::Rfis, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(report.succeeded(), "{:?}", report.validation);
    }

    #[test]
    fn rfis_one_element_per_pe() {
        let cfg = RunConfig::default().with_p(64).with_n_per_pe(1);
        for d in [Distribution::Uniform, Distribution::Zero, Distribution::Staggered] {
            let report = run(Algorithm::Rfis, &cfg, generate(&cfg, d));
            assert!(report.succeeded(), "{d:?}: {:?}", report.validation);
        }
    }

    #[test]
    fn rfis_latency_is_logarithmic() {
        // tiny input on many PEs: time must stay O(α·log p), way below α·√p
        let cfg = RunConfig::default().with_p(256).with_n_per_pe(1);
        let report = run(Algorithm::Rfis, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(report.succeeded());
        let alpha = cfg.cost.alpha;
        assert!(report.time < 40.0 * alpha, "time {} vs α {}", report.time, alpha);
    }

    #[test]
    fn rfis_small_p() {
        for p in [1usize, 2, 4] {
            let cfg = RunConfig::default().with_p(p).with_n_per_pe(8);
            let report = run(Algorithm::Rfis, &cfg, generate(&cfg, Distribution::RandDupl));
            assert!(report.succeeded(), "p={p}: {:?}", report.validation);
        }
    }
}
