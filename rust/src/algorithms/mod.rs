//! The sorting algorithms: the paper's four robust algorithms spanning the
//! input-size spectrum, every baseline of the evaluation, and the
//! nonrobust ablation variants of §VII-B.

pub mod all_gather_merge;
pub mod bitonic;
pub mod gather_merge;
pub mod hyksort;
pub mod mergesort;
pub mod minisort;
pub mod quick;
pub mod rams;
pub mod rfis;
pub mod selector;
pub mod ssort;

use crate::config::RunConfig;
use crate::elements::Elem;
use crate::localsort::{RustSort, SortBackend};
use crate::metrics::Stats;
use crate::sim::Machine;
use crate::verify::{validate, Validation};

/// Every algorithm of the evaluation (§VII).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Binomial-tree gather-merge to PE 0 — fastest for very sparse inputs.
    GatherM,
    /// Hypercube all-gather-merge — every PE ends with everything.
    AllGatherM,
    /// Robust fast work-inefficient sort (§V): √p×√p grid ranking with
    /// provenance tie-breaking + in-column delivery.
    Rfis,
    /// Robust hypercube quicksort (§VI, Algorithm 2).
    RQuick,
    /// RQuick without shuffle and without tie-breaking (Fig. 2a/2b).
    NtbQuick,
    /// Bitonic sort (Batcher/Johnsson) — the deterministic baseline.
    Bitonic,
    /// Robust multi-level AMS-sort (App. G).
    Rams,
    /// RAMS without splitter tie-breaking (Fig. 2b).
    NtbAms,
    /// RAMS without deterministic message assignment (Fig. 2c).
    NdmaAms,
    /// HykSort (Sundar et al. [6]) — k-way, sample splitters, nonrobust.
    HykSort,
    /// Single-level p-way sample sort with direct delivery.
    SSort,
    /// SSort with the splitter-selection phase not charged (Fig. 2d's
    /// lower bound for single-delivery algorithms).
    NsSSort,
    /// Minisort (Siebert & Wolf [2]): one element per PE (n = p).
    Minisort,
    /// Single-level multiway mergesort with exact splitters (Table I).
    Mways,
    /// The paper's headline: pick GatherM/RFIS/RQuick/RAMS by n/p.
    Robust,
}

impl Algorithm {
    pub const ALL: [Algorithm; 15] = [
        Algorithm::GatherM,
        Algorithm::AllGatherM,
        Algorithm::Rfis,
        Algorithm::RQuick,
        Algorithm::NtbQuick,
        Algorithm::Bitonic,
        Algorithm::Rams,
        Algorithm::NtbAms,
        Algorithm::NdmaAms,
        Algorithm::HykSort,
        Algorithm::SSort,
        Algorithm::NsSSort,
        Algorithm::Minisort,
        Algorithm::Mways,
        Algorithm::Robust,
    ];

    /// The eight algorithms Figure 1 compares.
    pub const FIG1: [Algorithm; 8] = [
        Algorithm::GatherM,
        Algorithm::AllGatherM,
        Algorithm::Rfis,
        Algorithm::RQuick,
        Algorithm::Bitonic,
        Algorithm::Rams,
        Algorithm::HykSort,
        Algorithm::SSort,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::GatherM => "GatherM",
            Algorithm::AllGatherM => "AllGatherM",
            Algorithm::Rfis => "RFIS",
            Algorithm::RQuick => "RQuick",
            Algorithm::NtbQuick => "NTB-Quick",
            Algorithm::Bitonic => "Bitonic",
            Algorithm::Rams => "RAMS",
            Algorithm::NtbAms => "NTB-AMS",
            Algorithm::NdmaAms => "NDMA-AMS",
            Algorithm::HykSort => "HykSort",
            Algorithm::SSort => "SSort",
            Algorithm::NsSSort => "NS-SSort",
            Algorithm::Minisort => "Minisort",
            Algorithm::Mways => "Mways",
            Algorithm::Robust => "Robust",
        }
    }

    pub fn parse(s: &str) -> Option<Algorithm> {
        Self::ALL.iter().copied().find(|a| {
            a.name().eq_ignore_ascii_case(s)
                || a.name().replace('-', "").eq_ignore_ascii_case(&s.replace(['-', '_'], ""))
        })
    }
}

/// How an algorithm leaves its output (drives validation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutputShape {
    /// (1+ε)·n/p per PE, globally sorted — the §II contract.
    Balanced,
    /// Everything on PE 0 (GatherM). Sorted but not balanced.
    RootOnly,
    /// Every PE holds the full sorted input (AllGatherM).
    Replicated,
}

/// Everything a single run reports (one point of a paper figure).
#[derive(Clone, Debug)]
pub struct RunReport {
    pub algorithm: Algorithm,
    /// Simulated makespan in model units (the paper's time axis).
    pub time: f64,
    pub stats: Stats,
    pub validation: Validation,
    pub output_shape: OutputShape,
    /// Crash description for nonrobust algorithms on hard instances.
    pub crashed: Option<String>,
    /// Host wallclock of the simulation (perf pass metric, ms).
    pub wall_ms: f64,
    pub is_globally_sorted: bool,
    /// The sorted output (per PE) — callers that permute satellite data
    /// (e.g. the SFC rebalancing example) consume this.
    pub output: Vec<Vec<Elem>>,
}

impl RunReport {
    /// A run "succeeded" in the paper's sense: no crash, correct output.
    pub fn succeeded(&self) -> bool {
        self.crashed.is_none() && self.validation.ok()
    }
}

/// Run `alg` on `input` under `cfg` with the pure-Rust local sorter.
pub fn run(alg: Algorithm, cfg: &RunConfig, input: Vec<Vec<Elem>>) -> RunReport {
    run_with_backend(alg, cfg, input, &mut RustSort)
}

/// Run `alg` with an explicit local-sort backend (e.g. the PJRT `XlaSort`
/// in [`crate::runtime`], available with the `xla` cargo feature).
pub fn run_with_backend(
    alg: Algorithm,
    cfg: &RunConfig,
    input: Vec<Vec<Elem>>,
    backend: &mut dyn SortBackend,
) -> RunReport {
    let mut mach = Machine::new(cfg.p, cfg.cost);
    mach.mem_cap_elems = cfg.mem_cap_elems();
    let reference = input.clone();
    let mut data = input;
    let start = std::time::Instant::now();

    let shape = match alg {
        Algorithm::GatherM => {
            gather_merge::sort(&mut mach, &mut data, cfg, backend);
            OutputShape::RootOnly
        }
        Algorithm::AllGatherM => {
            all_gather_merge::sort(&mut mach, &mut data, cfg, backend);
            OutputShape::Replicated
        }
        Algorithm::Rfis => {
            rfis::sort(&mut mach, &mut data, cfg, backend);
            OutputShape::Balanced
        }
        Algorithm::RQuick => {
            quick::sort(&mut mach, &mut data, cfg, backend, &quick::QuickConfig::robust());
            OutputShape::Balanced
        }
        Algorithm::NtbQuick => {
            quick::sort(&mut mach, &mut data, cfg, backend, &quick::QuickConfig::nonrobust());
            OutputShape::Balanced
        }
        Algorithm::Bitonic => {
            bitonic::sort(&mut mach, &mut data, cfg, backend);
            OutputShape::Balanced
        }
        Algorithm::Rams => {
            rams::sort(&mut mach, &mut data, cfg, backend, &rams::AmsConfig::robust(cfg));
            OutputShape::Balanced
        }
        Algorithm::NtbAms => {
            let c = rams::AmsConfig { tie_break: false, ..rams::AmsConfig::robust(cfg) };
            rams::sort(&mut mach, &mut data, cfg, backend, &c);
            OutputShape::Balanced
        }
        Algorithm::NdmaAms => {
            let c = rams::AmsConfig { dma: rams::Dma::Never, ..rams::AmsConfig::robust(cfg) };
            rams::sort(&mut mach, &mut data, cfg, backend, &c);
            OutputShape::Balanced
        }
        Algorithm::HykSort => {
            hyksort::sort(&mut mach, &mut data, cfg, backend, &hyksort::HykConfig::default());
            OutputShape::Balanced
        }
        Algorithm::SSort => {
            ssort::sort(&mut mach, &mut data, cfg, backend, true);
            OutputShape::Balanced
        }
        Algorithm::NsSSort => {
            ssort::sort(&mut mach, &mut data, cfg, backend, false);
            OutputShape::Balanced
        }
        Algorithm::Minisort => {
            minisort::sort(&mut mach, &mut data, cfg, backend);
            OutputShape::Balanced
        }
        Algorithm::Mways => {
            mergesort::sort(&mut mach, &mut data, cfg, backend);
            OutputShape::Balanced
        }
        Algorithm::Robust => selector::sort(&mut mach, &mut data, cfg, backend),
    };

    let wall_ms = start.elapsed().as_secs_f64() * 1e3;
    let crashed = mach.crash().map(|c| c.to_string());

    // validate according to the output shape
    let validation = match shape {
        OutputShape::Balanced => validate(&reference, &data, cfg.epsilon),
        OutputShape::RootOnly => {
            let mut proj = vec![Vec::new(); cfg.p];
            proj[0] = data[0].clone();
            let mut v = validate(&reference, &proj, f64::INFINITY);
            v.balanced = false; // by construction
            v
        }
        OutputShape::Replicated => {
            // every PE must hold the identical full sorted input
            let mut proj = vec![Vec::new(); cfg.p];
            proj[0] = data[0].clone();
            let mut v = validate(&reference, &proj, f64::INFINITY);
            v.balanced = false;
            let all_equal = data.iter().all(|d| d == &data[0]);
            v.globally_sorted &= all_equal;
            v
        }
    };

    RunReport {
        algorithm: alg,
        time: mach.time(),
        stats: mach.stats,
        is_globally_sorted: validation.globally_sorted && crashed.is_none(),
        validation,
        output_shape: shape,
        crashed,
        wall_ms,
        output: data,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_algorithm_names() {
        assert_eq!(Algorithm::parse("rquick"), Some(Algorithm::RQuick));
        assert_eq!(Algorithm::parse("NTB-Quick"), Some(Algorithm::NtbQuick));
        assert_eq!(Algorithm::parse("ntbquick"), Some(Algorithm::NtbQuick));
        assert_eq!(Algorithm::parse("ns_ssort"), Some(Algorithm::NsSSort));
        assert_eq!(Algorithm::parse("bogus"), None);
    }

    /// `name()` → `parse` must round-trip for every variant, and parsing
    /// must be insensitive to ASCII case and to `-`/`_` separators.
    #[test]
    fn parse_round_trips_every_variant() {
        assert_eq!(Algorithm::ALL.len(), 15);
        for a in Algorithm::ALL {
            let name = a.name();
            assert_eq!(Algorithm::parse(name), Some(a), "{name}");
            assert_eq!(Algorithm::parse(&name.to_lowercase()), Some(a), "{name} lower");
            assert_eq!(Algorithm::parse(&name.to_uppercase()), Some(a), "{name} upper");
            assert_eq!(
                Algorithm::parse(&name.replace('-', "_")),
                Some(a),
                "{name} with underscores"
            );
            assert_eq!(
                Algorithm::parse(&name.replace('-', "")),
                Some(a),
                "{name} separators stripped"
            );
        }
    }

    /// Parsing is case- and separator-insensitive, so the *normalized*
    /// names must be unique or `parse` would silently return the first
    /// match for an ambiguous input.
    #[test]
    fn algorithm_names_are_unique_after_normalization() {
        let mut names: Vec<String> = Algorithm::ALL
            .iter()
            .map(|a| a.name().to_ascii_lowercase().replace(['-', '_'], ""))
            .collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Algorithm::ALL.len());
    }
}
