//! The sorting algorithms: the paper's four robust algorithms spanning the
//! input-size spectrum, every baseline of the evaluation, and the
//! nonrobust ablation variants of §VII-B.
//!
//! Algorithms are first-class values implementing the [`Sorter`] trait
//! (defined in [`sorter`], one implementation per algorithm file) and are
//! enumerated through the [`registry`]; runs are built and batched through
//! the [`Runner`]. The [`Algorithm`] enum remains as a compact tag for the
//! paper's fixed evaluation set, and the [`run`]/[`run_with_backend`] free
//! functions remain as thin shims over the `Runner` core — byte-identical
//! reports, asserted in `rust/tests/runner_equivalence.rs`.

pub mod all_gather_merge;
pub mod ams;
pub mod bitonic;
pub mod gather_merge;
pub mod hyksort;
pub mod mergesort;
pub mod minisort;
pub mod quick;
pub mod rams;
pub mod rfis;
pub mod runner;
pub mod selector;
pub mod sorter;
pub mod ssort;

pub use runner::Runner;
pub use sorter::{
    builtin_sorters, find_sorter, normalize, register, registry, RegisterError, Sorter,
};

use crate::config::RunConfig;
use crate::elements::Elem;
use crate::localsort::{default_backend, SortBackend};
use crate::metrics::Stats;
use crate::sim::Machine;
use crate::verify::Validation;

/// Every algorithm of the evaluation (§VII).
///
/// A tag for the fixed built-in set — each variant's behaviour (and its
/// name, shape, and robustness metadata) lives in the [`Sorter`] value
/// behind [`Algorithm::sorter`]. New algorithms implement [`Sorter`] and
/// go through [`register`]/[`find_sorter`]; they do not get enum variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Binomial-tree gather-merge to PE 0 — fastest for very sparse inputs.
    GatherM,
    /// Hypercube all-gather-merge — every PE ends with everything.
    AllGatherM,
    /// Robust fast work-inefficient sort (§V): √p×√p grid ranking with
    /// provenance tie-breaking + in-column delivery.
    Rfis,
    /// Robust hypercube quicksort (§VI, Algorithm 2).
    RQuick,
    /// RQuick without shuffle and without tie-breaking (Fig. 2a/2b).
    NtbQuick,
    /// Bitonic sort (Batcher/Johnsson) — the deterministic baseline.
    Bitonic,
    /// Robust multi-level AMS-sort (App. G).
    Rams,
    /// RAMS without splitter tie-breaking (Fig. 2b).
    NtbAms,
    /// RAMS without deterministic message assignment (Fig. 2c).
    NdmaAms,
    /// HykSort (Sundar et al. [6]) — k-way, sample splitters, nonrobust.
    HykSort,
    /// Single-level p-way sample sort with direct delivery.
    SSort,
    /// SSort with the splitter-selection phase not charged (Fig. 2d's
    /// lower bound for single-delivery algorithms).
    NsSSort,
    /// Minisort (Siebert & Wolf [2]): one element per PE (n = p).
    Minisort,
    /// Single-level multiway mergesort with exact splitters (Table I).
    Mways,
    /// The paper's headline: pick GatherM/RFIS/RQuick/RAMS by n/p.
    Robust,
}

impl Algorithm {
    pub const ALL: [Algorithm; 15] = [
        Algorithm::GatherM,
        Algorithm::AllGatherM,
        Algorithm::Rfis,
        Algorithm::RQuick,
        Algorithm::NtbQuick,
        Algorithm::Bitonic,
        Algorithm::Rams,
        Algorithm::NtbAms,
        Algorithm::NdmaAms,
        Algorithm::HykSort,
        Algorithm::SSort,
        Algorithm::NsSSort,
        Algorithm::Minisort,
        Algorithm::Mways,
        Algorithm::Robust,
    ];

    /// The eight algorithms Figure 1 (and the empirical Table I) compares.
    pub const FIG1: [Algorithm; 8] = [
        Algorithm::GatherM,
        Algorithm::AllGatherM,
        Algorithm::Rfis,
        Algorithm::RQuick,
        Algorithm::Bitonic,
        Algorithm::Rams,
        Algorithm::HykSort,
        Algorithm::SSort,
    ];

    /// Display name. Kept as a literal match (no allocation — this sits in
    /// bench labels and parse loops); agreement with each sorter's own
    /// [`Sorter::name`] is pinned by `sorter::tests::
    /// builtin_sorter_names_match_enum`.
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::GatherM => "GatherM",
            Algorithm::AllGatherM => "AllGatherM",
            Algorithm::Rfis => "RFIS",
            Algorithm::RQuick => "RQuick",
            Algorithm::NtbQuick => "NTB-Quick",
            Algorithm::Bitonic => "Bitonic",
            Algorithm::Rams => "RAMS",
            Algorithm::NtbAms => "NTB-AMS",
            Algorithm::NdmaAms => "NDMA-AMS",
            Algorithm::HykSort => "HykSort",
            Algorithm::SSort => "SSort",
            Algorithm::NsSSort => "NS-SSort",
            Algorithm::Minisort => "Minisort",
            Algorithm::Mways => "Mways",
            Algorithm::Robust => "Robust",
        }
    }

    /// Resolve a name to a built-in tag, insensitive to ASCII case and to
    /// `-`/`_` separators. For external (registered) sorters use
    /// [`find_sorter`], which the CLI resolves `--algo` through.
    pub fn parse(s: &str) -> Option<Algorithm> {
        let key = sorter::normalize(s);
        Self::ALL.iter().copied().find(|a| sorter::normalize(a.name()) == key)
    }
}

/// How an algorithm leaves its output (drives validation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutputShape {
    /// (1+ε)·n/p per PE, globally sorted — the §II contract.
    Balanced,
    /// Everything on PE 0 (GatherM). Sorted but not balanced.
    RootOnly,
    /// Every PE holds the full sorted input (AllGatherM).
    Replicated,
}

/// Everything a single run reports (one point of a paper figure).
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Registry name of the sorter that produced this report
    /// ([`Sorter::name`]).
    pub algorithm: &'static str,
    /// Simulated makespan in model units (the paper's time axis).
    pub time: f64,
    pub stats: Stats,
    /// All-false `Validation::default()` when the run was executed with
    /// [`Runner::validate`]`(false)` — "not validated", not "invalid".
    pub validation: Validation,
    pub output_shape: OutputShape,
    /// Crash description for nonrobust algorithms on hard instances.
    pub crashed: Option<String>,
    /// Host wallclock of the simulation alone (perf pass metric, ms) —
    /// validation and the reference clone are outside the timed window.
    pub wall_ms: f64,
    pub is_globally_sorted: bool,
    /// The sorted output (per PE) — callers that permute satellite data
    /// (e.g. the SFC rebalancing example) consume this. Empty when the run
    /// was executed with [`Runner::keep_output`]`(false)`.
    pub output: Vec<Vec<Elem>>,
}

impl RunReport {
    /// A run "succeeded" in the paper's sense: no crash, correct output.
    pub fn succeeded(&self) -> bool {
        self.crashed.is_none() && self.validation.ok()
    }
}

/// Run `alg` on `input` under `cfg` with the process-default local
/// sorter ([`crate::localsort::default_backend`]).
///
/// Legacy shim over [`Runner`] (validation on, output kept — the historic
/// defaults); byte-identical to `Runner::new(cfg.clone()).run_algorithm()`.
pub fn run(alg: Algorithm, cfg: &RunConfig, input: Vec<Vec<Elem>>) -> RunReport {
    run_with_backend(alg, cfg, input, default_backend().as_mut())
}

/// Run `alg` with an explicit local-sort backend (e.g. the PJRT `XlaSort`
/// in [`crate::runtime`], available with the `xla` cargo feature).
///
/// Legacy shim over the [`Runner`] core — see [`run`].
pub fn run_with_backend(
    alg: Algorithm,
    cfg: &RunConfig,
    input: Vec<Vec<Elem>>,
    backend: &mut dyn SortBackend,
) -> RunReport {
    run_sorter_with_backend(alg.sorter().as_ref(), cfg, input, backend)
}

/// One-shot run of any [`Sorter`] with an explicit backend (the borrow-y
/// sibling of [`Runner::run`] for callers that own neither a runner nor a
/// boxed backend).
pub fn run_sorter_with_backend(
    sorter: &dyn Sorter,
    cfg: &RunConfig,
    input: Vec<Vec<Elem>>,
    backend: &mut dyn SortBackend,
) -> RunReport {
    let mut mach = Machine::new(cfg.p, cfg.cost);
    mach.mem_cap_elems = cfg.mem_cap_elems();
    runner::execute(&mut mach, cfg, sorter, backend, input, true, true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_algorithm_names() {
        assert_eq!(Algorithm::parse("rquick"), Some(Algorithm::RQuick));
        assert_eq!(Algorithm::parse("NTB-Quick"), Some(Algorithm::NtbQuick));
        assert_eq!(Algorithm::parse("ntbquick"), Some(Algorithm::NtbQuick));
        assert_eq!(Algorithm::parse("ns_ssort"), Some(Algorithm::NsSSort));
        assert_eq!(Algorithm::parse("bogus"), None);
    }

    /// `name()` → `parse` must round-trip for every variant, and parsing
    /// must be insensitive to ASCII case and to `-`/`_` separators.
    #[test]
    fn parse_round_trips_every_variant() {
        assert_eq!(Algorithm::ALL.len(), 15);
        for a in Algorithm::ALL {
            let name = a.name();
            assert_eq!(Algorithm::parse(name), Some(a), "{name}");
            assert_eq!(Algorithm::parse(&name.to_lowercase()), Some(a), "{name} lower");
            assert_eq!(Algorithm::parse(&name.to_uppercase()), Some(a), "{name} upper");
            assert_eq!(
                Algorithm::parse(&name.replace('-', "_")),
                Some(a),
                "{name} with underscores"
            );
            assert_eq!(
                Algorithm::parse(&name.replace('-', "")),
                Some(a),
                "{name} separators stripped"
            );
        }
    }

    /// Parsing is case- and separator-insensitive, so the *normalized*
    /// names must be unique or `parse` would silently return the first
    /// match for an ambiguous input.
    #[test]
    fn algorithm_names_are_unique_after_normalization() {
        let mut names: Vec<String> =
            Algorithm::ALL.iter().map(|a| sorter::normalize(a.name())).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Algorithm::ALL.len());
    }
}
