//! HykSort (Sundar et al. [6]) reimplemented from the ICS'13 description:
//! k-way hypercube quicksort with sample-based splitter selection.
//!
//! Faithful to the paper's robustness profile:
//! * splitters are selected from *key-only* samples — no tie-breaking, so
//!   duplicate-heavy instances (DeterDupl, Zero, RandDupl) overload one
//!   bucket until the memory cap trips ("HykSort crashes");
//! * every level pays the `MPI_Comm_Split` cost, whose implementations
//!   need Ω(β·q) — the "≥" in Table I;
//! * "almost" robust against skew: sampling adapts to the distribution,
//!   but there is no shuffle, so worst-case placements still imbalance.

use crate::config::RunConfig;
use crate::elements::{multiway_merge_into, Elem, Key};
use crate::localsort::{sort_all, SortBackend};
use crate::rng::Rng;
use crate::sim::{all_gather_merge, Cube, Machine, ParSpec};

use super::{OutputShape, Sorter};

#[derive(Clone, Copy, Debug)]
pub struct HykConfig {
    /// way-ness per level (the paper tunes k = 32 on JUQUEEN).
    pub k: usize,
    /// samples per PE per level.
    pub sample_per_pe: usize,
}

impl Default for HykConfig {
    fn default() -> Self {
        Self { k: 32, sample_per_pe: 24 }
    }
}

pub fn sort(
    mach: &mut Machine,
    data: &mut Vec<Vec<Elem>>,
    cfg: &RunConfig,
    backend: &mut dyn SortBackend,
    hc: &HykConfig,
) {
    let p = cfg.p;
    assert!(p.is_power_of_two());
    let mut rng = Rng::seeded(cfg.seed ^ 0x4859_4B53, 3);

    sort_all(mach, data, backend);

    let mut groups = vec![Cube::whole(p)];
    while groups[0].dim > 0 {
        let mut next = Vec::new();
        for group in &groups {
            level(mach, group, data, cfg, hc, &mut rng, &mut next);
            if mach.crashed() {
                return;
            }
        }
        groups = next;
    }
}

fn level(
    mach: &mut Machine,
    group: &Cube,
    data: &mut [Vec<Elem>],
    cfg: &RunConfig,
    hc: &HykConfig,
    rng: &mut Rng,
    next: &mut Vec<Cube>,
) {
    let q = group.size();
    let pes = group.pe_vec();
    let logk = (hc.k.max(2).trailing_zeros()).min(group.dim);
    let k = 1usize << logk;
    let subgroups = group.split_k(logk);
    next.extend(subgroups.iter().copied());

    // MPI_Comm_Split: Ω(β·q) per level (the Table I "≥")
    let split_cost = cfg.cost.alpha * (q.max(2) as f64).log2() + cfg.cost.beta * q as f64;
    for &pe in &pes {
        mach.work(pe, split_cost);
    }

    // --- sample-based splitter selection (key-only: nonrobust) -------
    let mut samples: Vec<Vec<Elem>> = vec![Vec::new(); data.len()];
    // keep the replicated sample within the per-PE memory budget
    let budget = mach.mem_cap_elems.unwrap_or(usize::MAX).min(hc.sample_per_pe * q) / 2;
    let per_pe_cap = (budget / q).max(1);
    for &pe in &pes {
        let local = &data[pe];
        let take = hc.sample_per_pe.min(per_pe_cap).min(local.len());
        for _ in 0..take {
            samples[pe].push(local[rng.below(local.len() as u64) as usize]);
        }
        samples[pe].sort_unstable_by_key(|e| e.key);
        mach.work_sort(pe, take);
    }
    let gathered = all_gather_merge(mach, &pes, &samples);
    let sorted_samples = gathered[0].merged();
    let splitters: Vec<Key> = (1..k)
        .map(|i| {
            if sorted_samples.is_empty() {
                Key::MAX
            } else {
                sorted_samples[(i * sorted_samples.len() / k).min(sorted_samples.len() - 1)].key
            }
        })
        .collect();

    // --- partition (key-only) and k-way exchange ----------------------
    // every bucket is posted straight to its target PE: the data plane
    // coalesces, charges the irregular round, and delivers — no
    // per-level outgoing/incoming tables. Bucket building runs as one PE
    // task per member; posting keeps the historical (rank, bucket) order.
    let q_sub = q / k;
    let base = group.base();
    let total: usize = pes.iter().map(|&pe| data[pe].len()).sum();
    let outs: Vec<Vec<Vec<Elem>>> =
        mach.par_pes(base, ParSpec::work(total).bufs(k + 1), &mut data[base..base + q], |ctx, slot| {
            let local = std::mem::take(slot);
            ctx.work_classify(local.len(), k);
            let mut buckets: Vec<Vec<Elem>> = (0..k).map(|_| ctx.take_buf()).collect();
            for &e in &local {
                let b = splitters.partition_point(|&s| s < e.key);
                buckets[b].push(e);
            }
            ctx.recycle_buf(local);
            buckets
        });
    let mut ex = mach.exchange();
    for (r, buckets) in outs.into_iter().enumerate() {
        // bucket b goes to subgroup b, target rank = own rank within sub
        for (b, bucket) in buckets.into_iter().enumerate() {
            let target = subgroups[b].pe(r % q_sub);
            ex.post(pes[r], target, bucket);
        }
    }
    let inboxes = ex.deliver(mach);
    let total_recv: usize = pes.iter().map(|&pe| inboxes.total(pe)).sum();
    mach.par_pes(base, ParSpec::work(2 * total_recv).bufs(1), &mut data[base..base + q], |ctx, slot| {
        let refs: Vec<&[Elem]> = inboxes.runs(ctx.pe()).iter().map(|(_, v)| v.as_slice()).collect();
        let mut merged = ctx.take_buf();
        multiway_merge_into(&refs, &mut merged, ctx.merge_scratch());
        ctx.work(cfg.cost.cmp * merged.len() as f64 * (refs.len().max(2) as f64).log2());
        ctx.note_mem(merged.len(), "HykSort k-way exchange");
        *slot = merged;
    });
    mach.recycle(inboxes);
}

/// [`Sorter`]: HykSort — k-way hypercube quicksort with key-only sample
/// splitters; nonrobust on duplicate-heavy instances by design.
#[derive(Clone, Copy, Debug, Default)]
pub struct HykSorter {
    pub config: HykConfig,
}

impl HykSorter {
    /// A custom (k, sample rate) configuration (tuning sweeps).
    pub fn with_config(config: HykConfig) -> Self {
        Self { config }
    }
}

impl Sorter for HykSorter {
    fn name(&self) -> &'static str {
        "HykSort"
    }

    fn output_shape(&self) -> OutputShape {
        OutputShape::Balanced
    }

    fn is_robust(&self) -> bool {
        false
    }

    fn sort(
        &self,
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) -> OutputShape {
        self::sort(mach, data, cfg, backend, &self.config);
        OutputShape::Balanced
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, Algorithm};
    use crate::input::{generate, Distribution};

    #[test]
    fn hyksort_sorts_uniform() {
        let cfg = RunConfig::default().with_p(64).with_n_per_pe(256);
        let report = run(Algorithm::HykSort, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(report.validation.ok(), "{:?}", report.validation);
        assert!(report.crashed.is_none());
    }

    #[test]
    fn hyksort_moves_data_fewer_times_than_rquick() {
        // log_k p levels vs log p levels → lower comm volume for large n/p
        let cfg = RunConfig::default().with_p(64).with_n_per_pe(1024);
        let h = run(Algorithm::HykSort, &cfg, generate(&cfg, Distribution::Uniform));
        let r = run(Algorithm::RQuick, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(h.stats.words < r.stats.words, "Hyk {} vs RQuick {}", h.stats.words, r.stats.words);
    }

    #[test]
    fn hyksort_crashes_on_duplicates() {
        let mut cfg = RunConfig::default().with_p(64).with_n_per_pe(512);
        cfg.mem_cap_factor = Some(8.0);
        let z = run(Algorithm::HykSort, &cfg, generate(&cfg, Distribution::Zero));
        let bad = z.crashed.is_some() || !z.validation.balanced;
        assert!(bad, "HykSort must collapse on Zero: {:?}", z.validation.imbalance);
        let d = run(Algorithm::HykSort, &cfg, generate(&cfg, Distribution::DeterDupl));
        let bad = d.crashed.is_some() || !d.validation.balanced;
        assert!(bad, "HykSort must collapse on DeterDupl: {:?}", d.validation.imbalance);
    }
}
