//! Hypercube quicksort, parametrized to cover the whole family:
//!
//! * **RQuick** (§VI, Algorithm 2): initial hypercube random shuffle,
//!   k-window single-reduction median (§III-B), and the local duplicate
//!   split `a = aℓ·s^m·a_r → L = aℓ·s^x`, `R = s^(m−x)·a_r` with `x`
//!   chosen to bring `|L|` closest to `|a|/2` — tie-breaking with zero
//!   communicated bytes.
//! * **NTB-Quick** (Fig. 2a/2b): no shuffle, no tie-breaking — duplicates
//!   and skew pile up until the memory cap trips (the paper's OOM).
//! * Wagar's original pivot (PE 0's local median) and Lan & Mohamed's
//!   median-of-medians (the `β·p` Table I row) as pivot strategies.

use crate::config::RunConfig;
use crate::elements::{merge_into, Elem, Key};
use crate::localsort::{sort_all, SortBackend};
use crate::median::median_binary;
use crate::rng::Rng;
use crate::shuffle::hypercube_shuffle;
use crate::sim::{bcast_cost, Cube, Machine, ParSpec};

use super::{OutputShape, Sorter};

/// Pivot selection strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pivot {
    /// §III-B k-window single-reduction median — fast *and* accurate.
    Window,
    /// Wagar's hyperquicksort: cube rank 0 broadcasts its local median.
    Pe0LocalMedian,
    /// Lan & Mohamed: global median of all local medians (adds β·q).
    MedianOfMedians,
}

/// Knobs distinguishing RQuick from its ablations.
#[derive(Clone, Copy, Debug)]
pub struct QuickConfig {
    pub shuffle: bool,
    pub tie_break: bool,
    pub pivot: Pivot,
    /// k-window width for [`Pivot::Window`].
    pub window_k: usize,
}

impl QuickConfig {
    /// The paper's RQuick.
    pub fn robust() -> Self {
        Self { shuffle: true, tie_break: true, pivot: Pivot::Window, window_k: 16 }
    }

    /// NTB-Quick: same median selection, no shuffle, no tie-breaking —
    /// isolating exactly the two robustness measures of Fig. 2a/2b.
    pub fn nonrobust() -> Self {
        Self { shuffle: false, tie_break: false, pivot: Pivot::Window, window_k: 16 }
    }
}

/// Split a sorted run at the splitter. Tie-breaking picks `x` dup copies
/// for the left side so `|L|` lands closest to `|a|/2`; the nonrobust
/// split sends *all* duplicates right (Wagar's convention).
fn split_run(a: &[Elem], s: Key, tie_break: bool) -> (usize, usize) {
    // lo = #keys < s, hi = #keys ≤ s  (binary searches on the sorted run)
    let lo = a.partition_point(|e| e.key < s);
    let hi = a.partition_point(|e| e.key <= s);
    if !tie_break {
        return (lo, lo); // cut before the duplicates: all `s` go right
    }
    let m = hi - lo;
    let desired = a.len() / 2;
    let x = desired.saturating_sub(lo).min(m);
    (lo, lo + x)
}

/// Select the pivot for one subcube, pricing the selection.
fn select_pivot(
    mach: &mut Machine,
    pes: &[usize],
    data: &[Vec<Elem>],
    qc: &QuickConfig,
    rng: &mut Rng,
) -> Option<Key> {
    match qc.pivot {
        Pivot::Window => median_binary(mach, pes, data, qc.window_k, rng),
        Pivot::Pe0LocalMedian => {
            // Wagar: rank 0 broadcasts its local median (skew-fragile)
            let local = &data[pes[0]];
            let s = local.get(local.len() / 2).map(|e| e.key);
            bcast_cost(mach, pes, 0, 1);
            // if rank 0 is empty the subcube's split degenerates; fall back
            // to any member's median like practical implementations do
            s.or_else(|| {
                pes.iter()
                    .find_map(|&pe| data[pe].get(data[pe].len() / 2).map(|e| e.key))
            })
        }
        Pivot::MedianOfMedians => {
            // binomial gather of local medians (message sizes double → β·q)
            let q = pes.len();
            let dim = q.trailing_zeros();
            let mut have: Vec<usize> = vec![1; q];
            for j in 0..dim {
                let bit = 1usize << j;
                for r in 0..q {
                    if r & bit != 0 && r & (bit - 1) == 0 {
                        let dst = r & !bit;
                        mach.send(pes[r], pes[dst], have[r]);
                        have[dst] += have[r];
                    }
                }
            }
            let mut meds: Vec<Key> = pes
                .iter()
                .filter_map(|&pe| data[pe].get(data[pe].len() / 2).map(|e| e.key))
                .collect();
            if meds.is_empty() {
                return None;
            }
            meds.sort_unstable();
            mach.work_sort(pes[0], q);
            bcast_cost(mach, pes, 0, 1);
            Some(meds[meds.len() / 2])
        }
    }
}

/// Hypercube quicksort main loop (Algorithm 2). `data` is indexed by
/// global PE; local runs must end sorted (they do: merge maintains order).
pub fn sort(
    mach: &mut Machine,
    data: &mut Vec<Vec<Elem>>,
    cfg: &RunConfig,
    backend: &mut dyn SortBackend,
    qc: &QuickConfig,
) {
    let p = cfg.p;
    assert!(p.is_power_of_two());
    let mut rng = Rng::seeded(cfg.seed ^ 0x5157_4943, 1);

    if qc.shuffle {
        hypercube_shuffle(mach, Cube::whole(p), data, &mut rng);
    }
    sort_all(mach, data, backend);

    let mut cubes = vec![Cube::whole(p)];
    while cubes[0].dim > 0 {
        let mut next = Vec::with_capacity(cubes.len() * 2);
        for cube in &cubes {
            let pes = cube.pe_vec();
            if let Some(s) = select_pivot(mach, &pes, data, qc, &mut rng) {
                exchange_level(mach, cube, data, s, qc.tie_break);
            }
            // ISEMPTY(s): nothing to split — members keep (empty) data
            let (lo, hi) = cube.split();
            next.push(lo);
            next.push(hi);
            if mach.crashed() {
                return;
            }
        }
        cubes = next;
    }
}

/// One quicksort exchange along the cube's highest dimension.
fn exchange_level(mach: &mut Machine, cube: &Cube, data: &mut [Vec<Elem>], s: Key, tie_break: bool) {
    let j = cube.dim - 1;
    let bit = 1usize << j;
    let size = cube.size();
    let base = cube.base();
    let total: usize = data[base..base + size].iter().map(Vec::len).sum();
    // split + outgoing-half staging, one PE task per member (settled in
    // PE order — the historical split-loop charge sequence)
    let outs: Vec<Vec<Elem>> = mach.par_pes(
        base,
        ParSpec::work(total).bufs(1),
        &mut data[base..base + size],
        |ctx, run| {
            let (_, cut) = split_run(run, s, tie_break);
            ctx.work(2.0 * (run.len().max(2) as f64).log2()); // two binary searches
            let keep_low = ctx.rank() & bit == 0;
            let mut out = ctx.take_buf();
            if keep_low {
                out.extend_from_slice(&run[cut..]); // ship R
                run.truncate(cut);
            } else {
                out.extend_from_slice(&run[..cut]); // ship L, keep R
                let keep = run.len() - cut;
                run.copy_within(cut.., 0);
                run.truncate(keep);
            }
            out
        },
    );
    // pairwise exchange through the data plane: the low partner ships its
    // R half, the high partner its L half, in one pooled payload each —
    // charging and movement are the same call
    let mut ex = mach.exchange();
    for (r, out) in outs.into_iter().enumerate() {
        ex.xchg_leg(base + r, base + (r ^ bit), out);
    }
    let inboxes = ex.deliver(mach);
    let total_recv: usize = (0..size).map(|r| inboxes.total(base + r)).sum();
    mach.par_pes(
        base,
        ParSpec::work(total + total_recv).bufs(1),
        &mut data[base..base + size],
        |ctx, run| {
            let mut merged = ctx.take_buf();
            merge_into(run, inboxes.single(ctx.pe()), &mut merged);
            ctx.recycle_buf(std::mem::replace(run, merged));
            ctx.work_linear(run.len());
            ctx.note_mem(run.len(), "quicksort exchange");
        },
    );
    mach.recycle(inboxes);
}

/// [`Sorter`] for the hypercube-quicksort family: the robust **RQuick**
/// (§VI, Algorithm 2) and the **NTB-Quick** ablation are two values of
/// this type, distinguished by the [`QuickConfig`] they carry.
#[derive(Clone, Copy, Debug)]
pub struct RQuickSorter {
    pub config: QuickConfig,
    name: &'static str,
}

impl RQuickSorter {
    /// The paper's RQuick: shuffle + window median + duplicate split.
    pub fn robust() -> Self {
        Self { config: QuickConfig::robust(), name: "RQuick" }
    }

    /// NTB-Quick: no shuffle, no tie-breaking (Fig. 2a/2b).
    pub fn nonrobust() -> Self {
        Self { config: QuickConfig::nonrobust(), name: "NTB-Quick" }
    }

    /// A custom configuration under the RQuick name (tuning sweeps).
    pub fn with_config(config: QuickConfig) -> Self {
        Self { config, name: "RQuick" }
    }
}

impl Sorter for RQuickSorter {
    fn name(&self) -> &'static str {
        self.name
    }

    fn output_shape(&self) -> OutputShape {
        OutputShape::Balanced
    }

    fn is_robust(&self) -> bool {
        self.config.shuffle && self.config.tie_break
    }

    fn sort(
        &self,
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) -> OutputShape {
        self::sort(mach, data, cfg, backend, &self.config);
        OutputShape::Balanced
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, Algorithm};
    use crate::input::{generate, Distribution};

    #[test]
    fn split_run_tiebreak_balances_duplicates() {
        let a: Vec<Elem> = (0..8).map(|i| Elem::with_id(5, i)).collect();
        // all keys equal the splitter: tie-break puts half left
        assert_eq!(split_run(&a, 5, true), (0, 4));
        // nonrobust: everything right
        assert_eq!(split_run(&a, 5, false), (0, 0));
    }

    #[test]
    fn split_run_mixed() {
        let keys = [1u64, 2, 5, 5, 5, 7, 9, 9];
        let a: Vec<Elem> = keys.iter().enumerate().map(|(i, &k)| Elem::with_id(k, i as u64)).collect();
        // lo=2, m=3, desired=4 → x=2 → cut=4
        assert_eq!(split_run(&a, 5, true), (2, 4));
        assert_eq!(split_run(&a, 5, false), (2, 2));
        assert_eq!(split_run(&a, 0, true), (0, 0));
        assert_eq!(split_run(&a, 100, true), (8, 8));
    }

    #[test]
    fn rquick_sorts_uniform() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(64);
        let input = generate(&cfg, Distribution::Uniform);
        let report = run(Algorithm::RQuick, &cfg, input);
        assert!(report.succeeded(), "{:?} {:?}", report.crashed, report.validation);
        assert!(report.validation.balanced, "imbalance {:?}", report.validation.imbalance);
    }

    #[test]
    fn rquick_sorts_every_distribution() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(32);
        for d in Distribution::ALL {
            let report = run(Algorithm::RQuick, &cfg, generate(&cfg, d));
            assert!(report.succeeded(), "{d:?}: {:?} {:?}", report.crashed, report.validation);
        }
    }

    #[test]
    fn rquick_handles_sparse_inputs() {
        let cfg = RunConfig::default().with_p(32).with_sparsity(3);
        let report = run(Algorithm::RQuick, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(report.succeeded(), "{:?}", report.validation);
    }

    #[test]
    fn ntb_quick_fine_on_uniform_unique() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(64);
        let report = run(Algorithm::NtbQuick, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(report.validation.ok(), "{:?}", report.validation);
    }

    #[test]
    fn ntb_quick_collapses_on_duplicates() {
        // Zero: every key identical → without tie-breaking one side of every
        // split gets everything
        let mut cfg = RunConfig::default().with_p(16).with_n_per_pe(256);
        cfg.mem_cap_factor = Some(4.0);
        let report = run(Algorithm::NtbQuick, &cfg, generate(&cfg, Distribution::Zero));
        let blew_up = report.crashed.is_some()
            || report.validation.imbalance.epsilon > 3.0
            || !report.validation.balanced;
        assert!(blew_up, "NTB-Quick should collapse: {:?}", report.validation.imbalance);
    }

    #[test]
    fn rquick_beats_ntb_on_mirrored_skew() {
        let cfg = RunConfig::default().with_p(64).with_n_per_pe(128);
        let r = run(Algorithm::RQuick, &cfg, generate(&cfg, Distribution::Mirrored));
        let n = run(Algorithm::NtbQuick, &cfg, generate(&cfg, Distribution::Mirrored));
        assert!(r.succeeded());
        // NTB either crashes, is unbalanced, or is much slower
        let ntb_bad = n.crashed.is_some()
            || !n.validation.balanced
            || n.time > 1.5 * r.time;
        assert!(ntb_bad, "RQuick {} vs NTB {} (imb {:?})", r.time, n.time, n.validation.imbalance);
    }

    #[test]
    fn wagar_pivot_works_on_uniform() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(128);
        let mut mach = Machine::new(cfg.p, cfg.cost);
        let mut data = generate(&cfg, Distribution::Uniform);
        let reference = data.clone();
        let qc = QuickConfig { pivot: Pivot::Pe0LocalMedian, ..QuickConfig::robust() };
        sort(&mut mach, &mut data, &cfg, &mut crate::localsort::RustSort, &qc);
        let v = crate::verify::validate(&reference, &data, 1.0);
        assert!(v.ok(), "{v:?}");
    }

    #[test]
    fn median_of_medians_pivot_sorts_correctly() {
        let cfg = RunConfig::default().with_p(64).with_n_per_pe(16);
        let mut mach = Machine::new(cfg.p, cfg.cost);
        let mut data = generate(&cfg, Distribution::Uniform);
        let reference = data.clone();
        let qc = QuickConfig { pivot: Pivot::MedianOfMedians, ..QuickConfig::robust() };
        sort(&mut mach, &mut data, &cfg, &mut crate::localsort::RustSort, &qc);
        let v = crate::verify::validate(&reference, &data, 1.0);
        assert!(v.ok(), "{v:?}");
    }

    #[test]
    fn median_of_medians_pivot_latency_grows_linearly() {
        // the Table I "+median of medians" β·p term: pivot selection cost
        // on an otherwise idle machine grows ~linearly in p, while the
        // §III-B window reduction grows only logarithmically
        let pivot_cost = |p: usize, pivot: Pivot| {
            let cfg = RunConfig::default().with_p(p).with_n_per_pe(4);
            let mut mach = Machine::new(p, cfg.cost);
            let mut data = generate(&cfg, Distribution::Uniform);
            for run in data.iter_mut() {
                run.sort_unstable(); // select_pivot expects sorted locals
            }
            let mut rng = crate::rng::Rng::seeded(1, 1);
            let qc = QuickConfig { pivot, ..QuickConfig::robust() };
            let pes: Vec<usize> = (0..p).collect();
            select_pivot(&mut mach, &pes, &data, &qc, &mut rng);
            mach.time()
        };
        let mom_small = pivot_cost(1 << 8, Pivot::MedianOfMedians);
        let mom_large = pivot_cost(1 << 12, Pivot::MedianOfMedians);
        let win_small = pivot_cost(1 << 8, Pivot::Window);
        let win_large = pivot_cost(1 << 12, Pivot::Window);
        let mom_growth = mom_large / mom_small;
        let win_growth = win_large / win_small;
        assert!(mom_growth > 2.0, "median-of-medians growth {mom_growth}");
        assert!(win_growth < 2.0, "window growth {win_growth}");
    }
}
