//! Minisort (Siebert & Wolf [2]): sorting with minimal data — exactly one
//! element per PE (`n = p`), the MPI_Comm_Split use case of §I. Table I
//! row: O(log²p) latency, O(log²p) volume.
//!
//! Our implementation is hypercube quicksort specialised to m = 1 with the
//! §III-B median reduction (the paper's own fix of Siebert & Wolf's
//! unbalanced-ternary-tree heuristic) and *with* tie-breaking, so it also
//! handles the duplicate-heavy instances the original cannot. Element
//! movement (the shuffle permutation round and every exchange level)
//! inherits RQuick's pooled [`crate::sim::Exchange`] data plane.

use crate::config::RunConfig;
use crate::elements::Elem;
use crate::localsort::SortBackend;
use crate::sim::Machine;

use super::quick::{self, Pivot, QuickConfig};
use super::{OutputShape, Sorter};

pub fn sort(
    mach: &mut Machine,
    data: &mut Vec<Vec<Elem>>,
    cfg: &RunConfig,
    backend: &mut dyn SortBackend,
) {
    if data.iter().any(|v| v.len() != 1) {
        mach.fail(0, "Minisort requires exactly one element per PE (n = p)");
        return;
    }
    // n = p: shuffling a single element per PE is one permutation round;
    // the §III-B median over singleton leaves replaces the ternary tree.
    let qc = QuickConfig {
        shuffle: true,
        tie_break: true,
        pivot: Pivot::Window,
        window_k: 2,
    };
    quick::sort(mach, data, cfg, backend, &qc);
}

/// [`Sorter`]: Minisort — sorting with minimal data, defined only for
/// exactly one element per PE (n = p); anything else reports a crash.
#[derive(Clone, Copy, Debug, Default)]
pub struct MinisortSorter;

impl Sorter for MinisortSorter {
    fn name(&self) -> &'static str {
        "Minisort"
    }

    fn output_shape(&self) -> OutputShape {
        OutputShape::Balanced
    }

    fn is_robust(&self) -> bool {
        true
    }

    fn valid_range(&self, n_per_pe: f64, _p: usize) -> bool {
        n_per_pe == 1.0
    }

    fn sort(
        &self,
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) -> OutputShape {
        self::sort(mach, data, cfg, backend);
        OutputShape::Balanced
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, Algorithm};
    use crate::input::{generate, Distribution};

    #[test]
    fn minisort_sorts_one_element_per_pe() {
        let cfg = RunConfig::default().with_p(128).with_n_per_pe(1);
        for d in [Distribution::Uniform, Distribution::Zero, Distribution::Mirrored] {
            let report = run(Algorithm::Minisort, &cfg, generate(&cfg, d));
            assert!(report.succeeded(), "{d:?}: {:?}", report.validation);
        }
    }

    #[test]
    fn minisort_rejects_dense_input() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(4);
        let report = run(Algorithm::Minisort, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(report.crashed.is_some());
    }

    #[test]
    fn minisort_latency_is_polylog() {
        let cfg = RunConfig::default().with_p(1 << 10).with_n_per_pe(1);
        let report = run(Algorithm::Minisort, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(report.succeeded());
        // log²p ≈ 100 α-rounds at p=1024; far below the α·p of any
        // gather-to-root scheme at this scale... keep a generous bound
        let alpha = cfg.cost.alpha;
        assert!(report.time < 350.0 * alpha, "time {} vs α {}", report.time, alpha);
    }
}
