//! AllGatherM: hypercube all-gather-merge (§II) — every PE ends up with
//! the complete sorted input. O(β·n + α·log p): the β·n term (every PE
//! receives *everything*) is why the paper finds it "not competitive for
//! any input size" — it exists as a baseline and as RFIS' row/column
//! primitive.
//!
//! All element movement happens inside the [`all_gather_merge`]
//! collective, whose dimension rounds run on the pooled
//! [`crate::sim::Exchange`] data plane (each pairwise `xchg` moves both
//! runs and charges the model in one call).

use crate::config::RunConfig;
use crate::elements::Elem;
use crate::localsort::{sort_all, SortBackend};
use crate::sim::{all_gather_merge, Cube, Machine};

use super::{OutputShape, Sorter};

pub fn sort(
    mach: &mut Machine,
    data: &mut Vec<Vec<Elem>>,
    cfg: &RunConfig,
    backend: &mut dyn SortBackend,
) {
    sort_all(mach, data, backend);
    let pes = Cube::whole(cfg.p).pe_vec();
    let runs = all_gather_merge(mach, &pes, data);
    for (pe, r) in runs.into_iter().enumerate() {
        data[pe] = r.merged();
    }
}

/// [`Sorter`]: AllGatherM — every PE ends with the complete sorted input
/// ([`OutputShape::Replicated`]); the paper's "not competitive" baseline.
#[derive(Clone, Copy, Debug, Default)]
pub struct AllGatherMSorter;

impl Sorter for AllGatherMSorter {
    fn name(&self) -> &'static str {
        "AllGatherM"
    }

    fn output_shape(&self) -> OutputShape {
        OutputShape::Replicated
    }

    fn is_robust(&self) -> bool {
        true
    }

    fn sort(
        &self,
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) -> OutputShape {
        self::sort(mach, data, cfg, backend);
        OutputShape::Replicated
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, Algorithm};
    use crate::input::{generate, Distribution};

    #[test]
    fn replicates_sorted_input_everywhere() {
        let cfg = RunConfig::default().with_p(8).with_n_per_pe(4);
        let input = generate(&cfg, Distribution::Uniform);
        let report = run(Algorithm::AllGatherM, &cfg, input);
        assert!(report.validation.ok(), "{:?}", report.validation);
    }

    #[test]
    fn slower_than_gatherm_on_sparse_inputs() {
        // the paper: AllGatherM sorts even the sparsest input twice as slow
        // as RFIS, and GatherM beats it there too
        let cfg = RunConfig::default().with_p(64).with_sparsity(3);
        let g = run(Algorithm::GatherM, &cfg, generate(&cfg, Distribution::Uniform));
        let ag = run(Algorithm::AllGatherM, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(ag.validation.ok());
        // AllGatherM replicates everything everywhere: strictly more data
        // on the wire and never faster than a plain gather
        assert!(ag.stats.words > 2 * g.stats.words, "AllGatherM {} vs GatherM {} words", ag.stats.words, g.stats.words);
        assert!(ag.time >= g.time, "AllGatherM {} vs GatherM {}", ag.time, g.time);
    }
}
