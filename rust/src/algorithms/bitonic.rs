//! Bitonic sort on a hypercube (Batcher [11], Johnsson [12]): local sort,
//! then log p merge phases of up to log p compare-split rounds — every
//! element crosses the network O(log²p) times, which is exactly the
//! `β·(n/p)·log²p` Table I row that makes it uncompetitive for large
//! inputs. Deterministic (the paper notes its negligible run-to-run
//! fluctuation) and oblivious to duplicates, but it *requires dense,
//! perfectly balanced input* — like the paper's implementation it fails on
//! sparse instances.

use crate::config::RunConfig;
use crate::elements::Elem;
use crate::localsort::{sort_all, SortBackend};
use crate::sim::{Machine, ParSpec};

use super::{OutputShape, Sorter};

/// Compare-split: keep the lower/upper `keep` elements of two sorted runs.
#[cfg(test)]
fn compare_split(mine: &[Elem], theirs: &[Elem], keep_low: bool) -> Vec<Elem> {
    let mut out = Vec::new();
    compare_split_into(mine, theirs, keep_low, &mut out);
    out
}

/// Compare-split writing into a reusable buffer (cleared first) — the
/// per-round output vectors cycle through the machine's data-plane pool.
fn compare_split_into(mine: &[Elem], theirs: &[Elem], keep_low: bool, out: &mut Vec<Elem>) {
    let keep = mine.len();
    out.clear();
    out.reserve(keep);
    if keep_low {
        let (mut i, mut j) = (0, 0);
        while out.len() < keep {
            if j >= theirs.len() || (i < mine.len() && mine[i] <= theirs[j]) {
                out.push(mine[i]);
                i += 1;
            } else {
                out.push(theirs[j]);
                j += 1;
            }
        }
    } else {
        let (mut i, mut j) = (mine.len() as i64 - 1, theirs.len() as i64 - 1);
        while out.len() < keep {
            if j < 0 || (i >= 0 && mine[i as usize] >= theirs[j as usize]) {
                out.push(mine[i as usize]);
                i -= 1;
            } else {
                out.push(theirs[j as usize]);
                j -= 1;
            }
        }
        out.reverse();
    }
}

pub fn sort(
    mach: &mut Machine,
    data: &mut Vec<Vec<Elem>>,
    cfg: &RunConfig,
    backend: &mut dyn SortBackend,
) {
    let p = cfg.p;
    assert!(p.is_power_of_two());
    let d = p.trailing_zeros();
    let m = data[0].len();
    if data.iter().any(|v| v.len() != m) || (m == 0 && cfg.n_total() > 0) {
        // the paper: "Bitonic … fails to sort sparse inputs"
        mach.fail(0, "bitonic requires dense balanced input");
        return;
    }
    sort_all(mach, data, backend);

    for i in 0..d {
        for j in (0..=i).rev() {
            let bit = 1usize << j;
            // exchange whole fragments through the data plane: each pair
            // swaps runs wholesale, so after delivery the partner's inbox
            // holds this PE's old run — no whole-machine snapshot clone
            let mut ex = mach.exchange();
            for pe in 0..p {
                let partner = pe ^ bit;
                if pe < partner {
                    let a = std::mem::take(&mut data[pe]);
                    let b = std::mem::take(&mut data[partner]);
                    ex.xchg(pe, partner, a, b);
                }
            }
            let inboxes = ex.deliver(mach);
            // compare-split: one PE task per member (each pair's runs are
            // read back from both inboxes, so tasks share nothing mutable)
            mach.par_pes(0, ParSpec::work(2 * m * p).bufs(1), &mut data[..], |ctx, slot| {
                let pe = ctx.pe();
                let partner = pe ^ bit;
                let mine = inboxes.single(partner);
                let theirs = inboxes.single(pe);
                let ascending = pe & (1 << (i + 1)) == 0;
                let keep_low = (pe & bit == 0) == ascending;
                let mut out = ctx.take_buf();
                compare_split_into(mine, theirs, keep_low, &mut out);
                *slot = out;
                ctx.work_linear(2 * m);
                ctx.note_mem(2 * m, "bitonic compare-split");
            });
            mach.recycle(inboxes);
        }
    }
    // final intra-PE order is ascending per PE already; ensure ascending
    // globally: with the (i+1)-bit direction rule the top phase (i = d-1)
    // uses bit d → all ascending. Runs stay sorted by construction.
}

/// [`Sorter`]: Bitonic — the deterministic baseline. Oblivious to
/// duplicates and skew, but only defined on dense, perfectly balanced
/// inputs (its [`Sorter::valid_range`] excludes n/p < 1; out of range it
/// reports a crash, like the paper's implementation).
#[derive(Clone, Copy, Debug, Default)]
pub struct BitonicSorter;

impl Sorter for BitonicSorter {
    fn name(&self) -> &'static str {
        "Bitonic"
    }

    fn output_shape(&self) -> OutputShape {
        OutputShape::Balanced
    }

    fn is_robust(&self) -> bool {
        true
    }

    fn valid_range(&self, n_per_pe: f64, _p: usize) -> bool {
        n_per_pe >= 1.0
    }

    fn sort(
        &self,
        mach: &mut Machine,
        data: &mut Vec<Vec<Elem>>,
        cfg: &RunConfig,
        backend: &mut dyn SortBackend,
    ) -> OutputShape {
        self::sort(mach, data, cfg, backend);
        OutputShape::Balanced
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, Algorithm};
    use crate::input::{generate, Distribution};

    #[test]
    fn compare_split_low_high() {
        let a: Vec<Elem> = [1u64, 4, 7].iter().enumerate().map(|(i, &k)| Elem::with_id(k, i as u64)).collect();
        let b: Vec<Elem> = [2u64, 3, 9].iter().enumerate().map(|(i, &k)| Elem::with_id(k, 10 + i as u64)).collect();
        let lo = compare_split(&a, &b, true);
        let keys: Vec<u64> = lo.iter().map(|e| e.key).collect();
        assert_eq!(keys, vec![1, 2, 3]);
        let hi = compare_split(&a, &b, false);
        let keys: Vec<u64> = hi.iter().map(|e| e.key).collect();
        assert_eq!(keys, vec![4, 7, 9]);
    }

    #[test]
    fn bitonic_sorts_all_dense_distributions() {
        let cfg = RunConfig::default().with_p(16).with_n_per_pe(16);
        for d in Distribution::ALL {
            let report = run(Algorithm::Bitonic, &cfg, generate(&cfg, d));
            assert!(report.succeeded(), "{d:?}: {:?}", report.validation);
            assert_eq!(report.validation.imbalance.epsilon, 0.0, "{d:?} perfectly balanced");
        }
    }

    #[test]
    fn bitonic_fails_on_sparse() {
        let cfg = RunConfig::default().with_p(16).with_sparsity(3);
        let report = run(Algorithm::Bitonic, &cfg, generate(&cfg, Distribution::Uniform));
        assert!(report.crashed.is_some(), "bitonic must refuse sparse input");
    }

    #[test]
    fn bitonic_volume_scales_with_log2p_squared() {
        // words moved ≈ p·m·(log²p+log p)/2 — check the growth trend
        let mut words = Vec::new();
        for logp in [3u32, 4, 5] {
            let cfg = RunConfig::default().with_p(1 << logp).with_n_per_pe(16);
            let report = run(Algorithm::Bitonic, &cfg, generate(&cfg, Distribution::Uniform));
            assert!(report.succeeded());
            words.push(report.stats.words as f64 / cfg.n_total() as f64);
        }
        assert!(words[1] > words[0] && words[2] > words[1], "{words:?}");
    }
}
