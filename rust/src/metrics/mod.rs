//! Run metrics: everything the paper's evaluation reports — simulated time,
//! startup counts (the α axis), communication volume (the β axis), local
//! work, memory high-water marks, and imbalance.

/// Aggregate counters accumulated by the [`crate::sim::Machine`].
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    /// Total messages sent (each pays one α).
    pub messages: u64,
    /// Total words moved (each pays one β).
    pub words: u64,
    /// Total local work charged (instruction units).
    pub local_work: f64,
    /// Maximum number of elements simultaneously resident on any PE.
    pub max_mem_elems: usize,
    /// Maximum messages sent or received by a single PE in a single
    /// irregular round (the DMA analysis of Fig. 2c watches this).
    pub max_degree: usize,
}

impl Stats {
    pub fn merge_from(&mut self, o: &Stats) {
        self.messages += o.messages;
        self.words += o.words;
        self.local_work += o.local_work;
        self.max_mem_elems = self.max_mem_elems.max(o.max_mem_elems);
        self.max_degree = self.max_degree.max(o.max_degree);
    }
}

/// Latency percentile summary — the tail-latency digest the serve
/// front-end reports per drained job stream (queue wait, service, and
/// end-to-end wall time each get one of these).
///
/// Percentiles use the **nearest-rank** definition (the ⌈q·N⌉-th smallest
/// sample): every reported value is an actually observed latency, never
/// an interpolation between two — the convention of SLO reporting.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Percentiles {
    /// Digest `samples` (any order; an empty slice yields all zeros —
    /// "no data", not "zero latency", callers report the count alongside).
    pub fn of(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self::default();
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        let rank = |q: f64| {
            let r = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
            sorted[r - 1]
        };
        Self { p50: rank(0.50), p95: rank(0.95), p99: rank(0.99), max: sorted[sorted.len() - 1] }
    }

    /// The digest as a JSON object fragment (used by `BENCH_serve.json`).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"p50\": {:.3}, \"p95\": {:.3}, \"p99\": {:.3}, \"max\": {:.3}}}",
            self.p50, self.p95, self.p99, self.max
        )
    }
}

/// Load-imbalance summary over final PE loads.
#[derive(Clone, Copy, Debug, Default)]
pub struct Imbalance {
    pub max_load: usize,
    pub min_load: usize,
    pub avg_load: f64,
    /// `max_load / avg_load - 1` (paper's ε); 0 for perfectly balanced.
    pub epsilon: f64,
}

impl Imbalance {
    pub fn from_loads(loads: impl IntoIterator<Item = usize>) -> Self {
        let mut max = 0usize;
        let mut min = usize::MAX;
        let mut sum = 0usize;
        let mut count = 0usize;
        for l in loads {
            max = max.max(l);
            min = min.min(l);
            sum += l;
            count += 1;
        }
        if count == 0 {
            return Self::default();
        }
        let avg = sum as f64 / count as f64;
        Self {
            max_load: max,
            min_load: min,
            avg_load: avg,
            epsilon: if avg > 0.0 { max as f64 / avg - 1.0 } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imbalance_balanced() {
        let im = Imbalance::from_loads([4, 4, 4, 4]);
        assert_eq!(im.epsilon, 0.0);
        assert_eq!(im.max_load, 4);
    }

    #[test]
    fn imbalance_skewed() {
        let im = Imbalance::from_loads([8, 0, 0, 0]);
        assert_eq!(im.max_load, 8);
        assert_eq!(im.min_load, 0);
        assert!((im.epsilon - 3.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_empty() {
        let im = Imbalance::from_loads([]);
        assert_eq!(im.max_load, 0);
    }

    /// Nearest-rank on a known sample: 1..=100 makes every percentile its
    /// own index, so the expected values are exact.
    #[test]
    fn percentiles_nearest_rank_exact() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let p = Percentiles::of(&samples);
        assert_eq!(p.p50, 50.0);
        assert_eq!(p.p95, 95.0);
        assert_eq!(p.p99, 99.0);
        assert_eq!(p.max, 100.0);
        // order-independent
        let mut shuffled = samples.clone();
        shuffled.reverse();
        assert_eq!(Percentiles::of(&shuffled), p);
    }

    #[test]
    fn percentiles_small_and_empty_samples() {
        assert_eq!(Percentiles::of(&[]), Percentiles::default());
        // a single sample is every percentile
        let one = Percentiles::of(&[7.5]);
        assert_eq!((one.p50, one.p95, one.p99, one.max), (7.5, 7.5, 7.5, 7.5));
        // two samples: p50 is the lower (rank ⌈0.5·2⌉ = 1), the tail is the upper
        let two = Percentiles::of(&[10.0, 20.0]);
        assert_eq!((two.p50, two.p99, two.max), (10.0, 20.0, 20.0));
    }

    #[test]
    fn percentiles_json_shape() {
        let j = Percentiles::of(&[1.0, 2.0]).to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        for key in ["\"p50\"", "\"p95\"", "\"p99\"", "\"max\""] {
            assert!(j.contains(key), "{j}");
        }
    }

    #[test]
    fn stats_merge() {
        let mut a = Stats { messages: 1, words: 10, local_work: 5.0, max_mem_elems: 3, max_degree: 2 };
        let b = Stats { messages: 2, words: 1, local_work: 1.0, max_mem_elems: 9, max_degree: 1 };
        a.merge_from(&b);
        assert_eq!(a.messages, 3);
        assert_eq!(a.words, 11);
        assert_eq!(a.max_mem_elems, 9);
        assert_eq!(a.max_degree, 2);
    }
}
