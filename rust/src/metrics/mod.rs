//! Run metrics: everything the paper's evaluation reports — simulated time,
//! startup counts (the α axis), communication volume (the β axis), local
//! work, memory high-water marks, and imbalance.

/// Aggregate counters accumulated by the [`crate::sim::Machine`].
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    /// Total messages sent (each pays one α).
    pub messages: u64,
    /// Total words moved (each pays one β).
    pub words: u64,
    /// Total local work charged (instruction units).
    pub local_work: f64,
    /// Maximum number of elements simultaneously resident on any PE.
    pub max_mem_elems: usize,
    /// Maximum messages sent or received by a single PE in a single
    /// irregular round (the DMA analysis of Fig. 2c watches this).
    pub max_degree: usize,
}

impl Stats {
    pub fn merge_from(&mut self, o: &Stats) {
        self.messages += o.messages;
        self.words += o.words;
        self.local_work += o.local_work;
        self.max_mem_elems = self.max_mem_elems.max(o.max_mem_elems);
        self.max_degree = self.max_degree.max(o.max_degree);
    }
}

/// Load-imbalance summary over final PE loads.
#[derive(Clone, Copy, Debug, Default)]
pub struct Imbalance {
    pub max_load: usize,
    pub min_load: usize,
    pub avg_load: f64,
    /// `max_load / avg_load - 1` (paper's ε); 0 for perfectly balanced.
    pub epsilon: f64,
}

impl Imbalance {
    pub fn from_loads(loads: impl IntoIterator<Item = usize>) -> Self {
        let mut max = 0usize;
        let mut min = usize::MAX;
        let mut sum = 0usize;
        let mut count = 0usize;
        for l in loads {
            max = max.max(l);
            min = min.min(l);
            sum += l;
            count += 1;
        }
        if count == 0 {
            return Self::default();
        }
        let avg = sum as f64 / count as f64;
        Self {
            max_load: max,
            min_load: min,
            avg_load: avg,
            epsilon: if avg > 0.0 { max as f64 / avg - 1.0 } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imbalance_balanced() {
        let im = Imbalance::from_loads([4, 4, 4, 4]);
        assert_eq!(im.epsilon, 0.0);
        assert_eq!(im.max_load, 4);
    }

    #[test]
    fn imbalance_skewed() {
        let im = Imbalance::from_loads([8, 0, 0, 0]);
        assert_eq!(im.max_load, 8);
        assert_eq!(im.min_load, 0);
        assert!((im.epsilon - 3.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_empty() {
        let im = Imbalance::from_loads([]);
        assert_eq!(im.max_load, 0);
    }

    #[test]
    fn stats_merge() {
        let mut a = Stats { messages: 1, words: 10, local_work: 5.0, max_mem_elems: 3, max_degree: 2 };
        let b = Stats { messages: 2, words: 1, local_work: 1.0, max_mem_elems: 9, max_degree: 1 };
        a.merge_from(&b);
        assert_eq!(a.messages, 3);
        assert_eq!(a.words, 11);
        assert_eq!(a.max_mem_elems, 9);
        assert_eq!(a.max_degree, 2);
    }
}
