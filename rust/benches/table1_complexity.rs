//! Bench: validate Table I empirically — measure per-PE startup counts and
//! word volumes at p and 4p and compare the growth factors against the
//! predicted asymptotic rows.
//!
//! Knobs: RMPS_BENCH_PSMALL (default 128), RMPS_BENCH_NPP (default 64),
//! RMPS_BENCH_JOBS (default: all cores).

mod common;

use rmps::experiments::table1;

fn main() {
    let p_small = common::env_usize("RMPS_BENCH_PSMALL", 1 << 7);
    let npp = common::env_usize("RMPS_BENCH_NPP", 64);
    let t = std::time::Instant::now();
    let rows = table1::run_table(npp, p_small, 7, common::env_jobs());
    table1::print_rows(&rows);

    println!("\npredicted growth when p ×4 (n/p fixed):");
    println!("  GatherM/AllGatherM/RFIS msgs : ~×1.2 (log p)");
    println!("  RQuick/Bitonic msgs          : ~×1.4 (log² p)");
    println!("  SSort msgs                   : ~×4   (p)");
    println!("  AllGatherM words             : ~×4   (n)");
    println!("  RFIS words                   : ~×2   (n/√p)");
    println!(
        "\n[table1] p={p_small}→{}: {:.1}s host wallclock",
        4 * p_small,
        t.elapsed().as_secs_f64()
    );
}
