//! Bench: regenerate Figure 5 / Appendix K — running-time ratios of every
//! algorithm to the fastest one, per instance and n/p.
//!
//! Knobs: RMPS_BENCH_P (default 512), RMPS_BENCH_MAXLOG (default 10),
//! RMPS_BENCH_JOBS (default: all cores).

mod common;

use rmps::config::RunConfig;
use rmps::experiments::fig5;

fn main() {
    let p = common::env_usize("RMPS_BENCH_P", 1 << 9);
    let max_log = common::env_usize("RMPS_BENCH_MAXLOG", 10) as u32;
    let t = std::time::Instant::now();
    let fig = fig5::run(&RunConfig::default().with_p(p), max_log, 1, common::env_jobs());
    fig.print();
    println!("\n[fig5] p={p}: {:.1}s host wallclock", t.elapsed().as_secs_f64());
}
