//! Bench: host-wallclock hot paths of the simulator — the §Perf targets.
//!
//! Measures (median of reps) the end-to-end simulation wallclock for the
//! flagship algorithms at reference sizes, plus the isolated hot kernels
//! (merge, partition, shuffle), and emits `BENCH_hotpath.json` (CI uploads
//! it as an artifact).
//!
//! Knobs: RMPS_BENCH_REPS (default 3); RMPS_BENCH_TINY=1 shrinks every
//! size so a CI smoke run finishes in seconds while still driving the
//! same code paths.

mod common;

use rmps::algorithms::{run, Algorithm};
use rmps::config::RunConfig;
use rmps::elements::{merge_into, multiway_merge, Elem};
use rmps::input::{generate, Distribution};
use rmps::partition::{partition, pick_splitters, SplitterTree};
use rmps::rng::Rng;

/// One measured line: (label, median ms, Melem/s).
type Line = (String, f64, f64);

fn bench_algo(alg: Algorithm, p: usize, m: usize, reps: usize, out: &mut Vec<Line>) {
    let cfg = RunConfig::default().with_p(p).with_n_per_pe(m);
    let input = generate(&cfg, Distribution::Uniform);
    let ms = common::time_ms(reps, || {
        let r = run(alg, &cfg, input.clone());
        assert!(r.crashed.is_none());
        r.time
    });
    let n = (p * m) as f64;
    let rate = n / ms / 1e3;
    println!("{:>10} p={p:<5} n/p={m:<6} {ms:>9.1} ms host   {rate:>7.2} Melem/s", alg.name());
    out.push((format!("{} p={p} n/p={m}", alg.name()), ms, rate));
}

fn main() {
    let reps = common::env_usize("RMPS_BENCH_REPS", 3);
    let tiny = common::env_usize("RMPS_BENCH_TINY", 0) != 0;
    // full sizes for perf tracking; tiny sizes for the CI smoke run
    let sz = |full: usize, small: usize| if tiny { small } else { full };
    let mut lines: Vec<Line> = Vec::new();

    println!("== end-to-end simulation wallclock (median of {reps}) ==");
    bench_algo(Algorithm::RQuick, sz(1 << 10, 1 << 5), sz(1 << 10, 1 << 6), reps, &mut lines);
    bench_algo(Algorithm::Rams, sz(1 << 9, 1 << 5), sz(1 << 12, 1 << 7), reps, &mut lines);
    bench_algo(Algorithm::Rfis, sz(1 << 10, 1 << 6), 4, reps, &mut lines);
    bench_algo(Algorithm::Bitonic, sz(1 << 8, 1 << 5), sz(1 << 10, 1 << 6), reps, &mut lines);
    bench_algo(Algorithm::HykSort, sz(1 << 9, 1 << 5), sz(1 << 12, 1 << 7), reps, &mut lines);
    bench_algo(Algorithm::Robust, sz(1 << 10, 1 << 5), sz(1 << 10, 1 << 6), reps, &mut lines);

    println!("\n== isolated hot kernels ==");
    let mut rng = Rng::seeded(1, 1);
    let kn = sz(1 << 19, 1 << 12); // per-run elements of the 2-way merge
    let mut a: Vec<Elem> = (0..kn).map(|i| Elem::new(rng.next_u64(), 0, i)).collect();
    let mut b: Vec<Elem> = (0..kn).map(|i| Elem::new(rng.next_u64(), 1, i)).collect();
    a.sort_unstable();
    b.sort_unstable();
    let mut out = Vec::new();
    let ms = common::time_ms(reps, || {
        merge_into(&a, &b, &mut out);
        out.len()
    });
    let rate = (2 * kn) as f64 / ms / 1e3;
    println!("merge_into 2-way       {ms:>9.1} ms   {rate:>7.2} Melem/s");
    lines.push((format!("merge_into 2x{kn}"), ms, rate));

    let runs_n = 64;
    let run_len = sz(1 << 14, 1 << 8);
    let runs: Vec<Vec<Elem>> = (0..runs_n)
        .map(|r| {
            let mut v: Vec<Elem> =
                (0..run_len).map(|i| Elem::new(rng.next_u64(), r, i)).collect();
            v.sort_unstable();
            v
        })
        .collect();
    let refs: Vec<&[Elem]> = runs.iter().map(|v| v.as_slice()).collect();
    let ms = common::time_ms(reps, || multiway_merge(&refs).len());
    let rate = (runs_n * run_len) as f64 / ms / 1e3;
    println!("multiway_merge 64-way  {ms:>9.1} ms   {rate:>7.2} Melem/s");
    lines.push((format!("multiway_merge 64x{run_len}"), ms, rate));

    let pn = sz(1 << 20, 1 << 13);
    let data: Vec<Elem> = (0..pn).map(|i| Elem::new(rng.next_u64(), 0, i)).collect();
    let mut sample: Vec<Elem> = data.iter().step_by(101).copied().collect();
    sample.sort_unstable();
    let spl = pick_splitters(&sample, 127);
    let tree = SplitterTree::new(&spl);
    let ms = common::time_ms(reps, || partition(&data, &tree, true).len());
    let rate = pn as f64 / ms / 1e3;
    println!("partition s=127 TB     {ms:>9.1} ms   {rate:>7.2} Melem/s");
    lines.push((format!("partition {pn} s=127 TB"), ms, rate));
    let ms = common::time_ms(reps, || partition(&data, &tree, false).len());
    let rate = pn as f64 / ms / 1e3;
    println!("partition s=127        {ms:>9.1} ms   {rate:>7.2} Melem/s");
    lines.push((format!("partition {pn} s=127"), ms, rate));

    let results: Vec<String> = lines
        .iter()
        .map(|(name, ms, rate)| {
            format!(
                "{{\"name\": {}, \"ms\": {ms:.3}, \"melem_per_s\": {rate:.3}}}",
                common::json_str(name)
            )
        })
        .collect();
    common::write_bench_json(
        "hotpath",
        &[
            ("bench", common::json_str("hotpath")),
            ("reps", reps.to_string()),
            ("tiny", tiny.to_string()),
            ("results", format!("[{}]", results.join(", "))),
        ],
    );
}
