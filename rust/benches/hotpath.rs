//! Bench: host-wallclock hot paths of the simulator — the §Perf targets.
//!
//! Measures (median of reps) the end-to-end simulation wallclock for the
//! flagship algorithms at reference sizes, plus the isolated hot kernels
//! (merge, partition, shuffle). EXPERIMENTS.md §Perf records before/after.
//!
//! Knobs: RMPS_BENCH_REPS (default 3).

mod common;

use rmps::algorithms::{run, Algorithm};
use rmps::config::RunConfig;
use rmps::elements::{merge_into, multiway_merge, Elem};
use rmps::input::{generate, Distribution};
use rmps::partition::{partition, pick_splitters, SplitterTree};
use rmps::rng::Rng;

fn bench_algo(alg: Algorithm, p: usize, m: usize, reps: usize) {
    let cfg = RunConfig::default().with_p(p).with_n_per_pe(m);
    let input = generate(&cfg, Distribution::Uniform);
    let ms = common::time_ms(reps, || {
        let r = run(alg, &cfg, input.clone());
        assert!(r.crashed.is_none());
        r.time
    });
    let n = (p * m) as f64;
    println!(
        "{:>10} p={p:<5} n/p={m:<6} {ms:>9.1} ms host   {:>7.2} Melem/s",
        alg.name(),
        n / ms / 1e3
    );
}

fn main() {
    let reps = common::env_usize("RMPS_BENCH_REPS", 3);
    println!("== end-to-end simulation wallclock (median of {reps}) ==");
    bench_algo(Algorithm::RQuick, 1 << 10, 1 << 10, reps);
    bench_algo(Algorithm::Rams, 1 << 9, 1 << 12, reps);
    bench_algo(Algorithm::Rfis, 1 << 10, 4, reps);
    bench_algo(Algorithm::Bitonic, 1 << 8, 1 << 10, reps);
    bench_algo(Algorithm::HykSort, 1 << 9, 1 << 12, reps);
    bench_algo(Algorithm::Robust, 1 << 10, 1 << 10, reps);

    println!("\n== isolated hot kernels ==");
    let mut rng = Rng::seeded(1, 1);
    // two-way merge of 1M elements
    let mut a: Vec<Elem> = (0..1 << 19).map(|i| Elem::new(rng.next_u64(), 0, i)).collect();
    let mut b: Vec<Elem> = (0..1 << 19).map(|i| Elem::new(rng.next_u64(), 1, i)).collect();
    a.sort_unstable();
    b.sort_unstable();
    let mut out = Vec::new();
    let ms = common::time_ms(reps, || {
        merge_into(&a, &b, &mut out);
        out.len()
    });
    println!("merge_into 2×512k      {ms:>9.1} ms   {:>7.2} Melem/s", (1 << 20) as f64 / ms / 1e3);

    // 64-way merge of 1M total
    let runs: Vec<Vec<Elem>> = (0..64)
        .map(|r| {
            let mut v: Vec<Elem> =
                (0..1 << 14).map(|i| Elem::new(rng.next_u64(), r, i)).collect();
            v.sort_unstable();
            v
        })
        .collect();
    let refs: Vec<&[Elem]> = runs.iter().map(|v| v.as_slice()).collect();
    let ms = common::time_ms(reps, || multiway_merge(&refs).len());
    println!("multiway_merge 64×16k  {ms:>9.1} ms   {:>7.2} Melem/s", (1 << 20) as f64 / ms / 1e3);

    // SSSS partition of 1M elements over 127 splitters
    let data: Vec<Elem> = (0..1 << 20).map(|i| Elem::new(rng.next_u64(), 0, i)).collect();
    let mut sample: Vec<Elem> = data.iter().step_by(101).copied().collect();
    sample.sort_unstable();
    let spl = pick_splitters(&sample, 127);
    let tree = SplitterTree::new(&spl);
    let ms = common::time_ms(reps, || partition(&data, &tree, true).len());
    println!("partition 1M s=127 TB  {ms:>9.1} ms   {:>7.2} Melem/s", (1 << 20) as f64 / ms / 1e3);
    let ms = common::time_ms(reps, || partition(&data, &tree, false).len());
    println!("partition 1M s=127     {ms:>9.1} ms   {:>7.2} Melem/s", (1 << 20) as f64 / ms / 1e3);
}
