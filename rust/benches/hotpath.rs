//! Bench: host-wallclock hot paths of the simulator — the §Perf targets.
//!
//! Measures (median of reps) the end-to-end simulation wallclock for the
//! flagship algorithms at reference sizes, plus the isolated hot kernels
//! (merge, partition, shuffle), and emits `BENCH_hotpath.json` (CI uploads
//! it as an artifact).
//!
//! The binary installs a counting global allocator and reports, per
//! algorithm, the heap-allocation count of the **first** run on a fresh
//! `Runner` (`allocs_cold` — data-plane pools empty) against a
//! **steady-state** run on the same runner (`allocs_warm` — pooled
//! exchange buffers reused). The cold/warm gap is the pooling win of the
//! Exchange data plane; both land in the JSON so CI artifacts track
//! allocation regressions across commits.
//!
//! The persistent-pool section measures the wake/park handshake of one
//! pooled round (`pool_round_us`) against the pre-pool scoped
//! spawn/join scheme on the identical round (`spawn_round_us`), then
//! sweeps real `Machine::par_pes` rounds across the inline/pooled
//! crossover (`pool_crossover`, one `{work, inline_us, pooled_us}` point
//! per doubling of the round's total work) and reports the smallest work
//! at which pooling wins (`measured_crossover_work`) — the empirical
//! basis for the `sim::PAR_MIN_WORK` default and the `--par-min-work` /
//! `RMPS_PAR_MIN_WORK` knob.
//!
//! The rewritten-kernel section pits each hot per-PE kernel against the
//! implementation it replaced on identical inputs: scalar vs 4-lane
//! interleaved classifier descents (ns/elem), the ping-pong cascade vs
//! the loser-tree k-way merge at k ∈ {4, 64, 1024} (ns/elem, outputs
//! asserted identical), pdqsort vs the digit-skipping LSD radix local
//! sort (ms + ratio), and the steady-state allocations of one warm call
//! per kernel — all under the `kernels` JSON key.
//!
//! Knobs: RMPS_BENCH_REPS (default 3); RMPS_BENCH_TINY=1 shrinks every
//! size so a CI smoke run finishes in seconds while still driving the
//! same code paths.

mod common;

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use rmps::algorithms::{Algorithm, Runner};
use rmps::config::RunConfig;
use rmps::elements::{
    cascade_merge_into, loser_tree_merge_into, merge_into, multiway_merge, Elem, MergeScratch,
};
use rmps::input::{generate, Distribution};
use rmps::localsort::radix_sort_run;
use rmps::partition::{
    partition, partition_scatter, pick_splitters, PartitionScratch, SplitterTree,
};
use rmps::rng::Rng;

/// System allocator wrapped with a call counter (alloc/realloc/zeroed;
/// frees are not counted — the metric is allocation churn).
struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOC_COUNT.load(Relaxed)
}

/// Intra-run parallelism measurement of one algorithm: serial vs pooled
/// wallclock (same simulated results, asserted), plus the warm
/// allocation count under each mode.
struct PePar {
    pe_jobs: usize,
    ms_pe1: f64,
    ms_pen: f64,
    allocs_warm_pe1: u64,
    allocs_warm_pen: u64,
}

/// One measured line: label, median ms, Melem/s, and (for end-to-end
/// algorithm runs) cold/warm allocation counts and the pe-jobs split.
struct Line {
    name: String,
    ms: f64,
    rate: f64,
    allocs: Option<(u64, u64)>,
    pe_par: Option<PePar>,
}

fn bench_algo(alg: Algorithm, p: usize, m: usize, reps: usize, out: &mut Vec<Line>) {
    let cfg = RunConfig::default().with_p(p).with_n_per_pe(m);
    let input = generate(&cfg, Distribution::Uniform);

    // allocation counting uses a lean runner (no reference clone, no kept
    // output) and clones the input *outside* the counted window, so the
    // cold/warm delta isolates the data-plane pool warmup. pe_jobs = 1
    // keeps the historical serial counting semantics.
    let mut lean = Runner::new(cfg.clone()).validate(false).keep_output(false).pe_jobs(1);
    // cold: fresh machine, empty data-plane pools
    let run_input = input.clone();
    let before = alloc_count();
    let r = lean.run_algorithm(alg, run_input);
    let allocs_cold = alloc_count() - before;
    assert!(r.crashed.is_none());
    // warm: same runner, pooled exchange buffers in steady state
    let run_input = input.clone();
    let before = alloc_count();
    let r = lean.run_algorithm(alg, run_input);
    let allocs_warm = alloc_count() - before;
    assert!(r.crashed.is_none());

    // timing keeps the historical semantics (validation on, output kept)
    let mut runner = Runner::new(cfg.clone());
    let ms = common::time_ms(reps, || {
        let r = runner.run_algorithm(alg, input.clone());
        assert!(r.crashed.is_none());
        r.time
    });
    let n = (p * m) as f64;
    let rate = n / ms / 1e3;

    // intra-run parallelism: pe_jobs = 1 vs pe_jobs = host on a lean
    // warmed runner — same simulated time (asserted bit-for-bit, the
    // determinism contract), different host wallclock; the warm
    // allocation count must not depend on the mode either
    let pe_n = rmps::exec::available_jobs().max(2);
    let measure = |pe_jobs: usize| -> (f64, u64, u64) {
        let mut lean = Runner::new(cfg.clone()).validate(false).keep_output(false).pe_jobs(pe_jobs);
        let r = lean.run_algorithm(alg, input.clone()); // warm the pools
        let sim_time = r.time;
        let before = alloc_count();
        let r = lean.run_algorithm(alg, input.clone());
        let allocs_warm = alloc_count() - before;
        assert_eq!(r.time.to_bits(), sim_time.to_bits());
        let ms = common::time_ms(reps, || {
            let r = lean.run_algorithm(alg, input.clone());
            assert!(r.crashed.is_none());
            r.time
        });
        (ms, allocs_warm, sim_time.to_bits())
    };
    let (ms_pe1, allocs_warm_pe1, bits1) = measure(1);
    let (ms_pen, allocs_warm_pen, bits_n) = measure(pe_n);
    assert_eq!(bits1, bits_n, "{}: pe_jobs must not change simulated time", alg.name());
    let speedup = ms_pe1 / ms_pen.max(1e-9);

    println!(
        "{:>10} p={p:<5} n/p={m:<6} {ms:>9.1} ms host   {rate:>7.2} Melem/s   \
         allocs {allocs_cold:>8} cold / {allocs_warm:>8} warm   \
         pe1 {ms_pe1:>8.1} ms / pe{pe_n} {ms_pen:>8.1} ms ({speedup:>4.2}x)",
        alg.name()
    );
    out.push(Line {
        name: format!("{} p={p} n/p={m}", alg.name()),
        ms,
        rate,
        allocs: Some((allocs_cold, allocs_warm)),
        pe_par: Some(PePar { pe_jobs: pe_n, ms_pe1, ms_pen, allocs_warm_pe1, allocs_warm_pen }),
    });
}

/// One point of the inline-vs-pooled crossover sweep: µs per
/// `Machine::par_pes` round of `work` total elements, with the gate
/// forced inline (`usize::MAX`) vs forced pooled (`1`).
struct CrossPoint {
    work: usize,
    inline_us: f64,
    pooled_us: f64,
}

/// µs per real `par_pes` round (p = 64 tasks, `w` total elements, a
/// deterministic fold kernel plus the `work_linear` ledger charge) at the
/// given inline-vs-pooled threshold. Small rounds run many iterations per
/// timed call so the median is resolvable.
fn par_round_us(reps: usize, workers: usize, w: usize, threshold: usize) -> f64 {
    use rmps::model::CostModel;
    use rmps::sim::{Machine, ParSpec};
    let p = 64usize;
    let mut mach = Machine::new(p, CostModel::default());
    mach.set_pe_jobs(workers);
    mach.set_par_min_work(threshold);
    let each = (w / p).max(1);
    let mut items: Vec<Vec<u64>> =
        (0..p).map(|t| (0..each).map(|i| (t * each + i) as u64).collect()).collect();
    let iters = ((1usize << 16) / w.max(1)).clamp(1, 256);
    let ms = common::time_ms(reps, || {
        let mut acc = 0u64;
        for _ in 0..iters {
            let sums = mach.par_pes(0, ParSpec::work(w), &mut items, |ctx, v: &mut Vec<u64>| {
                ctx.work_linear(v.len());
                v.iter().fold(0u64, |a, &b| {
                    a.wrapping_add(b.wrapping_mul(0x9E37_79B9_7F4A_7C15))
                })
            });
            acc = acc.wrapping_add(sums.into_iter().fold(0u64, u64::wrapping_add));
        }
        acc
    });
    ms * 1e3 / iters as f64
}

/// The persistent-pool measurements: wake/park round cost vs the old
/// scoped spawn/join scheme, and the swept inline/pooled crossover.
fn bench_pool(reps: usize, tiny: bool) -> (f64, f64, Vec<CrossPoint>, Option<usize>) {
    let workers = rmps::exec::available_jobs().max(2);
    let n = 256usize;
    let task = |i: usize| (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let rounds = 64usize;

    // persistent pool: wake parked workers, self-schedule n trivial
    // jobs, park again — the steady-state per-round overhead
    let _ = rmps::exec::parallel_map(workers, n, task); // warm: spawn the team
    let ms = common::time_ms(reps, || {
        let mut acc = 0u64;
        for _ in 0..rounds {
            let sums = rmps::exec::parallel_map(workers, n, task);
            acc = acc.wrapping_add(sums.into_iter().fold(0u64, u64::wrapping_add));
        }
        acc
    });
    let pool_round_us = ms * 1e3 / rounds as f64;

    // the pre-pool scheme, emulated verbatim: scoped spawn per round,
    // single-index self-scheduling, per-worker accumulation, join
    let ms = common::time_ms(reps, || {
        let mut acc = 0u64;
        for _ in 0..rounds {
            let next = std::sync::atomic::AtomicUsize::new(0);
            let sum = std::thread::scope(|s| {
                let handles: Vec<_> = (0..workers)
                    .map(|_| {
                        s.spawn(|| {
                            let mut done = 0u64;
                            loop {
                                let i = next.fetch_add(1, Relaxed);
                                if i >= n {
                                    break;
                                }
                                done = done.wrapping_add(task(i));
                            }
                            done
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).fold(0u64, u64::wrapping_add)
            });
            acc = acc.wrapping_add(sum);
        }
        acc
    });
    let spawn_round_us = ms * 1e3 / rounds as f64;
    println!(
        "pool round n={n}        {pool_round_us:>9.1} µs   (old spawn/join {spawn_round_us:>9.1} µs)"
    );

    // crossover sweep: the same par_pes round forced inline vs forced
    // pooled, doubling the total work until pooling clearly wins
    let max_log = if tiny { 12u32 } else { 17 };
    let mut points = Vec::new();
    let mut w = 256usize;
    while w <= 1usize << max_log {
        let inline_us = par_round_us(reps, workers, w, usize::MAX);
        let pooled_us = par_round_us(reps, workers, w, 1);
        println!(
            "par_pes W={w:<7}        inline {inline_us:>9.1} µs / pooled {pooled_us:>9.1} µs"
        );
        points.push(CrossPoint { work: w, inline_us, pooled_us });
        w *= 2;
    }
    let crossover = points.iter().find(|pt| pt.pooled_us <= pt.inline_us).map(|pt| pt.work);
    match crossover {
        Some(w) => println!(
            "measured crossover     {w} elements (sim::par_min_work default {})",
            rmps::sim::par_min_work()
        ),
        None => println!("measured crossover     not reached in this sweep"),
    }
    (pool_round_us, spawn_round_us, points, crossover)
}

fn main() {
    let reps = common::env_usize("RMPS_BENCH_REPS", 3);
    let tiny = common::env_usize("RMPS_BENCH_TINY", 0) != 0;
    // full sizes for perf tracking; tiny sizes for the CI smoke run
    let sz = |full: usize, small: usize| if tiny { small } else { full };
    let mut lines: Vec<Line> = Vec::new();

    println!("== end-to-end simulation wallclock (median of {reps}) ==");
    bench_algo(Algorithm::RQuick, sz(1 << 10, 1 << 5), sz(1 << 10, 1 << 6), reps, &mut lines);
    bench_algo(Algorithm::Rams, sz(1 << 9, 1 << 5), sz(1 << 12, 1 << 7), reps, &mut lines);
    bench_algo(Algorithm::Rfis, sz(1 << 10, 1 << 6), 4, reps, &mut lines);
    bench_algo(Algorithm::Bitonic, sz(1 << 8, 1 << 5), sz(1 << 10, 1 << 6), reps, &mut lines);
    bench_algo(Algorithm::HykSort, sz(1 << 9, 1 << 5), sz(1 << 12, 1 << 7), reps, &mut lines);
    bench_algo(Algorithm::Robust, sz(1 << 10, 1 << 5), sz(1 << 10, 1 << 6), reps, &mut lines);

    println!("\n== persistent pool: round overhead and PAR_MIN_WORK crossover ==");
    let (pool_round_us, spawn_round_us, cross, crossover) = bench_pool(reps, tiny);

    println!("\n== isolated hot kernels ==");
    let mut rng = Rng::seeded(1, 1);
    let kn = sz(1 << 19, 1 << 12); // per-run elements of the 2-way merge
    let mut a: Vec<Elem> = (0..kn).map(|i| Elem::new(rng.next_u64(), 0, i)).collect();
    let mut b: Vec<Elem> = (0..kn).map(|i| Elem::new(rng.next_u64(), 1, i)).collect();
    a.sort_unstable();
    b.sort_unstable();
    let mut out = Vec::new();
    let ms = common::time_ms(reps, || {
        merge_into(&a, &b, &mut out);
        out.len()
    });
    let rate = (2 * kn) as f64 / ms / 1e3;
    println!("merge_into 2-way       {ms:>9.1} ms   {rate:>7.2} Melem/s");
    lines.push(Line { name: format!("merge_into 2x{kn}"), ms, rate, allocs: None, pe_par: None });

    let runs_n = 64;
    let run_len = sz(1 << 14, 1 << 8);
    let runs: Vec<Vec<Elem>> = (0..runs_n)
        .map(|r| {
            let mut v: Vec<Elem> =
                (0..run_len).map(|i| Elem::new(rng.next_u64(), r, i)).collect();
            v.sort_unstable();
            v
        })
        .collect();
    let refs: Vec<&[Elem]> = runs.iter().map(|v| v.as_slice()).collect();
    let ms = common::time_ms(reps, || multiway_merge(&refs).len());
    let rate = (runs_n * run_len) as f64 / ms / 1e3;
    println!("multiway_merge 64-way  {ms:>9.1} ms   {rate:>7.2} Melem/s");
    lines.push(Line { name: format!("multiway_merge 64x{run_len}"), ms, rate, allocs: None, pe_par: None });

    let pn = sz(1 << 20, 1 << 13);
    let data: Vec<Elem> = (0..pn).map(|i| Elem::new(rng.next_u64(), 0, i)).collect();
    let mut sample: Vec<Elem> = data.iter().step_by(101).copied().collect();
    sample.sort_unstable();
    let spl = pick_splitters(&sample, 127);
    let tree = SplitterTree::new(&spl);
    let ms = common::time_ms(reps, || partition(&data, &tree, true).len());
    let rate = pn as f64 / ms / 1e3;
    println!("partition s=127 TB     {ms:>9.1} ms   {rate:>7.2} Melem/s");
    lines.push(Line { name: format!("partition {pn} s=127 TB"), ms, rate, allocs: None, pe_par: None });
    let ms = common::time_ms(reps, || partition(&data, &tree, false).len());
    let rate = pn as f64 / ms / 1e3;
    println!("partition s=127        {ms:>9.1} ms   {rate:>7.2} Melem/s");
    lines.push(Line { name: format!("partition {pn} s=127"), ms, rate, allocs: None, pe_par: None });

    println!("\n== rewritten per-PE kernels (old vs new) ==");
    // classifier descent, tie-breaking tree s=127: one scalar descent per
    // element vs four interleaved descents (the ILP rewrite), same inputs
    let ms_scalar =
        common::time_ms(reps, || data.iter().map(|e| tree.classify_tb(e)).sum::<usize>());
    let ms_lane4 = common::time_ms(reps, || {
        let mut acc = 0usize;
        let mut quads = data.chunks_exact(4);
        for q in &mut quads {
            let b = tree.classify_tb4([&q[0], &q[1], &q[2], &q[3]]);
            acc += b[0] + b[1] + b[2] + b[3];
        }
        for e in quads.remainder() {
            acc += tree.classify_tb(e);
        }
        acc
    });
    let classify_scalar_ns = ms_scalar * 1e6 / pn as f64;
    let classify_lane4_ns = ms_lane4 * 1e6 / pn as f64;
    println!(
        "classify_tb s=127      scalar {classify_scalar_ns:>6.2} ns/elem / 4-lane \
         {classify_lane4_ns:>6.2} ns/elem ({:.2}x)",
        classify_scalar_ns / classify_lane4_ns.max(1e-9)
    );

    // k-way merge: the old ping-pong cascade vs the loser tree, same runs,
    // warm scratches (outputs asserted identical — the rewrite contract)
    let merge_total = sz(1 << 20, 1 << 12);
    let mut merge_json: Vec<String> = Vec::new();
    let mut casc_scratch = MergeScratch::default();
    let mut tree_scratch = MergeScratch::default();
    let (mut casc_out, mut tree_out) = (Vec::new(), Vec::new());
    for k in [4usize, 64, 1024] {
        let run_len = (merge_total / k).max(1);
        let mruns: Vec<Vec<Elem>> = (0..k)
            .map(|r| {
                let mut v: Vec<Elem> =
                    (0..run_len).map(|i| Elem::new(rng.next_u64(), r, i)).collect();
                v.sort_unstable();
                v
            })
            .collect();
        let mrefs: Vec<&[Elem]> = mruns.iter().map(|v| v.as_slice()).collect();
        let n = (k * run_len) as f64;
        let ms_casc = common::time_ms(reps, || {
            cascade_merge_into(&mrefs, &mut casc_out, &mut casc_scratch);
            casc_out.len()
        });
        let ms_tree = common::time_ms(reps, || {
            loser_tree_merge_into(&mrefs, &mut tree_out, &mut tree_scratch);
            tree_out.len()
        });
        assert_eq!(casc_out, tree_out, "merge kernels must agree (k={k})");
        let casc_ns = ms_casc * 1e6 / n;
        let tree_ns = ms_tree * 1e6 / n;
        println!(
            "merge k={k:<5}          cascade {casc_ns:>6.2} ns/elem / loser-tree \
             {tree_ns:>6.2} ns/elem ({:.2}x)",
            casc_ns / tree_ns.max(1e-9)
        );
        merge_json.push(format!(
            "{{\"k\": {k}, \"cascade_ns_per_elem\": {casc_ns:.3}, \
             \"loser_tree_ns_per_elem\": {tree_ns:.3}}}"
        ));
    }

    // local sort: pdqsort vs the digit-skipping LSD radix kernel on the
    // same random run (the copy-in is identical on both sides)
    let sn = sz(1 << 20, 1 << 13);
    let sdata: Vec<Elem> = (0..sn).map(|i| Elem::new(rng.next_u64(), 3, i)).collect();
    let mut sbuf: Vec<Elem> = Vec::with_capacity(sn);
    let ms_pdq = common::time_ms(reps, || {
        sbuf.clear();
        sbuf.extend_from_slice(&sdata);
        sbuf.sort_unstable();
        sbuf.len()
    });
    let ms_radix = common::time_ms(reps, || {
        sbuf.clear();
        sbuf.extend_from_slice(&sdata);
        radix_sort_run(&mut sbuf);
        sbuf.len()
    });
    let radix_over_pdq = ms_radix / ms_pdq.max(1e-9);
    println!(
        "local sort n={sn:<7}  pdqsort {ms_pdq:>8.1} ms / radix {ms_radix:>8.1} ms \
         (radix/pdq {radix_over_pdq:.2})"
    );

    // steady-state allocation count of one warm call per rewritten kernel
    // (scatter and loser tree must be 0; radix allocates its per-call
    // histogram table — tracked so growth shows up in the artifact)
    let mut part_scratch = PartitionScratch::default();
    let _ = partition_scatter(&data, &tree, true, &mut part_scratch);
    let before = alloc_count();
    let _ = partition_scatter(&data, &tree, true, &mut part_scratch);
    let allocs_partition = alloc_count() - before;
    let warm_runs: Vec<Vec<Elem>> = (0..16)
        .map(|r| {
            let mut v: Vec<Elem> = (0..512).map(|i| Elem::new(rng.next_u64(), r, i)).collect();
            v.sort_unstable();
            v
        })
        .collect();
    let warm_refs: Vec<&[Elem]> = warm_runs.iter().map(|v| v.as_slice()).collect();
    loser_tree_merge_into(&warm_refs, &mut tree_out, &mut tree_scratch);
    let before = alloc_count();
    loser_tree_merge_into(&warm_refs, &mut tree_out, &mut tree_scratch);
    let allocs_merge = alloc_count() - before;
    sbuf.clear();
    sbuf.extend_from_slice(&sdata);
    radix_sort_run(&mut sbuf);
    sbuf.clear();
    sbuf.extend_from_slice(&sdata);
    let before = alloc_count();
    radix_sort_run(&mut sbuf);
    let allocs_radix = alloc_count() - before;
    println!(
        "warm allocs/call       partition_scatter {allocs_partition} / loser_tree \
         {allocs_merge} / radix {allocs_radix}"
    );

    let kernels_json = format!(
        "{{\"classify_scalar_ns_per_elem\": {classify_scalar_ns:.3}, \
         \"classify_lane4_ns_per_elem\": {classify_lane4_ns:.3}, \
         \"merge\": [{}], \
         \"sort_n\": {sn}, \"sort_pdq_ms\": {ms_pdq:.3}, \"sort_radix_ms\": {ms_radix:.3}, \
         \"radix_over_pdq\": {radix_over_pdq:.3}, \
         \"warm_allocs\": {{\"partition_scatter\": {allocs_partition}, \
         \"loser_tree_merge\": {allocs_merge}, \"radix_sort\": {allocs_radix}}}}}",
        merge_json.join(", ")
    );

    let results: Vec<String> = lines
        .iter()
        .map(|l| {
            let allocs = match l.allocs {
                Some((cold, warm)) => {
                    format!(", \"allocs_cold\": {cold}, \"allocs_warm\": {warm}")
                }
                None => String::new(),
            };
            let pe_par = match &l.pe_par {
                Some(pp) => {
                    let speedup = pp.ms_pe1 / pp.ms_pen.max(1e-9);
                    format!(
                        ", \"pe_jobs\": {}, \"ms_pe1\": {:.3}, \"ms_pen\": {:.3}, \
                         \"pe_speedup\": {:.3}, \"allocs_warm_pe1\": {}, \
                         \"allocs_warm_pen\": {}",
                        pp.pe_jobs,
                        pp.ms_pe1,
                        pp.ms_pen,
                        speedup,
                        pp.allocs_warm_pe1,
                        pp.allocs_warm_pen
                    )
                }
                None => String::new(),
            };
            format!(
                "{{\"name\": {}, \"ms\": {:.3}, \"melem_per_s\": {:.3}{allocs}{pe_par}}}",
                common::json_str(&l.name),
                l.ms,
                l.rate
            )
        })
        .collect();
    let cross_json: Vec<String> = cross
        .iter()
        .map(|pt| {
            format!(
                "{{\"work\": {}, \"inline_us\": {:.3}, \"pooled_us\": {:.3}}}",
                pt.work, pt.inline_us, pt.pooled_us
            )
        })
        .collect();
    common::write_bench_json(
        "hotpath",
        &[
            ("bench", common::json_str("hotpath")),
            ("reps", reps.to_string()),
            ("tiny", tiny.to_string()),
            ("par_min_work", rmps::sim::par_min_work().to_string()),
            ("pool_round_us", format!("{pool_round_us:.3}")),
            ("spawn_round_us", format!("{spawn_round_us:.3}")),
            ("pool_crossover", format!("[{}]", cross_json.join(", "))),
            (
                "measured_crossover_work",
                crossover.map_or_else(|| "null".to_string(), |w| w.to_string()),
            ),
            ("kernels", kernels_json),
            ("results", format!("[{}]", results.join(", "))),
        ],
    );
}
