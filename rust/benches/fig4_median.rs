//! Bench: regenerate Figure 4 / Appendix H — max rank error and variance
//! of the binary k-window median tree vs Dean et al.'s ternary tree, with
//! the c·n^−γ power-law fits.
//!
//! Knobs: RMPS_BENCH_MAXPOW2 (default 18), RMPS_BENCH_REPS (default 400),
//! RMPS_BENCH_JOBS (default: all cores).

mod common;

use rmps::experiments::fig4;

fn main() {
    let max_pow2 = common::env_usize("RMPS_BENCH_MAXPOW2", 18) as u32;
    let reps = common::env_usize("RMPS_BENCH_REPS", 400);
    let t = std::time::Instant::now();
    let fig = fig4::run(max_pow2, reps, 42, common::env_jobs());
    fig.print();
    println!(
        "\n[fig4] max n = 2^{max_pow2}, {reps} reps: {:.1}s host wallclock",
        t.elapsed().as_secs_f64()
    );
}
