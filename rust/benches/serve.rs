//! Bench: the sort-as-a-service front-end. Drains a deterministic mixed
//! job stream (sizes, distributions, forced + untargeted sorters, one
//! deliberate crash job) at `jobs = 1` and `jobs = host`, asserts the
//! simulated results are bit-identical across the two concurrency levels
//! (scheduling must never leak into results), and emits
//! `BENCH_serve.json` with throughput, p50/p95/p99 queue/service/e2e
//! latency, the machine-reuse economy, and crossover-cache traffic.
//!
//! Knobs: RMPS_BENCH_P (default 64), RMPS_BENCH_SERVE_JOBS (stream
//!        length multiplier, default 8 → 48 jobs), RMPS_BENCH_JOBS
//!        (service concurrency for the parallel drain, default: all
//!        cores). RMPS_BENCH_TINY=1 shrinks everything for CI smoke.

mod common;

use rmps::config::RunConfig;
use rmps::serve::{JobSpec, Service, ServeOptions};

/// One deterministic stream: `rounds` repetitions of a 6-job mixed batch
/// (dense small/large, sparse, untargeted, forced sorters, one crasher).
fn stream(rounds: usize) -> Vec<JobSpec> {
    let mut specs = Vec::new();
    for r in 0..rounds {
        let lines = [
            format!(r#"{{"n_per_pe": 4, "seed": {}, "algo": "RQuick"}}"#, 100 + r),
            format!(r#"{{"n_per_pe": 256, "seed": {}, "algo": "RAMS", "dist": "Staggered"}}"#, 200 + r),
            format!(r#"{{"sparsity": 8, "seed": {}, "algo": "RFIS"}}"#, 300 + r),
            format!(r#"{{"n_per_pe": 64, "seed": {}}}"#, 400 + r),
            format!(r#"{{"n_per_pe": 32, "seed": {}, "dist": "Zero"}}"#, 500 + r),
            // HykSort on Zero under a tight cap: the robustness crash path
            format!(
                r#"{{"n_per_pe": 128, "seed": {}, "algo": "HykSort", "dist": "Zero", "mem_cap": 0.001}}"#,
                600 + r
            ),
        ];
        for l in &lines {
            specs.push(JobSpec::parse(l).expect("bench stream specs are valid"));
        }
    }
    specs
}

fn main() {
    let tiny = common::env_usize("RMPS_BENCH_TINY", 0) != 0;
    let p = common::env_usize("RMPS_BENCH_P", if tiny { 16 } else { 64 });
    let rounds = common::env_usize("RMPS_BENCH_SERVE_JOBS", if tiny { 2 } else { 8 });
    let jobs = common::env_jobs();

    let opts = |jobs: usize| ServeOptions {
        jobs,
        base: RunConfig::default().with_p(p).with_n_per_pe(64),
        validate: true,
        keep_output: false,
        route_tuned: true,
    };

    // serial reference drain
    let t = std::time::Instant::now();
    let serial = Service::new(opts(1)).drain(stream(rounds));
    let serial_wall = t.elapsed().as_secs_f64();
    assert!(serial.errors.is_empty(), "bench stream must be fully admitted");

    // concurrent drain of the same stream
    let t = std::time::Instant::now();
    let par = Service::new(opts(jobs)).drain(stream(rounds));
    let wall = t.elapsed().as_secs_f64();

    // scheduling must not leak into results: per-job simulated outcomes
    // are bit-identical at every service concurrency
    assert_eq!(serial.reports.len(), par.reports.len());
    let identical = serial.reports.iter().zip(&par.reports).all(|(a, b)| {
        a.algorithm == b.algorithm
            && a.time.to_bits() == b.time.to_bits()
            && a.stats.messages == b.stats.messages
            && a.stats.words == b.stats.words
            && a.crashed == b.crashed
    });
    assert!(identical, "serve results diverged across job-concurrency levels");

    let n_jobs = par.stats.jobs;
    println!(
        "[serve] p={p} jobs={jobs}: {n_jobs} job(s) in {wall:.3}s \
         ({:.1} jobs/s; jobs=1 baseline {serial_wall:.3}s, speedup ×{:.2}, identical={identical})",
        par.stats.throughput_jobs_per_s,
        serial_wall / wall.max(1e-9)
    );
    par.stats.print();

    let s = &par.stats;
    common::write_bench_json(
        "serve",
        &[
            ("bench", common::json_str("serve")),
            ("p", p.to_string()),
            ("jobs", jobs.to_string()),
            ("n_jobs", n_jobs.to_string()),
            ("crashed", s.crashed.to_string()),
            ("wall_s", format!("{wall:.6}")),
            ("serial_wall_s", format!("{serial_wall:.6}")),
            ("speedup", format!("{:.3}", serial_wall / wall.max(1e-9))),
            ("identical_across_jobs", identical.to_string()),
            ("throughput_jobs_per_s", format!("{:.3}", s.throughput_jobs_per_s)),
            ("queue_us", s.queue.to_json()),
            ("service_us", s.service.to_json()),
            ("e2e_us", s.total.to_json()),
            ("machine_reuse_hits", s.machine_reuse_hits.to_string()),
            ("machine_fresh_builds", s.machine_fresh_builds.to_string()),
            ("crossover_cache_hits", s.crossover_cache_hits.to_string()),
            ("crossover_probes", s.crossover_probes.to_string()),
        ],
    );
}
