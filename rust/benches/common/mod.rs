//! Shared measurement scaffolding for the bench binaries (criterion is not
//! vendored in this offline environment, so each bench is a plain
//! `harness = false` binary with a median-of-reps wallclock loop).

// each bench binary includes this module but uses only part of it
#![allow(dead_code)]

use std::time::Instant;

/// Median-of-`reps` wallclock of `f`, in milliseconds, after one warmup.
pub fn time_ms<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    let _ = f(); // warmup (the paper discards the first run too)
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t = Instant::now();
            let _ = f();
            t.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    times.sort_by(f64::total_cmp);
    times[times.len() / 2]
}

/// Environment knob with default (e.g. `RMPS_BENCH_P=4096`).
pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}
