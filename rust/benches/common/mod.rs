//! Shared measurement scaffolding for the bench binaries (criterion is not
//! vendored in this offline environment, so each bench is a plain
//! `harness = false` binary with a median-of-reps wallclock loop).

// each bench binary includes this module but uses only part of it
#![allow(dead_code)]

use std::time::Instant;

/// Median-of-`reps` wallclock of `f`, in milliseconds, after one warmup.
pub fn time_ms<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    let _ = f(); // warmup (the paper discards the first run too)
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t = Instant::now();
            let _ = f();
            t.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    times.sort_by(f64::total_cmp);
    times[times.len() / 2]
}

/// Environment knob with default (e.g. `RMPS_BENCH_P=4096`).
pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Worker-thread knob for the experiment driver (`RMPS_BENCH_JOBS`,
/// default: available host parallelism).
pub fn env_jobs() -> usize {
    env_usize("RMPS_BENCH_JOBS", rmps::exec::available_jobs())
}

/// JSON string literal (the only escaping our bench labels need).
pub fn json_str(s: &str) -> String {
    format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
}

/// Write `BENCH_<name>.json` into the current directory — the *package*
/// root (`rust/`) under `cargo bench`, which runs bench binaries with cwd
/// set to the manifest dir. CI uploads these as artifacts so perf
/// regressions leave a machine-readable trail. `fields` values must
/// already be valid JSON fragments (numbers as-is, strings via
/// [`json_str`], arrays preassembled).
pub fn write_bench_json(name: &str, fields: &[(&str, String)]) {
    let body: Vec<String> =
        fields.iter().map(|(k, v)| format!("  {}: {v}", json_str(k))).collect();
    let json = format!("{{\n{}\n}}\n", body.join(",\n"));
    let path = format!("BENCH_{name}.json");
    // fail loudly: this JSON is the perf-regression record CI archives —
    // a silently missing file would read as "bench passed, no data"
    std::fs::write(&path, json).unwrap_or_else(|e| panic!("could not write {path}: {e}"));
    println!("[bench] wrote {path}");
}
