//! Bench: the giant-p sweep — Fig. 1's sparse end at the paper's machine
//! sizes, up to 2^18 = 262 144 simulated PEs (the JUQUEEN scale).
//!
//! For every ladder size it runs GatherM/RFIS/Robust over the sparse
//! points plus n/p = 1 on Uniform inputs, and records per machine size:
//! host wallclock, settled supersteps, host µs/superstep, and the heap
//! allocation count of the whole block (counting global allocator, same
//! idiom as the hotpath bench). Supersteps cost O(active PEs + messages)
//! host work — not O(p) — so the µs/superstep series must grow sublinearly
//! in p; the recorded `sublinear` field tracks exactly that, and the whole
//! sweep lands in `BENCH_giantp.json` (CI uploads it as an artifact).
//!
//! Knobs: RMPS_BENCH_REPS (default 1), RMPS_BENCH_JOBS (default: all
//! cores), RMPS_BENCH_SERIAL=0 skips the jobs=1 identity baseline.
//! RMPS_BENCH_TINY=1 trims the point set to {3^-5, 2^0} — the p ladder is
//! deliberately NOT reduced: reaching 2^18 inside the CI smoke budget is
//! the point of this bench.

mod common;

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use rmps::config::RunConfig;
use rmps::experiments::fig1;
use rmps::experiments::NpPoint;

/// System allocator wrapped with a call counter (alloc/realloc/zeroed;
/// frees are not counted — the metric is allocation churn).
struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOC_COUNT.load(Relaxed)
}

/// Per-machine-size measurements of one ladder entry.
struct PBlock {
    p: usize,
    wall_s: f64,
    host_rounds: u64,
    us_per_round: f64,
    allocs: u64,
    crashes: usize,
}

fn main() {
    let reps = common::env_usize("RMPS_BENCH_REPS", 1);
    let jobs = common::env_jobs();
    let tiny = common::env_usize("RMPS_BENCH_TINY", 0) != 0;
    let serial_too = common::env_usize("RMPS_BENCH_SERIAL", 1) != 0;

    let ladder = fig1::GIANT_P_LADDER;
    let points: Vec<NpPoint> = if tiny {
        vec![NpPoint::Sparse(243), NpPoint::Dense(1)]
    } else {
        fig1::giant_p_points()
    };
    let base = RunConfig::default();

    // one run_giant_p call per ladder entry, so the wallclock / superstep
    // / allocation window brackets exactly one machine size (the jobs>1
    // pool allocates too — the count is a churn diagnostic, not a proof)
    let mut blocks: Vec<PBlock> = Vec::new();
    let mut cells = Vec::new();
    for &p in &ladder {
        let before = alloc_count();
        let t = std::time::Instant::now();
        let fig = fig1::run_giant_p(&base, &[p], &points, fig1::giant_p_sorters(), reps, jobs);
        let wall_s = t.elapsed().as_secs_f64();
        let allocs = alloc_count() - before;
        fig.print();
        let host_rounds: u64 = fig.cells.iter().map(|c| c.host_rounds).sum();
        let us_per_round = fig.host_us_per_round(p);
        let crashes = fig.cells.iter().filter(|c| c.crashed).count();
        for c in &fig.cells {
            assert!(c.crashed || c.ok, "{} {:?} invalid at p={p}", c.algorithm, c.point);
        }
        println!(
            "[giantp] p=2^{:<2} {wall_s:>7.2}s host  {host_rounds:>9} supersteps  \
             {us_per_round:>8.2} µs/superstep  {allocs:>9} allocs  {crashes} crash(es)",
            (p as f64).log2().round() as u32
        );
        blocks.push(PBlock { p, wall_s, host_rounds, us_per_round, allocs, crashes });
        cells.extend(fig.cells);
    }

    // the acceptance series: host µs/superstep from 2^14 to 2^18 must grow
    // sublinearly in p (recorded, not asserted — CI hosts are noisy)
    let first = &blocks[0];
    let last = &blocks[blocks.len() - 1];
    let us_ratio = last.us_per_round / first.us_per_round.max(1e-9);
    let p_ratio = last.p as f64 / first.p as f64;
    let sublinear = us_ratio < p_ratio;
    println!(
        "[giantp] µs/superstep 2^{}→2^{}: ×{us_ratio:.2} over a ×{p_ratio:.0} machine \
         (sublinear={sublinear})",
        (first.p as f64).log2().round() as u32,
        (last.p as f64).log2().round() as u32
    );

    let mut fields = vec![
        ("bench", common::json_str("giantp")),
        ("reps", reps.to_string()),
        ("jobs", jobs.to_string()),
        ("tiny", tiny.to_string()),
        ("points", points.len().to_string()),
        ("us_per_round_ratio", format!("{us_ratio:.3}")),
        ("p_ratio", format!("{p_ratio:.1}")),
        ("sublinear", sublinear.to_string()),
    ];
    let ladder_json: Vec<String> = blocks
        .iter()
        .map(|b| {
            format!(
                "{{\"p\": {}, \"wall_s\": {:.3}, \"host_rounds\": {}, \
                 \"host_us_per_superstep\": {:.3}, \"allocs\": {}, \"crashes\": {}}}",
                b.p, b.wall_s, b.host_rounds, b.us_per_round, b.allocs, b.crashes
            )
        })
        .collect();
    fields.push(("ladder", format!("[{}]", ladder_json.join(", "))));

    if serial_too && jobs > 1 {
        // the determinism contract the other benches enforce: the whole
        // ladder re-run on one worker is bit-identical
        let t = std::time::Instant::now();
        let mut serial_cells = Vec::new();
        for &p in &ladder {
            let fig =
                fig1::run_giant_p(&base, &[p], &points, fig1::giant_p_sorters(), reps, 1);
            serial_cells.extend(fig.cells);
        }
        let serial_wall = t.elapsed().as_secs_f64();
        let identical = serial_cells
            .iter()
            .zip(&cells)
            .all(|(a, b)| a.time.to_bits() == b.time.to_bits() && a.crashed == b.crashed);
        assert!(identical, "giant-p sweep must be bit-identical across job counts");
        println!("[giantp] jobs=1 baseline: {serial_wall:.1}s  (identical={identical})");
        fields.push(("serial_wall_s", format!("{serial_wall:.3}")));
        fields.push(("identical_across_jobs", identical.to_string()));
    }
    common::write_bench_json("giantp", &fields);
}
