//! Bench: regenerate Figure 2 (a–d) — robust/nonrobust running-time
//! ratios: RQuick vs NTB-Quick (2a big machine, 2b small machine), RAMS vs
//! NDMA-AMS (2c), RAMS vs NS-SSort (2d).
//!
//! Knobs: RMPS_BENCH_P (default 512), RMPS_BENCH_MAXLOG (default 10),
//! RMPS_BENCH_JOBS (default: all cores).

mod common;

use rmps::config::RunConfig;
use rmps::experiments::fig2;
use rmps::experiments::NpPoint;

fn main() {
    let p = common::env_usize("RMPS_BENCH_P", 1 << 9);
    let max_log = common::env_usize("RMPS_BENCH_MAXLOG", 10) as u32;
    let jobs = common::env_jobs();
    let points: Vec<NpPoint> =
        (0..=max_log).step_by(2).map(|l| NpPoint::Dense(1 << l)).collect();

    let t = std::time::Instant::now();
    let base = RunConfig::default().with_p(p);
    let series = fig2::fig2a(&base, &points, 1, jobs);
    fig2::print_series(&format!("Fig.2a RQuick vs NTB-Quick (p={p})"), &series);

    let small = RunConfig::default().with_p((p / 4).max(16));
    let series = fig2::fig2a(&small, &points, 1, jobs);
    fig2::print_series(&format!("Fig.2b RQuick vs NTB-Quick (p={})", small.p), &series);

    let series = fig2::fig2c(&base, &points, 1, jobs);
    fig2::print_series(&format!("Fig.2c RAMS vs NDMA-AMS (p={p})"), &series);

    let series = fig2::fig2d(&base, &points, 1, jobs);
    fig2::print_series(&format!("Fig.2d RAMS vs NS-SSort (p={p})"), &series);

    println!("\n[fig2] total host wallclock {:.1}s", t.elapsed().as_secs_f64());
}
