//! Bench: regenerate Figure 1 — running times of GatherM, AllGatherM,
//! RFIS, RQuick, Bitonic, RAMS, HykSort, SSort over the n/p sweep on the
//! four headline instances. Prints the paper-style table (simulated model
//! time) plus host wallclock per sweep.
//!
//! Knobs: RMPS_BENCH_P (default 1024), RMPS_BENCH_MAXLOG (default 12),
//!        RMPS_BENCH_REPS (default 1).

mod common;

use rmps::config::RunConfig;
use rmps::experiments::fig1;

fn main() {
    let p = common::env_usize("RMPS_BENCH_P", 1 << 9);
    let max_log = common::env_usize("RMPS_BENCH_MAXLOG", 10) as u32;
    let reps = common::env_usize("RMPS_BENCH_REPS", 1);
    let base = RunConfig::default().with_p(p);

    let t = std::time::Instant::now();
    let fig = fig1::run(&base, max_log, reps);
    let wall = t.elapsed().as_secs_f64();
    fig.print();
    println!(
        "\n[fig1] p={p} max_log={max_log} reps={reps}: {} cells in {wall:.1}s host wallclock",
        fig.cells.len()
    );
}
