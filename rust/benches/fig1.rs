//! Bench: regenerate Figure 1 — running times of GatherM, AllGatherM,
//! RFIS, RQuick, Bitonic, RAMS, HykSort, SSort, plus the successor
//! paper's AMS-1/2/3 columns (1-factor exchange), over the n/p sweep on
//! the four headline instances. Prints the paper-style table (simulated
//! model time) plus host wallclock per sweep, and emits `BENCH_fig1.json`
//! with the serial/parallel wallclocks (CI uploads it as an artifact).
//!
//! Knobs: RMPS_BENCH_P (default 512), RMPS_BENCH_MAXLOG (default 10),
//!        RMPS_BENCH_REPS (default 1), RMPS_BENCH_JOBS (default: all
//!        cores). The --jobs 1 baseline sweep (for the recorded speedup
//!        and identity check) runs by default; RMPS_BENCH_SERIAL=0 skips
//!        it.

mod common;

use rmps::config::RunConfig;
use rmps::experiments::fig1;

fn main() {
    let p = common::env_usize("RMPS_BENCH_P", 1 << 9);
    let max_log = common::env_usize("RMPS_BENCH_MAXLOG", 10) as u32;
    let reps = common::env_usize("RMPS_BENCH_REPS", 1);
    let jobs = common::env_jobs();
    let serial_too = common::env_usize("RMPS_BENCH_SERIAL", 1) != 0;

    let t = std::time::Instant::now();
    let fig = fig1::run_ams(&RunConfig::default().with_p(p), max_log, reps, jobs);
    let wall = t.elapsed().as_secs_f64();
    fig.print();
    println!(
        "\n[fig1] p={p} max_log={max_log} reps={reps} jobs={jobs}: {} cells in {wall:.1}s host wallclock",
        fig.cells.len()
    );

    // the per-cell machine-reuse economy, surfaced for free by the
    // Runner's RunMeta path: reps beyond a cell's first are reuse hits
    let reuse_hits: u64 = fig.cells.iter().map(|c| c.machine_reuse_hits).sum();
    let fresh_builds: u64 = fig.cells.iter().map(|c| c.machine_fresh_builds).sum();
    println!(
        "[fig1] machine reuse: {reuse_hits} hit(s) / {fresh_builds} fresh build(s) across {} cells",
        fig.cells.len()
    );

    let mut fields = vec![
        ("bench", common::json_str("fig1")),
        ("p", p.to_string()),
        ("max_log", max_log.to_string()),
        ("reps", reps.to_string()),
        ("jobs", jobs.to_string()),
        ("cells", fig.cells.len().to_string()),
        ("wall_s", format!("{wall:.3}")),
        ("machine_reuse_hits", reuse_hits.to_string()),
        ("machine_fresh_builds", fresh_builds.to_string()),
    ];
    if serial_too && jobs > 1 {
        let t = std::time::Instant::now();
        let serial = fig1::run_ams(&RunConfig::default().with_p(p), max_log, reps, 1);
        let serial_wall = t.elapsed().as_secs_f64();
        let identical = serial
            .cells
            .iter()
            .zip(&fig.cells)
            .all(|(a, b)| a.time.to_bits() == b.time.to_bits() && a.crashed == b.crashed);
        println!(
            "[fig1] jobs=1 baseline: {serial_wall:.1}s  (speedup ×{:.2}, identical={identical})",
            serial_wall / wall.max(1e-9)
        );
        fields.push(("serial_wall_s", format!("{serial_wall:.3}")));
        fields.push(("speedup", format!("{:.3}", serial_wall / wall.max(1e-9))));
        fields.push(("identical_across_jobs", identical.to_string()));
    }
    common::write_bench_json("fig1", &fields);
}
