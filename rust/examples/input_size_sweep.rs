//! Input-size sweep (Fig. 1 in miniature): which algorithm wins at each
//! n/p, demonstrating the paper's headline — four algorithms cover the
//! entire input-size spectrum.
//!
//! ```sh
//! cargo run --release --example input_size_sweep [p] [max_log]
//! ```

use rmps::algorithms::selector;
use rmps::config::RunConfig;
use rmps::experiments::{fig1, NpPoint};
use rmps::input::Distribution;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let p: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1 << 8);
    let max_log: u32 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(10);

    let base = RunConfig::default().with_p(p);
    let fig = fig1::run(&base, max_log, 1, rmps::exec::available_jobs());

    println!("winners per n/p on p = {p} (Uniform):");
    println!("{:>8} {:>12} {:>14} {:>12}", "n/p", "winner", "time", "selector");
    for &pt in &fig.points {
        let w = fig.winner(Distribution::Uniform, pt);
        let t = fig.cell(Distribution::Uniform, pt, w).time;
        let choice = selector::choose(pt.n_over_p());
        let mark = if w == choice || matches!(pt, NpPoint::Sparse(_)) && choice == "GatherM" {
            "✓"
        } else {
            " "
        };
        println!("{:>8} {:>12} {:>14.3e} {:>10}{mark}", pt.label(), w, t, choice);
    }
    println!("\nselector column = what rmps::algorithms::selector would pick;");
    println!("✓ = matches the measured winner.");
}
