//! Quickstart: sort a skewed, duplicate-heavy input with the robust
//! selector through the builder-style `Runner`, and inspect the report.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use rmps::algorithms::{Algorithm, Runner};
use rmps::config::RunConfig;
use rmps::input::{generate, Distribution};

fn main() {
    // a 256-PE simulated machine, 1024 elements per PE
    let cfg = RunConfig::default().with_p(1 << 8).with_n_per_pe(1 << 10);

    // one runner owns the simulated machine; batched runs below reuse its
    // scratch instead of reallocating per run
    let mut runner = Runner::new(cfg.clone());

    // a deliberately nasty input: only log(n) distinct keys
    let input = generate(&cfg, Distribution::DeterDupl);

    // the paper's headline component: GatherM/RFIS/RQuick/RAMS by n/p
    let report = runner.run_algorithm(Algorithm::Robust, input);

    println!("robust selector on {} PEs, n/p = {}", cfg.p, cfg.n_per_pe);
    println!("  simulated time : {:.3e} model units", report.time);
    println!("  messages       : {}", report.stats.messages);
    println!("  words moved    : {}", report.stats.words);
    println!("  sorted         : {}", report.validation.ok());
    println!(
        "  balanced       : {} (ε = {:.3})",
        report.validation.balanced, report.validation.imbalance.epsilon
    );
    assert!(report.succeeded(), "the robust stack must survive DeterDupl");

    // compare: a nonrobust classic on the same input, same runner
    let input = generate(&cfg, Distribution::DeterDupl);
    let naive = runner.run_algorithm(Algorithm::NtbQuick, input);
    match &naive.crashed {
        Some(c) => println!("NTB-Quick on the same input: CRASH ({c})"),
        None => println!(
            "NTB-Quick on the same input: time {:.3e}, imbalance ε = {:.1}",
            naive.time, naive.validation.imbalance.epsilon
        ),
    }
}
