//! Robustness demo (§VII-B in miniature): run every algorithm against the
//! adversarial instances and print a survival/slowdown matrix — the
//! qualitative content of Fig. 2 at a glance. Rows come from the sorter
//! registry; the `*` marker is each sorter's own `is_robust()` metadata.
//!
//! ```sh
//! cargo run --release --example robustness
//! ```

use rmps::algorithms::{Algorithm, Runner};
use rmps::config::RunConfig;
use rmps::input::{generate, Distribution};

fn main() {
    let mut cfg = RunConfig::default().with_p(1 << 6).with_n_per_pe(1 << 9);
    cfg.mem_cap_factor = Some(16.0); // tight memory: nonrobust algos crash

    let algos = [
        Algorithm::RQuick,
        Algorithm::NtbQuick,
        Algorithm::Rams,
        Algorithm::NtbAms,
        Algorithm::HykSort,
        Algorithm::SSort,
        Algorithm::Rfis,
        Algorithm::Bitonic,
    ];
    let instances = [
        Distribution::Uniform,
        Distribution::Staggered,
        Distribution::Mirrored,
        Distribution::BucketSorted,
        Distribution::DeterDupl,
        Distribution::Zero,
        Distribution::AllToOne,
    ];

    // one runner, reused across the whole matrix; no figure reads the
    // sorted payload, so don't keep it
    let mut runner = Runner::new(cfg.clone()).keep_output(false);

    // baseline: RQuick on Uniform
    let base = runner
        .run_algorithm(Algorithm::RQuick, generate(&cfg, Distribution::Uniform))
        .time;

    println!(
        "slowdown vs RQuick/Uniform on p={} n/p={} (✗ = crash/OOM, ! = unbalanced, * = robust)",
        cfg.p, cfg.n_per_pe
    );
    print!("{:>13}", "");
    for d in &instances {
        print!("{:>14}", d.name());
    }
    println!();
    for alg in algos {
        let sorter = alg.sorter();
        let marker = if sorter.is_robust() { "*" } else { " " };
        print!("{:>12}{marker}", sorter.name());
        for &d in &instances {
            let r = runner.run(sorter.as_ref(), generate(&cfg, d));
            let cell = if r.crashed.is_some() {
                "✗".to_string()
            } else if !r.validation.ok() {
                "✗✗".to_string()
            } else if !r.validation.balanced {
                format!("{:.1}!", r.time / base)
            } else {
                format!("{:.1}", r.time / base)
            };
            print!("{cell:>14}");
        }
        println!();
    }
    println!("\nreading: the robust (*) rows survive every column;");
    println!("the nonrobust rows crash (✗) or unbalance (!) on the right half.");
}
