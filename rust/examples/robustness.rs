//! Robustness demo (§VII-B in miniature): run every algorithm against the
//! adversarial instances and print a survival/slowdown matrix — the
//! qualitative content of Fig. 2 at a glance.
//!
//! ```sh
//! cargo run --release --example robustness
//! ```

use rmps::algorithms::{run, Algorithm};
use rmps::config::RunConfig;
use rmps::input::{generate, Distribution};

fn main() {
    let mut cfg = RunConfig::default().with_p(1 << 6).with_n_per_pe(1 << 9);
    cfg.mem_cap_factor = Some(16.0); // tight memory: nonrobust algos crash

    let algos = [
        Algorithm::RQuick,
        Algorithm::NtbQuick,
        Algorithm::Rams,
        Algorithm::NtbAms,
        Algorithm::HykSort,
        Algorithm::SSort,
        Algorithm::Rfis,
        Algorithm::Bitonic,
    ];
    let instances = [
        Distribution::Uniform,
        Distribution::Staggered,
        Distribution::Mirrored,
        Distribution::BucketSorted,
        Distribution::DeterDupl,
        Distribution::Zero,
        Distribution::AllToOne,
    ];

    // baseline: RQuick on Uniform
    let base = run(Algorithm::RQuick, &cfg, generate(&cfg, Distribution::Uniform)).time;

    println!(
        "slowdown vs RQuick/Uniform on p={} n/p={} (✗ = crash/OOM, ! = unbalanced)",
        cfg.p, cfg.n_per_pe
    );
    print!("{:>12}", "");
    for d in &instances {
        print!("{:>14}", d.name());
    }
    println!();
    for alg in algos {
        print!("{:>12}", alg.name());
        for &d in &instances {
            let r = run(alg, &cfg, generate(&cfg, d));
            let cell = if r.crashed.is_some() {
                "✗".to_string()
            } else if !r.validation.ok() {
                "✗✗".to_string()
            } else if !r.validation.balanced {
                format!("{:.1}!", r.time / base)
            } else {
                format!("{:.1}", r.time / base)
            };
            print!("{cell:>14}");
        }
        println!();
    }
    println!("\nreading: the R-prefixed (robust) rows survive every column;");
    println!("the nonrobust rows crash (✗) or unbalance (!) on the right half.");
}
