//! End-to-end driver: space-filling-curve load rebalancing — the paper's
//! §I motivating application ("many applications perform load (re)balancing
//! by mapping objects to space filling curves and sorting them").
//!
//! A particle simulation runs on the virtual machine: each PE owns a set of
//! 2-D particles, every step the particles drift, get re-encoded as Morton
//! (Z-order) keys, and the *whole machine sorts the keys* so every PE ends
//! up with a contiguous, balanced chunk of the curve. The sort is executed
//! by the robust selector and, optionally, the PJRT/XLA local-sort backend
//! (`--xla`), putting the AOT Pallas artifact on the hot path.
//!
//! Reports per-step simulated sort time, throughput, and balance — the
//! headline metric EXPERIMENTS.md records for the end-to-end validation.
//!
//! ```sh
//! cargo run --release --example sfc_rebalance [steps] [--xla]
//! ```

use rmps::algorithms::{Algorithm, Runner};
use rmps::config::RunConfig;
use rmps::elements::Elem;
use rmps::localsort::{RustSort, SortBackend};
use rmps::rng::Rng;

/// Interleave the low 16 bits of x and y: the Morton / Z-order key.
fn morton(x: u16, y: u16) -> u64 {
    fn spread(v: u16) -> u64 {
        let mut v = v as u64;
        v = (v | (v << 16)) & 0x0000_FFFF_0000_FFFF;
        v = (v | (v << 8)) & 0x00FF_00FF_00FF_00FF;
        v = (v | (v << 4)) & 0x0F0F_0F0F_0F0F_0F0F;
        v = (v | (v << 2)) & 0x3333_3333_3333_3333;
        v = (v | (v << 1)) & 0x5555_5555_5555_5555;
        v
    }
    (spread(x) << 1) | spread(y)
}

#[derive(Clone, Copy)]
struct Particle {
    x: f64,
    y: f64,
    vx: f64,
    vy: f64,
}

/// Pick the local-sort backend. The PJRT path exists only in builds with
/// the `xla` cargo feature; without it `--xla` falls back to pdqsort.
#[cfg(feature = "xla")]
fn make_backend(use_xla: bool) -> Box<dyn SortBackend> {
    if use_xla {
        match rmps::runtime::XlaSort::from_env() {
            Ok(b) => {
                println!("local sort backend: PJRT/XLA Pallas bitonic (AOT artifacts)");
                return Box::new(b);
            }
            Err(e) => println!("XLA backend unavailable ({e}); falling back to pdqsort"),
        }
    } else {
        println!("local sort backend: rust pdqsort (use --xla for the PJRT path)");
    }
    Box::new(RustSort)
}

#[cfg(not(feature = "xla"))]
fn make_backend(use_xla: bool) -> Box<dyn SortBackend> {
    if use_xla {
        println!("built without the `xla` feature; using rust pdqsort");
    } else {
        println!("local sort backend: rust pdqsort (build with --features xla for PJRT)");
    }
    Box::new(RustSort)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.iter().skip(1).find_map(|s| s.parse().ok()).unwrap_or(10);
    let use_xla = args.iter().any(|a| a == "--xla");

    let p = 1 << 8;
    let per_pe = 1 << 9;
    let cfg = RunConfig::default().with_p(p).with_n_per_pe(per_pe);
    // one runner drives every rebalancing step: the simulated machine (and
    // its scratch) is reused across the whole loop; validation stays on
    // (the default) because each step asserts the sort succeeded
    let mut runner = Runner::new(cfg.clone()).backend(make_backend(use_xla));

    // initial particles: a hot cluster near the origin → heavy skew, the
    // case SFC rebalancing exists for
    let mut rng = Rng::seeded(7, 0);
    let mut particles: Vec<Vec<Particle>> = (0..p)
        .map(|pe| {
            (0..per_pe)
                .map(|_| {
                    let cluster = pe % 7 == 0;
                    let scale = if cluster { 0.05 } else { 1.0 };
                    Particle {
                        x: rng.unit_f64() * scale,
                        y: rng.unit_f64() * scale,
                        vx: (rng.unit_f64() - 0.5) * 0.02,
                        vy: (rng.unit_f64() - 0.5) * 0.02,
                    }
                })
                .collect()
        })
        .collect();

    println!(
        "SFC rebalancing: {p} PEs × {per_pe} particles, {steps} steps\n{:>5} {:>14} {:>12} {:>10} {:>10}",
        "step", "sort time", "elem/unit", "ε before", "ε after"
    );

    let mut total_time = 0.0;
    let n_total = (p * per_pe) as f64;
    for step in 0..steps {
        // drift
        for local in particles.iter_mut() {
            for q in local.iter_mut() {
                q.x = (q.x + q.vx).rem_euclid(1.0);
                q.y = (q.y + q.vy).rem_euclid(1.0);
            }
        }
        // encode Morton keys; the element id carries (pe, idx) so we can
        // permute the actual particles after the key sort
        // the element id is the index into `flat` (PE loads drift slightly
        // after each rebalancing, so a running counter, not pe·per_pe+i)
        let mut flat: Vec<Particle> = Vec::with_capacity(p * per_pe);
        let input: Vec<Vec<Elem>> = particles
            .iter()
            .map(|local| {
                local
                    .iter()
                    .map(|q| {
                        let id = flat.len() as u64;
                        flat.push(*q);
                        let key = morton((q.x * 65535.0) as u16, (q.y * 65535.0) as u16);
                        Elem::with_id(key, id)
                    })
                    .collect()
            })
            .collect();
        let eps_before = imbalance_by_curve(&input, p);

        let report = runner.run_algorithm(Algorithm::Robust, input);
        assert!(report.succeeded(), "sort failed at step {step}: {:?}", report.crashed);
        total_time += report.time;

        // redistribute the particles to match the sorted key order
        let mut new_particles: Vec<Vec<Particle>> = Vec::with_capacity(p);
        for pe_out in 0..p {
            new_particles.push(
                report.output[pe_out]
                    .iter()
                    .map(|e| flat[e.id as usize])
                    .collect(),
            );
        }
        particles = new_particles;
        let eps_after = report.validation.imbalance.epsilon;
        println!(
            "{step:>5} {:>14.3e} {:>12.2} {:>10.3} {:>10.3}",
            report.time,
            n_total / report.time,
            eps_before,
            eps_after
        );
    }
    println!(
        "\ntotal simulated sort time over {steps} steps: {total_time:.3e} model units"
    );
    println!("throughput: {:.2} sorted elements per model unit", n_total * steps as f64 / total_time);
}

/// how unevenly the curve-contiguous chunks would land without sorting:
/// measure per-PE load if keys were range-partitioned naively
fn imbalance_by_curve(input: &[Vec<Elem>], p: usize) -> f64 {
    let mut loads = vec![0usize; p];
    for local in input {
        for e in local {
            let bucket = ((e.key as u128 * p as u128) >> 32) as usize;
            loads[bucket.min(p - 1)] += 1;
        }
    }
    rmps::metrics::Imbalance::from_loads(loads).epsilon
}

